//! Property-based tests over coordinator/simulator invariants (proptest is
//! not in the offline crate set; `splitplace::testutil::check` provides the
//! seeded-case driver — failures report the case seed for replay).

use splitplace::chaos::{
    self, BugKind, ChaosEvent, ChaosOptions, FaultPlan, Profile, TimedEvent,
};
use splitplace::cluster::build_fleet;
use splitplace::config::{
    ClusterConfig, EnvConstraint, ExperimentConfig, MabConfig, PolicyKind, SimConfig,
    WorkloadConfig,
};
use splitplace::coordinator::{LatMemSplitter, SplitCtx, Splitter};
use splitplace::harness::{Cell, CellSummary, Scenario};
use splitplace::mab::{Bandit, Context, MabPolicy, Mode};
use splitplace::placement::{BestFitPlacer, FeatureLayout, Placer, PlacementInput, SlotInfo};
use splitplace::sim::{CompletedTask, ContainerState, Engine, WorkerSnapshot};
use splitplace::splits::{App, Registry, SplitDecision, APPS};
use splitplace::testutil::check;
use splitplace::util::rng::Rng;
use splitplace::workload::generator::Generator;
use splitplace::workload::Task;

fn rand_app(rng: &mut Rng) -> App {
    APPS[rng.below(3) as usize]
}

fn rand_decision(rng: &mut Rng) -> SplitDecision {
    [
        SplitDecision::Layer,
        SplitDecision::Semantic,
        SplitDecision::Compressed,
        SplitDecision::Full,
    ][rng.below(4) as usize]
}

/// Engine + random admissions + random (feasibility-checked) placements.
fn random_engine(rng: &mut Rng, intervals: usize) -> (Engine, usize) {
    let cluster = build_fleet(&ClusterConfig::small());
    let mut engine = Engine::new(cluster, SimConfig::default(), rng.next_u64());
    let mut admitted = 0;
    for i in 0..intervals {
        let n = rng.below(4);
        for j in 0..n {
            let task = Task {
                id: (i * 10 + j as usize) as u64,
                app: rand_app(rng),
                batch: rng.int_range(16_000, 64_000) as u64,
                sla: rng.range(1.0, 15.0),
                arrival_s: engine.now_s,
                decision: None,
            };
            engine.admit(task, rand_decision(rng));
            admitted += 1;
        }
        let mut assigns: Vec<(usize, usize)> = Vec::new();
        for c in engine.placeable() {
            if rng.chance(0.8) {
                assigns.push((c, rng.below(10) as usize));
            }
        }
        engine.apply_placement(&assigns);
        engine.step_interval();
    }
    (engine, admitted)
}

#[test]
fn prop_no_task_lost_or_duplicated() {
    check(
        "task-conservation",
        20,
        |rng| random_engine(rng, 12),
        |(engine, admitted)| {
            // every admitted task is either active or fully completed;
            // container states are consistent with task bookkeeping
            let mut per_task: std::collections::HashMap<u64, (usize, usize)> =
                std::collections::HashMap::new();
            for c in engine.containers() {
                let e = per_task.entry(c.task_id).or_insert((0, 0));
                e.0 += 1;
                if c.is_done() {
                    e.1 += 1;
                }
            }
            if per_task.len() != *admitted {
                return Err(format!(
                    "admitted {admitted} tasks but engine tracks {}",
                    per_task.len()
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_capacity_never_exceeded_at_allocation() {
    check(
        "allocation-capacity",
        20,
        |rng| {
            let cluster = build_fleet(&ClusterConfig::small());
            let mut engine = Engine::new(cluster, SimConfig::default(), rng.next_u64());
            for j in 0..12 {
                let task = Task {
                    id: j,
                    app: rand_app(rng),
                    batch: 64_000,
                    sla: 5.0,
                    arrival_s: 0.0,
                    decision: None,
                };
                engine.admit(task, rand_decision(rng));
            }
            let assigns: Vec<(usize, usize)> = engine
                .placeable()
                .into_iter()
                .map(|c| (c, rng.below(10) as usize))
                .collect();
            engine.apply_placement(&assigns);
            engine
        },
        |engine| {
            let resident = engine.resident_ram();
            for (w, worker) in engine.cluster.workers.iter().enumerate() {
                let cap = worker.spec.ram_mb * splitplace::sim::RAM_OVERCOMMIT;
                // a single container may legitimately exceed cap on its own
                // only if it was the first (engine admits |c| <= cap slack);
                // the invariant: resident never exceeds cap + one container
                if resident[w] > cap + 1e-6 {
                    // check it's not due to a single oversized container
                    let on_w: Vec<f64> = engine
                        .containers()
                        .iter()
                        .filter(|c| c.worker == Some(w) && c.is_active())
                        .map(|c| c.ram_mb)
                        .collect();
                    let max_single = on_w.iter().cloned().fold(0.0, f64::max);
                    if resident[w] - max_single > cap {
                        return Err(format!(
                            "worker {w}: resident {} > cap {cap}",
                            resident[w]
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_layer_precedence_never_violated() {
    check(
        "chain-precedence",
        20,
        |rng| random_engine(rng, 10).0,
        |engine| {
            for c in engine.containers() {
                if let Some(prev) = c.prev {
                    let prev_done = engine.containers()[prev].is_done();
                    let started = !matches!(
                        c.state,
                        ContainerState::Blocked | ContainerState::Queued
                    ) || c.mi_done > 0.0;
                    // a successor that has started (or moved past Blocked)
                    // requires its predecessor to be complete
                    if c.mi_done > 0.0 && !prev_done {
                        return Err(format!(
                            "container {} progressed before predecessor {prev} finished",
                            c.id
                        ));
                    }
                    if matches!(c.state, ContainerState::Running) && !prev_done {
                        return Err(format!("container {} running before {prev} done", c.id));
                    }
                    let _ = started;
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_completed_task_times_consistent() {
    check(
        "time-decomposition",
        15,
        |rng| {
            let (engine, _) = random_engine(rng, 25);
            engine
        },
        |_engine| Ok(()), // engine state checked during run below
    );
    // stronger: responses are positive and decomposition parts are
    // non-negative on a seeded full run
    let mut rng = Rng::new(99);
    let cluster = build_fleet(&ClusterConfig::small());
    let mut engine = Engine::new(cluster, SimConfig::default(), 5);
    let mut completed: Vec<CompletedTask> = Vec::new();
    for i in 0..30 {
        let task = Task {
            id: i,
            app: rand_app(&mut rng),
            batch: 32_000,
            sla: 6.0,
            arrival_s: engine.now_s,
            decision: None,
        };
        engine.admit(task, SplitDecision::Layer);
        let assigns: Vec<(usize, usize)> = engine
            .placeable()
            .into_iter()
            .map(|c| (c, rng.below(10) as usize))
            .collect();
        engine.apply_placement(&assigns);
        completed.extend(engine.step_interval().completed);
    }
    assert!(!completed.is_empty());
    for t in &completed {
        assert!(t.response > 0.0, "response must be positive");
        assert!(t.wait >= 0.0 && t.exec > 0.0 && t.transfer >= 0.0 && t.migrate >= 0.0);
        assert!(
            t.response + 1e-6 >= t.exec / 3.0,
            "response can't be wildly below exec"
        );
        assert!(!t.workers.is_empty());
    }
}

#[test]
fn prop_mab_rewards_bounded() {
    check(
        "mab-reward-bounds",
        50,
        |rng| {
            let mut tasks = Vec::new();
            for i in 0..rng.int_range(1, 20) {
                tasks.push(CompletedTask {
                    task_id: i as u64,
                    app: rand_app(rng),
                    decision: if rng.chance(0.5) {
                        SplitDecision::Layer
                    } else {
                        SplitDecision::Semantic
                    },
                    batch: rng.int_range(16_000, 64_000) as u64,
                    sla: rng.range(0.5, 20.0),
                    response: rng.range(0.1, 25.0),
                    wait: rng.range(0.0, 3.0),
                    exec: rng.range(0.1, 20.0),
                    transfer: rng.range(0.0, 2.0),
                    migrate: 0.0,
                    workers: vec![0],
                    accuracy: rng.f64(),
                });
            }
            tasks
        },
        |tasks| {
            let mut bandit = Bandit::new(0.3);
            let tagged: Vec<(Context, &CompletedTask)> = tasks
                .iter()
                .map(|t| (Context::of(t.sla, 5.0), t))
                .collect();
            let o = bandit.update(&tagged);
            if !(0.0..=1.0).contains(&o) {
                return Err(format!("O^MAB {o} out of [0,1]"));
            }
            for c in 0..2 {
                for a in 0..2 {
                    if !(0.0..=1.0).contains(&bandit.q[c][a]) {
                        return Err(format!("Q[{c}][{a}] = {} out of [0,1]", bandit.q[c][a]));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_mab_policy_decisions_are_arms() {
    check(
        "mab-decisions-valid",
        30,
        |rng| {
            let mode = if rng.chance(0.5) { Mode::Train } else { Mode::Test };
            let mut policy = MabPolicy::new(MabConfig::default(), mode);
            let mut ds = Vec::new();
            for i in 0..50 {
                let t = Task {
                    id: i,
                    app: rand_app(rng),
                    batch: rng.int_range(16_000, 64_000) as u64,
                    sla: rng.range(0.5, 20.0),
                    arrival_s: 0.0,
                    decision: None,
                };
                ds.push(policy.decide(&t));
            }
            ds
        },
        |ds| {
            for d in ds {
                if !matches!(d, SplitDecision::Layer | SplitDecision::Semantic) {
                    return Err(format!("MAB produced non-arm decision {d:?}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_placement_outputs_feasible_and_unique() {
    check(
        "placement-feasible",
        40,
        |rng| {
            let n = rng.int_range(2, 20) as usize;
            let slots: Vec<SlotInfo> = (0..rng.int_range(1, 30) as usize)
                .map(|i| SlotInfo {
                    cid: i,
                    prev_worker: None,
                    decision: SplitDecision::Layer,
                    mi_remaining: rng.range(1e5, 5e6),
                    ram_mb: rng.range(50.0, 6000.0),
                    input_mb: rng.range(1.0, 300.0),
                    remaining_frac: rng.f64(),
                })
                .collect();
            let caps: Vec<f64> = (0..n).map(|_| rng.range(2000.0, 8000.0)).collect();
            let resident: Vec<f64> = caps.iter().map(|c| rng.range(0.0, *c)).collect();
            (slots, caps, resident, rng.next_u64())
        },
        |(slots, caps, resident, seed)| {
            let snaps =
                vec![WorkerSnapshot { cpu: 0.5, ram: 0.5, net: 0.0, disk: 0.0, containers: 0 }; caps.len()];
            let input = PlacementInput {
                snapshots: &snaps,
                slots: slots.clone(),
                ram_capacity: caps.clone(),
                resident_ram: resident.clone(),
                overcommit: 2.0,
            };
            let mut placer = BestFitPlacer::new();
            let out = placer.place(&input);
            // no duplicate containers
            let mut seen = std::collections::HashSet::new();
            for (cid, w) in &out {
                if !seen.insert(*cid) {
                    return Err(format!("container {cid} placed twice"));
                }
                if *w >= caps.len() {
                    return Err(format!("invalid worker {w}"));
                }
            }
            // cumulative feasibility
            let mut extra = vec![0.0; caps.len()];
            for (cid, w) in &out {
                let slot = slots.iter().find(|s| s.cid == *cid).unwrap();
                extra[*w] += slot.ram_mb;
                if resident[*w] + extra[*w] > caps[*w] * 2.0 + 1e-6 {
                    return Err(format!(
                        "worker {w} over capacity (seed {seed:#x})"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_feature_vector_always_bounded() {
    check(
        "features-bounded",
        40,
        |rng| {
            let h = rng.int_range(2, 12) as usize;
            let m = rng.int_range(2, 20) as usize;
            let layout = FeatureLayout::new(h, m);
            let snaps: Vec<WorkerSnapshot> = (0..h)
                .map(|_| WorkerSnapshot {
                    cpu: rng.range(0.0, 1.5),
                    ram: rng.range(0.0, 3.0),
                    net: rng.range(0.0, 2.0),
                    disk: rng.range(0.0, 2.0),
                    containers: rng.below(5) as usize,
                })
                .collect();
            let n_slots = rng.below(m as u64 + 1) as usize;
            let slots: Vec<SlotInfo> = (0..n_slots)
                .map(|i| SlotInfo {
                    cid: i,
                    prev_worker: None,
                    decision: rand_decision(rng),
                    mi_remaining: rng.range(0.0, 1e9),
                    ram_mb: rng.range(0.0, 50_000.0),
                    input_mb: rng.range(0.0, 10_000.0),
                    remaining_frac: rng.range(-1.0, 2.0),
                })
                .collect();
            let p: Vec<f32> = (0..layout.placement_dim())
                .map(|_| rng.f64() as f32)
                .collect();
            (layout, snaps, slots, p)
        },
        |(layout, snaps, slots, p)| {
            let x = layout.featurize(snaps, slots, p, true);
            if x.len() != layout.feature_dim() {
                return Err("wrong feature dim".into());
            }
            for (i, v) in x.iter().enumerate() {
                if !v.is_finite() || *v < -0.001 || *v > 2.001 {
                    return Err(format!("feature {i} out of range: {v}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_generator_stays_in_spec() {
    check(
        "generator-spec",
        25,
        |rng| {
            let cfg = WorkloadConfig {
                lambda: rng.range(0.5, 40.0),
                batch_min: 16_000,
                batch_max: 64_000,
                app_weights: [rng.f64() + 0.01, rng.f64() + 0.01, rng.f64() + 0.01],
                sla_lo: 0.5,
                sla_hi: 2.0,
                seed: rng.next_u64(),
            };
            let mut g = Generator::new(cfg);
            (0..200).map(|i| g.one(i as f64)).collect::<Vec<Task>>()
        },
        |tasks| {
            for t in tasks {
                if !(16_000..=64_000).contains(&t.batch) {
                    return Err(format!("batch {} out of range", t.batch));
                }
                if t.sla <= 0.0 || !t.sla.is_finite() {
                    return Err(format!("bad sla {}", t.sla));
                }
            }
            Ok(())
        },
    );
}

fn chaos_cfg(intervals: usize, lambda: f64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::small();
    cfg.policy = PolicyKind::ModelCompression; // runs without artifacts
    cfg.sim.intervals = intervals;
    cfg.workload.lambda = lambda;
    cfg
}

#[test]
fn prop_chaos_replay_is_deterministic_and_green() {
    check(
        "chaos-determinism",
        6,
        |rng| rng.next_u64() % 10_000,
        |seed| {
            let cfg = chaos_cfg(8, 3.0);
            let plan =
                FaultPlan::generate(*seed, 8, Profile::Heavy, cfg.cluster.total_workers());
            let opts = ChaosOptions::default();
            let a = chaos::run_chaos(&cfg, &plan, &opts, None).map_err(|e| e.to_string())?;
            let b = chaos::run_chaos(&cfg, &plan, &opts, None).map_err(|e| e.to_string())?;
            if a.signatures != b.signatures {
                return Err(format!(
                    "same seed + plan must replay identically (plan seed {seed})"
                ));
            }
            if !a.violations.is_empty() {
                return Err(format!("clean engine violated invariants: {:?}", a.violations));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_chaos_shrink_preserves_the_violated_oracle() {
    check(
        "chaos-shrink",
        3,
        |rng| rng.next_u64() % 1_000,
        |seed| {
            let cfg = chaos_cfg(8, 6.0);
            let n = cfg.cluster.total_workers();
            // a generated plan as decoys, plus a crash of every worker —
            // under the skip-crash-requeue bug something must keep running
            // on a dead machine
            let base = FaultPlan::generate(*seed, 8, Profile::Light, n);
            let mut events = base.events.clone();
            for w in 0..n {
                events.push(TimedEvent { t: 2, event: ChaosEvent::Crash { worker: w } });
            }
            events.sort_by_key(|e| e.t);
            let plan = base.with_events(events);
            let opts =
                ChaosOptions { bug: Some(BugKind::SkipCrashRequeue), ..Default::default() };

            let out = chaos::run_chaos(&cfg, &plan, &opts, None).map_err(|e| e.to_string())?;
            let Some(first) = out.violations.first() else {
                return Err("injected bug was not caught by any oracle".into());
            };
            let oracle = first.oracle;

            let shrunk = chaos::shrink_to_minimal(&cfg, &plan, &opts, None, oracle);
            if shrunk.plan.events.len() > plan.events.len() {
                return Err("shrinking must never grow the plan".into());
            }
            if shrunk.plan.events.len() > 3 {
                return Err(format!(
                    "counterexample should be minimal, got {} events",
                    shrunk.plan.events.len()
                ));
            }
            let replay =
                chaos::run_chaos(&cfg, &shrunk.plan, &opts, None).map_err(|e| e.to_string())?;
            if !replay.violations.iter().any(|v| v.oracle == oracle) {
                return Err(format!(
                    "shrunk counterexample no longer violates '{oracle}'"
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_shrink_output_is_1_minimal() {
    // ddmin's contract: removing ANY single event from the shrunk plan no
    // longer satisfies the failure predicate. Checked against two
    // predicate shapes over seeded generated plans.
    check(
        "shrink-1-minimal",
        12,
        |rng| {
            let plan = FaultPlan::generate(rng.next_u64() % 10_000, 40, Profile::Heavy, 8);
            // predicate A: a random subset of 1..=3 events must survive
            let k = rng.int_range(1, 3) as usize;
            let mut required = Vec::new();
            for _ in 0..k.min(plan.events.len()) {
                required.push(plan.events[rng.below(plan.events.len() as u64) as usize]);
            }
            // predicate B threshold: at least m crash events
            let m = rng.int_range(1, 3) as usize;
            (plan, required, m)
        },
        |(plan, required, m)| {
            if plan.events.is_empty() || required.is_empty() {
                return Ok(());
            }
            let holds_a = |p: &FaultPlan| required.iter().all(|e| p.events.contains(e));
            let crashes = |p: &FaultPlan| {
                p.events
                    .iter()
                    .filter(|e| matches!(e.event, ChaosEvent::Crash { .. }))
                    .count()
            };
            let holds_b = |p: &FaultPlan| crashes(p) >= *m;
            for (name, pred) in [
                ("subset", &holds_a as &dyn Fn(&FaultPlan) -> bool),
                ("crash-count", &holds_b),
            ] {
                if !pred(plan) {
                    continue; // plan doesn't fail this predicate at all
                }
                let shrunk = chaos::shrink_plan(plan, 100_000, |p| pred(p));
                if !pred(&shrunk.plan) {
                    return Err(format!("{name}: shrunk plan no longer fails"));
                }
                for i in 0..shrunk.plan.events.len() {
                    let mut events = shrunk.plan.events.clone();
                    events.remove(i);
                    if pred(&shrunk.plan.with_events(events)) {
                        return Err(format!(
                            "{name}: not 1-minimal — event {i} of {} is removable",
                            shrunk.plan.events.len()
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_rack_failure_plans_replay_identically_and_green() {
    // determinism property for CorrelatedRackFailure (ROADMAP follow-up):
    // seeded rack-only plans replay bit-identically and a correct engine
    // keeps every oracle green, including the plan-ledger one.
    check(
        "rack-failure-determinism",
        5,
        |rng| {
            let intervals = 10usize;
            let mut events = Vec::new();
            let mut t = 1usize;
            while t + 2 < intervals {
                let rack = rng.below(4) as usize;
                let d = 1 + rng.below(2) as usize;
                events.push(TimedEvent { t, event: ChaosEvent::CorrelatedRackFailure { rack } });
                events.push(TimedEvent { t: t + d, event: ChaosEvent::RackRecover { rack } });
                t += d + 1 + rng.below(3) as usize;
            }
            events.sort_by_key(|e| e.t);
            (FaultPlan::empty(rng.next_u64() % 1000, intervals).with_events(events), intervals)
        },
        |(plan, intervals)| {
            let cfg = chaos_cfg(*intervals, 3.0);
            let opts = ChaosOptions::default();
            let a = chaos::run_chaos(&cfg, plan, &opts, None).map_err(|e| e.to_string())?;
            let b = chaos::run_chaos(&cfg, plan, &opts, None).map_err(|e| e.to_string())?;
            if a.signatures != b.signatures {
                return Err("rack-failure plan must replay identically".into());
            }
            if !a.violations.is_empty() {
                return Err(format!("clean engine violated: {:?}", a.violations));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_clock_skew_plans_replay_identically_and_green() {
    // determinism property for ClockSkew (ROADMAP follow-up): seeded
    // skew-only plans replay bit-identically and stay green.
    check(
        "clock-skew-determinism",
        5,
        |rng| {
            let intervals = 10usize;
            let mut events = Vec::new();
            for _ in 0..4 {
                let w = rng.below(10) as usize;
                let t = 1 + rng.below(intervals as u64 - 3) as usize;
                let d = 1 + rng.below(2) as usize;
                events.push(TimedEvent {
                    t,
                    event: ChaosEvent::ClockSkew { worker: w, offset_s: rng.range(5.0, 120.0) },
                });
                events.push(TimedEvent {
                    t: t + d,
                    event: ChaosEvent::ClockSkew { worker: w, offset_s: 0.0 },
                });
            }
            events.sort_by_key(|e| e.t);
            (FaultPlan::empty(rng.next_u64() % 1000, intervals).with_events(events), intervals)
        },
        |(plan, intervals)| {
            let cfg = chaos_cfg(*intervals, 3.0);
            let opts = ChaosOptions::default();
            let a = chaos::run_chaos(&cfg, plan, &opts, None).map_err(|e| e.to_string())?;
            let b = chaos::run_chaos(&cfg, plan, &opts, None).map_err(|e| e.to_string())?;
            if a.signatures != b.signatures {
                return Err("clock-skew plan must replay identically".into());
            }
            if !a.violations.is_empty() {
                return Err(format!("clean engine violated: {:?}", a.violations));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_payload_corruption_plans_replay_identically_and_green() {
    // determinism property for PayloadCorruption: seeded corruption-only
    // plans replay bit-identically, stay green on a correct engine, and
    // never let a corrupted task complete (conservation holds because the
    // task surfaces through `failed` instead).
    check(
        "payload-corruption-determinism",
        5,
        |rng| {
            let intervals = 10usize;
            let mut events = Vec::new();
            for _ in 0..6 {
                let w = rng.below(10) as usize;
                let t = 1 + rng.below(intervals as u64 - 2) as usize;
                events.push(TimedEvent { t, event: ChaosEvent::PayloadCorruption { worker: w } });
            }
            events.sort_by_key(|e| e.t);
            (FaultPlan::empty(rng.next_u64() % 1000, intervals).with_events(events), intervals)
        },
        |(plan, intervals)| {
            let mut cfg = ExperimentConfig::small();
            cfg.policy = PolicyKind::ModelCompression;
            cfg.sim.intervals = *intervals;
            cfg.workload.lambda = 4.0;
            let opts = ChaosOptions::default();
            let a = chaos::run_chaos(&cfg, plan, &opts, None).map_err(|e| e.to_string())?;
            let b = chaos::run_chaos(&cfg, plan, &opts, None).map_err(|e| e.to_string())?;
            if a.signatures != b.signatures {
                return Err("payload-corruption plan must replay identically".into());
            }
            if !a.violations.is_empty() {
                return Err(format!("clean engine violated: {:?}", a.violations));
            }
            // a task that failed by corruption must never also complete
            let failed: std::collections::HashSet<u64> =
                a.signatures.iter().flat_map(|s| s.failed.iter().copied()).collect();
            let completed: std::collections::HashSet<u64> =
                a.signatures.iter().flat_map(|s| s.completed.iter().copied()).collect();
            if let Some(id) = failed.intersection(&completed).next() {
                return Err(format!("task {id} both failed and completed"));
            }
            Ok(())
        },
    );
}

/// Index-consistency under chaos-heavy fault injection: after EVERY
/// interval of a run that mixes admissions, placements, migrations,
/// crashes, rack failures, squeezes, corruption and starvation sweeps, the
/// engine's incremental indexes (active list, per-worker residency /
/// resident-RAM totals, remaining-fragment counters, task counters) must
/// exactly equal the values the old full-scan derivations recompute
/// (`Engine::verify_indices` — resident RAM compared bit-for-bit).
#[test]
fn prop_incremental_indices_match_full_scan_under_heavy_chaos() {
    check(
        "index-consistency",
        8,
        |rng| rng.next_u64(),
        |&seed| {
            let mut rng = Rng::new(seed);
            let cluster = build_fleet(&ClusterConfig::small());
            let mut engine = Engine::new(cluster, SimConfig::default(), rng.next_u64());
            let intervals = 14usize;
            let plan =
                FaultPlan::generate(rng.next_u64(), intervals, Profile::Heavy, engine.workers());
            let mut next_id = 0u64;
            for t in 0..intervals {
                for e in plan.events_at(t) {
                    for cmd in e.event.compile(engine.workers()) {
                        engine.apply(cmd);
                    }
                }
                if t % 5 == 4 {
                    engine.apply(splitplace::sim::EngineCmd::FailTasksOlderThan {
                        age_s: 3.0 * 300.0,
                    });
                }
                engine
                    .verify_indices()
                    .map_err(|e| format!("interval {t} post-faults: {e}"))?;
                for _ in 0..rng.below(4) {
                    let task = Task {
                        id: next_id,
                        app: rand_app(&mut rng),
                        batch: rng.int_range(16_000, 64_000) as u64,
                        sla: rng.range(1.0, 15.0),
                        arrival_s: engine.now_s,
                        decision: None,
                    };
                    next_id += 1;
                    engine.admit(task, rand_decision(&mut rng));
                }
                // random placements INCLUDING re-placements (migrations);
                // plain loop: chance() and below() each need &mut rng
                let mut assigns: Vec<(usize, usize)> = Vec::new();
                for c in engine.placeable() {
                    if rng.chance(0.8) {
                        assigns.push((c, rng.below(10) as usize));
                    }
                }
                engine.apply_placement(&assigns);
                engine
                    .verify_indices()
                    .map_err(|e| format!("interval {t} post-placement: {e}"))?;
                engine.step_interval();
                engine
                    .verify_indices()
                    .map_err(|e| format!("interval {t} post-step: {e}"))?;
            }
            // the run must have exercised real churn in the container pool
            if engine.containers().is_empty() {
                return Err("no containers were ever admitted".into());
            }
            Ok(())
        },
    );
}

/// Ledger-replay self-consistency with the engine's own churn active:
/// replaying the full command ledger (external + churn-origin records)
/// onto a fresh fault surface reproduces the live one exactly.
#[test]
fn prop_ledger_replay_reproduces_the_fault_surface_under_churn() {
    check(
        "ledger-replay",
        6,
        |rng| rng.next_u64(),
        |&seed| {
            let mut rng = Rng::new(seed);
            let cluster = build_fleet(&ClusterConfig::small());
            let mut engine = Engine::new(cluster, SimConfig::default(), rng.next_u64());
            engine.apply(splitplace::sim::EngineCmd::SetChurn { rate: 0.3 });
            let intervals = 12usize;
            let plan =
                FaultPlan::generate(rng.next_u64(), intervals, Profile::Heavy, engine.workers());
            for t in 0..intervals {
                for e in plan.events_at(t) {
                    for cmd in e.event.compile(engine.workers()) {
                        engine.apply(cmd);
                    }
                }
                engine.step_interval();
                let replayed = splitplace::sim::FaultSurface::replay(
                    engine.workers(),
                    engine.ledger(),
                );
                if replayed != engine.fault_surface() {
                    return Err(format!("interval {t}: ledger replay diverged"));
                }
            }
            Ok(())
        },
    );
}

/// ISSUE-5: both related-work splitter stacks replay byte-identically
/// under the HEAVY chaos profile (the ROADMAP's bar for every new policy)
/// and keep all 14 oracles green on a correct engine.
#[test]
fn prop_new_splitter_stacks_deterministic_and_green_under_heavy_chaos() {
    check(
        "new-splitter-heavy-chaos",
        3,
        |rng| rng.next_u64() % 10_000,
        |seed| {
            for policy in [PolicyKind::LatMem, PolicyKind::OnlineSplit] {
                let (cfg, plan) = Scenario::ChaosHeavy.build(policy, *seed, 10);
                let opts = ChaosOptions::default();
                let a = chaos::run_chaos(&cfg, &plan, &opts, None).map_err(|e| e.to_string())?;
                let b = chaos::run_chaos(&cfg, &plan, &opts, None).map_err(|e| e.to_string())?;
                if a.signatures != b.signatures {
                    return Err(format!("{policy:?}: heavy-chaos replay diverged (seed {seed})"));
                }
                if !a.violations.is_empty() {
                    return Err(format!("{policy:?} violated: {:?}", a.violations));
                }
                if a.admitted == 0 {
                    return Err(format!("{policy:?}: no load admitted"));
                }
            }
            Ok(())
        },
    );
}

/// ISSUE-7 tentpole contract at the cell level: the intra-interval shard
/// count is invisible in every observable — the full `CellSummary` JSON
/// (response EMA, violation rate, reward, energy, …) and the engine's
/// replay signatures are byte-identical whether the CPU phase ran serially
/// or fanned out across threads. Chaos-heavy on purpose: crashes,
/// evictions and rejoins keep the resident sets ragged, so shard
/// boundaries constantly cut through non-uniform worker ranges.
#[test]
fn prop_sharded_cells_summarize_byte_identically_to_serial() {
    check(
        "shard-vs-serial-cell-summary",
        3,
        |rng| rng.next_u64() % 10_000,
        |&seed| {
            let cell = Cell {
                policy: PolicyKind::ModelCompression,
                scenario: Scenario::ChaosHeavy,
                seed,
            };
            let opts = ChaosOptions::default();
            let run = |shards: usize| -> Result<(String, Vec<chaos::IntervalSig>), String> {
                let (mut cfg, plan) = cell.scenario.build(cell.policy, cell.seed, 10);
                cfg.sim.shards = shards;
                let out = chaos::run_chaos(&cfg, &plan, &opts, None)
                    .map_err(|e| e.to_string())?;
                let summary = CellSummary::from_outcome(&cell, 10, &out);
                Ok((summary.to_json().to_string(), out.signatures))
            };
            let (serial_json, serial_sigs) = run(1)?;
            for shards in [2usize, 7] {
                let (json, sigs) = run(shards)?;
                if json != serial_json {
                    return Err(format!(
                        "seed {seed}: {shards}-shard summary drifted from serial:\n  \
                         serial  {serial_json}\n  sharded {json}"
                    ));
                }
                if sigs != serial_sigs {
                    return Err(format!(
                        "seed {seed}: {shards}-shard signatures diverged from serial"
                    ));
                }
            }
            Ok(())
        },
    );
}

/// ISSUE-10: the mobility adversary plane is deterministic at the cell
/// level. For both mobility scenarios — fail-stop churn (`mobility-heavy`)
/// and rack handoffs (`mobility-handoff`) — rebuilding the fault plan from
/// the same cell coordinates yields the identical event stream (handoffs
/// included, and they survive the plan's JSON ledger round-trip verbatim),
/// and the full `CellSummary` JSON plus replay signatures are
/// byte-identical whether the CPU phase ran serially or across 4 shards —
/// the same purity contract `--jobs 1 == --jobs N` rests on.
#[test]
fn prop_mobility_cells_byte_identical_across_shards_and_rebuilds() {
    check(
        "mobility-cell-determinism",
        3,
        |rng| rng.next_u64() % 10_000,
        |&seed| {
            for scenario in [Scenario::MobilityHeavy, Scenario::MobilityHandoff] {
                let cell =
                    Cell { policy: PolicyKind::ModelCompression, scenario, seed };
                let (_, plan_a) = scenario.build(cell.policy, seed, 10);
                let (_, plan_b) = scenario.build(cell.policy, seed, 10);
                if plan_a.events != plan_b.events {
                    return Err(format!(
                        "{}: rebuilt plan events diverged (seed {seed})",
                        scenario.name()
                    ));
                }
                let text = plan_a.to_json().to_string();
                let back = FaultPlan::from_json(
                    &splitplace::util::json::parse(&text).map_err(|e| e.to_string())?,
                )
                .map_err(|e| e.to_string())?;
                if back.events != plan_a.events {
                    return Err(format!(
                        "{}: plan JSON round-trip mutated the event stream",
                        scenario.name()
                    ));
                }
                if scenario == Scenario::MobilityHandoff
                    && !plan_a
                        .events
                        .iter()
                        .any(|e| matches!(e.event, ChaosEvent::Handoff { .. }))
                {
                    return Err("mobility-handoff plan generated no handoffs".into());
                }
                let opts = ChaosOptions::default();
                let run = |shards: usize| -> Result<(String, Vec<chaos::IntervalSig>), String> {
                    let (mut cfg, plan) = scenario.build(cell.policy, cell.seed, 10);
                    cfg.sim.shards = shards;
                    let out = chaos::run_chaos(&cfg, &plan, &opts, None)
                        .map_err(|e| e.to_string())?;
                    let summary = CellSummary::from_outcome(&cell, 10, &out);
                    Ok((summary.to_json().to_string(), out.signatures))
                };
                let (serial_json, serial_sigs) = run(1)?;
                let (sharded_json, sharded_sigs) = run(4)?;
                if sharded_json != serial_json {
                    return Err(format!(
                        "{}: 4-shard summary drifted from serial:\n  \
                         serial  {serial_json}\n  sharded {sharded_json}",
                        scenario.name()
                    ));
                }
                if sharded_sigs != serial_sigs {
                    return Err(format!(
                        "{}: 4-shard signatures diverged from serial",
                        scenario.name()
                    ));
                }
            }
            Ok(())
        },
    );
}

/// ISSUE-5: fixed seed ⇒ byte-identical decision sequence, checked at the
/// splitter level (not just via engine signatures): the same seeded task
/// and feedback stream must produce the exact same `Vec<SplitDecision>`.
#[test]
fn prop_new_splitters_decision_streams_replay_byte_identically() {
    check(
        "new-splitter-decision-stream",
        6,
        |rng| rng.next_u64() % 100_000,
        |&seed| {
            for policy in [PolicyKind::LatMem, PolicyKind::OnlineSplit] {
                let stream = || -> Result<Vec<SplitDecision>, String> {
                    let mut cfg = ExperimentConfig::small();
                    cfg.workload.seed = seed ^ 0x5EED;
                    let mut stack = policy
                        .stack(&cfg, None, Mode::Test, true)
                        .map_err(|e| e.to_string())?;
                    let mut generator = Generator::new(cfg.workload.clone());
                    let mut rng = Rng::new(seed ^ 0xDEC1);
                    let mut decisions = Vec::new();
                    for t in 0..12 {
                        let tasks = generator.arrivals(t as f64 * 300.0);
                        let mut leaving = Vec::new();
                        for task in &tasks {
                            let d = stack.decide(task, &mut SplitCtx { rng: &mut rng });
                            decisions.push(d);
                            // synthetic feedback drawn from the same seeded
                            // stream, so both runs observe identical history
                            leaving.push(CompletedTask {
                                task_id: task.id,
                                app: task.app,
                                decision: d,
                                batch: task.batch,
                                sla: task.sla,
                                response: rng.range(0.5, 12.0),
                                wait: 0.0,
                                exec: 1.0,
                                transfer: 0.0,
                                migrate: 0.0,
                                workers: vec![0],
                                accuracy: 0.9,
                            });
                        }
                        stack.observe_interval(&leaving);
                    }
                    Ok(decisions)
                };
                let a = stream()?;
                let b = stream()?;
                if a.is_empty() {
                    return Err(format!("{policy:?}: empty decision stream (seed {seed})"));
                }
                if a != b {
                    return Err(format!("{policy:?}: decision stream diverged (seed {seed})"));
                }
                if a.iter().any(|d| !SplitDecision::ARMS.contains(d)) {
                    return Err(format!("{policy:?}: produced a non-arm decision"));
                }
            }
            Ok(())
        },
    );
}

/// ISSUE-5 structural property: LatMem never picks a split whose
/// estimated fragment RAM exceeds the fleet budget while a feasible arm
/// exists — checked over random fleets (single-worker and
/// memory-constrained included, where one arm genuinely stops fitting).
#[test]
fn prop_latmem_never_picks_a_split_exceeding_fleet_ram() {
    check(
        "latmem-ram-budget",
        25,
        |rng| {
            let presets: [[usize; 4]; 3] = [[1, 0, 0, 0], [0, 1, 0, 0], [4, 2, 2, 2]];
            let counts = presets[rng.below(3) as usize];
            let memory = rng.chance(0.5);
            let tasks: Vec<Task> = (0..12)
                .map(|i| Task {
                    id: i,
                    app: rand_app(rng),
                    batch: rng.int_range(16_000, 64_000) as u64,
                    sla: rng.range(0.2, 15.0),
                    arrival_s: 0.0,
                    decision: None,
                })
                .collect();
            (counts, memory, tasks)
        },
        |(counts, memory, tasks)| {
            let mut cfg = ExperimentConfig::small();
            cfg.cluster.counts = *counts;
            if *memory {
                cfg.cluster.constraint = EnvConstraint::Memory;
            }
            let fleet_ram = build_fleet(&cfg.cluster).total_ram_mb();
            let mut s = LatMemSplitter::new(&cfg);
            let mut rng = Rng::new(7);
            for task in tasks {
                let d = s.decide(task, &mut SplitCtx { rng: &mut rng });
                let any_fits = SplitDecision::ARMS
                    .iter()
                    .any(|&a| s.fits_fleet(task.app, task.batch, a));
                if any_fits && !s.fits_fleet(task.app, task.batch, d) {
                    return Err(format!(
                        "picked infeasible {d:?} for {:?}/{} on a {fleet_ram:.0} MB fleet",
                        task.app, task.batch
                    ));
                }
                let (total, _) = LatMemSplitter::estimated_ram_mb(task.app, task.batch, d);
                if any_fits && total > fleet_ram {
                    return Err(format!(
                        "{d:?} plan needs {total:.0} MB > fleet {fleet_ram:.0} MB",
                    ));
                }
            }
            Ok(())
        },
    );
}

/// The oracle-plane migration contract: after every interval of a
/// faulted run — including moments when the `crashed-workers-idle`
/// verdict is NON-empty (forced via the no-evict bug hook) — the
/// full-pool-scan and active-index derivations of EVERY migrated oracle
/// must return identical verdict lists: chain-precedence (terminal latch
/// included), crashed-workers-idle, allocation-capacity,
/// task-conservation (order-free — the full twin iterates a hash set),
/// and the telemetry queued count.
#[test]
fn prop_precedence_and_idle_oracles_agree_scan_vs_index() {
    use splitplace::chaos::oracle as orc;
    check(
        "oracle-scan-vs-index",
        6,
        |rng| rng.next_u64(),
        |&seed| {
            let mut rng = Rng::new(seed);
            let cluster = build_fleet(&ClusterConfig::small());
            let mut engine = Engine::new(cluster, SimConfig::default(), rng.next_u64());
            let intervals = 12usize;
            let plan =
                FaultPlan::generate(rng.next_u64(), intervals, Profile::Heavy, engine.workers());
            let agree = |engine: &Engine, t: usize| -> Result<(), String> {
                if orc::chain_precedence_full(engine) != orc::chain_precedence_indexed(engine) {
                    return Err(format!("interval {t}: chain-precedence derivations diverged"));
                }
                if orc::crashed_workers_idle_full(engine)
                    != orc::crashed_workers_idle_indexed(engine)
                {
                    return Err(format!(
                        "interval {t}: crashed-workers-idle derivations diverged"
                    ));
                }
                if orc::allocation_capacity_full(engine)
                    != orc::allocation_capacity_indexed(engine)
                {
                    return Err(format!(
                        "interval {t}: allocation-capacity derivations diverged"
                    ));
                }
                let mut tc_full = orc::task_conservation_full(engine);
                tc_full.sort();
                let mut tc_idx = orc::task_conservation_indexed(engine);
                tc_idx.sort();
                if tc_full != tc_idx {
                    return Err(format!(
                        "interval {t}: task-conservation derivations diverged"
                    ));
                }
                if orc::telemetry_queued_full(engine) != orc::telemetry_queued_indexed(engine) {
                    return Err(format!("interval {t}: queued-count derivations diverged"));
                }
                Ok(())
            };
            let mut next_id = 0u64;
            let mut forced_nonempty = false;
            for t in 0..intervals {
                for e in plan.events_at(t) {
                    for cmd in e.event.compile(engine.workers()) {
                        engine.apply(cmd);
                    }
                }
                for _ in 0..1 + rng.below(3) {
                    let task = Task {
                        id: next_id,
                        app: rand_app(&mut rng),
                        batch: rng.int_range(16_000, 64_000) as u64,
                        sla: rng.range(1.0, 15.0),
                        arrival_s: engine.now_s,
                        decision: None,
                    };
                    next_id += 1;
                    engine.admit(task, rand_decision(&mut rng));
                }
                let mut assigns: Vec<(usize, usize)> = Vec::new();
                for c in engine.placeable() {
                    if rng.chance(0.8) {
                        assigns.push((c, rng.below(10) as usize));
                    }
                }
                engine.apply_placement(&assigns);
                engine.step_interval();
                agree(&engine, t)?;
                // in the latter half, sabotage once: take a busy worker
                // offline WITHOUT evicting, so both derivations must flag
                // the same offenders — agreement on non-empty verdicts is
                // the point (first interval with in-flight work wins)
                if !forced_nonempty && t >= intervals / 2 {
                    let busy = engine
                        .containers()
                        .iter()
                        .find(|c| {
                            matches!(
                                c.state,
                                ContainerState::Running | ContainerState::Transferring { .. }
                            )
                        })
                        .and_then(|c| c.worker);
                    if let Some(w) = busy {
                        engine.apply(splitplace::sim::EngineCmd::ForceOfflineNoEvict {
                            worker: w,
                        });
                        let full = orc::crashed_workers_idle_full(&engine);
                        if full.is_empty() {
                            return Err(format!(
                                "forcing worker {w} offline left no offenders"
                            ));
                        }
                        forced_nonempty = true;
                        agree(&engine, t)?;
                        engine.apply(splitplace::sim::EngineCmd::Recover { worker: w });
                    }
                }
            }
            if !forced_nonempty {
                return Err("run never exercised a non-empty verdict".into());
            }
            Ok(())
        },
    );
}

/// End-to-end paranoid gate: full chaos runs (broker + traffic + oracle
/// plane) over random heavy plans with `paranoid: true` must stay
/// completely green — in particular no `paranoid-divergence` — proving
/// the O(active) oracle plane and the retained full-scan twins agree
/// interval by interval on the real pipeline, not just on hand-driven
/// engines.
#[test]
fn prop_paranoid_chaos_runs_have_no_scan_index_divergence() {
    check(
        "paranoid-divergence-free",
        4,
        |rng| rng.next_u64(),
        |&seed| {
            let mut cfg = ExperimentConfig::small();
            cfg.policy = PolicyKind::ModelCompression;
            cfg.sim.intervals = 10;
            cfg.workload.lambda = 4.0;
            let plan =
                FaultPlan::generate(seed, 10, Profile::Heavy, cfg.cluster.total_workers());
            let opts = ChaosOptions { paranoid: true, ..Default::default() };
            let out = chaos::run_chaos(&cfg, &plan, &opts, None).map_err(|e| e.to_string())?;
            if !out.violations.is_empty() {
                return Err(format!("paranoid run not green: {:?}", out.violations));
            }
            Ok(())
        },
    );
}

/// The decision-plane index migration's contract: over randomized fleets
/// and slot mixes — quantized values so score/RAM ties are common,
/// deliberately infeasible slots, slots sitting exactly on the overcommit
/// boundary, already-placed slots — the tournament-tree `BestFitPlacer`
/// must produce the assignment the retired full scan produces, pair for
/// pair, and its paranoid self-check must record zero divergences.
#[test]
fn prop_tournament_best_fit_assignment_identical_to_full_scan() {
    check(
        "best-fit-tree-vs-scan",
        60,
        |rng| {
            let n = rng.int_range(1, 40) as usize;
            // quantized caps/resident/cpu: equal free-RAM fractions and
            // equal scores happen constantly, exercising the strict->
            // leftmost tie-break
            let caps: Vec<f64> =
                (0..n).map(|_| 1000.0 * rng.int_range(2, 9) as f64).collect();
            let resident: Vec<f64> = caps
                .iter()
                .map(|c| 500.0 * rng.below(1 + (*c as u64) / 1000) as f64)
                .collect();
            let cpus: Vec<f64> = (0..n).map(|_| 0.1 * rng.below(5) as f64).collect();
            let m = rng.int_range(1, 30) as usize;
            let mut slots: Vec<SlotInfo> = (0..m)
                .map(|i| SlotInfo {
                    cid: i,
                    prev_worker: rng.chance(0.15).then(|| rng.below(n as u64) as usize),
                    decision: SplitDecision::Layer,
                    mi_remaining: rng.range(1e5, 5e6),
                    ram_mb: 50.0 * rng.int_range(1, 120) as f64,
                    input_mb: rng.range(1.0, 300.0),
                    remaining_frac: rng.f64(),
                })
                .collect();
            // sprinkle pathological demands: infeasible-everywhere and
            // exactly-at-the-overcommit-edge of a random worker
            for s in &mut slots {
                if rng.chance(0.1) {
                    s.ram_mb = 50_000.0;
                } else if rng.chance(0.1) {
                    let w = rng.below(n as u64) as usize;
                    s.ram_mb = caps[w] * 2.0 - resident[w];
                }
            }
            (slots, caps, resident, cpus)
        },
        |(slots, caps, resident, cpus)| {
            let snaps: Vec<WorkerSnapshot> = cpus
                .iter()
                .map(|&cpu| WorkerSnapshot { cpu, ram: 0.5, net: 0.0, disk: 0.0, containers: 0 })
                .collect();
            let input = PlacementInput {
                snapshots: &snaps,
                slots: slots.clone(),
                ram_capacity: caps.clone(),
                resident_ram: resident.clone(),
                overcommit: 2.0,
            };
            let reference = BestFitPlacer::reference_place(&input);
            let mut placer = BestFitPlacer::new();
            placer.set_paranoid(true);
            let indexed = placer.place(&input);
            if indexed != reference {
                return Err(format!(
                    "assignments diverged: tree {indexed:?} vs full scan {reference:?}"
                ));
            }
            let div = placer.take_paranoid_divergences();
            if !div.is_empty() {
                return Err(format!("paranoid twin recorded divergences: {div:?}"));
            }
            Ok(())
        },
    );
}

/// The sub-step index migration's contract, chaos-heavy: drive an engine
/// through random fault plans, admissions and placements, and after every
/// interval (a) `verify_indices` must hold — it now recomputes the
/// phase-1 `transit` and phase-3 `blocked` partitions from a full pool
/// scan — and (b) the exposed partitions must equal an independent
/// recomputation here, so the test does not lean on the engine's own
/// cross-check alone.
#[test]
fn prop_state_partitions_match_full_scan_under_chaos() {
    check(
        "state-partitions-vs-scan",
        8,
        |rng| rng.next_u64(),
        |&seed| {
            let mut rng = Rng::new(seed);
            let cluster = build_fleet(&ClusterConfig::small());
            let mut engine = Engine::new(cluster, SimConfig::default(), rng.next_u64());
            let intervals = 14usize;
            let plan =
                FaultPlan::generate(rng.next_u64(), intervals, Profile::Heavy, engine.workers());
            let mut next_id = 0u64;
            for t in 0..intervals {
                for e in plan.events_at(t) {
                    for cmd in e.event.compile(engine.workers()) {
                        engine.apply(cmd);
                    }
                }
                for _ in 0..1 + rng.below(3) {
                    let task = Task {
                        id: next_id,
                        app: rand_app(&mut rng),
                        batch: rng.int_range(16_000, 64_000) as u64,
                        sla: rng.range(1.0, 15.0),
                        arrival_s: engine.now_s,
                        decision: None,
                    };
                    next_id += 1;
                    engine.admit(task, rand_decision(&mut rng));
                }
                let mut assigns: Vec<(usize, usize)> = Vec::new();
                for c in engine.placeable() {
                    if rng.chance(0.8) {
                        assigns.push((c, rng.below(10) as usize));
                    }
                }
                engine.apply_placement(&assigns);
                if rng.chance(0.3) {
                    engine.apply(splitplace::sim::EngineCmd::FailTasksOlderThan {
                        age_s: 3.0 * engine.interval_seconds(),
                    });
                }
                engine.step_interval();
                engine
                    .verify_indices()
                    .map_err(|e| format!("interval {t}: {e}"))?;
                let want_transit: Vec<usize> = engine
                    .containers()
                    .iter()
                    .filter(|c| {
                        matches!(
                            c.state,
                            ContainerState::Queued
                                | ContainerState::Transferring { .. }
                                | ContainerState::Migrating { .. }
                        )
                    })
                    .map(|c| c.id)
                    .collect();
                if want_transit != engine.transit_ids() {
                    return Err(format!(
                        "interval {t}: transit partition {:?} != full scan {want_transit:?}",
                        engine.transit_ids()
                    ));
                }
                let want_blocked: Vec<usize> = engine
                    .containers()
                    .iter()
                    .filter(|c| matches!(c.state, ContainerState::Blocked))
                    .map(|c| c.id)
                    .collect();
                if want_blocked != engine.blocked_ids() {
                    return Err(format!(
                        "interval {t}: blocked partition {:?} != full scan {want_blocked:?}",
                        engine.blocked_ids()
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_registry_plans_internally_consistent() {
    check(
        "registry-consistency",
        20,
        |rng| (rand_app(rng), rand_decision(rng), rng.int_range(16_000, 64_000) as u64),
        |(app, decision, batch)| {
            let plan = Registry::plan(*app, *decision);
            if plan.fragments.is_empty() {
                return Err("empty plan".into());
            }
            if plan.total_mi(*batch) <= 0.0 {
                return Err("non-positive MI".into());
            }
            for f in &plan.fragments {
                if f.ram_fixed_mb <= 0.0 || f.image_mb <= 0.0 || f.mi_per_ksample <= 0.0 {
                    return Err(format!("bad fragment profile {f:?}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_traffic_models_deterministic_per_seed() {
    use splitplace::traffic::TrafficShape;
    check(
        "traffic-model-determinism",
        24,
        |rng| (rng.next_u64(), rng.range(1.0, 10.0)),
        |(seed, base)| {
            for shape in TrafficShape::all() {
                // two independent builds from the same seed: λ streams must
                // be byte-identical (the --jobs 1 == --jobs N contract rests
                // on models being pure functions of (t, seed))
                let a = shape.build(*seed);
                let b = shape.build(*seed);
                for t in 0..64 {
                    let la = a.lambda_at(t, *base);
                    let lb = b.lambda_at(t, *base);
                    if la.to_bits() != lb.to_bits() {
                        return Err(format!(
                            "{}: λ(t={t}) diverged across builds: {la} vs {lb}",
                            shape.name()
                        ));
                    }
                    if !la.is_finite() || la < 0.0 {
                        return Err(format!("{}: λ(t={t}) = {la} not a valid rate", shape.name()));
                    }
                }
                // out-of-order queries agree with in-order ones (no hidden
                // per-call state): replay t=63 first, then t=0..64
                let c = shape.build(*seed);
                let _ = c.lambda_at(63, *base);
                for t in 0..64 {
                    if c.lambda_at(t, *base).to_bits() != a.lambda_at(t, *base).to_bits() {
                        return Err(format!(
                            "{}: λ(t={t}) depends on query order",
                            shape.name()
                        ));
                    }
                }
                // task shaping is equally deterministic (HeavyTail rewrites
                // batches; the rest must leave tasks untouched)
                let wl = WorkloadConfig { seed: *seed, lambda: *base, ..WorkloadConfig::default() };
                let mut g1 = Generator::new(wl.clone());
                let mut g2 = Generator::new(wl.clone());
                let mut t1: Vec<Task> =
                    (0..8).flat_map(|t| g1.arrivals(t as f64 * 300.0)).collect();
                let mut t2: Vec<Task> =
                    (0..8).flat_map(|t| g2.arrivals(t as f64 * 300.0)).collect();
                a.shape_tasks(&mut t1);
                b.shape_tasks(&mut t2);
                if t1.len() != t2.len() {
                    return Err(format!("{}: shape_tasks changed stream length", shape.name()));
                }
                for (x, y) in t1.iter().zip(&t2) {
                    if x.id != y.id
                        || x.batch != y.batch
                        || x.sla.to_bits() != y.sla.to_bits()
                        || x.arrival_s.to_bits() != y.arrival_s.to_bits()
                    {
                        return Err(format!(
                            "{}: shape_tasks nondeterministic at task {}",
                            shape.name(),
                            x.id
                        ));
                    }
                }
            }
            // seeded shapes must actually use the seed: some pair of seeds
            // produces different streams (flat is seed-free by design)
            for shape in [TrafficShape::Diurnal, TrafficShape::Mmpp] {
                let differs = (0..8u64).any(|d| {
                    let m1 = shape.build(*seed);
                    let m2 = shape.build(seed.wrapping_add(d + 1));
                    (0..64).any(|t| {
                        m1.lambda_at(t, *base).to_bits() != m2.lambda_at(t, *base).to_bits()
                    })
                });
                if !differs {
                    return Err(format!("{}: stream ignores its seed", shape.name()));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_trace_record_replay_round_trip() {
    use splitplace::traffic::{self, TrafficShape};
    use splitplace::workload::replay::{self, Replay};
    check(
        "trace-record-replay-roundtrip",
        12,
        |rng| {
            (
                rng.next_u64(),
                rng.range(2.0, 8.0),
                TrafficShape::all()[rng.below(4) as usize],
                rng.int_range(4, 10) as usize,
            )
        },
        |(seed, lambda, shape, intervals)| {
            let wl = WorkloadConfig {
                seed: *seed,
                lambda: *lambda,
                ..WorkloadConfig::default()
            };
            let recorded = traffic::generate_trace(&wl, *shape, *intervals, 300.0);
            // recording is itself deterministic
            let again = traffic::generate_trace(&wl, *shape, *intervals, 300.0);
            if recorded.len() != again.len() {
                return Err("re-recording changed the stream length".into());
            }
            // save → load → windowed replay reproduces the stream
            // task-for-task (JSON carries floats through shortest-roundtrip
            // formatting; ids/apps/batches must survive exactly)
            let path = std::env::temp_dir().join(format!(
                "splitplace-prop-trace-{}-{}.json",
                std::process::id(),
                seed
            ));
            replay::save(&recorded, &path).map_err(|e| e.to_string())?;
            let loaded = replay::load(&path).map_err(|e| e.to_string())?;
            let _ = std::fs::remove_file(&path);
            let mut r = Replay::new(loaded, 300.0);
            let mut replayed = Vec::new();
            for _ in 0..*intervals {
                replayed.extend(r.next_interval());
            }
            if r.remaining() != 0 {
                return Err(format!(
                    "{} task(s) fell outside the recorded horizon",
                    r.remaining()
                ));
            }
            if replayed.len() != recorded.len() {
                return Err(format!(
                    "replay returned {} tasks, recorded {}",
                    replayed.len(),
                    recorded.len()
                ));
            }
            for (orig, back) in recorded.iter().zip(&replayed) {
                if orig.id != back.id || orig.app != back.app || orig.batch != back.batch {
                    return Err(format!("task {} mutated through the round-trip", orig.id));
                }
                if (orig.sla - back.sla).abs() > 1e-9
                    || (orig.arrival_s - back.arrival_s).abs() > 1e-9
                {
                    return Err(format!("task {} floats drifted through JSON", orig.id));
                }
            }
            Ok(())
        },
    );
}
