//! Cross-module integration tests: full broker runs over the real PJRT
//! runtime, failure injection on the artifact path, and end-to-end
//! serving. Tests that need artifacts skip loudly when they are missing.

use splitplace::config::{AccuracyMode, ExperimentConfig, PolicyKind};
use splitplace::coordinator::runner::{artifacts_dir, run_experiment, try_runtime};
use splitplace::runtime::{Manifest, Runtime};

fn have_artifacts() -> bool {
    let ok = try_runtime().is_some();
    if !ok {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
    }
    ok
}

#[test]
fn full_pipeline_with_measured_accuracy() {
    if !have_artifacts() {
        return;
    }
    let rt = try_runtime().unwrap();
    let mut cfg = ExperimentConfig::small();
    cfg.policy = PolicyKind::MabDaso;
    cfg.sim.intervals = 10;
    cfg.workload.lambda = 1.5;
    cfg.accuracy = AccuracyMode::Measured; // REAL fragment execution
    let out = run_experiment(cfg, Some(&rt)).unwrap();
    assert!(out.summary.tasks > 0);
    // measured accuracies must look like the manifest ladder
    assert!(
        out.summary.accuracy > 0.4 && out.summary.accuracy < 1.0,
        "accuracy {}",
        out.summary.accuracy
    );
}

#[test]
fn all_policies_complete_and_rank_sanely() {
    if !have_artifacts() {
        return;
    }
    let rt = try_runtime().unwrap();
    let mut rewards = std::collections::HashMap::new();
    for policy in PolicyKind::all() {
        let mut cfg = ExperimentConfig::small();
        cfg.policy = policy;
        cfg.sim.intervals = 15;
        cfg.workload.lambda = 1.5;
        let out = run_experiment(cfg, Some(&rt)).unwrap();
        assert!(out.summary.tasks > 0, "{policy:?} completed nothing");
        rewards.insert(policy, out.summary.avg_reward);
    }
    // weak ordering invariant that holds even on short small-cluster runs:
    // the layer-only policy cannot beat the adaptive MAB policy by much
    let md = rewards[&PolicyKind::MabDaso];
    let lg = rewards[&PolicyKind::LayerGobi];
    assert!(
        md >= lg - 0.1,
        "M+D ({md:.3}) must not trail L+G ({lg:.3}) badly"
    );
}

#[test]
fn seeded_runs_are_reproducible() {
    if !have_artifacts() {
        return;
    }
    let rt = try_runtime().unwrap();
    let run = || {
        let mut cfg = ExperimentConfig::small();
        cfg.policy = PolicyKind::Gillis; // no float-order-sensitive surrogate
        cfg.sim.intervals = 12;
        run_experiment(cfg, Some(&rt)).unwrap().summary
    };
    let a = run();
    let b = run();
    assert_eq!(a.tasks, b.tasks);
    assert!((a.avg_reward - b.avg_reward).abs() < 1e-12);
    assert!((a.response.0 - b.response.0).abs() < 1e-12);
}

// ---------------------------------------------------------------------------
// Failure injection
// ---------------------------------------------------------------------------

#[test]
fn missing_artifacts_dir_fails_cleanly() {
    let err = Manifest::load("/nonexistent/path").unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("make artifacts"), "actionable error, got: {msg}");
}

#[test]
fn corrupt_manifest_rejected() {
    let dir = std::env::temp_dir().join("splitplace_corrupt_manifest");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), "{not valid json").unwrap();
    assert!(Manifest::load(&dir).is_err());
    std::fs::write(dir.join("manifest.json"), r#"{"version":1}"#).unwrap();
    assert!(Manifest::load(&dir).is_err(), "missing keys must error");
}

#[test]
fn truncated_blob_rejected() {
    if !have_artifacts() {
        return;
    }
    let dir = std::env::temp_dir().join("splitplace_trunc_blob");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("bad.bin"), [0u8; 7]).unwrap(); // not /4
    let m = Manifest::load(artifacts_dir()).unwrap();
    // read through a manifest rooted at tmp
    let m2 = Manifest { dir: dir.clone(), ..m };
    assert!(m2.read_f32("bad.bin").is_err());
    assert!(m2.read_i32("bad.bin").is_err());
}

#[test]
fn missing_hlo_file_fails_at_compile_not_earlier() {
    if !have_artifacts() {
        return;
    }
    let rt = Runtime::load(&artifacts_dir()).unwrap();
    let err = rt.executable("does_not_exist.hlo.txt");
    assert!(err.is_err());
}

#[test]
fn gradient_policy_without_runtime_is_rejected() {
    let mut cfg = ExperimentConfig::small();
    cfg.policy = PolicyKind::MabDaso;
    let Err(err) = run_experiment(cfg, None) else {
        panic!("gradient policy must require the runtime");
    };
    assert!(format!("{err:#}").contains("runtime"));
}

#[test]
fn oversubscribed_cluster_keeps_tasks_queued_not_lost() {
    // Tiny cluster + huge lambda: most containers can't be placed; the
    // wait queue must absorb them and the engine must not panic.
    if !have_artifacts() {
        return;
    }
    let rt = try_runtime().unwrap();
    let mut cfg = ExperimentConfig::small();
    cfg.policy = PolicyKind::MabDaso;
    cfg.sim.intervals = 8;
    cfg.workload.lambda = 25.0;
    let out = run_experiment(cfg, Some(&rt)).unwrap();
    // queue grows under oversubscription
    assert!(
        out.metrics.queued.iter().copied().max().unwrap_or(0) > 0,
        "expected queueing under overload"
    );
}

#[test]
fn splitplace_survives_worker_churn() {
    // Paper §7 future work implemented: non-stationary worker population.
    // Under aggressive churn the broker must keep completing tasks
    // (checkpoint + requeue + replace), not crash or stall.
    if !have_artifacts() {
        return;
    }
    let rt = try_runtime().unwrap();
    let mut cfg = ExperimentConfig::small();
    cfg.policy = PolicyKind::MabDaso;
    cfg.sim.intervals = 20;
    cfg.workload.lambda = 1.5;
    cfg.cluster.churn_rate = 0.2;
    let out = run_experiment(cfg.clone(), Some(&rt)).unwrap();
    assert!(out.summary.tasks > 0, "tasks must still complete under churn");
    // compare with the stable fleet: churn can only hurt, never help much
    cfg.cluster.churn_rate = 0.0;
    let stable = run_experiment(cfg, Some(&rt)).unwrap();
    assert!(
        out.summary.avg_reward <= stable.summary.avg_reward + 0.1,
        "churn {} vs stable {}",
        out.summary.avg_reward,
        stable.summary.avg_reward
    );
}

#[test]
fn serving_under_concurrent_load() {
    if !have_artifacts() {
        return;
    }
    let server =
        splitplace::server::Server::start(&artifacts_dir(), "127.0.0.1:0", 3).unwrap();
    let addr = server.addr;
    let mut handles = Vec::new();
    for c in 0..3 {
        handles.push(std::thread::spawn(move || {
            let mut client = splitplace::server::Client::connect(addr).unwrap();
            let mut ok = 0;
            for i in 0..5 {
                let app = ["mnist", "fashionmnist", "cifar100"][(c + i) % 3];
                let r = client.request(app, 20_000, 5.0).unwrap();
                if r.get("ok").and_then(|b| b.as_bool().ok()) == Some(true) {
                    ok += 1;
                }
            }
            ok
        }));
    }
    let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    assert_eq!(total, 15, "all concurrent requests must succeed");
    assert_eq!(server.requests_served(), 15);
    server.shutdown();
}
