//! Bug-base regression replay: every artifact committed under
//! `tests/bugbase/` is replayed on every test run, forever.
//!
//! The contract (see `splitplace::harness::bugbase`):
//! * `expect: "green"` artifacts are shrunk scenarios that once exposed a
//!   real bug — after the fix they must stay violation-free.
//! * `expect: "violates"` artifacts pair a deliberate injected bug with
//!   the oracle that catches it — the oracle must keep firing, or the
//!   harness has silently lost detection power.

use std::path::PathBuf;

use splitplace::harness::bugbase;

fn bugbase_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests").join("bugbase")
}

#[test]
fn every_bugbase_artifact_replays_and_holds_its_expectation() {
    let records = bugbase::load_dir(&bugbase_dir()).expect("bug-base must load cleanly");
    assert!(
        !records.is_empty(),
        "tests/bugbase/ must hold at least one artifact — the replay gate \
         is pointless when empty"
    );
    let mut failures = Vec::new();
    for record in &records {
        if let Err(e) = record.replay() {
            failures.push(e);
        }
    }
    assert!(failures.is_empty(), "bug-base regressions:\n{}", failures.join("\n"));
}

#[test]
fn bugbase_covers_both_expectation_directions() {
    let records = bugbase::load_dir(&bugbase_dir()).unwrap();
    let greens = records.iter().filter(|r| r.expect == bugbase::Expectation::Green).count();
    let violates =
        records.iter().filter(|r| r.expect == bugbase::Expectation::Violates).count();
    assert!(greens > 0, "need at least one fixed-bug (green) artifact");
    assert!(violates > 0, "need at least one detection-power (violates) artifact");
}

/// End-to-end format exercise: write a fresh shrunk-style artifact, load
/// it back through the directory scanner, and replay it — the same path a
/// matrix-discovered violation takes.
#[test]
fn freshly_persisted_artifact_roundtrips_and_replays() {
    use splitplace::chaos::{BugKind, ChaosEvent, FaultPlan, TimedEvent};
    use splitplace::config::PolicyKind;
    use splitplace::harness::{BugRecord, Expectation, Scenario};

    let dir = std::env::temp_dir()
        .join(format!("splitplace-bugbase-e2e-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let record = BugRecord {
        id: "e2e__skip-crash-requeue".into(),
        oracle: "offline-matches-plan".into(),
        expect: Expectation::Violates,
        bug: Some(BugKind::ForgetRackMember),
        policy: PolicyKind::Gillis,
        scenario: Scenario::Clean,
        seed: 11,
        intervals: 6,
        task_timeout_intervals: 40,
        plan: FaultPlan::empty(11, 6).with_events(vec![TimedEvent {
            t: 1,
            event: ChaosEvent::CorrelatedRackFailure { rack: 2 },
        }]),
        note: "end-to-end format exercise".into(),
    };
    let path = bugbase::save(&dir, &record).unwrap();
    assert!(path.ends_with("e2e__skip-crash-requeue.json"));
    let loaded = bugbase::load_dir(&dir).unwrap();
    assert_eq!(loaded.len(), 1);
    assert!(loaded[0].replay().is_ok(), "{:?}", loaded[0].replay());
    let _ = std::fs::remove_dir_all(&dir);
}
