//! Refactor-parity goldens: fixed-seed trajectories for EVERY PolicyKind.
//!
//! Each policy runs one chaos-light matrix cell (seed 3, 10 intervals)
//! through the full `DecisionStack` + `EngineCmd` wiring; the per-interval
//! signature stream (completed/failed task ids, queue depth, offline
//! count, energy bits) serializes canonically and must match the golden
//! committed under `tests/goldens/parity/` byte-for-byte. Any behavioral
//! change to the decision plane, the command bus, the engine integrator or
//! the RNG stream derivation shows up here as a diff — re-record only for
//! an *intended* behavior change, and review the diff like code.
//!
//! Bootstrap: on a tree with no parity goldens (e.g. the refactor commit
//! itself was authored on a toolchain-less machine), the first `cargo
//! test` run records them and passes; commit the generated files. After
//! that the test is a byte-exact regression gate.

use std::path::PathBuf;

use splitplace::chaos::{self, ChaosOptions, IntervalSig};
use splitplace::config::PolicyKind;
use splitplace::harness::{policy_slug, Scenario};
use splitplace::util::json::Value;

const SEED: u64 = 3;
const INTERVALS: usize = 10;

fn parity_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("goldens")
        .join("parity")
}

fn sig_json(s: &IntervalSig) -> Value {
    Value::obj(vec![
        ("t", Value::Num(s.interval as f64)),
        (
            "completed",
            Value::Arr(s.completed.iter().map(|&id| Value::Num(id as f64)).collect()),
        ),
        (
            "failed",
            Value::Arr(s.failed.iter().map(|&id| Value::Num(id as f64)).collect()),
        ),
        ("queued", Value::Num(s.queued as f64)),
        ("offline", Value::Num(s.offline as f64)),
        // string: f64 bit patterns exceed 2^53
        ("energy_bits", Value::Str(s.energy_bits.to_string())),
    ])
}

/// Run one policy's parity cell and serialize its trajectory canonically.
fn trajectory(policy: PolicyKind) -> String {
    let (cfg, plan) = Scenario::ChaosLight.build(policy, SEED, INTERVALS);
    let out = chaos::run_chaos(&cfg, &plan, &ChaosOptions::default(), None)
        .unwrap_or_else(|e| panic!("{policy:?} parity run failed: {e:#}"));
    assert!(
        out.violations.is_empty(),
        "{policy:?} parity run must be green: {:?}",
        out.violations
    );
    let v = Value::obj(vec![
        ("policy", Value::Str(policy_slug(policy).to_string())),
        ("scenario", Value::Str("chaos-light".into())),
        ("seed", Value::Str(SEED.to_string())),
        ("intervals", Value::Num(INTERVALS as f64)),
        ("admitted", Value::Num(out.admitted as f64)),
        ("completed", Value::Num(out.completed as f64)),
        ("failed", Value::Num(out.failed as f64)),
        (
            "signatures",
            Value::Arr(out.signatures.iter().map(sig_json).collect()),
        ),
    ]);
    let mut text = v.to_pretty();
    text.push('\n');
    text
}

#[test]
fn fixed_seed_trajectories_match_goldens_for_every_policy() {
    let dir = parity_dir();
    let mut bootstrapped = Vec::new();
    for policy in PolicyKind::all() {
        let got = trajectory(policy);
        let path = dir.join(format!("{}.json", policy_slug(policy)));
        match std::fs::read_to_string(&path) {
            Ok(want) => assert_eq!(
                want,
                got,
                "{} trajectory drifted from its parity golden {} — an \
                 unintended behavior change, or an intended one to re-record",
                policy_slug(policy),
                path.display()
            ),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                // Bootstrap-on-first-run. NOTE: a golden recorded here
                // captures CURRENT behavior — it gates future refactors,
                // not this one; review the file before committing it.
                let write = std::fs::create_dir_all(&dir)
                    .and_then(|()| std::fs::write(&path, &got));
                if let Err(we) = write {
                    panic!(
                        "parity golden for {} is missing and could not be \
                         bootstrapped at {} ({we}); record it on a writable \
                         checkout and commit it",
                        policy_slug(policy),
                        path.display()
                    );
                }
                bootstrapped.push(path.display().to_string());
            }
            Err(e) => panic!("reading {}: {e}", path.display()),
        }
    }
    if !bootstrapped.is_empty() {
        eprintln!(
            "bootstrapped {} parity golden(s) — review and commit:\n  {}",
            bootstrapped.len(),
            bootstrapped.join("\n  ")
        );
    }
}

#[test]
fn parity_trajectories_are_deterministic_in_process() {
    for policy in [PolicyKind::MabDaso, PolicyKind::Gillis] {
        assert_eq!(trajectory(policy), trajectory(policy), "{policy:?}");
    }
}
