//! Integration tests for the scenario-matrix harness: serial-vs-parallel
//! equivalence, end-to-end golden gating, and matrix smoke health.

use splitplace::chaos::ChaosOptions;
use splitplace::config::PolicyKind;
use splitplace::harness::{
    matrix_cells, run_matrix, Cell, GoldenStatus, GoldenStore, MatrixCell, MatrixOptions,
    Scenario,
};

fn single(policy: PolicyKind, scenario: Scenario, seed: u64) -> MatrixCell {
    MatrixCell::Single(Cell { policy, scenario, seed })
}

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("splitplace-matrix-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// The headline determinism contract: the same matrix slice run with
/// `--jobs 1` and `--jobs 4` serializes to byte-identical CellSummary
/// JSON. Everything else (goldens, CI bootstrap, replay) leans on this.
#[test]
fn serial_and_parallel_runs_are_byte_identical() {
    let cells = matrix_cells("smoke", &[1]);
    assert!(cells.len() >= 8, "smoke slice unexpectedly small: {}", cells.len());
    let base = MatrixOptions { intervals: 8, ..Default::default() };
    let serial = run_matrix(&cells, &MatrixOptions { jobs: 1, ..base.clone() });
    let parallel = run_matrix(&cells, &MatrixOptions { jobs: 4, ..base });
    assert_eq!(serial.results.len(), parallel.results.len());
    let a = serial.summaries_json().to_string();
    let b = parallel.summaries_json().to_string();
    assert_eq!(a, b, "--jobs 1 and --jobs 4 must serialize identically");
    // and a re-run of either is byte-identical too (full replay stability)
    let again = run_matrix(&cells, &MatrixOptions { jobs: 4, ..MatrixOptions { intervals: 8, ..Default::default() } });
    assert_eq!(b, again.summaries_json().to_string());
}

/// Every smoke cell must run clean: no construction errors, no oracle
/// violations and no ordering failures — the matrix is the regression
/// net, so the net itself has to be green at head.
#[test]
fn smoke_matrix_is_green() {
    let cells = matrix_cells("smoke", &[1]);
    assert!(
        cells.iter().any(|c| matches!(c, MatrixCell::Diff(_))),
        "smoke must include at least one differential policy-pair cell"
    );
    let report =
        run_matrix(&cells, &MatrixOptions { jobs: 4, intervals: 8, ..Default::default() });
    assert_eq!(report.results.len(), cells.len());
    for r in &report.results {
        assert!(r.error.is_none(), "{}: {:?}", r.cell.id(), r.error);
        assert!(
            r.violations.is_empty(),
            "{}: {:?}",
            r.cell.id(),
            r.summary.violated_oracles
        );
        assert!(
            r.ordering_failures.is_empty(),
            "{}: {:?}",
            r.cell.id(),
            r.ordering_failures
        );
        // diff cells carry side-prefixed metrics
        let admitted = r
            .summary
            .metrics
            .get("admitted")
            .or_else(|| r.summary.metrics.get("a_admitted"))
            .copied()
            .unwrap_or(0.0);
        assert!(admitted > 0.0, "{}: no tasks admitted", r.cell.id());
        if let MatrixCell::Diff(_) = r.cell {
            assert!(
                r.summary.metrics.contains_key("delta_avg_reward"),
                "{}: diff cell without delta metrics",
                r.cell.id()
            );
        }
    }
    assert!(!report.failed());
}

/// Golden gating end-to-end on a real slice: record goldens, re-run and
/// match, then corrupt one golden and watch the drift gate trip.
#[test]
fn golden_gate_matches_then_catches_injected_drift() {
    let dir = tmpdir("gate");
    let cells = vec![
        single(PolicyKind::ModelCompression, Scenario::Clean, 1),
        single(PolicyKind::Gillis, Scenario::ChaosHeavy, 1),
    ];
    let record = MatrixOptions {
        jobs: 2,
        intervals: 8,
        update_goldens: true,
        goldens: Some(GoldenStore::new(&dir)),
        ..Default::default()
    };
    let rec = run_matrix(&cells, &record);
    assert!(rec.results.iter().all(|r| r.golden == GoldenStatus::Updated));
    assert!(!rec.failed(), "recording goldens must not fail the run");

    let gate = MatrixOptions {
        jobs: 2,
        intervals: 8,
        goldens: Some(GoldenStore::new(&dir)),
        ..Default::default()
    };
    let ok = run_matrix(&cells, &gate);
    assert!(
        ok.results.iter().all(|r| r.golden == GoldenStatus::Match),
        "{:?}",
        ok.results.iter().map(|r| r.golden.clone()).collect::<Vec<_>>()
    );
    assert!(!ok.failed());

    // corrupt one recorded metric → that cell must drift, the other match
    let store = GoldenStore::new(&dir);
    let stem = cells[0].file_stem();
    let mut golden = store.load(&stem).unwrap().unwrap();
    *golden.metrics.get_mut("completed").unwrap() += 1.0;
    store.save(&stem, &golden).unwrap();
    let drifted = run_matrix(&cells, &gate);
    assert!(drifted.failed(), "tampered golden must fail the gate");
    match &drifted.results[0].golden {
        GoldenStatus::Drift(msgs) => {
            assert!(msgs.iter().any(|m| m.contains("completed")), "{msgs:?}")
        }
        other => panic!("expected drift on tampered cell, got {other:?}"),
    }
    assert_eq!(drifted.results[1].golden, GoldenStatus::Match);

    // a cell with no golden at all is a gate failure, not a silent pass
    let extra = vec![single(PolicyKind::ModelCompression, Scenario::FlashCrowd, 1)];
    let missing = run_matrix(&extra, &gate);
    assert_eq!(missing.results[0].golden, GoldenStatus::Missing);
    assert!(missing.failed());
    let _ = std::fs::remove_dir_all(&dir);
}

/// A matrix cell replays identically through the chaos entry point with
/// the same plan — the contract that lets `splitplace chaos --plan`
/// reproduce any matrix finding.
#[test]
fn matrix_cell_replays_through_chaos_cli_path() {
    let cell = Cell { policy: PolicyKind::Gillis, scenario: Scenario::ChaosHeavy, seed: 2 };
    let report = run_matrix(
        &[MatrixCell::Single(cell)],
        &MatrixOptions { jobs: 1, intervals: 8, ..Default::default() },
    );
    let summary = &report.results[0].summary;
    let (cfg, plan) = cell.scenario.build(cell.policy, cell.seed, 8);
    let out = splitplace::chaos::run_chaos(&cfg, &plan, &ChaosOptions::default(), None).unwrap();
    let direct = splitplace::harness::CellSummary::from_outcome(&cell, 8, &out);
    assert_eq!(
        summary.to_json().to_string(),
        direct.to_json().to_string(),
        "matrix cell and direct chaos replay must agree byte-for-byte"
    );
}

/// fail-fast stops scheduling new cells once a failure lands.
#[test]
fn fail_fast_skips_remaining_cells() {
    // a missing-golden failure on every cell, serial so ordering is exact
    let cells = matrix_cells("smoke", &[1]);
    let dir = tmpdir("failfast");
    let opts = MatrixOptions {
        jobs: 1,
        intervals: 4,
        fail_fast: true,
        goldens: Some(GoldenStore::new(&dir)),
        ..Default::default()
    };
    let report = run_matrix(&cells, &opts);
    assert!(report.failed());
    assert_eq!(report.results.len(), 1, "first failure must stop the serial run");
    assert_eq!(report.skipped, cells.len() - 1);
    let _ = std::fs::remove_dir_all(&dir);
}
