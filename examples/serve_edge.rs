//! End-to-end serving driver (the DESIGN.md §validation workload):
//!
//!   * starts the SplitPlace serving front-end (thread-pool TCP server,
//!     one PJRT runtime per worker thread — Python nowhere in sight);
//!   * fires a batched request mix from concurrent clients with
//!     paper-style SLAs (tight deadlines → the MAB picks semantic splits,
//!     loose deadlines → layer splits);
//!   * every request executes the REAL AOT-compiled split-fragment HLOs
//!     on the 256-row held-out batch and reports measured accuracy;
//!   * prints latency percentiles, throughput, and the decision mix.
//!
//!     make artifacts && cargo run --release --example serve_edge

use std::sync::{Arc, Mutex};
use std::time::Instant;

use splitplace::coordinator::runner::{artifacts_dir, try_runtime};
use splitplace::server::{Client, Server};
use splitplace::util::rng::Rng;
use splitplace::util::stats;
use splitplace::util::table::{fnum, Table};

const CLIENTS: usize = 4;
const REQUESTS_PER_CLIENT: usize = 25;
const SERVER_THREADS: usize = 4;

fn main() -> anyhow::Result<()> {
    if try_runtime().is_none() {
        anyhow::bail!("artifacts not found — run `make artifacts` first");
    }
    let dir = artifacts_dir();
    println!("starting server ({SERVER_THREADS} worker threads, artifacts: {dir})");
    let server = Server::start(&dir, "127.0.0.1:0", SERVER_THREADS)?;
    let addr = server.addr;

    #[derive(Clone, Default)]
    struct Stats {
        latencies_ms: Vec<f64>,
        accuracies: Vec<f64>,
        decisions: std::collections::HashMap<String, usize>,
        rows: usize,
        errors: usize,
    }
    let stats = Arc::new(Mutex::new(Stats::default()));

    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..CLIENTS {
        let stats = stats.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(1000 + c as u64);
            let mut client = Client::connect(addr).expect("connect");
            for _ in 0..REQUESTS_PER_CLIENT {
                let app = *rng.choice(&["mnist", "fashionmnist", "cifar100"]);
                let batch = rng.int_range(16_000, 64_000) as u64;
                // tight or loose SLA with equal probability: exercises
                // both MAB contexts
                let sla = if rng.chance(0.5) {
                    rng.range(0.5, 0.9)
                } else {
                    rng.range(8.0, 14.0)
                };
                let t = Instant::now();
                match client.request(app, batch, sla) {
                    Ok(v) if v.get("ok").and_then(|b| b.as_bool().ok()) == Some(true) => {
                        let mut s = stats.lock().unwrap();
                        s.latencies_ms.push(t.elapsed().as_secs_f64() * 1000.0);
                        s.accuracies
                            .push(v.get("accuracy").unwrap().as_f64().unwrap());
                        let d = v.get("decision").unwrap().as_str().unwrap().to_string();
                        *s.decisions.entry(d).or_insert(0) += 1;
                        s.rows += v.get("rows").unwrap().as_f64().unwrap() as usize;
                    }
                    _ => stats.lock().unwrap().errors += 1,
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let wall = t0.elapsed().as_secs_f64();
    let s = stats.lock().unwrap().clone();
    let n = s.latencies_ms.len();

    let mut t = Table::new("Serving results", &["metric", "value"]);
    t.row(vec!["requests ok / errors".into(), format!("{n} / {}", s.errors)]);
    t.row(vec!["wall time (s)".into(), fnum(wall)]);
    t.row(vec!["throughput (req/s)".into(), fnum(n as f64 / wall)]);
    t.row(vec![
        "inference rows/s".into(),
        fnum(s.rows as f64 / wall),
    ]);
    t.row(vec!["latency p50 (ms)".into(), fnum(stats::percentile(&s.latencies_ms, 50.0))]);
    t.row(vec!["latency p95 (ms)".into(), fnum(stats::percentile(&s.latencies_ms, 95.0))]);
    t.row(vec!["latency p99 (ms)".into(), fnum(stats::percentile(&s.latencies_ms, 99.0))]);
    t.row(vec!["mean accuracy (measured)".into(), fnum(stats::mean(&s.accuracies))]);
    for (d, count) in &s.decisions {
        t.row(vec![format!("decision: {d}"), count.to_string()]);
    }
    t.print();

    assert_eq!(s.errors, 0, "all requests must succeed");
    assert!(
        s.decisions.len() >= 2,
        "mixed SLAs must produce both layer and semantic decisions: {:?}",
        s.decisions
    );
    println!("serve_edge OK — {} requests via real PJRT split-inference", n);
    server.shutdown();
    Ok(())
}
