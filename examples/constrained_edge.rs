//! Constrained-environment walkthrough (Appendix A.3, Figs. 13–14 in
//! miniature): runs SplitPlace in the normal and the three constrained
//! variants of the edge testbed and shows where the time goes — compute
//! constraints inflate execution, network constraints inflate transfers,
//! memory constraints trigger the swap-thrash path.
//!
//!     make artifacts && cargo run --release --example constrained_edge

use splitplace::config::{EnvConstraint, ExperimentConfig, PolicyKind};
use splitplace::coordinator::runner::{run_experiment, try_runtime};
use splitplace::util::table::{fnum, Table};

fn main() -> anyhow::Result<()> {
    let rt = try_runtime().ok_or_else(|| {
        anyhow::anyhow!("artifacts not found — run `make artifacts` first")
    })?;

    let mut results = Table::new(
        "SplitPlace across constrained environments",
        &["environment", "response", "SLA viol", "reward", "wait", "exec", "transfer"],
    );
    for constraint in [
        EnvConstraint::None,
        EnvConstraint::Compute,
        EnvConstraint::Network,
        EnvConstraint::Memory,
    ] {
        let mut cfg = ExperimentConfig::default();
        cfg.policy = PolicyKind::MabDaso;
        cfg.sim.intervals = 20;
        cfg.cluster.constraint = constraint;
        let out = run_experiment(cfg, Some(&rt))?;
        let s = &out.summary;
        let d = out.metrics.decomposition();
        results.row(vec![
            constraint.name().into(),
            fnum(s.response.0),
            fnum(s.sla_violations),
            fnum(s.avg_reward),
            fnum(d[0]),
            fnum(d[1]),
            fnum(d[2]),
        ]);
        eprintln!("[constrained_edge] {} done", constraint.name());
    }
    results.print();
    println!(
        "(paper A.3: constraints degrade every model, but the MAB adapts by \
         shifting the split mix toward semantic, limiting the reward drop)"
    );
    Ok(())
}
