//! MAB training curves (paper §6.3, Fig. 6): trains the two context
//! bandits with feedback-based ε-greedy exploration for 200 intervals on
//! the simulated testbed and prints the six curves of Fig. 6:
//!   (a) layer response-time estimates R^a per app,
//!   (b,c) decision counts per context,
//!   (d) ε decay and ρ growth,
//!   (e,f) Q-estimates per context.
//!
//!     make artifacts && cargo run --release --example mab_training

use splitplace::config::{ExperimentConfig, PolicyKind};
use splitplace::coordinator::Broker;
use splitplace::coordinator::runner::try_runtime;
use splitplace::mab::Mode;
use splitplace::splits::APPS;
use splitplace::util::table::{fnum, Table};

const TRAIN_INTERVALS: usize = 200;
const SAMPLE_EVERY: usize = 20;

fn main() -> anyhow::Result<()> {
    let rt = try_runtime().ok_or_else(|| {
        anyhow::anyhow!("artifacts not found — run `make artifacts` first")
    })?;

    // Train on the full 50-worker fleet (paper §6.3): an overloaded small
    // cluster inflates layer RT estimates and washes out the two contexts.
    let mut cfg = ExperimentConfig::default();
    cfg.policy = PolicyKind::MabDaso;
    cfg.sim.intervals = TRAIN_INTERVALS;

    let mut broker = Broker::new(cfg, Some(&rt), Mode::Train)?;

    let mut t = Table::new(
        "Fig. 6 — MAB training trace",
        &[
            "interval", "eps", "rho", "R_mnist", "R_fashion", "R_cifar",
            "Q[h][L]", "Q[h][S]", "Q[l][L]", "Q[l][S]",
            "N[h][L]", "N[h][S]", "N[l][L]", "N[l][S]",
        ],
    );
    for i in 0..TRAIN_INTERVALS {
        broker.step();
        if (i + 1) % SAMPLE_EVERY == 0 {
            let mab = broker.mab().unwrap();
            let b = &mab.bandit;
            t.row(vec![
                (i + 1).to_string(),
                fnum(mab.epsilon),
                fnum(mab.rho),
                fnum(mab.estimator.estimate(APPS[0])),
                fnum(mab.estimator.estimate(APPS[1])),
                fnum(mab.estimator.estimate(APPS[2])),
                fnum(b.q[0][0]),
                fnum(b.q[0][1]),
                fnum(b.q[1][0]),
                fnum(b.q[1][1]),
                b.n[0][0].to_string(),
                b.n[0][1].to_string(),
                b.n[1][0].to_string(),
                b.n[1][1].to_string(),
            ]);
        }
    }
    t.print();

    let mab = broker.mab().unwrap();
    println!("final ε = {:.4} (started at 1.0, decays on reward feedback)", mab.epsilon);
    println!(
        "low-SLA context dichotomy (Fig. 6f): Q[l][semantic]={:.3} vs Q[l][layer]={:.3}",
        mab.bandit.q[1][1], mab.bandit.q[1][0]
    );
    let s = broker.metrics.summary("MAB training run");
    println!(
        "training-run reward {:.3} over {} tasks",
        s.avg_reward, s.tasks
    );
    Ok(())
}
