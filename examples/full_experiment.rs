//! Full Table-4 experiment: all nine policy stacks (the paper's seven
//! plus the related-work LatMem and OnlineSplit splitters) on the
//! 50-worker Table-3 fleet, Γ=100 intervals of 300 s, Poisson(λ=6)
//! arrivals — the paper's headline configuration. Prints Table 4 plus
//! the per-application panels of Fig. 7 and the response-time
//! decomposition of Fig. 8/14.
//!
//! This is a long run (nine policies × 100 intervals with PJRT-backed
//! placement). Pass `--quick` for a 25-interval smoke version.
//!
//!     make artifacts && cargo run --release --example full_experiment

use splitplace::config::{ExperimentConfig, PolicyKind};
use splitplace::coordinator::runner::{run_experiment, try_runtime};
use splitplace::util::table::{fnum, fpm, Table};

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let intervals = if quick { 25 } else { 100 };
    let rt = try_runtime().ok_or_else(|| {
        anyhow::anyhow!("artifacts not found — run `make artifacts` first")
    })?;

    let mut table4 = Table::new(
        &format!("Table 4 — policy comparison ({intervals} intervals, 50 workers, λ=6)"),
        &[
            "model", "energy MWh", "sched s", "fairness", "wait", "response",
            "SLA viol", "accuracy", "reward", "cost $/ctr",
        ],
    );
    let mut fig7 = Table::new(
        "Fig. 7 — per-application accuracy / response / violations",
        &["model", "app", "accuracy", "response", "SLA viol"],
    );
    let mut fig14 = Table::new(
        "Fig. 8/14 — response-time decomposition (intervals)",
        &["model", "wait", "exec", "transfer", "migrate", "sched"],
    );

    for policy in PolicyKind::all() {
        let mut cfg = ExperimentConfig::default();
        cfg.policy = policy;
        cfg.sim.intervals = intervals;
        let out = run_experiment(cfg, Some(&rt))?;
        let s = &out.summary;
        table4.row(vec![
            s.policy.clone(),
            fnum(s.energy_mwh),
            fpm(s.sched_time_s.0, s.sched_time_s.1),
            fnum(s.fairness),
            fpm(s.wait.0, s.wait.1),
            fpm(s.response.0, s.response.1),
            fnum(s.sla_violations),
            fnum(s.accuracy),
            fnum(s.avg_reward),
            fnum(s.cost_per_container),
        ]);
        let per = out.metrics.per_app();
        for app in splitplace::splits::APPS {
            if let Some((acc, resp, viol)) = per.get(&app) {
                fig7.row(vec![
                    s.policy.clone(),
                    app.name().into(),
                    fnum(*acc),
                    fnum(*resp),
                    fnum(*viol),
                ]);
            }
        }
        let d = out.metrics.decomposition();
        fig14.row(vec![
            s.policy.clone(),
            fnum(d[0]),
            fnum(d[1]),
            fnum(d[2]),
            fnum(d[3]),
            fnum(d[4]),
        ]);
        eprintln!("[done] {}", s.policy);
    }

    table4.print();
    fig7.print();
    fig14.print();
    println!("(paper shape: MAB+DASO highest reward & lowest SLA violations; \
              Layer+GOBI highest accuracy & response; Semantic+GOBI fastest)");
    Ok(())
}
