//! Quickstart: run SplitPlace (MAB split decider + DASO placement) on a
//! small 10-worker edge cluster for 15 scheduling intervals and print the
//! paper's headline metrics.
//!
//!     make artifacts && cargo run --release --example quickstart

use splitplace::config::{ExperimentConfig, PolicyKind};
use splitplace::coordinator::runner::{run_experiment, try_runtime};
use splitplace::util::table::{fnum, fpm, Table};

fn main() -> anyhow::Result<()> {
    let rt = try_runtime().ok_or_else(|| {
        anyhow::anyhow!("artifacts not found — run `make artifacts` first")
    })?;

    let mut cfg = ExperimentConfig::small();
    cfg.policy = PolicyKind::MabDaso;
    cfg.sim.intervals = 15;
    cfg.workload.lambda = 2.0;

    println!(
        "SplitPlace quickstart: {} workers, {} intervals, Poisson(λ={}) arrivals",
        cfg.cluster.total_workers(),
        cfg.sim.intervals,
        cfg.workload.lambda
    );
    let out = run_experiment(cfg, Some(&rt))?;
    let s = &out.summary;

    let mut t = Table::new("Results (paper §6.4 metrics)", &["metric", "value"]);
    t.row(vec!["tasks completed".into(), s.tasks.to_string()]);
    t.row(vec!["average reward (eq. 15)".into(), fnum(s.avg_reward)]);
    t.row(vec!["average accuracy (eq. 13)".into(), fnum(s.accuracy)]);
    t.row(vec!["SLA violation rate (eq. 14)".into(), fnum(s.sla_violations)]);
    t.row(vec!["response time (intervals)".into(), fpm(s.response.0, s.response.1)]);
    t.row(vec!["wait time (intervals)".into(), fpm(s.wait.0, s.wait.1)]);
    t.row(vec!["energy (MW-hr)".into(), fnum(s.energy_mwh)]);
    t.row(vec!["fairness (Jain)".into(), fnum(s.fairness)]);
    t.row(vec!["execution cost (USD)".into(), fnum(s.cost_usd)]);
    t.print();

    let mut t = Table::new("Per-application", &["app", "accuracy", "response", "SLA violations"]);
    let per = out.metrics.per_app();
    for app in splitplace::splits::APPS {
        if let Some((acc, resp, viol)) = per.get(&app) {
            t.row(vec![app.name().into(), fnum(*acc), fnum(*resp), fnum(*viol)]);
        }
    }
    t.print();
    Ok(())
}
