//! §Perf — hot-path microbenchmarks (EXPERIMENTS.md §Perf): the request
//! path's building blocks measured in isolation so regressions are
//! attributable per layer.
//!
//!   L3: simulator interval step, featurization, MAB decision, best-fit
//!   L2/runtime: surrogate fwd / grad / train-step PJRT calls
//!   L1-derived: fragment-chain inference (the Pallas-kernel HLOs)
//!
//!     cargo bench --bench perf_hotpath

use splitplace::benchlib::{bench, black_box, report};
use splitplace::cluster::build_fleet;
use splitplace::config::{ClusterConfig, MabConfig, SimConfig, WorkloadConfig};
use splitplace::coordinator::runner::try_runtime;
use splitplace::mab::{MabPolicy, Mode};
use splitplace::placement::{BestFitPlacer, FeatureLayout, Placer, PlacementInput, SlotInfo};
use splitplace::runtime::{InferenceEngine, Surrogate};
use splitplace::sim::{Engine, WorkerSnapshot};
use splitplace::splits::{App, SplitDecision};
use splitplace::workload::generator::Generator;
use splitplace::workload::Task;

fn main() {
    let mut results = Vec::new();

    // ---- L3: pure-rust hot paths ----------------------------------------
    let cluster = build_fleet(&ClusterConfig::default());
    let mut engine = Engine::new(cluster, SimConfig::default(), 1);
    let mut generator = Generator::new(WorkloadConfig::default());
    // steady-state load
    for _ in 0..10 {
        for task in generator.arrivals(engine.now_s) {
            engine.admit(task, SplitDecision::Layer);
        }
        let assigns: Vec<(usize, usize)> = engine
            .placeable()
            .into_iter()
            .enumerate()
            .map(|(i, c)| (c, i % engine.workers()))
            .collect();
        engine.apply_placement(&assigns);
        engine.step_interval();
    }
    results.push(bench("L3 sim interval step (50 workers, steady load)", 3, 30, || {
        for task in generator.arrivals(engine.now_s) {
            engine.admit(task, SplitDecision::Semantic);
        }
        let assigns: Vec<(usize, usize)> = engine
            .placeable()
            .into_iter()
            .enumerate()
            .map(|(i, c)| (c, i % engine.workers()))
            .collect();
        engine.apply_placement(&assigns);
        black_box(engine.step_interval());
    }));

    let layout = FeatureLayout::new(50, 64);
    let snaps = vec![WorkerSnapshot { cpu: 0.4, ram: 0.5, net: 0.1, disk: 0.1, containers: 2 }; 50];
    let slots: Vec<SlotInfo> = (0..48)
        .map(|i| SlotInfo {
            cid: i,
            prev_worker: (i % 3 == 0).then_some(i % 50),
            decision: SplitDecision::Layer,
            mi_remaining: 2e6,
            ram_mb: 700.0,
            input_mb: 80.0,
            remaining_frac: 0.8,
        })
        .collect();
    let p = vec![0.01f32; layout.placement_dim()];
    results.push(bench("L3 featurize (H=50, M=64)", 10, 200, || {
        black_box(layout.featurize(&snaps, &slots, &p, true));
    }));

    let mut mab = MabPolicy::new(MabConfig::default(), Mode::Test);
    let task = Task { id: 0, app: App::Cifar100, batch: 40_000, sla: 8.0, arrival_s: 0.0, decision: None };
    results.push(bench("L3 MAB UCB decision", 100, 1000, || {
        black_box(mab.decide(&task));
    }));

    let input = PlacementInput {
        snapshots: &snaps,
        slots: slots.clone(),
        ram_capacity: vec![8000.0; 50],
        resident_ram: vec![1000.0; 50],
        overcommit: 2.0,
    };
    let mut best_fit = BestFitPlacer::new();
    results.push(bench("L3 best-fit placement (48 slots, 50 workers)", 10, 200, || {
        black_box(best_fit.place(&input));
    }));

    // ---- runtime: PJRT calls ---------------------------------------------
    if let Some(rt) = try_runtime() {
        let mut surrogate = Surrogate::for_workers(&rt, 50).expect("surrogate");
        let f = surrogate.feature_dim();
        let x = vec![0.1f32; f];
        // warm compile
        surrogate.fwd(&x).unwrap();
        surrogate.grad(&x).unwrap();
        results.push(bench("L2 surrogate fwd (h50_m64, PJRT)", 3, 50, || {
            black_box(surrogate.fwd(&x).unwrap());
        }));
        results.push(bench("L2 surrogate grad (eq.12 step)", 3, 50, || {
            black_box(surrogate.grad(&x).unwrap());
        }));
        let b = surrogate.spec.train_batch;
        let xb = vec![0.1f32; b * f];
        let yb = vec![0.5f32; b];
        surrogate.train_step(&xb, &yb).unwrap();
        results.push(bench("L2 surrogate AdamW train step", 2, 20, || {
            black_box(surrogate.train_step(&xb, &yb).unwrap());
        }));

        let eng = InferenceEngine::new(&rt).expect("engine");
        for d in [SplitDecision::Layer, SplitDecision::Semantic] {
            eng.warm(App::Mnist, d).unwrap();
        }
        results.push(bench("L1 mnist layer-chain inference (256 rows, 3 HLOs)", 2, 20, || {
            black_box(eng.run(App::Mnist, SplitDecision::Layer).unwrap());
        }));
        results.push(bench("L1 mnist semantic fan-out inference (256 rows)", 2, 20, || {
            black_box(eng.run(App::Mnist, SplitDecision::Semantic).unwrap());
        }));
        eng.warm(App::Cifar100, SplitDecision::Layer).unwrap();
        results.push(bench("L1 cifar100 layer-chain inference (256 rows)", 2, 20, || {
            black_box(eng.run(App::Cifar100, SplitDecision::Layer).unwrap());
        }));
    } else {
        println!("[perf] PJRT benches skipped — artifacts not built");
    }

    report("§Perf — hot-path microbenchmarks", &results);
}
