//! Chaos-profile scenarios: the same fixed-seed fault plans run under
//! several policies, reporting oracle status and the headline metrics.
//! Artifact-free policies always run (the surrogate policies degrade to
//! best-fit placement when artifacts are missing), so this bench is the
//! quickest way to eyeball how a policy behaves under hostile conditions.

use splitplace::benchlib::scenarios;
use splitplace::chaos::Profile;
use splitplace::coordinator::runner::try_runtime;
use splitplace::harness::Scenario;
use splitplace::util::table::{fnum, Table};

fn main() {
    let rt = try_runtime();
    let mut t = Table::new(
        "Chaos profiles (fixed seed 7)",
        &["policy", "profile", "events", "violations", "completed", "failed", "SLA viol", "reward"],
    );
    for profile in [Profile::Light, Profile::Heavy] {
        for policy in scenarios::chaos_table_policies() {
            let (mut cfg, plan) = scenarios::chaos_scenario(profile, 7);
            cfg.policy = policy;
            let Some(out) = scenarios::run_chaos(cfg, &plan, rt.as_ref()) else {
                continue;
            };
            t.row(vec![
                policy.name().into(),
                profile.name().into(),
                plan.events.len().to_string(),
                out.violations.len().to_string(),
                out.completed.to_string(),
                out.failed.to_string(),
                fnum(out.summary.sla_violations),
                fnum(out.summary.avg_reward),
            ]);
        }
    }
    t.print();

    // the matrix harness's scenario universe under the artifact-free
    // policy set (the smoke policies, LatMem/OnlineSplit included) — the
    // same cells `splitplace matrix` gates with goldens
    let mut t = Table::new(
        "Matrix scenarios (fixed seed 1)",
        &["policy", "scenario", "events", "violations", "completed", "resp ema", "reward"],
    );
    for scenario in Scenario::ALL {
        for policy in scenarios::chaos_table_policies() {
            let (cfg, plan) = scenarios::matrix_scenario(scenario, policy, 1);
            let Some(out) = scenarios::run_chaos(cfg, &plan, rt.as_ref()) else {
                continue;
            };
            t.row(vec![
                policy.name().into(),
                scenario.name().into(),
                plan.events.len().to_string(),
                out.violations.len().to_string(),
                out.completed.to_string(),
                fnum(out.response_ema),
                fnum(out.summary.avg_reward),
            ]);
        }
    }
    t.print();
}
