//! Fig. 18 — edge vs cloud (Appendix A.5): the same workload served by the
//! LAN edge fleet (split execution) vs a WAN datacenter (unsplit full
//! models on memory-rich remote nodes). Reproduces the response-time and
//! SLA-violation comparison motivating the edge-only formulation.
//!
//!     cargo bench --bench fig18_cloud

use splitplace::benchlib::scenarios;
use splitplace::config::{PolicyKind, Tier};
use splitplace::util::table::{fnum, fpm, Table};

fn main() {
    let Some(rt) = scenarios::runtime_or_skip("fig18") else { return };

    let mut t = Table::new(
        "Fig. 18 — Edge (SplitPlace) vs Cloud (unsplit, WAN)",
        &["setup", "response", "SLA viol", "accuracy", "reward", "image bcast s"],
    );

    // Edge: full SplitPlace on the LAN fleet.
    let mut edge_cfg = scenarios::base_config();
    edge_cfg.policy = PolicyKind::MabDaso;
    let edge = scenarios::run(edge_cfg.clone(), Some(&rt));

    // Cloud: workers moved across the WAN; no splitting needed (memory-rich
    // nodes run the full model), so the layer-only policy with Full-like
    // behaviour stands in — transfers dominate.
    let mut cloud_cfg = scenarios::base_config();
    cloud_cfg.policy = PolicyKind::LayerGobi;
    cloud_cfg.cluster.tier = Tier::Cloud;
    let cloud = scenarios::run(cloud_cfg.clone(), Some(&rt));

    for (name, cfg, out) in [("edge", &edge_cfg, edge), ("cloud", &cloud_cfg, cloud)] {
        let Some(out) = out else { continue };
        let s = &out.summary;
        let cluster = splitplace::cluster::build_fleet(&cfg.cluster);
        let bcast = splitplace::cluster::topology::image_broadcast_s(&cluster, 1200.0);
        t.row(vec![
            name.into(),
            fpm(s.response.0, s.response.1),
            fnum(s.sla_violations),
            fnum(s.accuracy),
            fnum(s.avg_reward),
            fnum(bcast),
        ]);
    }
    t.print();
    println!(
        "expected shape (paper A.5): cloud response times and violation rates far \
         above edge; one-time image transfer ~2.4x slower over the WAN (30 s vs 72 s)."
    );
}
