//! Fig. 6 — MAB training curves: feedback-based ε-greedy training of the
//! two context bandits, tracking (a) layer response-time estimates,
//! (b,c) decision counts, (d) ε/ρ feedback pair, (e,f) Q-estimates.
//! Also runs the single-context ablation called out in DESIGN.md §7.
//!
//!     cargo bench --bench fig6_mab_training

use splitplace::benchlib::scenarios;
use splitplace::config::{ExperimentConfig, PolicyKind};
#[allow(unused_imports)]
use splitplace::config::ClusterConfig;
use splitplace::coordinator::Broker;
use splitplace::mab::{Context, Mode};
use splitplace::splits::APPS;
use splitplace::util::table::{fnum, Table};

fn main() {
    let Some(rt) = scenarios::runtime_or_skip("fig6") else { return };
    let intervals = (scenarios::bench_intervals() * 4).max(100);

    // Train on the full 50-worker fleet (as the paper does, §6.3): a
    // saturated cluster would blow up layer RTs and wash out the contexts.
    let mut cfg = ExperimentConfig::default();
    cfg.policy = PolicyKind::MabDaso;
    cfg.sim.intervals = intervals;
    let mut broker = Broker::new(cfg, Some(&rt), Mode::Train).expect("broker");

    let mut curve = Table::new(
        &format!("Fig. 6 — training curves over {intervals} intervals"),
        &["t", "eps (d)", "rho (d)", "R_mnist (a)", "R_cifar (a)",
          "Q[h][L] (e)", "Q[h][S] (e)", "Q[l][L] (f)", "Q[l][S] (f)"],
    );
    let sample_every = (intervals / 10).max(1);
    for i in 0..intervals {
        broker.step();
        if (i + 1) % sample_every == 0 {
            let mab = broker.mab().unwrap();
            curve.row(vec![
                (i + 1).to_string(),
                fnum(mab.epsilon),
                fnum(mab.rho),
                fnum(mab.estimator.estimate(APPS[0])),
                fnum(mab.estimator.estimate(APPS[2])),
                fnum(mab.bandit.q[0][0]),
                fnum(mab.bandit.q[0][1]),
                fnum(mab.bandit.q[1][0]),
                fnum(mab.bandit.q[1][1]),
            ]);
        }
    }
    curve.print();

    let mab = broker.mab().unwrap();
    let mut counts = Table::new(
        "Fig. 6(b,c) — decision counts",
        &["context", "layer", "semantic"],
    );
    counts.row(vec![
        "high-SLA".into(),
        mab.bandit.n[Context::High.index()][0].to_string(),
        mab.bandit.n[Context::High.index()][1].to_string(),
    ]);
    counts.row(vec![
        "low-SLA".into(),
        mab.bandit.n[Context::Low.index()][0].to_string(),
        mab.bandit.n[Context::Low.index()][1].to_string(),
    ]);
    counts.print();

    // the paper's training signature: eps decays from 1, rho grows, and in
    // the LOW context the semantic arm's Q dominates the layer arm's
    println!("checks:");
    println!("  eps decayed:        {} (1.0 -> {:.3})", mab.epsilon < 0.9, mab.epsilon);
    println!("  rho grew:           {} (0.1 -> {:.3})", mab.rho > 0.1, mab.rho);
    println!(
        "  low-ctx dichotomy:  {} (Q[l][S]={:.3} vs Q[l][L]={:.3})",
        mab.bandit.q[1][1] > mab.bandit.q[1][0],
        mab.bandit.q[1][1],
        mab.bandit.q[1][0]
    );
    println!(
        "  R estimates learned: {} (mnist {:.2}, cifar {:.2} intervals/40k-batch)",
        mab.estimator.estimate(APPS[0]) > 0.0,
        mab.estimator.estimate(APPS[0]),
        mab.estimator.estimate(APPS[2])
    );

    // ---- ablation (DESIGN.md §7): two-context vs single-context MAB ----
    let run_variant = |single: bool| -> f64 {
        let mut cfg = ExperimentConfig::default();
        cfg.policy = PolicyKind::MabDaso;
        cfg.sim.intervals = scenarios::bench_intervals();
        cfg.mab.single_context = single;
        let mut b = Broker::new(cfg, Some(&rt), Mode::Test).expect("broker");
        b.run();
        b.metrics.avg_reward()
    };
    let two = run_variant(false);
    let one = run_variant(true);
    let mut abl = Table::new(
        "Ablation — context structure (reward, eq. 15)",
        &["variant", "reward"],
    );
    abl.row(vec!["two-context (paper)".into(), fnum(two)]);
    abl.row(vec!["single-context".into(), fnum(one)]);
    abl.print();
    println!(
        "(the SLA-context split is the mechanism that lets the bandit hedge: \
         two-context should not trail single-context)"
    );
}
