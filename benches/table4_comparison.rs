//! Table 4 + Fig. 7 + Fig. 8: the headline policy comparison — all seven
//! models on the 50-worker fleet, every §6.4 metric, per-application
//! panels and the auxiliary metrics (energy, execution time, fairness,
//! cost).
//!
//!     cargo bench --bench table4_comparison
//!     SPLITPLACE_BENCH_INTERVALS=100 cargo bench --bench table4_comparison

use splitplace::benchlib::scenarios;
use splitplace::util::table::{fnum, fpm, Table};

fn main() {
    let Some(rt) = scenarios::runtime_or_skip("table4") else { return };
    let intervals = scenarios::bench_intervals();

    let mut table4 = Table::new(
        &format!("Table 4 — comparison with baselines and ablations ({intervals} intervals)"),
        &[
            "model", "energy MWh", "sched s", "fairness", "wait", "response",
            "SLA viol", "accuracy", "reward",
        ],
    );
    let mut fig7 = Table::new(
        "Fig. 7 — per-application breakdown",
        &["model", "app", "accuracy", "response", "SLA viol"],
    );
    let mut fig8 = Table::new(
        "Fig. 8 — auxiliary metrics",
        &["model", "exec time", "transfer", "migrate", "cost $/ctr", "queue len", "tasks"],
    );

    for policy in scenarios::all_policies() {
        let mut cfg = scenarios::base_config();
        cfg.policy = policy;
        let Some(out) = scenarios::run(cfg, Some(&rt)) else { continue };
        let s = &out.summary;
        table4.row(vec![
            s.policy.clone(),
            fnum(s.energy_mwh),
            fpm(s.sched_time_s.0, s.sched_time_s.1),
            fnum(s.fairness),
            fpm(s.wait.0, s.wait.1),
            fpm(s.response.0, s.response.1),
            fnum(s.sla_violations),
            fnum(s.accuracy),
            fnum(s.avg_reward),
        ]);
        let per = out.metrics.per_app();
        for app in splitplace::splits::APPS {
            if let Some((acc, resp, viol)) = per.get(&app) {
                fig7.row(vec![
                    s.policy.clone(),
                    app.name().into(),
                    fnum(*acc),
                    fnum(*resp),
                    fnum(*viol),
                ]);
            }
        }
        fig8.row(vec![
            s.policy.clone(),
            fpm(s.exec.0, s.exec.1),
            fnum(s.transfer_mean),
            fnum(s.migrate_mean),
            fnum(s.cost_per_container),
            fnum(out.metrics.mean_queue()),
            s.tasks.to_string(),
        ]);
        eprintln!("[table4] {} done", s.policy);
    }
    table4.print();
    fig7.print();
    fig8.print();
    println!(
        "expected shape (paper Table 4): MAB+DASO best reward & lowest SLA violations; \
         Layer+GOBI best accuracy & worst response; Semantic+GOBI fastest."
    );
}
