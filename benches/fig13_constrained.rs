//! Figs. 13–15 — constrained environments (Appendix A.3): rerun the
//! comparison with compute / network / memory halved, report the headline
//! metrics and their ratios vs the normal setup (Fig. 13), the
//! response-time decomposition (Fig. 14) and per-app SLA violations
//! (Fig. 15).
//!
//!     cargo bench --bench fig13_constrained

use std::collections::HashMap;

use splitplace::benchlib::scenarios;
use splitplace::config::{EnvConstraint, PolicyKind};
use splitplace::util::table::{fnum, Table};

const ENVS: [EnvConstraint; 4] = [
    EnvConstraint::None,
    EnvConstraint::Compute,
    EnvConstraint::Network,
    EnvConstraint::Memory,
];

const POLICIES: [PolicyKind; 4] = [
    PolicyKind::ModelCompression,
    PolicyKind::Gillis,
    PolicyKind::MabGobi,
    PolicyKind::MabDaso,
];

fn main() {
    let Some(rt) = scenarios::runtime_or_skip("fig13") else { return };

    let mut fig13 = Table::new(
        "Fig. 13 — constrained environments",
        &["env", "model", "accuracy", "response", "SLA viol", "reward", "vs-normal reward"],
    );
    let mut fig14 = Table::new(
        "Fig. 14 — response-time decomposition (intervals)",
        &["env", "model", "wait", "exec", "transfer", "migrate", "sched"],
    );
    let mut fig15 = Table::new(
        "Fig. 15 — SLA violations per application",
        &["env", "model", "mnist", "fashionmnist", "cifar100"],
    );

    let mut normal_reward: HashMap<PolicyKind, f64> = HashMap::new();
    for env in ENVS {
        for policy in POLICIES {
            let mut cfg = scenarios::base_config();
            cfg.policy = policy;
            cfg.cluster.constraint = env;
            let Some(out) = scenarios::run(cfg, Some(&rt)) else { continue };
            let s = &out.summary;
            if env == EnvConstraint::None {
                normal_reward.insert(policy, s.avg_reward);
            }
            let rel = normal_reward
                .get(&policy)
                .map(|n| s.avg_reward / n)
                .unwrap_or(f64::NAN);
            fig13.row(vec![
                env.name().into(),
                s.policy.clone(),
                fnum(s.accuracy),
                fnum(s.response.0),
                fnum(s.sla_violations),
                fnum(s.avg_reward),
                fnum(rel),
            ]);
            let d = out.metrics.decomposition();
            fig14.row(vec![
                env.name().into(),
                s.policy.clone(),
                fnum(d[0]),
                fnum(d[1]),
                fnum(d[2]),
                fnum(d[3]),
                fnum(d[4]),
            ]);
            let per = out.metrics.per_app();
            let viol = |app| per.get(&app).map(|x| x.2).unwrap_or(f64::NAN);
            fig15.row(vec![
                env.name().into(),
                s.policy.clone(),
                fnum(viol(splitplace::splits::App::Mnist)),
                fnum(viol(splitplace::splits::App::FashionMnist)),
                fnum(viol(splitplace::splits::App::Cifar100)),
            ]);
            eprintln!("[fig13] {} {} done", env.name(), s.policy);
        }
    }
    fig13.print();
    fig14.print();
    fig15.print();
    println!(
        "expected shape (paper A.3): compute constraint inflates exec time, network \
         constraint inflates transfer time, memory constraint inflates exec+transfer \
         via swap; MAB models keep the highest relative reward; CIFAR100 suffers most."
    );
}
