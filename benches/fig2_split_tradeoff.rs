//! Fig. 2 — layer vs semantic splitting trade-off per application:
//! accuracy (REAL PJRT execution of the AOT fragments on held-out data)
//! and response time (single-policy simulator runs), reproducing the
//! motivating figure of §2.
//!
//!     cargo bench --bench fig2_split_tradeoff

use splitplace::benchlib::scenarios;
use splitplace::config::PolicyKind;
use splitplace::runtime::InferenceEngine;
use splitplace::splits::{SplitDecision, APPS};
use splitplace::util::table::{fnum, Table};

fn main() {
    let Some(rt) = scenarios::runtime_or_skip("fig2") else { return };

    // accuracy panel: measured by executing the fragments
    let eng = InferenceEngine::new(&rt).expect("inference engine");
    let mut acc = Table::new(
        "Fig. 2(a) — inference accuracy (measured via PJRT)",
        &["app", "layer", "semantic", "compressed"],
    );
    for app in APPS {
        let l = eng.run(app, SplitDecision::Layer).unwrap().accuracy;
        let s = eng.run(app, SplitDecision::Semantic).unwrap().accuracy;
        let c = eng.run(app, SplitDecision::Compressed).unwrap().accuracy;
        acc.row(vec![app.name().into(), fnum(l), fnum(s), fnum(c)]);
        assert!(l >= s - 0.02, "{app:?}: layer must beat semantic");
    }
    acc.print();

    // response-time panel: L+G vs S+G per app
    let mut rtm = Table::new(
        "Fig. 2(b) — average response time (intervals)",
        &["app", "layer (L+G)", "semantic (S+G)"],
    );
    let run_app = |policy: PolicyKind, app_idx: usize| -> Option<f64> {
        let mut cfg = scenarios::base_config();
        cfg.policy = policy;
        cfg.workload.app_weights = [0.0; 3];
        cfg.workload.app_weights[app_idx] = 1.0;
        let out = scenarios::run(cfg, Some(&rt))?;
        Some(out.summary.response.0)
    };
    for (i, app) in APPS.iter().enumerate() {
        let l = run_app(PolicyKind::LayerGobi, i).unwrap_or(f64::NAN);
        let s = run_app(PolicyKind::SemanticGobi, i).unwrap_or(f64::NAN);
        rtm.row(vec![app.name().into(), fnum(l), fnum(s)]);
        if l.is_finite() && s.is_finite() {
            assert!(s < l, "{app:?}: semantic ({s}) must respond faster than layer ({l})");
        }
    }
    rtm.print();
    println!("(paper: layer splits higher accuracy AND higher response time per app)");
}
