//! Engine-throughput bench across fleet tiers (the perf trajectory seed).
//!
//! Measures scheduling intervals/sec and active-container-intervals/sec on
//! the small (10), medium (200) and large (1000) worker tiers under a
//! chaos-light plan, and writes `BENCH_engine.json` at the repo root.
//! The CLI twin is `splitplace bench` (same measurement, same artifact).
//!
//!     cargo bench --bench engine_throughput
//!
//! `SPLITPLACE_BENCH_INTERVALS` overrides the horizon (default 50 — the
//! acceptance bar is the large tier finishing a ≥50-interval chaos-light
//! run in seconds).

use std::path::PathBuf;

use splitplace::benchlib::throughput::{self, Throughput};
use splitplace::util::table::Table;

fn main() {
    let intervals = splitplace::benchlib::scenarios::bench_intervals().max(50);
    let mut results: Vec<Throughput> = Vec::new();
    for tier in throughput::tiers() {
        match throughput::measure(
            &tier,
            intervals,
            7,
            true,
            splitplace::config::PolicyKind::ModelCompression,
        ) {
            Ok(r) => {
                eprintln!(
                    "[engine_throughput] {}: {} workers, {} intervals in {:.0} ms",
                    r.tier, r.workers, r.intervals, r.wall_ms
                );
                results.push(r);
            }
            Err(e) => eprintln!("[engine_throughput] {} tier failed: {e:#}", tier.name),
        }
    }

    let mut t = Table::new(
        "Engine throughput — chaos-light, per fleet tier",
        &[
            "tier",
            "workers",
            "intervals",
            "wall ms",
            "intervals/s",
            "container-intervals/s",
            "admitted",
            "done",
        ],
    );
    for r in &results {
        t.row(vec![
            r.tier.clone(),
            r.workers.to_string(),
            r.intervals.to_string(),
            format!("{:.0}", r.wall_ms),
            format!("{:.1}", r.intervals_per_sec),
            format!("{:.0}", r.container_intervals_per_sec),
            r.admitted.to_string(),
            r.completed.to_string(),
        ]);
    }
    t.print();

    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("BENCH_engine.json");
    match throughput::write_json(&path, &results) {
        Ok(()) => eprintln!("[engine_throughput] wrote {}", path.display()),
        Err(e) => {
            eprintln!("[engine_throughput] writing {} failed: {e}", path.display());
            std::process::exit(1);
        }
    }
}
