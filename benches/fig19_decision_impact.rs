//! Fig. 19 — decomposition hypothesis (Appendix A.6): response time varies
//! far more across SPLIT decisions than across PLACEMENT decisions, which
//! is what justifies SplitPlace's two-stage (decide-then-place) design.
//!
//! We fix the workload and measure response-time spread (a) between
//! layer-only and semantic-only runs under one placer, and (b) between
//! four different placers under one split decision.
//!
//!     cargo bench --bench fig19_decision_impact

use splitplace::benchlib::scenarios;
use splitplace::config::PolicyKind;
use splitplace::util::stats;
use splitplace::util::table::{fnum, Table};

fn main() {
    let Some(rt) = scenarios::runtime_or_skip("fig19") else { return };

    let run = |policy: PolicyKind, seed: u64| -> Option<f64> {
        let mut cfg = scenarios::base_config();
        cfg.policy = policy;
        cfg.workload.seed = seed;
        Some(scenarios::run(cfg, Some(&rt))?.summary.response.0)
    };

    // (a) split-decision axis: same placer (GOBI), different decisions
    let layer = run(PolicyKind::LayerGobi, 7).unwrap_or(f64::NAN);
    let semantic = run(PolicyKind::SemanticGobi, 7).unwrap_or(f64::NAN);

    // (b) placement axis: same decision mix (random split choice), DASO
    //     gradient placement vs three seeds of the random-split policy
    //     (placement path varies with seed through the fine-tuned
    //     surrogate trajectory)
    let placements: Vec<f64> = [11u64, 23, 37]
        .iter()
        .filter_map(|&s| run(PolicyKind::RandomDaso, s))
        .collect();

    let mut t = Table::new(
        "Fig. 19 — response-time deviation: split vs placement decision",
        &["axis", "responses (intervals)", "spread (max-min)", "std"],
    );
    let split_axis = vec![layer, semantic];
    let spread = |xs: &[f64]| {
        xs.iter().cloned().fold(f64::MIN, f64::max) - xs.iter().cloned().fold(f64::MAX, f64::min)
    };
    t.row(vec![
        "split decision (L vs S)".into(),
        format!("{}", split_axis.iter().map(|x| fnum(*x)).collect::<Vec<_>>().join(", ")),
        fnum(spread(&split_axis)),
        fnum(stats::std(&split_axis)),
    ]);
    t.row(vec![
        "placement decision".into(),
        format!("{}", placements.iter().map(|x| fnum(*x)).collect::<Vec<_>>().join(", ")),
        fnum(spread(&placements)),
        fnum(stats::std(&placements)),
    ]);
    t.print();

    if spread(&split_axis).is_finite() && !placements.is_empty() {
        assert!(
            spread(&split_axis) > spread(&placements),
            "split axis must dominate response-time deviation (paper A.6)"
        );
        println!("confirmed: split decision dominates response time (paper A.6 hypothesis)");
    }
}
