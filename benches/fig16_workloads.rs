//! Figs. 16–17 — single-workload settings (Appendix A.4): rerun the
//! comparison with MNIST-only, FashionMNIST-only and CIFAR100-only
//! arrivals, plus the response-time decomposition per workload.
//!
//!     cargo bench --bench fig16_workloads

use splitplace::benchlib::scenarios;
use splitplace::config::PolicyKind;
use splitplace::util::table::{fnum, Table};

const POLICIES: [PolicyKind; 5] = [
    PolicyKind::ModelCompression,
    PolicyKind::Gillis,
    PolicyKind::SemanticGobi,
    PolicyKind::MabGobi,
    PolicyKind::MabDaso,
];

fn main() {
    let Some(rt) = scenarios::runtime_or_skip("fig16") else { return };

    let mut fig16 = Table::new(
        "Fig. 16 — single-workload settings",
        &["workload", "model", "accuracy", "response", "SLA viol", "reward"],
    );
    let mut fig17 = Table::new(
        "Fig. 17 — response decomposition per workload (MAB+DASO)",
        &["workload", "wait", "exec", "transfer", "migrate"],
    );

    for (wi, wname) in ["mnist", "fashionmnist", "cifar100"].iter().enumerate() {
        for policy in POLICIES {
            let mut cfg = scenarios::base_config();
            cfg.policy = policy;
            cfg.workload.app_weights = [0.0; 3];
            cfg.workload.app_weights[wi] = 1.0;
            let Some(out) = scenarios::run(cfg, Some(&rt)) else { continue };
            let s = &out.summary;
            fig16.row(vec![
                (*wname).into(),
                s.policy.clone(),
                fnum(s.accuracy),
                fnum(s.response.0),
                fnum(s.sla_violations),
                fnum(s.avg_reward),
            ]);
            if policy == PolicyKind::MabDaso {
                let d = out.metrics.decomposition();
                fig17.row(vec![
                    (*wname).into(),
                    fnum(d[0]),
                    fnum(d[1]),
                    fnum(d[2]),
                    fnum(d[3]),
                ]);
            }
            eprintln!("[fig16] {wname} {} done", s.policy);
        }
    }
    fig16.print();
    fig17.print();
    println!(
        "expected shape (paper A.4): MNIST-only highest accuracy & lowest response; \
         CIFAR100-only the opposite; MAB+DASO best reward in every setting."
    );
}
