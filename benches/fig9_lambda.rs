//! Fig. 9 + Fig. 11 — sensitivity to the arrival rate λ (Appendix A.1):
//! accuracy / response / violations / reward / energy for λ ∈ {2, 6, 20,
//! 50} per policy, plus the fraction of layer decisions the MAB takes as
//! load grows (it should fall — semantic splits relieve congestion).
//!
//!     cargo bench --bench fig9_lambda

use splitplace::benchlib::scenarios;
use splitplace::config::PolicyKind;
use splitplace::util::stats;
use splitplace::util::table::{fnum, Table};

const LAMBDAS: [f64; 4] = [2.0, 6.0, 20.0, 50.0];

fn main() {
    let Some(rt) = scenarios::runtime_or_skip("fig9") else { return };

    let mut fig9 = Table::new(
        "Fig. 9 — λ sensitivity",
        &["model", "λ", "accuracy", "response", "SLA viol", "reward", "energy MWh"],
    );
    let mut fig11 = Table::new(
        "Fig. 11 — fraction of layer decisions (MAB+DASO)",
        &["λ", "layer fraction"],
    );

    for policy in [
        PolicyKind::ModelCompression,
        PolicyKind::Gillis,
        PolicyKind::SemanticGobi,
        PolicyKind::LayerGobi,
        PolicyKind::MabGobi,
        PolicyKind::MabDaso,
    ] {
        for lambda in LAMBDAS {
            let mut cfg = scenarios::base_config();
            cfg.policy = policy;
            cfg.workload.lambda = lambda;
            let Some(out) = scenarios::run(cfg, Some(&rt)) else { continue };
            let s = &out.summary;
            fig9.row(vec![
                s.policy.clone(),
                fnum(lambda),
                fnum(s.accuracy),
                fnum(s.response.0),
                fnum(s.sla_violations),
                fnum(s.avg_reward),
                fnum(s.energy_mwh),
            ]);
            if policy == PolicyKind::MabDaso {
                let fracs: Vec<f64> = out
                    .metrics
                    .layer_fraction
                    .iter()
                    .copied()
                    .filter(|f| f.is_finite())
                    .collect();
                fig11.row(vec![fnum(lambda), fnum(stats::mean(&fracs))]);
            }
            eprintln!("[fig9] {} λ={lambda} done", s.policy);
        }
    }
    fig9.print();
    fig11.print();
    println!(
        "expected shape (paper Fig. 9/11): response & violations grow with λ for all \
         models, most slowly for MAB+DASO; the MAB's layer fraction falls as λ grows."
    );
}
