//! Fig. 10 + Fig. 12 — sensitivity to the α/β energy-vs-response weights
//! of eq. 10 (Appendix A.2), over the ablated SplitPlace models, plus the
//! layer-decision fraction as α grows (it should fall: energy-biased
//! placement congests the small nodes, pushing the MAB to semantic).
//!
//!     cargo bench --bench fig10_alpha

use splitplace::benchlib::scenarios;
use splitplace::config::PolicyKind;
use splitplace::util::stats;
use splitplace::util::table::{fnum, Table};

const ALPHAS: [f64; 4] = [0.0, 0.25, 0.5, 1.0];

fn main() {
    let Some(rt) = scenarios::runtime_or_skip("fig10") else { return };

    let mut fig10 = Table::new(
        "Fig. 10 — α/β sensitivity (ablated models)",
        &["model", "α", "accuracy", "response", "SLA viol", "reward", "energy MWh"],
    );
    let mut fig12 = Table::new(
        "Fig. 12 — fraction of layer decisions vs α (MAB+DASO)",
        &["α", "layer fraction"],
    );

    for policy in scenarios::ablation_policies() {
        for alpha in ALPHAS {
            let mut cfg = scenarios::base_config();
            cfg.policy = policy;
            cfg.placement.alpha = alpha;
            let Some(out) = scenarios::run(cfg, Some(&rt)) else { continue };
            let s = &out.summary;
            fig10.row(vec![
                s.policy.clone(),
                fnum(alpha),
                fnum(s.accuracy),
                fnum(s.response.0),
                fnum(s.sla_violations),
                fnum(s.avg_reward),
                fnum(s.energy_mwh),
            ]);
            if policy == PolicyKind::MabDaso {
                let fracs: Vec<f64> = out
                    .metrics
                    .layer_fraction
                    .iter()
                    .copied()
                    .filter(|f| f.is_finite())
                    .collect();
                fig12.row(vec![fnum(alpha), fnum(stats::mean(&fracs))]);
            }
            eprintln!("[fig10] {} α={alpha} done", s.policy);
        }
    }
    fig10.print();
    fig12.print();
    println!(
        "expected shape (paper Fig. 10): MAB models keep the highest reward across α; \
         reward-free models (L+G, S+G) barely change accuracy with α."
    );
}
