//! Typed configuration for experiments, with the paper's defaults baked in.
//!
//! Every knob the evaluation sweeps (λ, α/β, Γ, constrained environments,
//! workload mix, cluster size) lives here so benches and examples build
//! scenario configs declaratively. JSON round-trip uses [`crate::util::json`].

use crate::traffic::{AdmissionConfig, AutoscaleConfig, TrafficShape};
use crate::util::json::{self, Value};

/// Which of the paper's policies drives the broker (Table 4 rows).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PolicyKind {
    /// MAB split decider + DASO placement (the full SplitPlace model, M+D).
    MabDaso,
    /// MAB split decider + decision-blind GOBI placement (M+G).
    MabGobi,
    /// Random split decision + DASO placement (R+D).
    RandomDaso,
    /// Always layer splits + GOBI (L+G).
    LayerGobi,
    /// Always semantic splits + GOBI (S+G).
    SemanticGobi,
    /// Gillis baseline: RL over layer-partition/compression, no semantic arm.
    Gillis,
    /// BottleNet++-style model compression baseline.
    ModelCompression,
    /// Latency-memory optimized splitting (arXiv:2107.09123): per-task
    /// scorer that picks layer vs semantic from the fragments' estimated
    /// RAM footprint against the fleet's memory and the pipeline latency
    /// estimate against the task's deadline.
    LatMem,
    /// Online model splitting for device-edge co-inference
    /// (arXiv:2105.13618): online threshold policy over a per-strategy
    /// deadline-violation EMA with a learned switching cutoff.
    OnlineSplit,
    /// Energy-aware placement (latency-vs-resource co-design,
    /// arXiv:2107.09123): ModelCompression's splitter paired with the
    /// `energy-fit` placer, which trades the best-fit score against the
    /// marginal watts each worker would draw for the extra load.
    EnergyFit,
}

impl PolicyKind {
    pub fn all() -> [PolicyKind; 10] {
        [
            PolicyKind::ModelCompression,
            PolicyKind::EnergyFit,
            PolicyKind::Gillis,
            PolicyKind::LatMem,
            PolicyKind::OnlineSplit,
            PolicyKind::SemanticGobi,
            PolicyKind::LayerGobi,
            PolicyKind::RandomDaso,
            PolicyKind::MabGobi,
            PolicyKind::MabDaso,
        ]
    }

    pub fn name(&self) -> &'static str {
        match self {
            PolicyKind::MabDaso => "MAB+DASO",
            PolicyKind::MabGobi => "MAB+GOBI",
            PolicyKind::RandomDaso => "Random+DASO",
            PolicyKind::LayerGobi => "Layer+GOBI",
            PolicyKind::SemanticGobi => "Semantic+GOBI",
            PolicyKind::Gillis => "Gillis",
            PolicyKind::ModelCompression => "ModelCompression",
            PolicyKind::LatMem => "LatMem",
            PolicyKind::OnlineSplit => "OnlineSplit",
            PolicyKind::EnergyFit => "EnergyFit",
        }
    }

    pub fn parse(s: &str) -> Option<PolicyKind> {
        Some(match s.to_ascii_lowercase().as_str() {
            "mab+daso" | "mab-daso" | "m+d" | "splitplace" | "mabdaso" => PolicyKind::MabDaso,
            "mab+gobi" | "mab-gobi" | "m+g" | "mabgobi" => PolicyKind::MabGobi,
            "random+daso" | "random-daso" | "r+d" | "randomdaso" => PolicyKind::RandomDaso,
            "layer+gobi" | "layer-gobi" | "l+g" | "layergobi" => PolicyKind::LayerGobi,
            "semantic+gobi" | "semantic-gobi" | "s+g" | "semanticgobi" => {
                PolicyKind::SemanticGobi
            }
            "gillis" => PolicyKind::Gillis,
            "mc" | "modelcompression" | "model-compression" => PolicyKind::ModelCompression,
            "latmem" | "lat-mem" | "latency-memory" => PolicyKind::LatMem,
            "onlinesplit" | "online-split" | "online" => PolicyKind::OnlineSplit,
            "energyfit" | "energy-fit" => PolicyKind::EnergyFit,
            _ => return None,
        })
    }
}

/// Resource-constrained environment variants (paper Appendix A.3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EnvConstraint {
    None,
    /// Core count / MIPS halved.
    Compute,
    /// Network bandwidth halved.
    Network,
    /// RAM halved.
    Memory,
}

impl EnvConstraint {
    pub fn name(&self) -> &'static str {
        match self {
            EnvConstraint::None => "normal",
            EnvConstraint::Compute => "compute-constrained",
            EnvConstraint::Network => "network-constrained",
            EnvConstraint::Memory => "memory-constrained",
        }
    }
}

/// Cluster topology: LAN edge (paper default) or WAN cloud (Fig. 18).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tier {
    Edge,
    Cloud,
}

/// Cluster-level settings.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// Worker counts per Azure type, in Table 3 order
    /// [B2ms, E2asv4, B4ms, E4asv4]. Default sums to the paper's 50.
    pub counts: [usize; 4],
    pub constraint: EnvConstraint,
    pub tier: Tier,
    /// Fraction of workers that are mobile (mobility modulates ping/bw).
    pub mobile_fraction: f64,
    /// Worker churn (paper §7 future work: "non-stationary number of
    /// active edge nodes"): per-interval probability that a mobile worker
    /// toggles offline/online. Containers on a failing worker are
    /// checkpointed and requeued.
    pub churn_rate: f64,
    /// Per-worker battery capacity in watt-hours; `None` = grid-powered
    /// (the inert default — no battery state exists in the engine). When
    /// set, every worker starts with this charge, drains it at the SPEC
    /// power curve while online, and crashes for good on exhaustion
    /// (`CmdOrigin::Battery`, never rejoined by the autoscaler).
    pub battery_wh: Option<f64>,
    pub seed: u64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            counts: [20, 10, 10, 10],
            constraint: EnvConstraint::None,
            tier: Tier::Edge,
            mobile_fraction: 0.5,
            churn_rate: 0.0,
            battery_wh: None,
            seed: 42,
        }
    }
}

impl ClusterConfig {
    /// λ the fleet-tier scenarios AND the throughput bench pair with each
    /// preset (small/medium/large/huge/hyperscale) — one source of truth,
    /// so the matrix cells and the BENCH_engine.json perf trajectory
    /// always measure the same regime. Scaled sub-linearly with the fleet:
    /// the active set grows with n without saturating the wait queue at
    /// matrix horizons.
    pub const SMALL_TIER_LAMBDA: f64 = 3.0;
    pub const MEDIUM_TIER_LAMBDA: f64 = 12.0;
    pub const LARGE_TIER_LAMBDA: f64 = 40.0;
    pub const HUGE_TIER_LAMBDA: f64 = 120.0;
    pub const HYPERSCALE_TIER_LAMBDA: f64 = 400.0;

    pub fn small() -> Self {
        // 10-worker variant matching the h10_m16 surrogate artifact.
        ClusterConfig { counts: [4, 2, 2, 2], ..Default::default() }
    }

    /// ≈200-worker fleet tier: 4× the paper's testbed in Table-3
    /// proportions. The paper stops at 50 edge nodes; the medium/large
    /// tiers are where the O(active) engine core earns its keep and where
    /// fleet-scale scenario sweeps run.
    pub fn medium() -> Self {
        ClusterConfig { counts: [80, 40, 40, 40], ..Default::default() }
    }

    /// ≈1000-worker fleet tier (20× the paper's testbed, Table-3
    /// proportions). Chaos rack quarters and plan worker draws scale with
    /// `total_workers()` automatically.
    pub fn large() -> Self {
        ClusterConfig { counts: [400, 200, 200, 200], ..Default::default() }
    }

    /// 5000-worker fleet tier (100× the paper's testbed, Table-3
    /// proportions) — the regime the shard-parallel integrator targets:
    /// at this n the CPU phase dominates the interval and fans out across
    /// rack shards.
    pub fn huge() -> Self {
        ClusterConfig { counts: [2000, 1000, 1000, 1000], ..Default::default() }
    }

    /// 25 000-worker fleet tier (500× the paper's testbed, Table-3
    /// proportions). The hyperscale headline cell: flash-crowd chaos over
    /// a fleet no single-threaded interval loop could sweep.
    pub fn hyperscale() -> Self {
        ClusterConfig { counts: [10_000, 5_000, 5_000, 5_000], ..Default::default() }
    }

    pub fn total_workers(&self) -> usize {
        self.counts.iter().sum()
    }
}

/// Workload generation settings (paper §6.2).
#[derive(Clone, Debug)]
pub struct WorkloadConfig {
    /// Poisson arrival rate per interval (paper default 6).
    pub lambda: f64,
    /// Batch size range, inclusive (paper: 16k–64k samples).
    pub batch_min: u64,
    pub batch_max: u64,
    /// Per-app sampling weights over [mnist, fashionmnist, cifar100];
    /// uniform by default. Single-workload settings (Fig. 16) zero two.
    pub app_weights: [f64; 3],
    /// SLA deadline = U(sla_lo, sla_hi) × nominal layer response time.
    pub sla_lo: f64,
    pub sla_hi: f64,
    pub seed: u64,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        WorkloadConfig {
            lambda: 6.0,
            batch_min: 16_000,
            batch_max: 64_000,
            app_weights: [1.0, 1.0, 1.0],
            sla_lo: 0.6,
            sla_hi: 2.4,
            seed: 7,
        }
    }
}

/// MAB split-decider hyper-parameters (paper §4.1, §6.1).
#[derive(Clone, Debug)]
pub struct MabConfig {
    /// EMA multiplier for layer response-time estimates (eq. 2), φ = 0.9.
    pub phi: f64,
    /// UCB exploration factor (eq. 9), c = 0.5.
    pub ucb_c: f64,
    /// Q-estimate decay (eq. 5).
    pub gamma: f64,
    /// Convergence-rate constant k in decay/increment (eqs. 7–8), k = 0.1.
    pub k: f64,
    /// Initial reward threshold ρ (small positive constant < 1).
    pub rho0: f64,
    /// Ablation (DESIGN.md §7): collapse the two SLA contexts into one
    /// bandit — isolates the value of the context split.
    pub single_context: bool,
    pub seed: u64,
}

impl Default for MabConfig {
    fn default() -> Self {
        MabConfig { phi: 0.9, ucb_c: 0.5, gamma: 0.3, k: 0.1, rho0: 0.1, single_context: false, seed: 11 }
    }
}

/// DASO / GOBI placement hyper-parameters (paper §4.2).
#[derive(Clone, Debug)]
pub struct PlacementConfig {
    /// Energy weight α in eq. 10 (α + β = 1); paper default 0.5.
    pub alpha: f64,
    /// Gradient-ascent learning rate η on the placement matrix (eq. 12).
    pub eta: f64,
    /// Max gradient iterations per interval.
    pub max_iters: usize,
    /// L2 convergence threshold between consecutive placement matrices.
    pub converge_eps: f64,
    /// Online fine-tune: surrogate train steps per interval (0 disables).
    pub finetune_steps: usize,
    pub seed: u64,
}

impl PlacementConfig {
    pub fn beta(&self) -> f64 {
        1.0 - self.alpha
    }
}

impl Default for PlacementConfig {
    fn default() -> Self {
        PlacementConfig {
            alpha: 0.5,
            eta: 0.05,
            max_iters: 12,
            converge_eps: 1e-3,
            finetune_steps: 1,
            seed: 13,
        }
    }
}

/// Simulation timing.
#[derive(Clone, Debug)]
pub struct SimConfig {
    /// Γ: number of scheduling intervals per run (paper: 100).
    pub intervals: usize,
    /// Interval length in seconds (paper: 300).
    pub interval_seconds: f64,
    /// Sub-steps per interval for the progress integrator.
    pub sub_steps: usize,
    /// Rack shards for the intra-interval CPU phase: the integrator fans
    /// the per-worker fair-share pass out over this many threads and joins
    /// through the order-free accumulator, so any value ≥ 1 produces
    /// byte-identical trajectories (1 = the serial walk, no threads
    /// spawned). Clamped to the worker count at run time.
    pub shards: usize,
    /// Per-phase interval profiling (`util::phase_timer`): when true the
    /// engine/broker accumulate wall-ms per phase (cpu/network/decision/
    /// oracle/traffic) for the bench breakdown. Timing reads never feed
    /// back into simulation state, so this knob cannot change any
    /// trajectory; off by default and zero-cost when off.
    pub profile_phases: bool,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            intervals: 100,
            interval_seconds: 300.0,
            sub_steps: 10,
            shards: 1,
            profile_phases: false,
        }
    }
}

/// Traffic-plane settings (`crate::traffic`): which arrival process shapes
/// the per-interval λ, an optional recorded trace to replay instead of
/// generating, and optional admission/autoscale policies. The default —
/// flat Poisson, no trace, no shedding, no scaling — reproduces the
/// pre-traffic-plane behavior byte-for-byte.
#[derive(Clone, Debug)]
pub struct TrafficConfig {
    pub shape: TrafficShape,
    /// Path to a recorded trace (see `workload::replay`); when set, the
    /// trace replaces the generator entirely and `shape` is ignored.
    pub trace: Option<String>,
    pub admission: Option<AdmissionConfig>,
    pub autoscale: Option<AutoscaleConfig>,
}

impl Default for TrafficConfig {
    fn default() -> Self {
        TrafficConfig { shape: TrafficShape::Flat, trace: None, admission: None, autoscale: None }
    }
}

/// How task inference accuracy `p_i` is obtained.
#[derive(Clone, Debug, PartialEq)]
pub enum AccuracyMode {
    /// Real PJRT execution of the split-fragment HLOs on a held-out
    /// subsample (the end-to-end path).
    Measured,
    /// Manifest lookup + small seeded jitter (fast path for large sweeps).
    Manifest,
}

/// Top-level experiment config.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub policy: PolicyKind,
    pub cluster: ClusterConfig,
    pub workload: WorkloadConfig,
    pub mab: MabConfig,
    pub placement: PlacementConfig,
    pub sim: SimConfig,
    pub traffic: TrafficConfig,
    pub accuracy: AccuracyMode,
    /// Artifacts directory (HLO modules + manifest).
    pub artifacts_dir: String,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            policy: PolicyKind::MabDaso,
            cluster: ClusterConfig::default(),
            workload: WorkloadConfig::default(),
            mab: MabConfig::default(),
            placement: PlacementConfig::default(),
            sim: SimConfig::default(),
            traffic: TrafficConfig::default(),
            accuracy: AccuracyMode::Manifest,
            artifacts_dir: "artifacts".into(),
        }
    }
}

impl ExperimentConfig {
    /// Small/fast config for tests and the quickstart example.
    pub fn small() -> Self {
        ExperimentConfig {
            cluster: ClusterConfig::small(),
            sim: SimConfig { intervals: 20, ..Default::default() },
            workload: WorkloadConfig { lambda: 2.0, ..Default::default() },
            ..Default::default()
        }
    }

    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("policy", Value::Str(self.policy.name().into())),
            ("cluster", {
                let mut fields = vec![
                    ("counts", Value::num_arr(&self.cluster.counts.map(|c| c as f64))),
                    ("constraint", Value::Str(self.cluster.constraint.name().into())),
                    (
                        "tier",
                        Value::Str(
                            match self.cluster.tier {
                                Tier::Edge => "edge",
                                Tier::Cloud => "cloud",
                            }
                            .into(),
                        ),
                    ),
                    ("mobile_fraction", Value::Num(self.cluster.mobile_fraction)),
                    ("seed", Value::Num(self.cluster.seed as f64)),
                ];
                // emitted only when set so grid-powered configs serialize
                // byte-identically to the pre-battery schema
                if let Some(b) = self.cluster.battery_wh {
                    fields.push(("battery_wh", Value::Num(b)));
                }
                Value::obj(fields)
            }),
            (
                "workload",
                Value::obj(vec![
                    ("lambda", Value::Num(self.workload.lambda)),
                    ("batch_min", Value::Num(self.workload.batch_min as f64)),
                    ("batch_max", Value::Num(self.workload.batch_max as f64)),
                    ("app_weights", Value::num_arr(&self.workload.app_weights)),
                    ("sla_lo", Value::Num(self.workload.sla_lo)),
                    ("sla_hi", Value::Num(self.workload.sla_hi)),
                    ("seed", Value::Num(self.workload.seed as f64)),
                ]),
            ),
            (
                "mab",
                Value::obj(vec![
                    ("phi", Value::Num(self.mab.phi)),
                    ("ucb_c", Value::Num(self.mab.ucb_c)),
                    ("gamma", Value::Num(self.mab.gamma)),
                    ("k", Value::Num(self.mab.k)),
                    ("rho0", Value::Num(self.mab.rho0)),
                ]),
            ),
            (
                "placement",
                Value::obj(vec![
                    ("alpha", Value::Num(self.placement.alpha)),
                    ("eta", Value::Num(self.placement.eta)),
                    ("max_iters", Value::Num(self.placement.max_iters as f64)),
                    ("finetune_steps", Value::Num(self.placement.finetune_steps as f64)),
                ]),
            ),
            ("sim", {
                let mut fields = vec![
                    ("intervals", Value::Num(self.sim.intervals as f64)),
                    ("interval_seconds", Value::Num(self.sim.interval_seconds)),
                    ("sub_steps", Value::Num(self.sim.sub_steps as f64)),
                    ("shards", Value::Num(self.sim.shards as f64)),
                ];
                // emitted only when set so default configs serialize
                // byte-identically to the pre-profiler schema
                if self.sim.profile_phases {
                    fields.push(("profile_phases", Value::Bool(true)));
                }
                Value::obj(fields)
            }),
            ("traffic", {
                let mut fields =
                    vec![("shape", Value::Str(self.traffic.shape.name().into()))];
                if let Some(trace) = &self.traffic.trace {
                    fields.push(("trace", Value::Str(trace.clone())));
                }
                if let Some(a) = &self.traffic.admission {
                    fields.push((
                        "admission",
                        Value::obj(vec![
                            ("max_queue_depth", Value::Num(a.max_queue_depth as f64)),
                            ("deadline_floor", Value::Num(a.deadline_floor)),
                        ]),
                    ));
                }
                if let Some(a) = &self.traffic.autoscale {
                    fields.push((
                        "autoscale",
                        Value::obj(vec![
                            ("queue_hi", Value::Num(a.queue_hi)),
                            ("queue_lo", Value::Num(a.queue_lo)),
                            ("min_online", Value::Num(a.min_online as f64)),
                        ]),
                    ));
                }
                Value::obj(fields)
            }),
            (
                "accuracy",
                Value::Str(
                    match self.accuracy {
                        AccuracyMode::Measured => "measured",
                        AccuracyMode::Manifest => "manifest",
                    }
                    .into(),
                ),
            ),
            ("artifacts_dir", Value::Str(self.artifacts_dir.clone())),
        ])
    }

    /// Parse from JSON; unknown keys ignored, missing keys take defaults.
    pub fn from_json(v: &Value) -> Result<Self, json::JsonError> {
        let mut cfg = ExperimentConfig::default();
        if let Some(p) = v.get("policy") {
            if let Some(k) = PolicyKind::parse(p.as_str()?) {
                cfg.policy = k;
            }
        }
        if let Some(c) = v.get("cluster") {
            if let Some(counts) = c.get("counts") {
                let a = counts.as_arr()?;
                for (i, x) in a.iter().take(4).enumerate() {
                    cfg.cluster.counts[i] = x.as_usize()?;
                }
            }
            if let Some(x) = c.get("constraint") {
                cfg.cluster.constraint = match x.as_str()? {
                    "compute-constrained" | "compute" => EnvConstraint::Compute,
                    "network-constrained" | "network" => EnvConstraint::Network,
                    "memory-constrained" | "memory" => EnvConstraint::Memory,
                    _ => EnvConstraint::None,
                };
            }
            if let Some(x) = c.get("tier") {
                cfg.cluster.tier = if x.as_str()? == "cloud" { Tier::Cloud } else { Tier::Edge };
            }
            if let Some(x) = c.get("mobile_fraction") {
                cfg.cluster.mobile_fraction = x.as_f64()?;
            }
            if let Some(x) = c.get("seed") {
                cfg.cluster.seed = x.as_f64()? as u64;
            }
            // absent → None: configs recorded before the battery plane
            // existed parse unchanged
            if let Some(x) = c.get("battery_wh") {
                cfg.cluster.battery_wh = Some(x.as_f64()?);
            }
        }
        if let Some(w) = v.get("workload") {
            if let Some(x) = w.get("lambda") {
                cfg.workload.lambda = x.as_f64()?;
            }
            if let Some(x) = w.get("batch_min") {
                cfg.workload.batch_min = x.as_f64()? as u64;
            }
            if let Some(x) = w.get("batch_max") {
                cfg.workload.batch_max = x.as_f64()? as u64;
            }
            if let Some(x) = w.get("app_weights") {
                let a = x.as_arr()?;
                for (i, x) in a.iter().take(3).enumerate() {
                    cfg.workload.app_weights[i] = x.as_f64()?;
                }
            }
            if let Some(x) = w.get("sla_lo") {
                cfg.workload.sla_lo = x.as_f64()?;
            }
            if let Some(x) = w.get("sla_hi") {
                cfg.workload.sla_hi = x.as_f64()?;
            }
            if let Some(x) = w.get("seed") {
                cfg.workload.seed = x.as_f64()? as u64;
            }
        }
        if let Some(m) = v.get("mab") {
            if let Some(x) = m.get("phi") {
                cfg.mab.phi = x.as_f64()?;
            }
            if let Some(x) = m.get("ucb_c") {
                cfg.mab.ucb_c = x.as_f64()?;
            }
            if let Some(x) = m.get("gamma") {
                cfg.mab.gamma = x.as_f64()?;
            }
            if let Some(x) = m.get("k") {
                cfg.mab.k = x.as_f64()?;
            }
            if let Some(x) = m.get("rho0") {
                cfg.mab.rho0 = x.as_f64()?;
            }
        }
        if let Some(p) = v.get("placement") {
            if let Some(x) = p.get("alpha") {
                cfg.placement.alpha = x.as_f64()?;
            }
            if let Some(x) = p.get("eta") {
                cfg.placement.eta = x.as_f64()?;
            }
            if let Some(x) = p.get("max_iters") {
                cfg.placement.max_iters = x.as_usize()?;
            }
            if let Some(x) = p.get("finetune_steps") {
                cfg.placement.finetune_steps = x.as_usize()?;
            }
        }
        if let Some(s) = v.get("sim") {
            if let Some(x) = s.get("intervals") {
                cfg.sim.intervals = x.as_usize()?;
            }
            if let Some(x) = s.get("interval_seconds") {
                cfg.sim.interval_seconds = x.as_f64()?;
            }
            if let Some(x) = s.get("sub_steps") {
                cfg.sim.sub_steps = x.as_usize()?;
            }
            if let Some(x) = s.get("shards") {
                cfg.sim.shards = x.as_usize()?.max(1);
            }
            // absent → false: baselines and configs recorded before the
            // profiler existed parse unchanged
            if let Some(x) = s.get("profile_phases") {
                cfg.sim.profile_phases = x.as_bool()?;
            }
        }
        if let Some(t) = v.get("traffic") {
            if let Some(x) = t.get("shape") {
                if let Some(shape) = TrafficShape::parse(x.as_str()?) {
                    cfg.traffic.shape = shape;
                }
            }
            if let Some(x) = t.get("trace") {
                cfg.traffic.trace = Some(x.as_str()?.to_string());
            }
            if let Some(a) = t.get("admission") {
                let mut adm = AdmissionConfig::default();
                if let Some(x) = a.get("max_queue_depth") {
                    adm.max_queue_depth = x.as_usize()?;
                }
                if let Some(x) = a.get("deadline_floor") {
                    adm.deadline_floor = x.as_f64()?;
                }
                cfg.traffic.admission = Some(adm);
            }
            if let Some(a) = t.get("autoscale") {
                let mut sc = AutoscaleConfig::default();
                if let Some(x) = a.get("queue_hi") {
                    sc.queue_hi = x.as_f64()?;
                }
                if let Some(x) = a.get("queue_lo") {
                    sc.queue_lo = x.as_f64()?;
                }
                if let Some(x) = a.get("min_online") {
                    sc.min_online = x.as_usize()?;
                }
                cfg.traffic.autoscale = Some(sc);
            }
        }
        if let Some(x) = v.get("accuracy") {
            cfg.accuracy = if x.as_str()? == "measured" {
                AccuracyMode::Measured
            } else {
                AccuracyMode::Manifest
            };
        }
        if let Some(x) = v.get("artifacts_dir") {
            cfg.artifacts_dir = x.as_str()?.to_string();
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let c = ExperimentConfig::default();
        assert_eq!(c.cluster.total_workers(), 50);
        assert_eq!(c.workload.lambda, 6.0);
        assert_eq!(c.mab.phi, 0.9);
        assert_eq!(c.mab.ucb_c, 0.5);
        assert_eq!(c.mab.k, 0.1);
        assert_eq!(c.placement.alpha, 0.5);
        assert!((c.placement.alpha + c.placement.beta() - 1.0).abs() < 1e-12);
        assert_eq!(c.sim.intervals, 100);
        assert_eq!(c.sim.interval_seconds, 300.0);
        assert_eq!(c.workload.batch_min, 16_000);
        assert_eq!(c.workload.batch_max, 64_000);
    }

    #[test]
    fn json_roundtrip() {
        let mut c = ExperimentConfig::default();
        c.policy = PolicyKind::Gillis;
        c.workload.lambda = 30.0;
        c.cluster.constraint = EnvConstraint::Memory;
        c.placement.alpha = 0.8;
        let j = c.to_json();
        let c2 = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(c2.policy, PolicyKind::Gillis);
        assert_eq!(c2.workload.lambda, 30.0);
        assert_eq!(c2.cluster.constraint, EnvConstraint::Memory);
        assert!((c2.placement.alpha - 0.8).abs() < 1e-12);
    }

    #[test]
    fn policy_parse_aliases() {
        assert_eq!(PolicyKind::parse("splitplace"), Some(PolicyKind::MabDaso));
        assert_eq!(PolicyKind::parse("M+G"), Some(PolicyKind::MabGobi));
        assert_eq!(PolicyKind::parse("mc"), Some(PolicyKind::ModelCompression));
        assert_eq!(PolicyKind::parse("latency-memory"), Some(PolicyKind::LatMem));
        assert_eq!(PolicyKind::parse("online-split"), Some(PolicyKind::OnlineSplit));
        assert_eq!(PolicyKind::parse("nope"), None);
        for p in PolicyKind::all() {
            assert_eq!(PolicyKind::parse(p.name()), Some(p));
        }
    }

    #[test]
    fn traffic_section_roundtrips_and_defaults_are_inert() {
        // default: flat shape, no trace/admission/autoscale — the config
        // that reproduces pre-traffic-plane behavior
        let d = ExperimentConfig::default();
        assert_eq!(d.traffic.shape, TrafficShape::Flat);
        assert!(d.traffic.trace.is_none());
        assert!(d.traffic.admission.is_none() && d.traffic.autoscale.is_none());
        let back = ExperimentConfig::from_json(&d.to_json()).unwrap();
        assert!(back.traffic.admission.is_none() && back.traffic.autoscale.is_none());

        let mut c = ExperimentConfig::default();
        c.traffic.shape = TrafficShape::Mmpp;
        c.traffic.trace = Some("tests/traces/edge-burst.json".into());
        c.traffic.admission =
            Some(AdmissionConfig { max_queue_depth: 12, deadline_floor: 0.5 });
        c.traffic.autoscale =
            Some(AutoscaleConfig { queue_hi: 3.0, queue_lo: 0.1, min_online: 2 });
        let c2 = ExperimentConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.traffic.shape, TrafficShape::Mmpp);
        assert_eq!(c2.traffic.trace.as_deref(), Some("tests/traces/edge-burst.json"));
        let a = c2.traffic.admission.unwrap();
        assert_eq!(a.max_queue_depth, 12);
        assert!((a.deadline_floor - 0.5).abs() < 1e-12);
        let s = c2.traffic.autoscale.unwrap();
        assert_eq!(s.min_online, 2);
        assert!((s.queue_hi - 3.0).abs() < 1e-12 && (s.queue_lo - 0.1).abs() < 1e-12);
    }

    #[test]
    fn battery_roundtrips_and_stays_out_of_default_json() {
        let d = ExperimentConfig::default();
        assert!(d.cluster.battery_wh.is_none(), "grid-powered by default");
        // grid-powered configs serialize byte-identically to the
        // pre-battery schema: no battery_wh key at all
        let cluster = d.to_json();
        let cluster = cluster.get("cluster").unwrap();
        assert!(cluster.get("battery_wh").is_none());
        assert!(ExperimentConfig::from_json(&d.to_json()).unwrap().cluster.battery_wh.is_none());
        let mut c = ExperimentConfig::default();
        c.cluster.battery_wh = Some(25.0);
        let c2 = ExperimentConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.cluster.battery_wh, Some(25.0));
    }

    #[test]
    fn small_config_matches_small_surrogate() {
        let c = ExperimentConfig::small();
        assert_eq!(c.cluster.total_workers(), 10);
    }

    #[test]
    fn fleet_tiers_scale_in_table3_proportions() {
        let small = ClusterConfig::small();
        let medium = ClusterConfig::medium();
        let large = ClusterConfig::large();
        let huge = ClusterConfig::huge();
        let hyperscale = ClusterConfig::hyperscale();
        assert_eq!(medium.total_workers(), 200);
        assert_eq!(large.total_workers(), 1000);
        assert_eq!(huge.total_workers(), 5000);
        assert_eq!(hyperscale.total_workers(), 25_000);
        // same mix as the paper's default [20,10,10,10] → [2,1,1,1] ratios
        for cfg in [&small, &medium, &large, &huge, &hyperscale] {
            let [a, b, c, d] = cfg.counts;
            assert_eq!(a, 2 * b);
            assert_eq!(b, c);
            assert_eq!(c, d);
        }
        // non-fleet knobs stay at defaults so tier cells differ only in n
        assert_eq!(medium.mobile_fraction, large.mobile_fraction);
        assert_eq!(medium.churn_rate, 0.0);
        assert_eq!(hyperscale.churn_rate, 0.0);
        // λ/n shrinks monotonically up the tiers (sub-linear λ scaling)
        let ratios = [
            ClusterConfig::SMALL_TIER_LAMBDA / small.total_workers() as f64,
            ClusterConfig::MEDIUM_TIER_LAMBDA / medium.total_workers() as f64,
            ClusterConfig::LARGE_TIER_LAMBDA / large.total_workers() as f64,
            ClusterConfig::HUGE_TIER_LAMBDA / huge.total_workers() as f64,
            ClusterConfig::HYPERSCALE_TIER_LAMBDA / hyperscale.total_workers() as f64,
        ];
        for pair in ratios.windows(2) {
            assert!(pair[1] < pair[0], "λ/n must shrink up the tiers: {ratios:?}");
        }
    }

    #[test]
    fn profile_phases_roundtrips_and_stays_out_of_default_json() {
        let d = ExperimentConfig::default();
        assert!(!d.sim.profile_phases, "profiler off by default");
        // the default config serializes byte-identically to the
        // pre-profiler schema: no profile_phases key at all
        let sim = d.to_json();
        let sim = sim.get("sim").unwrap();
        assert!(sim.get("profile_phases").is_none());
        // absent key parses back to false; explicit true round-trips
        assert!(!ExperimentConfig::from_json(&d.to_json()).unwrap().sim.profile_phases);
        let mut c = ExperimentConfig::default();
        c.sim.profile_phases = true;
        let c2 = ExperimentConfig::from_json(&c.to_json()).unwrap();
        assert!(c2.sim.profile_phases);
    }

    #[test]
    fn shards_roundtrip_and_default_to_serial() {
        let d = ExperimentConfig::default();
        assert_eq!(d.sim.shards, 1, "serial by default");
        let mut c = ExperimentConfig::default();
        c.sim.shards = 8;
        let c2 = ExperimentConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.sim.shards, 8);
    }
}
