//! The complete MAB split-decision policy: ε-greedy feedback training
//! (eqs. 6–8) and UCB deployment (eq. 9), wired to the response estimator.

use super::bandit::{Bandit, Context};
use super::estimator::ResponseEstimator;
use crate::config::MabConfig;
use crate::sim::CompletedTask;
use crate::splits::{App, SplitDecision};
use crate::util::rng::Rng;
use crate::workload::Task;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    /// ε-greedy with reward-feedback decay (training, paper §6.3).
    Train,
    /// Deterministic UCB (test, eq. 9).
    Test,
}

#[derive(Clone, Debug)]
pub struct MabPolicy {
    pub bandit: Bandit,
    pub estimator: ResponseEstimator,
    pub mode: Mode,
    /// Exploration probability ε (starts at 1, decays on feedback).
    pub epsilon: f64,
    /// Reward threshold ρ.
    pub rho: f64,
    cfg: MabConfig,
    rng: Rng,
    /// Scheduling-interval counter t for the UCB bonus.
    pub t: u64,
    /// Last interval's O^MAB (exposed for eq. 10).
    pub last_o_mab: f64,
}

impl MabPolicy {
    pub fn new(cfg: MabConfig, mode: Mode) -> Self {
        let (bandit, estimator, epsilon) = match mode {
            Mode::Train => (
                Bandit::new(cfg.gamma),
                ResponseEstimator::new(cfg.phi),
                1.0,
            ),
            // Test mode starts from trained estimates (paper §6.3: "we
            // initialize the expected reward (Q) and layer-split response
            // time (R) estimates by the values we get from training").
            Mode::Test => (
                Bandit::with_q(
                    cfg.gamma,
                    // High ctx: layer slightly better (accuracy edge);
                    // Low ctx: semantic clearly better (SLA edge) — the
                    // dichotomy of Fig. 6(e)/(f).
                    [[0.93, 0.90], [0.55, 0.88]],
                    [[50, 50], [50, 50]],
                ),
                ResponseEstimator::warm(cfg.phi),
                0.0,
            ),
        };
        let rho = cfg.rho0;
        let seed = cfg.seed;
        MabPolicy {
            bandit,
            estimator,
            mode,
            epsilon,
            rho,
            cfg,
            rng: Rng::new(seed),
            t: 1,
            last_o_mab: 0.0,
        }
    }

    /// Batch-size factor: R^a estimates are normalized to a 40k batch
    /// (response times scale with work; see workload::generator).
    fn size_factor(batch: u64) -> f64 {
        batch as f64 / 40_000.0
    }

    pub fn context_of(&self, task: &Task) -> Context {
        if self.cfg.single_context {
            return Context::High; // ablation: one undifferentiated bandit
        }
        Context::of(
            task.sla,
            self.estimator.estimate(task.app) * Self::size_factor(task.batch),
        )
    }

    /// Take the split decision for an incoming task (Algorithm 1 line 9).
    pub fn decide(&mut self, task: &Task) -> SplitDecision {
        let ctx = self.context_of(task);
        let d = match self.mode {
            Mode::Train => {
                if self.rng.chance(self.epsilon) {
                    *self.rng.choice(&SplitDecision::ARMS)
                } else {
                    self.bandit.greedy(ctx)
                }
            }
            Mode::Test => self.bandit.ucb(ctx, self.cfg.ucb_c, self.t),
        };
        self.bandit.record_decision(ctx, d);
        d
    }

    /// Interval bookkeeping with the leaving tasks E_t (Algorithm 1 lines
    /// 3–6): update R^a estimates, Q-estimates, and the ε/ρ feedback pair.
    /// Returns O^MAB.
    pub fn observe_interval(&mut self, leaving: &[CompletedTask]) -> f64 {
        // context evaluated against the *current* estimates, per eqs. 3–4
        let tagged: Vec<(Context, &CompletedTask)> = leaving
            .iter()
            .map(|t| {
                let ctx = if self.cfg.single_context {
                    Context::High
                } else {
                    Context::of(
                        t.sla,
                        self.estimator.estimate_app(t.app) * Self::size_factor(t.batch),
                    )
                };
                (ctx, t)
            })
            .collect();
        let o_mab = self.bandit.update(&tagged);

        // eq. 2: EMA update from layer-decision tasks (batch-normalized)
        for t in leaving {
            if t.decision == SplitDecision::Layer {
                self.estimator
                    .observe(t.app, t.response / Self::size_factor(t.batch));
            }
        }

        // eqs. 7–8: feedback-based ε decay / ρ increment (train mode)
        if self.mode == Mode::Train && o_mab > self.rho {
            self.epsilon *= 1.0 - self.cfg.k;
            self.rho *= 1.0 + self.cfg.k;
        }

        self.t += 1;
        self.last_o_mab = o_mab;
        o_mab
    }

    /// Failed (abandoned) tasks carry a zero reward for the arm that was
    /// chosen for them — without this, a policy whose decisions strand
    /// tasks never feels it. The R^a estimator is untouched: a failure
    /// says nothing about layer response time.
    pub fn observe_failures(&mut self, failed: &[crate::sim::FailedTask]) {
        for t in failed {
            if !matches!(t.decision, SplitDecision::Layer | SplitDecision::Semantic) {
                continue;
            }
            let ctx = if self.cfg.single_context {
                Context::High
            } else {
                Context::of(
                    t.sla,
                    self.estimator.estimate(t.app) * Self::size_factor(t.batch),
                )
            };
            self.bandit.penalize(ctx, t.decision);
        }
    }
}

impl ResponseEstimator {
    /// Alias used above (kept on the estimator for discoverability).
    pub fn estimate_app(&self, app: App) -> f64 {
        self.estimate(app)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MabConfig;
    use crate::splits::App;

    fn task(app: App, sla: f64) -> Task {
        Task { id: 0, app, batch: 32_000, sla, arrival_s: 0.0, decision: None }
    }

    fn done(app: App, d: SplitDecision, response: f64, sla: f64, acc: f64) -> CompletedTask {
        CompletedTask {
            task_id: 0,
            app,
            decision: d,
            batch: 32_000,
            sla,
            response,
            wait: 0.0,
            exec: response,
            transfer: 0.0,
            migrate: 0.0,
            workers: vec![0],
            accuracy: acc,
        }
    }

    #[test]
    fn train_starts_fully_exploring() {
        let p = MabPolicy::new(MabConfig::default(), Mode::Train);
        assert_eq!(p.epsilon, 1.0);
        assert_eq!(p.estimator.estimate(App::Mnist), 0.0);
    }

    #[test]
    fn epsilon_decays_only_on_good_feedback() {
        let mut p = MabPolicy::new(MabConfig::default(), Mode::Train);
        let eps0 = p.epsilon;
        // all-violating interval: reward 0 < rho -> no decay
        let bad = done(App::Mnist, SplitDecision::Layer, 9.0, 1.0, 0.0);
        p.observe_interval(&[bad]);
        assert_eq!(p.epsilon, eps0);
        // strong interval: reward > rho -> decay and rho increment
        let good = done(App::Mnist, SplitDecision::Layer, 1.0, 5.0, 1.0);
        let rho0 = p.rho;
        p.observe_interval(std::slice::from_ref(&good));
        assert!(p.epsilon < eps0);
        assert!(p.rho > rho0);
    }

    #[test]
    fn training_learns_the_dichotomy() {
        // Simulate the paper's training loop: layer RT ~5 intervals,
        // semantic ~2; SLAs mixed. After enough intervals the Low-context
        // bandit must prefer Semantic and the High-context prefer Layer.
        let mut p = MabPolicy::new(MabConfig::default(), Mode::Train);
        let mut rng = crate::util::rng::Rng::new(5);
        for _ in 0..200 {
            let mut leaving = Vec::new();
            for _ in 0..6 {
                let sla = rng.range(2.0, 9.0);
                let t = task(App::Mnist, sla);
                let d = p.decide(&t);
                let (resp, acc) = match d {
                    SplitDecision::Layer => (rng.range(4.0, 6.0), 0.99),
                    SplitDecision::Semantic => (rng.range(1.5, 2.5), 0.93),
                    _ => unreachable!(),
                };
                leaving.push(done(App::Mnist, d, resp, sla, acc));
            }
            p.observe_interval(&leaving);
        }
        assert!(p.epsilon < 0.5, "epsilon={} should have decayed", p.epsilon);
        // R^mnist should approach the true batch-normalized layer RT:
        // responses 4–6 at batch 32k (size factor 0.8) → R ≈ 5–7.5
        let r = p.estimator.estimate(App::Mnist);
        assert!((3.5..=8.0).contains(&r), "R={r}");
        // dichotomy in Q
        assert!(
            p.bandit.q[1][1] > p.bandit.q[1][0],
            "low ctx must favor semantic: {:?}",
            p.bandit.q
        );
        assert!(
            p.bandit.q[0][0] >= p.bandit.q[0][1] - 0.05,
            "high ctx should not strongly favor semantic: {:?}",
            p.bandit.q
        );
    }

    #[test]
    fn failures_penalize_the_chosen_arm_only() {
        let mut p = MabPolicy::new(MabConfig::default(), Mode::Test);
        let q0 = p.bandit.q[0][0];
        let f = crate::sim::FailedTask {
            task_id: 0,
            app: App::Mnist,
            decision: SplitDecision::Layer,
            batch: 32_000,
            sla: 20.0, // far above the warm estimate: High context
            age: 40.0,
        };
        p.observe_failures(std::slice::from_ref(&f));
        assert!(p.bandit.q[0][0] < q0, "failed layer task must drag Q down");
        // non-arm decisions are ignored
        let q_before = p.bandit.q;
        let f2 = crate::sim::FailedTask { decision: SplitDecision::Compressed, ..f };
        p.observe_failures(std::slice::from_ref(&f2));
        assert_eq!(p.bandit.q, q_before);
    }

    #[test]
    fn test_mode_is_deterministic() {
        let mut a = MabPolicy::new(MabConfig::default(), Mode::Test);
        let mut b = MabPolicy::new(MabConfig::default(), Mode::Test);
        for sla in [1.0, 3.0, 5.0, 9.0] {
            let t = task(App::Cifar100, sla);
            assert_eq!(a.decide(&t), b.decide(&t));
        }
    }

    #[test]
    fn test_mode_respects_contexts() {
        let mut p = MabPolicy::new(MabConfig::default(), Mode::Test);
        // far above the estimate: High ctx -> layer (warm Q favors layer)
        let high = task(App::Mnist, 20.0);
        assert_eq!(p.decide(&high), SplitDecision::Layer);
        // far below: Low ctx -> semantic
        let low = task(App::Mnist, 0.5);
        assert_eq!(p.decide(&low), SplitDecision::Semantic);
    }

    #[test]
    fn estimator_adapts_at_test_time() {
        // non-stationarity: if layer RTs double, R^a follows and the
        // context boundary moves (paper's volatile-environment adaptation)
        let mut p = MabPolicy::new(MabConfig::default(), Mode::Test);
        let r0 = p.estimator.estimate(App::Mnist);
        for _ in 0..30 {
            let t = done(App::Mnist, SplitDecision::Layer, r0 * 2.0, 10.0, 0.99);
            p.observe_interval(&[t]);
        }
        assert!(p.estimator.estimate(App::Mnist) > 1.8 * r0);
    }
}
