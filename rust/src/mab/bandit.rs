//! Context-conditioned bandit state: Q-estimates and decision counts per
//! (context, arm), with the paper's reward metrics and update rules.

use crate::sim::CompletedTask;
use crate::splits::SplitDecision;

/// SLA context (paper: MAB^h vs MAB^l).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Context {
    /// sla_i ≥ R^{a_i}: layer split likely meets the deadline.
    High = 0,
    /// sla_i < R^{a_i}: layer split likely violates it.
    Low = 1,
}

impl Context {
    pub fn of(sla: f64, layer_estimate: f64) -> Context {
        if sla >= layer_estimate {
            Context::High
        } else {
            Context::Low
        }
    }

    pub fn index(&self) -> usize {
        *self as usize
    }
}

/// Q/N state for both contexts and both arms.
#[derive(Clone, Debug)]
pub struct Bandit {
    /// Q-estimates, `[context][arm]`.
    pub q: [[f64; 2]; 2],
    /// Decision counts, `[context][arm]`.
    pub n: [[u64; 2]; 2],
    /// Decay γ in eq. 5.
    gamma: f64,
}

impl Bandit {
    pub fn new(gamma: f64) -> Self {
        Bandit { q: [[0.0; 2]; 2], n: [[0; 2]; 2], gamma }
    }

    /// Warm-start Q values (test-time initialization from training).
    pub fn with_q(gamma: f64, q: [[f64; 2]; 2], n: [[u64; 2]; 2]) -> Self {
        Bandit { q, n, gamma }
    }

    pub fn record_decision(&mut self, ctx: Context, d: SplitDecision) {
        self.n[ctx.index()][d.arm_index()] += 1;
    }

    /// Per-task reward term: (1(r ≤ sla) + p) / 2 — numerator of eqs. 3–4.
    pub fn task_reward(t: &CompletedTask) -> f64 {
        let sla_ok = if t.response <= t.sla { 1.0 } else { 0.0 };
        let p = if t.accuracy.is_finite() { t.accuracy } else { 0.0 };
        (sla_ok + p) / 2.0
    }

    /// Compute the interval reward metrics O^{c,d} (eqs. 3–4) over the
    /// leaving tasks E_t, given each task's context, and apply eq. 5.
    /// Returns O^MAB = mean over the four cells (missing cells fall back
    /// to the current Q estimate so the average stays defined).
    pub fn update(&mut self, leaving: &[(Context, &CompletedTask)]) -> f64 {
        let mut o_sum = 0.0;
        for c in 0..2 {
            for a in 0..2 {
                let cell: Vec<f64> = leaving
                    .iter()
                    .filter(|(ctx, t)| {
                        ctx.index() == c
                            && matches!(
                                t.decision,
                                SplitDecision::Layer | SplitDecision::Semantic
                            )
                            && t.decision.arm_index() == a
                    })
                    .map(|(_, t)| Self::task_reward(t))
                    .collect();
                let o = if cell.is_empty() {
                    self.q[c][a]
                } else {
                    let o = cell.iter().sum::<f64>() / cell.len() as f64;
                    // eq. 5: Q ← Q + γ (O − Q)
                    self.q[c][a] += self.gamma * (o - self.q[c][a]);
                    o
                };
                o_sum += o;
            }
        }
        o_sum / 4.0
    }

    /// Eq. 5 update toward a zero observation — a task of this
    /// (context, arm) left the system failed: SLA blown, no output.
    pub fn penalize(&mut self, ctx: Context, d: SplitDecision) {
        if !matches!(d, SplitDecision::Layer | SplitDecision::Semantic) {
            return;
        }
        let (c, a) = (ctx.index(), d.arm_index());
        self.q[c][a] += self.gamma * (0.0 - self.q[c][a]);
    }

    /// Greedy arm for a context.
    pub fn greedy(&self, ctx: Context) -> SplitDecision {
        if self.q[ctx.index()][0] >= self.q[ctx.index()][1] {
            SplitDecision::Layer
        } else {
            SplitDecision::Semantic
        }
    }

    /// UCB arm (eq. 9): argmax_d Q^{c,d} + c·sqrt(ln t / N^{c,d}).
    /// Unvisited arms get an infinite bonus.
    pub fn ucb(&self, ctx: Context, c: f64, t: u64) -> SplitDecision {
        let ci = ctx.index();
        let score = |a: usize| -> f64 {
            if self.n[ci][a] == 0 {
                return f64::INFINITY;
            }
            self.q[ci][a] + c * ((t.max(2) as f64).ln() / self.n[ci][a] as f64).sqrt()
        };
        if score(0) >= score(1) {
            SplitDecision::Layer
        } else {
            SplitDecision::Semantic
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::splits::App;

    fn done(decision: SplitDecision, response: f64, sla: f64, acc: f64) -> CompletedTask {
        CompletedTask {
            task_id: 0,
            app: App::Mnist,
            decision,
            batch: 16_000,
            sla,
            response,
            wait: 0.0,
            exec: response,
            transfer: 0.0,
            migrate: 0.0,
            workers: vec![0],
            accuracy: acc,
        }
    }

    #[test]
    fn context_boundary() {
        assert_eq!(Context::of(5.0, 5.0), Context::High);
        assert_eq!(Context::of(4.9, 5.0), Context::Low);
    }

    #[test]
    fn task_reward_combines_sla_and_accuracy() {
        let hit = done(SplitDecision::Layer, 3.0, 5.0, 0.9);
        assert!((Bandit::task_reward(&hit) - 0.95).abs() < 1e-12);
        let miss = done(SplitDecision::Layer, 6.0, 5.0, 0.9);
        assert!((Bandit::task_reward(&miss) - 0.45).abs() < 1e-12);
    }

    #[test]
    fn update_moves_q_toward_observation() {
        let mut b = Bandit::new(0.5);
        let t = done(SplitDecision::Layer, 3.0, 5.0, 1.0); // reward 1.0
        let o = b.update(&[(Context::High, &t)]);
        assert!((b.q[0][0] - 0.5).abs() < 1e-12, "Q += 0.5*(1-0)");
        assert!(o > 0.0);
        // unobserved cells unchanged
        assert_eq!(b.q[1][0], 0.0);
        assert_eq!(b.q[0][1], 0.0);
    }

    #[test]
    fn low_context_learns_layer_is_bad() {
        // In the Low context layer violates SLA (reward ~0.5·acc), semantic
        // hits it — Q should separate (paper Fig. 6(f)).
        let mut b = Bandit::new(0.3);
        for _ in 0..50 {
            let l = done(SplitDecision::Layer, 8.0, 4.0, 0.95);
            let s = done(SplitDecision::Semantic, 2.0, 4.0, 0.85);
            b.update(&[(Context::Low, &l), (Context::Low, &s)]);
        }
        assert!(b.q[1][1] > b.q[1][0] + 0.2, "q={:?}", b.q);
        assert_eq!(b.greedy(Context::Low), SplitDecision::Semantic);
    }

    #[test]
    fn ucb_prefers_unvisited() {
        let mut b = Bandit::new(0.3);
        b.q[0][0] = 0.9;
        b.n[0][0] = 100;
        // arm 1 never tried
        assert_eq!(b.ucb(Context::High, 0.5, 100), SplitDecision::Semantic);
        b.n[0][1] = 50;
        b.q[0][1] = 0.1;
        assert_eq!(b.ucb(Context::High, 0.5, 100), SplitDecision::Layer);
    }

    #[test]
    fn ucb_exploration_bonus_decays_with_count() {
        let mut b = Bandit::new(0.3);
        b.q[0][0] = 0.6;
        b.q[0][1] = 0.5;
        b.n[0][0] = 1000;
        b.n[0][1] = 2;
        // rarely-tried arm 1 wins on bonus at small t... with c=2.0
        assert_eq!(b.ucb(Context::High, 2.0, 1000), SplitDecision::Semantic);
        b.n[0][1] = 1000;
        assert_eq!(b.ucb(Context::High, 2.0, 1000), SplitDecision::Layer);
    }

    #[test]
    fn nan_accuracy_treated_as_zero() {
        let t = done(SplitDecision::Layer, 1.0, 5.0, f64::NAN);
        assert!((Bandit::task_reward(&t) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn non_arm_decisions_ignored_in_update() {
        let mut b = Bandit::new(0.5);
        let t = done(SplitDecision::Compressed, 1.0, 5.0, 1.0);
        b.update(&[(Context::High, &t)]);
        assert_eq!(b.q, [[0.0; 2]; 2]);
    }
}
