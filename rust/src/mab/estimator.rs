//! Layer-split response-time estimates R^a (paper §4.1.1, eq. 2):
//! per-application EMA over observed layer-decision response times,
//! `R^a ← φ·r_i + (1−φ)·R^a`, giving recent observations more weight so
//! the context boundary tracks mobility-induced drift.

use crate::splits::App;
use crate::util::stats::Ema;

#[derive(Clone, Debug)]
pub struct ResponseEstimator {
    emas: [Ema; 3],
}

impl ResponseEstimator {
    /// Fresh estimator starting from zero estimates (paper Fig. 6(a)
    /// "learned starting from zero").
    pub fn new(phi: f64) -> Self {
        ResponseEstimator { emas: [Ema::with_initial(phi, 0.0); 3] }
    }

    /// Warm-start from known nominals (what the paper does at test time:
    /// "we initialize ... by the values we get from this training").
    pub fn warm(phi: f64) -> Self {
        let mut e = ResponseEstimator::new(phi);
        for app in crate::splits::APPS {
            e.emas[app.index()] = Ema::with_initial(phi, app.nominal_layer_rt());
        }
        e
    }

    /// Record an observed layer-split response time (intervals).
    pub fn observe(&mut self, app: App, response: f64) {
        self.emas[app.index()].push(response);
    }

    /// Current estimate R^a.
    pub fn estimate(&self, app: App) -> f64 {
        self.emas[app.index()].get_or(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::splits::App;

    #[test]
    fn ema_update_matches_eq2() {
        let mut e = ResponseEstimator::warm(0.9);
        let r0 = e.estimate(App::Mnist);
        e.observe(App::Mnist, 10.0);
        assert!((e.estimate(App::Mnist) - (0.9 * 10.0 + 0.1 * r0)).abs() < 1e-12);
    }

    #[test]
    fn cold_start_is_zero() {
        let e = ResponseEstimator::new(0.9);
        for app in crate::splits::APPS {
            assert_eq!(e.estimate(app), 0.0);
        }
    }

    #[test]
    fn apps_independent() {
        let mut e = ResponseEstimator::new(0.9);
        e.observe(App::Cifar100, 8.0);
        assert_eq!(e.estimate(App::Mnist), 0.0);
        assert!(e.estimate(App::Cifar100) > 0.0);
    }

    #[test]
    fn converges_to_stationary_value() {
        let mut e = ResponseEstimator::new(0.9);
        for _ in 0..50 {
            e.observe(App::FashionMnist, 5.5);
        }
        assert!((e.estimate(App::FashionMnist) - 5.5).abs() < 1e-3);
    }
}
