//! Multi-Armed-Bandit split decider (paper §4.1).
//!
//! Two stateless bandits, one per SLA context: `High` (sla ≥ R^a estimate)
//! and `Low` (sla < R^a). Arms are {Layer, Semantic}. Rewards combine SLA
//! compliance and inference accuracy (eqs. 3–4); Q-estimates update with a
//! decay step (eq. 5); training explores with feedback-decayed ε-greedy
//! (eqs. 6–8); test time uses UCB (eq. 9).

pub mod bandit;
pub mod estimator;
pub mod policy;

pub use bandit::{Bandit, Context};
pub use estimator::ResponseEstimator;
pub use policy::{MabPolicy, Mode};
