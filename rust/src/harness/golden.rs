//! Golden-trace regression gating.
//!
//! Each matrix cell has one committed golden: the canonical
//! [`CellSummary`] JSON recorded by a trusted run (`matrix
//! --update-goldens`, diff reviewed like code). A later run *drifts* when
//! any metric leaves its tolerance band, the oracle verdicts change, or
//! the metric key sets diverge — drift is a regression gate, not noise,
//! because every cell is deterministic by construction.

use std::path::{Path, PathBuf};

use crate::util::json;

use super::cell::CellSummary;

/// Per-metric tolerance band: `|got − want| ≤ abs + rel·|want|`.
/// Defaults are tight — cells are bit-deterministic on one binary; the
/// band only absorbs cross-platform libm/rounding differences.
#[derive(Clone, Copy, Debug)]
pub struct Tolerance {
    pub abs: f64,
    pub rel: f64,
}

impl Default for Tolerance {
    fn default() -> Self {
        Tolerance { abs: 1e-9, rel: 1e-6 }
    }
}

impl Tolerance {
    /// Exact comparison (counters: admitted/completed/failed/…).
    pub const EXACT: Tolerance = Tolerance { abs: 0.0, rel: 0.0 };

    /// Is `got` within this band of `want`? Two NaNs agree (an empty cell
    /// must stay empty); any other non-finite pairing agrees only on
    /// bitwise-equal semantics.
    pub fn accepts(&self, got: f64, want: f64) -> bool {
        if got.is_nan() && want.is_nan() {
            return true;
        }
        if !got.is_finite() || !want.is_finite() {
            return got == want;
        }
        (got - want).abs() <= self.abs + self.rel * want.abs()
    }
}

/// Tolerance for a named metric: counters compare exactly, continuous
/// metrics get the default band. Differential cells prefix per-side
/// metrics with `a_`/`b_`; the base name decides the band, and the
/// `ordering_ok` flag is a counter.
pub fn tolerance_for(metric: &str) -> Tolerance {
    let base = metric
        .strip_prefix("a_")
        .or_else(|| metric.strip_prefix("b_"))
        .unwrap_or(metric);
    match base {
        "admitted" | "completed" | "failed" | "oracle_violations" | "ordering_ok"
        | "offered" | "shed_queue" | "shed_deadline" | "scale_up" | "scale_down" => {
            Tolerance::EXACT
        }
        _ => Tolerance::default(),
    }
}

/// Compare a freshly computed summary against its golden. Returns every
/// drift found (empty = match). A key present on one side only is drift:
/// a *new* metric means the golden is stale (re-record it), a *missing*
/// one means the summary lost coverage.
pub fn drift(golden: &CellSummary, got: &CellSummary) -> Vec<String> {
    let mut out = Vec::new();
    if golden.cell != got.cell {
        out.push(format!("cell id mismatch: golden '{}' vs run '{}'", golden.cell, got.cell));
    }
    if golden.intervals != got.intervals {
        out.push(format!(
            "horizon mismatch: golden ran {} intervals, this run {} — \
             re-record with --update-goldens",
            golden.intervals, got.intervals
        ));
    }
    for (k, want) in &golden.metrics {
        match got.metrics.get(k) {
            None => out.push(format!("metric '{k}' in golden but missing from this run")),
            Some(&g) => {
                if !tolerance_for(k).accepts(g, *want) {
                    out.push(format!("metric '{k}': golden {want}, got {g}"));
                }
            }
        }
    }
    for k in got.metrics.keys() {
        if !golden.metrics.contains_key(k) {
            out.push(format!(
                "new metric '{k}' not in golden — review and --update-goldens"
            ));
        }
    }
    if golden.violated_oracles != got.violated_oracles {
        out.push(format!(
            "oracle verdicts changed: golden {:?}, got {:?}",
            golden.violated_oracles, got.violated_oracles
        ));
    }
    out
}

/// Outcome of gating one cell against its golden.
#[derive(Clone, Debug, PartialEq)]
pub enum GoldenStatus {
    /// Within tolerance of the committed golden.
    Match,
    /// `--update-goldens` rewrote (or created) the golden.
    Updated,
    /// No golden recorded for this cell yet — a gate failure, because an
    /// ungated cell is an unwatched regime.
    Missing,
    /// Out of tolerance; carries one message per drifting quantity.
    Drift(Vec<String>),
    /// Golden gating disabled for this run.
    Skipped,
}

impl GoldenStatus {
    pub fn is_failure(&self) -> bool {
        matches!(self, GoldenStatus::Missing | GoldenStatus::Drift(_))
    }

    pub fn label(&self) -> &'static str {
        match self {
            GoldenStatus::Match => "match",
            GoldenStatus::Updated => "updated",
            GoldenStatus::Missing => "MISSING",
            GoldenStatus::Drift(_) => "DRIFT",
            GoldenStatus::Skipped => "-",
        }
    }
}

/// Directory of per-cell golden files (`<file_stem>.json`).
#[derive(Clone, Debug)]
pub struct GoldenStore {
    pub dir: PathBuf,
}

impl GoldenStore {
    pub fn new(dir: impl AsRef<Path>) -> GoldenStore {
        GoldenStore { dir: dir.as_ref().to_path_buf() }
    }

    pub fn path(&self, stem: &str) -> PathBuf {
        self.dir.join(format!("{stem}.json"))
    }

    /// Load a cell's golden. `Ok(None)` when none is recorded; `Err` when
    /// the file exists but does not parse (a corrupt golden must fail the
    /// gate loudly, not read as "missing").
    pub fn load(&self, stem: &str) -> Result<Option<CellSummary>, String> {
        let path = self.path(stem);
        let text = match std::fs::read_to_string(&path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => return Err(format!("reading {}: {e}", path.display())),
        };
        let v = json::parse(&text).map_err(|e| format!("parsing {}: {e}", path.display()))?;
        CellSummary::from_json(&v)
            .map(Some)
            .map_err(|e| format!("decoding {}: {e}", path.display()))
    }

    /// Record `summary` as the golden for its cell (pretty-printed so the
    /// review diff reads line-per-metric).
    pub fn save(&self, stem: &str, summary: &CellSummary) -> Result<(), String> {
        std::fs::create_dir_all(&self.dir)
            .map_err(|e| format!("creating {}: {e}", self.dir.display()))?;
        let path = self.path(stem);
        let mut text = summary.to_json().to_pretty();
        text.push('\n');
        std::fs::write(&path, text).map_err(|e| format!("writing {}: {e}", path.display()))
    }

    /// Gate `summary`: compare against the stored golden, or record it
    /// when `update` is set.
    pub fn gate(&self, stem: &str, summary: &CellSummary, update: bool) -> GoldenStatus {
        if update {
            return match self.save(stem, summary) {
                Ok(()) => GoldenStatus::Updated,
                Err(e) => GoldenStatus::Drift(vec![e]),
            };
        }
        match self.load(stem) {
            Ok(None) => GoldenStatus::Missing,
            Ok(Some(golden)) => {
                let d = drift(&golden, summary);
                if d.is_empty() {
                    GoldenStatus::Match
                } else {
                    GoldenStatus::Drift(d)
                }
            }
            Err(e) => GoldenStatus::Drift(vec![e]),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    fn summary(cell: &str) -> CellSummary {
        let mut metrics = BTreeMap::new();
        metrics.insert("completed".to_string(), 12.0);
        metrics.insert("response_mean".to_string(), 3.5);
        metrics.insert("accuracy".to_string(), 0.9);
        CellSummary {
            cell: cell.to_string(),
            policy: "mc".into(),
            scenario: "clean".into(),
            seed: 1,
            intervals: 12,
            metrics,
            violated_oracles: Vec::new(),
        }
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("splitplace-golden-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn identical_summaries_match() {
        let g = summary("mc/clean/s1");
        assert!(drift(&g, &g.clone()).is_empty());
    }

    #[test]
    fn tolerance_band_absorbs_rounding_but_not_regressions() {
        let g = summary("mc/clean/s1");
        let mut close = g.clone();
        *close.metrics.get_mut("response_mean").unwrap() = 3.5 * (1.0 + 1e-9);
        assert!(drift(&g, &close).is_empty(), "1e-9 relative wiggle is rounding");
        let mut far = g.clone();
        *far.metrics.get_mut("response_mean").unwrap() = 3.6;
        let d = drift(&g, &far);
        assert_eq!(d.len(), 1, "{d:?}");
        assert!(d[0].contains("response_mean"));
    }

    #[test]
    fn counters_compare_exactly() {
        let g = summary("mc/clean/s1");
        let mut off = g.clone();
        *off.metrics.get_mut("completed").unwrap() = 12.0000001;
        assert!(!drift(&g, &off).is_empty(), "counters get no tolerance band");
    }

    #[test]
    fn side_prefixed_counters_and_ordering_flag_are_exact() {
        assert_eq!(tolerance_for("a_completed").abs, 0.0);
        assert_eq!(tolerance_for("b_failed").rel, 0.0);
        assert_eq!(tolerance_for("ordering_ok").abs, 0.0);
        // traffic-plane counters are exact too
        assert_eq!(tolerance_for("offered").abs, 0.0);
        assert_eq!(tolerance_for("shed_queue").rel, 0.0);
        assert_eq!(tolerance_for("shed_deadline").abs, 0.0);
        assert_eq!(tolerance_for("scale_up").rel, 0.0);
        assert_eq!(tolerance_for("scale_down").abs, 0.0);
        // continuous metrics keep the band, prefixed or not
        assert!(tolerance_for("a_avg_reward").rel > 0.0);
        assert!(tolerance_for("delta_avg_reward").rel > 0.0);
    }

    #[test]
    fn nan_metrics_agree_only_with_nan() {
        // both NaN (cell with zero completions): no drift
        let mut g = summary("mc/clean/s1");
        *g.metrics.get_mut("accuracy").unwrap() = f64::NAN;
        let mut got = g.clone();
        assert!(drift(&g, &got).is_empty(), "NaN golden vs NaN run must match");
        // golden NaN, run finite → the cell started completing tasks: drift
        *got.metrics.get_mut("accuracy").unwrap() = 0.8;
        assert!(!drift(&g, &got).is_empty());
        // golden finite, run NaN → the cell stopped completing tasks: drift
        let g2 = summary("mc/clean/s1");
        let mut got2 = g2.clone();
        *got2.metrics.get_mut("accuracy").unwrap() = f64::NAN;
        assert!(!drift(&g2, &got2).is_empty());
    }

    #[test]
    fn new_and_missing_metric_keys_are_drift() {
        let g = summary("mc/clean/s1");
        let mut extra = g.clone();
        extra.metrics.insert("queue_p99".to_string(), 4.0);
        let d = drift(&g, &extra);
        assert_eq!(d.len(), 1, "{d:?}");
        assert!(d[0].contains("new metric 'queue_p99'"), "{d:?}");

        let mut lost = g.clone();
        lost.metrics.remove("accuracy");
        let d = drift(&g, &lost);
        assert_eq!(d.len(), 1, "{d:?}");
        assert!(d[0].contains("missing from this run"), "{d:?}");
    }

    #[test]
    fn oracle_verdict_changes_are_drift() {
        let g = summary("mc/clean/s1");
        let mut got = g.clone();
        got.violated_oracles.push("task-conservation".into());
        let d = drift(&g, &got);
        assert!(d.iter().any(|m| m.contains("oracle verdicts")), "{d:?}");
    }

    #[test]
    fn missing_golden_file_fails_the_gate() {
        let store = GoldenStore::new(tmpdir("missing"));
        let s = summary("mc/clean/s1");
        assert_eq!(store.gate("mc__clean__s1", &s, false), GoldenStatus::Missing);
        assert!(GoldenStatus::Missing.is_failure());
    }

    #[test]
    fn update_then_gate_roundtrips_through_disk() {
        let dir = tmpdir("roundtrip");
        let store = GoldenStore::new(&dir);
        let mut s = summary("mc/clean/s1");
        *s.metrics.get_mut("accuracy").unwrap() = f64::NAN; // null on disk
        assert_eq!(store.gate("mc__clean__s1", &s, true), GoldenStatus::Updated);
        assert_eq!(store.gate("mc__clean__s1", &s, false), GoldenStatus::Match);
        // a drifted rerun is rejected with a per-metric message
        let mut bad = s.clone();
        *bad.metrics.get_mut("response_mean").unwrap() = 99.0;
        match store.gate("mc__clean__s1", &bad, false) {
            GoldenStatus::Drift(msgs) => {
                assert!(msgs.iter().any(|m| m.contains("response_mean")), "{msgs:?}")
            }
            other => panic!("expected drift, got {other:?}"),
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_golden_is_a_loud_failure_not_missing() {
        let dir = tmpdir("corrupt");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("mc__clean__s1.json"), "{not json").unwrap();
        let store = GoldenStore::new(&dir);
        let s = summary("mc/clean/s1");
        match store.gate("mc__clean__s1", &s, false) {
            GoldenStatus::Drift(msgs) => assert!(msgs[0].contains("parsing"), "{msgs:?}"),
            other => panic!("expected loud failure, got {other:?}"),
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
