//! Persisted bug-base: shrunk failing scenarios that replay forever.
//!
//! Every invariant violation the matrix (or `chaos` CLI) ever finds is
//! ddmin-shrunk and written here as a self-contained `seed + plan`
//! artifact. A dedicated regression test (`tests/bugbase_replay.rs`)
//! replays every artifact on every CI run:
//!
//! * `expect: "green"` — the scenario once exposed a real engine/broker
//!   bug; after the fix it must stay violation-free forever.
//! * `expect: "violates"` — the scenario pairs a deliberate [`BugKind`]
//!   with the oracle that catches it; the oracle must keep firing, or the
//!   harness has lost detection power.

use std::path::{Path, PathBuf};

use crate::chaos::{self, BugKind, ChaosOptions, FaultPlan};
use crate::config::PolicyKind;
use crate::util::json::{self, JsonError, Value};

use super::scenario::{policy_slug, Scenario};

/// What a replay of the artifact must observe.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Expectation {
    /// The run is violation-free (a fixed bug staying fixed).
    Green,
    /// The named oracle fires (a deliberate bug staying caught).
    Violates,
}

impl Expectation {
    pub fn name(&self) -> &'static str {
        match self {
            Expectation::Green => "green",
            Expectation::Violates => "violates",
        }
    }

    pub fn parse(s: &str) -> Option<Expectation> {
        match s {
            "green" => Some(Expectation::Green),
            "violates" => Some(Expectation::Violates),
            _ => None,
        }
    }
}

/// One bug-base artifact: everything needed to rebuild the exact cell
/// config and replay the (shrunk) fault plan.
#[derive(Clone, Debug)]
pub struct BugRecord {
    /// Artifact id; also the file stem.
    pub id: String,
    /// Oracle the expectation is stated over.
    pub oracle: String,
    pub expect: Expectation,
    /// Deliberate bug to inject on replay (None for real-bug artifacts).
    pub bug: Option<BugKind>,
    pub policy: PolicyKind,
    pub scenario: Scenario,
    pub seed: u64,
    pub intervals: usize,
    pub task_timeout_intervals: usize,
    /// The shrunk plan (replayed verbatim, never regenerated).
    pub plan: FaultPlan,
    /// Free-form provenance (who found it, shrink stats).
    pub note: String,
}

impl BugRecord {
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("id", Value::Str(self.id.clone())),
            ("oracle", Value::Str(self.oracle.clone())),
            ("expect", Value::Str(self.expect.name().into())),
            (
                "inject_bug",
                match self.bug {
                    Some(b) => Value::Str(b.name().into()),
                    None => Value::Null,
                },
            ),
            ("policy", Value::Str(policy_slug(self.policy).into())),
            ("scenario", Value::Str(self.scenario.name().into())),
            ("seed", Value::Str(self.seed.to_string())),
            ("intervals", Value::Num(self.intervals as f64)),
            (
                "task_timeout_intervals",
                Value::Num(self.task_timeout_intervals as f64),
            ),
            ("plan", self.plan.to_json()),
            ("note", Value::Str(self.note.clone())),
        ])
    }

    pub fn from_json(v: &Value) -> Result<BugRecord, JsonError> {
        let expect = Expectation::parse(v.req("expect")?.as_str()?)
            .ok_or(JsonError::Type("expect: green|violates"))?;
        let bug = match v.req("inject_bug")? {
            Value::Null => None,
            other => Some(
                BugKind::parse(other.as_str()?).ok_or(JsonError::Type("known bug kind"))?,
            ),
        };
        let policy = PolicyKind::parse(v.req("policy")?.as_str()?)
            .ok_or(JsonError::Type("known policy"))?;
        let scenario = Scenario::parse(v.req("scenario")?.as_str()?)
            .ok_or(JsonError::Type("known scenario"))?;
        let seed = match v.req("seed")? {
            Value::Str(s) => s.parse().map_err(|_| JsonError::Type("u64 seed"))?,
            other => other.as_f64()? as u64,
        };
        Ok(BugRecord {
            id: v.req("id")?.as_str()?.to_string(),
            oracle: v.req("oracle")?.as_str()?.to_string(),
            expect,
            bug,
            policy,
            scenario,
            seed,
            intervals: v.req("intervals")?.as_usize()?,
            task_timeout_intervals: v.req("task_timeout_intervals")?.as_usize()?,
            plan: FaultPlan::from_json(v.req("plan")?)?,
            note: v.get("note").and_then(|n| n.as_str().ok()).unwrap_or("").to_string(),
        })
    }

    /// Replay the artifact and check its expectation. `Ok(())` means the
    /// contract still holds; `Err` carries a human-readable diagnosis.
    pub fn replay(&self) -> Result<(), String> {
        let (cfg, _generated) = self.scenario.build(self.policy, self.seed, self.intervals);
        let opts = ChaosOptions {
            bug: self.bug,
            task_timeout_intervals: self.task_timeout_intervals,
            // replay under the exact oracle regime the artifact was
            // recorded with — paranoid twin-auditing stays off
            paranoid: false,
        };
        let out = chaos::run_chaos(&cfg, &self.plan, &opts, None)
            .map_err(|e| format!("{}: replay failed to run: {e:#}", self.id))?;
        let hit = out.violations.iter().any(|v| v.oracle == self.oracle);
        match self.expect {
            Expectation::Green => {
                if let Some(first) = out.violations.first() {
                    Err(format!(
                        "{}: expected a green replay but got {} violation(s); first: {first}",
                        self.id,
                        out.violations.len()
                    ))
                } else {
                    Ok(())
                }
            }
            Expectation::Violates => {
                if hit {
                    Ok(())
                } else {
                    Err(format!(
                        "{}: oracle '{}' no longer fires — detection power regressed \
                         (other violations: {:?})",
                        self.id,
                        self.oracle,
                        out.violated_oracles()
                    ))
                }
            }
        }
    }
}

/// Write a record into `dir` as `<id>.json` (pretty-printed for review).
pub fn save(dir: &Path, record: &BugRecord) -> Result<PathBuf, String> {
    std::fs::create_dir_all(dir).map_err(|e| format!("creating {}: {e}", dir.display()))?;
    let path = dir.join(format!("{}.json", record.id));
    let mut text = record.to_json().to_pretty();
    text.push('\n');
    std::fs::write(&path, text).map_err(|e| format!("writing {}: {e}", path.display()))?;
    Ok(path)
}

/// Load every artifact in `dir`, sorted by file name for a stable replay
/// order. A missing directory is an empty bug-base; an unparsable file is
/// an error (a corrupt artifact must not silently stop guarding).
pub fn load_dir(dir: &Path) -> Result<Vec<BugRecord>, String> {
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(Vec::new()),
        Err(e) => return Err(format!("reading {}: {e}", dir.display())),
    };
    let mut paths: Vec<PathBuf> = entries
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().map(|x| x == "json").unwrap_or(false))
        .collect();
    paths.sort();
    let mut out = Vec::with_capacity(paths.len());
    for path in paths {
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("reading {}: {e}", path.display()))?;
        let v = json::parse(&text).map_err(|e| format!("parsing {}: {e}", path.display()))?;
        out.push(
            BugRecord::from_json(&v)
                .map_err(|e| format!("decoding {}: {e}", path.display()))?,
        );
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chaos::{ChaosEvent, TimedEvent};

    fn record() -> BugRecord {
        let plan = FaultPlan::empty(5, 8).with_events(vec![TimedEvent {
            t: 1,
            event: ChaosEvent::CorrelatedRackFailure { rack: 0 },
        }]);
        BugRecord {
            id: "forget-rack-member__offline-matches-plan".into(),
            oracle: "offline-matches-plan".into(),
            expect: Expectation::Violates,
            bug: Some(BugKind::ForgetRackMember),
            policy: PolicyKind::ModelCompression,
            scenario: Scenario::Clean,
            seed: 5,
            intervals: 8,
            task_timeout_intervals: 40,
            plan,
            note: "unit-test artifact".into(),
        }
    }

    #[test]
    fn record_json_roundtrip() {
        let r = record();
        let text = r.to_json().to_string();
        let back = BugRecord::from_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.id, r.id);
        assert_eq!(back.oracle, r.oracle);
        assert_eq!(back.expect, r.expect);
        assert_eq!(back.bug, r.bug);
        assert_eq!(back.policy, r.policy);
        assert_eq!(back.scenario, r.scenario);
        assert_eq!(back.seed, r.seed);
        assert_eq!(back.plan, r.plan);
    }

    #[test]
    fn violates_artifact_replays_and_guards_detection() {
        let r = record();
        assert!(r.replay().is_ok(), "oracle must still catch the deliberate bug");
        // without the bug the same plan is green, so a Green twin also holds
        let green = BugRecord {
            id: "rack-cycle-green".into(),
            expect: Expectation::Green,
            bug: None,
            ..record()
        };
        assert!(green.replay().is_ok(), "{:?}", green.replay());
        // and a Green expectation WITH the bug must fail loudly
        let broken = BugRecord { expect: Expectation::Green, ..record() };
        let err = broken.replay().unwrap_err();
        assert!(err.contains("expected a green replay"), "{err}");
    }

    #[test]
    fn save_load_dir_roundtrip() {
        let dir = std::env::temp_dir()
            .join(format!("splitplace-bugbase-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        assert!(load_dir(&dir).unwrap().is_empty(), "missing dir is an empty base");
        let r = record();
        save(&dir, &r).unwrap();
        let loaded = load_dir(&dir).unwrap();
        assert_eq!(loaded.len(), 1);
        assert_eq!(loaded[0].id, r.id);
        // corrupt artifacts fail loudly
        std::fs::write(dir.join("zz-corrupt.json"), "{nope").unwrap();
        assert!(load_dir(&dir).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
