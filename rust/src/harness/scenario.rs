//! Matrix axes: scenarios × policies × seeds.
//!
//! A [`Cell`] is one point of the evaluation cross product. Each cell is a
//! *pure function of its coordinates*: the experiment config, the fault
//! plan and every internal RNG stream derive deterministically from
//! (policy, scenario, seed), so cells can execute on any worker thread in
//! any order and still reproduce bit-identical results.

use crate::chaos::{ChaosEvent, FaultPlan, Profile, TimedEvent};
use crate::config::{ExperimentConfig, PolicyKind};
use crate::util::rng::{mix, Rng};

/// Derive the experiment's internal seeds from one master seed so a single
/// number reproduces the whole run (plan, fleet, workload, MAB). Shared by
/// the `chaos` and `matrix` CLIs — a matrix cell replays exactly under
/// `splitplace chaos --plan`.
pub fn seed_config(cfg: &mut ExperimentConfig, seed: u64) {
    cfg.workload.seed = seed ^ 0x57AB;
    cfg.cluster.seed = seed ^ 0xC1A0;
    cfg.mab.seed = seed ^ 0x03AB;
}

/// One workload regime of the paper's evaluation (Table 4 / Figs. 16–18
/// territory), encoded as a config shape plus a deterministic fault plan.
///
/// The `Medium*`/`Large*` variants are the **fleet-tier axis**: the same
/// regimes on the ≈200 / ≈1000-worker presets
/// ([`crate::config::ClusterConfig::medium`]/[`large`][`crate::config::ClusterConfig::large`]),
/// with λ scaled up so the active set grows with the fleet. Chaos plans
/// generate against the tier's worker count, so crash draws and rack
/// quarters respect the tier's `n`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Scenario {
    /// Fault-free control run.
    Clean,
    /// Occasional single-worker faults (generated light profile).
    ChaosLight,
    /// Crash storms, stragglers, blackouts, squeezes, rack failures,
    /// clock skew and flash crowds (generated heavy profile).
    ChaosHeavy,
    /// Lower base λ punctured by two seeded arrival bursts.
    FlashCrowd,
    /// Every worker mobile: channels swing across the full OU range, plus
    /// seeded blackout episodes on top.
    MobilityHeavy,
    /// Every worker mobile, with seeded rack handoffs chained off the
    /// generator's own rack mirror (each `from_rack` is the rack the
    /// engine actually holds when the event lands, so every handoff is
    /// effectual and the plan-ledger oracle stays armed).
    MobilityHandoff,
    /// Finite per-worker batteries on an otherwise fault-free run: the
    /// SPEC power curve drains them until workers die Battery-owned,
    /// mid-horizon — the energy-fit placer's headline regime.
    BatteryConstrained,
    /// Fault-free run on the ≈200-worker tier.
    MediumClean,
    /// Light chaos on the ≈200-worker tier.
    MediumChaosLight,
    /// Fault-free run on the ≈1000-worker tier.
    LargeClean,
    /// Light chaos on the ≈1000-worker tier.
    LargeChaosLight,
    /// Fault-free run on the 5000-worker tier.
    HugeClean,
    /// Light chaos on the 5000-worker tier.
    HugeChaosLight,
    /// Fault-free run on the 25 000-worker tier.
    HyperscaleClean,
    /// Light chaos on the 25 000-worker tier — the shard-parallel
    /// integrator's headline regime.
    HyperscaleChaosLight,
    /// Committed-trace replay: arrivals come verbatim from
    /// `tests/traces/edge-burst.json` instead of the generator — the
    /// recorded stream is itself the regression fixture.
    TraceReplay,
    /// Headline traffic cell: diurnal λ punctured by flash-crowd bursts
    /// under light chaos, with admission control and the autoscaler
    /// active — the regime where scaling and the MAB champion interact.
    DiurnalFlashCrowd,
    /// Fig. 13 regime: compute-constrained edge under MMPP burst arrivals
    /// with admission shedding.
    ConstrainedEdge,
    /// Fig. 16 regime: single-application workload (CIFAR-100 only).
    SingleApp,
    /// Fig. 18 regime: WAN cloud tier under heavy-tail batch arrivals.
    CloudTier,
}

impl Scenario {
    /// The paper-scale regimes (10-worker fleet).
    pub const BASE: [Scenario; 7] = [
        Scenario::Clean,
        Scenario::ChaosLight,
        Scenario::ChaosHeavy,
        Scenario::FlashCrowd,
        Scenario::MobilityHeavy,
        Scenario::MobilityHandoff,
        Scenario::BatteryConstrained,
    ];

    /// The fleet-tier regimes (200/1000/5000/25 000-worker fleets).
    pub const TIERS: [Scenario; 8] = [
        Scenario::MediumClean,
        Scenario::MediumChaosLight,
        Scenario::LargeClean,
        Scenario::LargeChaosLight,
        Scenario::HugeClean,
        Scenario::HugeChaosLight,
        Scenario::HyperscaleClean,
        Scenario::HyperscaleChaosLight,
    ];

    /// The traffic-plane regimes (ISSUE-6): trace replay, the
    /// diurnal-flash-crowd headline, and the paper's Fig. 13/16/18 shapes.
    pub const TRAFFIC: [Scenario; 5] = [
        Scenario::TraceReplay,
        Scenario::DiurnalFlashCrowd,
        Scenario::ConstrainedEdge,
        Scenario::SingleApp,
        Scenario::CloudTier,
    ];

    pub const ALL: [Scenario; 20] = [
        Scenario::Clean,
        Scenario::ChaosLight,
        Scenario::ChaosHeavy,
        Scenario::FlashCrowd,
        Scenario::MobilityHeavy,
        Scenario::MobilityHandoff,
        Scenario::BatteryConstrained,
        Scenario::MediumClean,
        Scenario::MediumChaosLight,
        Scenario::LargeClean,
        Scenario::LargeChaosLight,
        Scenario::HugeClean,
        Scenario::HugeChaosLight,
        Scenario::HyperscaleClean,
        Scenario::HyperscaleChaosLight,
        Scenario::TraceReplay,
        Scenario::DiurnalFlashCrowd,
        Scenario::ConstrainedEdge,
        Scenario::SingleApp,
        Scenario::CloudTier,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Scenario::Clean => "clean",
            Scenario::ChaosLight => "chaos-light",
            Scenario::ChaosHeavy => "chaos-heavy",
            Scenario::FlashCrowd => "flash-crowd",
            Scenario::MobilityHeavy => "mobility-heavy",
            Scenario::MobilityHandoff => "mobility-handoff",
            Scenario::BatteryConstrained => "battery-constrained",
            Scenario::MediumClean => "medium-clean",
            Scenario::MediumChaosLight => "medium-chaos-light",
            Scenario::LargeClean => "large-clean",
            Scenario::LargeChaosLight => "large-chaos-light",
            Scenario::HugeClean => "huge-clean",
            Scenario::HugeChaosLight => "huge-chaos-light",
            Scenario::HyperscaleClean => "hyperscale-clean",
            Scenario::HyperscaleChaosLight => "hyperscale-chaos-light",
            Scenario::TraceReplay => "trace-replay",
            Scenario::DiurnalFlashCrowd => "diurnal-flash-crowd",
            Scenario::ConstrainedEdge => "constrained-edge",
            Scenario::SingleApp => "single-app",
            Scenario::CloudTier => "cloud-tier",
        }
    }

    pub fn parse(s: &str) -> Option<Scenario> {
        Scenario::ALL.into_iter().find(|sc| sc.name() == s.to_ascii_lowercase())
    }

    /// Build the cell's experiment config and fault plan. Deterministic in
    /// (policy, seed, intervals); never touches global state.
    pub fn build(
        &self,
        policy: PolicyKind,
        seed: u64,
        intervals: usize,
    ) -> (ExperimentConfig, FaultPlan) {
        let mut cfg = ExperimentConfig::small();
        cfg.policy = policy;
        cfg.sim.intervals = intervals;
        cfg.workload.lambda = crate::config::ClusterConfig::SMALL_TIER_LAMBDA;
        // fleet-tier axis: swap the 10-worker fleet for the 200/1000
        // presets BEFORE seeding (seed_config stamps cluster.seed) and
        // before plan generation (worker draws use the tier's n). The
        // tier λ constants live next to the presets in `ClusterConfig`.
        match self {
            Scenario::MediumClean | Scenario::MediumChaosLight => {
                cfg.cluster = crate::config::ClusterConfig::medium();
                cfg.workload.lambda = crate::config::ClusterConfig::MEDIUM_TIER_LAMBDA;
            }
            Scenario::LargeClean | Scenario::LargeChaosLight => {
                cfg.cluster = crate::config::ClusterConfig::large();
                cfg.workload.lambda = crate::config::ClusterConfig::LARGE_TIER_LAMBDA;
            }
            Scenario::HugeClean | Scenario::HugeChaosLight => {
                cfg.cluster = crate::config::ClusterConfig::huge();
                cfg.workload.lambda = crate::config::ClusterConfig::HUGE_TIER_LAMBDA;
            }
            Scenario::HyperscaleClean | Scenario::HyperscaleChaosLight => {
                cfg.cluster = crate::config::ClusterConfig::hyperscale();
                cfg.workload.lambda = crate::config::ClusterConfig::HYPERSCALE_TIER_LAMBDA;
            }
            _ => {}
        }
        seed_config(&mut cfg, seed);
        let n = cfg.cluster.total_workers();
        let plan = match self {
            Scenario::Clean
            | Scenario::MediumClean
            | Scenario::LargeClean
            | Scenario::HugeClean
            | Scenario::HyperscaleClean => FaultPlan::empty(seed, intervals),
            Scenario::ChaosLight
            | Scenario::MediumChaosLight
            | Scenario::LargeChaosLight
            | Scenario::HugeChaosLight
            | Scenario::HyperscaleChaosLight => {
                FaultPlan::generate(seed, intervals, Profile::Light, n)
            }
            Scenario::ChaosHeavy => FaultPlan::generate(seed, intervals, Profile::Heavy, n),
            Scenario::FlashCrowd => {
                cfg.workload.lambda = 2.0;
                let mut rng = Rng::new(mix(seed, 0xF1A5));
                let mut events = Vec::new();
                // two bursts: one early, one in the latter half; episodes
                // never overlap (an earlier END would cancel a later burst)
                let mut flash_until = 0usize;
                for phase in 0..2usize {
                    let lo = (1 + phase * intervals / 2).max(flash_until);
                    if lo + 1 >= intervals {
                        break;
                    }
                    let t = lo + rng.below(2) as usize;
                    let d = 2 + rng.below(3) as usize;
                    let mult = rng.range(6.0, 10.0);
                    if t >= intervals {
                        break;
                    }
                    events.push(TimedEvent {
                        t,
                        event: ChaosEvent::FlashCrowd { lambda_mult: mult },
                    });
                    let end = (t + d).min(intervals - 1).max(t + 1);
                    if end < intervals {
                        events.push(TimedEvent { t: end, event: ChaosEvent::FlashCrowdEnd });
                    }
                    flash_until = end + 1;
                }
                events.sort_by_key(|e| e.t);
                FaultPlan {
                    seed,
                    intervals,
                    profile: "flash-crowd".into(),
                    events,
                }
            }
            Scenario::TraceReplay => {
                // the committed trace is the arrival stream; resolved
                // relative to the crate root so any cwd works
                cfg.traffic.trace = Some("tests/traces/edge-burst.json".into());
                FaultPlan::empty(seed, intervals)
            }
            Scenario::DiurnalFlashCrowd => {
                cfg.workload.lambda = 3.0;
                cfg.traffic.shape = crate::traffic::TrafficShape::Diurnal;
                cfg.traffic.admission = Some(crate::traffic::AdmissionConfig::default());
                cfg.traffic.autoscale = Some(crate::traffic::AutoscaleConfig::default());
                // light chaos with two seeded flash bursts riding on top:
                // the autoscaler must grow into the bursts while the fault
                // plan churns availability underneath it
                let mut events =
                    FaultPlan::generate(seed, intervals, Profile::Light, n).events;
                let mut rng = Rng::new(mix(seed, 0xD1F1));
                let mut flash_until = 0usize;
                for phase in 0..2usize {
                    let lo = (1 + phase * intervals / 2).max(flash_until);
                    if lo + 1 >= intervals {
                        break;
                    }
                    let t = lo + rng.below(2) as usize;
                    let d = 2 + rng.below(3) as usize;
                    let mult = rng.range(4.0, 8.0);
                    if t >= intervals {
                        break;
                    }
                    events.push(TimedEvent {
                        t,
                        event: ChaosEvent::FlashCrowd { lambda_mult: mult },
                    });
                    let end = (t + d).min(intervals - 1).max(t + 1);
                    if end < intervals {
                        events.push(TimedEvent { t: end, event: ChaosEvent::FlashCrowdEnd });
                    }
                    flash_until = end + 1;
                }
                events.sort_by_key(|e| e.t);
                FaultPlan {
                    seed,
                    intervals,
                    profile: "diurnal-flash-crowd".into(),
                    events,
                }
            }
            Scenario::ConstrainedEdge => {
                cfg.cluster.constraint = crate::config::EnvConstraint::Compute;
                cfg.traffic.shape = crate::traffic::TrafficShape::Mmpp;
                cfg.traffic.admission = Some(crate::traffic::AdmissionConfig::default());
                FaultPlan::empty(seed, intervals)
            }
            Scenario::SingleApp => {
                cfg.workload.app_weights = [0.0, 0.0, 1.0];
                FaultPlan::empty(seed, intervals)
            }
            Scenario::CloudTier => {
                cfg.cluster.tier = crate::config::Tier::Cloud;
                cfg.traffic.shape = crate::traffic::TrafficShape::HeavyTail;
                FaultPlan::empty(seed, intervals)
            }
            Scenario::MobilityHandoff => {
                cfg.cluster.mobile_fraction = 1.0;
                // the generator mirrors the engine's rack state, so every
                // emitted `from_rack` is the rack the worker actually
                // occupies when the event fires — no handoff compiles to a
                // Noop, and replaying the plan reproduces the same chain
                let mut rng = Rng::new(mix(seed, 0xD0FF));
                let mut racks = crate::chaos::events::initial_racks(n);
                let mut events = Vec::new();
                for t in 1..intervals {
                    // at least one handoff per run (t=1 is forced), then a
                    // seeded ~35% chance each later interval
                    if t == 1 || rng.chance(0.35) {
                        let w = rng.below(n as u64) as usize;
                        let hop =
                            1 + rng.below((crate::chaos::events::RACKS - 1) as u64) as usize;
                        let from = racks[w];
                        let to = (from + hop) % crate::chaos::events::RACKS;
                        events.push(TimedEvent {
                            t,
                            event: ChaosEvent::Handoff { worker: w, from_rack: from, to_rack: to },
                        });
                        racks[w] = to;
                    }
                }
                FaultPlan {
                    seed,
                    intervals,
                    profile: "mobility-handoff".into(),
                    events,
                }
            }
            Scenario::BatteryConstrained => {
                // ~45 Wh at 5–6.5 Wh/interval idle draw: the hungriest
                // node types die around interval 7, the frugal ones later —
                // staggered Battery-owned evictions inside a 10–12-interval
                // matrix horizon, no chaos plan needed
                cfg.cluster.battery_wh = Some(45.0);
                FaultPlan::empty(seed, intervals)
            }
            Scenario::MobilityHeavy => {
                cfg.cluster.mobile_fraction = 1.0;
                let mut rng = Rng::new(mix(seed, 0xB1AC));
                let mut events = Vec::new();
                let mut black_until = vec![0usize; n];
                for t in 0..intervals {
                    if rng.chance(0.10) {
                        let w = rng.below(n as u64) as usize;
                        let d = 1 + rng.below(3) as usize;
                        if t >= black_until[w] {
                            events.push(TimedEvent { t, event: ChaosEvent::Blackout { worker: w } });
                            if t + d < intervals {
                                events.push(TimedEvent {
                                    t: t + d,
                                    event: ChaosEvent::BlackoutEnd { worker: w },
                                });
                            }
                            black_until[w] = t + d;
                        }
                    }
                }
                events.sort_by_key(|e| e.t);
                FaultPlan {
                    seed,
                    intervals,
                    profile: "mobility-heavy".into(),
                    events,
                }
            }
        };
        (cfg, plan)
    }
}

/// CLI-facing policy slug (lowercase, also accepted by [`PolicyKind::parse`]).
pub fn policy_slug(p: PolicyKind) -> &'static str {
    match p {
        PolicyKind::MabDaso => "mab-daso",
        PolicyKind::MabGobi => "mab-gobi",
        PolicyKind::RandomDaso => "random-daso",
        PolicyKind::LayerGobi => "layer-gobi",
        PolicyKind::SemanticGobi => "semantic-gobi",
        PolicyKind::Gillis => "gillis",
        PolicyKind::ModelCompression => "mc",
        PolicyKind::EnergyFit => "energyfit",
        PolicyKind::LatMem => "latmem",
        PolicyKind::OnlineSplit => "onlinesplit",
    }
}

/// One point of the policy × scenario × seed cross product.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Cell {
    pub policy: PolicyKind,
    pub scenario: Scenario,
    pub seed: u64,
}

impl Cell {
    /// Human-facing id, also the unit `--filter` substrings match against.
    pub fn id(&self) -> String {
        format!("{}/{}/s{}", policy_slug(self.policy), self.scenario.name(), self.seed)
    }

    /// Filesystem-safe id (golden and bug-base file stems).
    pub fn file_stem(&self) -> String {
        self.id().replace('/', "__")
    }
}

/// Slack on the Table-4 reward ordering assertion: at matrix horizons
/// (≈8–12 intervals, small fleet, fallback placement) the champion may
/// trail a baseline by small-sample noise without the paper's claim being
/// wrong — the gate exists to catch gross inversions (a broken champion
/// stack losing the accuracy/SLA trade it is built around), while the
/// exact deltas stay golden-gated at full precision.
pub const REWARD_SLACK: f64 = 0.10;

/// A differential policy-pair cell: policies `a` (champion) and `b`
/// (challenger) run against the SAME scenario config and fault plan — the
/// engine replays one compiled command stream per side, derived from
/// identical coordinates — and the cell's summary carries the per-metric
/// deltas (a − b) as first-class golden-gated quantities.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DiffCell {
    pub a: PolicyKind,
    pub b: PolicyKind,
    pub scenario: Scenario,
    pub seed: u64,
    /// Assert the Table-4 ordering: `a` must not trail `b` on avg reward
    /// by more than [`REWARD_SLACK`] (checked only when both sides
    /// completed tasks). An ordering failure fails the cell like an
    /// oracle violation does.
    pub expect_a_reward_ge_b: bool,
}

impl DiffCell {
    /// `a~b` — the pair slug shared by the cell id, the summary's policy
    /// field and the golden/bug-base file stems.
    pub fn policy_pair(&self) -> String {
        format!("{}~{}", policy_slug(self.a), policy_slug(self.b))
    }

    /// `a~b/scenario/sN` — the `~` marks a differential pair.
    pub fn id(&self) -> String {
        format!("{}/{}/s{}", self.policy_pair(), self.scenario.name(), self.seed)
    }

    pub fn file_stem(&self) -> String {
        self.id().replace('/', "__")
    }
}

/// One schedulable unit of the matrix: a single policy run or a
/// differential policy pair.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MatrixCell {
    Single(Cell),
    Diff(DiffCell),
}

impl MatrixCell {
    pub fn id(&self) -> String {
        match self {
            MatrixCell::Single(c) => c.id(),
            MatrixCell::Diff(d) => d.id(),
        }
    }

    pub fn file_stem(&self) -> String {
        match self {
            MatrixCell::Single(c) => c.file_stem(),
            MatrixCell::Diff(d) => d.file_stem(),
        }
    }

    /// Scenario coordinate (shared by both sides of a diff cell).
    pub fn scenario(&self) -> Scenario {
        match self {
            MatrixCell::Single(c) => c.scenario,
            MatrixCell::Diff(d) => d.scenario,
        }
    }

    pub fn seed(&self) -> u64 {
        match self {
            MatrixCell::Single(c) => c.seed,
            MatrixCell::Diff(d) => d.seed,
        }
    }
}

fn cross(policies: &[PolicyKind], scenarios: &[Scenario], seeds: &[u64]) -> Vec<Cell> {
    let mut cells = Vec::with_capacity(policies.len() * scenarios.len() * seeds.len());
    for &policy in policies {
        for &scenario in scenarios {
            for &seed in seeds {
                cells.push(Cell { policy, scenario, seed });
            }
        }
    }
    cells
}

/// Differential pairs: the MAB+DASO champion against every baseline, on a
/// clean run and under heavy chaos. The ordering assertion is armed only
/// where it is structural at matrix horizons: against model compression on
/// clean runs, where the champion's accuracy edge is decisive (Table 4).
fn diff_cells(baselines: &[PolicyKind], seeds: &[u64]) -> Vec<MatrixCell> {
    let mut cells = Vec::new();
    for &b in baselines {
        for scenario in [Scenario::Clean, Scenario::ChaosHeavy] {
            for &seed in seeds {
                cells.push(MatrixCell::Diff(DiffCell {
                    a: PolicyKind::MabDaso,
                    b,
                    scenario,
                    seed,
                    expect_a_reward_ge_b: b == PolicyKind::ModelCompression
                        && scenario == Scenario::Clean,
                }));
            }
        }
    }
    cells
}

/// The representative policy set the CI smoke matrix runs on every base
/// scenario: heuristic MC, RL Gillis, the related-work LatMem and
/// OnlineSplit stacks, and the full MAB+DASO champion. Single source of
/// truth — the benchlib chaos tables chart exactly this set
/// ([`crate::benchlib::scenarios::chaos_table_policies`]), so what the
/// benches eyeball is what CI gates.
pub const SMOKE_POLICIES: [PolicyKind; 5] = [
    PolicyKind::ModelCompression,
    PolicyKind::Gillis,
    PolicyKind::LatMem,
    PolicyKind::OnlineSplit,
    PolicyKind::MabDaso,
];

/// Energy differential pairs: energy-fit against its model-compression
/// twin (`energyfit~mc/…`) — the SAME splitter on both sides, so the
/// per-metric deltas isolate the placer's contribution — on the
/// battery-constrained regime it targets and on a clean control. No
/// ordering assertion is armed; the AEC/reward deltas are golden-gated at
/// full precision instead.
fn energy_diff_cells(seeds: &[u64]) -> Vec<MatrixCell> {
    let mut cells = Vec::new();
    for scenario in [Scenario::BatteryConstrained, Scenario::Clean] {
        for &seed in seeds {
            cells.push(MatrixCell::Diff(DiffCell {
                a: PolicyKind::EnergyFit,
                b: PolicyKind::ModelCompression,
                scenario,
                seed,
                expect_a_reward_ge_b: false,
            }));
        }
    }
    cells
}

/// Challenger differential pairs: each related-work splitter stack leads a
/// pair against the MAB+DASO champion (ids `latmem~mab-daso/…`,
/// `onlinesplit~mab-daso/…`) on a clean run and under light chaos. No
/// ordering assertion is armed — these cells golden-gate HOW the new
/// stacks compare against the paper's model, not that they beat it.
fn challenger_diff_cells(seeds: &[u64]) -> Vec<MatrixCell> {
    let mut cells = Vec::new();
    for a in [PolicyKind::LatMem, PolicyKind::OnlineSplit] {
        for scenario in [Scenario::Clean, Scenario::ChaosLight] {
            for &seed in seeds {
                cells.push(MatrixCell::Diff(DiffCell {
                    a,
                    b: PolicyKind::MabDaso,
                    scenario,
                    seed,
                    expect_a_reward_ge_b: false,
                }));
            }
        }
    }
    cells
}

/// Enumerate matrix cells for a filter, in a fixed deterministic order.
///
/// * `"smoke"` — the CI subset: 5 representative policies (heuristic MC,
///   RL Gillis, the related-work LatMem and OnlineSplit stacks, the full
///   MAB+DASO stack) × every base scenario × the first seed — every new
///   policy rides through chaos-heavy here, as the ROADMAP demands — the
///   fleet-tier scenarios under the cheap MC policy (the tier axis stays
///   golden-gated without tripling 1000-worker cells in CI), the
///   traffic-plane scenarios under MC plus the headline
///   `mab-daso/diurnal-flash-crowd` cell (autoscaler × MAB champion), the
///   MAB+DASO-vs-{MC, Gillis} differential pairs, the challenger pairs
///   `latmem~mab-daso` / `onlinesplit~mab-daso`, and the energy pairs
///   `energyfit~mc` on battery-constrained + clean.
/// * `"full"` / `""` — all 10 policies × every scenario (base AND tier) ×
///   all seeds, plus MAB+DASO-vs-baseline differential pairs (the two
///   related-work stacks and energy-fit excluded: they meet their
///   counterparts in the challenger/energy pairs only, so no pair is
///   simulated twice with swapped sides), the challenger pairs, and the
///   energy pairs.
/// * anything else — substring match against [`MatrixCell::id`] over the
///   full cross product (e.g. `"chaos-heavy"`, `"mab-daso/"`, `"/s2"`,
///   `"~"` for all differential cells).
pub fn matrix_cells(filter: &str, seeds: &[u64]) -> Vec<MatrixCell> {
    let full = |seeds: &[u64]| -> Vec<MatrixCell> {
        let mut cells: Vec<MatrixCell> = cross(&PolicyKind::all(), &Scenario::ALL, seeds)
            .into_iter()
            .map(MatrixCell::Single)
            .collect();
        // the related-work stacks pair with the champion via the
        // challenger cells below — a champion-led twin of the same clean
        // coordinates would re-run the identical pair of simulations and
        // gate the same data with the sign flipped. Energy-fit likewise
        // meets only its MC twin, in the dedicated energy pairs.
        let baselines: Vec<PolicyKind> = PolicyKind::all()
            .into_iter()
            .filter(|&p| {
                p != PolicyKind::MabDaso
                    && !matches!(
                        p,
                        PolicyKind::LatMem | PolicyKind::OnlineSplit | PolicyKind::EnergyFit
                    )
            })
            .collect();
        cells.extend(diff_cells(&baselines, seeds));
        cells.extend(challenger_diff_cells(seeds));
        cells.extend(energy_diff_cells(seeds));
        cells
    };
    match filter {
        "smoke" => {
            let first = &seeds[..seeds.len().min(1)];
            let mut cells: Vec<MatrixCell> = cross(&SMOKE_POLICIES, &Scenario::BASE, first)
                .into_iter()
                .map(MatrixCell::Single)
                .collect();
            cells.extend(
                cross(&[PolicyKind::ModelCompression], &Scenario::TIERS, first)
                    .into_iter()
                    .map(MatrixCell::Single),
            );
            // the traffic-plane regimes ride smoke on the cheap MC policy…
            cells.extend(
                cross(&[PolicyKind::ModelCompression], &Scenario::TRAFFIC, first)
                    .into_iter()
                    .map(MatrixCell::Single),
            );
            // …plus the one headline cell where the autoscaler and the MAB
            // champion interact (ISSUE-6 acceptance)
            if let Some(&s0) = first.first() {
                cells.push(MatrixCell::Single(Cell {
                    policy: PolicyKind::MabDaso,
                    scenario: Scenario::DiurnalFlashCrowd,
                    seed: s0,
                }));
            }
            cells.extend(diff_cells(
                &[PolicyKind::ModelCompression, PolicyKind::Gillis],
                first,
            ));
            cells.extend(challenger_diff_cells(first));
            cells.extend(energy_diff_cells(first));
            cells
        }
        "full" | "" => full(seeds),
        substr => full(seeds).into_iter().filter(|c| c.id().contains(substr)).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scenario_names_roundtrip() {
        for s in Scenario::ALL {
            assert_eq!(Scenario::parse(s.name()), Some(s));
        }
        assert_eq!(Scenario::parse("meteor"), None);
    }

    #[test]
    fn policy_slugs_parse_back() {
        for p in PolicyKind::all() {
            assert_eq!(PolicyKind::parse(policy_slug(p)), Some(p), "{}", policy_slug(p));
        }
    }

    #[test]
    fn build_is_deterministic_per_coordinates() {
        for s in Scenario::ALL {
            let (cfg_a, plan_a) = s.build(PolicyKind::MabDaso, 3, 12);
            let (cfg_b, plan_b) = s.build(PolicyKind::MabDaso, 3, 12);
            assert_eq!(plan_a, plan_b, "{}", s.name());
            assert_eq!(cfg_a.workload.seed, cfg_b.workload.seed);
            let (_, plan_c) = s.build(PolicyKind::MabDaso, 4, 12);
            if !matches!(s, Scenario::Clean) {
                // plan content (or at least its seed) must track the seed
                assert_ne!(plan_a.seed, plan_c.seed);
            }
        }
    }

    #[test]
    fn scenario_plans_stay_in_horizon_and_sorted() {
        for s in Scenario::ALL {
            for seed in [1u64, 2, 9] {
                let (cfg, plan) = s.build(PolicyKind::ModelCompression, seed, 10);
                assert_eq!(plan.intervals, 10);
                for pair in plan.events.windows(2) {
                    assert!(pair[0].t <= pair[1].t, "{} unsorted", s.name());
                }
                for e in &plan.events {
                    assert!(e.t < 10, "{} event beyond horizon", s.name());
                    if let Some(w) = e.event.worker() {
                        assert!(w < cfg.cluster.total_workers());
                    }
                }
            }
        }
    }

    #[test]
    fn flash_crowd_scenario_has_bursts() {
        let (_, plan) = Scenario::FlashCrowd.build(PolicyKind::ModelCompression, 1, 12);
        let bursts = plan
            .events
            .iter()
            .filter(|e| matches!(e.event, ChaosEvent::FlashCrowd { .. }))
            .count();
        assert!(bursts >= 1, "flash-crowd scenario without a burst");
    }

    #[test]
    fn mobility_heavy_is_fully_mobile() {
        let (cfg, _) = Scenario::MobilityHeavy.build(PolicyKind::ModelCompression, 1, 12);
        assert_eq!(cfg.cluster.mobile_fraction, 1.0);
        assert_eq!(cfg.cluster.churn_rate, 0.0, "plan-ledger oracles need churn off");
    }

    #[test]
    fn mobility_handoff_chains_rack_moves() {
        let (cfg, plan) = Scenario::MobilityHandoff.build(PolicyKind::ModelCompression, 1, 12);
        assert_eq!(cfg.cluster.mobile_fraction, 1.0);
        assert_eq!(cfg.cluster.churn_rate, 0.0, "plan-ledger oracles need churn off");
        assert!(cfg.cluster.battery_wh.is_none(), "plan-state tracking needs batteries off");
        let n = cfg.cluster.total_workers();
        let mut racks = crate::chaos::events::initial_racks(n);
        let mut handoffs = 0usize;
        for e in &plan.events {
            let ChaosEvent::Handoff { worker, from_rack, to_rack } = e.event else {
                panic!("mobility-handoff plans carry only handoffs: {:?}", e.event);
            };
            handoffs += 1;
            // the generator's mirror must match the chain the engine will
            // walk — a stale from_rack would compile to a Noop
            assert_eq!(racks[worker], from_rack, "handoff must chain from the live rack");
            assert_ne!(from_rack, to_rack);
            assert!(to_rack < crate::chaos::events::RACKS);
            racks[worker] = to_rack;
        }
        assert!(handoffs >= 1, "the t=1 handoff is forced");
    }

    #[test]
    fn battery_constrained_carries_finite_batteries_and_no_plan() {
        let (cfg, plan) = Scenario::BatteryConstrained.build(PolicyKind::ModelCompression, 1, 12);
        assert_eq!(cfg.cluster.battery_wh, Some(45.0));
        assert!(plan.events.is_empty(), "pressure comes from the drain, not the plan");
    }

    #[test]
    fn fleet_tier_scenarios_scale_the_fleet_and_the_plan() {
        let (cfg_m, plan_m) =
            Scenario::MediumChaosLight.build(PolicyKind::ModelCompression, 2, 12);
        assert_eq!(cfg_m.cluster.total_workers(), 200);
        assert!(cfg_m.workload.lambda > 3.0, "tier cells carry more load");
        let (cfg_l, plan_l) =
            Scenario::LargeChaosLight.build(PolicyKind::ModelCompression, 2, 12);
        assert_eq!(cfg_l.cluster.total_workers(), 1000);
        // plan worker draws respect the tier's n — and actually use the
        // headroom beyond the small fleet across a few seeds
        let mut beyond_small = false;
        for seed in 1..6u64 {
            let (_, plan) =
                Scenario::LargeChaosLight.build(PolicyKind::ModelCompression, seed, 20);
            for e in &plan.events {
                if let Some(w) = e.event.worker() {
                    assert!(w < 1000);
                    beyond_small |= w >= 10;
                }
            }
        }
        assert!(beyond_small, "large-tier plans must target the big fleet");
        // clean tier cells are fault-free controls
        let (_, plan_clean) = Scenario::LargeClean.build(PolicyKind::ModelCompression, 2, 12);
        assert!(plan_clean.events.is_empty());
        // the hyperscale tiers swap in the big presets and scale λ with them
        let (cfg_h, _) = Scenario::HugeChaosLight.build(PolicyKind::ModelCompression, 2, 12);
        assert_eq!(cfg_h.cluster.total_workers(), 5_000);
        let (cfg_hs, plan_hs) =
            Scenario::HyperscaleChaosLight.build(PolicyKind::ModelCompression, 2, 12);
        assert_eq!(cfg_hs.cluster.total_workers(), 25_000);
        assert!(cfg_hs.workload.lambda > cfg_h.workload.lambda);
        for e in &plan_hs.events {
            if let Some(w) = e.event.worker() {
                assert!(w < 25_000);
            }
        }
        let (_, plan_hc) = Scenario::HyperscaleClean.build(PolicyKind::ModelCompression, 2, 12);
        assert!(plan_hc.events.is_empty());
        // same coordinates, different tier ⇒ different fleet, same seeds
        assert_eq!(cfg_m.workload.seed, cfg_l.workload.seed);
        assert_eq!(plan_m.intervals, plan_l.intervals);
    }

    #[test]
    fn base_tiers_and_traffic_partition_all() {
        let mut combined: Vec<Scenario> = Scenario::BASE.to_vec();
        combined.extend(Scenario::TIERS);
        combined.extend(Scenario::TRAFFIC);
        assert_eq!(combined, Scenario::ALL.to_vec());
    }

    #[test]
    fn traffic_scenarios_carry_their_regimes() {
        use crate::config::{EnvConstraint, Tier};
        use crate::traffic::TrafficShape;
        let (cfg, plan) = Scenario::TraceReplay.build(PolicyKind::ModelCompression, 1, 8);
        assert!(cfg.traffic.trace.as_deref().unwrap().ends_with("edge-burst.json"));
        assert!(plan.events.is_empty(), "trace replay is a fault-free control");

        let (cfg, plan) = Scenario::DiurnalFlashCrowd.build(PolicyKind::MabDaso, 1, 12);
        assert_eq!(cfg.traffic.shape, TrafficShape::Diurnal);
        assert!(cfg.traffic.admission.is_some(), "admission control must be active");
        assert!(cfg.traffic.autoscale.is_some(), "the autoscaler must be active");
        assert!(
            plan.events.iter().any(|e| matches!(e.event, ChaosEvent::FlashCrowd { .. })),
            "headline cell needs its bursts"
        );
        assert!(
            plan.events.iter().any(|e| !matches!(
                e.event,
                ChaosEvent::FlashCrowd { .. } | ChaosEvent::FlashCrowdEnd
            )),
            "headline cell rides on light chaos, not a clean plan"
        );

        let (cfg, _) = Scenario::ConstrainedEdge.build(PolicyKind::ModelCompression, 1, 8);
        assert_eq!(cfg.cluster.constraint, EnvConstraint::Compute);
        assert_eq!(cfg.traffic.shape, TrafficShape::Mmpp);
        assert!(cfg.traffic.admission.is_some());

        let (cfg, _) = Scenario::SingleApp.build(PolicyKind::ModelCompression, 1, 8);
        assert_eq!(cfg.workload.app_weights, [0.0, 0.0, 1.0]);

        let (cfg, _) = Scenario::CloudTier.build(PolicyKind::ModelCompression, 1, 8);
        assert_eq!(cfg.cluster.tier, Tier::Cloud);
        assert_eq!(cfg.traffic.shape, TrafficShape::HeavyTail);
    }

    #[test]
    fn smoke_filter_is_small_and_full_is_the_cross_product() {
        let seeds = [1u64, 2];
        let smoke = matrix_cells("smoke", &seeds);
        // 5 policies × base scenarios × 1 seed, + MC × tier scenarios,
        // + MC × traffic scenarios + the mab-daso headline traffic cell,
        // + 2 baselines × 2 scenarios diff, + 2 challengers × 2 scenarios,
        // + energyfit~mc × 2 scenarios
        assert_eq!(
            smoke.len(),
            5 * Scenario::BASE.len()
                + Scenario::TIERS.len()
                + Scenario::TRAFFIC.len()
                + 1
                + 4
                + 4
                + 2
        );
        // the headline autoscaler × champion cell is present
        assert!(smoke.iter().any(|c| c.id() == "mab-daso/diurnal-flash-crowd/s1"));
        // the tier axis is present in smoke (golden-gated), MC-only
        for s in Scenario::TIERS {
            let with = smoke
                .iter()
                .filter(|c| c.id().contains(s.name()))
                .collect::<Vec<_>>();
            assert_eq!(with.len(), 1, "{} must appear exactly once in smoke", s.name());
            assert!(with[0].id().starts_with("mc/"));
        }
        let full = matrix_cells("full", &seeds);
        // singles + MAB+DASO-vs-6-baselines × {clean, chaos-heavy} × seeds
        // + 2 challengers × {clean, chaos-light} × seeds (the new stacks
        // pair with the champion ONLY challenger-side — no swapped twins)
        // + energyfit~mc × {battery-constrained, clean} × seeds
        assert_eq!(
            full.len(),
            10 * Scenario::ALL.len() * seeds.len()
                + 6 * 2 * seeds.len()
                + 2 * 2 * seeds.len()
                + 2 * seeds.len()
        );
        assert!(
            !full.iter().any(|c| c.id().starts_with("mab-daso~latmem")
                || c.id().starts_with("mab-daso~onlinesplit")),
            "champion-led twins of the challenger pairs would duplicate runs"
        );
        let slice = matrix_cells("mab-daso/chaos", &seeds);
        assert!(!slice.is_empty());
        assert!(slice.iter().all(|c| c.id().contains("mab-daso/chaos")));
        assert!(matrix_cells("no-such-cell", &seeds).is_empty());
        // ids are unique — they key goldens and bug-base artifacts
        let mut ids: Vec<String> = full.iter().map(|c| c.id()).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), full.len());
    }

    /// The ISSUE-5 acceptance shape: smoke carries the new splitter stacks
    /// as single cells on every base scenario (chaos-heavy included) and
    /// as challenger differential pairs against the champion.
    #[test]
    fn smoke_carries_the_new_splitter_stacks() {
        let smoke = matrix_cells("smoke", &[1]);
        for slug in ["latmem", "onlinesplit"] {
            for scenario in Scenario::BASE {
                let id = format!("{slug}/{}/s1", scenario.name());
                assert!(
                    smoke.iter().any(|c| c.id() == id),
                    "smoke must include single cell {id}"
                );
            }
            for scenario in ["clean", "chaos-light"] {
                let id = format!("{slug}~mab-daso/{scenario}/s1");
                assert!(
                    smoke.iter().any(|c| c.id() == id),
                    "smoke must include differential cell {id}"
                );
            }
        }
    }

    #[test]
    fn diff_cells_pair_the_champion_with_baselines() {
        let seeds = [1u64];
        let diffs: Vec<MatrixCell> = matrix_cells("~", &seeds);
        assert!(!diffs.is_empty(), "the ~ filter selects differential cells");
        for cell in &diffs {
            let MatrixCell::Diff(d) = cell else {
                panic!("~ filter matched a non-diff cell: {}", cell.id());
            };
            if d.a == PolicyKind::EnergyFit {
                // the energy pair: MC splitter on both sides, so the
                // deltas isolate the placer — never ordering-armed
                assert_eq!(d.b, PolicyKind::ModelCompression, "{}", cell.id());
                assert!(!d.expect_a_reward_ge_b, "energy pairs are never armed");
            } else {
                // every other pair has the full MAB+DASO stack on exactly
                // one side: champion pairs lead with it, challengers chase
                assert!(
                    (d.a == PolicyKind::MabDaso) != (d.b == PolicyKind::MabDaso),
                    "{}: exactly one side must be the champion",
                    cell.id()
                );
                if d.a != PolicyKind::MabDaso {
                    assert!(
                        matches!(d.a, PolicyKind::LatMem | PolicyKind::OnlineSplit),
                        "{}: only the new stacks lead challenger pairs",
                        cell.id()
                    );
                    assert!(!d.expect_a_reward_ge_b, "challenger pairs are never armed");
                }
            }
            assert!(cell.id().contains('~'));
            assert!(!cell.file_stem().contains('/'));
        }
        // the ordering assertion is armed on the structural pair only
        let armed: Vec<&MatrixCell> = diffs
            .iter()
            .filter(|c| matches!(c, MatrixCell::Diff(d) if d.expect_a_reward_ge_b))
            .collect();
        assert!(!armed.is_empty(), "at least one cell must assert Table-4 ordering");
        for cell in armed {
            let MatrixCell::Diff(d) = cell else { unreachable!() };
            assert_eq!(d.a, PolicyKind::MabDaso);
            assert_eq!(d.b, PolicyKind::ModelCompression);
            assert_eq!(d.scenario, Scenario::Clean);
        }
        // smoke includes differential cells too
        assert!(matrix_cells("smoke", &seeds).iter().any(|c| c.id().contains('~')));
    }
}
