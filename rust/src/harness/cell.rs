//! Compact, canonically-serialized result of one matrix cell.
//!
//! A [`CellSummary`] holds only *deterministic* quantities — everything in
//! it is a pure function of the cell coordinates, never of wall-clock or
//! scheduling. That is what makes two guarantees checkable byte-for-byte:
//! `--jobs 1` and `--jobs N` runs serialize identically, and a golden
//! recorded yesterday still matches a replay today.

use std::collections::BTreeMap;

use crate::chaos::ChaosOutcome;
use crate::util::json::{JsonError, Value};

use super::scenario::{Cell, DiffCell};

/// Scalar reduction of one cell run. Metric keys are sorted (BTreeMap) and
/// non-finite values serialize as JSON `null`, so serialization is
/// canonical.
#[derive(Clone, Debug, PartialEq)]
pub struct CellSummary {
    /// `policy/scenario/sN` coordinates, e.g. `mab-daso/chaos-heavy/s1`.
    pub cell: String,
    pub policy: String,
    pub scenario: String,
    pub seed: u64,
    pub intervals: usize,
    /// Named scalar metrics (NaN allowed, e.g. accuracy with zero
    /// completions).
    pub metrics: BTreeMap<String, f64>,
    /// Distinct oracle names violated during the run, in detection order.
    pub violated_oracles: Vec<String>,
}

impl CellSummary {
    /// Reduce a chaos run into the cell's scalar summary.
    pub fn from_outcome(cell: &Cell, intervals: usize, out: &ChaosOutcome) -> CellSummary {
        let s = &out.summary;
        let mut metrics = BTreeMap::new();
        metrics.insert("admitted".into(), out.admitted as f64);
        metrics.insert("completed".into(), out.completed as f64);
        metrics.insert("failed".into(), out.failed as f64);
        // traffic-plane counters (exact-gated; offered == admitted and the
        // shed/scale counters are zero when admission/autoscale are off)
        metrics.insert("offered".into(), out.offered as f64);
        metrics.insert("shed_queue".into(), out.shed_queue as f64);
        metrics.insert("shed_deadline".into(), out.shed_deadline as f64);
        metrics.insert("scale_up".into(), out.scale_up as f64);
        metrics.insert("scale_down".into(), out.scale_down as f64);
        metrics.insert("oracle_violations".into(), out.violations.len() as f64);
        metrics.insert("response_mean".into(), s.response.0);
        metrics.insert("response_ema".into(), out.response_ema);
        metrics.insert("wait_mean".into(), s.wait.0);
        metrics.insert("sla_violation_rate".into(), s.sla_violations);
        metrics.insert("accuracy".into(), s.accuracy);
        metrics.insert("avg_reward".into(), s.avg_reward);
        metrics.insert("energy_mwh".into(), s.energy_mwh);
        // energy plane (ISSUE-10): total watt-hours and mean normalized
        // AEC, golden-gated in every cell — offline workers draw 0 W
        metrics.insert("energy_wh".into(), out.energy_wh);
        metrics.insert("aec_mean".into(), out.mean_aec);
        CellSummary {
            cell: cell.id(),
            policy: super::scenario::policy_slug(cell.policy).to_string(),
            scenario: cell.scenario.name().to_string(),
            seed: cell.seed,
            intervals,
            metrics,
            violated_oracles: out
                .violated_oracles()
                .into_iter()
                .map(str::to_string)
                .collect(),
        }
    }

    /// Reduce a differential pair run into one summary: each side's
    /// headline metrics plus the policy-pair deltas (a − b) as first-class
    /// gated quantities. `ordering_ok` is 1 unless the cell's Table-4
    /// ordering assertion was armed and violated (see
    /// [`DiffCell::expect_a_reward_ge_b`]).
    pub fn from_diff(
        cell: &DiffCell,
        intervals: usize,
        a: &ChaosOutcome,
        b: &ChaosOutcome,
        ordering_ok: bool,
    ) -> CellSummary {
        let mut metrics = BTreeMap::new();
        let mut side = |tag: &str, out: &ChaosOutcome| {
            let s = &out.summary;
            metrics.insert(format!("{tag}_admitted"), out.admitted as f64);
            metrics.insert(format!("{tag}_completed"), out.completed as f64);
            metrics.insert(format!("{tag}_failed"), out.failed as f64);
            metrics.insert(format!("{tag}_response_ema"), out.response_ema);
            metrics.insert(format!("{tag}_sla_violation_rate"), s.sla_violations);
            metrics.insert(format!("{tag}_accuracy"), s.accuracy);
            metrics.insert(format!("{tag}_avg_reward"), s.avg_reward);
            metrics.insert(format!("{tag}_energy_wh"), out.energy_wh);
            metrics.insert(format!("{tag}_aec_mean"), out.mean_aec);
        };
        side("a", a);
        side("b", b);
        // deltas: NaN when either side has no completions (serializes null)
        metrics.insert(
            "delta_avg_reward".into(),
            a.summary.avg_reward - b.summary.avg_reward,
        );
        metrics.insert("delta_response_ema".into(), a.response_ema - b.response_ema);
        metrics.insert(
            "delta_sla_violation_rate".into(),
            a.summary.sla_violations - b.summary.sla_violations,
        );
        metrics.insert("delta_accuracy".into(), a.summary.accuracy - b.summary.accuracy);
        metrics.insert("delta_completed".into(), a.completed as f64 - b.completed as f64);
        // the energyfit~mc pair gates on these: the energy-aware placer
        // should push both deltas negative without the reward delta caving
        metrics.insert("delta_energy_wh".into(), a.energy_wh - b.energy_wh);
        metrics.insert("delta_aec_mean".into(), a.mean_aec - b.mean_aec);
        metrics.insert(
            "oracle_violations".into(),
            (a.violations.len() + b.violations.len()) as f64,
        );
        metrics.insert("ordering_ok".into(), if ordering_ok { 1.0 } else { 0.0 });
        let mut violated: Vec<String> =
            a.violated_oracles().into_iter().map(|o| format!("a:{o}")).collect();
        violated.extend(b.violated_oracles().into_iter().map(|o| format!("b:{o}")));
        CellSummary {
            cell: cell.id(),
            policy: cell.policy_pair(),
            scenario: cell.scenario.name().to_string(),
            seed: cell.seed,
            intervals,
            metrics,
            violated_oracles: violated,
        }
    }

    pub fn to_json(&self) -> Value {
        let metrics = Value::Obj(
            self.metrics
                .iter()
                .map(|(k, v)| {
                    (k.clone(), if v.is_finite() { Value::Num(*v) } else { Value::Null })
                })
                .collect(),
        );
        Value::obj(vec![
            ("cell", Value::Str(self.cell.clone())),
            ("policy", Value::Str(self.policy.clone())),
            ("scenario", Value::Str(self.scenario.clone())),
            // string, not number: seeds above 2^53 would corrupt as f64
            ("seed", Value::Str(self.seed.to_string())),
            ("intervals", Value::Num(self.intervals as f64)),
            ("metrics", metrics),
            (
                "violated_oracles",
                Value::Arr(
                    self.violated_oracles.iter().map(|s| Value::Str(s.clone())).collect(),
                ),
            ),
        ])
    }

    pub fn from_json(v: &Value) -> Result<CellSummary, JsonError> {
        let mut metrics = BTreeMap::new();
        for (k, mv) in v.req("metrics")?.as_obj()? {
            let x = match mv {
                Value::Null => f64::NAN,
                other => other.as_f64()?,
            };
            metrics.insert(k.clone(), x);
        }
        let seed = match v.req("seed")? {
            Value::Str(s) => s.parse().map_err(|_| JsonError::Type("u64 seed"))?,
            other => other.as_f64()? as u64,
        };
        Ok(CellSummary {
            cell: v.req("cell")?.as_str()?.to_string(),
            policy: v.req("policy")?.as_str()?.to_string(),
            scenario: v.req("scenario")?.as_str()?.to_string(),
            seed,
            intervals: v.req("intervals")?.as_usize()?,
            metrics,
            violated_oracles: v
                .req("violated_oracles")?
                .as_arr()?
                .iter()
                .map(|s| s.as_str().map(str::to_string))
                .collect::<Result<Vec<_>, _>>()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn summary() -> CellSummary {
        let mut metrics = BTreeMap::new();
        metrics.insert("accuracy".to_string(), f64::NAN);
        metrics.insert("response_mean".to_string(), 4.25);
        metrics.insert("completed".to_string(), 17.0);
        CellSummary {
            cell: "mc/clean/s1".into(),
            policy: "mc".into(),
            scenario: "clean".into(),
            seed: 1,
            intervals: 12,
            metrics,
            violated_oracles: vec!["task-conservation".into()],
        }
    }

    #[test]
    fn json_roundtrip_preserves_nan_as_null() {
        let s = summary();
        let text = s.to_json().to_string();
        assert!(text.contains("\"accuracy\":null"), "{text}");
        let back = CellSummary::from_json(&json::parse(&text).unwrap()).unwrap();
        assert!(back.metrics["accuracy"].is_nan());
        assert_eq!(back.metrics["response_mean"], 4.25);
        assert_eq!(back.cell, s.cell);
        assert_eq!(back.violated_oracles, s.violated_oracles);
    }

    #[test]
    fn serialization_is_canonical() {
        let s = summary();
        // repeated serialization and a roundtrip both yield the same bytes
        let a = s.to_json().to_string();
        let b = s.to_json().to_string();
        assert_eq!(a, b);
        let back = CellSummary::from_json(&json::parse(&a).unwrap()).unwrap();
        assert_eq!(back.to_json().to_string(), a, "roundtrip must be byte-stable");
        // metric keys come out sorted regardless of insertion order
        let pos = |k: &str| a.find(k).unwrap();
        assert!(pos("accuracy") < pos("completed"));
        assert!(pos("completed") < pos("response_mean"));
    }

    #[test]
    fn huge_seed_survives_json() {
        let mut s = summary();
        s.seed = (1u64 << 53) + 1;
        let back =
            CellSummary::from_json(&json::parse(&s.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back.seed, s.seed);
    }
}
