//! Parallel fleet runner for the scenario matrix.
//!
//! Cells are pulled off a shared atomic cursor by `jobs` worker threads.
//! Every cell builds its own broker, engine and RNG streams from its
//! coordinates alone (see [`super::scenario`]), so *which thread runs a
//! cell, and in what order, cannot change its result* — `--jobs 1` and
//! `--jobs N` produce byte-identical [`CellSummary`] JSON. Wall-clock is
//! measured per cell and reported, but kept out of the summary precisely
//! so that guarantee stays checkable.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::chaos::{self, ChaosOptions, FaultPlan, Violation};
use crate::config::ExperimentConfig;

use super::cell::CellSummary;
use super::golden::{GoldenStatus, GoldenStore};
use super::scenario::Cell;

/// Matrix execution knobs.
#[derive(Clone, Debug)]
pub struct MatrixOptions {
    /// Worker threads (≥1). Results are independent of this.
    pub jobs: usize,
    /// Scheduling intervals per cell.
    pub intervals: usize,
    /// Stop scheduling new cells after the first failing one.
    pub fail_fast: bool,
    /// Record goldens instead of gating against them.
    pub update_goldens: bool,
    /// Golden store; None disables gating entirely.
    pub goldens: Option<GoldenStore>,
    /// Chaos knobs threaded into every cell (bug injection, starvation
    /// guard) — `--inject-bug` works through the matrix too, which is how
    /// the golden/bug-base machinery itself gets exercised.
    pub chaos: ChaosOptions,
}

impl Default for MatrixOptions {
    fn default() -> Self {
        MatrixOptions {
            jobs: 1,
            intervals: 12,
            fail_fast: false,
            update_goldens: false,
            goldens: None,
            chaos: ChaosOptions::default(),
        }
    }
}

/// Everything one executed cell produced.
#[derive(Clone, Debug)]
pub struct CellResult {
    pub cell: Cell,
    pub summary: CellSummary,
    /// Full violation details (the summary only keeps oracle names).
    pub violations: Vec<Violation>,
    /// The exact config/plan the cell ran — kept so a violating cell can
    /// be ddmin-shrunk and persisted without re-deriving anything.
    pub cfg: ExperimentConfig,
    pub plan: FaultPlan,
    pub golden: GoldenStatus,
    /// Broker/engine construction failure, if any (summary metrics are
    /// empty in that case).
    pub error: Option<String>,
    /// Wall-clock of this cell's execution, milliseconds. Reported, never
    /// serialized into the summary.
    pub wall_ms: f64,
}

impl CellResult {
    pub fn failed(&self) -> bool {
        self.error.is_some() || !self.violations.is_empty() || self.golden.is_failure()
    }
}

/// Outcome of one matrix run.
#[derive(Debug)]
pub struct MatrixReport {
    /// Executed cells, in enumeration order (independent of jobs); under
    /// `fail_fast` unscheduled cells are simply absent.
    pub results: Vec<CellResult>,
    /// Cells skipped by fail-fast.
    pub skipped: usize,
    /// Whole-matrix wall-clock, milliseconds.
    pub wall_ms: f64,
}

impl MatrixReport {
    pub fn failed(&self) -> bool {
        self.results.iter().any(CellResult::failed)
    }

    /// Canonical JSON of all cell summaries, in enumeration order. This is
    /// the byte string the serial-vs-parallel equivalence contract is
    /// stated over.
    pub fn summaries_json(&self) -> crate::util::json::Value {
        crate::util::json::Value::Arr(
            self.results.iter().map(|r| r.summary.to_json()).collect(),
        )
    }
}

/// Execute one cell, including its golden gate.
fn run_cell(cell: &Cell, opts: &MatrixOptions) -> CellResult {
    let (cfg, plan) = cell.scenario.build(cell.policy, cell.seed, opts.intervals);
    let t0 = Instant::now();
    let (summary, violations, error) =
        match chaos::run_chaos(&cfg, &plan, &opts.chaos, None) {
            Ok(out) => {
                (CellSummary::from_outcome(cell, opts.intervals, &out), out.violations, None)
            }
            Err(e) => {
                let empty = CellSummary {
                    cell: cell.id(),
                    policy: super::scenario::policy_slug(cell.policy).to_string(),
                    scenario: cell.scenario.name().to_string(),
                    seed: cell.seed,
                    intervals: opts.intervals,
                    metrics: Default::default(),
                    violated_oracles: Vec::new(),
                };
                (empty, Vec::new(), Some(format!("{e:#}")))
            }
        };
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    // Goldens capture healthy behavior only: a violating cell already
    // fails the run, and recording (or comparing) its skewed summary
    // would bake the violation into the committed baseline.
    let golden = match (&opts.goldens, &error) {
        (Some(store), None) if violations.is_empty() => {
            store.gate(&cell.file_stem(), &summary, opts.update_goldens)
        }
        _ => GoldenStatus::Skipped,
    };
    CellResult { cell: *cell, summary, violations, cfg, plan, golden, error, wall_ms }
}

/// Run every cell across `opts.jobs` worker threads.
pub fn run_matrix(cells: &[Cell], opts: &MatrixOptions) -> MatrixReport {
    let t0 = Instant::now();
    let next = AtomicUsize::new(0);
    let stop = AtomicBool::new(false);
    let slots: Vec<Mutex<Option<CellResult>>> =
        cells.iter().map(|_| Mutex::new(None)).collect();
    let jobs = opts.jobs.max(1).min(cells.len().max(1));

    std::thread::scope(|s| {
        for _ in 0..jobs {
            s.spawn(|| loop {
                if stop.load(Ordering::SeqCst) {
                    break;
                }
                let i = next.fetch_add(1, Ordering::SeqCst);
                if i >= cells.len() {
                    break;
                }
                let result = run_cell(&cells[i], opts);
                if opts.fail_fast && result.failed() {
                    stop.store(true, Ordering::SeqCst);
                }
                *slots[i].lock().unwrap() = Some(result);
            });
        }
    });

    let mut results = Vec::with_capacity(cells.len());
    for slot in slots {
        if let Some(r) = slot.into_inner().unwrap() {
            results.push(r);
        }
    }
    let skipped = cells.len() - results.len();
    MatrixReport { results, skipped, wall_ms: t0.elapsed().as_secs_f64() * 1e3 }
}

/// Shrink every violating cell's plan to a minimal counterexample and
/// persist each as a bug-base artifact. Returns the written records.
/// Serial on purpose: shrinking re-runs the scenario up to
/// [`chaos::SHRINK_MAX_RUNS`] times per violation.
pub fn persist_violations(
    report: &MatrixReport,
    opts: &MatrixOptions,
    dir: impl AsRef<std::path::Path>,
) -> Result<Vec<std::path::PathBuf>, String> {
    let mut written = Vec::new();
    for r in &report.results {
        let Some(first) = r.violations.first() else {
            continue;
        };
        let shrunk =
            chaos::shrink_to_minimal(&r.cfg, &r.plan, &opts.chaos, None, first.oracle);
        let note = format!(
            "found by matrix run; first violation: {first}; shrunk {} → {} events in {} re-runs",
            shrunk.original_events,
            shrunk.plan.events.len(),
            shrunk.runs
        );
        // A violation found with a deliberate bug injected guards the
        // oracle's detection power (must keep firing under the bug); one
        // found on the real engine is a real bug that must stay fixed.
        let expect = if opts.chaos.bug.is_some() {
            super::bugbase::Expectation::Violates
        } else {
            super::bugbase::Expectation::Green
        };
        let record = super::bugbase::BugRecord {
            id: format!("{}__{}", r.cell.file_stem(), first.oracle),
            oracle: first.oracle.to_string(),
            expect,
            bug: opts.chaos.bug,
            policy: r.cell.policy,
            scenario: r.cell.scenario,
            seed: r.cell.seed,
            intervals: opts.intervals,
            task_timeout_intervals: opts.chaos.task_timeout_intervals,
            plan: shrunk.plan,
            note,
        };
        let path = super::bugbase::save(dir.as_ref(), &record)?;
        written.push(path);
    }
    Ok(written)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PolicyKind;
    use crate::harness::scenario::Scenario;

    fn slice() -> Vec<Cell> {
        vec![
            Cell { policy: PolicyKind::ModelCompression, scenario: Scenario::Clean, seed: 1 },
            Cell { policy: PolicyKind::ModelCompression, scenario: Scenario::ChaosHeavy, seed: 1 },
            Cell { policy: PolicyKind::Gillis, scenario: Scenario::FlashCrowd, seed: 1 },
        ]
    }

    #[test]
    fn results_come_back_in_enumeration_order() {
        let cells = slice();
        let opts = MatrixOptions { jobs: 3, intervals: 6, ..Default::default() };
        let report = run_matrix(&cells, &opts);
        assert_eq!(report.results.len(), cells.len());
        assert_eq!(report.skipped, 0);
        for (r, c) in report.results.iter().zip(&cells) {
            assert_eq!(r.cell.id(), c.id());
            assert!(r.wall_ms >= 0.0);
            assert_eq!(r.golden, GoldenStatus::Skipped);
            assert!(r.error.is_none(), "{:?}", r.error);
        }
    }

    #[test]
    fn more_jobs_than_cells_is_fine() {
        let cells = vec![Cell {
            policy: PolicyKind::ModelCompression,
            scenario: Scenario::Clean,
            seed: 2,
        }];
        let opts = MatrixOptions { jobs: 16, intervals: 4, ..Default::default() };
        let report = run_matrix(&cells, &opts);
        assert_eq!(report.results.len(), 1);
    }

    #[test]
    fn empty_cell_list_yields_empty_report() {
        let report = run_matrix(&[], &MatrixOptions::default());
        assert!(report.results.is_empty());
        assert!(!report.failed());
    }

    #[test]
    fn injected_bug_marks_the_cell_failed() {
        // pick the first seed whose heavy plan holds a clock-skew episode,
        // so the test is structural rather than a bet on one seed's draw
        let seed = (1u64..50)
            .find(|&s| {
                let (_, plan) = Scenario::ChaosHeavy.build(PolicyKind::ModelCompression, s, 10);
                plan.events.iter().any(|e| {
                    matches!(e.event,
                        crate::chaos::ChaosEvent::ClockSkew { offset_s, .. } if offset_s > 0.0)
                })
            })
            .expect("some heavy plan within 50 seeds has clock skew");
        let cells = vec![Cell {
            policy: PolicyKind::ModelCompression,
            scenario: Scenario::ChaosHeavy,
            seed,
        }];
        let opts = MatrixOptions {
            jobs: 1,
            intervals: 10,
            chaos: ChaosOptions {
                bug: Some(crate::chaos::BugKind::DropClockSkew),
                ..Default::default()
            },
            ..Default::default()
        };
        let report = run_matrix(&cells, &opts);
        assert!(report.failed(), "bug run must fail");
        assert!(report.results[0]
            .summary
            .violated_oracles
            .iter()
            .any(|o| o == "clock-skew-applied"));
    }
}
