//! Parallel fleet runner for the scenario matrix.
//!
//! Cells are pulled off a shared atomic cursor by `jobs` worker threads.
//! Every cell builds its own broker, engine and RNG streams from its
//! coordinates alone (see [`super::scenario`]), so *which thread runs a
//! cell, and in what order, cannot change its result* — `--jobs 1` and
//! `--jobs N` produce byte-identical [`CellSummary`] JSON. Wall-clock is
//! measured per cell and reported, but kept out of the summary precisely
//! so that guarantee stays checkable.
//!
//! Differential cells run both policies against the same scenario
//! coordinates — one fault plan, compiled to one command stream per side —
//! and gate the policy-pair deltas (and the Table-4 reward ordering)
//! exactly like any single cell's metrics.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::chaos::{self, ChaosOptions, FaultPlan, Violation};
use crate::config::ExperimentConfig;

use super::cell::CellSummary;
use super::golden::{GoldenStatus, GoldenStore};
use super::scenario::{DiffCell, MatrixCell, REWARD_SLACK};

/// Matrix execution knobs.
#[derive(Clone, Debug)]
pub struct MatrixOptions {
    /// Worker threads (≥1). Results are independent of this.
    pub jobs: usize,
    /// Scheduling intervals per cell.
    pub intervals: usize,
    /// Intra-interval CPU-phase shards per cell (≥1). Like `jobs`, results
    /// are byte-identical at any value — this is the second, orthogonal
    /// parallelism axis (within a cell rather than across cells).
    pub shards: usize,
    /// Stop scheduling new cells after the first failing one.
    pub fail_fast: bool,
    /// Record goldens instead of gating against them.
    pub update_goldens: bool,
    /// Golden store; None disables gating entirely.
    pub goldens: Option<GoldenStore>,
    /// Chaos knobs threaded into every cell (bug injection, starvation
    /// guard, `--paranoid` scan-vs-index oracle auditing) — `--inject-bug`
    /// works through the matrix too, which is how the golden/bug-base
    /// machinery itself gets exercised, and `--paranoid` re-runs every
    /// indexed oracle's full-scan twin in every cell.
    pub chaos: ChaosOptions,
}

impl Default for MatrixOptions {
    fn default() -> Self {
        MatrixOptions {
            jobs: 1,
            intervals: 12,
            shards: 1,
            fail_fast: false,
            update_goldens: false,
            goldens: None,
            chaos: ChaosOptions::default(),
        }
    }
}

/// Everything one executed cell produced.
#[derive(Clone, Debug)]
pub struct CellResult {
    pub cell: MatrixCell,
    pub summary: CellSummary,
    /// Full violation details (the summary only keeps oracle names). For
    /// differential cells this concatenates both sides, side-tagged in the
    /// detail text.
    pub violations: Vec<Violation>,
    /// The exact config/plan the cell ran — kept so a violating cell can
    /// be ddmin-shrunk and persisted without re-deriving anything. For a
    /// differential cell this is the config of the side that violated
    /// first (side `a` when green).
    pub cfg: ExperimentConfig,
    pub plan: FaultPlan,
    pub golden: GoldenStatus,
    /// Table-4 ordering assertions that failed (differential cells only).
    pub ordering_failures: Vec<String>,
    /// Broker/engine construction failure, if any (summary metrics are
    /// empty in that case).
    pub error: Option<String>,
    /// Wall-clock of this cell's execution, milliseconds. Reported, never
    /// serialized into the summary.
    pub wall_ms: f64,
}

impl CellResult {
    pub fn failed(&self) -> bool {
        self.error.is_some()
            || !self.violations.is_empty()
            || !self.ordering_failures.is_empty()
            || self.golden.is_failure()
    }
}

/// Outcome of one matrix run.
#[derive(Debug)]
pub struct MatrixReport {
    /// Executed cells, in enumeration order (independent of jobs); under
    /// `fail_fast` unscheduled cells are simply absent.
    pub results: Vec<CellResult>,
    /// Cells skipped by fail-fast.
    pub skipped: usize,
    /// Whole-matrix wall-clock, milliseconds.
    pub wall_ms: f64,
}

impl MatrixReport {
    pub fn failed(&self) -> bool {
        self.results.iter().any(CellResult::failed)
    }

    /// Canonical JSON of all cell summaries, in enumeration order. This is
    /// the byte string the serial-vs-parallel equivalence contract is
    /// stated over.
    pub fn summaries_json(&self) -> crate::util::json::Value {
        crate::util::json::Value::Arr(
            self.results.iter().map(|r| r.summary.to_json()).collect(),
        )
    }
}

fn empty_summary(cell: &MatrixCell, opts: &MatrixOptions) -> CellSummary {
    let (policy, scenario) = match cell {
        MatrixCell::Single(c) => {
            (super::scenario::policy_slug(c.policy).to_string(), c.scenario)
        }
        MatrixCell::Diff(d) => (d.policy_pair(), d.scenario),
    };
    CellSummary {
        cell: cell.id(),
        policy,
        scenario: scenario.name().to_string(),
        seed: cell.seed(),
        intervals: opts.intervals,
        metrics: Default::default(),
        violated_oracles: Vec::new(),
    }
}

/// What one differential-pair execution produced (pre-golden-gate).
struct DiffRun {
    summary: CellSummary,
    violations: Vec<Violation>,
    cfg: ExperimentConfig,
    plan: FaultPlan,
    ordering_failures: Vec<String>,
}

/// Run a differential pair: both sides share the scenario's config shape
/// and fault plan, differing only in the policy field — the same entry
/// point `chaos --differential` uses, so matrix diff cells and the CLI
/// measure exactly the same thing.
fn run_diff(d: &DiffCell, opts: &MatrixOptions) -> Result<DiffRun, String> {
    let (mut cfg_a, plan) = d.scenario.build(d.a, d.seed, opts.intervals);
    cfg_a.sim.shards = opts.shards.max(1);
    let (a, b) = chaos::run_differential(&cfg_a, d.b, &plan, &opts.chaos, None)
        .map_err(|e| format!("{e:#}"))?;

    let mut ordering_failures = Vec::new();
    if d.expect_a_reward_ge_b {
        let (ra, rb) = (a.summary.avg_reward, b.summary.avg_reward);
        if ra.is_finite() && rb.is_finite() && ra < rb - REWARD_SLACK {
            ordering_failures.push(format!(
                "Table-4 ordering violated: {} reward {ra:.4} < {} reward {rb:.4} − slack {REWARD_SLACK}",
                super::scenario::policy_slug(d.a),
                super::scenario::policy_slug(d.b),
            ));
        }
    }
    let summary =
        CellSummary::from_diff(d, opts.intervals, &a, &b, ordering_failures.is_empty());

    let tag = |side: &str, v: Violation| Violation {
        oracle: v.oracle,
        interval: v.interval,
        detail: format!("[{side}] {}", v.detail),
    };
    // the shrink/persist config follows the side that violated first
    let cfg = if a.violations.is_empty() && !b.violations.is_empty() {
        let mut cfg_b = cfg_a.clone();
        cfg_b.policy = d.b;
        cfg_b
    } else {
        cfg_a.clone()
    };
    let mut violations: Vec<Violation> =
        a.violations.into_iter().map(|v| tag("a", v)).collect();
    violations.extend(b.violations.into_iter().map(|v| tag("b", v)));
    Ok(DiffRun { summary, violations, cfg, plan, ordering_failures })
}

/// Execute one cell, including its golden gate.
fn run_cell(cell: &MatrixCell, opts: &MatrixOptions) -> CellResult {
    let t0 = Instant::now();
    let (summary, violations, cfg, plan, ordering_failures, error) = match cell {
        MatrixCell::Single(c) => {
            let (mut cfg, plan) = c.scenario.build(c.policy, c.seed, opts.intervals);
            cfg.sim.shards = opts.shards.max(1);
            match chaos::run_chaos(&cfg, &plan, &opts.chaos, None) {
                Ok(out) => (
                    CellSummary::from_outcome(c, opts.intervals, &out),
                    out.violations,
                    cfg,
                    plan,
                    Vec::new(),
                    None,
                ),
                Err(e) => (
                    empty_summary(cell, opts),
                    Vec::new(),
                    cfg,
                    plan,
                    Vec::new(),
                    Some(format!("{e:#}")),
                ),
            }
        }
        MatrixCell::Diff(d) => match run_diff(d, opts) {
            Ok(run) => (
                run.summary,
                run.violations,
                run.cfg,
                run.plan,
                run.ordering_failures,
                None,
            ),
            Err(e) => {
                let (cfg, plan) = d.scenario.build(d.a, d.seed, opts.intervals);
                (empty_summary(cell, opts), Vec::new(), cfg, plan, Vec::new(), Some(e))
            }
        },
    };
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    // Goldens capture healthy behavior only: a violating cell already
    // fails the run, and recording (or comparing) its skewed summary
    // would bake the violation into the committed baseline.
    let golden = match (&opts.goldens, &error) {
        (Some(store), None) if violations.is_empty() && ordering_failures.is_empty() => {
            store.gate(&cell.file_stem(), &summary, opts.update_goldens)
        }
        _ => GoldenStatus::Skipped,
    };
    CellResult {
        cell: *cell,
        summary,
        violations,
        cfg,
        plan,
        golden,
        ordering_failures,
        error,
        wall_ms,
    }
}

/// Run every cell across `opts.jobs` worker threads.
pub fn run_matrix(cells: &[MatrixCell], opts: &MatrixOptions) -> MatrixReport {
    let t0 = Instant::now();
    let next = AtomicUsize::new(0);
    let stop = AtomicBool::new(false);
    let slots: Vec<Mutex<Option<CellResult>>> =
        cells.iter().map(|_| Mutex::new(None)).collect();
    let jobs = opts.jobs.max(1).min(cells.len().max(1));

    std::thread::scope(|s| {
        for _ in 0..jobs {
            s.spawn(|| loop {
                if stop.load(Ordering::SeqCst) {
                    break;
                }
                let i = next.fetch_add(1, Ordering::SeqCst);
                if i >= cells.len() {
                    break;
                }
                let result = run_cell(&cells[i], opts);
                if opts.fail_fast && result.failed() {
                    stop.store(true, Ordering::SeqCst);
                }
                *slots[i].lock().unwrap() = Some(result);
            });
        }
    });

    let mut results = Vec::with_capacity(cells.len());
    for slot in slots {
        if let Some(r) = slot.into_inner().unwrap() {
            results.push(r);
        }
    }
    let skipped = cells.len() - results.len();
    MatrixReport { results, skipped, wall_ms: t0.elapsed().as_secs_f64() * 1e3 }
}

/// Shrink every violating cell's plan to a minimal counterexample and
/// persist each as a bug-base artifact. Returns the written records.
/// Serial on purpose: shrinking re-runs the scenario up to
/// [`chaos::SHRINK_MAX_RUNS`] times per violation.
pub fn persist_violations(
    report: &MatrixReport,
    opts: &MatrixOptions,
    dir: impl AsRef<std::path::Path>,
) -> Result<Vec<std::path::PathBuf>, String> {
    let mut written = Vec::new();
    for r in &report.results {
        let Some(first) = r.violations.first() else {
            continue;
        };
        let shrunk =
            chaos::shrink_to_minimal(&r.cfg, &r.plan, &opts.chaos, None, first.oracle);
        let note = format!(
            "found by matrix run; first violation: {first}; shrunk {} → {} events in {} re-runs",
            shrunk.original_events,
            shrunk.plan.events.len(),
            shrunk.runs
        );
        // A violation found with a deliberate bug injected guards the
        // oracle's detection power (must keep firing under the bug); one
        // found on the real engine is a real bug that must stay fixed.
        let expect = if opts.chaos.bug.is_some() {
            super::bugbase::Expectation::Violates
        } else {
            super::bugbase::Expectation::Green
        };
        let record = super::bugbase::BugRecord {
            id: format!("{}__{}", r.cell.file_stem(), first.oracle),
            oracle: first.oracle.to_string(),
            expect,
            bug: opts.chaos.bug,
            policy: r.cfg.policy,
            scenario: r.cell.scenario(),
            seed: r.cell.seed(),
            intervals: opts.intervals,
            task_timeout_intervals: opts.chaos.task_timeout_intervals,
            plan: shrunk.plan,
            note,
        };
        let path = super::bugbase::save(dir.as_ref(), &record)?;
        written.push(path);
    }
    Ok(written)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PolicyKind;
    use crate::harness::scenario::{Cell, Scenario};

    fn single(policy: PolicyKind, scenario: Scenario, seed: u64) -> MatrixCell {
        MatrixCell::Single(Cell { policy, scenario, seed })
    }

    fn slice() -> Vec<MatrixCell> {
        vec![
            single(PolicyKind::ModelCompression, Scenario::Clean, 1),
            single(PolicyKind::ModelCompression, Scenario::ChaosHeavy, 1),
            single(PolicyKind::Gillis, Scenario::FlashCrowd, 1),
        ]
    }

    #[test]
    fn results_come_back_in_enumeration_order() {
        let cells = slice();
        let opts = MatrixOptions { jobs: 3, intervals: 6, ..Default::default() };
        let report = run_matrix(&cells, &opts);
        assert_eq!(report.results.len(), cells.len());
        assert_eq!(report.skipped, 0);
        for (r, c) in report.results.iter().zip(&cells) {
            assert_eq!(r.cell.id(), c.id());
            assert!(r.wall_ms >= 0.0);
            assert_eq!(r.golden, GoldenStatus::Skipped);
            assert!(r.error.is_none(), "{:?}", r.error);
        }
    }

    #[test]
    fn more_jobs_than_cells_is_fine() {
        let cells = vec![single(PolicyKind::ModelCompression, Scenario::Clean, 2)];
        let opts = MatrixOptions { jobs: 16, intervals: 4, ..Default::default() };
        let report = run_matrix(&cells, &opts);
        assert_eq!(report.results.len(), 1);
    }

    #[test]
    fn empty_cell_list_yields_empty_report() {
        let report = run_matrix(&[], &MatrixOptions::default());
        assert!(report.results.is_empty());
        assert!(!report.failed());
    }

    #[test]
    fn injected_bug_marks_the_cell_failed() {
        // pick the first seed whose heavy plan holds a clock-skew episode,
        // so the test is structural rather than a bet on one seed's draw
        let seed = (1u64..50)
            .find(|&s| {
                let (_, plan) = Scenario::ChaosHeavy.build(PolicyKind::ModelCompression, s, 10);
                plan.events.iter().any(|e| {
                    matches!(e.event,
                        crate::chaos::ChaosEvent::ClockSkew { offset_s, .. } if offset_s > 0.0)
                })
            })
            .expect("some heavy plan within 50 seeds has clock skew");
        let cells = vec![single(PolicyKind::ModelCompression, Scenario::ChaosHeavy, seed)];
        let opts = MatrixOptions {
            jobs: 1,
            intervals: 10,
            chaos: ChaosOptions {
                bug: Some(crate::chaos::BugKind::DropClockSkew),
                ..Default::default()
            },
            ..Default::default()
        };
        let report = run_matrix(&cells, &opts);
        assert!(report.failed(), "bug run must fail");
        assert!(report.results[0]
            .summary
            .violated_oracles
            .iter()
            .any(|o| o == "clock-skew-applied"));
    }

    #[test]
    fn diff_cell_carries_delta_metrics_and_runs_green() {
        let d = crate::harness::scenario::DiffCell {
            a: PolicyKind::MabDaso,
            b: PolicyKind::ModelCompression,
            scenario: Scenario::Clean,
            seed: 1,
            expect_a_reward_ge_b: false,
        };
        let cells = vec![MatrixCell::Diff(d)];
        let opts = MatrixOptions { jobs: 1, intervals: 8, ..Default::default() };
        let report = run_matrix(&cells, &opts);
        let r = &report.results[0];
        assert!(r.error.is_none(), "{:?}", r.error);
        assert!(r.violations.is_empty(), "{:?}", r.violations);
        let m = &r.summary.metrics;
        for key in [
            "a_avg_reward",
            "b_avg_reward",
            "delta_avg_reward",
            "delta_response_ema",
            "delta_accuracy",
            "delta_sla_violation_rate",
            "ordering_ok",
        ] {
            assert!(m.contains_key(key), "missing metric {key}");
        }
        assert_eq!(m["ordering_ok"], 1.0, "unarmed assertion always passes");
        // both sides actually ran: admissions on each
        assert!(m["a_admitted"] > 0.0 && m["b_admitted"] > 0.0);
        // delta is exactly the difference of the sides (or NaN-consistent)
        let (ra, rb, dl) = (m["a_avg_reward"], m["b_avg_reward"], m["delta_avg_reward"]);
        if ra.is_finite() && rb.is_finite() {
            assert!((ra - rb - dl).abs() < 1e-12);
        } else {
            assert!(dl.is_nan());
        }
    }

    /// The tentpole contract at the harness layer: the CPU-phase shard
    /// count, like the job count, never shows up in the summaries. A
    /// chaos-heavy cell keeps the fleet churning so the sharded integrator
    /// sees offline workers, evictions, and ragged resident sets.
    #[test]
    fn matrix_summaries_are_byte_identical_across_shards() {
        let cells = vec![
            single(PolicyKind::ModelCompression, Scenario::ChaosHeavy, 1),
            single(PolicyKind::MabDaso, Scenario::ChaosHeavy, 2),
        ];
        let serial = run_matrix(
            &cells,
            &MatrixOptions { jobs: 1, intervals: 8, shards: 1, ..Default::default() },
        );
        for shards in [2, 5] {
            let sharded = run_matrix(
                &cells,
                &MatrixOptions { jobs: 2, intervals: 8, shards, ..Default::default() },
            );
            assert_eq!(
                serial.summaries_json().to_string(),
                sharded.summaries_json().to_string(),
                "{shards} shards drifted from serial"
            );
        }
    }

    #[test]
    fn diff_cell_is_deterministic_across_jobs() {
        let cells: Vec<MatrixCell> =
            crate::harness::scenario::matrix_cells("~", &[1]).into_iter().take(2).collect();
        assert!(!cells.is_empty());
        let serial =
            run_matrix(&cells, &MatrixOptions { jobs: 1, intervals: 6, ..Default::default() });
        let parallel =
            run_matrix(&cells, &MatrixOptions { jobs: 2, intervals: 6, ..Default::default() });
        assert_eq!(
            serial.summaries_json().to_string(),
            parallel.summaries_json().to_string()
        );
    }

    #[test]
    fn armed_ordering_assertion_fails_when_the_champion_trails() {
        // a~b with a == b would tie; instead invert the armed pair so the
        // "champion" is MC against the full stack — if MC genuinely beats
        // MAB+DASO by more than the slack, the assertion must trip; if not,
        // it must pass. Either way the plumbing is exercised end-to-end by
        // checking consistency between the metric and the failure list.
        let d = crate::harness::scenario::DiffCell {
            a: PolicyKind::ModelCompression,
            b: PolicyKind::MabDaso,
            scenario: Scenario::Clean,
            seed: 1,
            expect_a_reward_ge_b: true,
        };
        let report = run_matrix(
            &[MatrixCell::Diff(d)],
            &MatrixOptions { jobs: 1, intervals: 8, ..Default::default() },
        );
        let r = &report.results[0];
        let ok = r.summary.metrics["ordering_ok"] == 1.0;
        assert_eq!(ok, r.ordering_failures.is_empty());
        assert_eq!(r.failed(), !ok || !r.violations.is_empty());
    }
}
