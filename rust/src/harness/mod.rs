//! Scenario-matrix harness: exhaustive policy × scenario × seed
//! evaluation with parallel execution, golden-trace regression gating and
//! a persisted bug-base.
//!
//! The paper's claims are *comparative* — MAB+DASO beats the baselines on
//! response time, deadline violations and reward across workload regimes
//! (Table 4, Figs. 16–18) — so checking one policy×scenario pair at a
//! time leaves every other regime unwatched. This subsystem turns the
//! whole cross product into one deterministic, machine-checked run:
//!
//! 1. [`scenario`] enumerates [`scenario::MatrixCell`]s — single-policy
//!    cells and differential policy pairs ([`scenario::DiffCell`]: both
//!    sides replay the same fault plan, deltas and the Table-4 reward
//!    ordering gate like any metric) — each a pure function of its
//!    (policy, scenario, seed) coordinates, with RNG streams derived via
//!    [`crate::util::rng::mix`] so no state is shared.
//! 2. [`runner`] executes cells across worker threads; `--jobs 1` and
//!    `--jobs N` produce byte-identical [`cell::CellSummary`] JSON.
//! 3. [`golden`] gates each summary against a committed golden with
//!    per-metric tolerances; drift fails the run.
//! 4. Any oracle violation is ddmin-shrunk ([`crate::chaos::shrink`]) and
//!    persisted by [`bugbase`]; `tests/bugbase_replay.rs` replays every
//!    artifact forever after.
//!
//! CLI: `splitplace matrix --filter smoke --jobs 4 [--update-goldens]
//! [--fail-fast]` (see `main.rs`).

pub mod bugbase;
pub mod cell;
pub mod golden;
pub mod runner;
pub mod scenario;

pub use bugbase::{BugRecord, Expectation};
pub use cell::CellSummary;
pub use golden::{drift, GoldenStatus, GoldenStore, Tolerance};
pub use runner::{persist_violations, run_matrix, CellResult, MatrixOptions, MatrixReport};
pub use scenario::{
    matrix_cells, policy_slug, seed_config, Cell, DiffCell, MatrixCell, Scenario,
    REWARD_SLACK, SMOKE_POLICIES,
};
