//! Container-placement engines.
//!
//! The paper's contribution is [`daso`]: gradient-based optimization of the
//! placement matrix through a decision-aware neural surrogate (GOBI-style,
//! eq. 12), executed via the AOT-compiled gradient HLO. Decision-blind
//! GOBI and classic heuristics (random, round-robin, best-fit) serve as
//! ablations/baselines.

pub mod daso;
pub mod features;
pub mod heuristics;

pub use daso::GradientPlacer;
pub use features::{FeatureLayout, SlotInfo};
pub use heuristics::{
    reference_place_with_bias, BestFitPlacer, EnergyAwarePlacer, RandomPlacer, RoundRobinPlacer,
};

use crate::sim::{ContainerId, WorkerSnapshot};
use crate::util::rng::Rng;
use crate::workload::trace::TraceBuffer;

/// A placement decision: (container, worker) pairs. Containers omitted
/// stay in the wait queue (paper §4.3's relaxation).
pub type Assignment = Vec<(ContainerId, usize)>;

/// Everything a placer sees at the start of an interval.
pub struct PlacementInput<'a> {
    /// Last interval's per-worker utilization (S_t).
    pub snapshots: &'a [WorkerSnapshot],
    /// Placeable containers in slot order.
    pub slots: Vec<SlotInfo>,
    /// Per-worker RAM capacity (MB) and currently-resident demand (MB).
    pub ram_capacity: Vec<f64>,
    pub resident_ram: Vec<f64>,
    /// Allowed RAM overcommit factor (matches the engine's).
    pub overcommit: f64,
}

impl<'a> PlacementInput<'a> {
    pub fn workers(&self) -> usize {
        self.ram_capacity.len()
    }

    /// Greedy feasibility: can `slot` go to `w` given what this placement
    /// round has already committed (`extra` = MB added to w this round)?
    pub fn fits(&self, slot: &SlotInfo, w: usize, extra: f64) -> bool {
        if slot.prev_worker == Some(w) {
            return true; // already resident there
        }
        self.resident_ram[w] + extra + slot.ram_mb <= self.ram_capacity[w] * self.overcommit
    }
}

/// A placement engine: returns (container, worker) assignments. Containers
/// omitted from the result stay in the wait queue.
///
/// Beyond `place`, the trait carries the learning hooks a surrogate-based
/// placer needs from the broker loop (trace recording, online fine-tune,
/// pre-training, telemetry). Heuristic placers keep the default no-ops, so
/// the broker can hold one `Box<dyn Placer>` with no policy-specific
/// enums or downcasts.
pub trait Placer {
    fn place(&mut self, input: &PlacementInput) -> Assignment;
    fn name(&self) -> &'static str;

    /// True for learned placers that need pre-training and fine-tuning.
    fn is_learned(&self) -> bool {
        false
    }

    /// Pair the last placement's realized features with the observed
    /// objective `o_p` (pushed into `trace`), then take `steps` surrogate
    /// updates sampled from `trace` via `rng` (Algorithm 1 line 14).
    fn observe_objective(
        &mut self,
        o_p: f64,
        trace: &mut TraceBuffer,
        steps: usize,
        rng: &mut Rng,
    ) {
        let _ = (o_p, trace, steps, rng);
    }

    /// Featurize a realized cluster state with an empty placement window
    /// (pre-training trace collection). `None` for heuristics.
    fn featurize_idle(&self, snapshots: &[WorkerSnapshot]) -> Option<Vec<f32>> {
        let _ = snapshots;
        None
    }

    /// Fit the surrogate on the collected trace (paper: trained on an
    /// execution trace dataset before deployment). No-op for heuristics.
    fn pretrain(
        &mut self,
        trace: &TraceBuffer,
        steps: usize,
        rng: &mut Rng,
    ) -> anyhow::Result<()> {
        let _ = (trace, steps, rng);
        Ok(())
    }

    /// Gradient telemetry of the last `place` call: (iterations, surrogate
    /// score). `None` for heuristics.
    fn stats(&self) -> Option<(usize, f32)> {
        None
    }

    /// Enable the placer's full-scan twin (the `--paranoid` discipline the
    /// oracle plane uses): indexed placers re-derive every decision with
    /// the retired serial scan and record mismatches instead of trusting
    /// the index. No-op for placers with no index to distrust.
    fn set_paranoid(&mut self, on: bool) {
        let _ = on;
    }

    /// Drain index-vs-scan divergences recorded since the last call (one
    /// human-readable line each). Always empty outside paranoid mode and
    /// on a correct index.
    fn take_paranoid_divergences(&mut self) -> Vec<String> {
        Vec::new()
    }
}
