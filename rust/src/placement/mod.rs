//! Container-placement engines.
//!
//! The paper's contribution is [`daso`]: gradient-based optimization of the
//! placement matrix through a decision-aware neural surrogate (GOBI-style,
//! eq. 12), executed via the AOT-compiled gradient HLO. Decision-blind
//! GOBI and classic heuristics (random, round-robin, best-fit) serve as
//! ablations/baselines.

pub mod daso;
pub mod features;
pub mod heuristics;

pub use daso::GradientPlacer;
pub use features::{FeatureLayout, SlotInfo};
pub use heuristics::{BestFitPlacer, RandomPlacer, RoundRobinPlacer};

use crate::sim::{ContainerId, WorkerSnapshot};

/// Everything a placer sees at the start of an interval.
pub struct PlacementInput<'a> {
    /// Last interval's per-worker utilization (S_t).
    pub snapshots: &'a [WorkerSnapshot],
    /// Placeable containers in slot order.
    pub slots: Vec<SlotInfo>,
    /// Per-worker RAM capacity (MB) and currently-resident demand (MB).
    pub ram_capacity: Vec<f64>,
    pub resident_ram: Vec<f64>,
    /// Allowed RAM overcommit factor (matches the engine's).
    pub overcommit: f64,
}

impl<'a> PlacementInput<'a> {
    pub fn workers(&self) -> usize {
        self.ram_capacity.len()
    }

    /// Greedy feasibility: can `slot` go to `w` given what this placement
    /// round has already committed (`extra` = MB added to w this round)?
    pub fn fits(&self, slot: &SlotInfo, w: usize, extra: f64) -> bool {
        if slot.prev_worker == Some(w) {
            return true; // already resident there
        }
        self.resident_ram[w] + extra + slot.ram_mb <= self.ram_capacity[w] * self.overcommit
    }
}

/// A placement engine: returns (container, worker) assignments. Containers
/// omitted from the result stay in the wait queue.
pub trait Placer {
    fn place(&mut self, input: &PlacementInput) -> Vec<(ContainerId, usize)>;
    fn name(&self) -> &'static str;
}
