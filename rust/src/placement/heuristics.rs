//! Classic placement heuristics: random, round-robin, best-fit. These are
//! the baselines' schedulers and the fallback path when gradient placement
//! leaves a container unassigned.

use super::{Assignment, PlacementInput, Placer, SlotInfo};
use crate::sim::ContainerId;
use crate::util::rng::Rng;

/// Uniform random feasible worker.
pub struct RandomPlacer {
    rng: Rng,
}

impl RandomPlacer {
    pub fn new(seed: u64) -> Self {
        RandomPlacer { rng: Rng::new(seed) }
    }
}

impl Placer for RandomPlacer {
    fn place(&mut self, input: &PlacementInput) -> Vec<(ContainerId, usize)> {
        let n = input.workers();
        let mut extra = vec![0.0f64; n];
        let mut out = Vec::new();
        for slot in &input.slots {
            if slot.prev_worker.is_some() {
                continue; // never migrate randomly
            }
            // up to n probes for a feasible worker
            for _ in 0..n {
                let w = self.rng.below(n as u64) as usize;
                if input.fits(slot, w, extra[w]) {
                    extra[w] += slot.ram_mb;
                    out.push((slot.cid, w));
                    break;
                }
            }
        }
        out
    }

    fn name(&self) -> &'static str {
        "random"
    }
}

/// Cycling round-robin over workers, skipping infeasible ones.
pub struct RoundRobinPlacer {
    next: usize,
}

impl RoundRobinPlacer {
    pub fn new() -> Self {
        RoundRobinPlacer { next: 0 }
    }
}

impl Default for RoundRobinPlacer {
    fn default() -> Self {
        Self::new()
    }
}

impl Placer for RoundRobinPlacer {
    fn place(&mut self, input: &PlacementInput) -> Vec<(ContainerId, usize)> {
        let n = input.workers();
        let mut extra = vec![0.0f64; n];
        let mut out = Vec::new();
        for slot in &input.slots {
            if slot.prev_worker.is_some() {
                continue;
            }
            for probe in 0..n {
                let w = (self.next + probe) % n;
                if input.fits(slot, w, extra[w]) {
                    extra[w] += slot.ram_mb;
                    out.push((slot.cid, w));
                    self.next = (w + 1) % n;
                    break;
                }
            }
        }
        out
    }

    fn name(&self) -> &'static str {
        "round-robin"
    }
}

/// Leftmost-argmax tournament tree over the worker axis — the decision
/// plane's answer to the sim core's O(active) indexes. Each internal node
/// stores, over its leaf range, (a) the maximum of a *conservative upper
/// bound* on RAM headroom and (b) the maximum of the *exact* best-fit
/// score. A query descends left-child-first, pruning subtrees that
/// provably hold no feasible worker (headroom bound below the slot's
/// demand) or no score strictly above the incumbent (exact max — under
/// strict-`>` a tied subtree can never win), and re-checks the exact
/// `PlacementInput::fits` predicate plus the exact score update at every
/// leaf it reaches. Leaves are therefore visited in ascending worker
/// order with the serial scan's own comparisons deciding everything — the
/// winner is bit-identical to the retired left-to-right scan, in
/// O(log W + pruned-fringe) instead of O(W) per slot.
#[derive(Default)]
struct BestFitTree {
    /// Leaf capacity, `workers.next_power_of_two()`; leaf `w` sits at
    /// `base + w`, padding leaves carry −∞ in both keys.
    base: usize,
    workers: usize,
    head: Vec<f64>,
    score: Vec<f64>,
}

impl BestFitTree {
    /// Per-worker keys. `score` is the serial scan's expression verbatim
    /// (same operands, same order — the float is bit-identical). `head`
    /// over-approximates the headroom `fits` compares against: the exact
    /// predicate is `fl(fl(resident+extra)+ram) ≤ fl(cap·overcommit)`,
    /// which is NOT bitwise equivalent to any rearrangement, so the bound
    /// adds a relative-1e-9 margin that dwarfs the ≤3-ulp (≈7e-16
    /// relative) gap between `fl(C−s)` and the largest `ram` the exact
    /// predicate can accept. A pruned subtree thus never hides a feasible
    /// worker; an unpruned infeasible leaf fails the exact check at the
    /// leaf, exactly like the serial scan.
    ///
    /// `bias` is the energy-fit hook: a per-worker score penalty
    /// (marginal watts, see [`EnergyAwarePlacer`]) subtracted AFTER the
    /// unbiased expression. An empty slice skips the subtraction
    /// entirely, so the unbiased placers' floats are untouched — not
    /// merely equal, the same operations.
    fn key(input: &PlacementInput, w: usize, extra_w: f64, bias: &[f64]) -> (f64, f64) {
        let free_ram = (input.ram_capacity[w] - input.resident_ram[w] - extra_w)
            / input.ram_capacity[w].max(1.0);
        let mut score = free_ram - 0.5 * input.snapshots[w].cpu;
        if let Some(b) = bias.get(w) {
            score -= *b;
        }
        let cap = input.ram_capacity[w] * input.overcommit;
        let used = input.resident_ram[w] + extra_w;
        let head = (cap - used) + 1e-9 * (cap.abs() + used.abs()) + 1e-9;
        (head, score)
    }

    /// O(W) rebuild from scratch — once per `place()` call.
    fn rebuild(&mut self, input: &PlacementInput, extra: &[f64], bias: &[f64]) {
        let n = input.workers();
        self.workers = n;
        self.base = n.next_power_of_two().max(1);
        self.head.clear();
        self.head.resize(2 * self.base, f64::NEG_INFINITY);
        self.score.clear();
        self.score.resize(2 * self.base, f64::NEG_INFINITY);
        for w in 0..n {
            let (h, s) = Self::key(input, w, extra[w], bias);
            self.head[self.base + w] = h;
            self.score[self.base + w] = s;
        }
        for i in (1..self.base).rev() {
            self.pull(i);
        }
    }

    fn pull(&mut self, i: usize) {
        self.head[i] = self.head[2 * i].max(self.head[2 * i + 1]);
        self.score[i] = self.score[2 * i].max(self.score[2 * i + 1]);
    }

    /// O(log W) re-key of one worker after its `extra` commitment grows.
    fn update(&mut self, input: &PlacementInput, w: usize, extra_w: f64, bias: &[f64]) {
        let (h, s) = Self::key(input, w, extra_w, bias);
        let mut i = self.base + w;
        self.head[i] = h;
        self.score[i] = s;
        while i > 1 {
            i /= 2;
            self.pull(i);
        }
    }

    /// Leftmost maximum-score feasible worker for `slot` under the
    /// round's committed `extra` — `None` if no worker fits. Only called
    /// for fresh slots (`prev_worker == None`), where `fits` is the pure
    /// headroom predicate the `head` bound over-approximates.
    fn query(
        &self,
        input: &PlacementInput,
        slot: &SlotInfo,
        extra: &[f64],
    ) -> Option<(usize, f64)> {
        let mut best = None;
        self.descend(1, input, slot, extra, &mut best);
        best
    }

    fn descend(
        &self,
        node: usize,
        input: &PlacementInput,
        slot: &SlotInfo,
        extra: &[f64],
        best: &mut Option<(usize, f64)>,
    ) {
        if !(self.head[node] >= slot.ram_mb) {
            return; // provably infeasible everywhere below
        }
        if let Some((_, b)) = *best {
            if !(self.score[node] > b) {
                return; // nothing below beats the strict-> incumbent
            }
        }
        if node >= self.base {
            let w = node - self.base;
            if w < self.workers && input.fits(slot, w, extra[w]) {
                let s = self.score[node];
                if best.map(|(_, b)| s > b).unwrap_or(true) {
                    *best = Some((w, s));
                }
            }
            return;
        }
        self.descend(2 * node, input, slot, extra, best);
        self.descend(2 * node + 1, input, slot, extra, best);
    }
}

/// One slot of the retired serial derivation: left-to-right scan over all
/// workers, exact `fits`, strict-`>` score update, minus the same
/// per-worker `bias` the tree's [`BestFitTree::key`] subtracts (empty
/// slice → the unbiased expression, operation for operation). Shared by
/// the paranoid twins and [`reference_place_with_bias`]; never on the hot
/// path.
fn scan_best(
    input: &PlacementInput,
    slot: &SlotInfo,
    extra: &[f64],
    bias: &[f64],
) -> Option<(usize, f64)> {
    let mut best: Option<(usize, f64)> = None;
    for w in 0..input.workers() {
        if !input.fits(slot, w, extra[w]) {
            continue;
        }
        let free_ram = (input.ram_capacity[w] - input.resident_ram[w] - extra[w])
            / input.ram_capacity[w].max(1.0);
        let mut score = free_ram - 0.5 * input.snapshots[w].cpu;
        if let Some(b) = bias.get(w) {
            score -= *b;
        }
        if best.map(|(_, s)| score > s).unwrap_or(true) {
            best = Some((w, score));
        }
    }
    best
}

/// The whole retired derivation (decreasing sort + per-slot full scan),
/// kept as the reference the assignment-identity properties pin the tree
/// against — for [`BestFitPlacer`] with an empty `bias`, for
/// [`EnergyAwarePlacer`] with its watt bias.
pub fn reference_place_with_bias(input: &PlacementInput, bias: &[f64]) -> Assignment {
    let mut extra = vec![0.0f64; input.workers()];
    let mut order: Vec<usize> = (0..input.slots.len()).collect();
    order.sort_by(|&a, &b| input.slots[b].ram_mb.total_cmp(&input.slots[a].ram_mb));
    let mut out = Vec::new();
    for i in order {
        let slot = &input.slots[i];
        if slot.prev_worker.is_some() {
            continue;
        }
        if let Some((w, _)) = scan_best(input, slot, &extra, bias) {
            extra[w] += slot.ram_mb;
            out.push((slot.cid, w));
        }
    }
    out
}

/// Shared best-fit-decreasing engine behind [`BestFitPlacer`] (empty
/// `bias`) and [`EnergyAwarePlacer`] (per-worker watt bias): decreasing
/// RAM sort, per-slot tree query, paranoid full-scan cross-check. The
/// bias enters *only* through the score expression in
/// [`BestFitTree::key`] / [`scan_best`], and an empty slice skips the
/// subtraction entirely, so the unbiased placer's floats and winners are
/// byte-identical to the pre-bias code.
#[allow(clippy::too_many_arguments)]
fn place_decreasing(
    tree: &mut BestFitTree,
    extra: &mut Vec<f64>,
    order: &mut Vec<usize>,
    input: &PlacementInput,
    bias: &[f64],
    paranoid: bool,
    divergences: &mut Vec<String>,
) -> Assignment {
    let n = input.workers();
    extra.clear();
    extra.resize(n, 0.0);
    order.clear();
    order.extend(0..input.slots.len());
    // decreasing by RAM; total_cmp orders every non-NaN float exactly
    // like the old partial_cmp().unwrap() did, without the panic path
    order.sort_by(|&a, &b| input.slots[b].ram_mb.total_cmp(&input.slots[a].ram_mb));
    tree.rebuild(input, extra, bias);
    let mut out = Vec::new();
    for &i in order.iter() {
        let slot = &input.slots[i];
        if slot.prev_worker.is_some() {
            continue;
        }
        let best = tree.query(input, slot, extra);
        if paranoid {
            let full = scan_best(input, slot, extra, bias);
            let bits = |r: Option<(usize, f64)>| r.map(|(w, s)| (w, s.to_bits()));
            if bits(full) != bits(best) {
                divergences.push(format!(
                    "slot cid={} ram={}MB: full scan chose {:?}, tree chose {:?}",
                    slot.cid, slot.ram_mb, full, best
                ));
            }
        }
        if let Some((w, _)) = best {
            extra[w] += slot.ram_mb;
            tree.update(input, w, extra[w], bias);
            out.push((slot.cid, w));
        }
    }
    out
}

/// Best-fit-decreasing: biggest containers first, each to the feasible
/// worker with the most free RAM and lowest CPU (weighted score). This is
/// the scheduler the Gillis/MC baselines use. Since the index migration
/// the per-slot winner comes from a [`BestFitTree`] query (O(log W)
/// amortized) instead of a full-fleet scan; the retired scan survives as
/// [`scan_best`], re-run per slot under paranoid mode and compared
/// bit-for-bit.
pub struct BestFitPlacer {
    tree: BestFitTree,
    extra: Vec<f64>,
    order: Vec<usize>,
    paranoid: bool,
    divergences: Vec<String>,
}

impl BestFitPlacer {
    pub fn new() -> Self {
        BestFitPlacer {
            tree: BestFitTree::default(),
            extra: Vec::new(),
            order: Vec::new(),
            paranoid: false,
            divergences: Vec::new(),
        }
    }

    /// Unbiased reference derivation — see [`reference_place_with_bias`].
    pub fn reference_place(input: &PlacementInput) -> Assignment {
        reference_place_with_bias(input, &[])
    }
}

impl Default for BestFitPlacer {
    fn default() -> Self {
        Self::new()
    }
}

impl Placer for BestFitPlacer {
    fn place(&mut self, input: &PlacementInput) -> Vec<(ContainerId, usize)> {
        let mut extra = std::mem::take(&mut self.extra);
        let mut order = std::mem::take(&mut self.order);
        let out = place_decreasing(
            &mut self.tree,
            &mut extra,
            &mut order,
            input,
            &[],
            self.paranoid,
            &mut self.divergences,
        );
        self.extra = extra;
        self.order = order;
        out
    }

    fn name(&self) -> &'static str {
        "best-fit"
    }

    fn set_paranoid(&mut self, on: bool) {
        self.paranoid = on;
    }

    fn take_paranoid_divergences(&mut self) -> Vec<String> {
        std::mem::take(&mut self.divergences)
    }
}

/// How hard energy-fit leans against watts. The unbiased score lives in
/// roughly [−0.5, 1] (normalized free RAM minus half the CPU load), and
/// the bias is the worker's marginal watts normalized to [0, 1], so 0.35
/// lets a clearly-emptier worker still win while breaking near-ties
/// toward the cheaper machine — paper §6.3's energy term weighting.
const ENERGY_WEIGHT: f64 = 0.35;

/// Energy-aware best-fit ("energy-fit"): the [`BestFitPlacer`] derivation
/// with each worker's score docked by its normalized marginal power draw
/// (peak − idle watts), so among comparably-loaded feasible workers the
/// one whose next unit of utilization costs the fewest watts wins.
/// Feasibility is untouched — the bias only reorders winners, it never
/// admits a worker `fits` rejects. Runs on the same [`BestFitTree`] index
/// with the same paranoid full-scan twin (both sides biased identically).
pub struct EnergyAwarePlacer {
    tree: BestFitTree,
    extra: Vec<f64>,
    order: Vec<usize>,
    /// `ENERGY_WEIGHT · marginal_watts[w] / max(marginal_watts)` — fixed
    /// at construction from the fleet's specs; empty fleet → empty bias.
    watt_bias: Vec<f64>,
    paranoid: bool,
    divergences: Vec<String>,
}

impl EnergyAwarePlacer {
    /// `marginal_watts[w]` = peak − idle draw of worker `w`'s node type.
    pub fn new(marginal_watts: &[f64]) -> Self {
        let max = marginal_watts.iter().copied().fold(0.0f64, f64::max);
        let watt_bias = if max > 0.0 {
            marginal_watts.iter().map(|&m| ENERGY_WEIGHT * m / max).collect()
        } else {
            vec![0.0; marginal_watts.len()]
        };
        EnergyAwarePlacer {
            tree: BestFitTree::default(),
            extra: Vec::new(),
            order: Vec::new(),
            watt_bias,
            paranoid: false,
            divergences: Vec::new(),
        }
    }

    /// The biased reference derivation this placer's tree is pinned
    /// against — see [`reference_place_with_bias`].
    pub fn reference_place(&self, input: &PlacementInput) -> Assignment {
        reference_place_with_bias(input, &self.watt_bias)
    }
}

impl Placer for EnergyAwarePlacer {
    fn place(&mut self, input: &PlacementInput) -> Vec<(ContainerId, usize)> {
        debug_assert!(
            self.watt_bias.len() >= input.workers(),
            "EnergyAwarePlacer built for {} workers, placing over {}",
            self.watt_bias.len(),
            input.workers()
        );
        let mut extra = std::mem::take(&mut self.extra);
        let mut order = std::mem::take(&mut self.order);
        let out = place_decreasing(
            &mut self.tree,
            &mut extra,
            &mut order,
            input,
            &self.watt_bias,
            self.paranoid,
            &mut self.divergences,
        );
        self.extra = extra;
        self.order = order;
        out
    }

    fn name(&self) -> &'static str {
        "energy-fit"
    }

    fn set_paranoid(&mut self, on: bool) {
        self.paranoid = on;
    }

    fn take_paranoid_divergences(&mut self) -> Vec<String> {
        std::mem::take(&mut self.divergences)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::features::SlotInfo;
    use crate::sim::WorkerSnapshot;
    use crate::splits::SplitDecision;

    fn slot(cid: usize, ram: f64) -> SlotInfo {
        SlotInfo {
            cid,
            prev_worker: None,
            decision: SplitDecision::Layer,
            mi_remaining: 1e6,
            ram_mb: ram,
            input_mb: 10.0,
            remaining_frac: 1.0,
        }
    }

    fn input(slots: Vec<SlotInfo>, caps: Vec<f64>, resident: Vec<f64>) -> PlacementInput<'static> {
        // leak snapshots for the 'static test lifetime; fine in tests
        let snaps: &'static [WorkerSnapshot] = Box::leak(
            vec![
                WorkerSnapshot { cpu: 0.1, ram: 0.1, net: 0.0, disk: 0.0, containers: 0 };
                caps.len()
            ]
            .into_boxed_slice(),
        );
        PlacementInput {
            snapshots: snaps,
            slots,
            ram_capacity: caps,
            resident_ram: resident,
            overcommit: 2.0,
        }
    }

    #[test]
    fn random_respects_capacity() {
        let mut p = RandomPlacer::new(1);
        // one tiny worker, one big: the 5000 MB container only fits on w1
        let inp = input(vec![slot(0, 5000.0)], vec![1000.0, 8000.0], vec![0.0, 0.0]);
        for _ in 0..20 {
            let a = p.place(&inp);
            for &(_, w) in &a {
                assert_eq!(w, 1);
            }
        }
    }

    #[test]
    fn round_robin_spreads() {
        let mut p = RoundRobinPlacer::new();
        let inp = input(
            (0..4).map(|i| slot(i, 100.0)).collect(),
            vec![8000.0; 4],
            vec![0.0; 4],
        );
        let a = p.place(&inp);
        assert_eq!(a.len(), 4);
        let mut ws: Vec<usize> = a.iter().map(|&(_, w)| w).collect();
        ws.sort_unstable();
        assert_eq!(ws, vec![0, 1, 2, 3]);
    }

    #[test]
    fn best_fit_prefers_free_ram() {
        let mut p = BestFitPlacer::new();
        let inp = input(
            vec![slot(0, 1000.0)],
            vec![8000.0, 8000.0],
            vec![7000.0, 0.0],
        );
        let a = p.place(&inp);
        assert_eq!(a, vec![(0, 1)]);
    }

    #[test]
    fn best_fit_packs_decreasing() {
        let mut p = BestFitPlacer::new();
        // two big (6000) and two small (100); caps allow one big each
        let inp = input(
            vec![slot(0, 100.0), slot(1, 6000.0), slot(2, 6000.0), slot(3, 100.0)],
            vec![4000.0, 4000.0],
            vec![0.0, 0.0],
        );
        let a = p.place(&inp);
        // bigs fit under 2x overcommit (8000), one per worker
        let big_ws: Vec<usize> = a
            .iter()
            .filter(|&&(c, _)| c == 1 || c == 2)
            .map(|&(_, w)| w)
            .collect();
        assert_eq!(big_ws.len(), 2);
        assert_ne!(big_ws[0], big_ws[1], "bigs must not stack on one worker");
    }

    #[test]
    fn running_containers_not_reassigned_by_heuristics() {
        let mut s = slot(0, 100.0);
        s.prev_worker = Some(3);
        let inp = input(vec![s], vec![8000.0; 4], vec![0.0; 4]);
        assert!(RandomPlacer::new(2).place(&inp).is_empty());
        assert!(RoundRobinPlacer::new().place(&inp).is_empty());
        assert!(BestFitPlacer::new().place(&inp).is_empty());
    }

    #[test]
    fn oversized_container_left_queued() {
        let inp = input(vec![slot(0, 50_000.0)], vec![8000.0; 2], vec![0.0; 2]);
        assert!(BestFitPlacer::new().place(&inp).is_empty());
        assert!(RandomPlacer::new(3).place(&inp).is_empty());
    }

    #[test]
    fn tree_matches_reference_on_tie_and_edge_cases() {
        // equal-score workers: leftmost must win (serial strict-> keeps
        // the first maximum it sees)
        let tie = input(vec![slot(0, 100.0)], vec![4000.0; 4], vec![0.0; 4]);
        assert_eq!(BestFitPlacer::new().place(&tie), BestFitPlacer::reference_place(&tie));
        assert_eq!(BestFitPlacer::new().place(&tie), vec![(0, 0)]);

        // infeasible everywhere
        let none = input(vec![slot(0, 50_000.0)], vec![4000.0; 3], vec![0.0; 3]);
        assert_eq!(BestFitPlacer::new().place(&none), BestFitPlacer::reference_place(&none));
        assert!(BestFitPlacer::new().place(&none).is_empty());

        // exact overcommit boundary: demand == cap·overcommit − resident,
        // feasible on <= semantics, and only on worker 1
        let edge = input(
            vec![slot(0, 7000.0)],
            vec![4000.0, 4000.0],
            vec![2000.0, 1000.0],
        );
        assert_eq!(BestFitPlacer::new().place(&edge), BestFitPlacer::reference_place(&edge));
        assert_eq!(BestFitPlacer::new().place(&edge), vec![(0, 1)]);

        // single-worker fleet (degenerate tree base)
        let one = input(vec![slot(0, 10.0), slot(1, 20.0)], vec![4000.0], vec![0.0]);
        assert_eq!(BestFitPlacer::new().place(&one), BestFitPlacer::reference_place(&one));

        // multi-slot packing where earlier commitments shift later winners
        let pack = input(
            (0..6).map(|i| slot(i, 2500.0 + 10.0 * i as f64)).collect(),
            vec![4000.0, 4100.0, 3900.0],
            vec![100.0, 0.0, 50.0],
        );
        assert_eq!(BestFitPlacer::new().place(&pack), BestFitPlacer::reference_place(&pack));
    }

    #[test]
    fn paranoid_best_fit_records_no_divergence() {
        let mut p = BestFitPlacer::new();
        p.set_paranoid(true);
        let inp = input(
            (0..8).map(|i| slot(i, 500.0 * (1 + i % 4) as f64)).collect(),
            vec![4000.0, 2000.0, 6000.0, 1000.0],
            vec![500.0, 0.0, 3000.0, 900.0],
        );
        let a = p.place(&inp);
        assert_eq!(a, BestFitPlacer::reference_place(&inp));
        assert!(p.take_paranoid_divergences().is_empty());
        assert!(p.take_paranoid_divergences().is_empty(), "drain is one-shot");
    }

    #[test]
    fn energy_fit_with_zero_marginal_watts_matches_best_fit() {
        // all-zero marginal watts → all-zero bias → `score -= 0.0`, which
        // is bit-identical to the unbiased expression: every winner must
        // match BestFitPlacer exactly
        let inp = input(
            (0..8).map(|i| slot(i, 500.0 * (1 + i % 4) as f64)).collect(),
            vec![4000.0, 2000.0, 6000.0, 1000.0],
            vec![500.0, 0.0, 3000.0, 900.0],
        );
        let mut e = EnergyAwarePlacer::new(&[0.0; 4]);
        assert_eq!(e.place(&inp), BestFitPlacer::new().place(&inp));
    }

    #[test]
    fn energy_fit_breaks_ties_toward_the_cheaper_worker() {
        // two identical workers: unbiased best-fit ties and keeps the
        // leftmost; energy-fit docks worker 0's hungrier marginal draw and
        // sends the slot to worker 1
        let inp = input(vec![slot(0, 1000.0)], vec![8000.0; 2], vec![0.0; 2]);
        assert_eq!(BestFitPlacer::new().place(&inp), vec![(0, 0)]);
        let mut e = EnergyAwarePlacer::new(&[80.0, 30.0]);
        assert_eq!(e.place(&inp), vec![(0, 1)], "watt bias must break the tie");
    }

    #[test]
    fn energy_fit_never_overrides_feasibility() {
        // the cheap worker (w1) can't hold the slot — bias must not admit
        // it, the expensive-but-feasible worker wins
        let inp = input(vec![slot(0, 5000.0)], vec![8000.0, 1000.0], vec![0.0, 0.0]);
        let mut e = EnergyAwarePlacer::new(&[100.0, 1.0]);
        assert_eq!(e.place(&inp), vec![(0, 0)]);
        // nothing fits anywhere → empty, same as best-fit
        let none = input(vec![slot(0, 50_000.0)], vec![8000.0; 2], vec![0.0; 2]);
        let mut e = EnergyAwarePlacer::new(&[100.0, 1.0]);
        assert!(e.place(&none).is_empty());
    }

    #[test]
    fn paranoid_energy_fit_tree_matches_biased_reference() {
        // multi-slot pack over a mixed fleet: the biased tree must agree
        // with the biased serial scan bit-for-bit, and with the biased
        // reference derivation assignment-for-assignment
        let inp = input(
            (0..10).map(|i| slot(i, 400.0 * (1 + i % 5) as f64)).collect(),
            vec![4000.0, 4000.0, 6000.0, 2000.0, 4000.0],
            vec![200.0, 0.0, 1500.0, 100.0, 0.0],
        );
        let watts = [46.0, 44.0, 68.0, 66.0, 46.0];
        let mut e = EnergyAwarePlacer::new(&watts);
        e.set_paranoid(true);
        let a = e.place(&inp);
        assert_eq!(a, e.reference_place(&inp));
        assert!(e.take_paranoid_divergences().is_empty());
        // and the bias genuinely changes *something* vs plain best-fit on
        // this fleet, so the test isn't vacuous
        assert_ne!(a, BestFitPlacer::reference_place(&inp));
    }
}
