//! Classic placement heuristics: random, round-robin, best-fit. These are
//! the baselines' schedulers and the fallback path when gradient placement
//! leaves a container unassigned.

use super::{PlacementInput, Placer};
use crate::sim::ContainerId;
use crate::util::rng::Rng;

/// Uniform random feasible worker.
pub struct RandomPlacer {
    rng: Rng,
}

impl RandomPlacer {
    pub fn new(seed: u64) -> Self {
        RandomPlacer { rng: Rng::new(seed) }
    }
}

impl Placer for RandomPlacer {
    fn place(&mut self, input: &PlacementInput) -> Vec<(ContainerId, usize)> {
        let n = input.workers();
        let mut extra = vec![0.0f64; n];
        let mut out = Vec::new();
        for slot in &input.slots {
            if slot.prev_worker.is_some() {
                continue; // never migrate randomly
            }
            // up to n probes for a feasible worker
            for _ in 0..n {
                let w = self.rng.below(n as u64) as usize;
                if input.fits(slot, w, extra[w]) {
                    extra[w] += slot.ram_mb;
                    out.push((slot.cid, w));
                    break;
                }
            }
        }
        out
    }

    fn name(&self) -> &'static str {
        "random"
    }
}

/// Cycling round-robin over workers, skipping infeasible ones.
pub struct RoundRobinPlacer {
    next: usize,
}

impl RoundRobinPlacer {
    pub fn new() -> Self {
        RoundRobinPlacer { next: 0 }
    }
}

impl Default for RoundRobinPlacer {
    fn default() -> Self {
        Self::new()
    }
}

impl Placer for RoundRobinPlacer {
    fn place(&mut self, input: &PlacementInput) -> Vec<(ContainerId, usize)> {
        let n = input.workers();
        let mut extra = vec![0.0f64; n];
        let mut out = Vec::new();
        for slot in &input.slots {
            if slot.prev_worker.is_some() {
                continue;
            }
            for probe in 0..n {
                let w = (self.next + probe) % n;
                if input.fits(slot, w, extra[w]) {
                    extra[w] += slot.ram_mb;
                    out.push((slot.cid, w));
                    self.next = (w + 1) % n;
                    break;
                }
            }
        }
        out
    }

    fn name(&self) -> &'static str {
        "round-robin"
    }
}

/// Best-fit-decreasing: biggest containers first, each to the feasible
/// worker with the most free RAM and lowest CPU (weighted score). This is
/// the scheduler the Gillis/MC baselines use.
pub struct BestFitPlacer;

impl Placer for BestFitPlacer {
    fn place(&mut self, input: &PlacementInput) -> Vec<(ContainerId, usize)> {
        let n = input.workers();
        let mut extra = vec![0.0f64; n];
        let mut order: Vec<usize> = (0..input.slots.len()).collect();
        order.sort_by(|&a, &b| {
            input.slots[b]
                .ram_mb
                .partial_cmp(&input.slots[a].ram_mb)
                .unwrap()
        });
        let mut out = Vec::new();
        for i in order {
            let slot = &input.slots[i];
            if slot.prev_worker.is_some() {
                continue;
            }
            let mut best: Option<(usize, f64)> = None;
            for w in 0..n {
                if !input.fits(slot, w, extra[w]) {
                    continue;
                }
                let free_ram = (input.ram_capacity[w] - input.resident_ram[w] - extra[w])
                    / input.ram_capacity[w].max(1.0);
                let score = free_ram - 0.5 * input.snapshots[w].cpu;
                if best.map(|(_, s)| score > s).unwrap_or(true) {
                    best = Some((w, score));
                }
            }
            if let Some((w, _)) = best {
                extra[w] += slot.ram_mb;
                out.push((slot.cid, w));
            }
        }
        out
    }

    fn name(&self) -> &'static str {
        "best-fit"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::features::SlotInfo;
    use crate::sim::WorkerSnapshot;
    use crate::splits::SplitDecision;

    fn slot(cid: usize, ram: f64) -> SlotInfo {
        SlotInfo {
            cid,
            prev_worker: None,
            decision: SplitDecision::Layer,
            mi_remaining: 1e6,
            ram_mb: ram,
            input_mb: 10.0,
            remaining_frac: 1.0,
        }
    }

    fn input(slots: Vec<SlotInfo>, caps: Vec<f64>, resident: Vec<f64>) -> PlacementInput<'static> {
        // leak snapshots for the 'static test lifetime; fine in tests
        let snaps: &'static [WorkerSnapshot] = Box::leak(
            vec![
                WorkerSnapshot { cpu: 0.1, ram: 0.1, net: 0.0, disk: 0.0, containers: 0 };
                caps.len()
            ]
            .into_boxed_slice(),
        );
        PlacementInput {
            snapshots: snaps,
            slots,
            ram_capacity: caps,
            resident_ram: resident,
            overcommit: 2.0,
        }
    }

    #[test]
    fn random_respects_capacity() {
        let mut p = RandomPlacer::new(1);
        // one tiny worker, one big: the 5000 MB container only fits on w1
        let inp = input(vec![slot(0, 5000.0)], vec![1000.0, 8000.0], vec![0.0, 0.0]);
        for _ in 0..20 {
            let a = p.place(&inp);
            for &(_, w) in &a {
                assert_eq!(w, 1);
            }
        }
    }

    #[test]
    fn round_robin_spreads() {
        let mut p = RoundRobinPlacer::new();
        let inp = input(
            (0..4).map(|i| slot(i, 100.0)).collect(),
            vec![8000.0; 4],
            vec![0.0; 4],
        );
        let a = p.place(&inp);
        assert_eq!(a.len(), 4);
        let mut ws: Vec<usize> = a.iter().map(|&(_, w)| w).collect();
        ws.sort_unstable();
        assert_eq!(ws, vec![0, 1, 2, 3]);
    }

    #[test]
    fn best_fit_prefers_free_ram() {
        let mut p = BestFitPlacer;
        let inp = input(
            vec![slot(0, 1000.0)],
            vec![8000.0, 8000.0],
            vec![7000.0, 0.0],
        );
        let a = p.place(&inp);
        assert_eq!(a, vec![(0, 1)]);
    }

    #[test]
    fn best_fit_packs_decreasing() {
        let mut p = BestFitPlacer;
        // two big (6000) and two small (100); caps allow one big each
        let inp = input(
            vec![slot(0, 100.0), slot(1, 6000.0), slot(2, 6000.0), slot(3, 100.0)],
            vec![4000.0, 4000.0],
            vec![0.0, 0.0],
        );
        let a = p.place(&inp);
        // bigs fit under 2x overcommit (8000), one per worker
        let big_ws: Vec<usize> = a
            .iter()
            .filter(|&&(c, _)| c == 1 || c == 2)
            .map(|&(_, w)| w)
            .collect();
        assert_eq!(big_ws.len(), 2);
        assert_ne!(big_ws[0], big_ws[1], "bigs must not stack on one worker");
    }

    #[test]
    fn running_containers_not_reassigned_by_heuristics() {
        let mut s = slot(0, 100.0);
        s.prev_worker = Some(3);
        let inp = input(vec![s], vec![8000.0; 4], vec![0.0; 4]);
        assert!(RandomPlacer::new(2).place(&inp).is_empty());
        assert!(RoundRobinPlacer::new().place(&inp).is_empty());
        assert!(BestFitPlacer.place(&inp).is_empty());
    }

    #[test]
    fn oversized_container_left_queued() {
        let inp = input(vec![slot(0, 50_000.0)], vec![8000.0; 2], vec![0.0; 2]);
        assert!(BestFitPlacer.place(&inp).is_empty());
        assert!(RandomPlacer::new(3).place(&inp).is_empty());
    }
}
