//! DASO: decision-aware surrogate optimization (paper §4.2).
//!
//! Starting from the previous placement, iterate eq. 12
//! `P ← P + η ∇_P f([S, P, D]; θ)` through the AOT-compiled gradient HLO,
//! then project the continuous matrix onto a feasible discrete assignment.
//! With `decision_aware = false` the D block is zeroed and this becomes the
//! vanilla-GOBI ablation (M+G / L+G / S+G rows of Table 4).

use super::features::{FeatureLayout, SlotInfo};
use super::heuristics::BestFitPlacer;
use super::{Assignment, PlacementInput, Placer};
use crate::config::PlacementConfig;
use crate::runtime::Surrogate;
use crate::sim::WorkerSnapshot;
use crate::util::rng::Rng;
use crate::workload::trace::{TraceBuffer, TraceSample};

/// Minimum advantage of the new worker's P-mass over the current one
/// before a running container is migrated (hysteresis against churn).
const MIGRATION_MARGIN: f32 = 0.2;

pub struct GradientPlacer<'rt> {
    pub surrogate: Surrogate<'rt>,
    pub layout: FeatureLayout,
    cfg: PlacementConfig,
    pub decision_aware: bool,
    fallback: BestFitPlacer,
    /// Telemetry: gradient iterations and surrogate score of the last call.
    pub last_iters: usize,
    pub last_score: f32,
    /// Feature vector of the final (chosen) placement — the coordinator
    /// pairs it with the observed objective to fine-tune the surrogate.
    pub last_features: Vec<f32>,
}

impl<'rt> GradientPlacer<'rt> {
    pub fn new(surrogate: Surrogate<'rt>, cfg: PlacementConfig, decision_aware: bool) -> Self {
        let layout = FeatureLayout::new(surrogate.workers(), surrogate.slots());
        GradientPlacer {
            surrogate,
            layout,
            cfg,
            decision_aware,
            fallback: BestFitPlacer::new(),
            last_iters: 0,
            last_score: 0.0,
            last_features: Vec::new(),
        }
    }

    /// Continuous init: previous worker one-hot, uniform for new slots.
    fn init_placement(&self, slots: &[SlotInfo]) -> Vec<f32> {
        let h = self.layout.workers;
        let mut p = vec![0.0f32; self.layout.placement_dim()];
        for (m, slot) in slots.iter().enumerate() {
            match slot.prev_worker {
                Some(w) if w < h => p[m * h + w] = 1.0,
                _ => {
                    let u = 1.0 / h as f32;
                    for w in 0..h {
                        p[m * h + w] = u;
                    }
                }
            }
        }
        p
    }

    /// Project each slot row to the simplex-ish box: clamp ≥ 0, renorm.
    fn project(&self, p: &mut [f32], n_slots: usize) {
        let h = self.layout.workers;
        for m in 0..n_slots {
            let row = &mut p[m * h..(m + 1) * h];
            let mut sum = 0.0f32;
            for v in row.iter_mut() {
                *v = v.max(0.0);
                sum += *v;
            }
            if sum > 1e-9 {
                for v in row.iter_mut() {
                    *v /= sum;
                }
            } else {
                let u = 1.0 / h as f32;
                row.iter_mut().for_each(|v| *v = u);
            }
        }
    }
}

impl<'rt> Placer for GradientPlacer<'rt> {
    fn place(&mut self, input: &PlacementInput) -> Assignment {
        let h = self.layout.workers;
        let m_cap = self.layout.slots;
        assert_eq!(input.workers(), h, "cluster/surrogate worker mismatch");

        // Slot window: running containers first (their position matters
        // most), then queued by arrival; overflow goes to the fallback.
        let mut ordered: Vec<&SlotInfo> = input.slots.iter().collect();
        ordered.sort_by_key(|s| (s.prev_worker.is_none() as u8, s.cid));
        let (window, overflow): (Vec<&SlotInfo>, Vec<&SlotInfo>) = if ordered.len() > m_cap {
            let (a, b) = ordered.split_at(m_cap);
            (a.to_vec(), b.to_vec())
        } else {
            (ordered, Vec::new())
        };
        let win_slots: Vec<SlotInfo> = window.iter().map(|s| (*s).clone()).collect();

        // --- eq. 12 gradient loop on the continuous P ---
        let mut p = self.init_placement(&win_slots);
        let eta = self.cfg.eta as f32;
        self.last_iters = 0;
        for _ in 0..self.cfg.max_iters {
            let x = self
                .layout
                .featurize(input.snapshots, &win_slots, &p, self.decision_aware);
            let Ok((score, dx)) = self.surrogate.grad(&x) else { break };
            self.last_score = score;
            let off = self.layout.placement_off();
            let mut delta2 = 0.0f32;
            for i in 0..p.len() {
                let step = eta * dx[off + i];
                p[i] += step;
                delta2 += step * step;
            }
            self.project(&mut p, win_slots.len());
            self.last_iters += 1;
            if (delta2.sqrt() as f64) < self.cfg.converge_eps {
                break;
            }
        }

        // --- discretize with feasibility + migration hysteresis ---
        let mut extra = vec![0.0f64; h];
        let mut out = Vec::new();
        let mut final_assign: Vec<Option<usize>> = vec![None; win_slots.len()];
        for (m, slot) in win_slots.iter().enumerate() {
            let row = &p[m * h..(m + 1) * h];
            // workers by descending mass
            let mut order: Vec<usize> = (0..h).collect();
            order.sort_by(|&a, &b| row[b].partial_cmp(&row[a]).unwrap());
            match slot.prev_worker {
                Some(prev) => {
                    let best = order[0];
                    if best != prev
                        && row[best] - row[prev] > MIGRATION_MARGIN
                        && input.fits(slot, best, extra[best])
                    {
                        extra[best] += slot.ram_mb;
                        out.push((slot.cid, best));
                        final_assign[m] = Some(best);
                    } else {
                        final_assign[m] = Some(prev);
                    }
                }
                None => {
                    for &w in &order {
                        if input.fits(slot, w, extra[w]) {
                            extra[w] += slot.ram_mb;
                            out.push((slot.cid, w));
                            final_assign[m] = Some(w);
                            break;
                        }
                    }
                    // none feasible -> stays queued (paper's wait queue)
                }
            }
        }

        // record features of the realized placement for fine-tuning
        let p_final = self.layout.one_hot(&final_assign);
        self.last_features =
            self.layout
                .featurize(input.snapshots, &win_slots, &p_final, self.decision_aware);

        // overflow containers: best-fit
        if !overflow.is_empty() {
            let fb_input = PlacementInput {
                snapshots: input.snapshots,
                slots: overflow.into_iter().cloned().collect(),
                ram_capacity: input.ram_capacity.clone(),
                resident_ram: input
                    .resident_ram
                    .iter()
                    .zip(&extra)
                    .map(|(a, b)| a + b)
                    .collect(),
                overcommit: input.overcommit,
            };
            out.extend(self.fallback.place(&fb_input));
        }
        out
    }

    fn name(&self) -> &'static str {
        if self.decision_aware {
            "daso"
        } else {
            "gobi"
        }
    }

    fn is_learned(&self) -> bool {
        true
    }

    fn observe_objective(
        &mut self,
        o_p: f64,
        trace: &mut TraceBuffer,
        steps: usize,
        rng: &mut Rng,
    ) {
        if !self.last_features.is_empty() {
            trace.push(TraceSample {
                features: self.last_features.clone(),
                objective: o_p as f32,
            });
        }
        for _ in 0..steps {
            if let Some((xb, yb)) = trace
                .minibatch(self.surrogate.spec.train_batch, |n| rng.below(n as u64) as usize)
            {
                let _ = self.surrogate.train_step(&xb, &yb);
            }
        }
    }

    fn featurize_idle(&self, snapshots: &[WorkerSnapshot]) -> Option<Vec<f32>> {
        let slots: Vec<SlotInfo> = Vec::new();
        let p = vec![0.0f32; self.layout.placement_dim()];
        Some(self.layout.featurize(snapshots, &slots, &p, self.decision_aware))
    }

    fn pretrain(
        &mut self,
        trace: &TraceBuffer,
        steps: usize,
        rng: &mut Rng,
    ) -> anyhow::Result<()> {
        self.surrogate.pretrain(trace, steps, rng)?;
        Ok(())
    }

    fn stats(&self) -> Option<(usize, f32)> {
        Some((self.last_iters, self.last_score))
    }

    /// The gradient placer itself has no index to distrust; the paranoid
    /// twin covers its best-fit overflow fallback.
    fn set_paranoid(&mut self, on: bool) {
        self.fallback.set_paranoid(on);
    }

    fn take_paranoid_divergences(&mut self) -> Vec<String> {
        self.fallback.take_paranoid_divergences()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PlacementConfig;
    use crate::runtime::Runtime;
    use crate::sim::WorkerSnapshot;
    use crate::splits::SplitDecision;
    use std::path::PathBuf;

    fn runtime() -> Option<Runtime> {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !d.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(Runtime::load(d.to_str().unwrap()).unwrap())
    }

    fn slots(n: usize) -> Vec<SlotInfo> {
        (0..n)
            .map(|i| SlotInfo {
                cid: i,
                prev_worker: None,
                decision: if i % 2 == 0 { SplitDecision::Layer } else { SplitDecision::Semantic },
                mi_remaining: 1e6,
                ram_mb: 600.0,
                input_mb: 50.0,
                remaining_frac: 1.0,
            })
            .collect()
    }

    fn snaps(n: usize) -> Vec<WorkerSnapshot> {
        (0..n)
            .map(|i| WorkerSnapshot {
                cpu: (i as f64) / n as f64,
                ram: 0.2,
                net: 0.0,
                disk: 0.0,
                containers: 0,
            })
            .collect()
    }

    #[test]
    fn places_all_feasible_slots() {
        let Some(rt) = runtime() else { return };
        let s = Surrogate::for_workers(&rt, 10).unwrap();
        let mut placer = GradientPlacer::new(s, PlacementConfig::default(), true);
        let sn = snaps(10);
        let input = PlacementInput {
            snapshots: &sn,
            slots: slots(6),
            ram_capacity: vec![4000.0; 10],
            resident_ram: vec![0.0; 10],
            overcommit: 2.0,
        };
        let a = placer.place(&input);
        assert_eq!(a.len(), 6, "all queued slots must be placed");
        assert!(placer.last_iters >= 1);
        assert_eq!(placer.last_features.len(), placer.layout.feature_dim());
        let ws: std::collections::HashSet<usize> = a.iter().map(|&(_, w)| w).collect();
        assert!(!ws.is_empty());
        for &(_, w) in &a {
            assert!(w < 10);
        }
    }

    #[test]
    fn respects_capacity() {
        let Some(rt) = runtime() else { return };
        let s = Surrogate::for_workers(&rt, 10).unwrap();
        let mut placer = GradientPlacer::new(s, PlacementConfig::default(), true);
        let sn = snaps(10);
        // only worker 7 can take a 5 GB container (others are full)
        let mut resident = vec![7900.0; 10];
        resident[7] = 0.0;
        let mut sl = slots(1);
        sl[0].ram_mb = 5000.0;
        let input = PlacementInput {
            snapshots: &sn,
            slots: sl,
            ram_capacity: vec![4000.0; 10],
            resident_ram: resident,
            overcommit: 2.0,
        };
        let a = placer.place(&input);
        assert_eq!(a, vec![(0, 7)]);
    }

    #[test]
    fn running_containers_keep_place_without_strong_signal() {
        let Some(rt) = runtime() else { return };
        let s = Surrogate::for_workers(&rt, 10).unwrap();
        let mut placer = GradientPlacer::new(s, PlacementConfig::default(), true);
        let sn = snaps(10);
        let mut sl = slots(3);
        for (i, s) in sl.iter_mut().enumerate() {
            s.prev_worker = Some(i);
            s.remaining_frac = 0.5;
        }
        let input = PlacementInput {
            snapshots: &sn,
            slots: sl,
            ram_capacity: vec![4000.0; 10],
            resident_ram: vec![600.0; 3]
                .into_iter()
                .chain(vec![0.0; 7])
                .collect(),
            overcommit: 2.0,
        };
        let a = placer.place(&input);
        // an untrained surrogate shouldn't exceed the migration margin often
        assert!(a.len() <= 1, "spurious migrations: {a:?}");
    }

    #[test]
    fn overflow_goes_to_fallback() {
        let Some(rt) = runtime() else { return };
        let s = Surrogate::for_workers(&rt, 10).unwrap();
        let cap = s.slots();
        let mut placer = GradientPlacer::new(s, PlacementConfig::default(), true);
        let sn = snaps(10);
        let input = PlacementInput {
            snapshots: &sn,
            slots: slots(cap + 4),
            ram_capacity: vec![8000.0; 10],
            resident_ram: vec![0.0; 10],
            overcommit: 2.0,
        };
        let a = placer.place(&input);
        assert_eq!(a.len(), cap + 4, "overflow slots must still be placed");
    }

    #[test]
    fn gobi_variant_reports_name() {
        let Some(rt) = runtime() else { return };
        let s = Surrogate::for_workers(&rt, 10).unwrap();
        let placer = GradientPlacer::new(s, PlacementConfig::default(), false);
        assert_eq!(placer.name(), "gobi");
    }
}
