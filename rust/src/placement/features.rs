//! State featurization: the [S_t | P_t | D_t | demands] layout shared with
//! `python/compile/model.py` (they MUST stay in sync — the surrogate HLO
//! is compiled against this exact layout).
//!
//! ```text
//! [ 0 .. H*4 )        per-worker: cpu, ram, net, disk utilization
//! [ H*4 .. +M*H )     placement matrix P, slot-major
//! [ +M*H .. +M*2 )    decision one-hot per slot [layer, semantic]
//! [ +M*2 .. +M*4 )    per-slot demands: cpu, ram, net, remaining
//! ```

use crate::sim::{ContainerId, WorkerSnapshot};
use crate::splits::SplitDecision;

/// Per-slot (container) view the featurizer consumes.
#[derive(Clone, Debug)]
pub struct SlotInfo {
    pub cid: ContainerId,
    pub prev_worker: Option<usize>,
    pub decision: SplitDecision,
    /// Remaining compute, million instructions.
    pub mi_remaining: f64,
    pub ram_mb: f64,
    /// Pending input payload (MB).
    pub input_mb: f64,
    /// Remaining fraction of the container's total work.
    pub remaining_frac: f64,
}

/// Dimension bookkeeping for a surrogate variant.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FeatureLayout {
    pub workers: usize,
    pub slots: usize,
}

impl FeatureLayout {
    pub fn new(workers: usize, slots: usize) -> Self {
        FeatureLayout { workers, slots }
    }

    pub fn state_dim(&self) -> usize {
        self.workers * 4
    }

    pub fn placement_off(&self) -> usize {
        self.state_dim()
    }

    pub fn placement_dim(&self) -> usize {
        self.slots * self.workers
    }

    pub fn decision_off(&self) -> usize {
        self.placement_off() + self.placement_dim()
    }

    pub fn demand_off(&self) -> usize {
        self.decision_off() + self.slots * 2
    }

    pub fn feature_dim(&self) -> usize {
        self.demand_off() + self.slots * 4
    }

    /// Assemble the full feature vector.
    ///
    /// `placement` is the continuous P matrix, slot-major, length M×H.
    /// `decision_aware=false` zeroes the D block (the GOBI ablation).
    pub fn featurize(
        &self,
        snapshots: &[WorkerSnapshot],
        slots: &[SlotInfo],
        placement: &[f32],
        decision_aware: bool,
    ) -> Vec<f32> {
        assert_eq!(snapshots.len(), self.workers, "snapshot count");
        assert_eq!(placement.len(), self.placement_dim(), "placement dim");
        assert!(slots.len() <= self.slots, "too many slots");
        let mut x = vec![0.0f32; self.feature_dim()];

        for (w, s) in snapshots.iter().enumerate() {
            x[w * 4] = s.cpu.clamp(0.0, 1.0) as f32;
            x[w * 4 + 1] = s.ram.clamp(0.0, 2.0) as f32;
            x[w * 4 + 2] = s.net.clamp(0.0, 1.0) as f32;
            x[w * 4 + 3] = s.disk.clamp(0.0, 1.0) as f32;
        }

        x[self.placement_off()..self.placement_off() + self.placement_dim()]
            .copy_from_slice(placement);

        for (m, slot) in slots.iter().enumerate() {
            if decision_aware {
                match slot.decision {
                    SplitDecision::Layer | SplitDecision::Full => {
                        x[self.decision_off() + m * 2] = 1.0
                    }
                    SplitDecision::Semantic => x[self.decision_off() + m * 2 + 1] = 1.0,
                    SplitDecision::Compressed => {
                        // compression sits between the two regimes
                        x[self.decision_off() + m * 2] = 0.5;
                        x[self.decision_off() + m * 2 + 1] = 0.5;
                    }
                }
            }
            let d = self.demand_off() + m * 4;
            // normalizations: ~4 node-intervals of the largest node
            x[d] = (slot.mi_remaining / 1.0e7).clamp(0.0, 1.0) as f32;
            x[d + 1] = (slot.ram_mb / 8000.0).clamp(0.0, 1.0) as f32;
            x[d + 2] = (slot.input_mb / 1000.0).clamp(0.0, 1.0) as f32;
            x[d + 3] = slot.remaining_frac.clamp(0.0, 1.0) as f32;
        }
        x
    }

    /// One-hot placement vector from an assignment (None → all-zero row).
    pub fn one_hot(&self, assignment: &[Option<usize>]) -> Vec<f32> {
        let mut p = vec![0.0f32; self.placement_dim()];
        for (m, w) in assignment.iter().enumerate().take(self.slots) {
            if let Some(w) = w {
                p[m * self.workers + w] = 1.0;
            }
        }
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(cpu: f64) -> WorkerSnapshot {
        WorkerSnapshot { cpu, ram: 0.5, net: 0.1, disk: 0.1, containers: 1 }
    }

    fn slot(cid: usize, d: SplitDecision) -> SlotInfo {
        SlotInfo {
            cid,
            prev_worker: None,
            decision: d,
            mi_remaining: 1.2e6,
            ram_mb: 4000.0,
            input_mb: 500.0,
            remaining_frac: 1.0,
        }
    }

    #[test]
    fn layout_matches_python() {
        // python test asserts h10_m16 -> 296; mirror it here
        let l = FeatureLayout::new(10, 16);
        assert_eq!(l.feature_dim(), 296);
        let big = FeatureLayout::new(50, 64);
        assert_eq!(big.feature_dim(), 50 * 4 + 64 * 50 + 64 * 2 + 64 * 4);
    }

    #[test]
    fn featurize_blocks() {
        let l = FeatureLayout::new(2, 2);
        let snaps = vec![snap(1.0), snap(0.0)];
        let slots = vec![slot(0, SplitDecision::Layer), slot(1, SplitDecision::Semantic)];
        let p = l.one_hot(&[Some(1), None]);
        let x = l.featurize(&snaps, &slots, &p, true);
        assert_eq!(x.len(), l.feature_dim());
        // S block
        assert_eq!(x[0], 1.0);
        assert_eq!(x[4], 0.0);
        // P block: slot 0 on worker 1
        assert_eq!(x[l.placement_off() + 1], 1.0);
        assert_eq!(x[l.placement_off()], 0.0);
        // D block: slot0 layer, slot1 semantic
        assert_eq!(x[l.decision_off()], 1.0);
        assert_eq!(x[l.decision_off() + 1], 0.0);
        assert_eq!(x[l.decision_off() + 3], 1.0);
        // demands normalized into [0,1]
        let d = l.demand_off();
        assert!((x[d] - 0.12).abs() < 1e-6); // 1.2e6 MI / 1e7
        assert!((x[d + 1] - 0.5).abs() < 1e-6);
        assert!((x[d + 2] - 0.5).abs() < 1e-6);
        assert_eq!(x[d + 3], 1.0);
    }

    #[test]
    fn decision_blind_zeroes_d_block() {
        let l = FeatureLayout::new(2, 2);
        let snaps = vec![snap(0.2), snap(0.3)];
        let slots = vec![slot(0, SplitDecision::Layer)];
        let p = l.one_hot(&[Some(0)]);
        let x = l.featurize(&snaps, &slots, &p, false);
        for i in l.decision_off()..l.demand_off() {
            assert_eq!(x[i], 0.0);
        }
    }

    #[test]
    fn fewer_slots_than_capacity_padded_with_zeros() {
        let l = FeatureLayout::new(3, 4);
        let snaps = vec![snap(0.1); 3];
        let slots = vec![slot(0, SplitDecision::Layer)];
        let p = l.one_hot(&[Some(2)]);
        let x = l.featurize(&snaps, &slots, &p, true);
        // slot 3's demand block must be zero
        let d = l.demand_off() + 3 * 4;
        assert!(x[d..d + 4].iter().all(|&v| v == 0.0));
    }

    #[test]
    #[should_panic(expected = "too many slots")]
    fn overflow_slots_rejected() {
        let l = FeatureLayout::new(2, 1);
        let snaps = vec![snap(0.0); 2];
        let slots = vec![slot(0, SplitDecision::Layer), slot(1, SplitDecision::Layer)];
        let p = vec![0.0; l.placement_dim()];
        l.featurize(&snaps, &slots, &p, true);
    }
}
