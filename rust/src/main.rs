//! SplitPlace CLI — leader entrypoint.
//!
//! Subcommands:
//!   run [--policy P] [--intervals N] [--lambda L] [--workers small|full]
//!       [--alpha A] [--constraint c] [--accuracy measured|manifest]
//!       [--shards N]               shard the CPU phase across N threads
//!                                  (byte-identical results at any N)
//!   compare [--intervals N]        all 10 policies, Table-4 style
//!   chaos [--seed S] [--intervals N] [--profile light|heavy] [--policy P]
//!         [--differential P2] [--plan FILE] [--inject-bug KIND]
//!         [--task-timeout K] [--paranoid]
//!                                  deterministic fault injection + oracles
//!                                  (--paranoid re-runs every indexed
//!                                  oracle's full-scan twin each interval
//!                                  and flags any divergence)
//!   matrix [--filter smoke|full|SUBSTR] [--jobs N] [--seeds K]
//!          [--intervals N] [--update-goldens] [--fail-fast] [--list]
//!          [--goldens DIR] [--bugbase DIR] [--inject-bug KIND]
//!          [--shards N] [--paranoid]
//!                                  policy × scenario × seed cross product
//!                                  plus differential policy-pair cells
//!                                  (ids like mab-daso~mc/clean/s1; filter
//!                                  with '~'), parallel cells, golden
//!                                  gating, Table-4 ordering gate, bug-base
//!   bench [--tier small|medium|large|huge|hyperscale|all] [--intervals N]
//!         [--seed S] [--scenario clean|chaos-light] [--policy P]
//!         [--shards N] [--out FILE]
//!         [--gate BASELINE]        engine throughput per fleet tier
//!                                  (10/200/1000/5000/25 000 workers) under any policy
//!                                  stack (default mc isolates the engine
//!                                  hot path), written to BENCH_engine.json
//!                                  — the perf trajectory; --gate compares
//!                                  against the committed baseline (exact
//!                                  counters, banded rates) before
//!                                  overwriting it
//!   trace record [--out FILE] [--shape flat|diurnal|mmpp|heavy-tail]
//!         [--intervals N] [--lambda L] [--seed S]
//!   trace replay --trace FILE [--policy P] [--intervals N]
//!                                  record a traffic-model arrival stream
//!                                  to JSON / replay a recorded stream
//!                                  verbatim through the broker
//!   serve [--addr A] [--threads N] serving front-end
//!   info                           artifact + cluster inventory
//!
//! (Hand-rolled arg parsing: clap is not in the offline crate set.)

use anyhow::{bail, Result};

use splitplace::chaos::{self, BugKind, ChaosOptions, ChaosOutcome, FaultPlan, Profile};
use splitplace::config::{
    AccuracyMode, ClusterConfig, EnvConstraint, ExperimentConfig, PolicyKind,
};
use splitplace::coordinator::runner::{artifacts_dir, run_experiment, try_runtime};
use splitplace::harness::{self, GoldenStatus, GoldenStore, MatrixOptions};
use splitplace::util::table::{fnum, fpm, Table};

fn parse_flags(args: &[String]) -> std::collections::HashMap<String, String> {
    let mut map = std::collections::HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                map.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                map.insert(key.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            i += 1;
        }
    }
    map
}

fn build_config(flags: &std::collections::HashMap<String, String>) -> Result<ExperimentConfig> {
    let mut cfg = ExperimentConfig::default();
    if let Some(p) = flags.get("policy") {
        cfg.policy = PolicyKind::parse(p)
            .ok_or_else(|| anyhow::anyhow!("unknown policy '{p}'"))?;
    }
    if let Some(n) = flags.get("intervals") {
        cfg.sim.intervals = n.parse()?;
    }
    if let Some(l) = flags.get("lambda") {
        cfg.workload.lambda = l.parse()?;
    }
    if let Some(a) = flags.get("alpha") {
        cfg.placement.alpha = a.parse()?;
    }
    if let Some(w) = flags.get("workers") {
        cfg.cluster = match w.as_str() {
            "small" => ClusterConfig::small(),
            "full" => ClusterConfig::default(),
            other => bail!("--workers must be small|full, got {other}"),
        };
    }
    if let Some(c) = flags.get("constraint") {
        cfg.cluster.constraint = match c.as_str() {
            "compute" => EnvConstraint::Compute,
            "network" => EnvConstraint::Network,
            "memory" => EnvConstraint::Memory,
            "none" => EnvConstraint::None,
            other => bail!("unknown constraint {other}"),
        };
    }
    if let Some(a) = flags.get("accuracy") {
        cfg.accuracy = match a.as_str() {
            "measured" => AccuracyMode::Measured,
            _ => AccuracyMode::Manifest,
        };
    }
    if let Some(s) = flags.get("shards") {
        cfg.sim.shards = s.parse::<usize>()?.max(1);
    }
    cfg.artifacts_dir = artifacts_dir();
    Ok(cfg)
}

fn cmd_run(flags: std::collections::HashMap<String, String>) -> Result<()> {
    let cfg = build_config(&flags)?;
    let rt = try_runtime();
    let out = run_experiment(cfg.clone(), rt.as_ref())?;
    if let Some(dir) = flags.get("csv") {
        splitplace::metrics::export::write_csv(&out.metrics, dir)?;
        eprintln!("telemetry written to {dir}/intervals.csv and {dir}/tasks.csv");
    }
    let s = &out.summary;
    let mut t = Table::new(
        &format!("{} — {} intervals, λ={}", s.policy, cfg.sim.intervals, cfg.workload.lambda),
        &["metric", "value"],
    );
    t.row(vec!["tasks completed".into(), s.tasks.to_string()]);
    t.row(vec!["avg reward (eq.15)".into(), fnum(s.avg_reward)]);
    t.row(vec!["accuracy (eq.13)".into(), fnum(s.accuracy)]);
    t.row(vec!["SLA violations (eq.14)".into(), fnum(s.sla_violations)]);
    t.row(vec!["response (intervals)".into(), fpm(s.response.0, s.response.1)]);
    t.row(vec!["wait (intervals)".into(), fpm(s.wait.0, s.wait.1)]);
    t.row(vec!["energy (MW-hr)".into(), fnum(s.energy_mwh)]);
    t.row(vec!["fairness (Jain)".into(), fnum(s.fairness)]);
    t.row(vec!["scheduling time (s)".into(), fpm(s.sched_time_s.0, s.sched_time_s.1)]);
    t.row(vec!["cost (USD)".into(), fnum(s.cost_usd)]);
    t.print();
    Ok(())
}

fn cmd_compare(flags: std::collections::HashMap<String, String>) -> Result<()> {
    let rt = try_runtime();
    let mut t = Table::new(
        "Policy comparison (Table 4)",
        &["policy", "energy MWh", "sched s", "fairness", "wait", "response", "SLA viol", "accuracy", "reward"],
    );
    for policy in PolicyKind::all() {
        let mut cfg = build_config(&flags)?;
        cfg.policy = policy;
        match run_experiment(cfg, rt.as_ref()) {
            Ok(out) => {
                let s = out.summary;
                t.row(vec![
                    s.policy.clone(),
                    fnum(s.energy_mwh),
                    fnum(s.sched_time_s.0),
                    fnum(s.fairness),
                    fnum(s.wait.0),
                    fpm(s.response.0, s.response.1),
                    fnum(s.sla_violations),
                    fnum(s.accuracy),
                    fnum(s.avg_reward),
                ]);
            }
            Err(e) => {
                t.row(vec![
                    policy.name().into(),
                    format!("error: {e:#}"),
                    "-".into(), "-".into(), "-".into(), "-".into(), "-".into(), "-".into(), "-".into(),
                ]);
            }
        }
    }
    t.print();
    Ok(())
}

/// Derive the experiment's internal seeds from the chaos seed so one
/// number reproduces the whole run (plan, fleet, workload, MAB). Shared
/// with the matrix harness so its cells replay under `chaos --plan`.
fn chaos_seed_config(cfg: &mut ExperimentConfig, seed: u64) {
    harness::seed_config(cfg, seed);
}

/// `--inject-bug` / `--task-timeout` flags → [`ChaosOptions`], shared by
/// the `chaos` and `matrix` subcommands.
fn chaos_options_from_flags(
    flags: &std::collections::HashMap<String, String>,
) -> Result<ChaosOptions> {
    Ok(ChaosOptions {
        bug: match flags.get("inject-bug") {
            Some(s) => Some(
                BugKind::parse(s)
                    .ok_or_else(|| anyhow::anyhow!("unknown --inject-bug '{s}'"))?,
            ),
            None => None,
        },
        task_timeout_intervals: flags
            .get("task-timeout")
            .map(|s| s.parse())
            .transpose()?
            .unwrap_or(40),
        paranoid: flags.contains_key("paranoid"),
    })
}

fn print_chaos_outcome(policy: &str, out: &ChaosOutcome, intervals: usize) {
    let mut t = Table::new(
        &format!("Chaos oracles — {policy}, {intervals} intervals"),
        &["invariant", "status", "violations"],
    );
    for oracle in chaos::ORACLES {
        let n = out.violations.iter().filter(|v| v.oracle == oracle).count();
        t.row(vec![
            oracle.into(),
            if n == 0 { "ok".into() } else { "VIOLATED".into() },
            n.to_string(),
        ]);
    }
    t.print();
    let s = &out.summary;
    let mut t = Table::new("Run summary", &["metric", "value"]);
    t.row(vec!["tasks admitted".into(), out.admitted.to_string()]);
    t.row(vec!["tasks completed".into(), out.completed.to_string()]);
    t.row(vec!["tasks failed".into(), out.failed.to_string()]);
    t.row(vec!["SLA violations (eq.14)".into(), fnum(s.sla_violations)]);
    t.row(vec!["avg reward (eq.15)".into(), fnum(s.avg_reward)]);
    t.row(vec!["response (intervals)".into(), fpm(s.response.0, s.response.1)]);
    t.row(vec!["energy (MW-hr)".into(), fnum(s.energy_mwh)]);
    t.print();
}

fn cmd_chaos(flags: std::collections::HashMap<String, String>) -> Result<()> {
    let seed: u64 = flags.get("seed").map(|s| s.parse()).transpose()?.unwrap_or(7);
    let profile_name = flags.get("profile").map(String::as_str).unwrap_or("light");
    let profile = Profile::parse(profile_name)
        .ok_or_else(|| anyhow::anyhow!("--profile must be light|heavy, got {profile_name}"))?;

    let mut cfg = build_config(&flags)?;
    if !flags.contains_key("workers") {
        cfg.cluster = ClusterConfig::small();
    }
    if !flags.contains_key("intervals") {
        cfg.sim.intervals = 25;
    }
    chaos_seed_config(&mut cfg, seed);

    let plan = match flags.get("plan") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .map_err(|e| anyhow::anyhow!("reading plan {path}: {e}"))?;
            let plan = FaultPlan::from_json(&splitplace::util::json::parse(&text)?)?;
            if !flags.contains_key("intervals") {
                cfg.sim.intervals = plan.intervals;
            }
            // reproduce the original run exactly, whatever --seed says
            chaos_seed_config(&mut cfg, plan.seed);
            plan
        }
        None => FaultPlan::generate(seed, cfg.sim.intervals, profile, cfg.cluster.total_workers()),
    };

    let opts = chaos_options_from_flags(&flags)?;

    let rt = try_runtime();
    eprintln!(
        "chaos: seed {seed}, profile {}, {} events over {} intervals, policy {}",
        plan.profile,
        plan.events.len(),
        cfg.sim.intervals,
        cfg.policy.name()
    );

    let policy_b = flags
        .get("differential")
        .map(|p2| {
            PolicyKind::parse(p2)
                .ok_or_else(|| anyhow::anyhow!("unknown --differential policy '{p2}'"))
        })
        .transpose()?;
    let (out, out_b) = match policy_b {
        Some(pb) => {
            let (a, b) = chaos::run_differential(&cfg, pb, &plan, &opts, rt.as_ref())?;
            (a, Some((pb, b)))
        }
        None => (chaos::run_chaos(&cfg, &plan, &opts, rt.as_ref())?, None),
    };
    print_chaos_outcome(cfg.policy.name(), &out, cfg.sim.intervals);

    if let Some((pb, out_b)) = &out_b {
        print_chaos_outcome(pb.name(), out_b, cfg.sim.intervals);
        let mut t = Table::new(
            "Differential (same fault plan)",
            &["metric", cfg.policy.name(), pb.name()],
        );
        t.row(vec![
            "oracle violations".into(),
            out.violations.len().to_string(),
            out_b.violations.len().to_string(),
        ]);
        t.row(vec![
            "completed".into(),
            out.completed.to_string(),
            out_b.completed.to_string(),
        ]);
        t.row(vec!["failed".into(), out.failed.to_string(), out_b.failed.to_string()]);
        t.row(vec![
            "SLA violations".into(),
            fnum(out.summary.sla_violations),
            fnum(out_b.summary.sla_violations),
        ]);
        t.row(vec![
            "avg reward".into(),
            fnum(out.summary.avg_reward),
            fnum(out_b.summary.avg_reward),
        ]);
        t.print();
    }

    // A violation under EITHER policy is a bug: shrink under the policy
    // that hit it and exit non-zero so CI fails.
    let culprit = if !out.violations.is_empty() {
        Some((cfg.policy, &out.violations[0]))
    } else {
        out_b
            .as_ref()
            .and_then(|(pb, b)| b.violations.first().map(|v| (*pb, v)))
    };
    if let Some((policy, first)) = culprit {
        let mut cfg_v = cfg.clone();
        cfg_v.policy = policy;
        eprintln!("first violation ({}): {first}", policy.name());
        eprintln!("shrinking the plan to a minimal counterexample...");
        let shrunk = chaos::shrink_to_minimal(&cfg_v, &plan, &opts, rt.as_ref(), first.oracle);
        eprintln!(
            "minimal failing plan: {} events (from {}), found in {} re-runs",
            shrunk.plan.events.len(),
            shrunk.original_events,
            shrunk.runs
        );
        println!("{}", shrunk.plan.to_json().to_pretty());
        // carry every non-plan flag through so the replay rebuilds the
        // same cluster/workload/policy config, not the defaults
        let mut extra = String::new();
        let mut keys: Vec<&String> = flags.keys().collect();
        keys.sort();
        for key in keys {
            if matches!(key.as_str(), "plan" | "seed" | "profile" | "differential" | "policy") {
                continue; // plan carries seed/profile; policy set below
            }
            extra.push_str(&format!(" --{key} {}", flags[key]));
        }
        eprintln!(
            "reproduce: save the JSON above to plan.json, then run\n  \
             splitplace chaos --plan plan.json --policy {}{extra}",
            policy.name()
        );
        std::process::exit(1);
    }
    Ok(())
}

fn cmd_matrix(flags: std::collections::HashMap<String, String>) -> Result<()> {
    let filter = flags.get("filter").map(String::as_str).unwrap_or("smoke");
    let jobs: usize = flags.get("jobs").map(|s| s.parse()).transpose()?.unwrap_or(4);
    let intervals: usize =
        flags.get("intervals").map(|s| s.parse()).transpose()?.unwrap_or(12);
    let n_seeds: u64 = flags.get("seeds").map(|s| s.parse()).transpose()?.unwrap_or(2);
    let seeds: Vec<u64> = (1..=n_seeds.max(1)).collect();
    let cells = harness::matrix_cells(filter, &seeds);
    if cells.is_empty() {
        bail!("--filter '{filter}' matches no cells (try smoke, full, or an id substring)");
    }
    if flags.contains_key("list") {
        for c in &cells {
            println!("{}", c.id());
        }
        return Ok(());
    }

    let goldens_dir = flags.get("goldens").cloned().unwrap_or_else(|| "tests/goldens".into());
    let bugbase_dir = flags.get("bugbase").cloned().unwrap_or_else(|| "tests/bugbase".into());
    let shards: usize =
        flags.get("shards").map(|s| s.parse()).transpose()?.unwrap_or(1);
    let opts = MatrixOptions {
        jobs,
        intervals,
        shards,
        fail_fast: flags.contains_key("fail-fast"),
        update_goldens: flags.contains_key("update-goldens"),
        goldens: Some(GoldenStore::new(&goldens_dir)),
        chaos: chaos_options_from_flags(&flags)?,
    };

    eprintln!(
        "matrix: {} cells (filter '{filter}'), {} intervals each, {jobs} jobs",
        cells.len(),
        intervals
    );
    let report = harness::run_matrix(&cells, &opts);

    let mut t = Table::new(
        &format!("Scenario matrix — {} cells in {:.0} ms", report.results.len(), report.wall_ms),
        &["cell", "ms", "done", "fail", "resp ema", "viol rate", "reward", "oracles", "golden"],
    );
    for r in &report.results {
        // differential cells carry side-prefixed metrics; show side `a`
        // (the champion) in the shared columns, deltas in the oracle gap
        let m = |k: &str| {
            r.summary
                .metrics
                .get(k)
                .or_else(|| r.summary.metrics.get(&format!("a_{k}")))
                .copied()
                .unwrap_or(f64::NAN)
        };
        let mut verdicts = if r.summary.violated_oracles.is_empty() {
            "ok".to_string()
        } else {
            r.summary.violated_oracles.join(",")
        };
        if !r.ordering_failures.is_empty() {
            verdicts = format!("ORDERING,{verdicts}");
        }
        t.row(vec![
            r.cell.id(),
            format!("{:.0}", r.wall_ms),
            format!("{}", m("completed")),
            format!("{}", m("failed")),
            fnum(m("response_ema")),
            fnum(m("sla_violation_rate")),
            fnum(m("avg_reward")),
            verdicts,
            r.golden.label().into(),
        ]);
    }
    t.print();
    if report.skipped > 0 {
        eprintln!("fail-fast: {} cells not scheduled", report.skipped);
    }

    // errors + ordering + golden drift details
    for r in &report.results {
        if let Some(e) = &r.error {
            eprintln!("ERROR {}: {e}", r.cell.id());
        }
        for o in &r.ordering_failures {
            eprintln!("ORDERING {}: {o}", r.cell.id());
        }
        if let GoldenStatus::Drift(msgs) = &r.golden {
            for m in msgs {
                eprintln!("DRIFT {}: {m}", r.cell.id());
            }
        }
        if let GoldenStatus::Missing = &r.golden {
            eprintln!(
                "MISSING {}: no golden at {}; record with --update-goldens and review the diff",
                r.cell.id(),
                GoldenStore::new(&goldens_dir).path(&r.cell.file_stem()).display()
            );
        }
    }

    // violations → shrink → bug-base artifacts that replay forever
    let violated = report.results.iter().filter(|r| !r.violations.is_empty()).count();
    if violated > 0 {
        eprintln!("{violated} cell(s) violated invariants; shrinking to minimal plans...");
        match harness::persist_violations(&report, &opts, &bugbase_dir) {
            Ok(paths) => {
                for p in &paths {
                    eprintln!("bug-base artifact written: {}", p.display());
                }
                eprintln!(
                    "commit these artifacts: tests/bugbase_replay.rs replays them on every run"
                );
            }
            Err(e) => eprintln!("bug-base persistence failed: {e}"),
        }
    }

    if report.failed() {
        std::process::exit(1);
    }
    Ok(())
}

fn cmd_bench(flags: std::collections::HashMap<String, String>) -> Result<()> {
    use splitplace::benchlib::throughput;

    let tier_flag = flags.get("tier").map(String::as_str).unwrap_or("all");
    let tiers: Vec<throughput::TierSpec> = match tier_flag {
        "all" => throughput::tiers(),
        name => vec![throughput::tier_by_name(name).ok_or_else(|| {
            anyhow::anyhow!("--tier must be small|medium|large|huge|hyperscale|all, got {name}")
        })?],
    };
    let intervals: usize =
        flags.get("intervals").map(|s| s.parse()).transpose()?.unwrap_or(50);
    let seed: u64 = flags.get("seed").map(|s| s.parse()).transpose()?.unwrap_or(7);
    let chaos = match flags.get("scenario").map(String::as_str).unwrap_or("chaos-light") {
        "clean" => false,
        "chaos-light" => true,
        other => bail!("--scenario must be clean|chaos-light, got {other}"),
    };
    // policy axis: mc (default) times the bare engine hot path; any other
    // stack (latmem, onlinesplit, mab-daso, …) times its decision-plane
    // overhead on the same tier regime
    let policy = match flags.get("policy") {
        Some(p) => PolicyKind::parse(p)
            .ok_or_else(|| anyhow::anyhow!("unknown --policy '{p}'"))?,
        None => PolicyKind::ModelCompression,
    };
    let shards: usize =
        flags.get("shards").map(|s| s.parse()).transpose()?.unwrap_or(1);
    let out = flags.get("out").cloned().unwrap_or_else(|| "BENCH_engine.json".into());

    let mut results = Vec::new();
    for tier in &tiers {
        eprintln!(
            "bench: {} tier, {intervals} intervals, seed {seed}, policy {}, {shards} shard(s)...",
            tier.name,
            policy.name()
        );
        results.push(throughput::measure(tier, intervals, seed, chaos, policy, shards)?);
    }

    let mut t = Table::new(
        &format!(
            "Engine throughput — {} ({} intervals, seed {seed})",
            if chaos { "chaos-light" } else { "clean" },
            intervals
        ),
        &[
            "tier",
            "policy",
            "workers",
            "wall ms",
            "intervals/s",
            "container-intervals/s",
            "admitted",
            "done",
            "fail",
        ],
    );
    for r in &results {
        t.row(vec![
            r.tier.clone(),
            r.policy.clone(),
            r.workers.to_string(),
            format!("{:.0}", r.wall_ms),
            format!("{:.1}", r.intervals_per_sec),
            format!("{:.0}", r.container_intervals_per_sec),
            r.admitted.to_string(),
            r.completed.to_string(),
            r.failed.to_string(),
        ]);
    }
    t.print();

    // where the wall went: per-phase breakdown (informational — written
    // to the JSON record but never gated; oracle is 0 here because the
    // bench runs no oracle sweeps)
    let mut t = Table::new(
        "Phase breakdown (wall ms)",
        &["tier", "cpu", "network", "decision", "traffic", "oracle", "untimed"],
    );
    for r in &results {
        let p = &r.phases;
        let timed = p.cpu_ms + p.network_ms + p.decision_ms + p.traffic_ms + p.oracle_ms;
        t.row(vec![
            r.tier.clone(),
            format!("{:.0}", p.cpu_ms),
            format!("{:.0}", p.network_ms),
            format!("{:.0}", p.decision_ms),
            format!("{:.0}", p.traffic_ms),
            format!("{:.0}", p.oracle_ms),
            format!("{:.0}", (r.wall_ms - timed).max(0.0)),
        ]);
    }
    t.print();

    // perf-trajectory gate: compare against the committed baseline BEFORE
    // overwriting it with this run (the common case is --gate and --out
    // naming the same file)
    let gate = flags.get("gate").map(|baseline| {
        splitplace::benchlib::perfgate::gate_against_baseline(
            std::path::Path::new(baseline),
            &results,
        )
    });

    throughput::write_json(std::path::Path::new(&out), &results)
        .map_err(|e| anyhow::anyhow!("writing {out}: {e}"))?;
    eprintln!("perf record written to {out}");

    if let Some(gate) = gate {
        use splitplace::benchlib::perfgate::PerfGate;
        match gate {
            PerfGate::Skipped(why) => eprintln!("perf gate SKIPPED: {why}"),
            PerfGate::Pass(n) => eprintln!("perf gate: {n} tier(s) within bands"),
            PerfGate::Fail(msgs) => {
                for m in &msgs {
                    eprintln!("PERF REGRESSION: {m}");
                }
                std::process::exit(1);
            }
        }
    }
    Ok(())
}

fn cmd_trace(args: &[String], flags: std::collections::HashMap<String, String>) -> Result<()> {
    use splitplace::config::WorkloadConfig;
    use splitplace::traffic::{self, TrafficShape};
    use splitplace::workload::replay;

    match args.get(1).map(String::as_str) {
        Some("record") => {
            let shape_name = flags.get("shape").map(String::as_str).unwrap_or("flat");
            let shape = TrafficShape::parse(shape_name).ok_or_else(|| {
                anyhow::anyhow!(
                    "--shape must be flat|diurnal|mmpp|heavy-tail, got {shape_name}"
                )
            })?;
            let intervals: usize =
                flags.get("intervals").map(|s| s.parse()).transpose()?.unwrap_or(12);
            let mut wl = WorkloadConfig::default();
            if let Some(l) = flags.get("lambda") {
                wl.lambda = l.parse()?;
            }
            if let Some(s) = flags.get("seed") {
                wl.seed = s.parse()?;
            }
            let interval_seconds = ExperimentConfig::default().sim.interval_seconds;
            let out = flags.get("out").cloned().unwrap_or_else(|| "trace.json".into());
            let tasks = traffic::generate_trace(&wl, shape, intervals, interval_seconds);
            replay::save(&tasks, &out)?;
            eprintln!(
                "recorded {} tasks (shape {}, λ={}, seed {}) over {} intervals to {}",
                tasks.len(),
                shape.name(),
                wl.lambda,
                wl.seed,
                intervals,
                out
            );
            Ok(())
        }
        Some("replay") => {
            let path = flags
                .get("trace")
                .ok_or_else(|| anyhow::anyhow!("trace replay needs --trace FILE"))?;
            let mut cfg = build_config(&flags)?;
            if !flags.contains_key("workers") {
                cfg.cluster = ClusterConfig::small();
            }
            cfg.traffic.trace = Some(path.clone());
            let rt = try_runtime();
            let out = run_experiment(cfg.clone(), rt.as_ref())?;
            let s = &out.summary;
            let mut t = Table::new(
                &format!("{} — trace {path}, {} intervals", s.policy, cfg.sim.intervals),
                &["metric", "value"],
            );
            t.row(vec!["tasks completed".into(), s.tasks.to_string()]);
            t.row(vec!["avg reward (eq.15)".into(), fnum(s.avg_reward)]);
            t.row(vec!["accuracy (eq.13)".into(), fnum(s.accuracy)]);
            t.row(vec!["SLA violations (eq.14)".into(), fnum(s.sla_violations)]);
            t.row(vec![
                "response (intervals)".into(),
                fpm(s.response.0, s.response.1),
            ]);
            t.row(vec!["energy (MW-hr)".into(), fnum(s.energy_mwh)]);
            t.print();
            Ok(())
        }
        other => bail!(
            "trace needs a mode: record|replay (got '{}')",
            other.unwrap_or("")
        ),
    }
}

fn cmd_serve(flags: std::collections::HashMap<String, String>) -> Result<()> {
    let addr = flags.get("addr").cloned().unwrap_or_else(|| "127.0.0.1:7077".into());
    let threads: usize = flags.get("threads").map(|t| t.parse()).transpose()?.unwrap_or(4);
    if try_runtime().is_none() {
        bail!("artifacts not found — run `make artifacts`");
    }
    let server = splitplace::server::Server::start(&artifacts_dir(), &addr, threads)?;
    println!("splitplace serving on {} with {threads} worker threads", server.addr);
    println!("protocol: one JSON per line, e.g. {{\"app\":\"mnist\",\"batch\":32000,\"sla\":4.0}}");
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_info() -> Result<()> {
    println!("splitplace {}", splitplace::version());
    let client = xla::PjRtClient::cpu()?;
    println!("PJRT: platform={} devices={}", client.platform_name(), client.device_count());
    let dir = artifacts_dir();
    println!("artifacts: {dir}");
    match try_runtime() {
        Some(rt) => {
            let mut t = Table::new("Apps", &["app", "dim", "classes", "layer acc", "semantic acc", "compressed acc"]);
            for app in splitplace::splits::APPS {
                let a = &rt.manifest.apps[&app];
                t.row(vec![
                    app.name().into(),
                    a.input_dim.to_string(),
                    a.classes.to_string(),
                    fnum(a.accuracy_layer),
                    fnum(a.accuracy_semantic),
                    fnum(a.accuracy_compressed),
                ]);
            }
            t.print();
            let mut t = Table::new("Surrogates", &["variant", "workers", "slots", "feature dim"]);
            for (name, s) in &rt.manifest.surrogates {
                t.row(vec![
                    name.clone(),
                    s.workers.to_string(),
                    s.slots.to_string(),
                    s.feature_dim.to_string(),
                ]);
            }
            t.print();
        }
        None => println!("  (not built — run `make artifacts`)"),
    }
    let cluster = splitplace::cluster::build_fleet(&ClusterConfig::default());
    println!(
        "default fleet: {} workers, {:.0} total MIPS, {:.0} GB RAM",
        cluster.len(),
        cluster.total_mips(),
        cluster.total_ram_mb() / 1024.0
    );
    Ok(())
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("info");
    let flags = parse_flags(&args[args.len().min(1)..]);
    match cmd {
        "run" => cmd_run(flags),
        "compare" => cmd_compare(flags),
        "chaos" => cmd_chaos(flags),
        "matrix" => cmd_matrix(flags),
        "bench" => cmd_bench(flags),
        "trace" => cmd_trace(&args, flags),
        "serve" => cmd_serve(flags),
        "info" => cmd_info(),
        other => {
            eprintln!(
                "unknown command '{other}'; try: run, compare, chaos, matrix, bench, \
                 trace, serve, info"
            );
            std::process::exit(2);
        }
    }
}
