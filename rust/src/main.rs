//! SplitPlace CLI — leader entrypoint.
//!
//! Subcommands:
//!   run [--policy P] [--intervals N] [--lambda L] [--workers small|full]
//!       [--alpha A] [--constraint c] [--accuracy measured|manifest]
//!   compare [--intervals N]        all 7 policies, Table-4 style
//!   serve [--addr A] [--threads N] serving front-end
//!   info                           artifact + cluster inventory
//!
//! (Hand-rolled arg parsing: clap is not in the offline crate set.)

use anyhow::{bail, Result};

use splitplace::config::{
    AccuracyMode, ClusterConfig, EnvConstraint, ExperimentConfig, PolicyKind,
};
use splitplace::coordinator::runner::{artifacts_dir, run_experiment, try_runtime};
use splitplace::util::table::{fnum, fpm, Table};

fn parse_flags(args: &[String]) -> std::collections::HashMap<String, String> {
    let mut map = std::collections::HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                map.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                map.insert(key.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            i += 1;
        }
    }
    map
}

fn build_config(flags: &std::collections::HashMap<String, String>) -> Result<ExperimentConfig> {
    let mut cfg = ExperimentConfig::default();
    if let Some(p) = flags.get("policy") {
        cfg.policy = PolicyKind::parse(p)
            .ok_or_else(|| anyhow::anyhow!("unknown policy '{p}'"))?;
    }
    if let Some(n) = flags.get("intervals") {
        cfg.sim.intervals = n.parse()?;
    }
    if let Some(l) = flags.get("lambda") {
        cfg.workload.lambda = l.parse()?;
    }
    if let Some(a) = flags.get("alpha") {
        cfg.placement.alpha = a.parse()?;
    }
    if let Some(w) = flags.get("workers") {
        cfg.cluster = match w.as_str() {
            "small" => ClusterConfig::small(),
            "full" => ClusterConfig::default(),
            other => bail!("--workers must be small|full, got {other}"),
        };
    }
    if let Some(c) = flags.get("constraint") {
        cfg.cluster.constraint = match c.as_str() {
            "compute" => EnvConstraint::Compute,
            "network" => EnvConstraint::Network,
            "memory" => EnvConstraint::Memory,
            "none" => EnvConstraint::None,
            other => bail!("unknown constraint {other}"),
        };
    }
    if let Some(a) = flags.get("accuracy") {
        cfg.accuracy = match a.as_str() {
            "measured" => AccuracyMode::Measured,
            _ => AccuracyMode::Manifest,
        };
    }
    cfg.artifacts_dir = artifacts_dir();
    Ok(cfg)
}

fn cmd_run(flags: std::collections::HashMap<String, String>) -> Result<()> {
    let cfg = build_config(&flags)?;
    let rt = try_runtime();
    let out = run_experiment(cfg.clone(), rt.as_ref())?;
    if let Some(dir) = flags.get("csv") {
        splitplace::metrics::export::write_csv(&out.metrics, dir)?;
        eprintln!("telemetry written to {dir}/intervals.csv and {dir}/tasks.csv");
    }
    let s = &out.summary;
    let mut t = Table::new(
        &format!("{} — {} intervals, λ={}", s.policy, cfg.sim.intervals, cfg.workload.lambda),
        &["metric", "value"],
    );
    t.row(vec!["tasks completed".into(), s.tasks.to_string()]);
    t.row(vec!["avg reward (eq.15)".into(), fnum(s.avg_reward)]);
    t.row(vec!["accuracy (eq.13)".into(), fnum(s.accuracy)]);
    t.row(vec!["SLA violations (eq.14)".into(), fnum(s.sla_violations)]);
    t.row(vec!["response (intervals)".into(), fpm(s.response.0, s.response.1)]);
    t.row(vec!["wait (intervals)".into(), fpm(s.wait.0, s.wait.1)]);
    t.row(vec!["energy (MW-hr)".into(), fnum(s.energy_mwh)]);
    t.row(vec!["fairness (Jain)".into(), fnum(s.fairness)]);
    t.row(vec!["scheduling time (s)".into(), fpm(s.sched_time_s.0, s.sched_time_s.1)]);
    t.row(vec!["cost (USD)".into(), fnum(s.cost_usd)]);
    t.print();
    Ok(())
}

fn cmd_compare(flags: std::collections::HashMap<String, String>) -> Result<()> {
    let rt = try_runtime();
    let mut t = Table::new(
        "Policy comparison (Table 4)",
        &["policy", "energy MWh", "sched s", "fairness", "wait", "response", "SLA viol", "accuracy", "reward"],
    );
    for policy in PolicyKind::all() {
        let mut cfg = build_config(&flags)?;
        cfg.policy = policy;
        match run_experiment(cfg, rt.as_ref()) {
            Ok(out) => {
                let s = out.summary;
                t.row(vec![
                    s.policy.clone(),
                    fnum(s.energy_mwh),
                    fnum(s.sched_time_s.0),
                    fnum(s.fairness),
                    fnum(s.wait.0),
                    fpm(s.response.0, s.response.1),
                    fnum(s.sla_violations),
                    fnum(s.accuracy),
                    fnum(s.avg_reward),
                ]);
            }
            Err(e) => {
                t.row(vec![
                    policy.name().into(),
                    format!("error: {e:#}"),
                    "-".into(), "-".into(), "-".into(), "-".into(), "-".into(), "-".into(), "-".into(),
                ]);
            }
        }
    }
    t.print();
    Ok(())
}

fn cmd_serve(flags: std::collections::HashMap<String, String>) -> Result<()> {
    let addr = flags.get("addr").cloned().unwrap_or_else(|| "127.0.0.1:7077".into());
    let threads: usize = flags.get("threads").map(|t| t.parse()).transpose()?.unwrap_or(4);
    if try_runtime().is_none() {
        bail!("artifacts not found — run `make artifacts`");
    }
    let server = splitplace::server::Server::start(&artifacts_dir(), &addr, threads)?;
    println!("splitplace serving on {} with {threads} worker threads", server.addr);
    println!("protocol: one JSON per line, e.g. {{\"app\":\"mnist\",\"batch\":32000,\"sla\":4.0}}");
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_info() -> Result<()> {
    println!("splitplace {}", splitplace::version());
    let client = xla::PjRtClient::cpu()?;
    println!("PJRT: platform={} devices={}", client.platform_name(), client.device_count());
    let dir = artifacts_dir();
    println!("artifacts: {dir}");
    match try_runtime() {
        Some(rt) => {
            let mut t = Table::new("Apps", &["app", "dim", "classes", "layer acc", "semantic acc", "compressed acc"]);
            for app in splitplace::splits::APPS {
                let a = &rt.manifest.apps[&app];
                t.row(vec![
                    app.name().into(),
                    a.input_dim.to_string(),
                    a.classes.to_string(),
                    fnum(a.accuracy_layer),
                    fnum(a.accuracy_semantic),
                    fnum(a.accuracy_compressed),
                ]);
            }
            t.print();
            let mut t = Table::new("Surrogates", &["variant", "workers", "slots", "feature dim"]);
            for (name, s) in &rt.manifest.surrogates {
                t.row(vec![
                    name.clone(),
                    s.workers.to_string(),
                    s.slots.to_string(),
                    s.feature_dim.to_string(),
                ]);
            }
            t.print();
        }
        None => println!("  (not built — run `make artifacts`)"),
    }
    let cluster = splitplace::cluster::build_fleet(&ClusterConfig::default());
    println!(
        "default fleet: {} workers, {:.0} total MIPS, {:.0} GB RAM",
        cluster.len(),
        cluster.total_mips(),
        cluster.total_ram_mb() / 1024.0
    );
    Ok(())
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("info");
    let flags = parse_flags(&args[args.len().min(1)..]);
    match cmd {
        "run" => cmd_run(flags),
        "compare" => cmd_compare(flags),
        "serve" => cmd_serve(flags),
        "info" => cmd_info(),
        other => {
            eprintln!("unknown command '{other}'; try: run, compare, serve, info");
            std::process::exit(2);
        }
    }
}
