//! # SplitPlace — AI-augmented splitting and placement of split neural
//! # networks in mobile edge environments
//!
//! Reproduction of Tuli, Casale & Jennings (2022). Three-layer architecture:
//!
//! * **Layer 3 (this crate)** — the rust coordinator: Multi-Armed-Bandit
//!   split decider ([`mab`]), decision-aware surrogate placement
//!   ([`placement::daso`]), the broker loop implementing the paper's
//!   Algorithm 1 ([`coordinator`]), a discrete-interval mobile-edge cluster
//!   engine ([`sim`], [`cluster`]), baselines ([`baselines`]), a
//!   thread-pool serving front-end ([`server`]), a deterministic
//!   fault-injection harness with invariant oracles ([`chaos`]) and a
//!   parallel scenario-matrix harness with golden-trace gating and a
//!   persisted bug-base ([`harness`]).
//! * **Layer 2 (python/compile, build-time only)** — JAX split-network and
//!   surrogate graphs, AOT-lowered to HLO text in `artifacts/`.
//! * **Layer 1 (python/compile/kernels)** — the Pallas fused-dense kernel
//!   every graph lowers through.
//!
//! The [`runtime`] module loads the AOT artifacts through the PJRT C API
//! (`xla` crate) — Python never runs on the request path.

pub mod baselines;
pub mod benchlib;
pub mod chaos;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod harness;
pub mod mab;
pub mod metrics;
pub mod placement;
pub mod runtime;
pub mod server;
pub mod sim;
pub mod splits;
pub mod testutil;
pub mod traffic;
pub mod util;
pub mod workload;

/// Crate version (mirrors Cargo.toml).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
