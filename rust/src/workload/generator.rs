//! Poisson(λ) task generator (paper §6.2): at the start of each interval,
//! Poisson(λ) tasks arrive, app sampled from the (possibly constrained)
//! app mix, batch ~ U(16k, 64k), SLA ~ U(lo, hi) × nominal layer RT.

use super::Task;
use crate::config::WorkloadConfig;
use crate::splits::{App, APPS};
use crate::util::rng::Rng;

pub struct Generator {
    cfg: WorkloadConfig,
    rng: Rng,
    next_id: u64,
    cumulative_weights: [f64; 3],
}

impl Generator {
    pub fn new(cfg: WorkloadConfig) -> Self {
        let total: f64 = cfg.app_weights.iter().sum();
        assert!(total > 0.0, "app weights must not all be zero");
        let mut acc = 0.0;
        let mut cw = [0.0; 3];
        for i in 0..3 {
            acc += cfg.app_weights[i] / total;
            cw[i] = acc;
        }
        let seed = cfg.seed;
        Generator { cfg, rng: Rng::new(seed), next_id: 0, cumulative_weights: cw }
    }

    fn sample_app(&mut self) -> App {
        let u = self.rng.f64();
        for (i, &c) in self.cumulative_weights.iter().enumerate() {
            if u <= c {
                return APPS[i];
            }
        }
        APPS[2]
    }

    /// Tasks arriving at the start of one interval (`now_s` = interval start).
    pub fn arrivals(&mut self, now_s: f64) -> Vec<Task> {
        let lambda = self.cfg.lambda;
        self.arrivals_with(now_s, lambda)
    }

    /// Arrivals under an overridden rate (flash-crowd injection): same
    /// stream, different λ for this interval only.
    pub fn arrivals_with(&mut self, now_s: f64, lambda: f64) -> Vec<Task> {
        let n = self.rng.poisson(lambda);
        (0..n).map(|_| self.one(now_s)).collect()
    }

    /// A single task (used by the serving front-end too).
    pub fn one(&mut self, now_s: f64) -> Task {
        let app = self.sample_app();
        let batch = self
            .rng
            .int_range(self.cfg.batch_min as i64, self.cfg.batch_max as i64)
            as u64;
        // SLA scales with the batch (the paper takes per-request deadlines
        // from Gillis, which are proportional to the work): nominal layer
        // RT is calibrated at a 40k batch.
        let size_factor = batch as f64 / 40_000.0;
        let sla = self.rng.range(self.cfg.sla_lo, self.cfg.sla_hi)
            * app.nominal_layer_rt()
            * size_factor;
        let id = self.next_id;
        self.next_id += 1;
        Task { id, app, batch, sla, arrival_s: now_s, decision: None }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkloadConfig;

    #[test]
    fn poisson_rate_respected() {
        let mut g = Generator::new(WorkloadConfig { lambda: 6.0, ..Default::default() });
        let total: usize = (0..500).map(|i| g.arrivals(i as f64 * 300.0).len()).sum();
        let mean = total as f64 / 500.0;
        assert!((mean - 6.0).abs() < 0.5, "mean={mean}");
    }

    #[test]
    fn batch_range() {
        let mut g = Generator::new(WorkloadConfig::default());
        for _ in 0..200 {
            let t = g.one(0.0);
            assert!((16_000..=64_000).contains(&t.batch));
        }
    }

    #[test]
    fn sla_scales_with_app_nominal_and_batch() {
        let cfg = WorkloadConfig { sla_lo: 1.0, sla_hi: 1.0, ..Default::default() };
        let mut g = Generator::new(cfg);
        for _ in 0..100 {
            let t = g.one(0.0);
            let want = t.app.nominal_layer_rt() * t.batch as f64 / 40_000.0;
            assert!((t.sla - want).abs() < 1e-9);
        }
    }

    #[test]
    fn sla_spans_both_mab_contexts() {
        // defaults must generate both sla < nominal and sla >= nominal
        let mut g = Generator::new(WorkloadConfig::default());
        let (mut low, mut high) = (0, 0);
        for _ in 0..500 {
            let t = g.one(0.0);
            if t.sla < t.app.nominal_layer_rt() {
                low += 1;
            } else {
                high += 1;
            }
        }
        assert!(low > 50 && high > 50, "low={low} high={high}");
    }

    #[test]
    fn single_app_mix() {
        let cfg = WorkloadConfig { app_weights: [0.0, 0.0, 1.0], ..Default::default() };
        let mut g = Generator::new(cfg);
        for _ in 0..50 {
            assert_eq!(g.one(0.0).app, crate::splits::App::Cifar100);
        }
    }

    #[test]
    fn ids_unique_and_monotone() {
        let mut g = Generator::new(WorkloadConfig::default());
        let ids: Vec<u64> = (0..100).map(|_| g.one(0.0).id).collect();
        for (i, id) in ids.iter().enumerate() {
            assert_eq!(*id, i as u64);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mk = || {
            let mut g = Generator::new(WorkloadConfig::default());
            (0..50).map(|_| g.one(0.0).batch).collect::<Vec<_>>()
        };
        assert_eq!(mk(), mk());
    }

    #[test]
    #[should_panic(expected = "app weights")]
    fn zero_weights_rejected() {
        Generator::new(WorkloadConfig { app_weights: [0.0; 3], ..Default::default() });
    }
}
