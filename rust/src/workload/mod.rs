//! Workload model: Poisson task arrivals with app mix, batch sizes and SLA
//! deadlines (paper §6.2), plus trace record/replay for surrogate training.

pub mod generator;
pub mod replay;
pub mod trace;

use crate::splits::{App, SplitDecision};

/// One inference task (paper: i = {b_i, sla_i, a_i}).
#[derive(Clone, Debug)]
pub struct Task {
    pub id: u64,
    pub app: App,
    /// Batch size in samples (paper: uniform 16k–64k).
    pub batch: u64,
    /// SLA deadline in scheduling intervals.
    pub sla: f64,
    /// Arrival time (simulation seconds).
    pub arrival_s: f64,
    /// Split decision once taken (stays fixed for the task's lifetime).
    pub decision: Option<SplitDecision>,
}

impl Task {
    pub fn batch_k(&self) -> f64 {
        self.batch as f64 / 1000.0
    }
}
