//! Workload trace record/replay: serialize a generated arrival sequence to
//! JSON and replay it verbatim, so different policies can be compared on
//! the *identical* task stream (used by the figure benches for paired
//! comparisons, and handy for regression triage).

use anyhow::{Context as _, Result};

use crate::splits::App;
use crate::util::json::{self, Value};

use super::Task;

/// Serialize tasks (arrival order) to a JSON array.
pub fn to_json(tasks: &[Task]) -> Value {
    Value::Arr(
        tasks
            .iter()
            .map(|t| {
                Value::obj(vec![
                    ("id", Value::Num(t.id as f64)),
                    ("app", Value::Str(t.app.name().into())),
                    ("batch", Value::Num(t.batch as f64)),
                    ("sla", Value::Num(t.sla)),
                    ("arrival_s", Value::Num(t.arrival_s)),
                ])
            })
            .collect(),
    )
}

/// Parse a recorded trace. Errors name the offending entry index and
/// field, so a hand-edited or truncated trace file is debuggable from the
/// message alone.
pub fn from_json(v: &Value) -> Result<Vec<Task>> {
    v.as_arr()?
        .iter()
        .enumerate()
        .map(|(i, t)| {
            let num = |field: &'static str| -> Result<f64> {
                t.req(field)
                    .and_then(|v| v.as_f64())
                    .with_context(|| format!("trace entry {i}: field '{field}'"))
            };
            let app_name = t
                .req("app")
                .and_then(|v| v.as_str())
                .with_context(|| format!("trace entry {i}: field 'app'"))?;
            Ok(Task {
                id: num("id")? as u64,
                app: App::from_name(app_name).with_context(|| {
                    format!("trace entry {i}: unknown app '{app_name}' (field 'app')")
                })?,
                batch: num("batch")? as u64,
                sla: num("sla")?,
                arrival_s: num("arrival_s")?,
                decision: None,
            })
        })
        .collect()
}

/// Write a trace file.
pub fn save(tasks: &[Task], path: impl AsRef<std::path::Path>) -> Result<()> {
    std::fs::write(path, to_json(tasks).to_pretty())?;
    Ok(())
}

/// Load a trace file. Errors carry the path.
pub fn load(path: impl AsRef<std::path::Path>) -> Result<Vec<Task>> {
    let path = path.as_ref();
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading trace {}", path.display()))?;
    let v = json::parse(&text)
        .with_context(|| format!("parsing trace {}", path.display()))?;
    from_json(&v).with_context(|| format!("decoding trace {}", path.display()))
}

/// Replay iterator: yields the tasks arriving within each interval.
pub struct Replay {
    tasks: Vec<Task>,
    cursor: usize,
    interval_seconds: f64,
    interval: usize,
}

impl Replay {
    pub fn new(mut tasks: Vec<Task>, interval_seconds: f64) -> Self {
        tasks.sort_by(|a, b| a.arrival_s.partial_cmp(&b.arrival_s).unwrap());
        Replay { tasks, cursor: 0, interval_seconds, interval: 0 }
    }

    /// Tasks arriving in the next interval window.
    pub fn next_interval(&mut self) -> Vec<Task> {
        let end = (self.interval + 1) as f64 * self.interval_seconds;
        let mut out = Vec::new();
        while self.cursor < self.tasks.len() && self.tasks[self.cursor].arrival_s < end {
            out.push(self.tasks[self.cursor].clone());
            self.cursor += 1;
        }
        self.interval += 1;
        out
    }

    pub fn remaining(&self) -> usize {
        self.tasks.len() - self.cursor
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkloadConfig;
    use crate::workload::generator::Generator;

    fn sample_tasks() -> Vec<Task> {
        let mut g = Generator::new(WorkloadConfig::default());
        let mut tasks = Vec::new();
        for i in 0..5 {
            tasks.extend(g.arrivals(i as f64 * 300.0));
        }
        tasks
    }

    #[test]
    fn json_roundtrip_exact() {
        let tasks = sample_tasks();
        let back = from_json(&to_json(&tasks)).unwrap();
        assert_eq!(back.len(), tasks.len());
        for (a, b) in tasks.iter().zip(&back) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.app, b.app);
            assert_eq!(a.batch, b.batch);
            assert!((a.sla - b.sla).abs() < 1e-12);
            assert!((a.arrival_s - b.arrival_s).abs() < 1e-12);
        }
    }

    #[test]
    fn file_roundtrip() {
        let tasks = sample_tasks();
        let path = std::env::temp_dir().join("splitplace_trace_test.json");
        save(&tasks, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.len(), tasks.len());
    }

    #[test]
    fn replay_windows_tasks_by_interval() {
        let tasks = sample_tasks();
        let total = tasks.len();
        let mut r = Replay::new(tasks.clone(), 300.0);
        let mut replayed = 0;
        for i in 0..5 {
            let window = r.next_interval();
            for t in &window {
                assert!(t.arrival_s < (i + 1) as f64 * 300.0);
                assert!(t.arrival_s >= i as f64 * 300.0 - 1e-9);
            }
            replayed += window.len();
        }
        assert_eq!(replayed, total);
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn bad_trace_rejected() {
        assert!(from_json(&json::parse(r#"[{"id":1}]"#).unwrap()).is_err());
        assert!(from_json(&json::parse(r#"[{"id":1,"app":"bogus","batch":1,"sla":1,"arrival_s":0}]"#).unwrap()).is_err());
    }

    #[test]
    fn bad_trace_errors_name_entry_and_field() {
        let err = from_json(&json::parse(r#"[{"id":1}]"#).unwrap()).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("trace entry 0"), "{msg}");
        assert!(msg.contains("'app'"), "{msg}");

        let err = from_json(
            &json::parse(
                r#"[{"id":1,"app":"mnist","batch":1,"sla":1,"arrival_s":0},
                    {"id":2,"app":"bogus","batch":1,"sla":1,"arrival_s":0}]"#,
            )
            .unwrap(),
        )
        .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("trace entry 1"), "{msg}");
        assert!(msg.contains("unknown app 'bogus'"), "{msg}");

        let err = load("/nonexistent/path/edge.json").unwrap_err();
        assert!(format!("{err:#}").contains("/nonexistent/path/edge.json"));
    }
}
