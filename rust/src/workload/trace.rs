//! Execution-trace recording: the dataset Λ = {([S_t, P_t, D_t], O_t)}
//! used to pre-train the DASO/GOBI surrogate (paper §4.2, eq. 11), plus
//! CSV-ish export for offline analysis.

use crate::util::json::Value;

/// One surrogate training sample.
#[derive(Clone, Debug)]
pub struct TraceSample {
    /// Flattened feature vector [S_t | P_t | D_t | demands] (layout in
    /// `placement::features`).
    pub features: Vec<f32>,
    /// Observed objective O^P for the interval (eq. 10).
    pub objective: f32,
}

/// Rolling trace buffer with reservoir-style capping.
#[derive(Clone, Debug)]
pub struct TraceBuffer {
    samples: Vec<TraceSample>,
    cap: usize,
    seen: usize,
}

impl TraceBuffer {
    pub fn new(cap: usize) -> Self {
        TraceBuffer { samples: Vec::new(), cap, seen: 0 }
    }

    pub fn push(&mut self, s: TraceSample) {
        self.seen += 1;
        if self.samples.len() < self.cap {
            self.samples.push(s);
        } else {
            // overwrite oldest (sliding window keeps recent dynamics,
            // which matters for non-stationary fine-tuning)
            let idx = self.seen % self.cap;
            self.samples[idx] = s;
        }
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn samples(&self) -> &[TraceSample] {
        &self.samples
    }

    /// Assemble a training minibatch (xb flattened row-major, yb) of
    /// exactly `batch` rows, sampling with replacement via the caller's
    /// index choice function.
    pub fn minibatch(
        &self,
        batch: usize,
        mut pick: impl FnMut(usize) -> usize,
    ) -> Option<(Vec<f32>, Vec<f32>)> {
        if self.samples.is_empty() {
            return None;
        }
        let f = self.samples[0].features.len();
        let mut xb = Vec::with_capacity(batch * f);
        let mut yb = Vec::with_capacity(batch);
        for _ in 0..batch {
            let s = &self.samples[pick(self.samples.len())];
            xb.extend_from_slice(&s.features);
            yb.push(s.objective);
        }
        Some((xb, yb))
    }

    /// JSON export (for debugging / offline analysis).
    pub fn to_json(&self) -> Value {
        Value::Arr(
            self.samples
                .iter()
                .map(|s| {
                    Value::obj(vec![
                        ("y", Value::Num(s.objective as f64)),
                        ("f_dim", Value::Num(s.features.len() as f64)),
                    ])
                })
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(y: f32) -> TraceSample {
        TraceSample { features: vec![y; 4], objective: y }
    }

    #[test]
    fn capping_overwrites_oldest() {
        let mut b = TraceBuffer::new(3);
        for i in 0..10 {
            b.push(sample(i as f32));
        }
        assert_eq!(b.len(), 3);
        // newest samples survive
        let max = b.samples().iter().map(|s| s.objective).fold(0.0, f32::max);
        assert!(max >= 7.0);
    }

    #[test]
    fn minibatch_shapes() {
        let mut b = TraceBuffer::new(8);
        for i in 0..5 {
            b.push(sample(i as f32));
        }
        let (xb, yb) = b.minibatch(4, |n| n - 1).unwrap();
        assert_eq!(xb.len(), 4 * 4);
        assert_eq!(yb.len(), 4);
        assert!(yb.iter().all(|&y| y == 4.0));
    }

    #[test]
    fn empty_minibatch_none() {
        let b = TraceBuffer::new(4);
        assert!(b.minibatch(2, |_| 0).is_none());
    }
}
