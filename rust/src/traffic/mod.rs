//! Traffic plane (ROADMAP item 3, the arXiv:2110.04841 deployment story):
//! arrival-process models, replayable traces, admission control and
//! autoscaling as first-class scenario citizens.
//!
//! Three pieces, all deterministic:
//!
//! * **[`TrafficModel`]** — per-interval arrival-rate shaping over the
//!   scenario's base λ. Every implementation is a *stateless pure function
//!   of `(interval, seed)*`: the diurnal phase, every MMPP regime
//!   transition and every heavy-tail batch draw derive from
//!   `util::rng::mix` streams keyed by the model seed and the interval (or
//!   task id), never from call order — so `--jobs 1` ≡ `--jobs N` stays
//!   byte-identical and a cell can be replayed from its coordinates alone.
//! * **[`AdmissionConfig`]** — queue-depth / deadline-risk shedding applied
//!   *before* the split decision, so the MAB accounting and
//!   task-conservation oracles see only admitted tasks. Shed counts surface
//!   as `CellSummary` counters (`offered`, `shed_queue`, `shed_deadline`).
//! * **[`Autoscaler`]** — worker park/unpark as a *decision*: it emits
//!   typed `EngineCmd::{WorkerLeave,WorkerJoin}` through the engine command
//!   bus tagged `CmdOrigin::Autoscale`, so every capacity change lands in
//!   the audit ledger, replays through `ledger-replay-consistent`, and is
//!   distinguishable from chaos-origin offline events.
//!
//! Trace replay rides `workload::replay`: a committed file under
//! `tests/traces/` becomes the `trace-replay` scenario, and
//! `splitplace trace record|replay` generates and pins new ones.

use crate::config::WorkloadConfig;
use crate::sim::{CmdOrigin, EngineCmd};
use crate::util::rng::{mix, Rng};
use crate::workload::generator::Generator;
use crate::workload::Task;

/// Stream tag separating the traffic-model seed from every other consumer
/// of `cfg.workload.seed`.
pub const TRAFFIC_STREAM_TAG: u64 = 0x7EA_FF1C;

const DIURNAL_TAG: u64 = 0xD1_0172;
const MMPP_TAG: u64 = 0x4D4D_5050;
const HEAVY_TAG: u64 = 0x7A11_BA7C;

/// The arrival-process axis: which [`TrafficModel`] shapes a scenario's
/// per-interval λ (and, for heavy-tail, its batch sizes).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TrafficShape {
    /// The paper's flat Poisson(λ) per interval — byte-identical to the
    /// pre-traffic-plane arrival stream.
    Flat,
    /// Sinusoid-modulated Poisson with a seeded phase (diurnal swing).
    Diurnal,
    /// MMPP-style two-regime process: quiet/surge with seeded transitions.
    Mmpp,
    /// Flat λ with heavy-tail batch-size inflation (occasional Pareto-ish
    /// giants), SLA rescaled proportionally.
    HeavyTail,
}

impl TrafficShape {
    pub fn name(&self) -> &'static str {
        match self {
            TrafficShape::Flat => "flat",
            TrafficShape::Diurnal => "diurnal",
            TrafficShape::Mmpp => "mmpp",
            TrafficShape::HeavyTail => "heavy-tail",
        }
    }

    pub fn parse(s: &str) -> Option<TrafficShape> {
        Some(match s.to_ascii_lowercase().as_str() {
            "flat" | "poisson" => TrafficShape::Flat,
            "diurnal" | "sinusoid" => TrafficShape::Diurnal,
            "mmpp" | "burst" => TrafficShape::Mmpp,
            "heavy-tail" | "heavytail" | "pareto" => TrafficShape::HeavyTail,
            _ => return None,
        })
    }

    pub fn all() -> [TrafficShape; 4] {
        [TrafficShape::Flat, TrafficShape::Diurnal, TrafficShape::Mmpp, TrafficShape::HeavyTail]
    }

    /// Build the model for this shape. `seed` is the traffic-stream seed
    /// (callers derive it as `mix(workload_seed, TRAFFIC_STREAM_TAG)`).
    pub fn build(&self, seed: u64) -> Box<dyn TrafficModel> {
        match self {
            TrafficShape::Flat => Box::new(FlatPoisson),
            TrafficShape::Diurnal => Box::new(DiurnalPoisson::new(seed)),
            TrafficShape::Mmpp => Box::new(MmppBurst::new(seed)),
            TrafficShape::HeavyTail => Box::new(HeavyTailBatch::new(seed)),
        }
    }
}

/// A deterministic arrival process. Implementations hold only their seed
/// and constants — `lambda_at` and `shape_tasks` must be pure functions of
/// `(t, seed)` / `(task.id, seed)` so replay never depends on call order.
pub trait TrafficModel: Send {
    fn name(&self) -> &'static str;

    /// Arrival rate for scheduling interval `t`, given the scenario's base
    /// λ (post any chaos flash-crowd override).
    fn lambda_at(&self, t: usize, base: f64) -> f64;

    /// Post-generation task shaping (heavy-tail batch inflation). Default
    /// is the identity, leaving the generator's stream untouched.
    fn shape_tasks(&self, _tasks: &mut [Task]) {}
}

/// The paper's flat Poisson process.
pub struct FlatPoisson;

impl TrafficModel for FlatPoisson {
    fn name(&self) -> &'static str {
        "flat"
    }

    fn lambda_at(&self, _t: usize, base: f64) -> f64 {
        base
    }
}

/// Diurnal sinusoid: λ(t) = base · (1 + depth · sin(2π(t + φ)/period)),
/// with the phase φ drawn once from the model seed.
pub struct DiurnalPoisson {
    phase: usize,
    period: usize,
    depth: f64,
}

impl DiurnalPoisson {
    pub fn new(seed: u64) -> Self {
        let period = 24;
        let phase = Rng::new(mix(seed, DIURNAL_TAG)).below(period as u64) as usize;
        DiurnalPoisson { phase, period, depth: 0.6 }
    }
}

impl TrafficModel for DiurnalPoisson {
    fn name(&self) -> &'static str {
        "diurnal"
    }

    fn lambda_at(&self, t: usize, base: f64) -> f64 {
        let angle =
            2.0 * std::f64::consts::PI * ((t + self.phase) as f64) / self.period as f64;
        (base * (1.0 + self.depth * angle.sin())).max(0.0)
    }
}

/// MMPP-style two-regime process: quiet (λ·1) and surge (λ·surge_mult),
/// with per-interval seeded transition draws. The regime at interval `t`
/// is the result of walking the transition chain from interval 0 — each
/// step's draw comes from its own `mix(seed, mix(MMPP_TAG, i))` stream, so
/// the walk is a pure function of `(t, seed)` however often it is queried.
///
/// The walk is memoized in a prefix cache: querying `t` extends the cache
/// from its current frontier instead of re-walking from interval 0, taking
/// a full run's regime queries from O(T²) to O(T) total (the quadratic
/// walk was re-paid by both trace generation and the broker). The cache is
/// pure memoization — each chain step replays the identical per-`i` draw
/// the uncached walk would make, so cached and uncached answers (and every
/// λ stream built from them) are byte-identical, in any query order.
pub struct MmppBurst {
    seed: u64,
    surge_mult: f64,
    p_enter: f64,
    p_exit: f64,
    /// `regimes.borrow()[i]` = regime after the interval-`i` transition.
    /// RefCell (not Mutex): models are owned per broker and only need
    /// `Send`, and `lambda_at` takes `&self` by the pure-function
    /// contract.
    regimes: std::cell::RefCell<Vec<bool>>,
}

impl MmppBurst {
    pub fn new(seed: u64) -> Self {
        MmppBurst {
            seed,
            surge_mult: 4.0,
            p_enter: 0.15,
            p_exit: 0.5,
            regimes: std::cell::RefCell::new(Vec::new()),
        }
    }

    /// Regime at interval `t` (true = surge).
    pub fn surge_at(&self, t: usize) -> bool {
        let mut cache = self.regimes.borrow_mut();
        if cache.len() <= t {
            // resume the chain at the cache frontier; before interval 0
            // the process starts quiet
            let mut surge = cache.last().copied().unwrap_or(false);
            for i in cache.len()..=t {
                let mut r = Rng::new(mix(self.seed, mix(MMPP_TAG, i as u64)));
                if surge {
                    if r.chance(self.p_exit) {
                        surge = false;
                    }
                } else if r.chance(self.p_enter) {
                    surge = true;
                }
                cache.push(surge);
            }
        }
        cache[t]
    }
}

impl TrafficModel for MmppBurst {
    fn name(&self) -> &'static str {
        "mmpp"
    }

    fn lambda_at(&self, t: usize, base: f64) -> f64 {
        if self.surge_at(t) {
            base * self.surge_mult
        } else {
            base
        }
    }
}

/// Flat λ with heavy-tail batches: a seeded per-task draw occasionally
/// inflates the batch by a truncated Pareto factor (α = 1.5, cap 4×), with
/// the SLA rescaled proportionally so deadline pressure per sample is
/// unchanged. Applied *after* generation, keyed by task id — the
/// generator's own streams (and every flat-shape golden) stay untouched.
pub struct HeavyTailBatch {
    seed: u64,
    p_giant: f64,
}

impl HeavyTailBatch {
    pub fn new(seed: u64) -> Self {
        HeavyTailBatch { seed, p_giant: 0.12 }
    }
}

impl TrafficModel for HeavyTailBatch {
    fn name(&self) -> &'static str {
        "heavy-tail"
    }

    fn lambda_at(&self, _t: usize, base: f64) -> f64 {
        base
    }

    fn shape_tasks(&self, tasks: &mut [Task]) {
        for task in tasks {
            let mut r = Rng::new(mix(mix(self.seed, HEAVY_TAG), task.id));
            if r.chance(self.p_giant) {
                let factor = (1.0 - r.f64()).powf(-1.0 / 1.5).min(4.0);
                let old = task.batch;
                if old == 0 {
                    // nothing to inflate, and old==0 would divide the SLA
                    // rescale by zero (NaN SLA poisons CellSummary goldens)
                    continue;
                }
                // round (not truncate) and clamp to [1, 256_000]: truncation
                // could hand the next consumer a zero batch
                task.batch = ((old as f64 * factor).round() as u64).clamp(1, 256_000);
                task.sla *= task.batch as f64 / old as f64;
            }
        }
    }
}

/// Admission-control policy: shed on queue depth or deadline risk before
/// the split decision is taken (shed tasks are never admitted to the
/// engine, never decided by the splitter, and never counted by the MAB
/// accounting oracle).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AdmissionConfig {
    /// Previous-interval waiting-queue depth at and above which every new
    /// arrival is shed.
    pub max_queue_depth: usize,
    /// Deadline-risk floor: shed a task when its SLA (in intervals) falls
    /// below `deadline_floor · (1 + queued)` — a short deadline that the
    /// current backlog makes unservable.
    pub deadline_floor: f64,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig { max_queue_depth: 64, deadline_floor: 0.25 }
    }
}

/// Per-task admission verdict.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmissionVerdict {
    Admit,
    ShedQueueDepth,
    ShedDeadlineRisk,
}

impl AdmissionConfig {
    pub fn verdict(&self, task: &Task, queued: usize) -> AdmissionVerdict {
        if queued >= self.max_queue_depth {
            return AdmissionVerdict::ShedQueueDepth;
        }
        if task.sla < self.deadline_floor * (1.0 + queued as f64) {
            return AdmissionVerdict::ShedDeadlineRisk;
        }
        AdmissionVerdict::Admit
    }
}

/// Autoscaling thresholds (queue depth relative to online capacity).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AutoscaleConfig {
    /// Unpark a worker when queued > queue_hi × online.
    pub queue_hi: f64,
    /// Park a worker when queued < queue_lo × online.
    pub queue_lo: f64,
    /// Never park below this many online workers.
    pub min_online: usize,
}

impl Default for AutoscaleConfig {
    fn default() -> Self {
        AutoscaleConfig { queue_hi: 2.0, queue_lo: 0.25, min_online: 4 }
    }
}

/// Worker park/unpark as a decision: at most one action per interval,
/// driven by the previous interval's queue depth against the live
/// availability surface. Emits `EngineCmd::{WorkerLeave,WorkerJoin}` —
/// the caller applies them via `Engine::apply_scaling` so every action is
/// ledger-audited with `CmdOrigin::Autoscale`.
pub struct Autoscaler {
    pub cfg: AutoscaleConfig,
    /// LIFO stack of workers this autoscaler parked (most recent last).
    parked: Vec<usize>,
}

impl Autoscaler {
    pub fn new(cfg: AutoscaleConfig) -> Self {
        Autoscaler { cfg, parked: Vec::new() }
    }

    pub fn parked(&self) -> &[usize] {
        &self.parked
    }

    /// Plan at most one scaling command for this interval. `queued` is the
    /// previous interval's waiting-queue depth; `online` is the engine's
    /// live availability slice (so chaos crashes are seen, not assumed);
    /// `offline_origin` is the engine's per-worker record of *who* took
    /// each offline worker down (`Engine::offline_origins`).
    ///
    /// The parked stack has set semantics (a worker chaos recovered and
    /// re-parked is moved, not duplicated), and scale-up only rejoins a
    /// worker whose offline state this autoscaler owns
    /// (`CmdOrigin::Autoscale`) — a stale entry for a worker that is now
    /// offline because chaos *crashed* it is spent, never silently
    /// resurrected as fresh capacity.
    pub fn plan(
        &mut self,
        queued: usize,
        online: &[bool],
        offline_origin: &[Option<CmdOrigin>],
    ) -> Option<EngineCmd> {
        let up = online.iter().filter(|&&o| o).count();
        if queued as f64 > self.cfg.queue_hi * up.max(1) as f64 {
            // scale up: unpark the most recently parked worker that is
            // still offline *because we parked it* (a chaos recover may
            // have beaten us to one, or a chaos crash may have replaced
            // our graceful park — such entries are spent and dropped)
            while let Some(w) = self.parked.pop() {
                let ours = offline_origin.get(w).copied().flatten()
                    == Some(CmdOrigin::Autoscale);
                if w < online.len() && !online[w] && ours {
                    return Some(EngineCmd::WorkerJoin { worker: w });
                }
            }
            return None;
        }
        if up > self.cfg.min_online && (queued as f64) < self.cfg.queue_lo * up as f64 {
            // scale down: park the highest-index online worker (graceful —
            // its containers are checkpointed and requeued by the engine)
            if let Some(w) = (0..online.len()).rev().find(|&w| online[w]) {
                // set semantics: if chaos recovered w and we park it again,
                // move the entry to the top instead of duplicating it
                self.parked.retain(|&p| p != w);
                self.parked.push(w);
                return Some(EngineCmd::WorkerLeave { worker: w });
            }
        }
        None
    }
}

/// Resolve a trace path: absolute paths and paths that exist relative to
/// the current directory are used as-is; anything else is resolved against
/// the crate root, so committed traces under `tests/traces/` load from any
/// working directory.
pub fn resolve_trace_path(p: &str) -> std::path::PathBuf {
    let path = std::path::PathBuf::from(p);
    if path.is_absolute() || path.exists() {
        return path;
    }
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join(p)
}

/// Generate a recordable arrival stream: `intervals` windows of the given
/// workload config under `shape`, exactly as the broker would see them
/// (generation, then λ shaping, then batch shaping). Used by
/// `splitplace trace record` and the record→replay round-trip property.
pub fn generate_trace(
    workload: &WorkloadConfig,
    shape: TrafficShape,
    intervals: usize,
    interval_seconds: f64,
) -> Vec<Task> {
    let model = shape.build(mix(workload.seed, TRAFFIC_STREAM_TAG));
    let mut generator = Generator::new(workload.clone());
    let mut out = Vec::new();
    for t in 0..intervals {
        let lambda = model.lambda_at(t, workload.lambda);
        let mut tasks = generator.arrivals_with(t as f64 * interval_seconds, lambda);
        model.shape_tasks(&mut tasks);
        out.extend(tasks);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stream(shape: TrafficShape, seed: u64, base: f64) -> Vec<f64> {
        let m = shape.build(seed);
        (0..48).map(|t| m.lambda_at(t, base)).collect()
    }

    #[test]
    fn shape_names_roundtrip() {
        for s in TrafficShape::all() {
            assert_eq!(TrafficShape::parse(s.name()), Some(s));
        }
        assert_eq!(TrafficShape::parse("poisson"), Some(TrafficShape::Flat));
        assert_eq!(TrafficShape::parse("nope"), None);
    }

    #[test]
    fn flat_is_identity_on_lambda() {
        assert!(stream(TrafficShape::Flat, 1, 6.0).iter().all(|&l| l == 6.0));
    }

    #[test]
    fn diurnal_oscillates_and_stays_nonnegative() {
        let s = stream(TrafficShape::Diurnal, 3, 6.0);
        let lo = s.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = s.iter().cloned().fold(0.0, f64::max);
        assert!(hi > 6.0 * 1.3, "peak {hi} too flat");
        assert!(lo < 6.0 * 0.7, "trough {lo} too flat");
        assert!(lo >= 0.0);
        // different seeds shift the phase
        assert_ne!(s, stream(TrafficShape::Diurnal, 4, 6.0));
    }

    #[test]
    fn mmpp_visits_both_regimes() {
        let s = stream(TrafficShape::Mmpp, 7, 5.0);
        assert!(s.iter().any(|&l| l == 5.0), "never quiet");
        assert!(s.iter().any(|&l| l > 5.0), "never surged");
    }

    #[test]
    fn mmpp_regime_is_order_independent() {
        let m = MmppBurst::new(11);
        // query out of order, then in order: same regimes
        let backwards: Vec<bool> = (0..30).rev().map(|t| m.surge_at(t)).collect();
        let forwards: Vec<bool> = (0..30).map(|t| m.surge_at(t)).collect();
        assert_eq!(backwards.into_iter().rev().collect::<Vec<_>>(), forwards);
    }

    #[test]
    fn models_are_deterministic_per_seed() {
        for shape in TrafficShape::all() {
            assert_eq!(
                stream(shape, 42, 6.0),
                stream(shape, 42, 6.0),
                "{} stream not replayable",
                shape.name()
            );
        }
    }

    #[test]
    fn heavy_tail_inflates_some_batches_and_rescales_sla() {
        let wl = WorkloadConfig { lambda: 8.0, ..Default::default() };
        let tasks = generate_trace(&wl, TrafficShape::HeavyTail, 10, 300.0);
        let flat = generate_trace(&wl, TrafficShape::Flat, 10, 300.0);
        assert_eq!(tasks.len(), flat.len(), "heavy-tail must not change arrival counts");
        let mut inflated = 0;
        for (h, f) in tasks.iter().zip(&flat) {
            assert!(h.batch >= f.batch);
            assert!(h.batch <= 256_000);
            if h.batch > f.batch {
                inflated += 1;
                let ratio = h.batch as f64 / f.batch as f64;
                assert!((h.sla / f.sla - ratio).abs() < 1e-9, "sla must scale with batch");
            }
        }
        assert!(inflated > 0, "no batch was ever inflated");
    }

    #[test]
    fn admission_sheds_on_depth_then_risk() {
        let cfg = AdmissionConfig { max_queue_depth: 10, deadline_floor: 0.5 };
        let task = |sla: f64| Task {
            id: 0,
            app: crate::splits::APPS[0],
            batch: 32_000,
            sla,
            arrival_s: 0.0,
            decision: None,
        };
        assert_eq!(cfg.verdict(&task(5.0), 0), AdmissionVerdict::Admit);
        assert_eq!(cfg.verdict(&task(5.0), 10), AdmissionVerdict::ShedQueueDepth);
        // sla 1.0 < 0.5 * (1 + 4) = 2.5 → deadline risk
        assert_eq!(cfg.verdict(&task(1.0), 4), AdmissionVerdict::ShedDeadlineRisk);
        assert_eq!(cfg.verdict(&task(3.0), 4), AdmissionVerdict::Admit);
    }

    /// Test double for the engine's availability surface: applies the
    /// autoscaler's own commands the way `Engine::apply_scaling` would,
    /// keeping `online` and `offline_origin` in lockstep.
    struct FleetView {
        online: Vec<bool>,
        origin: Vec<Option<CmdOrigin>>,
    }

    impl FleetView {
        fn new(n: usize) -> Self {
            FleetView { online: vec![true; n], origin: vec![None; n] }
        }

        fn apply(&mut self, cmd: &EngineCmd) {
            match *cmd {
                EngineCmd::WorkerLeave { worker } => {
                    self.online[worker] = false;
                    self.origin[worker] = Some(CmdOrigin::Autoscale);
                }
                EngineCmd::WorkerJoin { worker } => {
                    self.online[worker] = true;
                    self.origin[worker] = None;
                }
                _ => panic!("autoscaler planned a non-scaling command: {cmd:?}"),
            }
        }

        fn chaos_crash(&mut self, worker: usize) {
            self.online[worker] = false;
            self.origin[worker] = Some(CmdOrigin::Churn);
        }

        fn chaos_recover(&mut self, worker: usize) {
            self.online[worker] = true;
            self.origin[worker] = None;
        }
    }

    #[test]
    fn autoscaler_parks_and_unparks_lifo() {
        let mut a = Autoscaler::new(AutoscaleConfig {
            queue_hi: 2.0,
            queue_lo: 0.5,
            min_online: 2,
        });
        let mut fleet = FleetView::new(4);
        // idle → park highest-index worker
        match a.plan(0, &fleet.online, &fleet.origin) {
            Some(EngineCmd::WorkerLeave { worker }) => {
                assert_eq!(worker, 3);
                fleet.apply(&EngineCmd::WorkerLeave { worker });
            }
            other => panic!("expected leave, got {other:?}"),
        }
        match a.plan(0, &fleet.online, &fleet.origin) {
            Some(EngineCmd::WorkerLeave { worker }) => {
                assert_eq!(worker, 2);
                fleet.apply(&EngineCmd::WorkerLeave { worker });
            }
            other => panic!("expected leave, got {other:?}"),
        }
        // at min_online → no further parking
        assert!(a.plan(0, &fleet.online, &fleet.origin).is_none());
        assert_eq!(a.parked(), &[3, 2]);
        // surge → unpark most recently parked first
        match a.plan(100, &fleet.online, &fleet.origin) {
            Some(EngineCmd::WorkerJoin { worker }) => {
                assert_eq!(worker, 2);
                fleet.apply(&EngineCmd::WorkerJoin { worker });
            }
            other => panic!("expected join, got {other:?}"),
        }
        match a.plan(100, &fleet.online, &fleet.origin) {
            Some(EngineCmd::WorkerJoin { worker }) => assert_eq!(worker, 3),
            other => panic!("expected join, got {other:?}"),
        }
        // stack drained → surge plans nothing
        assert!(a.plan(100, &fleet.online, &fleet.origin).is_none());
    }

    #[test]
    fn autoscaler_skips_entries_chaos_already_recovered() {
        let mut a = Autoscaler::new(AutoscaleConfig::default());
        let mut fleet = FleetView::new(6);
        let w = match a.plan(0, &fleet.online, &fleet.origin) {
            Some(EngineCmd::WorkerLeave { worker }) => {
                fleet.apply(&EngineCmd::WorkerLeave { worker });
                worker
            }
            other => panic!("expected leave, got {other:?}"),
        };
        // chaos recovers the parked worker behind our back
        fleet.chaos_recover(w);
        // surge: the stale entry is spent; nothing to unpark
        assert!(a.plan(1000, &fleet.online, &fleet.origin).is_none());
        assert!(a.parked().is_empty());
    }

    /// Regression for the parked-stack staleness bug: park w → chaos
    /// recovers w → park w again must not duplicate the entry, and after
    /// chaos *crashes* w a surge must not `WorkerJoin` it — the offline
    /// state belongs to chaos, not to the autoscaler. The pre-fix `plan`
    /// pushed the duplicate and happily resurrected the crashed worker.
    #[test]
    fn autoscaler_never_rejoins_chaos_crashed_worker() {
        let mut a = Autoscaler::new(AutoscaleConfig {
            queue_hi: 2.0,
            queue_lo: 0.5,
            min_online: 2,
        });
        let mut fleet = FleetView::new(4);
        // 1. idle → park worker 3
        let cmd = a.plan(0, &fleet.online, &fleet.origin).expect("park");
        assert_eq!(cmd, EngineCmd::WorkerLeave { worker: 3 });
        fleet.apply(&cmd);
        // 2. chaos recovers worker 3 behind the autoscaler's back
        fleet.chaos_recover(3);
        // 3. still idle → parks worker 3 again; set semantics keep one entry
        let cmd = a.plan(0, &fleet.online, &fleet.origin).expect("re-park");
        assert_eq!(cmd, EngineCmd::WorkerLeave { worker: 3 });
        fleet.apply(&cmd);
        assert_eq!(a.parked(), &[3], "re-park must move, not duplicate");
        // 4. chaos recovers again, then *crashes* worker 3: it is offline,
        //    but the offline state is chaos-owned now
        fleet.chaos_recover(3);
        fleet.chaos_crash(3);
        // 5. surge: worker 3 is offline and on the stack, but its origin is
        //    not Autoscale — the entry is spent, no WorkerJoin is issued
        assert!(
            a.plan(100, &fleet.online, &fleet.origin).is_none(),
            "must not resurrect a chaos-crashed worker"
        );
        assert!(a.parked().is_empty(), "spent entries are dropped");
    }

    #[test]
    fn heavy_tail_zero_batch_keeps_sla_finite() {
        // a zero-batch task used to yield 0/0 → NaN SLA; now it passes
        // through untouched, and shaped batches are always ≥ 1
        let h = HeavyTailBatch::new(9);
        // scan task ids until we hit one that draws the giant branch, so
        // the guard (not just the p_giant miss) is what protects the task
        let mut shaped_giant = false;
        for id in 0..400 {
            let mut probe = [Task {
                id,
                app: crate::splits::APPS[0],
                batch: 1,
                sla: 2.0,
                arrival_s: 0.0,
                decision: None,
            }];
            h.shape_tasks(&mut probe);
            let giant = probe[0].batch > 1;
            let mut tasks = [Task {
                id,
                app: crate::splits::APPS[0],
                batch: 0,
                sla: 2.0,
                arrival_s: 0.0,
                decision: None,
            }];
            h.shape_tasks(&mut tasks);
            assert!(tasks[0].sla.is_finite(), "NaN SLA for zero-batch task {id}");
            assert_eq!(tasks[0].batch, 0, "zero batch must pass through unshaped");
            if giant {
                shaped_giant = true;
                assert_eq!(tasks[0].sla.to_bits(), 2.0_f64.to_bits());
            }
        }
        assert!(shaped_giant, "no probed id ever drew the giant branch");
    }

    #[test]
    fn mmpp_cache_matches_uncached_walk_byte_for_byte() {
        // the λ stream after memoization must equal an uncached
        // from-scratch walk, whatever order the cache was filled in
        let uncached: Vec<f64> = {
            let mut out = Vec::new();
            for t in 0..64 {
                let mut surge = false;
                for i in 0..=t {
                    let mut r = Rng::new(mix(21, mix(MMPP_TAG, i as u64)));
                    if surge {
                        if r.chance(0.5) {
                            surge = false;
                        }
                    } else if r.chance(0.15) {
                        surge = true;
                    }
                }
                out.push(if surge { 5.0 * 4.0 } else { 5.0 });
            }
            out
        };
        // fill the cache out of order: far query first, then scattered
        let m = MmppBurst::new(21);
        m.surge_at(40);
        m.surge_at(7);
        m.surge_at(63);
        let cached: Vec<f64> = (0..64).map(|t| m.lambda_at(t, 5.0)).collect();
        for (t, (a, b)) in uncached.iter().zip(&cached).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "λ stream diverged at t={t}");
        }
    }

    #[test]
    fn generate_trace_flat_matches_generator_stream() {
        // the flat shape must reproduce the raw generator stream exactly —
        // the guarantee that default-config cells stay byte-identical
        let wl = WorkloadConfig::default();
        let via_traffic = generate_trace(&wl, TrafficShape::Flat, 6, 300.0);
        let mut g = Generator::new(wl);
        let mut direct = Vec::new();
        for t in 0..6 {
            direct.extend(g.arrivals(t as f64 * 300.0));
        }
        assert_eq!(via_traffic.len(), direct.len());
        for (a, b) in via_traffic.iter().zip(&direct) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.batch, b.batch);
            assert_eq!(a.sla.to_bits(), b.sla.to_bits());
        }
    }
}
