//! ASCII table rendering for bench harness reports (offline substitute for
//! pretty-printer crates). Produces github-markdown-compatible tables.

/// Column-aligned table builder.
#[derive(Clone, Debug)]
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn rows(&self) -> &[Vec<String>] {
        &self.rows
    }

    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("## {}\n", self.title));
        }
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for i in 0..ncols {
                line.push_str(&format!(" {:<w$} |", cells[i], w = widths[i]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// Format a float to 4 significant decimals, trimming noise.
pub fn fnum(x: f64) -> String {
    if x == 0.0 {
        "0".into()
    } else if x.abs() >= 1000.0 {
        format!("{x:.0}")
    } else if x.abs() >= 10.0 {
        format!("{x:.2}")
    } else {
        format!("{x:.4}")
    }
}

/// Format mean±std.
pub fn fpm(mean: f64, std: f64) -> String {
    format!("{}±{}", fnum(mean), fnum(std))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("Demo", &["model", "reward"]);
        t.row(vec!["MAB+DASO".into(), "0.9418".into()]);
        t.row(vec!["Gillis".into(), "0.84".into()]);
        let r = t.render();
        assert!(r.contains("## Demo"));
        assert!(r.contains("| MAB+DASO | 0.9418 |"));
        assert!(r.contains("| Gillis   | 0.84   |"));
        // header separator present
        assert!(r.lines().nth(2).unwrap().starts_with("|-"));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn num_formatting() {
        assert_eq!(fnum(0.0), "0");
        assert_eq!(fnum(1234.6), "1235");
        assert_eq!(fnum(12.345), "12.35");
        assert_eq!(fnum(0.94183), "0.9418");
        assert!(fpm(1.0, 0.5).contains('±'));
    }
}
