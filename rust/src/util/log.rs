//! Tiny leveled logger (offline substitute for `log` + `env_logger`).
//!
//! Level comes from `SPLITPLACE_LOG` (`error|warn|info|debug|trace`),
//! defaulting to `info`. Messages go to stderr so bench tables on stdout
//! stay machine-readable.

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX); // MAX = uninitialized

fn init_level() -> u8 {
    let lvl = match std::env::var("SPLITPLACE_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Info,
    } as u8;
    LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

pub fn level() -> u8 {
    let l = LEVEL.load(Ordering::Relaxed);
    if l == u8::MAX {
        init_level()
    } else {
        l
    }
}

/// Override programmatically (used by tests/benches to silence output).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    (l as u8) <= level()
}

pub fn log(l: Level, module: &str, msg: std::fmt::Arguments) {
    if enabled(l) {
        let tag = match l {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{tag}] {module}: {msg}");
    }
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Debug, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Error, module_path!(), format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Trace);
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }
}
