//! Order-free deterministic float accumulation.
//!
//! Every float reduction on the engine's hot path used to be pinned to
//! container-id visit order: IEEE-754 addition is not associative, so
//! `a + b + c` and `a + c + b` can differ in the last bit, and a golden
//! recorded against one visit order breaks under any other. That ordering
//! constraint is exactly what blocked intra-interval parallelism.
//!
//! [`Accum`] breaks the dependence deliberately with **fixed-point**
//! accumulation (the `eu4sim-core` approach, rebuilt from first
//! principles): each `f64` term is scaled by 2^64 and added into an
//! `i128`. Integer addition is exact, commutative and associative, so
//!
//! * `sum(perm(xs)) == sum(xs)` **bit-for-bit**, for every permutation;
//! * per-worker shards of the active set can be reduced independently and
//!   [`Accum::merge`]d in any order with bit-identical results — the join
//!   operation behind `Engine::sub_step`'s rack-sharded parallelism.
//!
//! Chosen over compensated (Neumaier) summation because compensation
//! shrinks the error but keeps it order-dependent; only an exact
//! commutative representation gives the bit-for-bit permutation contract
//! the shard-vs-serial property is stated over.
//!
//! ## Precision and range
//!
//! A finite `f64` is `m × 2^e` with a 53-bit significand, so `x × 2^64`
//! is an *exact* integer whenever the value's ulp is ≥ 2^-64 — every
//! |x| ≥ 2^-11 (≈ 4.9e-4) converts losslessly; smaller magnitudes are
//! truncated at the 2^-64 quantum (absolute error < 5.5e-20 per term).
//! The accumulated sum is exact over those fixed-point terms and rounds
//! to `f64` exactly once on [`Accum::value`], which is *more* accurate
//! than sequential f64 addition, not less.
//!
//! Magnitude budget: the i128 holds sums up to 2^63 (≈ 9.2e18) in value
//! units — far past any engine quantity (resident MB, busy seconds,
//! watt-hours, reward terms). Additions use wrapping arithmetic, which
//! stays commutative/associative even at the (unreachable) boundary, so
//! the permutation contract never silently degrades into UB or panics on
//! the hot path. Non-finite terms follow Rust's saturating `as` cast
//! (NaN → 0); reductions that can legitimately see NaN (the response-time
//! EMA, which is order-*sensitive* by design) must not route through
//! here — debug builds assert finiteness.

/// Exponent of the fixed-point scale: terms are stored as `x × 2^64`.
const SCALE_BITS: u32 = 64;

/// Order-free fixed-point accumulator over `f64` terms.
///
/// ```text
/// let mut a = Accum::ZERO;
/// a.add(0.1); a.add(0.2); a.add(0.3);
/// // any permutation of the adds yields the same a.value() bits
/// ```
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Accum {
    raw: i128,
}

impl Accum {
    pub const ZERO: Accum = Accum { raw: 0 };

    /// Convert one term to fixed point. Multiplying a finite f64 by a
    /// power of two is exact (significand unchanged), and `as i128`
    /// truncates deterministically toward zero; the cast saturates at the
    /// i128 range and maps NaN to 0 (both documented Rust semantics).
    #[inline]
    fn to_fixed(x: f64) -> i128 {
        debug_assert!(x.is_finite(), "non-finite term {x} in an order-free reduction");
        (x * (SCALE_BITS as f64).exp2()) as i128
    }

    /// Add one term.
    #[inline]
    pub fn add(&mut self, x: f64) {
        self.raw = self.raw.wrapping_add(Self::to_fixed(x));
    }

    /// Subtract one term (exact inverse of [`Accum::add`] of the same
    /// value — incremental bookkeeping like resident-RAM deltas cannot
    /// drift the way f64 `+=`/`-=` pairs do).
    #[inline]
    pub fn sub(&mut self, x: f64) {
        self.raw = self.raw.wrapping_sub(Self::to_fixed(x));
    }

    /// Join another accumulator — the shard merge. Commutative and
    /// associative, so shards can land in any completion order.
    #[inline]
    pub fn merge(&mut self, other: Accum) {
        self.raw = self.raw.wrapping_add(other.raw);
    }

    /// Round the exact fixed-point sum to `f64` (one rounding, at the
    /// end).
    #[inline]
    pub fn value(&self) -> f64 {
        (self.raw as f64) * (-(SCALE_BITS as f64)).exp2()
    }

    /// The raw fixed-point payload, for bit-level assertions in tests.
    pub fn raw(&self) -> i128 {
        self.raw
    }
}

impl std::iter::FromIterator<f64> for Accum {
    fn from_iter<I: IntoIterator<Item = f64>>(iter: I) -> Accum {
        let mut a = Accum::ZERO;
        for x in iter {
            a.add(x);
        }
        a
    }
}

/// Order-free sum of an iterator of terms — the drop-in replacement for
/// `xs.iter().sum::<f64>()` on reductions that must be shard-mergeable.
pub fn sum<I: IntoIterator<Item = f64>>(xs: I) -> f64 {
    xs.into_iter().collect::<Accum>().value()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// The tentpole contract: summing any permutation yields the same
    /// bits. Exercised over adversarial magnitude spreads where naive f64
    /// summation is provably order-dependent.
    #[test]
    fn permutation_invariance_is_bit_exact() {
        let mut rng = Rng::new(0xACC);
        for round in 0..20 {
            let n = 50 + round * 13;
            let xs: Vec<f64> = (0..n)
                .map(|i| {
                    // mix tiny and large magnitudes: worst case for
                    // order-dependent rounding
                    let scale = [1e-3, 1.0, 1e3, 1e6][i % 4];
                    rng.range(-1.0, 1.0) * scale
                })
                .collect();
            let want = sum(xs.iter().copied());
            let mut perm = xs.clone();
            for _ in 0..5 {
                rng.shuffle(&mut perm);
                let got = sum(perm.iter().copied());
                assert_eq!(
                    want.to_bits(),
                    got.to_bits(),
                    "permuted sum drifted: {want} vs {got}"
                );
            }
            // naive f64 summation does NOT have this property on these
            // inputs — confirm the test would catch an accumulator that
            // secretly fell back to sequential adds
            let naive: f64 = xs.iter().sum();
            let naive_rev: f64 = xs.iter().rev().sum();
            if naive.to_bits() != naive_rev.to_bits() {
                return; // witnessed the order dependence at least once
            }
        }
        panic!("inputs never exposed f64 order dependence — strengthen the generator");
    }

    #[test]
    fn shard_merge_is_order_free() {
        let mut rng = Rng::new(0x5AA);
        let xs: Vec<f64> = (0..997).map(|_| rng.range(-1e4, 1e4)).collect();
        let serial: Accum = xs.iter().copied().collect();
        // split into uneven shards, merge in reversed and rotated orders
        let shards: Vec<Accum> = xs.chunks(101).map(|c| c.iter().copied().collect()).collect();
        for rotation in 0..shards.len() {
            let mut merged = Accum::ZERO;
            for i in 0..shards.len() {
                merged.merge(shards[(i + rotation) % shards.len()]);
            }
            assert_eq!(merged, serial, "shard merge must be bit-identical at rotation {rotation}");
            assert_eq!(merged.value().to_bits(), serial.value().to_bits());
        }
    }

    #[test]
    fn values_at_engine_magnitudes_convert_exactly() {
        // ram_mb, busy seconds, watt-hours, MI: all ≥ 2^-11, so the
        // fixed-point conversion is lossless and a singleton sum returns
        // the input bits unchanged
        for &x in &[8192.0, 0.05, 300.0, 1.5e9, 2.4e-3, -7.25] {
            let mut a = Accum::ZERO;
            a.add(x);
            assert_eq!(a.value().to_bits(), x.to_bits(), "{x} must round-trip exactly");
        }
    }

    #[test]
    fn add_sub_round_trips_incremental_bookkeeping() {
        let mut a = Accum::ZERO;
        let terms = [4096.5, 123.0625, 0.75, 9000.125];
        for &t in &terms {
            a.add(t);
        }
        for &t in &terms[1..] {
            a.sub(t);
        }
        // exactly the first term remains — no f64 +=/-= residue
        assert_eq!(a.value().to_bits(), terms[0].to_bits());
        a.sub(terms[0]);
        assert_eq!(a, Accum::ZERO);
    }

    #[test]
    fn sum_matches_exact_rational_result() {
        // 0.1 is inexact in binary; ten of them sum to exactly 1.0 only
        // under exact accumulation with a single final rounding
        let got = sum(std::iter::repeat(0.1).take(10));
        assert!((got - 1.0).abs() < 1e-15, "got {got}");
        // integers are exact at any count
        let got = sum((1..=1000).map(|i| i as f64));
        assert_eq!(got, 500_500.0);
    }
}
