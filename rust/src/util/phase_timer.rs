//! Per-phase interval profiler: wall-clock millisecond counters for the
//! five hot phases of a run (CPU integration, network/transfer walk,
//! decision plane, oracle sweep, traffic shaping + autoscaling).
//!
//! Designed to be **zero-cost when disabled**: [`PhaseTimer::start`]
//! returns `None` without ever calling `Instant::now()`, and
//! [`PhaseTimer::stop`] on `None` is a no-op — a disabled timer adds two
//! branch checks per phase, no clock reads, no allocation. Timing reads
//! never feed back into simulation state, so enabling the profiler
//! cannot perturb trajectories: goldens, signatures and parity files are
//! byte-identical with the profiler on or off.
//!
//! The start/stop token pattern (rather than a closure-wrapping `time(f)`)
//! keeps borrows simple at call sites that need `&mut self` inside the
//! timed region.

use std::time::Instant;

/// The five profiled phases of one scheduling interval.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Fair-share CPU integration (the sharded phase of `sub_step`).
    Cpu,
    /// Transfer/migration walk + chain unblocking (payload movement).
    Network,
    /// Admission verdicts, split decisions + placement (the policy
    /// stack's share; admission rides here because the broker interleaves
    /// the verdict with the decision per task).
    Decision,
    /// The chaos oracle sweep (`check_interval`), zero outside chaos runs.
    Oracle,
    /// Arrival generation/shaping + autoscaling.
    Traffic,
}

/// All phases, in the order their counters are laid out.
pub const PHASES: [Phase; 5] =
    [Phase::Cpu, Phase::Network, Phase::Decision, Phase::Oracle, Phase::Traffic];

impl Phase {
    fn idx(self) -> usize {
        match self {
            Phase::Cpu => 0,
            Phase::Network => 1,
            Phase::Decision => 2,
            Phase::Oracle => 3,
            Phase::Traffic => 4,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Phase::Cpu => "cpu",
            Phase::Network => "network",
            Phase::Decision => "decision",
            Phase::Oracle => "oracle",
            Phase::Traffic => "traffic",
        }
    }
}

/// Accumulated wall-clock milliseconds per phase.
#[derive(Clone, Debug, Default)]
pub struct PhaseTimer {
    enabled: bool,
    ms: [f64; 5],
}

impl PhaseTimer {
    pub fn new(enabled: bool) -> Self {
        PhaseTimer { enabled, ms: [0.0; 5] }
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Begin timing a phase. `None` when disabled — no clock read happens.
    #[inline]
    pub fn start(&self) -> Option<Instant> {
        if self.enabled {
            Some(Instant::now())
        } else {
            None
        }
    }

    /// Credit the elapsed time since `start` to `phase`; no-op on `None`.
    #[inline]
    pub fn stop(&mut self, phase: Phase, started: Option<Instant>) {
        if let Some(t0) = started {
            self.ms[phase.idx()] += t0.elapsed().as_secs_f64() * 1e3;
        }
    }

    pub fn ms(&self, phase: Phase) -> f64 {
        self.ms[phase.idx()]
    }

    /// Copy the counters into a plain value (for bench records).
    pub fn snapshot(&self) -> PhaseBreakdown {
        PhaseBreakdown {
            cpu_ms: self.ms(Phase::Cpu),
            network_ms: self.ms(Phase::Network),
            decision_ms: self.ms(Phase::Decision),
            oracle_ms: self.ms(Phase::Oracle),
            traffic_ms: self.ms(Phase::Traffic),
        }
    }
}

/// Flat per-phase breakdown, in milliseconds. Informational only: the
/// perf gate never bands these (wall-clock phase splits are the noisiest
/// numbers a CI box produces), they exist so a measured
/// `BENCH_engine.json` can say exactly where each interval's time went.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PhaseBreakdown {
    pub cpu_ms: f64,
    pub network_ms: f64,
    pub decision_ms: f64,
    pub oracle_ms: f64,
    pub traffic_ms: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_timer_reads_no_clock_and_stays_zero() {
        let mut t = PhaseTimer::new(false);
        let tok = t.start();
        assert!(tok.is_none(), "disabled start must not touch the clock");
        t.stop(Phase::Cpu, tok);
        for p in PHASES {
            assert_eq!(t.ms(p), 0.0);
        }
        assert_eq!(t.snapshot(), PhaseBreakdown::default());
    }

    #[test]
    fn enabled_timer_accumulates_per_phase() {
        let mut t = PhaseTimer::new(true);
        let tok = t.start();
        assert!(tok.is_some());
        std::thread::sleep(std::time::Duration::from_millis(2));
        t.stop(Phase::Oracle, tok);
        assert!(t.ms(Phase::Oracle) > 0.0);
        assert_eq!(t.ms(Phase::Cpu), 0.0, "other phases untouched");
        // second measurement adds, never resets
        let before = t.ms(Phase::Oracle);
        let tok = t.start();
        t.stop(Phase::Oracle, tok);
        assert!(t.ms(Phase::Oracle) >= before);
        let snap = t.snapshot();
        assert_eq!(snap.oracle_ms, t.ms(Phase::Oracle));
    }

    #[test]
    fn default_is_disabled() {
        let t = PhaseTimer::default();
        assert!(!t.enabled());
        assert!(t.start().is_none());
    }

    #[test]
    fn phase_names_are_stable_bench_schema() {
        // these strings become BENCH_engine.json field prefixes — renaming
        // one is a schema change, not a refactor
        let names: Vec<&str> = PHASES.iter().map(|p| p.name()).collect();
        assert_eq!(names, ["cpu", "network", "decision", "oracle", "traffic"]);
    }
}
