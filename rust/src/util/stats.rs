//! Statistics helpers: running moments, percentiles, EMA, Jain fairness.
//!
//! The slice reductions ([`mean`], [`std`], [`jain_fairness`]) run their
//! sums through the order-free [`crate::util::accum::Accum`], so callers
//! that assemble their inputs from parallel shards get bit-identical
//! results regardless of merge order. [`Welford`] and [`Ema`] stay
//! sequential on purpose — they are order-*sensitive* recurrences.

use super::accum;

/// Welford online mean/variance accumulator.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Exponential moving average with multiplier `phi` for the newest sample
/// (paper eq. 2: `R <- phi * r + (1 - phi) * R`).
#[derive(Clone, Copy, Debug)]
pub struct Ema {
    phi: f64,
    value: Option<f64>,
}

impl Ema {
    pub fn new(phi: f64) -> Self {
        assert!((0.0..=1.0).contains(&phi));
        Ema { phi, value: None }
    }

    pub fn with_initial(phi: f64, init: f64) -> Self {
        Ema { phi, value: Some(init) }
    }

    pub fn push(&mut self, x: f64) {
        self.value = Some(match self.value {
            None => x,
            Some(v) => self.phi * x + (1.0 - self.phi) * v,
        });
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }

    pub fn get_or(&self, default: f64) -> f64 {
        self.value.unwrap_or(default)
    }
}

/// Percentile with linear interpolation (q in [0, 100]); sorts a copy.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = (q / 100.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        accum::sum(xs.iter().copied()) / xs.len() as f64
    }
}

pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (accum::sum(xs.iter().map(|x| (x - m) * (x - m))) / (xs.len() - 1) as f64).sqrt()
}

/// Jain's fairness index: `(sum x)^2 / (n * sum x^2)`; 1.0 = perfectly fair.
pub fn jain_fairness(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 1.0;
    }
    let s = accum::sum(xs.iter().copied());
    let s2 = accum::sum(xs.iter().map(|x| x * x));
    if s2 == 0.0 {
        1.0
    } else {
        s * s / (xs.len() as f64 * s2)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_closed_form() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut w = Welford::new();
        for x in xs {
            w.push(x);
        }
        assert!((w.mean() - 5.0).abs() < 1e-12);
        assert!((w.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(w.count(), 8);
    }

    #[test]
    fn welford_empty() {
        let w = Welford::new();
        assert_eq!(w.mean(), 0.0);
        assert_eq!(w.variance(), 0.0);
    }

    #[test]
    fn ema_paper_update_rule() {
        // R <- phi*r + (1-phi)*R with phi=0.9
        let mut e = Ema::with_initial(0.9, 10.0);
        e.push(20.0);
        assert!((e.get().unwrap() - (0.9 * 20.0 + 0.1 * 10.0)).abs() < 1e-12);
    }

    #[test]
    fn ema_first_sample_initializes() {
        let mut e = Ema::new(0.5);
        assert_eq!(e.get(), None);
        e.push(3.0);
        assert_eq!(e.get(), Some(3.0));
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 25.0), 2.0);
    }

    #[test]
    fn fairness_bounds() {
        assert_eq!(jain_fairness(&[5.0, 5.0, 5.0]), 1.0);
        let skewed = jain_fairness(&[1.0, 0.0, 0.0, 0.0]);
        assert!((skewed - 0.25).abs() < 1e-12);
        assert_eq!(jain_fairness(&[]), 1.0);
        assert_eq!(jain_fairness(&[0.0, 0.0]), 1.0);
    }

    #[test]
    fn mean_std() {
        assert_eq!(mean(&[]), 0.0);
        assert!((std(&[2.0, 4.0]) - std(&[4.0, 2.0])).abs() < 1e-12);
    }

    #[test]
    fn slice_reductions_are_order_free_bit_for_bit() {
        let xs = [1e6, 1e-3, -7.25, 300.0, 0.1, 8192.0, 2.4e-3];
        let rev: Vec<f64> = xs.iter().rev().copied().collect();
        assert_eq!(mean(&xs).to_bits(), mean(&rev).to_bits());
        assert_eq!(std(&xs).to_bits(), std(&rev).to_bits());
        assert_eq!(jain_fairness(&xs).to_bits(), jain_fairness(&rev).to_bits());
    }
}
