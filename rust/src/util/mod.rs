//! Self-built substrates: PRNG, JSON, statistics, tables, logging.
//!
//! The build host is fully offline and its crate cache only contains the
//! `xla` closure, so the usual `rand`/`serde`/`log` dependencies are
//! re-implemented here (see DESIGN.md §8).

pub mod accum;
pub mod json;
pub mod log;
pub mod phase_timer;
pub mod rng;
pub mod stats;
pub mod table;
