//! Deterministic PRNG + distribution sampling (offline substitute for the
//! `rand` crate).
//!
//! Core generator is xoshiro256++ seeded through SplitMix64; distributions
//! cover everything the workload generator and mobility model need:
//! uniform, normal (polar Box–Muller), Poisson (Knuth / PTRS), exponential.

/// xoshiro256++ PRNG. Fast, 256-bit state, passes BigCrush.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal variate from Box–Muller
    spare_normal: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Mix two u64s into a well-distributed derived seed (SplitMix64
/// finalizer). The matrix harness derives every cell's isolated RNG
/// stream as `mix(base_seed, stream_tag)`, so cells executing on
/// different worker threads never share generator state and a parallel
/// run is bit-identical to a serial one.
pub fn mix(a: u64, b: u64) -> u64 {
    let mut state = a ^ b.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    splitmix64(&mut state)
}

impl Rng {
    /// Seed deterministically: equal seeds yield equal streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent child stream (for per-worker / per-task rngs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). Debiased via rejection.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn int_range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via polar Box–Muller (caches the spare variate).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare_normal.take() {
            return v;
        }
        loop {
            let u = 2.0 * self.f64() - 1.0;
            let v = 2.0 * self.f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let f = (-2.0 * s.ln() / s).sqrt();
                self.spare_normal = Some(v * f);
                return u * f;
            }
        }
    }

    /// Normal with given mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential with rate `lambda`.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        -(1.0 - self.f64()).ln() / lambda
    }

    /// Poisson sample. Knuth's method for small means, PTRS-style
    /// normal-approximation w/ rejection fallback for large means.
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        assert!(lambda >= 0.0);
        if lambda == 0.0 {
            return 0;
        }
        if lambda < 30.0 {
            // Knuth
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        }
        // Normal approximation with continuity correction, clamped at 0;
        // adequate for lambda >= 30 at the fidelity the workload needs.
        let v = self.normal_ms(lambda, lambda.sqrt()).round();
        if v < 0.0 {
            0
        } else {
            v as u64
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Uniform choice from a non-empty slice.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn poisson_small_mean() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let lam = 6.0;
        let total: u64 = (0..n).map(|_| r.poisson(lam)).sum();
        let mean = total as f64 / n as f64;
        assert!((mean - lam).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn poisson_large_mean() {
        let mut r = Rng::new(17);
        let n = 20_000;
        let lam = 50.0;
        let total: u64 = (0..n).map(|_| r.poisson(lam)).sum();
        let mean = total as f64 / n as f64;
        assert!((mean - lam).abs() < 0.5, "mean={mean}");
    }

    #[test]
    fn poisson_zero() {
        let mut r = Rng::new(19);
        assert_eq!(r.poisson(0.0), 0);
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(23);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(29);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(31);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn mix_is_deterministic_and_spreads_streams() {
        assert_eq!(mix(7, 3), mix(7, 3));
        // nearby stream tags land far apart — no accidental correlation
        let mut seen = std::collections::HashSet::new();
        for tag in 0..1000u64 {
            assert!(seen.insert(mix(42, tag)), "collision at tag {tag}");
        }
        assert_ne!(mix(1, 0), mix(2, 0));
    }

    #[test]
    fn int_range_bounds() {
        let mut r = Rng::new(37);
        for _ in 0..1000 {
            let v = r.int_range(-5, 5);
            assert!((-5..=5).contains(&v));
        }
    }
}
