//! Minimal JSON parser / serializer (offline substitute for `serde_json`).
//!
//! Supports the full JSON grammar; numbers are kept as f64 (adequate for
//! manifests and configs). Object key order is preserved on round-trip.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    /// Keys kept in insertion order for stable serialization.
    Obj(Vec<(String, Value)>),
}

#[derive(Debug, thiserror::Error)]
pub enum JsonError {
    #[error("json parse error at byte {0}: {1}")]
    Parse(usize, String),
    #[error("type error: expected {0}")]
    Type(&'static str),
    #[error("missing key: {0}")]
    Missing(String),
}

impl Value {
    // -- accessors ---------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Value, JsonError> {
        self.get(key).ok_or_else(|| JsonError::Missing(key.into()))
    }

    pub fn as_f64(&self) -> Result<f64, JsonError> {
        match self {
            Value::Num(n) => Ok(*n),
            _ => Err(JsonError::Type("number")),
        }
    }

    pub fn as_usize(&self) -> Result<usize, JsonError> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_str(&self) -> Result<&str, JsonError> {
        match self {
            Value::Str(s) => Ok(s),
            _ => Err(JsonError::Type("string")),
        }
    }

    pub fn as_bool(&self) -> Result<bool, JsonError> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => Err(JsonError::Type("bool")),
        }
    }

    pub fn as_arr(&self) -> Result<&[Value], JsonError> {
        match self {
            Value::Arr(a) => Ok(a),
            _ => Err(JsonError::Type("array")),
        }
    }

    pub fn as_obj(&self) -> Result<&[(String, Value)], JsonError> {
        match self {
            Value::Obj(o) => Ok(o),
            _ => Err(JsonError::Type("object")),
        }
    }

    /// Convenience: `v["a"]["b"]` style path lookup.
    pub fn path(&self, keys: &[&str]) -> Option<&Value> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    // -- constructors ------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn from_map(map: &BTreeMap<String, f64>) -> Value {
        Value::Obj(map.iter().map(|(k, v)| (k.clone(), Value::Num(*v))).collect())
    }

    pub fn num_arr(xs: &[f64]) -> Value {
        Value::Arr(xs.iter().map(|x| Value::Num(*x)).collect())
    }

    pub fn str_arr(xs: &[&str]) -> Value {
        Value::Arr(xs.iter().map(|x| Value::Str(x.to_string())).collect())
    }

    // -- serialization -----------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Value::Str(s) => write_escaped(out, s),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Value::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

// ---------------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------------

/// Parse a JSON document.
pub fn parse(input: &str) -> Result<Value, JsonError> {
    let mut p = Parser { b: input.as_bytes(), i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(JsonError::Parse(p.i, "trailing characters".into()));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn err<T>(&self, msg: &str) -> Result<T, JsonError> {
        Err(JsonError::Parse(self.i, msg.into()))
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            self.err(&format!("expected '{}'", c as char))
        }
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            self.err(&format!("expected '{s}'"))
        }
    }

    fn value(&mut self) -> Result<Value, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => self.err("expected value"),
        }
    }

    fn object(&mut self) -> Result<Value, JsonError> {
        self.expect(b'{')?;
        let mut kv = Vec::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(kv));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            kv.push((k, v));
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(kv));
                }
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }

    fn array(&mut self) -> Result<Value, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(a));
                }
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return self.err("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return self.err("bad \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| JsonError::Parse(self.i, "bad \\u".into()))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| JsonError::Parse(self.i, "bad \\u".into()))?;
                            // Surrogate pairs unsupported; BMP is enough here.
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return self.err("bad escape"),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full UTF-8 char
                    let rest = &self.b[self.i..];
                    let text = std::str::from_utf8(rest)
                        .map_err(|_| JsonError::Parse(self.i, "invalid utf8".into()))?;
                    let c = text.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| JsonError::Parse(start, format!("bad number '{text}'")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("-1.5e2").unwrap(), Value::Num(-150.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.path(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"splitplace","n":42,"xs":[1,2.5,-3],"ok":true,"none":null}"#;
        let v = parse(src).unwrap();
        let v2 = parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn roundtrip_pretty() {
        let v = parse(r#"{"a":{"b":[1,2]},"c":"s"}"#).unwrap();
        assert_eq!(parse(&v.to_pretty()).unwrap(), v);
    }

    #[test]
    fn escapes() {
        let v = parse(r#""a\nb\t\"q\" \\ A""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"q\" \\ A");
        let v2 = parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse("\"héllo ☃\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo ☃");
    }

    #[test]
    fn errors() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn key_order_preserved() {
        let v = parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        let keys: Vec<_> = v.as_obj().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
    }

    #[test]
    fn missing_and_type_errors() {
        let v = parse(r#"{"a":1}"#).unwrap();
        assert!(v.req("b").is_err());
        assert!(v.get("a").unwrap().as_str().is_err());
        assert_eq!(v.get("a").unwrap().as_usize().unwrap(), 1);
    }

    #[test]
    fn big_manifest_like() {
        let src = r#"{
          "version": 1,
          "apps": {"mnist": {"layer": [{"hlo": "a.txt", "in_dim": 784}]}},
          "surrogates": {"h10_m16": {"param_shapes": [[296, 512], [512]]}}
        }"#;
        let v = parse(src).unwrap();
        assert_eq!(
            v.path(&["apps", "mnist", "layer"]).unwrap().as_arr().unwrap()[0]
                .get("in_dim")
                .unwrap()
                .as_usize()
                .unwrap(),
            784
        );
    }
}
