//! Property-testing driver (offline substitute for proptest): seeded case
//! generation with failure reporting. No shrinking — failures print the
//! case seed so they can be replayed deterministically.

use crate::util::rng::Rng;

/// Run `cases` property checks. `gen` builds a case from a seeded RNG,
/// `prop` returns Err(description) on violation. Panics with the seed of
/// the first failing case.
pub fn check<T, G, P>(name: &str, cases: usize, mut gen: G, mut prop: P)
where
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = 0x5EED_0000 + case as u64;
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!("property '{name}' failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Assert helper returning Result for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err(format!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check("add-commutes", 50, |r| (r.below(100), r.below(100)), |(a, b)| {
            if a + b == b + a {
                Ok(())
            } else {
                Err("math broke".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn failing_property_panics_with_seed() {
        check("always-fails", 5, |r| r.below(10), |_| Err("nope".into()));
    }
}
