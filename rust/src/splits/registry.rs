//! Per-app fragment profiles: compute (MI), memory, image sizes and
//! intermediate-payload sizes for every split strategy.
//!
//! Calibration (DESIGN.md §3): worker MIPS and 300 s intervals from Table 3
//! put a layer chain at ~5–9 intervals and a semantic fan-out at ~2–4 for
//! the paper's 16k–64k batches under typical contention, matching Fig. 2's
//! response-time ladder. Image sizes are the paper's (§6.2: 8–14 MB MNIST,
//! 34–56 MB FashionMNIST, 47–76 MB CIFAR100 per fragment).

use super::SplitDecision;

/// One of the three applications (task types).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum App {
    Mnist = 0,
    FashionMnist = 1,
    Cifar100 = 2,
}

pub const APPS: [App; 3] = [App::Mnist, App::FashionMnist, App::Cifar100];

impl App {
    pub fn name(&self) -> &'static str {
        match self {
            App::Mnist => "mnist",
            App::FashionMnist => "fashionmnist",
            App::Cifar100 => "cifar100",
        }
    }

    pub fn from_name(s: &str) -> Option<App> {
        Some(match s {
            "mnist" => App::Mnist,
            "fashionmnist" => App::FashionMnist,
            "cifar100" => App::Cifar100,
            _ => return None,
        })
    }

    pub fn index(&self) -> usize {
        *self as usize
    }

    pub fn input_dim(&self) -> usize {
        match self {
            App::Cifar100 => 1024,
            _ => 784,
        }
    }

    pub fn classes(&self) -> usize {
        match self {
            App::Cifar100 => 100,
            _ => 10,
        }
    }

    /// Relative compute weight (CIFAR100 is the paper's "resource hungry"
    /// app; Appendix A.3).
    pub fn mi_scale(&self) -> f64 {
        match self {
            App::Mnist => 1.0,
            App::FashionMnist => 1.25,
            App::Cifar100 => 2.2,
        }
    }

    pub fn semantic_groups(&self) -> usize {
        match self {
            App::Cifar100 => 4,
            _ => 2,
        }
    }

    /// Nominal layer-split response time (scheduling intervals) under
    /// typical load — the reference the SLA sampler scales (§6.2 uses
    /// Gillis' deadlines; this plays that role). Derived from calibration
    /// runs of the simulator.
    pub fn nominal_layer_rt(&self) -> f64 {
        match self {
            App::Mnist => 5.0,
            App::FashionMnist => 6.0,
            App::Cifar100 => 9.5,
        }
    }
}

/// Precedence structure of a split plan.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Precedence {
    /// Fragments form a linear chain; fragment k+1 may only start after k
    /// completes and its output is transferred (paper §3.2 constraint 2).
    Chain,
    /// Fragments run in parallel; the task completes when ALL finish
    /// (straggler-bound) and outputs are merged at the broker.
    Parallel,
}

/// Resource profile of one deployable fragment.
#[derive(Clone, Debug)]
pub struct FragmentProfile {
    /// Artifact key (`<app>_<kind><idx>`), resolves via the manifest.
    pub artifact: String,
    /// Compute demand: million instructions per 1000 batch samples.
    pub mi_per_ksample: f64,
    /// Resident memory independent of batch (params, runtime).
    pub ram_fixed_mb: f64,
    /// Activation memory per 1000 samples.
    pub ram_per_ksample_mb: f64,
    /// Docker-image size (one-time broadcast cost).
    pub image_mb: f64,
    /// Output payload per 1000 samples (intermediate forward / result).
    pub out_mb_per_ksample: f64,
}

/// A realized split plan for (app, decision).
#[derive(Clone, Debug)]
pub struct SplitPlan {
    pub app: App,
    pub decision: SplitDecision,
    pub precedence: Precedence,
    pub fragments: Vec<FragmentProfile>,
    /// Input payload per 1000 samples that must reach EVERY fragment at
    /// start (semantic broadcast) or the FIRST fragment (chain).
    pub input_mb_per_ksample: f64,
}

impl SplitPlan {
    pub fn total_image_mb(&self) -> f64 {
        self.fragments.iter().map(|f| f.image_mb).sum()
    }

    pub fn total_mi(&self, batch: u64) -> f64 {
        let k = batch as f64 / 1000.0;
        self.fragments.iter().map(|f| f.mi_per_ksample * k).sum()
    }
}

/// Static registry of split plans.
pub struct Registry;

impl Registry {
    /// Build the plan for a given app and decision.
    pub fn plan(app: App, decision: SplitDecision) -> SplitPlan {
        let s = app.mi_scale();
        let input_mb_per_ksample = app.input_dim() as f64 * 4.0 / 1000.0; // f32 rows
        let (image_lo, image_hi) = match app {
            App::Mnist => (8.0, 14.0),
            App::FashionMnist => (34.0, 56.0),
            App::Cifar100 => (47.0, 76.0),
        };
        match decision {
            SplitDecision::Layer => {
                // 3 sequential layer groups; the first is the widest
                // (input×hidden matmul dominates), the last the narrowest.
                let weights = [0.45, 0.35, 0.20];
                let out_dims = match app {
                    App::Cifar100 => [512.0, 256.0, 100.0],
                    _ => [256.0, 128.0, 10.0],
                };
                let fragments = (0..3)
                    .map(|i| FragmentProfile {
                        artifact: format!("{}_layer{}", app.name(), i),
                        mi_per_ksample: 36_000.0 * s * 3.0 * weights[i],
                        ram_fixed_mb: 120.0 * s,
                        ram_per_ksample_mb: 8.0 * s * weights[i] / 0.45,
                        image_mb: image_lo + (image_hi - image_lo) * (1.0 - i as f64 / 2.0),
                        out_mb_per_ksample: out_dims[i] * 4.0 / 1000.0,
                    })
                    .collect();
                SplitPlan {
                    app,
                    decision,
                    precedence: Precedence::Chain,
                    fragments,
                    input_mb_per_ksample,
                }
            }
            SplitDecision::Semantic => {
                let g = app.semantic_groups();
                // Each subnet is ~1/g the width but full depth; parallel
                // wall-clock is roughly half a layer chain per fragment.
                let fragments = (0..g)
                    .map(|i| FragmentProfile {
                        artifact: format!("{}_sem{}", app.name(), i),
                        mi_per_ksample: 60_000.0 * s / g as f64 * 1.4,
                        ram_fixed_mb: 80.0 * s,
                        ram_per_ksample_mb: 5.0 * s,
                        image_mb: image_lo + (image_hi - image_lo) * (i as f64 / g as f64),
                        out_mb_per_ksample: app.classes() as f64 / g as f64 * 4.0 / 1000.0,
                    })
                    .collect();
                SplitPlan {
                    app,
                    decision,
                    precedence: Precedence::Parallel,
                    fragments,
                    input_mb_per_ksample,
                }
            }
            SplitDecision::Compressed => SplitPlan {
                app,
                decision,
                precedence: Precedence::Chain,
                fragments: vec![FragmentProfile {
                    artifact: format!("{}_comp", app.name()),
                    mi_per_ksample: 120_000.0 * s,
                    ram_fixed_mb: 90.0 * s,
                    ram_per_ksample_mb: 9.0 * s,
                    image_mb: image_lo * 0.8,
                    out_mb_per_ksample: app.classes() as f64 * 4.0 / 1000.0,
                }],
                input_mb_per_ksample,
            },
            SplitDecision::Full => SplitPlan {
                app,
                decision,
                precedence: Precedence::Chain,
                fragments: vec![FragmentProfile {
                    artifact: format!("{}_full", app.name()),
                    mi_per_ksample: 180_000.0 * s,
                    ram_fixed_mb: 320.0 * s,
                    ram_per_ksample_mb: 14.0 * s,
                    image_mb: image_hi * 1.5,
                    out_mb_per_ksample: app.classes() as f64 * 4.0 / 1000.0,
                }],
                input_mb_per_ksample,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_plan_is_chain_of_three() {
        for app in APPS {
            let p = Registry::plan(app, SplitDecision::Layer);
            assert_eq!(p.precedence, Precedence::Chain);
            assert_eq!(p.fragments.len(), 3);
            assert!(p.fragments[0].mi_per_ksample > p.fragments[2].mi_per_ksample);
        }
    }

    #[test]
    fn semantic_plan_is_parallel_groups() {
        let p = Registry::plan(App::Cifar100, SplitDecision::Semantic);
        assert_eq!(p.precedence, Precedence::Parallel);
        assert_eq!(p.fragments.len(), 4);
        let p2 = Registry::plan(App::Mnist, SplitDecision::Semantic);
        assert_eq!(p2.fragments.len(), 2);
    }

    #[test]
    fn semantic_total_compute_comparable_but_parallel() {
        // total semantic MI stays within 1.2× of layer MI (the asserted
        // bound below), but per-fragment (= critical path) it is much
        // smaller.
        for app in APPS {
            let l = Registry::plan(app, SplitDecision::Layer);
            let s = Registry::plan(app, SplitDecision::Semantic);
            let l_total = l.total_mi(40_000);
            let s_total = s.total_mi(40_000);
            assert!(s_total < 1.2 * l_total, "{app:?}");
            let l_crit = l_total; // chain: sum
            let s_crit = s.fragments[0].mi_per_ksample * 40.0; // parallel: max
            assert!(
                s_crit < 0.4 * l_crit,
                "{app:?}: semantic critical path must be much shorter"
            );
        }
    }

    #[test]
    fn cifar_is_heaviest() {
        let m = Registry::plan(App::Mnist, SplitDecision::Layer).total_mi(40_000);
        let c = Registry::plan(App::Cifar100, SplitDecision::Layer).total_mi(40_000);
        assert!(c > 2.0 * m);
    }

    #[test]
    fn image_sizes_match_paper_ranges() {
        let p = Registry::plan(App::Mnist, SplitDecision::Layer);
        for f in &p.fragments {
            assert!((8.0..=14.0).contains(&f.image_mb), "{}", f.image_mb);
        }
        let p = Registry::plan(App::Cifar100, SplitDecision::Semantic);
        for f in &p.fragments {
            assert!((47.0..=76.0).contains(&f.image_mb), "{}", f.image_mb);
        }
    }

    #[test]
    fn artifact_names_match_manifest_convention() {
        assert_eq!(
            Registry::plan(App::Mnist, SplitDecision::Layer).fragments[0].artifact,
            "mnist_layer0"
        );
        assert_eq!(
            Registry::plan(App::Cifar100, SplitDecision::Semantic).fragments[3].artifact,
            "cifar100_sem3"
        );
        assert_eq!(
            Registry::plan(App::FashionMnist, SplitDecision::Compressed).fragments[0].artifact,
            "fashionmnist_comp"
        );
    }

    #[test]
    fn chain_dims_shrink_payloads() {
        let p = Registry::plan(App::Mnist, SplitDecision::Layer);
        assert!(p.fragments[0].out_mb_per_ksample > p.fragments[2].out_mb_per_ksample);
        // last fragment emits class logits only
        assert!((p.fragments[2].out_mb_per_ksample - 10.0 * 4.0 / 1000.0).abs() < 1e-12);
    }

    #[test]
    fn compressed_lighter_than_full() {
        for app in APPS {
            let c = Registry::plan(app, SplitDecision::Compressed);
            let f = Registry::plan(app, SplitDecision::Full);
            assert!(c.total_mi(40_000) < f.total_mi(40_000));
            assert!(c.fragments[0].ram_fixed_mb < f.fragments[0].ram_fixed_mb);
        }
    }

    #[test]
    fn app_helpers() {
        assert_eq!(App::from_name("mnist"), Some(App::Mnist));
        assert_eq!(App::from_name("bogus"), None);
        assert_eq!(App::Cifar100.input_dim(), 1024);
        assert_eq!(App::Mnist.classes(), 10);
        for (i, a) in APPS.iter().enumerate() {
            assert_eq!(a.index(), i);
        }
    }
}
