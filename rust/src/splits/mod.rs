//! Split-strategy space: layer chains, semantic groups, compressed and full
//! variants, with per-fragment resource profiles used by the simulator and
//! artifact names used by the PJRT runtime.

pub mod registry;

pub use registry::{App, FragmentProfile, Precedence, Registry, SplitPlan, APPS};

/// The broker's per-task split decision (paper: d^i ∈ {L, S}; the baselines
/// extend the space with compression and unsplit execution).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SplitDecision {
    /// Sequential layer groups (high accuracy, high response time).
    Layer,
    /// Parallel semantic class-group subnets (lower accuracy, fast).
    Semantic,
    /// Single pruned model (MC baseline).
    Compressed,
    /// Unsplit full model (cloud baseline, Fig. 18).
    Full,
}

impl SplitDecision {
    pub fn name(&self) -> &'static str {
        match self {
            SplitDecision::Layer => "layer",
            SplitDecision::Semantic => "semantic",
            SplitDecision::Compressed => "compressed",
            SplitDecision::Full => "full",
        }
    }

    /// The MAB's two arms (paper: d ∈ {L, S}).
    pub const ARMS: [SplitDecision; 2] = [SplitDecision::Layer, SplitDecision::Semantic];

    pub fn arm_index(&self) -> usize {
        match self {
            SplitDecision::Layer => 0,
            SplitDecision::Semantic => 1,
            _ => panic!("{self:?} is not a MAB arm"),
        }
    }
}
