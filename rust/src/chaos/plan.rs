//! Seeded, serializable fault plans.
//!
//! A [`FaultPlan`] is a per-interval schedule of [`ChaosEvent`]s generated
//! deterministically from a seed and a [`Profile`]. Plans round-trip
//! through JSON so a failing run can be reproduced (and shrunk) from the
//! printed `seed + plan` artifact alone.

use crate::util::json::{JsonError, Value};
use crate::util::rng::Rng;

use super::events::{ChaosEvent, TimedEvent};

/// How hostile the generated plan is.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Profile {
    /// Occasional single-worker faults; the system should barely notice.
    Light,
    /// Frequent crashes, stragglers, blackouts, squeezes and flash crowds.
    Heavy,
}

impl Profile {
    pub fn name(&self) -> &'static str {
        match self {
            Profile::Light => "light",
            Profile::Heavy => "heavy",
        }
    }

    pub fn parse(s: &str) -> Option<Profile> {
        Some(match s.to_ascii_lowercase().as_str() {
            "light" => Profile::Light,
            "heavy" => Profile::Heavy,
            _ => return None,
        })
    }

    /// Per-interval injection probabilities
    /// (crash, straggler, blackout, ram-squeeze, flash-crowd,
    /// rack-failure, clock-skew, payload-corruption).
    fn rates(&self) -> [f64; 8] {
        match self {
            Profile::Light => [0.03, 0.05, 0.03, 0.03, 0.02, 0.01, 0.03, 0.02],
            Profile::Heavy => [0.15, 0.20, 0.12, 0.12, 0.08, 0.04, 0.10, 0.08],
        }
    }

    /// Longest outage/episode, in intervals.
    fn max_duration(&self) -> usize {
        match self {
            Profile::Light => 3,
            Profile::Heavy => 6,
        }
    }
}

/// A complete, reproducible fault schedule.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultPlan {
    /// Seed the plan was generated from (also seeds the experiment config
    /// in the CLI so one number reproduces the whole run).
    pub seed: u64,
    /// Horizon the plan was generated for.
    pub intervals: usize,
    /// Profile name, for provenance in printed artifacts.
    pub profile: String,
    /// Events sorted by interval.
    pub events: Vec<TimedEvent>,
}

impl FaultPlan {
    /// Empty plan (a chaos run with no chaos — useful as a control).
    pub fn empty(seed: u64, intervals: usize) -> FaultPlan {
        FaultPlan { seed, intervals, profile: "none".into(), events: Vec::new() }
    }

    /// Generate a plan for `intervals` intervals over `n_workers` workers.
    /// Equal (seed, intervals, profile, n_workers) yield equal plans.
    ///
    /// Episodes of the same kind never overlap (per worker, or fleet-wide
    /// for flash crowds): an overlapping start would let the earlier
    /// episode's end event cancel the later one early, making plans less
    /// hostile than they claim.
    pub fn generate(seed: u64, intervals: usize, profile: Profile, n_workers: usize) -> FaultPlan {
        let mut rng = Rng::new(seed ^ 0xC4A0_5EED);
        let [p_crash, p_strag, p_black, p_squeeze, p_flash, p_rack, p_skew, p_corrupt] =
            profile.rates();
        let max_d = profile.max_duration();
        let n = n_workers.max(1);
        let mut events: Vec<TimedEvent> = Vec::new();
        let mut push = |t: usize, event: ChaosEvent| {
            if t < intervals {
                events.push(TimedEvent { t, event });
            }
        };
        // first interval the worker/fleet is free of each episode kind
        let mut offline_until = vec![0usize; n];
        let mut strag_until = vec![0usize; n];
        let mut black_until = vec![0usize; n];
        let mut squeeze_until = vec![0usize; n];
        let mut skew_until = vec![0usize; n];
        let mut flash_until = 0usize;
        for t in 0..intervals {
            if rng.chance(p_crash) {
                let w = rng.below(n as u64) as usize;
                let d = rng.int_range(1, max_d as i64) as usize;
                if t >= offline_until[w] {
                    push(t, ChaosEvent::Crash { worker: w });
                    push(t + d, ChaosEvent::Recover { worker: w });
                    offline_until[w] = t + d;
                }
            }
            if rng.chance(p_rack) {
                let rack = rng.below(super::events::RACKS as u64) as usize;
                let d = rng.int_range(1, max_d as i64) as usize;
                // the whole rack must be free of offline episodes, so an
                // individual Recover never revives a failed rack early
                let members = super::events::rack_members(n, rack);
                if !members.is_empty() && members.clone().all(|w| t >= offline_until[w]) {
                    push(t, ChaosEvent::CorrelatedRackFailure { rack });
                    push(t + d, ChaosEvent::RackRecover { rack });
                    for w in members {
                        offline_until[w] = t + d;
                    }
                }
            }
            if rng.chance(p_strag) {
                let w = rng.below(n as u64) as usize;
                let factor = rng.range(0.15, 0.6);
                let d = rng.int_range(1, max_d as i64) as usize;
                if t >= strag_until[w] {
                    push(t, ChaosEvent::Straggler { worker: w, factor });
                    push(t + d, ChaosEvent::Straggler { worker: w, factor: 1.0 });
                    strag_until[w] = t + d;
                }
            }
            if rng.chance(p_black) {
                let w = rng.below(n as u64) as usize;
                let d = rng.int_range(1, max_d as i64) as usize;
                if t >= black_until[w] {
                    push(t, ChaosEvent::Blackout { worker: w });
                    push(t + d, ChaosEvent::BlackoutEnd { worker: w });
                    black_until[w] = t + d;
                }
            }
            if rng.chance(p_squeeze) {
                let w = rng.below(n as u64) as usize;
                let factor = rng.range(0.25, 0.7);
                let d = rng.int_range(1, max_d as i64) as usize;
                if t >= squeeze_until[w] {
                    push(t, ChaosEvent::RamSqueeze { worker: w, factor });
                    push(t + d, ChaosEvent::RamSqueeze { worker: w, factor: 1.0 });
                    squeeze_until[w] = t + d;
                }
            }
            if rng.chance(p_skew) {
                let w = rng.below(n as u64) as usize;
                let offset = rng.range(10.0, 90.0);
                let d = rng.int_range(1, max_d as i64) as usize;
                if t >= skew_until[w] {
                    push(t, ChaosEvent::ClockSkew { worker: w, offset_s: offset });
                    push(t + d, ChaosEvent::ClockSkew { worker: w, offset_s: 0.0 });
                    skew_until[w] = t + d;
                }
            }
            if rng.chance(p_flash) {
                let mult = rng.range(3.0, 6.0);
                let d = rng.int_range(1, max_d as i64) as usize;
                if t >= flash_until {
                    push(t, ChaosEvent::FlashCrowd { lambda_mult: mult });
                    push(t + d, ChaosEvent::FlashCrowdEnd);
                    flash_until = t + d;
                }
            }
            // instantaneous, so no episode bookkeeping: corrupting a
            // worker with nothing in flight is a recorded no-op
            if rng.chance(p_corrupt) {
                let w = rng.below(n as u64) as usize;
                push(t, ChaosEvent::PayloadCorruption { worker: w });
            }
        }
        events.sort_by_key(|e| e.t);
        FaultPlan { seed, intervals, profile: profile.name().into(), events }
    }

    /// Same plan with a different event list (shrinker constructor).
    pub fn with_events(&self, events: Vec<TimedEvent>) -> FaultPlan {
        FaultPlan {
            seed: self.seed,
            intervals: self.intervals,
            profile: self.profile.clone(),
            events,
        }
    }

    /// Events firing at the start of interval `t`.
    pub fn events_at(&self, t: usize) -> impl Iterator<Item = &TimedEvent> {
        self.events.iter().filter(move |e| e.t == t)
    }

    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            // string, not number: JSON numbers are f64 here and would
            // silently corrupt seeds above 2^53
            ("seed", Value::Str(self.seed.to_string())),
            ("intervals", Value::Num(self.intervals as f64)),
            ("profile", Value::Str(self.profile.clone())),
            ("events", Value::Arr(self.events.iter().map(|e| e.to_json()).collect())),
        ])
    }

    pub fn from_json(v: &Value) -> Result<FaultPlan, JsonError> {
        let events = v
            .req("events")?
            .as_arr()?
            .iter()
            .map(TimedEvent::from_json)
            .collect::<Result<Vec<_>, _>>()?;
        let seed = match v.req("seed")? {
            Value::Str(s) => s.parse().map_err(|_| JsonError::Type("u64 seed"))?,
            other => other.as_f64()? as u64, // older numeric plans
        };
        Ok(FaultPlan {
            seed,
            intervals: v.req("intervals")?.as_usize()?,
            profile: v.req("profile")?.as_str()?.to_string(),
            events,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn generation_is_deterministic() {
        let a = FaultPlan::generate(7, 50, Profile::Heavy, 10);
        let b = FaultPlan::generate(7, 50, Profile::Heavy, 10);
        assert_eq!(a, b);
        let c = FaultPlan::generate(8, 50, Profile::Heavy, 10);
        assert_ne!(a, c, "different seeds must differ");
    }

    #[test]
    fn heavy_generates_more_events_than_light() {
        let light = FaultPlan::generate(3, 100, Profile::Light, 10);
        let heavy = FaultPlan::generate(3, 100, Profile::Heavy, 10);
        assert!(
            heavy.events.len() > 2 * light.events.len().max(1),
            "light={} heavy={}",
            light.events.len(),
            heavy.events.len()
        );
    }

    #[test]
    fn events_sorted_and_in_horizon() {
        let p = FaultPlan::generate(11, 40, Profile::Heavy, 10);
        assert!(!p.events.is_empty());
        for pair in p.events.windows(2) {
            assert!(pair[0].t <= pair[1].t);
        }
        for e in &p.events {
            assert!(e.t < 40);
            if let Some(w) = e.event.worker() {
                assert!(w < 10);
            }
        }
    }

    #[test]
    fn episodes_of_one_kind_never_overlap() {
        for seed in [5u64, 6, 7] {
            let p = FaultPlan::generate(seed, 80, Profile::Heavy, 6);
            let mut offline = vec![false; 6];
            let mut strag = vec![false; 6];
            let mut black = vec![false; 6];
            let mut squeeze = vec![false; 6];
            let mut skewed = vec![false; 6];
            let mut flash = false;
            // generation order is chronological and the sort is stable, so
            // an episode's end always precedes the next start at equal t
            for e in &p.events {
                match e.event {
                    ChaosEvent::Crash { worker } => {
                        assert!(!offline[worker], "overlapping crash on {worker}");
                        offline[worker] = true;
                    }
                    ChaosEvent::Recover { worker } => offline[worker] = false,
                    ChaosEvent::CorrelatedRackFailure { rack } => {
                        for w in crate::chaos::events::rack_members(6, rack) {
                            assert!(!offline[w], "rack failure overlaps offline worker {w}");
                            offline[w] = true;
                        }
                    }
                    ChaosEvent::RackRecover { rack } => {
                        for w in crate::chaos::events::rack_members(6, rack) {
                            offline[w] = false;
                        }
                    }
                    ChaosEvent::ClockSkew { worker, offset_s } if offset_s > 0.0 => {
                        assert!(!skewed[worker], "overlapping clock skew on {worker}");
                        skewed[worker] = true;
                    }
                    ChaosEvent::ClockSkew { worker, .. } => skewed[worker] = false,
                    ChaosEvent::Straggler { worker, factor } if factor < 1.0 => {
                        assert!(!strag[worker], "overlapping straggler on {worker}");
                        strag[worker] = true;
                    }
                    ChaosEvent::Straggler { worker, .. } => strag[worker] = false,
                    ChaosEvent::Blackout { worker } => {
                        assert!(!black[worker], "overlapping blackout on {worker}");
                        black[worker] = true;
                    }
                    ChaosEvent::BlackoutEnd { worker } => black[worker] = false,
                    ChaosEvent::RamSqueeze { worker, factor } if factor < 1.0 => {
                        assert!(!squeeze[worker], "overlapping squeeze on {worker}");
                        squeeze[worker] = true;
                    }
                    ChaosEvent::RamSqueeze { worker, .. } => squeeze[worker] = false,
                    ChaosEvent::FlashCrowd { .. } => {
                        assert!(!flash, "overlapping flash crowd");
                        flash = true;
                    }
                    ChaosEvent::FlashCrowdEnd => flash = false,
                    // instantaneous — no episode to overlap
                    ChaosEvent::PayloadCorruption { .. } => {}
                }
            }
        }
    }

    #[test]
    fn heavy_plans_exercise_the_full_vocabulary() {
        // union across a few seeds: every event kind must be reachable
        let mut kinds = std::collections::HashSet::new();
        for seed in 0..6u64 {
            for e in &FaultPlan::generate(seed, 120, Profile::Heavy, 8).events {
                kinds.insert(e.event.name());
            }
        }
        for kind in [
            "crash", "recover", "straggler", "ram-squeeze", "blackout",
            "flash-crowd", "rack-failure", "rack-recover", "clock-skew",
            "payload-corruption",
        ] {
            assert!(kinds.contains(kind), "generator never emits '{kind}'");
        }
    }

    #[test]
    fn plan_json_roundtrip() {
        let p = FaultPlan::generate(13, 30, Profile::Heavy, 8);
        let j = p.to_json().to_string();
        let back = FaultPlan::from_json(&json::parse(&j).unwrap()).unwrap();
        assert_eq!(back, p);
    }

    #[test]
    fn huge_seed_survives_json() {
        // above 2^53: would corrupt if routed through an f64 JSON number
        let p = FaultPlan::empty((1u64 << 53) + 1, 5);
        let back = FaultPlan::from_json(&json::parse(&p.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back.seed, p.seed);
    }

    #[test]
    fn events_at_filters_by_interval() {
        let base = FaultPlan::empty(1, 10);
        let p = base.with_events(vec![
            TimedEvent { t: 2, event: ChaosEvent::Crash { worker: 0 } },
            TimedEvent { t: 2, event: ChaosEvent::FlashCrowdEnd },
            TimedEvent { t: 5, event: ChaosEvent::Recover { worker: 0 } },
        ]);
        assert_eq!(p.events_at(2).count(), 2);
        assert_eq!(p.events_at(3).count(), 0);
        assert_eq!(p.events_at(5).count(), 1);
    }
}
