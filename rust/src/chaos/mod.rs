//! Deterministic chaos engine: seeded fault-injection plans, interval
//! invariant oracles, and shrink-to-minimal failing scenarios.
//!
//! The paper evaluates SplitPlace in *volatile* mobile-edge environments
//! and leaves non-stationary fleets as future work (§7); this subsystem
//! turns the simulator into a property-driven adversarial harness:
//!
//! 1. [`plan::FaultPlan`] — a seeded, serializable per-interval schedule of
//!    [`events::ChaosEvent`]s: worker crash/recover, stragglers, network
//!    blackouts, RAM squeezes, flash-crowd bursts, rack failures, clock
//!    skew, payload corruption, mobility handoffs.
//! 2. [`run_chaos`] compiles each event to typed
//!    [`crate::sim::EngineCmd`]s and applies them through the engine's
//!    single `apply` entry point — the engine's command ledger records
//!    every mutation. An injected [`BugKind`] *sabotages the compiled
//!    command list* (drops/replaces commands), which is exactly what the
//!    oracles must catch.
//! 3. [`oracle`] checks named invariants after every interval, auditing
//!    the bug-free compiled commands (replayed into a [`PlanLedger`])
//!    against engine state, and the engine's own command ledger against
//!    task outcomes.
//! 4. On a violation, [`shrink`] bisects the plan down to a minimal failing
//!    counterexample; the printed `seed + plan` JSON reproduces it exactly.

pub mod events;
pub mod oracle;
pub mod plan;
pub mod shrink;

use anyhow::Result;

use crate::config::ExperimentConfig;
use crate::coordinator::Broker;
use crate::metrics::Summary;
use crate::runtime::Runtime;
use crate::sim::{EngineCmd, IntervalReport};

pub use events::{ChaosEvent, TimedEvent};
pub use oracle::{check_interval, OracleCtx, OracleState, Violation, ORACLES};
pub use plan::{FaultPlan, Profile};
pub use shrink::{shrink_plan, ShrinkResult};

/// Deliberate invariant bugs, used to validate that the oracles catch real
/// defects and that shrinking produces minimal reproductions. Each bug is
/// a *command-level sabotage*: the event still compiles, but the command
/// list the engine receives is mutated.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BugKind {
    /// Crashes take the worker offline but "forget" to drop its
    /// containers — progress continues on a dead machine.
    SkipCrashRequeue,
    /// A correlated rack failure only takes down the first rack member —
    /// the rest of the rack keeps serving from a dead failure domain.
    ForgetRackMember,
    /// Clock-skew events are silently ignored — the engine's clocks stay
    /// synchronized while the plan says they drifted.
    DropClockSkew,
    /// Payload corruption is recorded but the checksum check is missing —
    /// the corrupted transfer completes as if nothing happened instead of
    /// failing the task.
    SwallowCorruption,
    /// Mobility handoffs are silently dropped — the worker keeps its old
    /// rack home (and channel state) while the plan says it moved.
    DropHandoff,
}

impl BugKind {
    pub fn name(&self) -> &'static str {
        match self {
            BugKind::SkipCrashRequeue => "skip-crash-requeue",
            BugKind::ForgetRackMember => "forget-rack-member",
            BugKind::DropClockSkew => "drop-clock-skew",
            BugKind::SwallowCorruption => "swallow-corruption",
            BugKind::DropHandoff => "drop-handoff",
        }
    }

    pub fn parse(s: &str) -> Option<BugKind> {
        match s.to_ascii_lowercase().as_str() {
            "skip-crash-requeue" => Some(BugKind::SkipCrashRequeue),
            "forget-rack-member" => Some(BugKind::ForgetRackMember),
            "drop-clock-skew" => Some(BugKind::DropClockSkew),
            "swallow-corruption" => Some(BugKind::SwallowCorruption),
            "drop-handoff" => Some(BugKind::DropHandoff),
            _ => None,
        }
    }
}

/// Harness knobs.
#[derive(Clone, Copy, Debug)]
pub struct ChaosOptions {
    /// Inject a deliberate invariant bug (oracle validation).
    pub bug: Option<BugKind>,
    /// Fail tasks older than this many intervals (starvation guard under
    /// crash storms); 0 disables the guard.
    pub task_timeout_intervals: usize,
    /// Run the retained full-scan oracle twins side by side with the
    /// O(active) indexed derivations every interval, and fail the run on
    /// any verdict divergence (`paranoid-divergence` violations). Restores
    /// the pre-migration oracle cost — a CI cross-check, not a default.
    pub paranoid: bool,
}

impl Default for ChaosOptions {
    fn default() -> Self {
        ChaosOptions { bug: None, task_timeout_intervals: 40, paranoid: false }
    }
}

/// Cheap structural fingerprint of one interval — two runs of the same
/// seed + plan must produce identical signature streams.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct IntervalSig {
    pub interval: usize,
    pub completed: Vec<u64>,
    pub failed: Vec<u64>,
    pub queued: usize,
    pub offline: usize,
    pub energy_bits: u64,
}

impl IntervalSig {
    pub(crate) fn of(report: &IntervalReport) -> IntervalSig {
        let mut completed: Vec<u64> = report.completed.iter().map(|t| t.task_id).collect();
        completed.sort_unstable();
        let mut failed: Vec<u64> = report.failed.iter().map(|t| t.task_id).collect();
        failed.sort_unstable();
        IntervalSig {
            interval: report.interval,
            completed,
            failed,
            queued: report.queued,
            offline: report.offline,
            energy_bits: report.energy_wh.to_bits(),
        }
    }
}

/// Everything one chaos run produced.
#[derive(Clone, Debug)]
pub struct ChaosOutcome {
    /// All invariant violations, in detection order.
    pub violations: Vec<Violation>,
    /// Per-interval fingerprints (determinism audits).
    pub signatures: Vec<IntervalSig>,
    pub admitted: u64,
    pub completed: usize,
    pub failed: usize,
    /// Traffic-plane counters (see [`crate::traffic`]): arrivals before
    /// admission control, tasks shed by each verdict, and autoscaler
    /// actions. `offered == admitted + shed_queue + shed_deadline`.
    pub offered: u64,
    pub shed_queue: u64,
    pub shed_deadline: u64,
    pub scale_up: u64,
    pub scale_down: u64,
    /// φ=0.9 EMA of task response times in completion order (NaN when no
    /// task left the system) — the matrix harness's latency headline.
    pub response_ema: f64,
    /// Total fleet energy over the run, watt-hours (offline workers draw
    /// 0 W) — the energy-gated headline.
    pub energy_wh: f64,
    /// Mean per-interval normalized AEC (eq. 10's energy term); 0 on a
    /// zero-interval run.
    pub mean_aec: f64,
    /// Standard experiment summary (Table-4 quantities) for the run.
    pub summary: Summary,
}

impl ChaosOutcome {
    pub fn violated_oracles(&self) -> Vec<&'static str> {
        let mut seen = Vec::new();
        for v in &self.violations {
            if !seen.contains(&v.oracle) {
                seen.push(v.oracle);
            }
        }
        seen
    }
}

/// Expected engine fault state, replayed from the *bug-free* compiled
/// commands of every plan event applied so far. The `offline-matches-plan`
/// and `clock-skew-applied` oracles compare engine state to this ledger —
/// a sabotaged command list makes the engine diverge from it, which is the
/// point. Replaying commands (not events) means the compilation in
/// [`ChaosEvent::compile`] is the single semantic source.
#[derive(Clone, Debug)]
pub struct PlanLedger {
    pub offline: Vec<bool>,
    pub skew: Vec<f64>,
    /// Per-worker rack homes — starts at [`events::initial_racks`] (the
    /// same single source the engine seeds `rack_of` from) and moves only
    /// through absorbed handoff commands.
    pub racks: Vec<usize>,
}

impl PlanLedger {
    pub fn new(n_workers: usize) -> PlanLedger {
        PlanLedger {
            offline: vec![false; n_workers],
            skew: vec![0.0; n_workers],
            racks: events::initial_racks(n_workers),
        }
    }

    /// Absorb one bug-free compiled command. Mirrors the engine's own
    /// semantics exactly: values clamp the same way, and out-of-range
    /// workers are no-ops (the engine Noops them; `ChaosEvent::compile`
    /// filters them too, but `absorb` must not trust its caller).
    pub fn absorb(&mut self, cmd: &EngineCmd) {
        let n = self.offline.len();
        if let Some(w) = cmd.worker() {
            if w >= n {
                return;
            }
        }
        match *cmd {
            EngineCmd::Crash { worker } | EngineCmd::ForceOfflineNoEvict { worker } => {
                self.offline[worker] = true;
            }
            EngineCmd::Recover { worker } => self.offline[worker] = false,
            EngineCmd::SetOnline { worker, up } => self.offline[worker] = !up,
            EngineCmd::SetClockSkew { worker, skew_s } => {
                self.skew[worker] = skew_s.clamp(0.0, 600.0);
            }
            EngineCmd::Handoff { worker, from_rack, to_rack } => {
                // exactly the engine's guard: stale handoffs (wrong
                // from_rack) and self-handoffs are no-ops, to_rack is
                // normalized into the rack ring
                let to = to_rack % events::RACKS;
                if self.racks[worker] == from_rack && to != from_rack {
                    self.racks[worker] = to;
                }
            }
            _ => {}
        }
    }
}

/// Mutate one event's compiled command list per the injected bug. Bugs are
/// event-kind-scoped: e.g. `ForgetRackMember` only sabotages rack
/// failures, never individual crashes.
fn sabotage(event: &ChaosEvent, cmds: Vec<EngineCmd>, bug: BugKind) -> Vec<EngineCmd> {
    match (bug, event) {
        (BugKind::SkipCrashRequeue, ChaosEvent::Crash { .. }) => cmds
            .into_iter()
            .map(|c| match c {
                EngineCmd::Crash { worker } => EngineCmd::ForceOfflineNoEvict { worker },
                other => other,
            })
            .collect(),
        (BugKind::ForgetRackMember, ChaosEvent::CorrelatedRackFailure { .. }) => {
            cmds.into_iter().take(1).collect()
        }
        (BugKind::DropClockSkew, ChaosEvent::ClockSkew { .. }) => Vec::new(),
        (BugKind::SwallowCorruption, ChaosEvent::PayloadCorruption { .. }) => cmds
            .into_iter()
            .map(|c| match c {
                EngineCmd::CorruptPayload { worker } => {
                    EngineCmd::CorruptPayloadSwallowed { worker }
                }
                other => other,
            })
            .collect(),
        (BugKind::DropHandoff, ChaosEvent::Handoff { .. }) => Vec::new(),
        _ => cmds,
    }
}

/// Apply one plan event: broker-scoped events adjust the arrival rate;
/// engine-scoped events compile to commands (sabotaged under an injected
/// bug) and go through the engine's command bus. Public so the throughput
/// bench (`benchlib::throughput`) can drive plans through exactly the same
/// path without paying for per-interval oracle sweeps.
pub fn apply_event(broker: &mut Broker, event: &ChaosEvent, opts: &ChaosOptions, base_lambda: f64) {
    match *event {
        ChaosEvent::FlashCrowd { lambda_mult } => {
            broker.set_lambda_override(Some(base_lambda * lambda_mult));
        }
        ChaosEvent::FlashCrowdEnd => broker.set_lambda_override(None),
        _ => {
            let mut cmds = event.compile(broker.engine.workers());
            if let Some(bug) = opts.bug {
                cmds = sabotage(event, cmds, bug);
            }
            for cmd in cmds {
                broker.engine.apply(cmd);
            }
        }
    }
}

/// Run `cfg.sim.intervals` broker intervals under `plan`, checking every
/// oracle each interval. Fully deterministic: equal (cfg, plan, opts)
/// yield equal [`ChaosOutcome::signatures`].
///
/// Surrogate-based policies degrade to best-fit placement when `runtime`
/// is `None` (see [`Broker::new_with_fallback`]), so chaos runs work in
/// artifact-less environments such as CI.
pub fn run_chaos(
    cfg: &ExperimentConfig,
    plan: &FaultPlan,
    opts: &ChaosOptions,
    runtime: Option<&Runtime>,
) -> Result<ChaosOutcome> {
    let mut broker = Broker::new_with_fallback(cfg.clone(), runtime, crate::mab::Mode::Test)?;
    // paranoid mode also arms the decision-plane twin: the placer re-runs
    // its retired full-fleet scan beside every indexed query and the loop
    // below drains any mismatch into `paranoid-divergence` violations.
    broker.set_placement_paranoid(opts.paranoid);
    let mab_baseline = broker.decision_count().unwrap_or(0);
    let base_lambda = cfg.workload.lambda;
    let mut oracle_state = OracleState::new();
    let mut violations = Vec::new();
    let mut signatures = Vec::with_capacity(cfg.sim.intervals);
    // Plan-state ledger for the injected-state oracles. Churn and the
    // autoscaler both let the engine toggle availability on its own, so
    // the comparison is only meaningful when neither is active (the
    // ledger-replay-consistent oracle still audits scaling commands —
    // they carry the Autoscale origin in the engine's own ledger).
    // (Battery exhaustion likewise crashes workers outside the plan, so a
    // battery-powered fleet stands the availability comparison down.)
    let track_plan_state = cfg.cluster.churn_rate == 0.0
        && cfg.traffic.autoscale.is_none()
        && cfg.cluster.battery_wh.is_none();
    let n_workers = broker.engine.workers();
    let mut plan_ledger = PlanLedger::new(n_workers);

    for t in 0..cfg.sim.intervals {
        let fired: Vec<ChaosEvent> = plan.events_at(t).map(|e| e.event).collect();
        for event in &fired {
            apply_event(&mut broker, event, opts, base_lambda);
            // the expectation absorbs the BUG-FREE compilation
            for cmd in event.compile(n_workers) {
                plan_ledger.absorb(&cmd);
            }
        }
        if opts.task_timeout_intervals > 0 {
            broker.engine.apply(EngineCmd::FailTasksOlderThan {
                age_s: opts.task_timeout_intervals as f64 * cfg.sim.interval_seconds,
            });
        }
        let (_o_p, report) = broker.step_report();
        let mab_decisions = broker.decision_count().map(|c| c - mab_baseline);
        let tok = broker.engine.phases().start();
        let mut ctx = OracleCtx {
            engine: &broker.engine,
            report: &report,
            admitted: broker.admitted,
            mab_decisions,
            state: &mut oracle_state,
            expected_offline: track_plan_state.then_some(plan_ledger.offline.as_slice()),
            expected_skew: track_plan_state.then_some(plan_ledger.skew.as_slice()),
            expected_racks: track_plan_state.then_some(plan_ledger.racks.as_slice()),
            paranoid: opts.paranoid,
        };
        violations.extend(check_interval(&mut ctx));
        for detail in broker.take_placement_divergences() {
            violations.push(Violation {
                oracle: "paranoid-divergence",
                interval: t,
                detail: format!("best-fit placement twin: {detail}"),
            });
        }
        broker.engine.phases_mut().stop(crate::util::phase_timer::Phase::Oracle, tok);
        signatures.push(IntervalSig::of(&report));
    }

    let summary = broker.metrics.summary(cfg.policy.name());
    let energy_wh = crate::util::accum::sum(broker.metrics.energy_wh.iter().copied());
    let mean_aec = if broker.metrics.aec.is_empty() {
        0.0
    } else {
        crate::util::accum::sum(broker.metrics.aec.iter().copied())
            / broker.metrics.aec.len() as f64
    };
    Ok(ChaosOutcome {
        violations,
        signatures,
        admitted: broker.admitted,
        completed: broker.engine.completed_task_count(),
        failed: broker.engine.failed_task_count(),
        offered: broker.offered,
        shed_queue: broker.shed_queue,
        shed_deadline: broker.shed_deadline,
        scale_up: broker.scale_up,
        scale_down: broker.scale_down,
        response_ema: broker.metrics.response_ema(0.9),
        energy_wh,
        mean_aec,
        summary,
    })
}

/// Differential mode: the same plan under two policies. Returns both
/// outcomes for side-by-side comparison of violations / SLA behavior.
pub fn run_differential(
    cfg: &ExperimentConfig,
    policy_b: crate::config::PolicyKind,
    plan: &FaultPlan,
    opts: &ChaosOptions,
    runtime: Option<&Runtime>,
) -> Result<(ChaosOutcome, ChaosOutcome)> {
    let a = run_chaos(cfg, plan, opts, runtime)?;
    let mut cfg_b = cfg.clone();
    cfg_b.policy = policy_b;
    let b = run_chaos(&cfg_b, plan, opts, runtime)?;
    Ok((a, b))
}

/// Shrink budget for [`shrink_to_minimal`] (re-runs of the scenario).
pub const SHRINK_MAX_RUNS: usize = 400;

/// Shrink `plan` to a minimal plan that still violates `oracle_name` under
/// the same cfg/opts. Assumes the full plan does.
pub fn shrink_to_minimal(
    cfg: &ExperimentConfig,
    plan: &FaultPlan,
    opts: &ChaosOptions,
    runtime: Option<&Runtime>,
    oracle_name: &str,
) -> ShrinkResult {
    shrink_plan(plan, SHRINK_MAX_RUNS, |candidate| {
        run_chaos(cfg, candidate, opts, runtime)
            .map(|o| o.violations.iter().any(|v| v.oracle == oracle_name))
            .unwrap_or(false)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ExperimentConfig, PolicyKind};

    fn chaos_cfg(intervals: usize, lambda: f64) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::small();
        cfg.policy = PolicyKind::ModelCompression; // runs without artifacts
        cfg.sim.intervals = intervals;
        cfg.workload.lambda = lambda;
        cfg
    }

    #[test]
    fn clean_heavy_run_is_deterministic_and_green() {
        let cfg = chaos_cfg(12, 4.0);
        let plan = FaultPlan::generate(7, 12, Profile::Heavy, cfg.cluster.total_workers());
        let opts = ChaosOptions::default();
        let a = run_chaos(&cfg, &plan, &opts, None).unwrap();
        let b = run_chaos(&cfg, &plan, &opts, None).unwrap();
        assert!(a.violations.is_empty(), "clean engine must stay green: {:?}", a.violations);
        assert_eq!(a.signatures, b.signatures, "same seed + plan ⇒ identical stream");
        assert!(a.admitted > 0);
    }

    #[test]
    fn mab_policy_survives_chaos_with_fallback_placer() {
        let mut cfg = chaos_cfg(12, 3.0);
        cfg.policy = PolicyKind::MabDaso;
        let plan = FaultPlan::generate(3, 12, Profile::Heavy, cfg.cluster.total_workers());
        let out = run_chaos(&cfg, &plan, &ChaosOptions::default(), None).unwrap();
        assert!(out.violations.is_empty(), "{:?}", out.violations);
        assert!(out.admitted > 0);
        // MAB state updates are order-sensitive (response-time EMA), so
        // this specifically guards the deterministic task-iteration order
        let replay = run_chaos(&cfg, &plan, &ChaosOptions::default(), None).unwrap();
        assert_eq!(out.signatures, replay.signatures, "MAB runs must replay identically");
    }

    #[test]
    fn crash_storm_still_completes_tasks() {
        let cfg = chaos_cfg(20, 3.0);
        // crash workers 0..5 early, recover them a few intervals later
        let base = FaultPlan::empty(1, 20);
        let mut events = Vec::new();
        for w in 0..5 {
            events.push(TimedEvent { t: 2, event: ChaosEvent::Crash { worker: w } });
            events.push(TimedEvent { t: 6, event: ChaosEvent::Recover { worker: w } });
        }
        let plan = base.with_events(events);
        let out = run_chaos(&cfg, &plan, &ChaosOptions::default(), None).unwrap();
        assert!(out.violations.is_empty(), "{:?}", out.violations);
        assert!(out.completed > 0, "tasks must complete despite the crash storm");
    }

    #[test]
    fn flash_crowd_inflates_admissions() {
        let cfg = chaos_cfg(10, 2.0);
        let quiet = run_chaos(
            &cfg,
            &FaultPlan::empty(2, 10),
            &ChaosOptions::default(),
            None,
        )
        .unwrap();
        let base = FaultPlan::empty(2, 10);
        let plan = base.with_events(vec![TimedEvent {
            t: 1,
            event: ChaosEvent::FlashCrowd { lambda_mult: 10.0 },
        }]);
        let crowd = run_chaos(&cfg, &plan, &ChaosOptions::default(), None).unwrap();
        assert!(
            crowd.admitted > 2 * quiet.admitted.max(1),
            "quiet={} crowd={}",
            quiet.admitted,
            crowd.admitted
        );
        assert!(crowd.violations.is_empty(), "{:?}", crowd.violations);
    }

    #[test]
    fn rack_failure_takes_the_whole_rack_down_and_recovers_it() {
        let cfg = chaos_cfg(8, 2.0);
        let n = cfg.cluster.total_workers();
        let rack = 1usize;
        let members: Vec<usize> = events::rack_members(n, rack).collect();
        assert!(members.len() >= 2, "small fleet racks must have ≥2 members");
        let base = FaultPlan::empty(5, 8);
        let plan = base.with_events(vec![
            TimedEvent { t: 1, event: ChaosEvent::CorrelatedRackFailure { rack } },
            TimedEvent { t: 4, event: ChaosEvent::RackRecover { rack } },
        ]);
        let out = run_chaos(&cfg, &plan, &ChaosOptions::default(), None).unwrap();
        assert!(out.violations.is_empty(), "{:?}", out.violations);
        // during the outage the interval reports count the members offline
        assert_eq!(out.signatures[1].offline, members.len());
        assert_eq!(out.signatures[3].offline, members.len());
        assert_eq!(out.signatures[5].offline, 0, "rack must rejoin after recovery");
    }

    #[test]
    fn forgotten_rack_member_is_caught_by_the_plan_ledger_oracle() {
        let cfg = chaos_cfg(8, 2.0);
        let plan = FaultPlan::empty(5, 8).with_events(vec![TimedEvent {
            t: 1,
            event: ChaosEvent::CorrelatedRackFailure { rack: 0 },
        }]);
        let opts = ChaosOptions { bug: Some(BugKind::ForgetRackMember), ..Default::default() };
        let out = run_chaos(&cfg, &plan, &opts, None).unwrap();
        assert!(
            out.violated_oracles().contains(&"offline-matches-plan"),
            "bug must be caught: {:?}",
            out.violated_oracles()
        );
        let fixed = run_chaos(&cfg, &plan, &ChaosOptions::default(), None).unwrap();
        assert!(fixed.violations.is_empty(), "{:?}", fixed.violations);
    }

    #[test]
    fn dropped_clock_skew_is_caught_by_the_skew_oracle() {
        let cfg = chaos_cfg(8, 2.0);
        let plan = FaultPlan::empty(6, 8).with_events(vec![
            TimedEvent { t: 1, event: ChaosEvent::ClockSkew { worker: 2, offset_s: 30.0 } },
            TimedEvent { t: 5, event: ChaosEvent::ClockSkew { worker: 2, offset_s: 0.0 } },
        ]);
        let opts = ChaosOptions { bug: Some(BugKind::DropClockSkew), ..Default::default() };
        let out = run_chaos(&cfg, &plan, &opts, None).unwrap();
        assert!(
            out.violated_oracles().contains(&"clock-skew-applied"),
            "bug must be caught: {:?}",
            out.violated_oracles()
        );
        let fixed = run_chaos(&cfg, &plan, &ChaosOptions::default(), None).unwrap();
        assert!(fixed.violations.is_empty(), "{:?}", fixed.violations);
    }

    // NOTE: the full bug→catch→shrink→replay scenario (including the ≤3
    // event minimality bound) lives in tests/properties.rs, seeded over
    // several generated plans. This unit test only pins the two ends of
    // it: the oracle fires with the bug and stays green without it.
    #[test]
    fn injected_bug_is_caught_by_the_idle_oracle() {
        let cfg = chaos_cfg(10, 6.0);
        let n = cfg.cluster.total_workers();
        let base = FaultPlan::empty(4, 10);
        let events = (0..n)
            .map(|w| TimedEvent { t: 2, event: ChaosEvent::Crash { worker: w } })
            .collect();
        let plan = base.with_events(events);
        let opts = ChaosOptions { bug: Some(BugKind::SkipCrashRequeue), ..Default::default() };

        let out = run_chaos(&cfg, &plan, &opts, None).unwrap();
        assert!(
            out.violated_oracles().contains(&"crashed-workers-idle"),
            "bug must be caught: {:?}",
            out.violated_oracles()
        );
        // the same plan without the bug is green
        let fixed = run_chaos(&cfg, &plan, &ChaosOptions::default(), None).unwrap();
        assert!(fixed.violations.is_empty(), "{:?}", fixed.violations);
    }

    #[test]
    fn handoff_run_is_green_deterministic_and_dropped_handoffs_are_caught() {
        let cfg = chaos_cfg(8, 2.0);
        let n = cfg.cluster.total_workers();
        let racks = events::initial_racks(n);
        // re-home three workers mid-run, one of them twice
        let plan = FaultPlan::empty(9, 8).with_events(vec![
            TimedEvent {
                t: 1,
                event: ChaosEvent::Handoff {
                    worker: 0,
                    from_rack: racks[0],
                    to_rack: (racks[0] + 1) % events::RACKS,
                },
            },
            TimedEvent {
                t: 2,
                event: ChaosEvent::Handoff {
                    worker: n - 1,
                    from_rack: racks[n - 1],
                    to_rack: (racks[n - 1] + 2) % events::RACKS,
                },
            },
            TimedEvent {
                t: 4,
                event: ChaosEvent::Handoff {
                    worker: 0,
                    from_rack: (racks[0] + 1) % events::RACKS,
                    to_rack: racks[0],
                },
            },
        ]);
        let opts = ChaosOptions { paranoid: true, ..Default::default() };
        let out = run_chaos(&cfg, &plan, &opts, None).unwrap();
        assert!(out.violations.is_empty(), "faithful handoffs stay green: {:?}", out.violations);
        assert!(out.admitted > 0);
        let replay = run_chaos(&cfg, &plan, &opts, None).unwrap();
        assert_eq!(out.signatures, replay.signatures, "handoff runs must replay identically");

        // sabotage: the handoff command list is emptied — the engine's
        // rack map diverges from the plan ledger's mirror
        let opts = ChaosOptions { bug: Some(BugKind::DropHandoff), ..Default::default() };
        let out = run_chaos(&cfg, &plan, &opts, None).unwrap();
        assert!(
            out.violated_oracles().contains(&"handoff-preserves-progress"),
            "dropped handoff must be caught: {:?}",
            out.violated_oracles()
        );
    }

    #[test]
    fn battery_fleet_dies_for_good_and_replays_identically() {
        let mut cfg = chaos_cfg(10, 2.0);
        cfg.cluster.battery_wh = Some(30.0);
        let plan = FaultPlan::empty(3, 10);
        let opts = ChaosOptions { paranoid: true, ..Default::default() };
        let out = run_chaos(&cfg, &plan, &opts, None).unwrap();
        // battery deaths are engine-initiated: the plan-state oracles
        // stand down and the run stays green
        assert!(out.violations.is_empty(), "{:?}", out.violations);
        let last = out.signatures.last().unwrap();
        assert!(last.offline > 0, "a 30 Wh battery must exhaust within 10 idle-ish intervals");
        // offline counts are monotone: nothing resurrects a dead battery
        for pair in out.signatures.windows(2) {
            assert!(pair[1].offline >= pair[0].offline, "battery deaths must be permanent");
        }
        assert!(out.energy_wh > 0.0);
        assert!(out.mean_aec > 0.0 && out.mean_aec < 1.0);
        let replay = run_chaos(&cfg, &plan, &opts, None).unwrap();
        assert_eq!(out.signatures, replay.signatures, "battery runs must replay identically");
    }

    #[test]
    fn paranoid_mode_is_green_and_divergence_free_even_under_bugs() {
        // paranoid re-runs the full-scan twins next to the indexed oracle
        // plane: a clean heavy run must stay green, and a SABOTAGED run
        // must violate the real oracle while scan and index still agree
        // on what the wrongness is (no paranoid-divergence)
        let cfg = chaos_cfg(10, 4.0);
        let plan = FaultPlan::generate(7, 10, Profile::Heavy, cfg.cluster.total_workers());
        let opts = ChaosOptions { paranoid: true, ..Default::default() };
        let out = run_chaos(&cfg, &plan, &opts, None).unwrap();
        assert!(out.violations.is_empty(), "{:?}", out.violations);

        let n = cfg.cluster.total_workers();
        let crash_plan = FaultPlan::empty(4, 10).with_events(
            (0..n)
                .map(|w| TimedEvent { t: 2, event: ChaosEvent::Crash { worker: w } })
                .collect(),
        );
        let opts = ChaosOptions {
            bug: Some(BugKind::SkipCrashRequeue),
            paranoid: true,
            ..Default::default()
        };
        let out = run_chaos(&cfg, &crash_plan, &opts, None).unwrap();
        assert!(out.violated_oracles().contains(&"crashed-workers-idle"), "{:?}", out.violated_oracles());
        assert!(
            !out.violated_oracles().contains(&"paranoid-divergence"),
            "scan and index must agree even on a sabotaged engine: {:?}",
            out.violated_oracles()
        );
    }

    #[test]
    fn traffic_plane_under_chaos_stays_green_and_replays() {
        // Autoscaler + admission + a non-flat arrival model, under a real
        // fault plan. The plan-state oracles stand down (the autoscaler
        // legitimately toggles availability), but ledger-replay-consistent
        // still audits every scaling command via its Autoscale origin.
        let mut cfg = chaos_cfg(14, 5.0);
        cfg.traffic.shape = crate::traffic::TrafficShape::Diurnal;
        cfg.traffic.admission = Some(crate::traffic::AdmissionConfig::default());
        cfg.traffic.autoscale = Some(crate::traffic::AutoscaleConfig {
            queue_hi: 2.0,
            queue_lo: 0.5,
            min_online: 4,
        });
        let plan = FaultPlan::generate(11, 14, Profile::Light, cfg.cluster.total_workers());
        let out = run_chaos(&cfg, &plan, &ChaosOptions::default(), None).unwrap();
        assert!(out.violations.is_empty(), "{:?}", out.violations);
        assert!(out.admitted > 0);
        assert_eq!(out.offered, out.admitted + out.shed_queue + out.shed_deadline);
        let replay = run_chaos(&cfg, &plan, &ChaosOptions::default(), None).unwrap();
        assert_eq!(out.signatures, replay.signatures, "traffic plane must replay identically");
        assert_eq!(out.scale_up, replay.scale_up);
        assert_eq!(out.scale_down, replay.scale_down);
    }

    /// A plan whose corruption events land while transfers are actually
    /// in flight — structural, not a bet on one run's draw: placement
    /// happens at interval starts and even a blackout-throttled transfer
    /// finishes inside one 300 s interval, so the plan drifts every
    /// worker's clock by 400 s instead. Each staging transfer then pays
    /// the skew and is guaranteed to still be in flight when the
    /// corruption sweep hits the following intervals. The run is
    /// deterministic in cfg (the plan's seed field is provenance only),
    /// so the expensive liveness check runs once and is cached across
    /// the tests sharing it — both pass `chaos_cfg(10, 5.0)`.
    fn corrupting_plan(cfg: &ExperimentConfig) -> FaultPlan {
        static FOUND: std::sync::OnceLock<FaultPlan> = std::sync::OnceLock::new();
        FOUND
            .get_or_init(|| {
                let n = cfg.cluster.total_workers();
                let mut events: Vec<TimedEvent> = Vec::new();
                for w in 0..n {
                    events.push(TimedEvent {
                        t: 1,
                        event: ChaosEvent::ClockSkew { worker: w, offset_s: 400.0 },
                    });
                    for t in [2usize, 3] {
                        events.push(TimedEvent {
                            t,
                            event: ChaosEvent::PayloadCorruption { worker: w },
                        });
                    }
                    events.push(TimedEvent {
                        t: 4,
                        event: ChaosEvent::ClockSkew { worker: w, offset_s: 0.0 },
                    });
                }
                events.sort_by_key(|e| e.t);
                let plan = FaultPlan::empty(1, cfg.sim.intervals).with_events(events);
                let out = run_chaos(cfg, &plan, &ChaosOptions::default(), None).unwrap();
                assert!(
                    out.failed > 0,
                    "skew-stretched corruption sweep hit no in-flight transfer — \
                     the transfer model or scenario shape changed"
                );
                plan
            })
            .clone()
    }

    #[test]
    fn payload_corruption_fails_tasks_and_stays_green() {
        let cfg = chaos_cfg(10, 5.0);
        let plan = corrupting_plan(&cfg);
        let out = run_chaos(&cfg, &plan, &ChaosOptions::default(), None).unwrap();
        assert!(out.violations.is_empty(), "{:?}", out.violations);
        assert!(out.failed > 0, "a corrupted in-flight transfer must fail its task");
        // determinism holds with corruption in the plan
        let replay = run_chaos(&cfg, &plan, &ChaosOptions::default(), None).unwrap();
        assert_eq!(out.signatures, replay.signatures);
    }

    #[test]
    fn swallowed_corruption_is_caught_by_the_corruption_oracle() {
        let cfg = chaos_cfg(10, 5.0);
        let plan = corrupting_plan(&cfg);
        let opts = ChaosOptions { bug: Some(BugKind::SwallowCorruption), ..Default::default() };
        let out = run_chaos(&cfg, &plan, &opts, None).unwrap();
        assert!(
            out.violated_oracles().contains(&"payload-corruption-handled"),
            "bug must be caught: {:?}",
            out.violated_oracles()
        );
    }
}
