//! Shrink a failing fault plan to a minimal counterexample.
//!
//! Classic ddmin over the flat event list: repeatedly try removing chunks
//! of events (halves, then quarters, … down to single events) and keep any
//! reduction under which the run — restarted from the same seed — still
//! violates the same oracle. The result is 1-minimal: removing any single
//! remaining event makes the violation disappear.

use super::plan::FaultPlan;

/// Outcome of a shrink pass.
#[derive(Clone, Debug)]
pub struct ShrinkResult {
    /// The minimized plan (still failing).
    pub plan: FaultPlan,
    /// Events in the original plan.
    pub original_events: usize,
    /// Re-runs spent shrinking.
    pub runs: usize,
}

/// Minimize `plan` while `still_fails` holds. `still_fails` must re-run
/// the whole scenario deterministically from the plan's seed and report
/// whether the *same* oracle is still violated; it is assumed to hold for
/// `plan` itself. Cost is bounded by `max_runs` re-executions.
pub fn shrink_plan<F>(plan: &FaultPlan, max_runs: usize, mut still_fails: F) -> ShrinkResult
where
    F: FnMut(&FaultPlan) -> bool,
{
    let original_events = plan.events.len();
    let mut events = plan.events.clone();
    let mut runs = 0;
    let mut granularity = 2usize;

    while events.len() >= 2 && runs < max_runs {
        let chunk = (events.len() + granularity - 1) / granularity;
        let mut reduced = false;
        let mut start = 0;
        while start < events.len() && runs < max_runs {
            let end = (start + chunk).min(events.len());
            let mut candidate = events.clone();
            candidate.drain(start..end);
            runs += 1;
            if still_fails(&plan.with_events(candidate.clone())) {
                events = candidate;
                granularity = granularity.saturating_sub(1).max(2);
                reduced = true;
                break;
            }
            start = end;
        }
        if !reduced {
            if granularity >= events.len() {
                break; // 1-minimal: no single event can be removed
            }
            granularity = (granularity * 2).min(events.len());
        }
    }

    ShrinkResult { plan: plan.with_events(events), original_events, runs }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chaos::events::{ChaosEvent, TimedEvent};

    fn plan_with(n: usize) -> FaultPlan {
        let events = (0..n)
            .map(|i| TimedEvent { t: i, event: ChaosEvent::Crash { worker: i % 4 } })
            .collect();
        FaultPlan::empty(9, n).with_events(events)
    }

    #[test]
    fn shrinks_to_single_culprit() {
        // the "bug" fires iff the plan still contains one specific crash
        let plan = plan_with(12);
        let culprit =
            TimedEvent { t: 5, event: ChaosEvent::Crash { worker: 1 } };
        let mut plan = plan;
        plan.events[5] = culprit;
        let r = shrink_plan(&plan, 10_000, |p| p.events.contains(&culprit));
        assert_eq!(r.plan.events, vec![culprit]);
        assert_eq!(r.original_events, 12);
        assert!(r.runs > 0);
    }

    #[test]
    fn shrinks_conjunction_to_both_events() {
        // violation needs BOTH event 3 and event 9 (e.g. crash + recover
        // interplay); ddmin must keep exactly the pair
        let plan = plan_with(16);
        let a = plan.events[3];
        let b = plan.events[9];
        let r = shrink_plan(&plan, 10_000, |p| {
            p.events.contains(&a) && p.events.contains(&b)
        });
        assert_eq!(r.plan.events, vec![a, b]);
    }

    #[test]
    fn already_minimal_plan_is_kept() {
        let plan = plan_with(1);
        let r = shrink_plan(&plan, 100, |_| true);
        assert!(r.plan.events.len() <= 1);
    }

    #[test]
    fn run_budget_respected() {
        let plan = plan_with(64);
        let r = shrink_plan(&plan, 5, |p| !p.events.is_empty());
        assert!(r.runs <= 5);
    }
}
