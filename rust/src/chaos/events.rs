//! Fault-event vocabulary for the chaos engine.
//!
//! Every event is an *instantaneous* state change applied at the start of a
//! scheduling interval, before the broker takes its decisions. Durational
//! faults (a straggler episode, a blackout, a flash crowd) are expressed as
//! start/end event pairs at plan-generation time, which keeps plans flat —
//! the shrinker can delete any single event and still have a valid plan.

use crate::cluster::mobility::ChannelState;
use crate::sim::EngineCmd;
use crate::util::json::{JsonError, Value};

/// Topology racks per fleet. Fleets are built type-grouped (Table 3 order),
/// so a contiguous quarter of the worker range shares a failure domain —
/// power feed, ToR switch — the way same-SKU machines do in a real rack.
pub const RACKS: usize = 4;

/// Workers belonging to `rack` (contiguous quarter of an `n_workers` fleet).
/// Identical at plan-generation and event-application time, so a plan
/// generated for one fleet size names the same machines when replayed.
pub fn rack_members(n_workers: usize, rack: usize) -> std::ops::Range<usize> {
    let r = rack % RACKS;
    (r * n_workers / RACKS)..((r + 1) * n_workers / RACKS)
}

/// Initial rack of every worker — the contiguous-quarter assignment of
/// [`rack_members`] as a per-worker vector. Single source for the engine's
/// live `rack_of` state and the plan ledger's expected-rack mirror, so a
/// handoff oracle compares two structures seeded identically.
pub fn initial_racks(n_workers: usize) -> Vec<usize> {
    let mut racks = vec![0; n_workers];
    for r in 0..RACKS {
        for w in rack_members(n_workers, r) {
            racks[w] = r;
        }
    }
    racks
}

/// One injectable fault.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ChaosEvent {
    /// Hard worker crash: offline, resident containers requeued with
    /// progress lost (no checkpoint window).
    Crash { worker: usize },
    /// Crashed/offline worker rejoins the fleet.
    Recover { worker: usize },
    /// Straggler episode: scale the worker's MIPS by `factor`
    /// (thermal throttling / co-tenant interference); 1.0 ends the episode.
    Straggler { worker: usize, factor: f64 },
    /// Memory squeeze: scale the worker's effective RAM by `factor`
    /// (co-tenant balloon); 1.0 ends the episode.
    RamSqueeze { worker: usize, factor: f64 },
    /// Network blackout: pin the worker's channel at the worst state.
    Blackout { worker: usize },
    /// End of a blackout: the mobility model resumes.
    BlackoutEnd { worker: usize },
    /// Flash crowd: multiply the Poisson arrival rate λ.
    FlashCrowd { lambda_mult: f64 },
    /// End of a flash crowd: the configured λ resumes.
    FlashCrowdEnd,
    /// Correlated rack failure: hard-crash every worker in a topology
    /// rack (see [`rack_members`]) in one interval — shared power feed or
    /// ToR switch going down, progress lost on all of them at once.
    CorrelatedRackFailure { rack: usize },
    /// End of a rack failure: every member rejoins the fleet.
    RackRecover { rack: usize },
    /// Clock skew: the worker's clock drifts `offset_s` seconds from the
    /// broker's; coordination pays the skew on every transfer touching the
    /// worker. 0.0 ends the episode (clocks resynchronized).
    ClockSkew { worker: usize, offset_s: f64 },
    /// Payload corruption: a bit-flip/truncation hits every input payload
    /// currently staging toward the worker (rsync-through-disk has no
    /// end-to-end checksum). A corrupted transfer cannot produce valid
    /// output: the owning task must fail-and-penalize, never complete.
    PayloadCorruption { worker: usize },
    /// Mobility handoff: the worker migrates between topology racks
    /// mid-interval (a vehicle crossing cell boundaries re-associates with
    /// a new edge site). The worker stays online and keeps its containers,
    /// but it re-homes to `to_rack` and every in-flight transfer touching
    /// it stretches through the re-association (see
    /// [`crate::sim::EngineCmd::Handoff`]). A no-op unless the worker is
    /// currently in `from_rack` — stale handoffs from a reordered plan
    /// must not teleport workers.
    Handoff { worker: usize, from_rack: usize, to_rack: usize },
}

impl ChaosEvent {
    pub fn name(&self) -> &'static str {
        match self {
            ChaosEvent::Crash { .. } => "crash",
            ChaosEvent::Recover { .. } => "recover",
            ChaosEvent::Straggler { .. } => "straggler",
            ChaosEvent::RamSqueeze { .. } => "ram-squeeze",
            ChaosEvent::Blackout { .. } => "blackout",
            ChaosEvent::BlackoutEnd { .. } => "blackout-end",
            ChaosEvent::FlashCrowd { .. } => "flash-crowd",
            ChaosEvent::FlashCrowdEnd => "flash-crowd-end",
            ChaosEvent::CorrelatedRackFailure { .. } => "rack-failure",
            ChaosEvent::RackRecover { .. } => "rack-recover",
            ChaosEvent::ClockSkew { .. } => "clock-skew",
            ChaosEvent::PayloadCorruption { .. } => "payload-corruption",
            ChaosEvent::Handoff { .. } => "handoff",
        }
    }

    /// Compile this event to the typed engine commands it means — the
    /// single semantic source both for application (possibly mutated by an
    /// injected [`super::BugKind`]) and for the plan-state ledger the
    /// chaos oracles audit against. Events targeting workers outside an
    /// `n_workers` fleet compile to nothing (plans generated for a bigger
    /// fleet replay harmlessly). Flash crowds are broker-scoped (arrival
    /// rate), not engine commands, and also compile to nothing.
    pub fn compile(&self, n_workers: usize) -> Vec<EngineCmd> {
        if let Some(w) = self.worker() {
            if w >= n_workers {
                return Vec::new();
            }
        }
        match *self {
            ChaosEvent::Crash { worker } => vec![EngineCmd::Crash { worker }],
            ChaosEvent::Recover { worker } => vec![EngineCmd::Recover { worker }],
            ChaosEvent::Straggler { worker, factor } => {
                vec![EngineCmd::SetMipsFactor { worker, factor }]
            }
            ChaosEvent::RamSqueeze { worker, factor } => {
                vec![EngineCmd::SetRamFactor { worker, factor }]
            }
            ChaosEvent::Blackout { worker } => vec![EngineCmd::SetChannelOverride {
                worker,
                channel: Some(ChannelState::BLACKOUT),
            }],
            ChaosEvent::BlackoutEnd { worker } => {
                vec![EngineCmd::SetChannelOverride { worker, channel: None }]
            }
            ChaosEvent::FlashCrowd { .. } | ChaosEvent::FlashCrowdEnd => Vec::new(),
            ChaosEvent::CorrelatedRackFailure { rack } => rack_members(n_workers, rack)
                .map(|worker| EngineCmd::Crash { worker })
                .collect(),
            ChaosEvent::RackRecover { rack } => rack_members(n_workers, rack)
                .map(|worker| EngineCmd::Recover { worker })
                .collect(),
            ChaosEvent::ClockSkew { worker, offset_s } => {
                vec![EngineCmd::SetClockSkew { worker, skew_s: offset_s }]
            }
            ChaosEvent::PayloadCorruption { worker } => {
                vec![EngineCmd::CorruptPayload { worker }]
            }
            ChaosEvent::Handoff { worker, from_rack, to_rack } => {
                vec![EngineCmd::Handoff { worker, from_rack, to_rack }]
            }
        }
    }

    /// Target worker, if the event is worker-scoped.
    pub fn worker(&self) -> Option<usize> {
        match self {
            ChaosEvent::Crash { worker }
            | ChaosEvent::Recover { worker }
            | ChaosEvent::Straggler { worker, .. }
            | ChaosEvent::RamSqueeze { worker, .. }
            | ChaosEvent::Blackout { worker }
            | ChaosEvent::BlackoutEnd { worker }
            | ChaosEvent::ClockSkew { worker, .. }
            | ChaosEvent::PayloadCorruption { worker }
            | ChaosEvent::Handoff { worker, .. } => Some(*worker),
            _ => None,
        }
    }

    /// Target rack, if the event is rack-scoped.
    pub fn rack(&self) -> Option<usize> {
        match self {
            ChaosEvent::CorrelatedRackFailure { rack } | ChaosEvent::RackRecover { rack } => {
                Some(*rack)
            }
            _ => None,
        }
    }

    pub fn to_json(&self) -> Value {
        let mut kv = vec![("kind", Value::Str(self.name().into()))];
        if let Some(w) = self.worker() {
            kv.push(("worker", Value::Num(w as f64)));
        }
        if let Some(r) = self.rack() {
            kv.push(("rack", Value::Num(r as f64)));
        }
        match self {
            ChaosEvent::Straggler { factor, .. } | ChaosEvent::RamSqueeze { factor, .. } => {
                kv.push(("factor", Value::Num(*factor)));
            }
            ChaosEvent::FlashCrowd { lambda_mult } => {
                kv.push(("lambda_mult", Value::Num(*lambda_mult)));
            }
            ChaosEvent::ClockSkew { offset_s, .. } => {
                kv.push(("offset_s", Value::Num(*offset_s)));
            }
            ChaosEvent::Handoff { from_rack, to_rack, .. } => {
                kv.push(("from_rack", Value::Num(*from_rack as f64)));
                kv.push(("to_rack", Value::Num(*to_rack as f64)));
            }
            _ => {}
        }
        Value::obj(kv)
    }

    pub fn from_json(v: &Value) -> Result<ChaosEvent, JsonError> {
        let kind = v.req("kind")?.as_str()?;
        let worker = || -> Result<usize, JsonError> { v.req("worker")?.as_usize() };
        let factor = || -> Result<f64, JsonError> { v.req("factor")?.as_f64() };
        Ok(match kind {
            "crash" => ChaosEvent::Crash { worker: worker()? },
            "recover" => ChaosEvent::Recover { worker: worker()? },
            "straggler" => ChaosEvent::Straggler { worker: worker()?, factor: factor()? },
            "ram-squeeze" => ChaosEvent::RamSqueeze { worker: worker()?, factor: factor()? },
            "blackout" => ChaosEvent::Blackout { worker: worker()? },
            "blackout-end" => ChaosEvent::BlackoutEnd { worker: worker()? },
            "flash-crowd" => {
                ChaosEvent::FlashCrowd { lambda_mult: v.req("lambda_mult")?.as_f64()? }
            }
            "flash-crowd-end" => ChaosEvent::FlashCrowdEnd,
            "rack-failure" => ChaosEvent::CorrelatedRackFailure { rack: v.req("rack")?.as_usize()? },
            "rack-recover" => ChaosEvent::RackRecover { rack: v.req("rack")?.as_usize()? },
            "clock-skew" => ChaosEvent::ClockSkew {
                worker: worker()?,
                offset_s: v.req("offset_s")?.as_f64()?,
            },
            "payload-corruption" => ChaosEvent::PayloadCorruption { worker: worker()? },
            "handoff" => ChaosEvent::Handoff {
                worker: worker()?,
                from_rack: v.req("from_rack")?.as_usize()?,
                to_rack: v.req("to_rack")?.as_usize()?,
            },
            _ => return Err(JsonError::Type("known chaos event kind")),
        })
    }
}

/// An event scheduled at the start of interval `t`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TimedEvent {
    pub t: usize,
    pub event: ChaosEvent,
}

impl TimedEvent {
    pub fn to_json(&self) -> Value {
        let mut kv = vec![("t".to_string(), Value::Num(self.t as f64))];
        if let Value::Obj(ev) = self.event.to_json() {
            kv.extend(ev);
        }
        Value::Obj(kv)
    }

    pub fn from_json(v: &Value) -> Result<TimedEvent, JsonError> {
        Ok(TimedEvent { t: v.req("t")?.as_usize()?, event: ChaosEvent::from_json(v)? })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn event_json_roundtrip() {
        let events = [
            ChaosEvent::Crash { worker: 3 },
            ChaosEvent::Recover { worker: 3 },
            ChaosEvent::Straggler { worker: 1, factor: 0.25 },
            ChaosEvent::RamSqueeze { worker: 0, factor: 0.5 },
            ChaosEvent::Blackout { worker: 7 },
            ChaosEvent::BlackoutEnd { worker: 7 },
            ChaosEvent::FlashCrowd { lambda_mult: 4.0 },
            ChaosEvent::FlashCrowdEnd,
            ChaosEvent::CorrelatedRackFailure { rack: 2 },
            ChaosEvent::RackRecover { rack: 2 },
            ChaosEvent::ClockSkew { worker: 4, offset_s: 37.5 },
            ChaosEvent::PayloadCorruption { worker: 6 },
            ChaosEvent::Handoff { worker: 5, from_rack: 2, to_rack: 0 },
        ];
        for (i, e) in events.iter().enumerate() {
            let te = TimedEvent { t: i, event: *e };
            let j = te.to_json().to_string();
            let back = TimedEvent::from_json(&json::parse(&j).unwrap()).unwrap();
            assert_eq!(back, te, "roundtrip of {j}");
        }
    }

    #[test]
    fn bad_event_rejected() {
        let v = json::parse(r#"{"t":0,"kind":"meteor-strike"}"#).unwrap();
        assert!(TimedEvent::from_json(&v).is_err());
        let v = json::parse(r#"{"t":0,"kind":"crash"}"#).unwrap();
        assert!(TimedEvent::from_json(&v).is_err(), "crash needs a worker");
        let v = json::parse(r#"{"t":0,"kind":"rack-failure"}"#).unwrap();
        assert!(TimedEvent::from_json(&v).is_err(), "rack failure needs a rack");
        let v = json::parse(r#"{"t":0,"kind":"clock-skew","worker":1}"#).unwrap();
        assert!(TimedEvent::from_json(&v).is_err(), "clock skew needs an offset");
        let v = json::parse(r#"{"t":0,"kind":"handoff","worker":1,"from_rack":0}"#).unwrap();
        assert!(TimedEvent::from_json(&v).is_err(), "handoff needs both racks");
    }

    #[test]
    fn racks_partition_the_fleet() {
        for n in [1usize, 4, 10, 50, 51] {
            let mut covered = vec![false; n];
            for r in 0..RACKS {
                for w in rack_members(n, r) {
                    assert!(!covered[w], "worker {w} in two racks (n={n})");
                    covered[w] = true;
                }
            }
            assert!(covered.iter().all(|&c| c), "racks must cover the fleet (n={n})");
        }
        // rack index wraps so plans survive fleet-size changes
        assert_eq!(rack_members(10, 5), rack_members(10, 1));
    }

    #[test]
    fn events_compile_to_their_engine_commands() {
        use crate::sim::EngineCmd;
        assert_eq!(
            ChaosEvent::Crash { worker: 3 }.compile(10),
            vec![EngineCmd::Crash { worker: 3 }]
        );
        assert_eq!(
            ChaosEvent::ClockSkew { worker: 1, offset_s: 30.0 }.compile(10),
            vec![EngineCmd::SetClockSkew { worker: 1, skew_s: 30.0 }]
        );
        assert_eq!(
            ChaosEvent::PayloadCorruption { worker: 2 }.compile(10),
            vec![EngineCmd::CorruptPayload { worker: 2 }]
        );
        // rack events fan out to one command per member
        let rack = ChaosEvent::CorrelatedRackFailure { rack: 0 }.compile(8);
        assert_eq!(rack.len(), rack_members(8, 0).len());
        assert!(rack.iter().all(|c| matches!(c, EngineCmd::Crash { .. })));
        // handoffs compile to the single typed command, racks included
        assert_eq!(
            ChaosEvent::Handoff { worker: 4, from_rack: 1, to_rack: 3 }.compile(10),
            vec![EngineCmd::Handoff { worker: 4, from_rack: 1, to_rack: 3 }]
        );
        // broker-scoped and out-of-range events compile to nothing
        assert!(ChaosEvent::FlashCrowd { lambda_mult: 4.0 }.compile(10).is_empty());
        assert!(ChaosEvent::FlashCrowdEnd.compile(10).is_empty());
        assert!(ChaosEvent::Crash { worker: 50 }.compile(10).is_empty());
        assert!(
            ChaosEvent::Handoff { worker: 50, from_rack: 0, to_rack: 1 }.compile(10).is_empty()
        );
    }
}
