//! Interval invariant oracles.
//!
//! After every simulated interval the chaos harness evaluates a fixed set
//! of *named* invariants against the engine state and the interval report.
//! Any violation is a bug — either in the simulator/broker or in a fault
//! hook — and carries enough detail to debug it; the harness then shrinks
//! the fault plan to a minimal reproduction (see [`super::shrink`]).

use std::collections::HashSet;

use crate::sim::{
    ContainerState, Effect, Engine, EngineCmd, FaultSurface, IntervalReport, RAM_OVERCOMMIT,
};

/// All invariant names, in evaluation order.
pub const ORACLES: [&str; 13] = [
    "task-conservation",
    "allocation-capacity",
    "chain-precedence",
    "task-times-sane",
    "energy-sane",
    "mab-accounting",
    "crashed-workers-idle",
    "telemetry-consistent",
    "completion-unique",
    "offline-matches-plan",
    "clock-skew-applied",
    "payload-corruption-handled",
    "ledger-replay-consistent",
];

pub fn describe(oracle: &str) -> &'static str {
    match oracle {
        "task-conservation" => "admitted = active + completed + failed, always",
        "allocation-capacity" => "resident RAM never exceeds the overcommit cap at allocation",
        "chain-precedence" => "no fragment progresses before its chain predecessor completes",
        "task-times-sane" => "response/wait/exec/transfer/migrate are finite and non-negative",
        "energy-sane" => "interval energy, AEC and utilization are finite and in range",
        "mab-accounting" => "bandit decision counts sum to decisions actually taken",
        "crashed-workers-idle" => "no container runs, stages or migrates on an offline worker",
        "telemetry-consistent" => "reported queue/offline figures match engine state",
        "completion-unique" => "every completion names a known task, at most once",
        "offline-matches-plan" => {
            "worker availability equals the fault plan's crash/rack ledger (churn-free runs)"
        }
        "clock-skew-applied" => "engine clock skew equals the plan's active skew, per worker",
        "payload-corruption-handled" => {
            "every task the command ledger marks payload-corrupted is failed, never completed"
        }
        "ledger-replay-consistent" => {
            "replaying the engine's own command ledger onto a fresh surface reproduces its \
             online/mips/ram/skew state"
        }
        _ => "unknown invariant",
    }
}

/// One invariant violation.
#[derive(Clone, Debug)]
pub struct Violation {
    pub oracle: &'static str,
    pub interval: usize,
    pub detail: String,
}

// ---------------------------------------------------------------------------
// Scan-vs-index oracle derivations
//
// The `chain-precedence` and `crashed-workers-idle` sweeps are the two
// oracles the ROADMAP plans to migrate from full-pool scans onto the
// engine's active-set index. Until the migration lands, both derivations
// are kept public and a property test asserts they agree after every
// interval of a chaos run — the evidence that switching the sweep to
// O(active) changes cost, not verdicts, on a correct engine.
//
// Equivalence caveat the migration must respect: `crashed-workers-idle`
// only ever flags non-terminal states, so its index twin is exactly
// equivalent by construction. `chain-precedence`'s full scan can ALSO
// flag a Done/Failed container whose `mi_done > 0` predates an unfinished
// predecessor — a broken engine that lets a successor finish out of order
// keeps failing the full scan forever, while the index twin only sees the
// violation while the container is live. Flipping `check_interval` to the
// indexed twin therefore trades that post-hoc memory for O(active); keep
// the full scan (or a terminal-transition check) if that memory matters.
// ---------------------------------------------------------------------------

/// `chain-precedence` details over an arbitrary container visit sequence.
fn chain_precedence_over<'c>(
    engine: &Engine,
    containers: impl Iterator<Item = &'c crate::sim::Container>,
) -> Vec<String> {
    let mut out = Vec::new();
    for c in containers {
        if let Some(prev) = c.prev {
            let prev_done = engine.containers()[prev].is_done();
            if c.mi_done > 0.0 && !prev_done {
                out.push(format!(
                    "container {} progressed before predecessor {prev} finished",
                    c.id
                ));
            }
            if matches!(c.state, ContainerState::Running) && !prev_done {
                out.push(format!(
                    "container {} running before predecessor {prev} done",
                    c.id
                ));
            }
        }
    }
    out
}

/// `chain-precedence` from the full container pool (the current oracle).
pub fn chain_precedence_full(engine: &Engine) -> Vec<String> {
    chain_precedence_over(engine, engine.containers().iter())
}

/// `chain-precedence` from the active-set index: O(active), same id visit
/// order as the full scan over the LIVE containers. Equivalent to
/// [`chain_precedence_full`] on a correct engine; see the section comment
/// for the terminal-container caveat a migration must respect.
pub fn chain_precedence_indexed(engine: &Engine) -> Vec<String> {
    chain_precedence_over(
        engine,
        engine.active_ids().iter().map(|&cid| &engine.containers()[cid]),
    )
}

/// `crashed-workers-idle` details over an arbitrary container visit
/// sequence: no container may run, stage or migrate on an offline worker.
fn crashed_workers_idle_over<'c>(
    engine: &Engine,
    containers: impl Iterator<Item = &'c crate::sim::Container>,
) -> Vec<String> {
    let online = engine.online();
    let mut out = Vec::new();
    for c in containers {
        let offending = match c.state {
            ContainerState::Running | ContainerState::Transferring { .. } => {
                c.worker.map(|w| !online[w]).unwrap_or(false)
            }
            ContainerState::Migrating { to, .. } => {
                !online[to] || c.worker.map(|w| !online[w]).unwrap_or(false)
            }
            _ => false,
        };
        if offending {
            out.push(format!(
                "container {} is {:?} on offline worker {:?}",
                c.id, c.state, c.worker
            ));
        }
    }
    out
}

/// `crashed-workers-idle` from the full container pool (the current oracle).
pub fn crashed_workers_idle_full(engine: &Engine) -> Vec<String> {
    crashed_workers_idle_over(engine, engine.containers().iter())
}

/// `crashed-workers-idle` from the active-set index: every offending state
/// (Running/Transferring/Migrating) is non-terminal, so the index covers
/// exactly the containers the full scan can flag, in the same id order.
pub fn crashed_workers_idle_indexed(engine: &Engine) -> Vec<String> {
    crashed_workers_idle_over(
        engine,
        engine.active_ids().iter().map(|&cid| &engine.containers()[cid]),
    )
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "[{}] interval {}: {}", self.oracle, self.interval, self.detail)
    }
}

/// Everything an interval check can see. `seen_completed` persists across
/// intervals (the harness owns it) so duplicate completions are caught.
pub struct OracleCtx<'a> {
    pub engine: &'a Engine,
    pub report: &'a IntervalReport,
    /// Tasks admitted by the broker since construction.
    pub admitted: u64,
    /// MAB decisions recorded by the bandit since harness start (current
    /// count sum minus the warm-start baseline); None for non-MAB policies.
    pub mab_decisions: Option<u64>,
    pub seen_completed: &'a mut HashSet<u64>,
    /// Per-worker offline expectation replayed from the fault plan's
    /// bug-free compiled commands (see [`super::PlanLedger`]). None when
    /// the engine can legitimately toggle availability on its own (churn
    /// enabled), which would make the comparison meaningless.
    pub expected_offline: Option<&'a [bool]>,
    /// Per-worker clock-skew seconds the plan currently holds active
    /// (post-clamp); None disables the check.
    pub expected_skew: Option<&'a [f64]>,
}

/// Evaluate every oracle; returns all violations found this interval.
pub fn check_interval(ctx: &mut OracleCtx) -> Vec<Violation> {
    let mut out = Vec::new();
    let t = ctx.report.interval;
    let mut fail = |oracle: &'static str, detail: String| {
        out.push(Violation { oracle, interval: t, detail });
    };

    // -- task-conservation --------------------------------------------------
    // Cross-structure checks (the task-map partition active/completed/
    // failed is exhaustive by construction, so comparing those counts to
    // each other would be a tautology): the broker's admission count, the
    // engine's task map, and the container pool must all agree.
    let admitted = ctx.engine.admitted_task_count();
    if admitted as u64 != ctx.admitted {
        fail(
            "task-conservation",
            format!("engine tracks {admitted} tasks but broker admitted {}", ctx.admitted),
        );
    }
    let container_tasks: HashSet<u64> =
        ctx.engine.containers().iter().map(|c| c.task_id).collect();
    if container_tasks.len() != admitted {
        fail(
            "task-conservation",
            format!(
                "containers reference {} distinct tasks but {admitted} were admitted",
                container_tasks.len()
            ),
        );
    }
    for id in &container_tasks {
        if ctx.engine.task(*id).is_none() {
            fail("task-conservation", format!("container references unknown task {id}"));
        }
    }

    // -- allocation-capacity ------------------------------------------------
    // Every path into residency is capacity-checked (placement and
    // migration via `fits`, chain unblocks via the Blocked reservation
    // that already counts), and squeezes only shrink the effective cap
    // below the physical one — so resident demand must NEVER exceed the
    // physical overcommit cap, not even by a single container.
    let resident = ctx.engine.resident_ram();
    for (w, worker) in ctx.engine.cluster.workers.iter().enumerate() {
        let cap = worker.spec.ram_mb * RAM_OVERCOMMIT;
        if resident[w] > cap + 1e-6 {
            fail(
                "allocation-capacity",
                format!("worker {w}: resident {:.0} MB > cap {cap:.0} MB", resident[w]),
            );
        }
    }

    // -- chain-precedence ---------------------------------------------------
    // Full-pool derivation; the index-backed twin must agree (see the
    // scan-vs-index section above and tests/properties.rs).
    for detail in chain_precedence_full(ctx.engine) {
        fail("chain-precedence", detail);
    }

    // -- task-times-sane ----------------------------------------------------
    for task in &ctx.report.completed {
        let parts = [
            ("response", task.response),
            ("wait", task.wait),
            ("exec", task.exec),
            ("transfer", task.transfer),
            ("migrate", task.migrate),
        ];
        for (name, v) in parts {
            if !v.is_finite() || v < 0.0 {
                fail(
                    "task-times-sane",
                    format!("task {}: {name} = {v}", task.task_id),
                );
            }
        }
        if task.response <= 0.0 {
            fail(
                "task-times-sane",
                format!("task {}: non-positive response {}", task.task_id, task.response),
            );
        }
    }
    for task in &ctx.report.failed {
        if !task.age.is_finite() || task.age < 0.0 {
            fail("task-times-sane", format!("failed task {}: age {}", task.task_id, task.age));
        }
    }

    // -- energy-sane --------------------------------------------------------
    if !ctx.report.energy_wh.is_finite() || ctx.report.energy_wh < 0.0 {
        fail("energy-sane", format!("energy_wh = {}", ctx.report.energy_wh));
    }
    if !ctx.report.aec.is_finite() || ctx.report.aec < 0.0 {
        fail("energy-sane", format!("aec = {}", ctx.report.aec));
    }
    for (w, s) in ctx.report.snapshots.iter().enumerate() {
        if !(0.0..=1.0).contains(&s.cpu) || !s.ram.is_finite() || s.ram < 0.0 {
            fail("energy-sane", format!("worker {w}: cpu {} ram {}", s.cpu, s.ram));
        }
    }

    // -- mab-accounting -----------------------------------------------------
    if let Some(decided) = ctx.mab_decisions {
        if decided != ctx.admitted {
            fail(
                "mab-accounting",
                format!("bandit recorded {decided} decisions, broker admitted {}", ctx.admitted),
            );
        }
    }

    // -- crashed-workers-idle -----------------------------------------------
    // Full-pool derivation; the index-backed twin must agree (see above).
    for detail in crashed_workers_idle_full(ctx.engine) {
        fail("crashed-workers-idle", detail);
    }

    // -- telemetry-consistent -----------------------------------------------
    let online = ctx.engine.online();
    let queued_now = ctx
        .engine
        .containers()
        .iter()
        .filter(|c| matches!(c.state, ContainerState::Queued))
        .count();
    if queued_now != ctx.report.queued {
        fail(
            "telemetry-consistent",
            format!("report says {} queued, engine holds {queued_now}", ctx.report.queued),
        );
    }
    let offline_now = online.iter().filter(|&&o| !o).count();
    if offline_now != ctx.report.offline {
        fail(
            "telemetry-consistent",
            format!("report says {} offline, engine has {offline_now}", ctx.report.offline),
        );
    }

    // -- offline-matches-plan -----------------------------------------------
    // Replaying the plan's crash/recover/rack ledger must land on exactly
    // the engine's availability vector — a rack failure that "forgets" a
    // member, or a recovery that revives the wrong machine, shows up here
    // even while the fleet is idle (crashed-workers-idle can't see those).
    if let Some(expected) = ctx.expected_offline {
        for (w, &exp_off) in expected.iter().enumerate().take(online.len()) {
            if exp_off == online[w] {
                fail(
                    "offline-matches-plan",
                    format!(
                        "worker {w}: plan says {}, engine says {}",
                        if exp_off { "offline" } else { "online" },
                        if online[w] { "online" } else { "offline" }
                    ),
                );
            }
        }
    }

    // -- clock-skew-applied -------------------------------------------------
    if let Some(expected) = ctx.expected_skew {
        for (w, &exp_skew) in expected.iter().enumerate() {
            let got = ctx.engine.clock_skew(w);
            if (got - exp_skew).abs() > 1e-9 {
                fail(
                    "clock-skew-applied",
                    format!("worker {w}: plan holds skew {exp_skew}s, engine applies {got}s"),
                );
            }
        }
    }

    // -- payload-corruption-handled -----------------------------------------
    // Audits the engine's own command ledger: every task a corruption
    // command reported as affected must be failed by now — a "swallowed"
    // corruption (missing checksum) leaves it active or lets it complete,
    // and keeps this firing every interval until fixed.
    for rec in ctx.engine.ledger() {
        let corrupting = matches!(
            rec.cmd,
            EngineCmd::CorruptPayload { .. } | EngineCmd::CorruptPayloadSwallowed { .. }
        );
        if !corrupting {
            continue;
        }
        let Effect::Affected { tasks } = &rec.effect else {
            continue;
        };
        for &id in tasks {
            if !ctx.engine.task_failed(id) {
                fail(
                    "payload-corruption-handled",
                    format!(
                        "task {id}: payload corrupted at interval {} but the task is not failed",
                        rec.interval
                    ),
                );
            }
        }
    }

    // -- completion-unique --------------------------------------------------
    for task in &ctx.report.completed {
        if ctx.engine.task(task.task_id).is_none() {
            fail(
                "completion-unique",
                format!("completion for unknown task {}", task.task_id),
            );
        }
        if !ctx.seen_completed.insert(task.task_id) {
            fail(
                "completion-unique",
                format!("task {} completed twice", task.task_id),
            );
        }
    }

    // -- ledger-replay-consistent -------------------------------------------
    // The command bus is the ONLY mutation path for the fault surface, so
    // a fresh replay of the engine's own ledger (churn toggles included —
    // they are bus-routed) must land on exactly the live surface. A
    // command that mutated state without recording it, or recorded an
    // effect it did not apply, diverges here. Float fields compare exactly:
    // replay mirrors the engine's own clamp arithmetic.
    let replayed = FaultSurface::replay(ctx.engine.workers(), ctx.engine.ledger());
    let live = ctx.engine.fault_surface();
    if replayed != live {
        let diff = (0..ctx.engine.workers())
            .find_map(|w| {
                let fields = [
                    ("online", replayed.online[w] != live.online[w]),
                    ("mips", replayed.mips_factor[w] != live.mips_factor[w]),
                    ("ram", replayed.ram_factor[w] != live.ram_factor[w]),
                    ("skew", replayed.clock_skew_s[w] != live.clock_skew_s[w]),
                ];
                fields.iter().find(|(_, d)| *d).map(|(name, _)| format!("worker {w}: {name}"))
            })
            .unwrap_or_else(|| "churn rate".into());
        fail(
            "ledger-replay-consistent",
            format!(
                "replaying {} ledger commands does not reproduce the fault surface ({diff})",
                ctx.engine.ledger().len()
            ),
        );
    }

    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::build_fleet;
    use crate::config::{ClusterConfig, SimConfig};
    use crate::sim::Engine;
    use crate::splits::{App, SplitDecision};
    use crate::workload::Task;

    fn engine() -> Engine {
        Engine::new(build_fleet(&ClusterConfig::small()), SimConfig::default(), 1)
    }

    fn task(id: u64) -> Task {
        Task { id, app: App::Mnist, batch: 32_000, sla: 5.0, arrival_s: 0.0, decision: None }
    }

    #[test]
    fn clean_interval_has_no_violations() {
        let mut e = engine();
        e.admit(task(0), SplitDecision::Compressed);
        e.apply_placement(&[(0, 0)]);
        let report = e.step_interval();
        let mut seen = HashSet::new();
        let mut ctx = OracleCtx {
            engine: &e,
            report: &report,
            admitted: 1,
            mab_decisions: None,
            seen_completed: &mut seen,
            expected_offline: None,
            expected_skew: None,
        };
        let v = check_interval(&mut ctx);
        assert!(v.is_empty(), "unexpected violations: {v:?}");
    }

    #[test]
    fn admission_mismatch_is_caught() {
        let mut e = engine();
        e.admit(task(0), SplitDecision::Compressed);
        let report = e.step_interval();
        let mut seen = HashSet::new();
        let mut ctx = OracleCtx {
            engine: &e,
            report: &report,
            admitted: 5, // broker claims more than the engine holds
            mab_decisions: None,
            seen_completed: &mut seen,
            expected_offline: None,
            expected_skew: None,
        };
        let v = check_interval(&mut ctx);
        assert!(v.iter().any(|v| v.oracle == "task-conservation"), "{v:?}");
    }

    #[test]
    fn progress_on_crashed_worker_is_caught() {
        let mut e = engine();
        e.admit(task(0), SplitDecision::Compressed);
        e.apply_placement(&[(0, 0)]);
        e.step_interval();
        // the deliberate bug hook: offline without evicting
        e.apply(EngineCmd::ForceOfflineNoEvict { worker: 0 });
        let report = e.step_interval();
        let mut seen = HashSet::new();
        let mut ctx = OracleCtx {
            engine: &e,
            report: &report,
            admitted: 1,
            mab_decisions: None,
            seen_completed: &mut seen,
            expected_offline: None,
            expected_skew: None,
        };
        let v = check_interval(&mut ctx);
        assert!(v.iter().any(|v| v.oracle == "crashed-workers-idle"), "{v:?}");
    }

    #[test]
    fn duplicate_completion_is_caught() {
        let mut e = engine();
        e.admit(task(0), SplitDecision::Compressed);
        e.apply_placement(&[(0, 0)]);
        let mut report = None;
        for _ in 0..40 {
            let r = e.step_interval();
            if !r.completed.is_empty() {
                report = Some(r);
                break;
            }
        }
        let report = report.expect("compressed task completes");
        let mut seen = HashSet::new();
        seen.insert(report.completed[0].task_id); // pretend we saw it before
        let mut ctx = OracleCtx {
            engine: &e,
            report: &report,
            admitted: 1,
            mab_decisions: None,
            seen_completed: &mut seen,
            expected_offline: None,
            expected_skew: None,
        };
        let v = check_interval(&mut ctx);
        assert!(v.iter().any(|v| v.oracle == "completion-unique"), "{v:?}");
    }

    #[test]
    fn offline_mismatch_against_plan_is_caught() {
        let mut e = engine();
        e.apply(EngineCmd::Crash { worker: 1 });
        let report = e.step_interval();
        let mut seen = HashSet::new();
        // plan ledger says workers 1 AND 2 should be down — a rack failure
        // that only took one member offline
        let mut expected = vec![false; e.workers()];
        expected[1] = true;
        expected[2] = true;
        let mut ctx = OracleCtx {
            engine: &e,
            report: &report,
            admitted: 0,
            mab_decisions: None,
            seen_completed: &mut seen,
            expected_offline: Some(&expected),
            expected_skew: None,
        };
        let v = check_interval(&mut ctx);
        assert!(v.iter().any(|v| v.oracle == "offline-matches-plan"), "{v:?}");
        assert!(
            v.iter().all(|v| v.oracle != "offline-matches-plan" || v.detail.contains("worker 2")),
            "only the forgotten member may be flagged: {v:?}"
        );
    }

    #[test]
    fn clock_skew_mismatch_is_caught_and_match_is_green() {
        let mut e = engine();
        e.apply(EngineCmd::SetClockSkew { worker: 3, skew_s: 42.0 });
        let report = e.step_interval();
        let mut expected = vec![0.0; e.workers()];
        expected[3] = 42.0;
        {
            let mut seen = HashSet::new();
            let mut ctx = OracleCtx {
                engine: &e,
                report: &report,
                admitted: 0,
                mab_decisions: None,
                seen_completed: &mut seen,
                expected_offline: None,
                expected_skew: Some(&expected),
            };
            let v = check_interval(&mut ctx);
            assert!(v.is_empty(), "matching skew must stay green: {v:?}");
        }
        expected[3] = 0.0; // plan says the episode ended; engine still skewed
        let mut seen = HashSet::new();
        let mut ctx = OracleCtx {
            engine: &e,
            report: &report,
            admitted: 0,
            mab_decisions: None,
            seen_completed: &mut seen,
            expected_offline: None,
            expected_skew: Some(&expected),
        };
        let v = check_interval(&mut ctx);
        assert!(v.iter().any(|v| v.oracle == "clock-skew-applied"), "{v:?}");
    }

    #[test]
    fn swallowed_corruption_is_caught_and_handled_corruption_is_green() {
        let mk = |swallow: bool| -> Vec<Violation> {
            let mut e = engine();
            e.admit(task(0), SplitDecision::Compressed);
            e.apply_placement(&[(0, 0)]); // transfer now staging toward 0
            if swallow {
                e.apply(EngineCmd::CorruptPayloadSwallowed { worker: 0 });
            } else {
                e.apply(EngineCmd::CorruptPayload { worker: 0 });
            }
            let report = e.step_interval();
            let mut seen = HashSet::new();
            let mut ctx = OracleCtx {
                engine: &e,
                report: &report,
                admitted: 1,
                mab_decisions: None,
                seen_completed: &mut seen,
                expected_offline: None,
                expected_skew: None,
            };
            check_interval(&mut ctx)
        };
        let v = mk(false);
        assert!(v.is_empty(), "handled corruption must stay green: {v:?}");
        let v = mk(true);
        assert!(
            v.iter().any(|v| v.oracle == "payload-corruption-handled"),
            "swallowed corruption must be caught: {v:?}"
        );
    }

    #[test]
    fn ledger_replay_oracle_matches_on_a_faulted_engine_and_catches_divergence() {
        let mut e = engine();
        e.apply(EngineCmd::Crash { worker: 1 });
        e.apply(EngineCmd::SetMipsFactor { worker: 2, factor: 0.4 });
        e.apply(EngineCmd::SetClockSkew { worker: 3, skew_s: 42.0 });
        let report = e.step_interval();
        let mut seen = HashSet::new();
        let mut ctx = OracleCtx {
            engine: &e,
            report: &report,
            admitted: 0,
            mab_decisions: None,
            seen_completed: &mut seen,
            expected_offline: None,
            expected_skew: None,
        };
        let v = check_interval(&mut ctx);
        assert!(v.is_empty(), "bus-routed mutations must replay cleanly: {v:?}");
        // divergence detection is covered structurally: FaultSurface::replay
        // of a truncated ledger must differ from the live surface
        let truncated =
            crate::sim::FaultSurface::replay(e.workers(), &e.ledger()[..1]);
        assert_ne!(truncated, e.fault_surface(), "truncation must be visible");
    }

    #[test]
    fn every_oracle_has_a_description() {
        for o in ORACLES {
            assert_ne!(describe(o), "");
        }
    }

    /// The scan-vs-index twins agree — on a healthy engine (both empty)
    /// and on a sabotaged one (both flag the same containers, in the same
    /// order). Groundwork for the ROADMAP's oracle migration; the
    /// per-interval sweep lives in tests/properties.rs.
    #[test]
    fn indexed_oracle_derivations_match_the_full_scans() {
        let mut e = engine();
        e.admit(task(0), SplitDecision::Layer);
        e.admit(task(1), SplitDecision::Compressed);
        e.apply_placement(&[(0, 0), (1, 1), (2, 2), (3, 3)]);
        e.step_interval();
        assert_eq!(chain_precedence_full(&e), chain_precedence_indexed(&e));
        assert_eq!(crashed_workers_idle_full(&e), crashed_workers_idle_indexed(&e));
        assert!(crashed_workers_idle_full(&e).is_empty());
        // force the bug hook: containers keep working on a dead machine
        for w in 0..e.workers() {
            e.apply(EngineCmd::ForceOfflineNoEvict { worker: w });
        }
        e.step_interval();
        let full = crashed_workers_idle_full(&e);
        assert!(!full.is_empty(), "offline-no-evict must leave offenders");
        assert_eq!(full, crashed_workers_idle_indexed(&e));
        assert_eq!(chain_precedence_full(&e), chain_precedence_indexed(&e));
    }
}
