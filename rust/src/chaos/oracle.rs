//! Interval invariant oracles.
//!
//! After every simulated interval the chaos harness evaluates a fixed set
//! of *named* invariants against the engine state and the interval report.
//! Any violation is a bug — either in the simulator/broker or in a fault
//! hook — and carries enough detail to debug it; the harness then shrinks
//! the fault plan to a minimal reproduction (see [`super::shrink`]).
//!
//! **The hot path is O(active).** [`check_interval`] never scans the full
//! container pool or the full command ledger: container sweeps walk
//! [`Engine::active_ids`] (plus the chain-precedence terminal latch),
//! capacity checks read the per-worker residency sums, and the two
//! ledger-audit oracles fold only the records appended since the previous
//! interval through a cursor carried in [`OracleState`]. The retained
//! `*_full` twins re-derive each verdict from a full scan; they run only
//! under `--paranoid` (see [`OracleCtx::paranoid`]) and in the
//! scan-vs-index property tests.

use std::collections::HashSet;

use crate::sim::{
    Container, ContainerState, Effect, Engine, EngineCmd, FaultSurface, HandoffAudit,
    IntervalReport, RAM_OVERCOMMIT,
};

/// All invariant names, in evaluation order.
pub const ORACLES: [&str; 14] = [
    "task-conservation",
    "allocation-capacity",
    "chain-precedence",
    "task-times-sane",
    "energy-sane",
    "mab-accounting",
    "crashed-workers-idle",
    "telemetry-consistent",
    "completion-unique",
    "offline-matches-plan",
    "clock-skew-applied",
    "payload-corruption-handled",
    "ledger-replay-consistent",
    "handoff-preserves-progress",
];

pub fn describe(oracle: &str) -> &'static str {
    match oracle {
        "task-conservation" => "admitted = active + completed + failed, always",
        "allocation-capacity" => "resident RAM never exceeds the overcommit cap at allocation",
        "chain-precedence" => "no fragment progresses before its chain predecessor completes",
        "task-times-sane" => "response/wait/exec/transfer/migrate are finite and non-negative",
        "energy-sane" => "interval energy, AEC and utilization are finite and in range",
        "mab-accounting" => "bandit decision counts sum to decisions actually taken",
        "crashed-workers-idle" => "no container runs, stages or migrates on an offline worker",
        "telemetry-consistent" => "reported queue/offline figures match engine state",
        "completion-unique" => "every completion names a known task, at most once",
        "offline-matches-plan" => {
            "worker availability equals the fault plan's crash/rack ledger (churn-free runs)"
        }
        "clock-skew-applied" => "engine clock skew equals the plan's active skew, per worker",
        "payload-corruption-handled" => {
            "every task the command ledger marks payload-corrupted is failed, never completed"
        }
        "ledger-replay-consistent" => {
            "replaying the engine's own command ledger onto a fresh surface reproduces its \
             online/mips/ram/skew state"
        }
        "handoff-preserves-progress" => {
            "mobility handoffs keep rack state in lockstep with the plan, audit cleanly, \
             and never lose recorded container progress"
        }
        "paranoid-divergence" => {
            "full-scan and index-backed oracle derivations returned different verdicts \
             (--paranoid cross-check; not one of the 14 invariants)"
        }
        _ => "unknown invariant",
    }
}

/// One invariant violation.
#[derive(Clone, Debug)]
pub struct Violation {
    pub oracle: &'static str,
    pub interval: usize,
    pub detail: String,
}

// ---------------------------------------------------------------------------
// Scan-vs-index oracle twins
//
// Every container-sweep oracle exists in two derivations: the `*_full`
// twin re-scans the entire pool (every container ever admitted — the
// pre-migration oracles), the `*_indexed` twin walks the engine's
// O(active) indexes in the same ascending-id order. `check_interval`
// runs ONLY the indexed twins; the full twins survive for the
// `--paranoid` side-by-side cross-check and the property tests in
// tests/properties.rs.
//
// `chain-precedence` is the one oracle whose full scan sees state the
// active set cannot: a Done/Failed container whose `mi_done > 0` predates
// an unfinished predecessor keeps failing the full scan after it leaves
// the active list. The engine closes that gap with a terminal-transition
// latch (`Engine::chain_suspects`): `set_container` records, at the
// moment a container goes terminal, whether it got ahead of an unfinished
// predecessor — predecessor done-ness is monotone, so latching at the
// transition captures exactly the offenders the full scan can ever flag
// post-hoc. The indexed sweep visits the merge of the active list and the
// latch and is therefore *exactly* equal to the full scan, terminal
// memory included, on correct and sabotaged engines alike.
// ---------------------------------------------------------------------------

/// `chain-precedence` details over an arbitrary container visit sequence.
fn chain_precedence_over<'c>(
    engine: &Engine,
    containers: impl Iterator<Item = &'c Container>,
) -> Vec<String> {
    let mut out = Vec::new();
    for c in containers {
        if let Some(prev) = c.prev {
            let prev_done = engine.containers()[prev].is_done();
            if c.mi_done > 0.0 && !prev_done {
                out.push(format!(
                    "container {} progressed before predecessor {prev} finished",
                    c.id
                ));
            }
            if matches!(c.state, ContainerState::Running) && !prev_done {
                out.push(format!(
                    "container {} running before predecessor {prev} done",
                    c.id
                ));
            }
        }
    }
    out
}

/// `chain-precedence` from the full container pool (the paranoid twin).
pub fn chain_precedence_full(engine: &Engine) -> Vec<String> {
    chain_precedence_over(engine, engine.containers().iter())
}

/// `chain-precedence` from the active-set index merged with the
/// terminal-transition latch, in ascending id order — the hot-path
/// derivation. Exactly equal to [`chain_precedence_full`] (see the
/// section comment): live offenders come from the active list, terminal
/// offenders from [`Engine::chain_suspects`], and both lists are
/// id-sorted and disjoint so the merge reproduces the full scan's visit
/// order over every container that can produce a detail.
pub fn chain_precedence_indexed(engine: &Engine) -> Vec<String> {
    let active = engine.active_ids();
    let latched = engine.chain_suspects();
    let mut merged = Vec::with_capacity(active.len() + latched.len());
    let (mut i, mut j) = (0, 0);
    while i < active.len() && j < latched.len() {
        if active[i] < latched[j] {
            merged.push(active[i]);
            i += 1;
        } else {
            merged.push(latched[j]);
            j += 1;
        }
    }
    merged.extend_from_slice(&active[i..]);
    merged.extend_from_slice(&latched[j..]);
    chain_precedence_over(engine, merged.iter().map(|&cid| &engine.containers()[cid]))
}

/// `crashed-workers-idle` details over an arbitrary container visit
/// sequence: no container may run, stage or migrate on an offline worker.
fn crashed_workers_idle_over<'c>(
    engine: &Engine,
    containers: impl Iterator<Item = &'c Container>,
) -> Vec<String> {
    let online = engine.online();
    let mut out = Vec::new();
    for c in containers {
        let offending = match c.state {
            ContainerState::Running | ContainerState::Transferring { .. } => {
                c.worker.map(|w| !online[w]).unwrap_or(false)
            }
            ContainerState::Migrating { to, .. } => {
                !online[to] || c.worker.map(|w| !online[w]).unwrap_or(false)
            }
            _ => false,
        };
        if offending {
            out.push(format!(
                "container {} is {:?} on offline worker {:?}",
                c.id, c.state, c.worker
            ));
        }
    }
    out
}

/// `crashed-workers-idle` from the full container pool (the paranoid twin).
pub fn crashed_workers_idle_full(engine: &Engine) -> Vec<String> {
    crashed_workers_idle_over(engine, engine.containers().iter())
}

/// `crashed-workers-idle` from the active-set index: every offending state
/// (Running/Transferring/Migrating) is non-terminal, so the index covers
/// exactly the containers the full scan can flag, in the same id order.
pub fn crashed_workers_idle_indexed(engine: &Engine) -> Vec<String> {
    crashed_workers_idle_over(
        engine,
        engine.active_ids().iter().map(|&cid| &engine.containers()[cid]),
    )
}

/// Where a `(state, worker)` pair holds resident RAM, if anywhere — the
/// oracle-side mirror of the engine's residency rule, so the full-scan
/// capacity twin re-derives per-worker demand without engine internals.
fn resident_home(c: &Container) -> Option<usize> {
    match c.state {
        ContainerState::Running
        | ContainerState::Transferring { .. }
        | ContainerState::Blocked => c.worker,
        ContainerState::Migrating { to, .. } => Some(to),
        _ => None,
    }
}

/// `allocation-capacity` details given per-worker resident-RAM demand.
fn allocation_capacity_over(engine: &Engine, resident: &[f64]) -> Vec<String> {
    let mut out = Vec::new();
    for (w, worker) in engine.cluster.workers.iter().enumerate() {
        let cap = worker.spec.ram_mb * RAM_OVERCOMMIT;
        if resident[w] > cap + 1e-6 {
            out.push(format!("worker {w}: resident {:.0} MB > cap {cap:.0} MB", resident[w]));
        }
    }
    out
}

/// `allocation-capacity` from a full pool scan (the paranoid twin): sums
/// resident demand per worker over every container ever admitted, through
/// the order-free accumulator — bit-identical to the residency-index sums
/// whatever order the terms are visited in.
pub fn allocation_capacity_full(engine: &Engine) -> Vec<String> {
    let mut sums = vec![crate::util::accum::Accum::ZERO; engine.workers()];
    for c in engine.containers() {
        if let Some(w) = resident_home(c) {
            sums[w].add(c.ram_mb);
        }
    }
    let resident: Vec<f64> = sums.iter().map(|a| a.value()).collect();
    allocation_capacity_over(engine, &resident)
}

/// `allocation-capacity` from the per-worker residency indexes — the
/// hot-path derivation, O(workers + resident).
pub fn allocation_capacity_indexed(engine: &Engine) -> Vec<String> {
    allocation_capacity_over(engine, &engine.resident_ram())
}

/// `task-conservation` container-side details from a full pool scan (the
/// paranoid twin): the pool must reference exactly the admitted task set.
/// Strictly broader than the indexed twin — it also counts distinct task
/// ids across terminal containers, which no O(active) derivation can see;
/// `--paranoid` treats anything the full scan catches that the hot path
/// missed as a divergence.
pub fn task_conservation_full(engine: &Engine) -> Vec<String> {
    let mut out = Vec::new();
    let admitted = engine.admitted_task_count();
    let container_tasks: HashSet<u64> =
        engine.containers().iter().map(|c| c.task_id).collect();
    if container_tasks.len() != admitted {
        out.push(format!(
            "containers reference {} distinct tasks but {admitted} were admitted",
            container_tasks.len()
        ));
    }
    for id in &container_tasks {
        if engine.task(*id).is_none() {
            out.push(format!("container references unknown task {id}"));
        }
    }
    out
}

/// `task-conservation` container-side details from the active-set index:
/// every in-flight container must reference a known task (first offense
/// per task id, ascending container order). O(active).
pub fn task_conservation_indexed(engine: &Engine) -> Vec<String> {
    let mut out = Vec::new();
    let mut flagged: HashSet<u64> = HashSet::new();
    for &cid in engine.active_ids() {
        let id = engine.containers()[cid].task_id;
        if engine.task(id).is_none() && flagged.insert(id) {
            out.push(format!("container references unknown task {id}"));
        }
    }
    out
}

/// Queued-container count from a full pool scan (the paranoid twin).
pub fn telemetry_queued_full(engine: &Engine) -> usize {
    engine
        .containers()
        .iter()
        .filter(|c| matches!(c.state, ContainerState::Queued))
        .count()
}

/// Queued-container count from the active-set index: `Queued` is a
/// non-terminal state, so the active list holds every queued container.
pub fn telemetry_queued_indexed(engine: &Engine) -> usize {
    engine
        .active_ids()
        .iter()
        .filter(|&&cid| matches!(engine.containers()[cid].state, ContainerState::Queued))
        .count()
}

/// `payload-corruption-handled` details from a full ledger walk (the
/// paranoid twin): every task any corruption record affected must be
/// failed by now.
pub fn payload_corruption_full(engine: &Engine) -> Vec<String> {
    let mut out = Vec::new();
    for rec in engine.ledger() {
        let corrupting = matches!(
            rec.cmd,
            EngineCmd::CorruptPayload { .. } | EngineCmd::CorruptPayloadSwallowed { .. }
        );
        if !corrupting {
            continue;
        }
        let Effect::Affected { tasks } = &rec.effect else {
            continue;
        };
        for &id in tasks {
            if !engine.task_failed(id) {
                out.push(corruption_detail(id, rec.interval));
            }
        }
    }
    out
}

fn corruption_detail(task: u64, at: usize) -> String {
    format!("task {task}: payload corrupted at interval {at} but the task is not failed")
}

/// `ledger-replay-consistent` detail for a replayed-vs-live surface
/// mismatch; `None` when the surfaces agree. Shared by the incremental
/// hot path and the full-replay paranoid twin so both emit the same text.
fn surface_divergence_detail(engine: &Engine, replayed: &FaultSurface) -> Option<String> {
    let live = engine.fault_surface();
    if *replayed == live {
        return None;
    }
    let diff = (0..engine.workers())
        .find_map(|w| {
            let fields = [
                ("online", replayed.online[w] != live.online[w]),
                ("mips", replayed.mips_factor[w] != live.mips_factor[w]),
                ("ram", replayed.ram_factor[w] != live.ram_factor[w]),
                ("skew", replayed.clock_skew_s[w] != live.clock_skew_s[w]),
            ];
            fields.iter().find(|(_, d)| *d).map(|(name, _)| format!("worker {w}: {name}"))
        })
        .unwrap_or_else(|| "churn rate".into());
    Some(format!(
        "replaying {} ledger commands does not reproduce the fault surface ({diff})",
        engine.ledger().len()
    ))
}

/// `ledger-replay-consistent` from a full from-scratch replay (the
/// paranoid twin).
pub fn ledger_replay_full(engine: &Engine) -> Vec<String> {
    let replayed = FaultSurface::replay(engine.workers(), engine.ledger());
    surface_divergence_detail(engine, &replayed).into_iter().collect()
}

/// Permanent `handoff-preserves-progress` details of one audit record:
/// structural well-formedness plus duplicate detection against `seen`.
/// Everything checked here is immutable after the audit is taken (worker
/// count, rack geometry, container↦task ownership, `mi_total`), so a
/// malformed or duplicate audit never heals: the indexed path accumulates
/// these details once at absorption and re-emits them every interval,
/// exactly what the full-log twin re-derives from scratch.
fn handoff_audit_details(
    engine: &Engine,
    a: &HandoffAudit,
    seen: &mut HashSet<(usize, usize, usize, usize)>,
    out: &mut Vec<String>,
) {
    let racks = crate::chaos::events::RACKS;
    if a.worker >= engine.workers() {
        out.push(format!(
            "handoff audit at interval {}: unknown worker {}",
            a.interval, a.worker
        ));
    }
    if a.from_rack >= racks || a.to_rack >= racks || a.from_rack == a.to_rack {
        out.push(format!(
            "handoff audit at interval {} (worker {}): bad rack pair {} -> {}",
            a.interval, a.worker, a.from_rack, a.to_rack
        ));
    }
    for pair in a.residents.windows(2) {
        if pair[0].0 >= pair[1].0 {
            out.push(format!(
                "handoff audit at interval {} (worker {}): residents not ascending by id",
                a.interval, a.worker
            ));
            break;
        }
    }
    for &(cid, task_id, mi_at) in &a.residents {
        let Some(c) = engine.containers().get(cid) else {
            out.push(format!(
                "handoff audit at interval {} (worker {}): unknown container {cid}",
                a.interval, a.worker
            ));
            continue;
        };
        if c.task_id != task_id {
            out.push(format!(
                "handoff audit at interval {} (worker {}): container {cid} belongs to \
                 task {}, audit charged task {task_id}",
                a.interval, a.worker, c.task_id
            ));
        }
        if !mi_at.is_finite() || mi_at < 0.0 || mi_at > c.mi_total + 1e-9 {
            out.push(format!(
                "handoff audit at interval {} (worker {}): container {cid} recorded \
                 {mi_at} MI outside [0, {}]",
                a.interval, a.worker, c.mi_total
            ));
        }
    }
    if !seen.insert((a.interval, a.worker, a.from_rack, a.to_rack)) {
        out.push(format!(
            "duplicate handoff audit: worker {} {} -> {} applied twice at interval {} \
             (the second application is stale and must Noop)",
            a.worker, a.from_rack, a.to_rack, a.interval
        ));
    }
}

/// Fresh `handoff-preserves-progress` details: every resident recorded at
/// a **this-interval** handoff must still hold at least its recorded
/// progress — a re-home that loses completed work shows up here.
/// Residents no longer on the audited worker (evicted by a later crash in
/// the same interval) are skipped unless Done: their progress loss is the
/// crash's, not the handoff's. Past-interval audits cannot be re-derived
/// (progress legitimately moves on), so both twins evaluate only
/// `now`-interval audits and stay exactly equal.
fn handoff_progress_over<'a>(
    engine: &Engine,
    audits: impl Iterator<Item = &'a HandoffAudit>,
    now: usize,
    out: &mut Vec<String>,
) {
    for a in audits {
        if a.interval != now {
            continue;
        }
        for &(cid, task_id, mi_at) in &a.residents {
            let Some(c) = engine.containers().get(cid) else {
                continue;
            };
            if c.worker != Some(a.worker) && !c.is_done() {
                continue;
            }
            if c.mi_done + 1e-9 < mi_at {
                out.push(format!(
                    "handoff of worker {} at interval {now} lost progress: container \
                     {cid} (task {task_id}) had {mi_at} MI recorded, holds {} now",
                    a.worker, c.mi_done
                ));
            }
        }
    }
}

/// `handoff-preserves-progress` from the whole audit log (the paranoid
/// twin): re-derives every permanent detail with a fresh duplicate set,
/// then the fresh progress details for `now`-interval audits — the exact
/// sequence the indexed accumulation emits (permanent details in audit
/// order, then fresh ones).
pub fn handoff_audit_full(engine: &Engine, now: usize) -> Vec<String> {
    let mut seen = HashSet::new();
    let mut out = Vec::new();
    for a in engine.handoff_audits() {
        handoff_audit_details(engine, a, &mut seen, &mut out);
    }
    handoff_progress_over(engine, engine.handoff_audits().iter(), now, &mut out);
    out
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "[{}] interval {}: {}", self.oracle, self.interval, self.detail)
    }
}

/// Cross-interval oracle memory, owned by the harness for the lifetime of
/// one run. Besides the duplicate-completion set this carries the ledger
/// cursor that makes the two ledger-audit oracles incremental: each
/// interval folds only the records appended since the last check into a
/// persistent replay surface and a pending-corruption list, turning two
/// O(ledger) walks per interval into O(new records).
#[derive(Debug, Default)]
pub struct OracleState {
    /// Task ids already seen in a completion report.
    seen_completed: HashSet<u64>,
    /// Ledger records `[..cursor]` have been absorbed.
    ledger_cursor: usize,
    /// Corrupted-but-not-yet-failed `(task, interval)` pairs, in ledger
    /// order; entries leave when the task fails (tasks never un-fail), so
    /// the per-interval sweep reproduces the full ledger walk's details
    /// exactly.
    corrupted_pending: Vec<(u64, usize)>,
    /// Incremental replay of the command ledger (`None` until the first
    /// check initializes it with the run's worker count).
    replayed: Option<FaultSurface>,
    /// Handoff audits `[..audit_cursor]` have been absorbed.
    audit_cursor: usize,
    /// `(interval, worker, from, to)` keys of absorbed handoff audits —
    /// a repeat means one handoff applied twice (impossible on a correct
    /// engine: the second application is stale and Noops unaudited).
    handoff_seen: HashSet<(usize, usize, usize, usize)>,
    /// Permanent handoff-audit details (malformed or duplicate audits
    /// never heal), in audit order; re-emitted every interval exactly as
    /// the full-log twin re-derives them.
    handoff_bad: Vec<String>,
}

impl OracleState {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record a completion observation; returns false if `task_id` was
    /// already seen (the duplicate the oracle flags). Exposed so tests can
    /// pre-seed the set.
    pub fn note_completed(&mut self, task_id: u64) -> bool {
        self.seen_completed.insert(task_id)
    }
}

/// Everything an interval check can see. `state` persists across
/// intervals (the harness owns it): duplicate-completion memory plus the
/// incremental ledger cursor.
pub struct OracleCtx<'a> {
    pub engine: &'a Engine,
    pub report: &'a IntervalReport,
    /// Tasks admitted by the broker since construction.
    pub admitted: u64,
    /// MAB decisions recorded by the bandit since harness start (current
    /// count sum minus the warm-start baseline); None for non-MAB policies.
    pub mab_decisions: Option<u64>,
    pub state: &'a mut OracleState,
    /// Per-worker offline expectation replayed from the fault plan's
    /// bug-free compiled commands (see [`super::PlanLedger`]). None when
    /// the engine can legitimately toggle availability on its own (churn
    /// enabled), which would make the comparison meaningless.
    pub expected_offline: Option<&'a [bool]>,
    /// Per-worker clock-skew seconds the plan currently holds active
    /// (post-clamp); None disables the check.
    pub expected_skew: Option<&'a [f64]>,
    /// Per-worker rack homes replayed from the fault plan's handoff
    /// ledger (see [`super::PlanLedger`]); None when plan tracking is off
    /// (churn, autoscaling or battery can re-shape availability, though
    /// racks themselves only ever move through handoff commands).
    pub expected_racks: Option<&'a [usize]>,
    /// Run the retained full-scan twins side by side with the indexed
    /// derivations and emit a `paranoid-divergence` violation on any
    /// verdict mismatch. Costs the pre-migration O(pool + ledger) per
    /// interval — a correctness cross-check, not a mode to leave on.
    pub paranoid: bool,
}

/// Evaluate every oracle; returns all violations found this interval.
///
/// Hot-path complexity: O(active + workers + new ledger records) — no
/// full-pool container scan, no full-ledger walk. The `--paranoid` mode
/// re-adds the full scans purely to diff them against the indexed
/// verdicts.
pub fn check_interval(ctx: &mut OracleCtx) -> Vec<Violation> {
    let mut out = Vec::new();
    let t = ctx.report.interval;
    let mut fail = |oracle: &'static str, detail: String| {
        out.push(Violation { oracle, interval: t, detail });
    };

    // -- task-conservation --------------------------------------------------
    // O(1) registry checks plus an O(active) sweep: the broker's admission
    // count must match the engine's task registry, and every in-flight
    // container must reference a known task. The full-pool twin
    // (`task_conservation_full`) additionally audits terminal containers
    // and the distinct-task count; it runs under --paranoid only.
    let admitted = ctx.engine.admitted_task_count();
    if admitted as u64 != ctx.admitted {
        fail(
            "task-conservation",
            format!("engine tracks {admitted} tasks but broker admitted {}", ctx.admitted),
        );
    }
    for detail in task_conservation_indexed(ctx.engine) {
        fail("task-conservation", detail);
    }

    // -- allocation-capacity ------------------------------------------------
    // Every path into residency is capacity-checked (placement and
    // migration via `fits`, chain unblocks via the Blocked reservation
    // that already counts), and squeezes only shrink the effective cap
    // below the physical one — so resident demand must NEVER exceed the
    // physical overcommit cap, not even by a single container.
    for detail in allocation_capacity_indexed(ctx.engine) {
        fail("allocation-capacity", detail);
    }

    // -- chain-precedence ---------------------------------------------------
    // Active set + terminal-transition latch; exactly the full scan's
    // verdicts, post-hoc memory included (see the twins section above).
    for detail in chain_precedence_indexed(ctx.engine) {
        fail("chain-precedence", detail);
    }

    // -- task-times-sane ----------------------------------------------------
    for task in &ctx.report.completed {
        let parts = [
            ("response", task.response),
            ("wait", task.wait),
            ("exec", task.exec),
            ("transfer", task.transfer),
            ("migrate", task.migrate),
        ];
        for (name, v) in parts {
            if !v.is_finite() || v < 0.0 {
                fail(
                    "task-times-sane",
                    format!("task {}: {name} = {v}", task.task_id),
                );
            }
        }
        if task.response <= 0.0 {
            fail(
                "task-times-sane",
                format!("task {}: non-positive response {}", task.task_id, task.response),
            );
        }
    }
    for task in &ctx.report.failed {
        if !task.age.is_finite() || task.age < 0.0 {
            fail("task-times-sane", format!("failed task {}: age {}", task.task_id, task.age));
        }
    }

    // -- energy-sane --------------------------------------------------------
    if !ctx.report.energy_wh.is_finite() || ctx.report.energy_wh < 0.0 {
        fail("energy-sane", format!("energy_wh = {}", ctx.report.energy_wh));
    }
    if !ctx.report.aec.is_finite() || ctx.report.aec < 0.0 {
        fail("energy-sane", format!("aec = {}", ctx.report.aec));
    }
    for (w, s) in ctx.report.snapshots.iter().enumerate() {
        if !(0.0..=1.0).contains(&s.cpu) || !s.ram.is_finite() || s.ram < 0.0 {
            fail("energy-sane", format!("worker {w}: cpu {} ram {}", s.cpu, s.ram));
        }
    }

    // -- mab-accounting -----------------------------------------------------
    if let Some(decided) = ctx.mab_decisions {
        if decided != ctx.admitted {
            fail(
                "mab-accounting",
                format!("bandit recorded {decided} decisions, broker admitted {}", ctx.admitted),
            );
        }
    }

    // -- crashed-workers-idle -----------------------------------------------
    // Active-set derivation; exactly the full scan (every offending state
    // is non-terminal).
    for detail in crashed_workers_idle_indexed(ctx.engine) {
        fail("crashed-workers-idle", detail);
    }

    // -- telemetry-consistent -----------------------------------------------
    let online = ctx.engine.online();
    let queued_now = telemetry_queued_indexed(ctx.engine);
    if queued_now != ctx.report.queued {
        fail(
            "telemetry-consistent",
            format!("report says {} queued, engine holds {queued_now}", ctx.report.queued),
        );
    }
    let offline_now = online.iter().filter(|&&o| !o).count();
    if offline_now != ctx.report.offline {
        fail(
            "telemetry-consistent",
            format!("report says {} offline, engine has {offline_now}", ctx.report.offline),
        );
    }

    // -- offline-matches-plan -----------------------------------------------
    // Replaying the plan's crash/recover/rack ledger must land on exactly
    // the engine's availability vector — a rack failure that "forgets" a
    // member, or a recovery that revives the wrong machine, shows up here
    // even while the fleet is idle (crashed-workers-idle can't see those).
    if let Some(expected) = ctx.expected_offline {
        for (w, &exp_off) in expected.iter().enumerate().take(online.len()) {
            if exp_off == online[w] {
                fail(
                    "offline-matches-plan",
                    format!(
                        "worker {w}: plan says {}, engine says {}",
                        if exp_off { "offline" } else { "online" },
                        if online[w] { "online" } else { "offline" }
                    ),
                );
            }
        }
    }

    // -- clock-skew-applied -------------------------------------------------
    if let Some(expected) = ctx.expected_skew {
        for (w, &exp_skew) in expected.iter().enumerate() {
            let got = ctx.engine.clock_skew(w);
            if (got - exp_skew).abs() > 1e-9 {
                fail(
                    "clock-skew-applied",
                    format!("worker {w}: plan holds skew {exp_skew}s, engine applies {got}s"),
                );
            }
        }
    }

    // -- incremental ledger absorption --------------------------------------
    // One pass over the records appended since the previous check feeds
    // BOTH ledger-audit oracles: the replay surface folds every new
    // command (the exact fold `FaultSurface::replay` performs from
    // scratch), and corruption records enqueue their affected tasks. The
    // cursor makes each of these O(new records) instead of O(ledger).
    let ledger = ctx.engine.ledger();
    if ctx.state.replayed.is_none() {
        ctx.state.replayed = Some(FaultSurface::baseline(ctx.engine.workers()));
    }
    let replayed = ctx.state.replayed.as_mut().unwrap();
    for rec in &ledger[ctx.state.ledger_cursor..] {
        replayed.absorb(&rec.cmd);
        let corrupting = matches!(
            rec.cmd,
            EngineCmd::CorruptPayload { .. } | EngineCmd::CorruptPayloadSwallowed { .. }
        );
        if corrupting {
            if let Effect::Affected { tasks } = &rec.effect {
                for &id in tasks {
                    ctx.state.corrupted_pending.push((id, rec.interval));
                }
            }
        }
    }
    ctx.state.ledger_cursor = ledger.len();

    // -- payload-corruption-handled -----------------------------------------
    // Audits the engine's own command ledger: every task a corruption
    // command reported as affected must be failed by now — a "swallowed"
    // corruption (missing checksum) leaves it active or lets it complete,
    // and keeps this firing every interval until fixed. Failed tasks leave
    // the pending list for good (tasks never un-fail), so the surviving
    // entries — still in ledger order — are exactly what the full ledger
    // walk would flag.
    let mut corruption_details = Vec::new();
    let engine = ctx.engine;
    ctx.state.corrupted_pending.retain(|&(id, at)| {
        if engine.task_failed(id) {
            false
        } else {
            corruption_details.push(corruption_detail(id, at));
            true
        }
    });
    for detail in &corruption_details {
        fail("payload-corruption-handled", detail.clone());
    }

    // -- completion-unique --------------------------------------------------
    for task in &ctx.report.completed {
        if ctx.engine.task(task.task_id).is_none() {
            fail(
                "completion-unique",
                format!("completion for unknown task {}", task.task_id),
            );
        }
        if !ctx.state.note_completed(task.task_id) {
            fail(
                "completion-unique",
                format!("task {} completed twice", task.task_id),
            );
        }
    }

    // -- ledger-replay-consistent -------------------------------------------
    // The command bus is the ONLY mutation path for the fault surface, so
    // the incrementally maintained replay (the same absorb fold a fresh
    // `FaultSurface::replay` performs over the whole ledger) must land on
    // exactly the live surface. A command that mutated state without
    // recording it, or recorded an effect it did not apply, diverges here.
    // Float fields compare exactly: replay mirrors the engine's own clamp
    // arithmetic.
    if let Some(detail) =
        surface_divergence_detail(ctx.engine, ctx.state.replayed.as_ref().unwrap())
    {
        fail("ledger-replay-consistent", detail);
    }

    // -- handoff-preserves-progress -------------------------------------------
    // Audits the engine's handoff log incrementally: new audits since the
    // cursor are checked for permanent defects (malformed geometry,
    // mis-charged tasks, duplicates — none of which heal, so they keep
    // firing like the full-log walk would), and every resident recorded
    // at a this-interval handoff must still hold its recorded progress —
    // a re-home that loses completed work or double-charges a task fails
    // here. With plan tracking on, the engine's rack map must equal the
    // plan's replayed handoff ledger: a dropped handoff diverges even
    // when the worker carried no containers.
    let audits = ctx.engine.handoff_audits();
    let fresh_from = ctx.state.audit_cursor;
    for a in &audits[fresh_from..] {
        handoff_audit_details(
            ctx.engine,
            a,
            &mut ctx.state.handoff_seen,
            &mut ctx.state.handoff_bad,
        );
    }
    ctx.state.audit_cursor = audits.len();
    let mut handoff_details = ctx.state.handoff_bad.clone();
    handoff_progress_over(ctx.engine, audits[fresh_from..].iter(), t, &mut handoff_details);
    for detail in &handoff_details {
        fail("handoff-preserves-progress", detail.clone());
    }
    if let Some(expected) = ctx.expected_racks {
        let racks = ctx.engine.rack_of();
        for (w, (&exp, &got)) in expected.iter().zip(racks).enumerate() {
            if exp != got {
                fail(
                    "handoff-preserves-progress",
                    format!("worker {w}: plan homes it in rack {exp}, engine holds rack {got}"),
                );
            }
        }
    }

    // -- paranoid: full-scan twins vs the indexed verdicts --------------------
    // Re-derives every migrated verdict from the pre-migration full scans
    // and hard-fails on ANY difference — including a full scan catching
    // something the hot path missed (for task-conservation the full twin
    // is deliberately broader; see its doc).
    if ctx.paranoid {
        let eng = ctx.engine;
        let twins: [(&'static str, Vec<String>, Vec<String>); 5] = [
            ("chain-precedence", chain_precedence_full(eng), chain_precedence_indexed(eng)),
            (
                "crashed-workers-idle",
                crashed_workers_idle_full(eng),
                crashed_workers_idle_indexed(eng),
            ),
            (
                "allocation-capacity",
                allocation_capacity_full(eng),
                allocation_capacity_indexed(eng),
            ),
            ("payload-corruption-handled", payload_corruption_full(eng), corruption_details),
            ("handoff-preserves-progress", handoff_audit_full(eng, t), handoff_details),
        ];
        for (oracle, full, indexed) in twins {
            if full != indexed {
                fail(
                    "paranoid-divergence",
                    format!(
                        "{oracle}: full scan found {} detail(s), indexed derivation {} \
                         (first full: {:?}, first indexed: {:?})",
                        full.len(),
                        indexed.len(),
                        full.first(),
                        indexed.first()
                    ),
                );
            }
        }
        // task-conservation's full twin iterates a HashSet — order-free
        // compare; any verdict the full scan has that the sweep lacks
        // (or vice versa) is a divergence
        let mut full = task_conservation_full(eng);
        full.sort();
        let mut indexed = task_conservation_indexed(eng);
        indexed.sort();
        if full != indexed {
            fail(
                "paranoid-divergence",
                format!(
                    "task-conservation: full scan found {} detail(s), indexed sweep {}",
                    full.len(),
                    indexed.len()
                ),
            );
        }
        let (q_full, q_indexed) = (telemetry_queued_full(eng), telemetry_queued_indexed(eng));
        if q_full != q_indexed {
            fail(
                "paranoid-divergence",
                format!("telemetry queued count: full scan {q_full}, indexed {q_indexed}"),
            );
        }
        let from_scratch = FaultSurface::replay(eng.workers(), eng.ledger());
        if Some(&from_scratch) != ctx.state.replayed.as_ref() {
            fail(
                "paranoid-divergence",
                "ledger replay: from-scratch surface differs from the incremental fold"
                    .to_string(),
            );
        }
        // Sub-step state partitions (phase-1 transit, phase-3 blocked) and
        // every other incremental engine index: the full-pool
        // recomputation inside `verify_indices` IS their twin.
        if let Err(e) = eng.verify_indices() {
            fail("paranoid-divergence", format!("engine index cross-check: {e}"));
        }
    }

    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chaos::plan::{FaultPlan, Profile};
    use crate::cluster::build_fleet;
    use crate::config::{ClusterConfig, SimConfig};
    use crate::sim::Engine;
    use crate::splits::{App, SplitDecision};
    use crate::util::rng::Rng;
    use crate::workload::Task;

    fn engine() -> Engine {
        Engine::new(build_fleet(&ClusterConfig::small()), SimConfig::default(), 1)
    }

    fn task(id: u64) -> Task {
        Task { id, app: App::Mnist, batch: 32_000, sla: 5.0, arrival_s: 0.0, decision: None }
    }

    #[test]
    fn clean_interval_has_no_violations() {
        let mut e = engine();
        e.admit(task(0), SplitDecision::Compressed);
        e.apply_placement(&[(0, 0)]);
        let report = e.step_interval();
        let mut state = OracleState::new();
        let mut ctx = OracleCtx {
            engine: &e,
            report: &report,
            admitted: 1,
            mab_decisions: None,
            state: &mut state,
            expected_offline: None,
            expected_skew: None,
            expected_racks: None,
            paranoid: true,
        };
        let v = check_interval(&mut ctx);
        assert!(v.is_empty(), "unexpected violations: {v:?}");
    }

    #[test]
    fn admission_mismatch_is_caught() {
        let mut e = engine();
        e.admit(task(0), SplitDecision::Compressed);
        let report = e.step_interval();
        let mut state = OracleState::new();
        let mut ctx = OracleCtx {
            engine: &e,
            report: &report,
            admitted: 5, // broker claims more than the engine holds
            mab_decisions: None,
            state: &mut state,
            expected_offline: None,
            expected_skew: None,
            expected_racks: None,
            paranoid: false,
        };
        let v = check_interval(&mut ctx);
        assert!(v.iter().any(|v| v.oracle == "task-conservation"), "{v:?}");
    }

    #[test]
    fn progress_on_crashed_worker_is_caught() {
        let mut e = engine();
        e.admit(task(0), SplitDecision::Compressed);
        e.apply_placement(&[(0, 0)]);
        e.step_interval();
        // the deliberate bug hook: offline without evicting
        e.apply(EngineCmd::ForceOfflineNoEvict { worker: 0 });
        let report = e.step_interval();
        let mut state = OracleState::new();
        let mut ctx = OracleCtx {
            engine: &e,
            report: &report,
            admitted: 1,
            mab_decisions: None,
            state: &mut state,
            expected_offline: None,
            expected_skew: None,
            expected_racks: None,
            paranoid: true,
        };
        let v = check_interval(&mut ctx);
        assert!(v.iter().any(|v| v.oracle == "crashed-workers-idle"), "{v:?}");
        // the sabotaged engine diverges scan-vs-index nowhere: both twins
        // see the same wrongness
        assert!(v.iter().all(|v| v.oracle != "paranoid-divergence"), "{v:?}");
    }

    #[test]
    fn duplicate_completion_is_caught() {
        let mut e = engine();
        e.admit(task(0), SplitDecision::Compressed);
        e.apply_placement(&[(0, 0)]);
        let mut report = None;
        for _ in 0..40 {
            let r = e.step_interval();
            if !r.completed.is_empty() {
                report = Some(r);
                break;
            }
        }
        let report = report.expect("compressed task completes");
        let mut state = OracleState::new();
        state.note_completed(report.completed[0].task_id); // pretend we saw it before
        let mut ctx = OracleCtx {
            engine: &e,
            report: &report,
            admitted: 1,
            mab_decisions: None,
            state: &mut state,
            expected_offline: None,
            expected_skew: None,
            expected_racks: None,
            paranoid: false,
        };
        let v = check_interval(&mut ctx);
        assert!(v.iter().any(|v| v.oracle == "completion-unique"), "{v:?}");
    }

    #[test]
    fn offline_mismatch_against_plan_is_caught() {
        let mut e = engine();
        e.apply(EngineCmd::Crash { worker: 1 });
        let report = e.step_interval();
        let mut state = OracleState::new();
        // plan ledger says workers 1 AND 2 should be down — a rack failure
        // that only took one member offline
        let mut expected = vec![false; e.workers()];
        expected[1] = true;
        expected[2] = true;
        let mut ctx = OracleCtx {
            engine: &e,
            report: &report,
            admitted: 0,
            mab_decisions: None,
            state: &mut state,
            expected_offline: Some(&expected),
            expected_skew: None,
            expected_racks: None,
            paranoid: false,
        };
        let v = check_interval(&mut ctx);
        assert!(v.iter().any(|v| v.oracle == "offline-matches-plan"), "{v:?}");
        assert!(
            v.iter().all(|v| v.oracle != "offline-matches-plan" || v.detail.contains("worker 2")),
            "only the forgotten member may be flagged: {v:?}"
        );
    }

    #[test]
    fn clock_skew_mismatch_is_caught_and_match_is_green() {
        let mut e = engine();
        e.apply(EngineCmd::SetClockSkew { worker: 3, skew_s: 42.0 });
        let report = e.step_interval();
        let mut expected = vec![0.0; e.workers()];
        expected[3] = 42.0;
        {
            let mut state = OracleState::new();
            let mut ctx = OracleCtx {
                engine: &e,
                report: &report,
                admitted: 0,
                mab_decisions: None,
                state: &mut state,
                expected_offline: None,
                expected_skew: Some(&expected),
                expected_racks: None,
                paranoid: false,
            };
            let v = check_interval(&mut ctx);
            assert!(v.is_empty(), "matching skew must stay green: {v:?}");
        }
        expected[3] = 0.0; // plan says the episode ended; engine still skewed
        let mut state = OracleState::new();
        let mut ctx = OracleCtx {
            engine: &e,
            report: &report,
            admitted: 0,
            mab_decisions: None,
            state: &mut state,
            expected_offline: None,
            expected_skew: Some(&expected),
            expected_racks: None,
            paranoid: false,
        };
        let v = check_interval(&mut ctx);
        assert!(v.iter().any(|v| v.oracle == "clock-skew-applied"), "{v:?}");
    }

    #[test]
    fn swallowed_corruption_is_caught_and_handled_corruption_is_green() {
        let mk = |swallow: bool| -> Vec<Violation> {
            let mut e = engine();
            e.admit(task(0), SplitDecision::Compressed);
            e.apply_placement(&[(0, 0)]); // transfer now staging toward 0
            if swallow {
                e.apply(EngineCmd::CorruptPayloadSwallowed { worker: 0 });
            } else {
                e.apply(EngineCmd::CorruptPayload { worker: 0 });
            }
            let report = e.step_interval();
            let mut state = OracleState::new();
            let mut ctx = OracleCtx {
                engine: &e,
                report: &report,
                admitted: 1,
                mab_decisions: None,
                state: &mut state,
                expected_offline: None,
                expected_skew: None,
                expected_racks: None,
                paranoid: true,
            };
            check_interval(&mut ctx)
        };
        let v = mk(false);
        assert!(v.is_empty(), "handled corruption must stay green: {v:?}");
        let v = mk(true);
        assert!(
            v.iter().any(|v| v.oracle == "payload-corruption-handled"),
            "swallowed corruption must be caught: {v:?}"
        );
        // the incremental pending sweep and the full ledger walk flag the
        // same tasks — a swallowed corruption produces no divergence
        assert!(v.iter().all(|v| v.oracle != "paranoid-divergence"), "{v:?}");
    }

    #[test]
    fn corruption_pending_persists_across_intervals_like_the_full_walk() {
        // the incremental oracle must keep firing on later intervals (the
        // full walk re-derived this each time; the pending list carries it)
        let mut e = engine();
        e.admit(task(0), SplitDecision::Compressed);
        e.apply_placement(&[(0, 0)]);
        e.apply(EngineCmd::CorruptPayloadSwallowed { worker: 0 });
        let mut state = OracleState::new();
        for round in 0..3 {
            let report = e.step_interval();
            let mut ctx = OracleCtx {
                engine: &e,
                report: &report,
                admitted: 1,
                mab_decisions: None,
                state: &mut state,
                expected_offline: None,
                expected_skew: None,
                expected_racks: None,
                paranoid: true,
            };
            let v = check_interval(&mut ctx);
            assert!(
                v.iter().any(|v| v.oracle == "payload-corruption-handled"),
                "round {round}: swallowed corruption must keep firing: {v:?}"
            );
            assert!(
                v.iter().all(|v| v.oracle != "paranoid-divergence"),
                "round {round}: {v:?}"
            );
        }
    }

    #[test]
    fn ledger_replay_oracle_matches_on_a_faulted_engine_and_catches_divergence() {
        let mut e = engine();
        e.apply(EngineCmd::Crash { worker: 1 });
        e.apply(EngineCmd::SetMipsFactor { worker: 2, factor: 0.4 });
        e.apply(EngineCmd::SetClockSkew { worker: 3, skew_s: 42.0 });
        let report = e.step_interval();
        let mut state = OracleState::new();
        let mut ctx = OracleCtx {
            engine: &e,
            report: &report,
            admitted: 0,
            mab_decisions: None,
            state: &mut state,
            expected_offline: None,
            expected_skew: None,
            expected_racks: None,
            paranoid: true,
        };
        let v = check_interval(&mut ctx);
        assert!(v.is_empty(), "bus-routed mutations must replay cleanly: {v:?}");
        // divergence detection is covered structurally: FaultSurface::replay
        // of a truncated ledger must differ from the live surface
        let truncated =
            crate::sim::FaultSurface::replay(e.workers(), &e.ledger()[..1]);
        assert_ne!(truncated, e.fault_surface(), "truncation must be visible");
    }

    #[test]
    fn handoff_oracle_green_on_correct_engine_and_catches_plan_divergence() {
        use crate::chaos::events::initial_racks;
        let mut e = engine();
        e.admit(task(0), SplitDecision::Compressed);
        e.apply_placement(&[(0, 0)]); // transferring toward worker 0, rack 0
        let from = e.rack_of()[0];
        let to = (from + 1) % crate::chaos::events::RACKS;
        e.apply(EngineCmd::Handoff { worker: 0, from_rack: from, to_rack: to });
        let mut expected = initial_racks(e.workers());
        expected[0] = to;
        let mut state = OracleState::new();
        // a faithful handoff is green across several intervals, paranoid
        // twins included (the audit's permanent details are re-derived
        // from the whole log each time)
        for _ in 0..3 {
            let report = e.step_interval();
            let mut ctx = OracleCtx {
                engine: &e,
                report: &report,
                admitted: 1,
                mab_decisions: None,
                state: &mut state,
                expected_offline: None,
                expected_skew: None,
                expected_racks: Some(&expected),
                paranoid: true,
            };
            let v = check_interval(&mut ctx);
            assert!(v.is_empty(), "faithful handoff must stay green: {v:?}");
        }
        // plan says the handoff never happened (a dropped-handoff bug in
        // reverse): the rack mirror diverges
        expected[0] = from;
        let report = e.step_interval();
        let mut ctx = OracleCtx {
            engine: &e,
            report: &report,
            admitted: 1,
            mab_decisions: None,
            state: &mut state,
            expected_offline: None,
            expected_skew: None,
            expected_racks: Some(&expected),
            paranoid: true,
        };
        let v = check_interval(&mut ctx);
        assert!(
            v.iter().any(|v| v.oracle == "handoff-preserves-progress"
                && v.detail.contains("worker 0")),
            "rack divergence must be caught: {v:?}"
        );
        assert!(v.iter().all(|v| v.oracle != "paranoid-divergence"), "{v:?}");
    }

    #[test]
    fn handoff_audit_defects_are_flagged_permanently() {
        let e = engine();
        let good = crate::sim::HandoffAudit {
            interval: 0,
            worker: 1,
            from_rack: 0,
            to_rack: 1,
            residents: Vec::new(),
        };
        let mut seen = HashSet::new();
        let mut out = Vec::new();
        handoff_audit_details(&e, &good, &mut seen, &mut out);
        assert!(out.is_empty(), "well-formed audit is quiet: {out:?}");
        // the same audit absorbed twice = one handoff applied twice
        handoff_audit_details(&e, &good, &mut seen, &mut out);
        assert_eq!(out.len(), 1);
        assert!(out[0].contains("duplicate handoff audit"), "{out:?}");
        // self-handoffs, out-of-range racks, unknown workers/containers
        let bad = crate::sim::HandoffAudit {
            interval: 0,
            worker: e.workers() + 7,
            from_rack: 2,
            to_rack: 2,
            residents: vec![(999, 0, 1.0), (3, 0, -1.0)],
        };
        let mut out = Vec::new();
        handoff_audit_details(&e, &bad, &mut HashSet::new(), &mut out);
        assert!(out.iter().any(|d| d.contains("unknown worker")), "{out:?}");
        assert!(out.iter().any(|d| d.contains("bad rack pair")), "{out:?}");
        assert!(out.iter().any(|d| d.contains("not ascending")), "{out:?}");
        assert!(out.iter().any(|d| d.contains("unknown container 999")), "{out:?}");
    }

    #[test]
    fn handoff_progress_loss_is_flagged_only_for_current_interval_audits() {
        let mut e = engine();
        e.admit(task(0), SplitDecision::Compressed);
        e.apply_placement(&[(0, 0)]);
        e.step_interval();
        let held = e.containers()[0].mi_done;
        // an audit claiming the container held MORE than it does = the
        // handoff lost progress
        let lossy = crate::sim::HandoffAudit {
            interval: 1,
            worker: 0,
            from_rack: 0,
            to_rack: 1,
            residents: vec![(0, 0, held + 5.0)],
        };
        let mut out = Vec::new();
        handoff_progress_over(&e, std::iter::once(&lossy), 1, &mut out);
        assert_eq!(out.len(), 1, "{out:?}");
        assert!(out[0].contains("lost progress"), "{out:?}");
        // a past-interval audit is not re-derivable: quiet
        let mut out = Vec::new();
        handoff_progress_over(&e, std::iter::once(&lossy), 2, &mut out);
        assert!(out.is_empty(), "{out:?}");
        // a resident evicted off the audited worker is the crash's loss,
        // not the handoff's: quiet
        let moved = crate::sim::HandoffAudit {
            interval: 1,
            worker: 3,
            from_rack: 0,
            to_rack: 1,
            residents: vec![(0, 0, held + 5.0)],
        };
        let mut out = Vec::new();
        handoff_progress_over(&e, std::iter::once(&moved), 1, &mut out);
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn every_oracle_has_a_description() {
        for o in ORACLES {
            assert_ne!(describe(o), "");
        }
        // the paranoid cross-check label is describable but is NOT one of
        // the 14 invariants (it names a twin divergence, not an engine bug)
        assert!(!ORACLES.contains(&"paranoid-divergence"));
        assert_ne!(describe("paranoid-divergence"), "unknown invariant");
    }

    /// The scan-vs-index twins agree — on a healthy engine (both empty)
    /// and on a sabotaged one (both flag the same containers, in the same
    /// order). This is the migration's evidence base: a seeded sweep of
    /// chaos-heavy random plans checks every migrated twin pair after
    /// every interval, a `ForceOfflineNoEvict` leg forces a non-empty
    /// verdict, and a sabotaged out-of-order terminal transition exercises
    /// the chain-precedence latch's post-hoc memory.
    #[test]
    fn indexed_oracle_derivations_match_the_full_scans() {
        // deterministic smoke leg (the original scenario)
        let mut e = engine();
        e.admit(task(0), SplitDecision::Layer);
        e.admit(task(1), SplitDecision::Compressed);
        e.apply_placement(&[(0, 0), (1, 1), (2, 2), (3, 3)]);
        e.step_interval();
        assert_twins_agree(&e, "smoke");
        assert!(crashed_workers_idle_full(&e).is_empty());
        // force the bug hook: containers keep working on a dead machine
        for w in 0..e.workers() {
            e.apply(EngineCmd::ForceOfflineNoEvict { worker: w });
        }
        e.step_interval();
        let full = crashed_workers_idle_full(&e);
        assert!(!full.is_empty(), "offline-no-evict must leave offenders");
        assert_eq!(full, crashed_workers_idle_indexed(&e));
        assert_twins_agree(&e, "smoke-offline");

        // property leg: random chaos-heavy plans, twins checked after
        // every interval
        for seed in 0..4u64 {
            let mut rng = Rng::new(0xD1CE ^ seed);
            let mut e = engine();
            let intervals = 10usize;
            let plan =
                FaultPlan::generate(rng.next_u64(), intervals, Profile::Heavy, e.workers());
            let mut next_id = 0u64;
            for t in 0..intervals {
                for ev in plan.events_at(t) {
                    for cmd in ev.event.compile(e.workers()) {
                        e.apply(cmd);
                    }
                }
                for _ in 0..1 + rng.below(3) {
                    e.admit(task(next_id), SplitDecision::Layer);
                    next_id += 1;
                }
                let mut assigns: Vec<(usize, usize)> = Vec::new();
                for c in e.placeable() {
                    if rng.chance(0.8) {
                        assigns.push((c, rng.below(10) as usize));
                    }
                }
                e.apply_placement(&assigns);
                e.step_interval();
                assert_twins_agree(&e, &format!("seed {seed} interval {t}"));
            }
        }
    }

    /// The chain-precedence latch: a container driven terminal *ahead of
    /// an unfinished predecessor* (a transition no correct engine
    /// performs — forced through the test-only sabotage hook) must keep
    /// failing the indexed sweep exactly as long as the full scan does,
    /// including after it leaves the active set, and must stop when the
    /// predecessor eventually finishes.
    #[test]
    fn terminal_transition_latch_preserves_post_hoc_memory() {
        let mut e = engine();
        e.admit(task(0), SplitDecision::Layer); // chain of fragments
        let succ = e
            .containers()
            .iter()
            .find(|c| c.prev.is_some())
            .map(|c| c.id)
            .expect("layer split admits a chain successor");
        let prev = e.containers()[succ].prev.unwrap();
        assert!(!e.containers()[prev].is_done(), "predecessor starts unfinished");
        // sanity: nothing latched, twins agree and are quiet
        assert!(e.chain_suspects().is_empty());
        assert_eq!(chain_precedence_full(&e), chain_precedence_indexed(&e));

        e.sabotage_out_of_order_terminal(succ);
        assert_eq!(e.chain_suspects(), &[succ], "latch fires at the transition");
        let full = chain_precedence_full(&e);
        assert!(
            full.iter().any(|d| d.contains(&format!("container {succ} progressed"))),
            "full scan must flag the terminal offender: {full:?}"
        );
        assert_eq!(full, chain_precedence_indexed(&e), "latch keeps the twins exact");
        e.verify_indices().expect("latch is index-consistent");

        // the memory is post-hoc: the offender stays flagged on later
        // intervals even though it is no longer active
        for _ in 0..3 {
            e.step_interval();
            let full = chain_precedence_full(&e);
            assert!(
                full.iter().any(|d| d.contains(&format!("container {succ} "))),
                "terminal offender must keep failing the full scan: {full:?}"
            );
            assert_eq!(full, chain_precedence_indexed(&e));
        }
        // place + run the chain until the predecessor finishes: both
        // derivations must go quiet about the (now-ordered) offender in
        // lockstep — the latch entry stays but produces no details
        e.apply_placement(&[(0, 0), (1, 1), (2, 2), (3, 3)]);
        for _ in 0..60 {
            e.step_interval();
            assert_eq!(chain_precedence_full(&e), chain_precedence_indexed(&e));
            if e.containers()[prev].is_done() {
                break;
            }
        }
        if e.containers()[prev].is_done() {
            assert!(
                !chain_precedence_full(&e)
                    .iter()
                    .any(|d| d.contains(&format!("container {succ} progressed"))),
                "a finished predecessor un-flags the offender in BOTH twins"
            );
            assert_eq!(e.chain_suspects(), &[succ], "stale latch entries are kept, inert");
        }
    }

    /// Every migrated twin pair, compared after an interval step.
    fn assert_twins_agree(e: &Engine, tag: &str) {
        assert_eq!(
            chain_precedence_full(e),
            chain_precedence_indexed(e),
            "chain-precedence diverged at {tag}"
        );
        assert_eq!(
            crashed_workers_idle_full(e),
            crashed_workers_idle_indexed(e),
            "crashed-workers-idle diverged at {tag}"
        );
        assert_eq!(
            allocation_capacity_full(e),
            allocation_capacity_indexed(e),
            "allocation-capacity diverged at {tag}"
        );
        let mut tc_full = task_conservation_full(e);
        tc_full.sort();
        let mut tc_idx = task_conservation_indexed(e);
        tc_idx.sort();
        assert_eq!(tc_full, tc_idx, "task-conservation diverged at {tag}");
        assert_eq!(
            telemetry_queued_full(e),
            telemetry_queued_indexed(e),
            "telemetry queued count diverged at {tag}"
        );
        assert_eq!(
            ledger_replay_full(e),
            {
                let replayed = FaultSurface::replay(e.workers(), e.ledger());
                surface_divergence_detail(e, &replayed).into_iter().collect::<Vec<_>>()
            },
            "ledger-replay twin must be self-consistent at {tag}"
        );
    }
}
