//! Network topology: single-hop LAN among edge workers + broker (paper
//! default), or WAN to a remote cloud datacenter (Fig. 18's "Cloud" setup).
//!
//! Transfer times combine base ping (mobility-modulated), payload size and
//! the bottleneck bandwidth of the two endpoints.

use super::mobility::ChannelState;
use super::node::{Cluster, BROKER};
use crate::config::Tier;

/// WAN penalty for the cloud setup (UK-South broker → East-US workers):
/// multi-hop RTT and shared-backbone bandwidth cap.
const WAN_EXTRA_PING_MS: f64 = 75.0;
const WAN_BW_CAP_MBPS: f64 = 120.0;

/// Effective one-way latency (seconds) between the broker and worker `w`.
pub fn broker_latency_s(cluster: &Cluster, w: usize, ch: &ChannelState) -> f64 {
    let base = cluster.workers[w].spec.ping_ms * ch.ping_mult + BROKER.ping_ms;
    let extra = match cluster.tier {
        Tier::Edge => 0.0,
        Tier::Cloud => WAN_EXTRA_PING_MS,
    };
    (base + extra) / 1000.0
}

/// Effective bandwidth (MB/s) between the broker and worker `w`.
/// Note Table 3 lists NIC speeds in Mbps; we convert to MB/s here.
pub fn broker_bw_mbytes(cluster: &Cluster, w: usize, ch: &ChannelState) -> f64 {
    let node_mbps = cluster.workers[w].spec.net_bw_mbps * ch.bw_factor;
    let mbps = match cluster.tier {
        Tier::Edge => node_mbps.min(BROKER.net_bw_mbps),
        Tier::Cloud => node_mbps.min(WAN_BW_CAP_MBPS),
    };
    mbps / 8.0
}

/// Transfer time (seconds) of `payload_mb` from the broker to worker `w`
/// (or back — symmetric).
pub fn broker_transfer_s(cluster: &Cluster, w: usize, ch: &ChannelState, payload_mb: f64) -> f64 {
    broker_latency_s(cluster, w, ch) + payload_mb / broker_bw_mbytes(cluster, w, ch)
}

/// Transfer time (seconds) of `payload_mb` between two workers (layer-split
/// intermediate-result forwarding; single hop inside the LAN, two hops —
/// via the backbone — in the cloud tier).
pub fn worker_transfer_s(
    cluster: &Cluster,
    src: usize,
    dst: usize,
    ch_src: &ChannelState,
    ch_dst: &ChannelState,
    payload_mb: f64,
) -> f64 {
    if src == dst {
        // same node: memcpy at RAM bandwidth
        return payload_mb / (cluster.workers[src].spec.ram_bw_mbps).max(1.0);
    }
    let lat = (cluster.workers[src].spec.ping_ms * ch_src.ping_mult
        + cluster.workers[dst].spec.ping_ms * ch_dst.ping_mult)
        / 1000.0
        + match cluster.tier {
            Tier::Edge => 0.0,
            Tier::Cloud => WAN_EXTRA_PING_MS / 1000.0,
        };
    let bw_mbps = (cluster.workers[src].spec.net_bw_mbps * ch_src.bw_factor)
        .min(cluster.workers[dst].spec.net_bw_mbps * ch_dst.bw_factor);
    let bw_mbps = match cluster.tier {
        Tier::Edge => bw_mbps,
        Tier::Cloud => bw_mbps.min(WAN_BW_CAP_MBPS),
    };
    lat + payload_mb / (bw_mbps / 8.0)
}

/// Container-image distribution time at experiment start (paper §6.6: one
/// 30 s one-time broadcast for SplitPlace): total image MB over the
/// broker's NIC, fanned out to every worker.
pub fn image_broadcast_s(cluster: &Cluster, total_image_mb: f64) -> f64 {
    let broker_bw = BROKER.net_bw_mbps / 8.0;
    let slowest = cluster
        .workers
        .iter()
        .map(|w| w.spec.net_bw_mbps / 8.0)
        .fold(f64::INFINITY, f64::min);
    let extra = match cluster.tier {
        Tier::Edge => 0.0,
        Tier::Cloud => total_image_mb / (WAN_BW_CAP_MBPS / 8.0),
    };
    total_image_mb / broker_bw.min(slowest) + extra
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::node::build_fleet;
    use crate::config::{ClusterConfig, Tier};

    fn edge() -> Cluster {
        build_fleet(&ClusterConfig::default())
    }

    fn cloud() -> Cluster {
        build_fleet(&ClusterConfig { tier: Tier::Cloud, ..Default::default() })
    }

    #[test]
    fn cloud_latency_dominates_edge() {
        let e = edge();
        let c = cloud();
        let ch = ChannelState::STATIC;
        assert!(broker_latency_s(&c, 0, &ch) > 20.0 * broker_latency_s(&e, 0, &ch));
    }

    #[test]
    fn cloud_bandwidth_capped() {
        let c = cloud();
        let ch = ChannelState::STATIC;
        assert!(broker_bw_mbytes(&c, 0, &ch) <= WAN_BW_CAP_MBPS / 8.0 + 1e-9);
    }

    #[test]
    fn mobility_slows_transfers() {
        let e = edge();
        let good = ChannelState::STATIC;
        let bad = ChannelState { ping_mult: 4.0, bw_factor: 0.3 };
        let t_good = broker_transfer_s(&e, 0, &good, 100.0);
        let t_bad = broker_transfer_s(&e, 0, &bad, 100.0);
        assert!(t_bad > 2.0 * t_good);
    }

    #[test]
    fn same_node_transfer_is_memcpy() {
        let e = edge();
        let ch = ChannelState::STATIC;
        let t_same = worker_transfer_s(&e, 3, 3, &ch, &ch, 100.0);
        let t_diff = worker_transfer_s(&e, 3, 4, &ch, &ch, 100.0);
        assert!(t_same < t_diff);
    }

    #[test]
    fn transfer_scales_with_payload() {
        let e = edge();
        let ch = ChannelState::STATIC;
        let t1 = worker_transfer_s(&e, 0, 1, &ch, &ch, 10.0);
        let t2 = worker_transfer_s(&e, 0, 1, &ch, &ch, 20.0);
        assert!(t2 > t1);
        // latency-dominated floor: tiny payloads still cost the ping
        let t0 = worker_transfer_s(&e, 0, 1, &ch, &ch, 0.0);
        assert!(t0 > 0.0);
    }

    #[test]
    fn broadcast_time_reasonable() {
        // ~1.2 GB of images over a 125 MB/s LAN ≈ 10 s-scale, the paper
        // reports 30 s including orchestration overheads.
        let e = edge();
        let t = image_broadcast_s(&e, 1200.0);
        assert!(t > 1.0 && t < 120.0, "t={t}");
        let c = cloud();
        assert!(image_broadcast_s(&c, 1200.0) > t);
    }
}
