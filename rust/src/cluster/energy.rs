//! SPEC-benchmark-style energy model (paper §6.1 takes power curves from
//! the SPEC repository; we encode the standard ssj linear-interpolation
//! shape: power grows monotonically, slightly super-linearly at low load).

use super::node::NodeType;

/// Instantaneous power draw (watts) at CPU utilization `util` ∈ [0, 1].
///
/// Piecewise-linear through the SPEC ssj anchor points: idle, 50%, 100%.
/// The 50% point sits at idle + 0.65·(peak−idle), matching the concave
/// shape of published SPEC curves for small x86 servers.
pub fn power_watts(spec: &NodeType, util: f64) -> f64 {
    let u = util.clamp(0.0, 1.0);
    let idle = spec.idle_watts;
    let peak = spec.peak_watts;
    let mid = idle + 0.65 * (peak - idle);
    if u <= 0.5 {
        idle + (mid - idle) * (u / 0.5)
    } else {
        mid + (peak - mid) * ((u - 0.5) / 0.5)
    }
}

/// Energy (watt-hours) consumed over `seconds` at constant `util`.
pub fn energy_wh(spec: &NodeType, util: f64, seconds: f64) -> f64 {
    power_watts(spec, util) * seconds / 3600.0
}

/// Interval energy for a whole fleet given per-worker utilizations.
pub fn fleet_energy_wh(specs: &[&NodeType], utils: &[f64], seconds: f64) -> f64 {
    fleet_energy_wh_over(specs.iter().copied(), utils, seconds)
}

/// Iterator-generic fleet energy: same left-to-right `sum()` fold as the
/// slice form (bit-identical for the same spec sequence), but callers can
/// feed worker specs straight from their own storage without building a
/// per-interval `Vec<&NodeType>`.
pub fn fleet_energy_wh_over<'a>(
    specs: impl Iterator<Item = &'a NodeType>,
    utils: &[f64],
    seconds: f64,
) -> f64 {
    specs.zip(utils).map(|(s, &u)| energy_wh(s, u, seconds)).sum()
}

/// Normalized average energy consumption (AEC ∈ [0,1]) for the reward in
/// eq. 10: actual energy over the maximum possible (all workers at peak).
pub fn normalized_aec(specs: &[&NodeType], utils: &[f64], seconds: f64) -> f64 {
    normalized_aec_over(specs.iter().copied(), utils, seconds)
}

/// Iterator-generic AEC (see [`fleet_energy_wh_over`]): both the actual
/// and the peak-power fold keep the slice form's exact order.
pub fn normalized_aec_over<'a>(
    specs: impl Iterator<Item = &'a NodeType> + Clone,
    utils: &[f64],
    seconds: f64,
) -> f64 {
    let actual = fleet_energy_wh_over(specs.clone(), utils, seconds);
    let max: f64 = specs.map(|s| s.peak_watts * seconds / 3600.0).sum();
    if max == 0.0 {
        0.0
    } else {
        actual / max
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::node::NODE_TYPES;

    #[test]
    fn power_monotone_in_util() {
        let s = &NODE_TYPES[0];
        let mut prev = 0.0;
        for i in 0..=20 {
            let p = power_watts(s, i as f64 / 20.0);
            assert!(p >= prev, "power must be monotone");
            prev = p;
        }
    }

    #[test]
    fn power_endpoints() {
        let s = &NODE_TYPES[1];
        assert_eq!(power_watts(s, 0.0), s.idle_watts);
        assert_eq!(power_watts(s, 1.0), s.peak_watts);
        // out-of-range clamped
        assert_eq!(power_watts(s, -1.0), s.idle_watts);
        assert_eq!(power_watts(s, 2.0), s.peak_watts);
    }

    #[test]
    fn concave_shape() {
        // 50% load should draw more than the linear midpoint
        let s = &NODE_TYPES[2];
        let half = power_watts(s, 0.5);
        let linear_mid = (s.idle_watts + s.peak_watts) / 2.0;
        assert!(half > linear_mid);
    }

    #[test]
    fn energy_integrates_power() {
        let s = &NODE_TYPES[0];
        let e = energy_wh(s, 1.0, 3600.0);
        assert!((e - s.peak_watts).abs() < 1e-9);
    }

    #[test]
    fn normalized_aec_bounds() {
        let specs: Vec<&NodeType> = NODE_TYPES.iter().collect();
        let idle = normalized_aec(&specs, &[0.0; 4], 300.0);
        let full = normalized_aec(&specs, &[1.0; 4], 300.0);
        assert!(idle > 0.0 && idle < full);
        assert!((full - 1.0).abs() < 1e-9);
    }
}
