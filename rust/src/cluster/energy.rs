//! SPEC-benchmark-style energy model (paper §6.1 takes power curves from
//! the SPEC repository; we encode the standard ssj linear-interpolation
//! shape: power grows monotonically, slightly super-linearly at low load).

use super::node::NodeType;

/// Instantaneous power draw (watts) at CPU utilization `util` ∈ [0, 1].
///
/// Piecewise-linear through the SPEC ssj anchor points: idle, 50%, 100%.
/// The 50% point sits at idle + 0.65·(peak−idle), matching the concave
/// shape of published SPEC curves for small x86 servers.
pub fn power_watts(spec: &NodeType, util: f64) -> f64 {
    let u = util.clamp(0.0, 1.0);
    let idle = spec.idle_watts;
    let peak = spec.peak_watts;
    let mid = idle + 0.65 * (peak - idle);
    if u <= 0.5 {
        idle + (mid - idle) * (u / 0.5)
    } else {
        mid + (peak - mid) * ((u - 0.5) / 0.5)
    }
}

/// Energy (watt-hours) consumed over `seconds` at constant `util`.
pub fn energy_wh(spec: &NodeType, util: f64, seconds: f64) -> f64 {
    power_watts(spec, util) * seconds / 3600.0
}

/// Interval energy for a whole fleet given per-worker utilizations.
pub fn fleet_energy_wh(specs: &[&NodeType], utils: &[f64], seconds: f64) -> f64 {
    fleet_energy_wh_over(specs.iter().copied(), utils, seconds)
}

/// Iterator-generic fleet energy: same left-to-right `sum()` fold as the
/// slice form (bit-identical for the same spec sequence), but callers can
/// feed worker specs straight from their own storage without building a
/// per-interval `Vec<&NodeType>`.
///
/// **Contract:** `specs` must yield exactly `utils.len()` items — one
/// utilization per worker spec. The zip would silently truncate the sum
/// to the shorter sequence on a mismatch, so the pairing is a checked
/// invariant (debug builds assert it).
pub fn fleet_energy_wh_over<'a>(
    specs: impl Iterator<Item = &'a NodeType>,
    utils: &[f64],
    seconds: f64,
) -> f64 {
    let mut paired = 0usize;
    let total = specs
        .zip(utils)
        .map(|(s, &u)| {
            paired += 1;
            energy_wh(s, u, seconds)
        })
        .sum();
    debug_assert_eq!(
        paired,
        utils.len(),
        "fleet_energy_wh_over: {paired} specs paired with {} utils — \
         the spec iterator ran short and the sum was truncated",
        utils.len()
    );
    total
}

/// Normalized average energy consumption (AEC ∈ [0,1]) for the reward in
/// eq. 10: actual energy over the maximum possible (all workers at peak).
pub fn normalized_aec(specs: &[&NodeType], utils: &[f64], seconds: f64) -> f64 {
    normalized_aec_over(specs.iter().copied(), utils, seconds)
}

/// Iterator-generic AEC (see [`fleet_energy_wh_over`]): both the actual
/// and the peak-power fold keep the slice form's exact order.
///
/// **Contract:** `specs` must yield exactly `utils.len()` items. The
/// `actual` numerator pairs specs with utils while the peak-power
/// denominator consumes *every* spec, so a longer spec iterator would
/// silently deflate AEC (truncated numerator over a full denominator) —
/// the length match is a checked invariant (debug builds assert it).
pub fn normalized_aec_over<'a>(
    specs: impl Iterator<Item = &'a NodeType> + Clone,
    utils: &[f64],
    seconds: f64,
) -> f64 {
    let actual = fleet_energy_wh_over(specs.clone(), utils, seconds);
    let mut n_specs = 0usize;
    let max: f64 = specs
        .map(|s| {
            n_specs += 1;
            s.peak_watts * seconds / 3600.0
        })
        .sum();
    debug_assert_eq!(
        n_specs,
        utils.len(),
        "normalized_aec_over: {n_specs} specs vs {} utils — actual energy \
         zips (truncates) while the peak denominator sums all specs, \
         silently deflating AEC on any mismatch",
        utils.len()
    );
    if max == 0.0 {
        0.0
    } else {
        actual / max
    }
}

/// AEC with offline gating: like [`normalized_aec_over`], but a worker
/// whose `online` flag is down contributes **0 W** to the numerator — a
/// crashed, parked, or battery-dead machine draws nothing, it does not
/// idle. The denominator stays the full fleet at peak, so taking workers
/// down *lowers* AEC rather than renormalizing it away.
///
/// Bit-compatibility: the numerator keeps the same left-to-right `sum()`
/// fold as [`fleet_energy_wh_over`], emitting a literal `0.0` for offline
/// workers. Adding `0.0` to a non-negative running sum is bit-identical
/// to skipping the term, so on an all-online fleet this returns exactly
/// the same bits as the ungated form.
///
/// **Contract:** `specs` must yield exactly `utils.len()` items and
/// `online.len()` must match (debug builds assert both).
pub fn normalized_aec_gated_over<'a>(
    specs: impl Iterator<Item = &'a NodeType> + Clone,
    utils: &[f64],
    online: &[bool],
    seconds: f64,
) -> f64 {
    debug_assert_eq!(
        utils.len(),
        online.len(),
        "normalized_aec_gated_over: {} utils vs {} online flags",
        utils.len(),
        online.len()
    );
    let mut paired = 0usize;
    let actual: f64 = specs
        .clone()
        .zip(utils.iter().zip(online))
        .map(|(s, (&u, &up))| {
            paired += 1;
            if up {
                energy_wh(s, u, seconds)
            } else {
                0.0
            }
        })
        .sum();
    let mut n_specs = 0usize;
    let max: f64 = specs
        .map(|s| {
            n_specs += 1;
            s.peak_watts * seconds / 3600.0
        })
        .sum();
    debug_assert_eq!(
        paired,
        utils.len(),
        "normalized_aec_gated_over: {paired} specs paired with {} utils — \
         the spec iterator ran short and the numerator was truncated",
        utils.len()
    );
    debug_assert_eq!(
        n_specs,
        utils.len(),
        "normalized_aec_gated_over: {n_specs} specs vs {} utils",
        utils.len()
    );
    if max == 0.0 {
        0.0
    } else {
        actual / max
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::node::NODE_TYPES;

    #[test]
    fn power_monotone_in_util() {
        let s = &NODE_TYPES[0];
        let mut prev = 0.0;
        for i in 0..=20 {
            let p = power_watts(s, i as f64 / 20.0);
            assert!(p >= prev, "power must be monotone");
            prev = p;
        }
    }

    #[test]
    fn power_endpoints() {
        let s = &NODE_TYPES[1];
        assert_eq!(power_watts(s, 0.0), s.idle_watts);
        assert_eq!(power_watts(s, 1.0), s.peak_watts);
        // out-of-range clamped
        assert_eq!(power_watts(s, -1.0), s.idle_watts);
        assert_eq!(power_watts(s, 2.0), s.peak_watts);
    }

    #[test]
    fn concave_shape() {
        // 50% load should draw more than the linear midpoint
        let s = &NODE_TYPES[2];
        let half = power_watts(s, 0.5);
        let linear_mid = (s.idle_watts + s.peak_watts) / 2.0;
        assert!(half > linear_mid);
    }

    #[test]
    fn energy_integrates_power() {
        let s = &NODE_TYPES[0];
        let e = energy_wh(s, 1.0, 3600.0);
        assert!((e - s.peak_watts).abs() < 1e-9);
    }

    #[test]
    fn normalized_aec_bounds() {
        let specs: Vec<&NodeType> = NODE_TYPES.iter().collect();
        let idle = normalized_aec(&specs, &[0.0; 4], 300.0);
        let full = normalized_aec(&specs, &[1.0; 4], 300.0);
        assert!(idle > 0.0 && idle < full);
        assert!((full - 1.0).abs() < 1e-9);
    }

    #[test]
    fn gated_aec_matches_ungated_bits_when_all_online() {
        let specs: Vec<&NodeType> = NODE_TYPES.iter().collect();
        let utils = [0.0, 0.3, 0.7, 1.0];
        let gated = normalized_aec_gated_over(specs.iter().copied(), &utils, &[true; 4], 300.0);
        let ungated = normalized_aec(&specs, &utils, 300.0);
        assert_eq!(
            gated.to_bits(),
            ungated.to_bits(),
            "all-online gated AEC must be bit-identical to the ungated fold"
        );
    }

    #[test]
    fn gated_aec_drops_offline_workers_from_the_numerator_only() {
        let specs: Vec<&NodeType> = NODE_TYPES.iter().collect();
        let utils = [1.0; 4];
        let all_on = normalized_aec_gated_over(specs.iter().copied(), &utils, &[true; 4], 300.0);
        let one_off =
            normalized_aec_gated_over(specs.iter().copied(), &utils, &[true, true, true, false], 300.0);
        assert!(one_off < all_on, "an offline worker must draw 0 W: {one_off} vs {all_on}");
        // denominator unchanged: the missing share is exactly worker 3's peak
        let peak_sum: f64 = NODE_TYPES.iter().map(|s| s.peak_watts).sum();
        let expected = (peak_sum - NODE_TYPES[3].peak_watts) / peak_sum;
        assert!((one_off - expected).abs() < 1e-12);
        // all offline → zero energy, not NaN
        let none = normalized_aec_gated_over(specs.iter().copied(), &utils, &[false; 4], 300.0);
        assert_eq!(none, 0.0);
    }

    /// Regression: a spec iterator longer than `utils` used to zip-truncate
    /// the actual-energy numerator while the peak denominator summed every
    /// spec, silently deflating AEC (an all-peak fleet reported < 1.0).
    /// The length mismatch is now a checked invariant.
    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "normalized_aec_over")]
    fn aec_spec_util_length_mismatch_is_rejected() {
        // 4 specs, 3 utils: the truncated fold would have returned ~3/4
        normalized_aec_over(NODE_TYPES.iter(), &[1.0; 3], 300.0);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "fleet_energy_wh_over")]
    fn fleet_energy_short_spec_iterator_is_rejected() {
        // 4 specs, 5 utils: the spec side runs short and the sum truncates
        fleet_energy_wh_over(NODE_TYPES.iter(), &[1.0; 5], 300.0);
    }
}
