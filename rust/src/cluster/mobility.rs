//! Urban-mobility channel model (SUMO/NetLimiter substitute, DESIGN.md §3).
//!
//! The paper feeds SUMO-generated per-worker ping/bandwidth time-series into
//! NetLimiter. The decision problem only ever observes those two series, so
//! we generate statistically similar ones: each mobile worker follows a
//! mean-reverting random walk in "signal quality" q ∈ [0, 1] (an
//! Ornstein–Uhlenbeck discretization — vehicles drift toward/away from
//! access points smoothly), mapped to
//!
//!   ping multiplier  = 1 / q      (clamped to [1, ping_max])
//!   bandwidth factor = q          (clamped to [bw_min, 1])
//!
//! Static workers keep multiplier 1. Series are seeded and reproducible.

use crate::util::rng::Rng;

/// Ceiling on the ping multiplier any channel state can carry — the single
/// source both the OU walk's clamp ([`MobilityModel`]) and the blackout
/// state ([`ChannelState::BLACKOUT`]) read, so "an outage is at least as
/// bad as the worst reachable signal" holds by construction, not by two
/// literals staying in sync.
pub const PING_MAX: f64 = 6.0;

/// Floor on the bandwidth factor the OU walk can reach. A blackout's
/// bandwidth sits strictly below this, keeping the outage dominance claim
/// structural on both axes.
pub const BW_MIN: f64 = 0.25;

/// Per-interval channel state of one worker.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ChannelState {
    /// ≥ 1: multiplies the node's base ping.
    pub ping_mult: f64,
    /// ∈ (0, 1]: scales the node's base bandwidth.
    pub bw_factor: f64,
}

impl ChannelState {
    pub const STATIC: ChannelState = ChannelState { ping_mult: 1.0, bw_factor: 1.0 };

    /// Worst-case channel during an injected network blackout: ping pinned
    /// at the mobility ceiling, bandwidth well below the OU floor (a real
    /// outage is worse than any bad-signal state the OU walk can reach).
    pub const BLACKOUT: ChannelState = ChannelState { ping_mult: PING_MAX, bw_factor: 0.05 };
}

/// Mobility trace generator for a fleet.
#[derive(Clone, Debug)]
pub struct MobilityModel {
    /// Current signal quality per worker (1.0 for static workers).
    q: Vec<f64>,
    mobile: Vec<bool>,
    rng: Rng,
    /// OU mean-reversion rate per interval.
    theta: f64,
    /// OU noise std per interval.
    sigma: f64,
    /// Long-run mean quality.
    mu: f64,
    ping_max: f64,
    bw_min: f64,
}

impl MobilityModel {
    pub fn new(mobile_flags: &[bool], seed: u64) -> Self {
        MobilityModel {
            q: vec![1.0; mobile_flags.len()],
            mobile: mobile_flags.to_vec(),
            rng: Rng::new(seed),
            theta: 0.25,
            sigma: 0.18,
            mu: 0.75,
            ping_max: PING_MAX,
            bw_min: BW_MIN,
        }
    }

    /// Advance one scheduling interval; returns the channel state per worker.
    pub fn step(&mut self) -> Vec<ChannelState> {
        let mut out = Vec::with_capacity(self.q.len());
        for i in 0..self.q.len() {
            if !self.mobile[i] {
                out.push(ChannelState::STATIC);
                continue;
            }
            // OU update toward mu
            let noise = self.rng.normal() * self.sigma;
            self.q[i] += self.theta * (self.mu - self.q[i]) + noise;
            self.q[i] = self.q[i].clamp(0.05, 1.0);
            let ping_mult = (1.0 / self.q[i]).clamp(1.0, self.ping_max);
            let bw_factor = self.q[i].clamp(self.bw_min, 1.0);
            out.push(ChannelState { ping_mult, bw_factor });
        }
        out
    }

    /// Generate a whole trace of `n` intervals up front (used by benches
    /// for reproducible scenario replay).
    pub fn trace(&mut self, n: usize) -> Vec<Vec<ChannelState>> {
        (0..n).map(|_| self.step()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn static_workers_unaffected() {
        let mut m = MobilityModel::new(&[false, true, false], 1);
        for _ in 0..50 {
            let s = m.step();
            assert_eq!(s[0], ChannelState::STATIC);
            assert_eq!(s[2], ChannelState::STATIC);
        }
    }

    #[test]
    fn mobile_workers_vary_within_bounds() {
        let mut m = MobilityModel::new(&[true], 2);
        let tr = m.trace(200);
        let pings: Vec<f64> = tr.iter().map(|s| s[0].ping_mult).collect();
        let bws: Vec<f64> = tr.iter().map(|s| s[0].bw_factor).collect();
        assert!(pings.iter().all(|p| (1.0..=6.0).contains(p)));
        assert!(bws.iter().all(|b| (0.25..=1.0).contains(b)));
        // actually varies
        let pmin = pings.iter().cloned().fold(f64::INFINITY, f64::min);
        let pmax = pings.iter().cloned().fold(0.0, f64::max);
        assert!(pmax - pmin > 0.2, "trace too flat: {pmin}..{pmax}");
    }

    #[test]
    fn seeded_reproducible() {
        let t1 = MobilityModel::new(&[true, true], 7).trace(20);
        let t2 = MobilityModel::new(&[true, true], 7).trace(20);
        assert_eq!(t1, t2);
        let t3 = MobilityModel::new(&[true, true], 8).trace(20);
        assert_ne!(t1, t3);
    }

    /// The dominance claim behind [`ChannelState::BLACKOUT`]: an injected
    /// outage must be at least as bad as ANY state the OU walk can reach —
    /// ping at the shared ceiling, bandwidth strictly below the OU floor.
    /// Both sides now read the same consts, so this pins the coupling.
    #[test]
    fn blackout_dominates_every_reachable_ou_state() {
        assert_eq!(ChannelState::BLACKOUT.ping_mult, PING_MAX);
        assert!(ChannelState::BLACKOUT.bw_factor < BW_MIN);
        let mut m = MobilityModel::new(&[true, true, true], 11);
        for states in m.trace(500) {
            for s in states {
                assert!(
                    s.ping_mult <= ChannelState::BLACKOUT.ping_mult,
                    "OU ping {} exceeds the blackout ceiling",
                    s.ping_mult
                );
                assert!(
                    s.bw_factor >= BW_MIN && s.bw_factor > ChannelState::BLACKOUT.bw_factor,
                    "OU bandwidth {} at or below the blackout floor",
                    s.bw_factor
                );
            }
        }
    }

    #[test]
    fn mean_reverts() {
        // long-run average quality should sit near mu, i.e. bw_factor ~0.7
        let mut m = MobilityModel::new(&[true], 3);
        let tr = m.trace(2000);
        let avg_bw: f64 = tr.iter().map(|s| s[0].bw_factor).sum::<f64>() / 2000.0;
        assert!((0.55..=0.9).contains(&avg_bw), "avg_bw={avg_bw}");
    }
}
