//! Worker-node fleet: the paper's Azure testbed (Table 3) encoded as typed
//! node specs, with the constrained-environment variants of Appendix A.3.

use crate::config::{ClusterConfig, EnvConstraint, Tier};
use crate::util::rng::Rng;

/// Static node specification — columns of Table 3 plus a SPEC-style power
/// model (idle/peak watts; see `cluster::energy`).
#[derive(Clone, Debug, PartialEq)]
pub struct NodeType {
    pub name: &'static str,
    pub cores: u32,
    /// Aggregate compute throughput, Million Instructions Per Second.
    pub mips: f64,
    pub ram_mb: f64,
    pub ram_bw_mbps: f64,
    /// Base one-way latency to the broker, milliseconds.
    pub ping_ms: f64,
    pub net_bw_mbps: f64,
    pub disk_bw_mbps: f64,
    pub cost_per_hr: f64,
    pub idle_watts: f64,
    pub peak_watts: f64,
}

/// Table 3 worker types. Power numbers follow the SPEC ssj-style linear
/// model (idle ≈ 55–60% of peak for small VMs), scaled by core count.
pub const NODE_TYPES: [NodeType; 4] = [
    NodeType {
        name: "B2ms",
        cores: 2,
        mips: 4029.0,
        ram_mb: 4295.0,
        ram_bw_mbps: 372.0,
        ping_ms: 2.0,
        net_bw_mbps: 1000.0,
        disk_bw_mbps: 13.4,
        cost_per_hr: 0.0944,
        idle_watts: 62.0,
        peak_watts: 108.0,
    },
    NodeType {
        name: "E2asv4",
        cores: 2,
        mips: 4019.0,
        ram_mb: 4172.0,
        ram_bw_mbps: 412.0,
        ping_ms: 2.0,
        net_bw_mbps: 1000.0,
        disk_bw_mbps: 10.3,
        cost_per_hr: 0.148,
        idle_watts: 60.0,
        peak_watts: 104.0,
    },
    NodeType {
        name: "B4ms",
        cores: 4,
        mips: 8102.0,
        ram_mb: 7962.0,
        ram_bw_mbps: 360.0,
        ping_ms: 3.0,
        net_bw_mbps: 2500.0,
        disk_bw_mbps: 10.6,
        cost_per_hr: 0.189,
        idle_watts: 78.0,
        peak_watts: 146.0,
    },
    NodeType {
        name: "E4asv4",
        cores: 4,
        mips: 7962.0,
        ram_mb: 7962.0,
        ram_bw_mbps: 476.0,
        ping_ms: 3.0,
        net_bw_mbps: 2500.0,
        disk_bw_mbps: 11.64,
        cost_per_hr: 0.296,
        idle_watts: 76.0,
        peak_watts: 142.0,
    },
];

/// The broker (L8sv2 in Table 3); only its network spec matters to workers.
pub const BROKER: NodeType = NodeType {
    name: "L8sv2",
    cores: 8,
    mips: 16182.0,
    ram_mb: 17012.0,
    ram_bw_mbps: 945.0,
    ping_ms: 1.0,
    net_bw_mbps: 4000.0,
    disk_bw_mbps: 17.6,
    cost_per_hr: 0.724,
    idle_watts: 110.0,
    peak_watts: 210.0,
};

/// One concrete worker instance.
#[derive(Clone, Debug)]
pub struct Worker {
    pub id: usize,
    pub spec: NodeType,
    /// Mobile workers get time-varying ping/bandwidth (see `mobility`).
    pub mobile: bool,
}

/// The whole edge layer.
#[derive(Clone, Debug)]
pub struct Cluster {
    pub workers: Vec<Worker>,
    pub tier: Tier,
    pub constraint: EnvConstraint,
    /// Per-worker battery capacity (Wh); `None` = grid-powered. Carried
    /// from [`ClusterConfig::battery_wh`] so the engine can seed its
    /// battery plane without re-reading the config.
    pub battery_wh: Option<f64>,
}

impl Cluster {
    pub fn len(&self) -> usize {
        self.workers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.workers.is_empty()
    }

    pub fn total_mips(&self) -> f64 {
        self.workers.iter().map(|w| w.spec.mips).sum()
    }

    pub fn total_ram_mb(&self) -> f64 {
        self.workers.iter().map(|w| w.spec.ram_mb).sum()
    }
}

fn apply_constraint(mut spec: NodeType, c: EnvConstraint) -> NodeType {
    match c {
        EnvConstraint::None => {}
        EnvConstraint::Compute => {
            // grub-config core limiting in the paper: half the cores.
            spec.cores = (spec.cores / 2).max(1);
            spec.mips /= 2.0;
        }
        EnvConstraint::Network => {
            spec.net_bw_mbps /= 2.0;
        }
        EnvConstraint::Memory => {
            spec.ram_mb /= 2.0;
        }
    }
    spec
}

/// Build the worker fleet from a [`ClusterConfig`]: Table 3 quantities,
/// constraint variant, and a seeded mobile/static assignment.
pub fn build_fleet(cfg: &ClusterConfig) -> Cluster {
    let mut rng = Rng::new(cfg.seed);
    let mut workers = Vec::new();
    for (ti, &count) in cfg.counts.iter().enumerate() {
        for _ in 0..count {
            let spec = apply_constraint(NODE_TYPES[ti].clone(), cfg.constraint);
            workers.push(Worker {
                id: workers.len(),
                spec,
                mobile: rng.chance(cfg.mobile_fraction),
            });
        }
    }
    Cluster { workers, tier: cfg.tier, constraint: cfg.constraint, battery_wh: cfg.battery_wh }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;

    #[test]
    fn default_fleet_is_fifty() {
        let c = build_fleet(&ClusterConfig::default());
        assert_eq!(c.len(), 50);
        assert_eq!(c.workers.iter().filter(|w| w.spec.name == "B2ms").count(), 20);
        assert_eq!(c.workers.iter().filter(|w| w.spec.name == "E4asv4").count(), 10);
        // ids are dense
        for (i, w) in c.workers.iter().enumerate() {
            assert_eq!(w.id, i);
        }
    }

    #[test]
    fn tier_fleets_build_dense_and_type_grouped() {
        // medium/large tiers must build like the paper fleet, just bigger:
        // dense ids, Table-3 type grouping (what rack quarters rely on)
        for (cfg, n) in [(ClusterConfig::medium(), 200), (ClusterConfig::large(), 1000)] {
            let c = build_fleet(&cfg);
            assert_eq!(c.len(), n);
            for (i, w) in c.workers.iter().enumerate() {
                assert_eq!(w.id, i);
            }
            // type-grouped in Table-3 order: B2ms block first, E4asv4 last
            assert_eq!(c.workers[0].spec.name, "B2ms");
            assert_eq!(c.workers[n - 1].spec.name, "E4asv4");
            assert_eq!(
                c.workers.iter().filter(|w| w.spec.name == "B2ms").count(),
                2 * n / 5
            );
            // rack quarters partition the tier's fleet exactly
            let mut covered = 0;
            for r in 0..crate::chaos::events::RACKS {
                covered += crate::chaos::events::rack_members(n, r).len();
            }
            assert_eq!(covered, n);
        }
    }

    #[test]
    fn table3_values() {
        assert_eq!(NODE_TYPES[0].mips, 4029.0);
        assert_eq!(NODE_TYPES[2].ram_mb, 7962.0);
        assert_eq!(NODE_TYPES[3].cost_per_hr, 0.296);
        assert_eq!(BROKER.ram_mb, 17012.0);
    }

    #[test]
    fn compute_constraint_halves_mips() {
        let cfg = ClusterConfig { constraint: EnvConstraint::Compute, ..Default::default() };
        let c = build_fleet(&cfg);
        let b2 = c
            .workers
            .iter()
            .find(|w| w.spec.name == "B2ms")
            .expect("constrained default fleet must still contain a B2ms worker");
        assert_eq!(b2.spec.mips, 4029.0 / 2.0);
        assert_eq!(b2.spec.cores, 1);
        // other resources untouched
        assert_eq!(b2.spec.ram_mb, 4295.0);
    }

    #[test]
    fn memory_constraint_halves_ram() {
        let cfg = ClusterConfig { constraint: EnvConstraint::Memory, ..Default::default() };
        let c = build_fleet(&cfg);
        assert_eq!(c.workers[0].spec.ram_mb, 4295.0 / 2.0);
        assert_eq!(c.workers[0].spec.mips, 4029.0);
    }

    #[test]
    fn network_constraint_halves_bw() {
        let cfg = ClusterConfig { constraint: EnvConstraint::Network, ..Default::default() };
        let c = build_fleet(&cfg);
        assert_eq!(c.workers[0].spec.net_bw_mbps, 500.0);
    }

    #[test]
    fn mobility_fraction_respected_statistically() {
        let cfg = ClusterConfig { mobile_fraction: 0.5, seed: 3, ..Default::default() };
        let c = build_fleet(&cfg);
        let mobile = c.workers.iter().filter(|w| w.mobile).count();
        assert!((10..=40).contains(&mobile), "mobile={mobile}");
        // deterministic across builds with same seed
        let c2 = build_fleet(&cfg);
        let flags: Vec<bool> = c.workers.iter().map(|w| w.mobile).collect();
        let flags2: Vec<bool> = c2.workers.iter().map(|w| w.mobile).collect();
        assert_eq!(flags, flags2);
    }
}
