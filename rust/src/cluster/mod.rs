//! Mobile-edge cluster substrate: node fleet (Table 3), mobility model,
//! energy/cost models, LAN/WAN topology.

pub mod energy;
pub mod mobility;
pub mod node;
pub mod topology;

pub use node::{build_fleet, Cluster, NodeType, Worker};
