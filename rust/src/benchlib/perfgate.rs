//! Perf-trajectory gate: compare a fresh [`Throughput`] run against the
//! committed `BENCH_engine.json` baseline.
//!
//! Two kinds of quantity, two kinds of band (reusing the golden-gate
//! [`Tolerance`] machinery):
//!
//! * **Counters** (`admitted`/`completed`/`failed`/`container_intervals`)
//!   are deterministic in (tier scenario, policy, seed, intervals) and
//!   compare with [`Tolerance::EXACT`] — drift there is a behavior change
//!   hiding inside a perf artifact, not noise.
//! * **Wall-clock rates** (`intervals_per_sec`,
//!   `container_intervals_per_sec`) get a wide *regression-only* band:
//!   speedups always pass, slowdowns beyond
//!   [`RATE_SLOWDOWN_TOLERANCE`] fail. Wide because CI boxes are noisy —
//!   the gate catches collapses, not percent-level drift.
//!
//! While the committed baseline is still the `measured: false`
//! placeholder (no toolchain has run the bench yet), or when no baseline
//! entry shares a fresh run's coordinates, the gate skips with a warning
//! instead of failing — an absent trajectory is debt, not a regression.
//!
//! The per-phase breakdown (`cpu_ms`/`network_ms`/`decision_ms`/
//! `oracle_ms`/`traffic_ms`) is **informational only** and deliberately
//! not read here: phase splits are the noisiest numbers a CI box
//! produces, and gating them would turn scheduler jitter into red builds.
//! The gate compares only the named counter and rate keys above, so both
//! directions of schema skew are safe — phase fields in a fresh run are
//! ignored, and pre-phase baselines (fields absent) gate exactly as
//! before.

use std::path::Path;

use crate::harness::golden::Tolerance;
use crate::util::json;

use super::throughput::Throughput;

/// Fractional slowdown tolerated on wall-clock rates before the gate
/// fails. Regression-only: a faster-than-baseline run always passes.
pub const RATE_SLOWDOWN_TOLERANCE: f64 = 0.35;

/// Outcome of gating one fresh run against the committed baseline.
#[derive(Clone, Debug, PartialEq)]
pub enum PerfGate {
    /// Baseline unusable or not comparable — carries the reason. CI warns
    /// and moves on.
    Skipped(String),
    /// All comparable tier entries were within bands; carries how many.
    Pass(usize),
    /// At least one quantity left its band; one message per failure.
    Fail(Vec<String>),
}

impl PerfGate {
    pub fn is_failure(&self) -> bool {
        matches!(self, PerfGate::Fail(_))
    }
}

/// Pull a numeric field out of a baseline tier entry; `None` when the
/// field is absent or `null` (placeholder schema).
fn num(entry: &json::Value, key: &str) -> Option<f64> {
    entry.get(key).and_then(|v| v.as_f64().ok())
}

/// Gate `fresh` against the baseline file at `path`. Call BEFORE
/// overwriting the baseline with the fresh results.
pub fn gate_against_baseline(path: &Path, fresh: &[Throughput]) -> PerfGate {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => return PerfGate::Skipped(format!("baseline {}: {e}", path.display())),
    };
    let v = match json::parse(&text) {
        Ok(v) => v,
        Err(e) => {
            return PerfGate::Skipped(format!("baseline {} unparsable: {e}", path.display()))
        }
    };
    let measured =
        v.get("measured").and_then(|m| m.as_bool().ok()).unwrap_or(false);
    if !measured {
        return PerfGate::Skipped(
            "baseline is the measured:false placeholder — record a real one with \
             `splitplace bench` on a toolchain-equipped box"
                .into(),
        );
    }
    let tiers = match v.req("tiers").and_then(|t| t.as_arr()) {
        Ok(t) => t,
        Err(e) => {
            return PerfGate::Skipped(format!("baseline {}: {e}", path.display()))
        }
    };

    let mut failures = Vec::new();
    let mut compared = 0usize;
    for r in fresh {
        // match on the full coordinate tuple; entries from the pre-policy
        // schema (no "policy" field) count as the default mc stack, and
        // entries from the pre-shards schema count as the serial 1-shard
        // run — counters are shard-independent by construction, but the
        // wall-clock rates are exactly what sharding moves, so the shard
        // count is a coordinate, not a detail
        let Some(base) = tiers.iter().find(|b| {
            b.get("tier").and_then(|t| t.as_str().ok()) == Some(r.tier.as_str())
                && b.get("policy").and_then(|p| p.as_str().ok()).unwrap_or("mc")
                    == r.policy
                && num(b, "shards").unwrap_or(1.0) == r.shards as f64
                && num(b, "intervals") == Some(r.intervals as f64)
                && b.get("seed").and_then(|s| s.as_str().ok())
                    == Some(r.seed.to_string().as_str())
                && b.get("scenario").and_then(|s| s.as_str().ok())
                    == Some(if r.chaos { "chaos-light" } else { "clean" })
        }) else {
            continue; // no baseline at these coordinates — nothing to gate
        };

        let exact: [(&str, f64); 4] = [
            ("admitted", r.admitted as f64),
            ("completed", r.completed as f64),
            ("failed", r.failed as f64),
            ("container_intervals", r.container_intervals as f64),
        ];
        let mut usable = true;
        for (key, got) in exact {
            match num(base, key) {
                None => {
                    usable = false;
                    break;
                }
                Some(want) => {
                    if !Tolerance::EXACT.accepts(got, want) {
                        failures.push(format!(
                            "{}/{}: counter '{key}' drifted: baseline {want}, got {got} \
                             — a determinism break, not perf noise",
                            r.tier, r.policy
                        ));
                    }
                }
            }
        }
        if !usable {
            continue; // placeholder-shaped entry inside a measured file
        }
        let rates: [(&str, f64); 2] = [
            ("intervals_per_sec", r.intervals_per_sec),
            ("container_intervals_per_sec", r.container_intervals_per_sec),
        ];
        for (key, got) in rates {
            if let Some(want) = num(base, key) {
                if got < want * (1.0 - RATE_SLOWDOWN_TOLERANCE) {
                    failures.push(format!(
                        "{}/{}: rate '{key}' regressed beyond {:.0}%: baseline \
                         {want:.1}, got {got:.1}",
                        r.tier,
                        r.policy,
                        RATE_SLOWDOWN_TOLERANCE * 100.0
                    ));
                }
            }
        }
        compared += 1;
    }

    if compared == 0 && failures.is_empty() {
        return PerfGate::Skipped(
            "no baseline entry shares this run's coordinates (tier/policy/intervals/\
             seed/scenario) — re-record the baseline"
                .into(),
        );
    }
    if failures.is_empty() {
        PerfGate::Pass(compared)
    } else {
        PerfGate::Fail(failures)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchlib::throughput::write_json;
    use std::path::PathBuf;

    fn sample(tier: &str, ips: f64) -> Throughput {
        Throughput {
            tier: tier.to_string(),
            policy: "mc".to_string(),
            workers: 10,
            intervals: 12,
            seed: 7,
            chaos: true,
            shards: 1,
            admitted: 40,
            completed: 30,
            failed: 2,
            container_intervals: 200,
            wall_ms: 12.0 / ips * 1e3,
            intervals_per_sec: ips,
            container_intervals_per_sec: ips * 200.0 / 12.0,
            phases: crate::util::phase_timer::PhaseBreakdown::default(),
        }
    }

    fn tmpfile(tag: &str) -> PathBuf {
        std::env::temp_dir()
            .join(format!("splitplace-perfgate-{tag}-{}.json", std::process::id()))
    }

    #[test]
    fn placeholder_baseline_skips_with_warning() {
        let path = tmpfile("placeholder");
        std::fs::write(
            &path,
            r#"{"bench":"engine_throughput","measured":false,"tiers":[]}"#,
        )
        .unwrap();
        match gate_against_baseline(&path, &[sample("small", 50.0)]) {
            PerfGate::Skipped(msg) => assert!(msg.contains("placeholder"), "{msg}"),
            other => panic!("expected skip, got {other:?}"),
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn missing_baseline_skips() {
        let gate =
            gate_against_baseline(Path::new("/nonexistent/bench.json"), &[sample("small", 50.0)]);
        assert!(matches!(gate, PerfGate::Skipped(_)), "{gate:?}");
    }

    #[test]
    fn identical_run_passes_and_speedups_pass() {
        let path = tmpfile("pass");
        write_json(&path, &[sample("small", 50.0)]).unwrap();
        assert_eq!(
            gate_against_baseline(&path, &[sample("small", 50.0)]),
            PerfGate::Pass(1)
        );
        // 2× faster: regression-only band lets it through
        assert_eq!(
            gate_against_baseline(&path, &[sample("small", 100.0)]),
            PerfGate::Pass(1)
        );
        // mild slowdown inside the band passes too
        assert_eq!(
            gate_against_baseline(&path, &[sample("small", 50.0 * 0.75)]),
            PerfGate::Pass(1)
        );
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn rate_collapse_fails() {
        let path = tmpfile("collapse");
        write_json(&path, &[sample("small", 50.0)]).unwrap();
        match gate_against_baseline(&path, &[sample("small", 50.0 * 0.5)]) {
            PerfGate::Fail(msgs) => {
                assert!(msgs.iter().any(|m| m.contains("intervals_per_sec")), "{msgs:?}")
            }
            other => panic!("expected fail, got {other:?}"),
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn counter_drift_fails_exactly() {
        let path = tmpfile("counter");
        write_json(&path, &[sample("small", 50.0)]).unwrap();
        let mut fresh = sample("small", 50.0);
        fresh.completed += 1;
        match gate_against_baseline(&path, &[fresh]) {
            PerfGate::Fail(msgs) => {
                assert!(msgs.iter().any(|m| m.contains("'completed'")), "{msgs:?}");
                assert!(msgs.iter().any(|m| m.contains("determinism")), "{msgs:?}");
            }
            other => panic!("expected fail, got {other:?}"),
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn different_coordinates_skip_not_fail() {
        let path = tmpfile("coords");
        write_json(&path, &[sample("small", 50.0)]).unwrap();
        let mut fresh = sample("small", 50.0);
        fresh.seed = 99; // different run coordinates — incomparable
        assert!(matches!(
            gate_against_baseline(&path, &[fresh]),
            PerfGate::Skipped(_)
        ));
        let _ = std::fs::remove_file(&path);
    }

    /// The phase breakdown is informational: a fresh run whose phase
    /// split looks nothing like the baseline's still passes, and a
    /// baseline stripped of the phase fields entirely gates the same run
    /// identically — the gate never reads those keys.
    #[test]
    fn phase_breakdown_is_never_gated() {
        let path = tmpfile("phases");
        write_json(&path, &[sample("small", 50.0)]).unwrap();
        let mut fresh = sample("small", 50.0);
        fresh.phases = crate::util::phase_timer::PhaseBreakdown {
            cpu_ms: 9_999.0,
            network_ms: 9_999.0,
            decision_ms: 9_999.0,
            oracle_ms: 9_999.0,
            traffic_ms: 9_999.0,
        };
        assert_eq!(gate_against_baseline(&path, &[fresh.clone()]), PerfGate::Pass(1));
        // pre-phase baseline (fields absent): same verdict. Stripping the
        // phase lines orphans a trailing comma (traffic_ms was the last
        // entry), so scrub commas that now sit directly before a brace.
        let text = std::fs::read_to_string(&path).unwrap();
        let kept: String = text
            .lines()
            .filter(|l| {
                !["cpu_ms", "network_ms", "decision_ms", "oracle_ms", "traffic_ms"]
                    .iter()
                    .any(|k| l.contains(k))
            })
            .collect::<Vec<_>>()
            .join("\n");
        let bytes = kept.as_bytes();
        let mut stripped = String::with_capacity(kept.len());
        for (i, &c) in bytes.iter().enumerate() {
            let next = bytes[i + 1..].iter().copied().find(|x| !x.is_ascii_whitespace());
            if c == b',' && matches!(next, Some(b'}') | Some(b']')) {
                continue;
            }
            stripped.push(c as char);
        }
        assert!(!stripped.contains("cpu_ms"));
        std::fs::write(&path, stripped).unwrap();
        assert_eq!(gate_against_baseline(&path, &[fresh]), PerfGate::Pass(1));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn shard_count_is_a_coordinate() {
        let path = tmpfile("shards");
        write_json(&path, &[sample("small", 50.0)]).unwrap();
        // a sharded run never compares against the serial baseline (its
        // rates legitimately differ), even when every counter matches
        let mut fresh = sample("small", 120.0);
        fresh.shards = 4;
        assert!(matches!(
            gate_against_baseline(&path, &[fresh]),
            PerfGate::Skipped(_)
        ));
        // a pre-shards baseline entry (field absent) gates the serial run
        let text = std::fs::read_to_string(&path).unwrap().replace("\"shards\": 1,", "");
        std::fs::write(&path, text).unwrap();
        assert_eq!(
            gate_against_baseline(&path, &[sample("small", 50.0)]),
            PerfGate::Pass(1)
        );
        let _ = std::fs::remove_file(&path);
    }
}
