//! Micro-benchmark harness (offline substitute for criterion): warmup,
//! timed iterations, mean/p50/p99 reporting. Used by all `benches/*.rs`
//! (registered with `harness = false`).

pub mod perfgate;
pub mod scenarios;
pub mod throughput;

use std::time::Instant;

use crate::util::stats;
use crate::util::table::Table;

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub std_ns: f64,
}

impl BenchResult {
    fn fmt_ns(ns: f64) -> String {
        if ns < 1e3 {
            format!("{ns:.0} ns")
        } else if ns < 1e6 {
            format!("{:.2} µs", ns / 1e3)
        } else if ns < 1e9 {
            format!("{:.2} ms", ns / 1e6)
        } else {
            format!("{:.3} s", ns / 1e9)
        }
    }

    pub fn row(&self) -> Vec<String> {
        vec![
            self.name.clone(),
            self.iters.to_string(),
            Self::fmt_ns(self.mean_ns),
            Self::fmt_ns(self.p50_ns),
            Self::fmt_ns(self.p99_ns),
            Self::fmt_ns(self.std_ns),
        ]
    }
}

/// Time `f` for at least `min_iters` iterations after `warmup` runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, min_iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(min_iters);
    for _ in 0..min_iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        mean_ns: stats::mean(&samples),
        p50_ns: stats::percentile(&samples, 50.0),
        p99_ns: stats::percentile(&samples, 99.0),
        std_ns: stats::std(&samples),
    }
}

/// Render a group of results as a table.
pub fn report(title: &str, results: &[BenchResult]) {
    let mut t = Table::new(title, &["bench", "iters", "mean", "p50", "p99", "std"]);
    for r in results {
        t.row(r.row());
    }
    t.print();
}

/// Prevent the optimizer from discarding a value (std::hint::black_box
/// wrapper kept here so benches don't import nightly-looking paths).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("spin", 2, 20, || {
            let mut acc = 0u64;
            for i in 0..1000 {
                acc = acc.wrapping_add(black_box(i));
            }
            black_box(acc);
        });
        assert_eq!(r.iters, 20);
        assert!(r.mean_ns > 0.0);
        assert!(r.p99_ns >= r.p50_ns);
    }

    #[test]
    fn formatting_scales() {
        assert!(BenchResult::fmt_ns(500.0).contains("ns"));
        assert!(BenchResult::fmt_ns(5.0e4).contains("µs"));
        assert!(BenchResult::fmt_ns(5.0e7).contains("ms"));
        assert!(BenchResult::fmt_ns(5.0e9).contains(" s"));
    }
}
