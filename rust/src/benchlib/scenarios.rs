//! Shared scenario plumbing for the paper-reproduction benches: every
//! `benches/fig*.rs` builds configs through here so the knobs (interval
//! count, policy set) stay consistent and env-tunable.
//!
//! `SPLITPLACE_BENCH_INTERVALS` overrides the per-run interval count
//! (default 25 — enough for the orderings to emerge; the paper's Γ=100 is
//! what `examples/full_experiment.rs` runs).

use crate::chaos::{self, ChaosOptions, ChaosOutcome, FaultPlan, Profile};
use crate::config::{ExperimentConfig, PolicyKind};
use crate::coordinator::runner::{run_experiment, try_runtime, ExperimentOutput};
use crate::harness::Scenario;
use crate::runtime::Runtime;

pub fn bench_intervals() -> usize {
    std::env::var("SPLITPLACE_BENCH_INTERVALS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(25)
}

/// Every policy stack: the Table-4 rows plus the related-work splitters
/// (LatMem, OnlineSplit), weakest-first like [`PolicyKind::all`].
pub fn all_policies() -> [PolicyKind; 9] {
    PolicyKind::all()
}

/// The policies the chaos/matrix bench tables chart: exactly the CI
/// smoke set ([`crate::harness::scenario::SMOKE_POLICIES`] — one source
/// of truth, so the bench tables always chart what CI gates). Everything
/// in it runs without built artifacts.
pub fn chaos_table_policies() -> [PolicyKind; 5] {
    crate::harness::scenario::SMOKE_POLICIES
}

/// The ablation subset used by the sensitivity appendices.
pub fn ablation_policies() -> [PolicyKind; 5] {
    [
        PolicyKind::SemanticGobi,
        PolicyKind::LayerGobi,
        PolicyKind::RandomDaso,
        PolicyKind::MabGobi,
        PolicyKind::MabDaso,
    ]
}

/// Base config for bench scenarios (paper defaults + bench interval count).
pub fn base_config() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.sim.intervals = bench_intervals();
    cfg
}

/// Runtime handle or a loud skip (benches print and exit 0 when artifacts
/// are missing, so `cargo bench` stays runnable pre-`make artifacts`).
pub fn runtime_or_skip(bench_name: &str) -> Option<Runtime> {
    match try_runtime() {
        Some(rt) => Some(rt),
        None => {
            println!("[{bench_name}] SKIPPED — artifacts not built (run `make artifacts`)");
            None
        }
    }
}

/// Run one scenario, tolerating per-policy failures (reported, not fatal).
/// The failure line names the policy and scenario shape so chaos-profile
/// and sweep benches stay attributable.
pub fn run(cfg: ExperimentConfig, rt: Option<&Runtime>) -> Option<ExperimentOutput> {
    let policy = cfg.policy.name();
    let shape = format!(
        "{} workers, {} intervals, λ={}",
        cfg.cluster.total_workers(),
        cfg.sim.intervals,
        cfg.workload.lambda
    );
    match run_experiment(cfg, rt) {
        Ok(out) => Some(out),
        Err(e) => {
            eprintln!("[bench] {policy} ({shape}) run failed: {e:#}");
            None
        }
    }
}

/// Build a chaos scenario for a bench: base config + the deterministic
/// fault plan a given profile generates for it.
pub fn chaos_scenario(profile: Profile, seed: u64) -> (ExperimentConfig, FaultPlan) {
    let cfg = base_config();
    let plan = FaultPlan::generate(seed, cfg.sim.intervals, profile, cfg.cluster.total_workers());
    (cfg, plan)
}

/// Build one matrix cell as a bench scenario (harness cluster/λ shape,
/// bench interval count): benches and `matrix` cells draw from the same
/// scenario universe, so a regime a bench charts is a regime the golden
/// gate watches.
pub fn matrix_scenario(
    scenario: Scenario,
    policy: PolicyKind,
    seed: u64,
) -> (ExperimentConfig, FaultPlan) {
    scenario.build(policy, seed, bench_intervals())
}

/// Run a chaos scenario, tolerating failures like [`run`] does. Oracle
/// violations are reported loudly (they are bugs, not bench noise).
pub fn run_chaos(
    cfg: ExperimentConfig,
    plan: &FaultPlan,
    rt: Option<&Runtime>,
) -> Option<ChaosOutcome> {
    let policy = cfg.policy.name();
    match chaos::run_chaos(&cfg, plan, &ChaosOptions::default(), rt) {
        Ok(out) => {
            if !out.violations.is_empty() {
                eprintln!(
                    "[bench] {policy} chaos run VIOLATED {:?} — first: {}",
                    out.violated_oracles(),
                    out.violations[0]
                );
            }
            Some(out)
        }
        Err(e) => {
            eprintln!("[bench] {policy} chaos run failed: {e:#}");
            None
        }
    }
}
