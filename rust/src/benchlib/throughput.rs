//! Engine-throughput measurement across fleet tiers.
//!
//! Seeds the perf trajectory for the O(active) engine core: one
//! chaos-light (or clean) run per tier (small through hyperscale —
//! 10/200/1000/5000/25 000 workers), measuring scheduling **intervals/sec** and
//! **active-container-intervals/sec** (Σ per-interval active-set size over
//! wall-clock — the unit the hot path actually scales with). Results
//! serialize to `BENCH_engine.json`; `scripts/ci.sh` records a smoke run
//! on every CI pass (perf numbers recorded, not yet regression-gated).
//!
//! Shared by `benches/engine_throughput.rs` and the `splitplace bench`
//! CLI so both emit the same artifact.

use std::time::Instant;

use crate::chaos::{self, ChaosOptions};
use crate::config::PolicyKind;
use crate::coordinator::Broker;
use crate::harness::Scenario;
use crate::mab::Mode;
use crate::sim::EngineCmd;
use crate::util::json::Value;
use crate::util::phase_timer::PhaseBreakdown;

/// One measurable fleet tier, named by its pair of matrix tier scenarios —
/// the bench derives its whole regime (cluster preset, tier λ, plan) from
/// `Scenario::build`, so BENCH_engine.json always measures exactly what
/// the golden gate watches, with no duplicated knobs.
#[derive(Clone, Copy, Debug)]
pub struct TierSpec {
    pub name: &'static str,
    pub clean: Scenario,
    pub chaos_light: Scenario,
}

impl TierSpec {
    pub fn scenario(&self, chaos: bool) -> Scenario {
        if chaos {
            self.chaos_light
        } else {
            self.clean
        }
    }
}

/// The five fleet tiers, smallest first.
pub fn tiers() -> Vec<TierSpec> {
    vec![
        TierSpec {
            name: "small",
            clean: Scenario::Clean,
            chaos_light: Scenario::ChaosLight,
        },
        TierSpec {
            name: "medium",
            clean: Scenario::MediumClean,
            chaos_light: Scenario::MediumChaosLight,
        },
        TierSpec {
            name: "large",
            clean: Scenario::LargeClean,
            chaos_light: Scenario::LargeChaosLight,
        },
        TierSpec {
            name: "huge",
            clean: Scenario::HugeClean,
            chaos_light: Scenario::HugeChaosLight,
        },
        TierSpec {
            name: "hyperscale",
            clean: Scenario::HyperscaleClean,
            chaos_light: Scenario::HyperscaleChaosLight,
        },
    ]
}

pub fn tier_by_name(name: &str) -> Option<TierSpec> {
    tiers().into_iter().find(|t| t.name == name.to_ascii_lowercase())
}

/// One tier's throughput measurement.
#[derive(Clone, Debug)]
pub struct Throughput {
    pub tier: String,
    /// Policy slug driving the broker during the measurement.
    pub policy: String,
    pub workers: usize,
    pub intervals: usize,
    pub seed: u64,
    pub chaos: bool,
    /// Intra-interval CPU-phase shard count (1 = serial). Results are
    /// byte-identical at any value; only wall-clock moves.
    pub shards: usize,
    pub admitted: u64,
    pub completed: usize,
    pub failed: usize,
    /// Σ over intervals of the post-interval active-container count — the
    /// work units the O(active) hot path processed.
    pub container_intervals: u64,
    pub wall_ms: f64,
    pub intervals_per_sec: f64,
    pub container_intervals_per_sec: f64,
    /// Where the wall-clock went, per phase (cpu/network/decision/oracle/
    /// traffic ms). Informational only: the perf gate never bands these —
    /// see `perfgate` — they exist so a recorded baseline says *which*
    /// phase moved when a rate does. Oracle is 0.0 here by construction
    /// (the bench runs no oracle sweeps).
    pub phases: PhaseBreakdown,
}

/// Run one tier's matrix scenario (chaos-light is the representative
/// fleet-scale regime) and measure wall-clock throughput. The policy axis
/// is explicit: the default MC isolates the engine+broker hot path, while
/// any other stack (latmem, onlinesplit, mab-daso, …) measures its
/// decision-plane overhead on the same regime — all run without artifacts
/// (surrogate stacks degrade to best-fit placement). Oracle sweeps are
/// deliberately absent: this times the simulation core, not the audit
/// machinery.
pub fn measure(
    tier: &TierSpec,
    intervals: usize,
    seed: u64,
    chaos: bool,
    policy: PolicyKind,
    shards: usize,
) -> anyhow::Result<Throughput> {
    let (mut cfg, plan) = tier.scenario(chaos).build(policy, seed, intervals);
    cfg.sim.shards = shards.max(1);
    // always profile here: the timer's clock reads never feed back into
    // simulation state, so counters stay identical and the breakdown is
    // free signal on a box that is already paying for the measurement
    cfg.sim.profile_phases = true;
    let n = cfg.cluster.total_workers();
    let shards = cfg.sim.shards;
    let opts = ChaosOptions::default();
    let base_lambda = cfg.workload.lambda;
    let timeout_s = opts.task_timeout_intervals as f64 * cfg.sim.interval_seconds;

    let mut broker = Broker::new_with_fallback(cfg, None, Mode::Test)?;
    let mut container_intervals = 0u64;
    let t0 = Instant::now();
    for t in 0..intervals {
        for e in plan.events_at(t) {
            chaos::apply_event(&mut broker, &e.event, &opts, base_lambda);
        }
        broker.engine.apply(EngineCmd::FailTasksOlderThan { age_s: timeout_s });
        broker.step();
        container_intervals += broker.engine.active_container_count() as u64;
    }
    let wall_s = t0.elapsed().as_secs_f64().max(1e-9);
    let phases = broker.engine.phases().snapshot();
    Ok(Throughput {
        tier: tier.name.to_string(),
        policy: crate::harness::policy_slug(policy).to_string(),
        workers: n,
        intervals,
        seed,
        chaos,
        shards,
        admitted: broker.admitted,
        completed: broker.engine.completed_task_count(),
        failed: broker.engine.failed_task_count(),
        container_intervals,
        wall_ms: wall_s * 1e3,
        intervals_per_sec: intervals as f64 / wall_s,
        container_intervals_per_sec: container_intervals as f64 / wall_s,
        phases,
    })
}

/// Canonical `BENCH_engine.json` payload.
pub fn to_json(results: &[Throughput]) -> Value {
    Value::obj(vec![
        ("bench", Value::Str("engine_throughput".into())),
        ("measured", Value::Bool(true)),
        (
            "tiers",
            Value::Arr(
                results
                    .iter()
                    .map(|r| {
                        Value::obj(vec![
                            ("tier", Value::Str(r.tier.clone())),
                            ("policy", Value::Str(r.policy.clone())),
                            ("workers", Value::Num(r.workers as f64)),
                            ("intervals", Value::Num(r.intervals as f64)),
                            ("seed", Value::Str(r.seed.to_string())),
                            (
                                "scenario",
                                Value::Str(
                                    if r.chaos { "chaos-light" } else { "clean" }.into(),
                                ),
                            ),
                            ("shards", Value::Num(r.shards as f64)),
                            ("admitted", Value::Num(r.admitted as f64)),
                            ("completed", Value::Num(r.completed as f64)),
                            ("failed", Value::Num(r.failed as f64)),
                            (
                                "container_intervals",
                                Value::Num(r.container_intervals as f64),
                            ),
                            ("wall_ms", Value::Num(r.wall_ms)),
                            ("intervals_per_sec", Value::Num(r.intervals_per_sec)),
                            (
                                "container_intervals_per_sec",
                                Value::Num(r.container_intervals_per_sec),
                            ),
                            // per-phase breakdown: informational, never
                            // gated (absent in pre-phase baselines — the
                            // gate treats absent as "nothing to compare")
                            ("cpu_ms", Value::Num(r.phases.cpu_ms)),
                            ("network_ms", Value::Num(r.phases.network_ms)),
                            ("decision_ms", Value::Num(r.phases.decision_ms)),
                            ("oracle_ms", Value::Num(r.phases.oracle_ms)),
                            ("traffic_ms", Value::Num(r.phases.traffic_ms)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Write `BENCH_engine.json` (pretty-printed; wall-clock fields make it a
/// perf record, not a golden — never gate equality on it).
pub fn write_json(path: &std::path::Path, results: &[Throughput]) -> std::io::Result<()> {
    let mut text = to_json(results).to_pretty();
    text.push('\n');
    std::fs::write(path, text)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_tier_measures_and_serializes() {
        let tier = tier_by_name("small").unwrap();
        let r = measure(&tier, 6, 1, true, PolicyKind::ModelCompression, 2).unwrap();
        assert_eq!(r.workers, 10);
        assert_eq!(r.intervals, 6);
        assert_eq!(r.policy, "mc");
        assert_eq!(r.shards, 2);
        assert!(r.admitted > 0, "load must arrive");
        assert!(r.intervals_per_sec > 0.0);
        assert!(r.wall_ms > 0.0);
        assert_eq!(r.phases.oracle_ms, 0.0, "bench runs no oracle sweeps");
        let phase_sum = r.phases.cpu_ms
            + r.phases.network_ms
            + r.phases.decision_ms
            + r.phases.traffic_ms;
        assert!(phase_sum > 0.0, "profiling is always on in measure()");
        assert!(phase_sum <= r.wall_ms, "phases are a partition of the wall");
        let j = to_json(&[r]).to_string();
        assert!(j.contains("\"bench\":\"engine_throughput\""), "{j}");
        assert!(j.contains("\"tier\":\"small\""), "{j}");
        assert!(j.contains("\"policy\":\"mc\""), "{j}");
        assert!(j.contains("\"shards\":2"), "{j}");
        assert!(j.contains("intervals_per_sec"), "{j}");
        for key in ["cpu_ms", "network_ms", "decision_ms", "oracle_ms", "traffic_ms"] {
            assert!(j.contains(&format!("\"{key}\"")), "{key} missing: {j}");
        }
    }

    /// The policy axis: any stack drives the measurement, including the
    /// related-work splitters — same regime, different decision plane.
    #[test]
    fn policy_axis_measures_the_new_stacks() {
        let tier = tier_by_name("small").unwrap();
        for policy in [PolicyKind::LatMem, PolicyKind::OnlineSplit] {
            let r = measure(&tier, 6, 1, true, policy, 1).unwrap();
            assert!(r.admitted > 0, "{policy:?} must carry load");
            let slug = crate::harness::policy_slug(policy);
            assert_eq!(r.policy, slug);
            assert!(to_json(&[r]).to_string().contains(&format!("\"policy\":\"{slug}\"")));
        }
    }

    #[test]
    fn tier_lookup_and_order() {
        let ts = tiers();
        assert_eq!(ts.len(), 5);
        let workers = |t: &TierSpec| {
            let (cfg, _) = t.scenario(true).build(PolicyKind::ModelCompression, 1, 4);
            cfg.cluster.total_workers()
        };
        assert!(ts.windows(2).all(|w| workers(&w[0]) < workers(&w[1])));
        assert!(tier_by_name("LARGE").is_some());
        assert!(tier_by_name("galactic").is_none());
        assert_eq!(workers(&tier_by_name("large").unwrap()), 1000);
        assert_eq!(workers(&tier_by_name("huge").unwrap()), 5_000);
        assert_eq!(workers(&tier_by_name("hyperscale").unwrap()), 25_000);
        // clean and chaos-light share the tier's fleet; only the plan differs
        for t in &ts {
            let (cfg_a, plan_a) = t.scenario(false).build(PolicyKind::ModelCompression, 1, 4);
            let (cfg_b, plan_b) = t.scenario(true).build(PolicyKind::ModelCompression, 1, 4);
            assert_eq!(cfg_a.cluster.total_workers(), cfg_b.cluster.total_workers());
            assert_eq!(cfg_a.workload.lambda, cfg_b.workload.lambda);
            assert!(plan_a.events.is_empty());
            let _ = plan_b;
        }
    }

    /// The acceptance bar for the refactor: a large-tier chaos-light run
    /// (≈1000 workers) over a meaningful horizon completes in seconds —
    /// O(active) sub-stepping, not O(everything ever admitted). Runs only
    /// in optimized builds: under `cargo test`'s debug profile the
    /// float-heavy integrator is easily 10×+ slower, the bound would be
    /// flaky, and without the bound the run would cost minutes for no
    /// signal (the smoke matrix's large cells already cover panics).
    /// `splitplace bench` runs the full ≥50-interval measurement.
    #[test]
    fn large_tier_run_is_fast() {
        if cfg!(debug_assertions) {
            return;
        }
        let tier = tier_by_name("large").unwrap();
        let t0 = std::time::Instant::now();
        let r = measure(&tier, 10, 1, true, PolicyKind::ModelCompression, 1).unwrap();
        assert_eq!(r.workers, 1000);
        assert!(r.admitted > 100, "large tier must carry real load");
        assert!(
            t0.elapsed().as_secs_f64() < 30.0,
            "large-tier run took {:.1}s — the active-set core has regressed",
            t0.elapsed().as_secs_f64()
        );
    }
}
