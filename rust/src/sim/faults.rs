//! The typed engine command bus.
//!
//! Every mutation of the engine's availability/degradation surface —
//! crashes, recoveries, stragglers, RAM squeezes, channel overrides, clock
//! skew, churn configuration, payload corruption, starvation sweeps — is a
//! value of [`EngineCmd`] applied through the single
//! [`Engine::apply`] entry point. `apply` returns the command's
//! [`Effect`] and appends a [`CmdRecord`] to a per-interval ledger, so a
//! fault harness never has to re-derive what it did to the engine: the
//! chaos oracles audit the ledger (`splitplace::chaos::oracle`), and
//! engine-internal mutations (churn) go through the same bus tagged with
//! their [`CmdOrigin`].

use crate::cluster::mobility::ChannelState;

use super::container::ContainerState;
use super::state::Engine;

/// One typed mutation of the engine's fault/availability surface.
#[derive(Clone, Debug, PartialEq)]
pub enum EngineCmd {
    /// Graceful availability toggle. Going down checkpoints (CRIU-style:
    /// progress kept) and requeues every resident container.
    SetOnline { worker: usize, up: bool },
    /// Hard crash: offline immediately, no checkpoint window — resident
    /// containers requeue with their progress LOST.
    Crash { worker: usize },
    /// Crashed/offline worker rejoins the fleet.
    Recover { worker: usize },
    /// Autoscaler decision: park a worker (graceful — resident containers
    /// checkpoint and requeue, like `SetOnline { up: false }`). Issued via
    /// [`Engine::apply_scaling`] so the ledger origin reads `Autoscale`.
    WorkerLeave { worker: usize },
    /// Autoscaler decision: unpark a previously parked worker (`Recover`
    /// semantics under the `Autoscale` origin).
    WorkerJoin { worker: usize },
    /// Straggler injection: scale the worker's MIPS by `factor`
    /// (clamped to [0.05, 1]); 1.0 restores full speed.
    SetMipsFactor { worker: usize, factor: f64 },
    /// Memory squeeze: scale the worker's effective RAM by `factor`
    /// (clamped to [0.1, 1]); 1.0 restores it. Physical capacity unchanged.
    SetRamFactor { worker: usize, factor: f64 },
    /// Force a worker's channel state (network blackout); `None` returns
    /// control to the mobility model at the next interval.
    SetChannelOverride { worker: usize, channel: Option<ChannelState> },
    /// Drift a worker's clock by `skew_s` seconds (clamped to [0, 600] —
    /// NTP-grade drift, not a wall-clock rewrite); every payload movement
    /// touching the worker pays the skew. 0.0 ends the episode.
    SetClockSkew { worker: usize, skew_s: f64 },
    /// Configure worker churn: per-interval probability that a mobile
    /// worker toggles offline/online (clamped to [0, 1]).
    SetChurn { rate: f64 },
    /// Corrupt every in-flight input transfer currently staging toward
    /// `worker`: a corrupted payload cannot produce valid output, so the
    /// owning tasks fail-and-penalize immediately (they surface in the
    /// next report's `failed`, never in `completed`).
    CorruptPayload { worker: usize },
    /// Starvation sweep: fail every active task older than `age_s`
    /// simulation seconds.
    FailTasksOlderThan { age_s: f64 },
    /// Mobility handoff: re-home `worker` from `from_rack` to `to_rack`
    /// (a vehicle crossing cell boundaries re-associates with a new edge
    /// site). The worker stays online and keeps its containers, but every
    /// in-flight transfer touching it stretches by one re-association
    /// round-trip under its current channel state, and the move lands in
    /// the handoff audit log the `handoff-preserves-progress` oracle
    /// sweeps. A stale handoff — the worker is not currently in
    /// `from_rack` — is a Noop: reordered plans must not teleport workers.
    Handoff { worker: usize, from_rack: usize, to_rack: usize },
    /// Chaos-testing bug-injection hook: take a worker offline WITHOUT
    /// evicting its containers. Deliberately violates the
    /// `crashed-workers-idle` invariant so the chaos oracles can be
    /// validated end-to-end. Never issue outside fault-injection tests.
    ForceOfflineNoEvict { worker: usize },
    /// Chaos-testing bug-injection hook: record the corruption in the
    /// ledger but "forget" the checksum check — affected transfers
    /// complete as if nothing happened. Deliberately violates the
    /// `payload-corruption-handled` invariant.
    CorruptPayloadSwallowed { worker: usize },
}

impl EngineCmd {
    /// Target worker, if the command is worker-scoped.
    pub fn worker(&self) -> Option<usize> {
        match *self {
            EngineCmd::SetOnline { worker, .. }
            | EngineCmd::Crash { worker }
            | EngineCmd::Recover { worker }
            | EngineCmd::WorkerLeave { worker }
            | EngineCmd::WorkerJoin { worker }
            | EngineCmd::SetMipsFactor { worker, .. }
            | EngineCmd::SetRamFactor { worker, .. }
            | EngineCmd::SetChannelOverride { worker, .. }
            | EngineCmd::SetClockSkew { worker, .. }
            | EngineCmd::CorruptPayload { worker }
            | EngineCmd::ForceOfflineNoEvict { worker }
            | EngineCmd::CorruptPayloadSwallowed { worker }
            | EngineCmd::Handoff { worker, .. } => Some(worker),
            EngineCmd::SetChurn { .. } | EngineCmd::FailTasksOlderThan { .. } => None,
        }
    }
}

/// What applying a command did.
#[derive(Clone, Debug, PartialEq)]
pub enum Effect {
    /// State changed as requested.
    Applied,
    /// Valid command that changed nothing (already in that state, or an
    /// out-of-range target — plans generated for a bigger fleet).
    Noop,
    /// Containers were checkpointed/dropped off a worker.
    Evicted { containers: usize },
    /// Task-scoped command: the ids it touched (corrupted transfers,
    /// starvation sweeps). May be empty — nothing was in flight.
    Affected { tasks: Vec<u64> },
}

/// Who issued a command.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CmdOrigin {
    /// The harness/broker, through [`Engine::apply`].
    External,
    /// The engine's own churn process (still bus-routed so the ledger
    /// stays a complete mutation history).
    Churn,
    /// The traffic plane's autoscaler, through [`Engine::apply_scaling`] —
    /// capacity changes that are *decisions*, distinguishable in the
    /// ledger from chaos-origin offline events.
    Autoscale,
    /// The engine's battery plane: a worker whose battery hit empty
    /// crashes under this origin. Nothing may resurrect a battery-dead
    /// worker automatically — the autoscaler rejoins only
    /// `Autoscale`-owned offline workers, so this origin keeps dead
    /// batteries dead.
    Battery,
}

/// One ledger entry: the command, when it landed, and what it did.
#[derive(Clone, Debug)]
pub struct CmdRecord {
    /// Interval counter at application time (commands land at the start
    /// of the interval that carries this index).
    pub interval: usize,
    pub origin: CmdOrigin,
    pub cmd: EngineCmd,
    pub effect: Effect,
}

/// The engine's availability/degradation surface as a value: what a fresh
/// engine would hold after replaying a command ledger. The
/// `ledger-replay-consistent` oracle compares [`FaultSurface::replay`] of
/// the engine's own ledger against [`Engine::fault_surface`] — since the
/// bus is the only mutation path, any divergence means a command mutated
/// state it did not record (or recorded state it did not mutate). This
/// also pins the refactored incremental indexes to the ledger: a desynced
/// index surfaces as a surface mismatch the moment it feeds back into
/// availability handling.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultSurface {
    pub online: Vec<bool>,
    pub mips_factor: Vec<f64>,
    pub ram_factor: Vec<f64>,
    pub clock_skew_s: Vec<f64>,
    pub churn_rate: f64,
}

impl FaultSurface {
    /// The surface of a freshly built `n_workers` engine.
    pub fn baseline(n_workers: usize) -> FaultSurface {
        FaultSurface {
            online: vec![true; n_workers],
            mips_factor: vec![1.0; n_workers],
            ram_factor: vec![1.0; n_workers],
            clock_skew_s: vec![0.0; n_workers],
            churn_rate: 0.0,
        }
    }

    /// Absorb one command, mirroring [`Engine::apply`]'s clamps exactly
    /// (identical float operations, so comparisons are exact, not
    /// approximate). Commands with no surface effect are ignored;
    /// out-of-range targets are no-ops like the engine's.
    pub fn absorb(&mut self, cmd: &EngineCmd) {
        let n = self.online.len();
        if let Some(w) = cmd.worker() {
            if w >= n {
                return;
            }
        }
        match *cmd {
            EngineCmd::SetOnline { worker, up } => self.online[worker] = up,
            EngineCmd::Crash { worker } | EngineCmd::ForceOfflineNoEvict { worker } => {
                self.online[worker] = false;
            }
            EngineCmd::Recover { worker } | EngineCmd::WorkerJoin { worker } => {
                self.online[worker] = true;
            }
            EngineCmd::WorkerLeave { worker } => self.online[worker] = false,
            EngineCmd::SetMipsFactor { worker, factor } => {
                self.mips_factor[worker] = factor.clamp(0.05, 1.0);
            }
            EngineCmd::SetRamFactor { worker, factor } => {
                self.ram_factor[worker] = factor.clamp(0.1, 1.0);
            }
            EngineCmd::SetClockSkew { worker, skew_s } => {
                self.clock_skew_s[worker] = skew_s.clamp(0.0, 600.0);
            }
            EngineCmd::SetChurn { rate } => self.churn_rate = rate.clamp(0.0, 1.0),
            EngineCmd::SetChannelOverride { .. }
            | EngineCmd::CorruptPayload { .. }
            | EngineCmd::CorruptPayloadSwallowed { .. }
            | EngineCmd::FailTasksOlderThan { .. }
            | EngineCmd::Handoff { .. } => {}
        }
    }

    /// Replay a full ledger onto a fresh surface. Churn toggles are
    /// ledger-recorded like external commands, so the replay tracks them
    /// too — the comparison holds even on churny runs.
    pub fn replay(n_workers: usize, ledger: &[CmdRecord]) -> FaultSurface {
        let mut surface = FaultSurface::baseline(n_workers);
        for rec in ledger {
            surface.absorb(&rec.cmd);
        }
        surface
    }
}

impl Engine {
    /// Apply one typed command and record it in the ledger. This is the
    /// only mutation path for the engine's fault/availability surface.
    pub fn apply(&mut self, cmd: EngineCmd) -> Effect {
        self.apply_with_origin(cmd, CmdOrigin::External)
    }

    /// Apply an autoscaler decision. Same bus, same ledger — the record's
    /// origin is [`CmdOrigin::Autoscale`], so audit sweeps can tell a
    /// capacity decision from a chaos-injected fault.
    pub fn apply_scaling(&mut self, cmd: EngineCmd) -> Effect {
        self.apply_with_origin(cmd, CmdOrigin::Autoscale)
    }

    /// Full command history, in application order.
    pub fn ledger(&self) -> &[CmdRecord] {
        &self.cmd_ledger
    }

    /// Snapshot of the current availability/degradation surface (the state
    /// the command bus owns). See [`FaultSurface`].
    pub fn fault_surface(&self) -> FaultSurface {
        FaultSurface {
            online: self.online.clone(),
            mips_factor: self.mips_factor.clone(),
            ram_factor: self.ram_factor.clone(),
            clock_skew_s: self.clock_skew_s.clone(),
            churn_rate: self.churn_rate,
        }
    }

    pub(super) fn apply_with_origin(&mut self, cmd: EngineCmd, origin: CmdOrigin) -> Effect {
        let effect = self.execute(&cmd);
        if effect != Effect::Noop {
            // keep the offline-ownership record in lockstep with `online`:
            // a command that takes a worker down stamps its origin; a
            // command that brings one up clears it. Noops (already in that
            // state, out-of-range) must not reassign ownership — a chaos
            // crash followed by a redundant autoscaler park stays
            // chaos-owned.
            match cmd {
                EngineCmd::SetOnline { worker, up } => {
                    self.offline_origin[worker] = if up { None } else { Some(origin) };
                }
                EngineCmd::Crash { worker }
                | EngineCmd::WorkerLeave { worker }
                | EngineCmd::ForceOfflineNoEvict { worker } => {
                    self.offline_origin[worker] = Some(origin);
                }
                EngineCmd::Recover { worker } | EngineCmd::WorkerJoin { worker } => {
                    self.offline_origin[worker] = None;
                }
                _ => {}
            }
        }
        self.cmd_ledger.push(CmdRecord {
            interval: self.interval,
            origin,
            cmd,
            effect: effect.clone(),
        });
        effect
    }

    fn execute(&mut self, cmd: &EngineCmd) -> Effect {
        let n = self.online.len();
        match *cmd {
            EngineCmd::SetOnline { worker, up } => {
                if worker >= n || self.online[worker] == up {
                    return Effect::Noop;
                }
                self.online[worker] = up;
                if up {
                    Effect::Applied
                } else {
                    Effect::Evicted { containers: self.evict_worker(worker, false) }
                }
            }
            EngineCmd::Crash { worker } => {
                if worker >= n || !self.online[worker] {
                    return Effect::Noop;
                }
                self.online[worker] = false;
                Effect::Evicted { containers: self.evict_worker(worker, true) }
            }
            EngineCmd::Recover { worker } | EngineCmd::WorkerJoin { worker } => {
                if worker >= n || self.online[worker] {
                    return Effect::Noop;
                }
                self.online[worker] = true;
                Effect::Applied
            }
            EngineCmd::WorkerLeave { worker } => {
                // graceful park: identical semantics to SetOnline{up:false}
                if worker >= n || !self.online[worker] {
                    return Effect::Noop;
                }
                self.online[worker] = false;
                Effect::Evicted { containers: self.evict_worker(worker, false) }
            }
            EngineCmd::SetMipsFactor { worker, factor } => {
                if worker >= n {
                    return Effect::Noop;
                }
                self.mips_factor[worker] = factor.clamp(0.05, 1.0);
                Effect::Applied
            }
            EngineCmd::SetRamFactor { worker, factor } => {
                if worker >= n {
                    return Effect::Noop;
                }
                self.ram_factor[worker] = factor.clamp(0.1, 1.0);
                Effect::Applied
            }
            EngineCmd::SetChannelOverride { worker, channel } => {
                if worker >= n {
                    return Effect::Noop;
                }
                self.channel_override[worker] = channel;
                if let Some(ch) = channel {
                    self.channels[worker] = ch;
                }
                Effect::Applied
            }
            EngineCmd::SetClockSkew { worker, skew_s } => {
                if worker >= n {
                    return Effect::Noop;
                }
                self.clock_skew_s[worker] = skew_s.clamp(0.0, 600.0);
                Effect::Applied
            }
            EngineCmd::SetChurn { rate } => {
                self.churn_rate = rate.clamp(0.0, 1.0);
                Effect::Applied
            }
            EngineCmd::CorruptPayload { worker } => {
                if worker >= n {
                    return Effect::Noop;
                }
                let tasks = self.in_flight_tasks(worker);
                for &id in &tasks {
                    self.fail_task(id);
                }
                Effect::Affected { tasks }
            }
            EngineCmd::FailTasksOlderThan { age_s } => {
                Effect::Affected { tasks: self.fail_tasks_older_than_collect(age_s) }
            }
            EngineCmd::ForceOfflineNoEvict { worker } => {
                if worker >= n || !self.online[worker] {
                    return Effect::Noop;
                }
                self.online[worker] = false;
                Effect::Applied
            }
            EngineCmd::CorruptPayloadSwallowed { worker } => {
                if worker >= n {
                    return Effect::Noop;
                }
                // record the blast radius but skip the fail path — the
                // missing-checksum bug the oracle must catch
                Effect::Affected { tasks: self.in_flight_tasks(worker) }
            }
            EngineCmd::Handoff { worker, from_rack, to_rack } => {
                let to = to_rack % crate::chaos::events::RACKS;
                if worker >= n || self.rack_of[worker] != from_rack || to == from_rack {
                    return Effect::Noop;
                }
                self.rack_of[worker] = to;
                // One re-association round-trip under the worker's current
                // channel state: every in-flight payload movement touching
                // the worker re-negotiates its window through the new site.
                let stretch = self.payload_transfer_s(None, worker, 0.0);
                let resident = self.resident_idx[worker].clone();
                let mut residents = Vec::with_capacity(resident.len());
                let mut tasks: Vec<u64> = Vec::new();
                for &cid in &resident {
                    let (state, home, task_id, mi_done) = {
                        let c = &self.containers[cid];
                        (c.state, c.worker, c.task_id, c.mi_done)
                    };
                    residents.push((cid, task_id, mi_done));
                    match state {
                        ContainerState::Transferring { until_s } => {
                            self.set_container(
                                cid,
                                ContainerState::Transferring { until_s: until_s + stretch },
                                home,
                            );
                            tasks.push(task_id);
                        }
                        // migrations toward the worker are filed here too
                        ContainerState::Migrating { until_s, to: dst } if dst == worker => {
                            self.set_container(
                                cid,
                                ContainerState::Migrating { until_s: until_s + stretch, to: dst },
                                home,
                            );
                            tasks.push(task_id);
                        }
                        _ => {}
                    }
                }
                tasks.sort_unstable();
                tasks.dedup();
                self.handoff_audits.push(super::state::HandoffAudit {
                    interval: self.interval,
                    worker,
                    from_rack,
                    to_rack: to,
                    residents,
                });
                Effect::Affected { tasks }
            }
        }
    }

    /// Tasks with an input payload currently staging toward `worker`
    /// (deterministic: container order, deduplicated, sorted by task id).
    /// Transferring containers live in the worker's residency index, so
    /// this is O(resident on `worker`).
    fn in_flight_tasks(&self, worker: usize) -> Vec<u64> {
        let mut tasks: Vec<u64> = self.resident_idx[worker]
            .iter()
            .map(|&cid| &self.containers[cid])
            .filter(|c| matches!(c.state, ContainerState::Transferring { .. }))
            .map(|c| c.task_id)
            .collect();
        tasks.sort_unstable();
        tasks.dedup();
        tasks
    }

    pub(super) fn evict_worker(&mut self, w: usize, drop_progress: bool) -> usize {
        let mut evicted = 0;
        // The active list covers every evictable container (terminal ones
        // never hold a worker), including in-flight migrations FROM `w`,
        // which the residency index files under their destination. None
        // of the transitions below is terminal, so indexed iteration is
        // stable; id order matches the old full pool scan.
        for i in 0..self.active.len() {
            let cid = self.active[i];
            let (state, worker) = {
                let c = &self.containers[cid];
                (c.state, c.worker)
            };
            let resident_here = match state {
                ContainerState::Running | ContainerState::Transferring { .. } => {
                    worker == Some(w)
                }
                ContainerState::Migrating { to, .. } => to == w || worker == Some(w),
                ContainerState::Blocked => {
                    // clear a chain reservation on the failed worker
                    if worker == Some(w) {
                        self.set_container(cid, ContainerState::Blocked, None);
                    }
                    false
                }
                _ => false,
            };
            if resident_here {
                // checkpoint (or drop): input must be re-staged either way
                self.set_container(cid, ContainerState::Queued, None);
                if drop_progress {
                    self.containers[cid].mi_done = 0.0;
                }
                evicted += 1;
            }
        }
        evicted
    }

    /// Per-interval churn process (paper §7: non-stationary node
    /// population). Bus-routed so toggles land in the ledger.
    pub(super) fn apply_churn(&mut self) {
        if self.churn_rate <= 0.0 {
            return;
        }
        for w in 0..self.cluster.len() {
            if !self.cluster.workers[w].mobile {
                continue;
            }
            if self.churn_rng.chance(self.churn_rate) {
                let up = !self.online[w];
                // never take the last online worker down
                if !up && self.online.iter().filter(|&&o| o).count() <= 1 {
                    continue;
                }
                self.apply_with_origin(EngineCmd::SetOnline { worker: w, up }, CmdOrigin::Churn);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::node::build_fleet;
    use crate::config::{ClusterConfig, SimConfig};
    use crate::splits::{App, SplitDecision};
    use crate::workload::Task;

    fn engine() -> Engine {
        let cluster = build_fleet(&ClusterConfig::small());
        Engine::new(cluster, SimConfig { intervals: 10, ..Default::default() }, 1)
    }

    fn task(id: u64, app: App, batch: u64) -> Task {
        Task { id, app, batch, sla: 5.0, arrival_s: 0.0, decision: None }
    }

    #[test]
    fn worker_failure_checkpoints_and_requeues() {
        let mut e = engine();
        e.admit(task(1, App::Mnist, 32_000), SplitDecision::Compressed);
        e.apply_placement(&[(0, 2)]);
        e.step_interval();
        let progress = e.containers[0].mi_done;
        assert!(progress > 0.0);
        assert_eq!(e.containers[0].state, ContainerState::Running);
        // worker 2 fails gracefully
        let eff = e.apply(EngineCmd::SetOnline { worker: 2, up: false });
        assert_eq!(eff, Effect::Evicted { containers: 1 });
        let c = &e.containers[0];
        assert_eq!(c.state, ContainerState::Queued, "container must requeue");
        assert_eq!(c.worker, None);
        assert!((c.mi_done - progress).abs() < 1e-9, "checkpoint keeps progress");
        // failed worker rejects placements
        assert!(!e.fits(0, 2));
        // replace elsewhere and finish
        e.apply_placement(&[(0, 3)]);
        let mut done = false;
        for _ in 0..20 {
            if !e.step_interval().completed.is_empty() {
                done = true;
                break;
            }
        }
        assert!(done, "task must complete after failover");
    }

    #[test]
    fn crash_drops_progress_and_requeues() {
        let mut e = engine();
        e.admit(task(1, App::Mnist, 32_000), SplitDecision::Compressed);
        e.apply_placement(&[(0, 2)]);
        e.step_interval();
        assert!(e.containers[0].mi_done > 0.0);
        assert_eq!(
            e.apply(EngineCmd::Crash { worker: 2 }),
            Effect::Evicted { containers: 1 }
        );
        let c = &e.containers[0];
        assert_eq!(c.state, ContainerState::Queued);
        assert_eq!(c.worker, None);
        assert_eq!(c.mi_done, 0.0, "hard crash loses progress");
        assert!(!e.fits(0, 2));
        assert_eq!(e.apply(EngineCmd::Recover { worker: 2 }), Effect::Applied);
        assert!(e.fits(0, 2));
        // crashing an already-offline worker is a no-op
        e.apply(EngineCmd::SetOnline { worker: 2, up: false });
        assert_eq!(e.apply(EngineCmd::Crash { worker: 2 }), Effect::Noop);
        // out-of-range targets are no-ops, never panics
        assert_eq!(e.apply(EngineCmd::Crash { worker: 99 }), Effect::Noop);
        assert_eq!(e.apply(EngineCmd::SetOnline { worker: 99, up: false }), Effect::Noop);
    }

    #[test]
    fn blocked_reservation_cleared_on_failure() {
        let mut e = engine();
        e.admit(task(1, App::Mnist, 16_000), SplitDecision::Layer);
        // pre-place the whole chain on worker 4
        e.apply_placement(&[(0, 4), (1, 4), (2, 4)]);
        assert_eq!(e.containers[1].worker, Some(4));
        e.apply(EngineCmd::SetOnline { worker: 4, up: false });
        assert_eq!(e.containers[1].worker, None, "reservation must clear");
        assert_eq!(e.containers[0].state, ContainerState::Queued);
    }

    #[test]
    fn straggler_slows_progress() {
        let progress = |factor: f64| -> f64 {
            let mut e = engine();
            e.admit(task(1, App::Mnist, 64_000), SplitDecision::Compressed);
            e.apply(EngineCmd::SetMipsFactor { worker: 0, factor });
            e.apply_placement(&[(0, 0)]);
            e.step_interval();
            e.containers[0].mi_done
        };
        let full = progress(1.0);
        let slow = progress(0.25);
        assert!(slow < 0.5 * full, "full={full} slow={slow}");
    }

    #[test]
    fn ram_squeeze_restricts_allocation_and_thrashes() {
        let mut e = engine();
        e.admit(task(1, App::Cifar100, 64_000), SplitDecision::Compressed);
        let ram = e.containers[0].ram_mb;
        // squeeze worker 0 so the container no longer fits
        let factor =
            ram / (e.cluster.workers[0].spec.ram_mb * super::super::state::RAM_OVERCOMMIT) * 0.5;
        e.apply(EngineCmd::SetRamFactor { worker: 0, factor });
        assert!(!e.fits(0, 0), "squeezed worker must reject the container");
        e.apply(EngineCmd::SetRamFactor { worker: 0, factor: 1.0 });
        assert!(e.fits(0, 0));
    }

    #[test]
    fn channel_override_floors_transfers() {
        use crate::cluster::mobility::ChannelState;
        let stage_time = |blackout: bool| -> f64 {
            let mut e = engine();
            e.admit(task(1, App::Cifar100, 64_000), SplitDecision::Compressed);
            if blackout {
                e.apply(EngineCmd::SetChannelOverride {
                    worker: 0,
                    channel: Some(ChannelState::BLACKOUT),
                });
            }
            e.apply_placement(&[(0, 0)]);
            match e.containers[0].state {
                ContainerState::Transferring { until_s } => until_s,
                _ => 0.0,
            }
        };
        let normal = stage_time(false);
        let blackout = stage_time(true);
        assert!(blackout > normal, "blackout={blackout} normal={normal}");
        // override persists across intervals until cleared
        let mut e = engine();
        e.apply(EngineCmd::SetChannelOverride {
            worker: 0,
            channel: Some(ChannelState::BLACKOUT),
        });
        e.step_interval();
        assert_eq!(e.channels[0], ChannelState::BLACKOUT);
        e.apply(EngineCmd::SetChannelOverride { worker: 0, channel: None });
        e.step_interval();
        assert_ne!(e.channels[0], ChannelState::BLACKOUT);
    }

    #[test]
    fn clock_skew_delays_transfers_by_the_offset() {
        let stage_until = |skew: f64| -> f64 {
            let mut e = engine();
            e.admit(task(1, App::Cifar100, 64_000), SplitDecision::Compressed);
            e.apply(EngineCmd::SetClockSkew { worker: 0, skew_s: skew });
            e.apply_placement(&[(0, 0)]);
            match e.containers[0].state {
                ContainerState::Transferring { until_s } => until_s,
                other => panic!("expected staging transfer, got {other:?}"),
            }
        };
        let normal = stage_until(0.0);
        let skewed = stage_until(45.0);
        assert!(
            (skewed - normal - 45.0).abs() < 1e-6,
            "skew must add exactly its offset: normal={normal} skewed={skewed}"
        );
        let mut e = engine();
        e.apply(EngineCmd::SetClockSkew { worker: 3, skew_s: 1e9 });
        assert_eq!(e.clock_skew(3), 600.0, "skew clamps to the NTP-grade cap");
        e.apply(EngineCmd::SetClockSkew { worker: 3, skew_s: 0.0 });
        assert_eq!(e.clock_skew(3), 0.0);
        assert_eq!(e.clock_skew(99), 0.0, "out-of-range worker reads as unskewed");
        assert_eq!(
            e.apply(EngineCmd::SetClockSkew { worker: 99, skew_s: 5.0 }),
            Effect::Noop
        );
    }

    #[test]
    fn churn_toggles_mobile_workers_only_and_lands_in_the_ledger() {
        let mut e = engine();
        e.apply(EngineCmd::SetChurn { rate: 0.9 });
        let mut saw_offline = false;
        for _ in 0..10 {
            let r = e.step_interval();
            saw_offline |= r.offline > 0;
            for (w, up) in e.online().iter().enumerate() {
                if !e.cluster.workers[w].mobile {
                    assert!(up, "static workers never churn");
                }
            }
            assert!(e.online().iter().any(|&o| o), "at least one worker stays up");
        }
        if e.cluster.workers.iter().any(|w| w.mobile) {
            assert!(saw_offline, "high churn must take someone offline");
            // every churn toggle is a bus command tagged with its origin
            assert!(
                e.ledger().iter().any(|r| r.origin == CmdOrigin::Churn
                    && matches!(r.cmd, EngineCmd::SetOnline { .. })),
                "churn toggles must be ledger-recorded"
            );
        }
    }

    #[test]
    fn force_offline_no_evict_leaves_containers_running() {
        let mut e = engine();
        e.admit(task(1, App::Mnist, 32_000), SplitDecision::Compressed);
        e.apply_placement(&[(0, 0)]);
        e.step_interval();
        assert_eq!(e.apply(EngineCmd::ForceOfflineNoEvict { worker: 0 }), Effect::Applied);
        assert!(!e.online()[0]);
        // the deliberate bug: the container still holds the dead worker
        assert_eq!(e.containers[0].worker, Some(0));
    }

    #[test]
    fn corrupt_payload_fails_the_in_flight_task() {
        let mut e = engine();
        e.admit(task(1, App::Cifar100, 64_000), SplitDecision::Compressed);
        e.apply_placement(&[(0, 0)]);
        assert!(matches!(e.containers[0].state, ContainerState::Transferring { .. }));
        // corruption on an untouched worker is empty-affected
        assert_eq!(
            e.apply(EngineCmd::CorruptPayload { worker: 5 }),
            Effect::Affected { tasks: vec![] }
        );
        // corruption on the staging worker fails the owning task
        assert_eq!(
            e.apply(EngineCmd::CorruptPayload { worker: 0 }),
            Effect::Affected { tasks: vec![1] }
        );
        assert!(e.task_failed(1));
        let r = e.step_interval();
        assert_eq!(r.failed.len(), 1, "corrupted task must fail-and-penalize");
        assert_eq!(r.failed[0].task_id, 1);
        assert!(r.completed.is_empty(), "a corrupted transfer must never complete");
        // out of range is a no-op
        assert_eq!(e.apply(EngineCmd::CorruptPayload { worker: 99 }), Effect::Noop);
    }

    #[test]
    fn swallowed_corruption_records_but_does_not_fail() {
        let mut e = engine();
        e.admit(task(1, App::Mnist, 16_000), SplitDecision::Compressed);
        e.apply_placement(&[(0, 0)]);
        assert_eq!(
            e.apply(EngineCmd::CorruptPayloadSwallowed { worker: 0 }),
            Effect::Affected { tasks: vec![1] }
        );
        assert!(!e.task_failed(1), "the bug hook must swallow the corruption");
        // the ledger still shows the blast radius — that is what the
        // payload-corruption-handled oracle audits
        let rec = e.ledger().last().unwrap();
        assert!(matches!(rec.cmd, EngineCmd::CorruptPayloadSwallowed { worker: 0 }));
        assert_eq!(rec.effect, Effect::Affected { tasks: vec![1] });
    }

    #[test]
    fn ledger_records_every_command_with_interval_stamps() {
        let mut e = engine();
        e.apply(EngineCmd::SetMipsFactor { worker: 1, factor: 0.5 });
        e.step_interval();
        e.apply(EngineCmd::Crash { worker: 1 });
        assert_eq!(e.ledger().len(), 2);
        assert_eq!(e.ledger()[0].interval, 0);
        assert_eq!(e.ledger()[0].origin, CmdOrigin::External);
        assert_eq!(e.ledger()[1].interval, 1);
        assert!(matches!(e.ledger()[1].cmd, EngineCmd::Crash { worker: 1 }));
        assert!(matches!(e.ledger()[1].effect, Effect::Evicted { containers: 0 }));
    }

    #[test]
    fn fault_surface_replay_reproduces_the_engine() {
        let mut e = engine();
        assert_eq!(
            FaultSurface::replay(e.workers(), e.ledger()),
            e.fault_surface(),
            "empty ledger replays to the baseline surface"
        );
        e.apply(EngineCmd::Crash { worker: 2 });
        e.apply(EngineCmd::SetMipsFactor { worker: 1, factor: 0.003 }); // clamps to 0.05
        e.apply(EngineCmd::SetRamFactor { worker: 3, factor: 0.5 });
        e.apply(EngineCmd::SetClockSkew { worker: 4, skew_s: 1e9 }); // clamps to 600
        e.apply(EngineCmd::SetChurn { rate: 2.0 }); // clamps to 1.0
        e.step_interval(); // churn toggles (if any) land in the ledger too
        e.apply(EngineCmd::Recover { worker: 2 });
        e.apply(EngineCmd::SetOnline { worker: 5, up: false });
        e.apply(EngineCmd::Crash { worker: 99 }); // out-of-range no-op
        let replayed = FaultSurface::replay(e.workers(), e.ledger());
        assert_eq!(replayed, e.fault_surface());
        assert!(!replayed.online[5]);
        assert_eq!(replayed.mips_factor[1], 0.05);
        assert_eq!(replayed.clock_skew_s[4], 600.0);
        assert_eq!(replayed.churn_rate, 1.0);
    }

    #[test]
    fn scaling_commands_park_gracefully_and_tag_their_origin() {
        let mut e = engine();
        e.admit(task(1, App::Mnist, 32_000), SplitDecision::Compressed);
        e.apply_placement(&[(0, 2)]);
        e.step_interval();
        let progress = e.containers[0].mi_done;
        assert!(progress > 0.0);
        // park: graceful eviction (checkpoint kept), Autoscale origin
        assert_eq!(
            e.apply_scaling(EngineCmd::WorkerLeave { worker: 2 }),
            Effect::Evicted { containers: 1 }
        );
        assert!(!e.online()[2]);
        let c = &e.containers[0];
        assert_eq!(c.state, ContainerState::Queued);
        assert!((c.mi_done - progress).abs() < 1e-9, "parking must checkpoint");
        // unpark
        assert_eq!(e.apply_scaling(EngineCmd::WorkerJoin { worker: 2 }), Effect::Applied);
        assert!(e.online()[2]);
        // idempotence + out-of-range are no-ops
        assert_eq!(e.apply_scaling(EngineCmd::WorkerJoin { worker: 2 }), Effect::Noop);
        assert_eq!(e.apply_scaling(EngineCmd::WorkerLeave { worker: 99 }), Effect::Noop);
        let scaling: Vec<&CmdRecord> = e
            .ledger()
            .iter()
            .filter(|r| r.origin == CmdOrigin::Autoscale)
            .collect();
        assert_eq!(scaling.len(), 4, "every scaling command must land in the ledger");
        assert!(matches!(scaling[0].cmd, EngineCmd::WorkerLeave { worker: 2 }));
        assert!(matches!(scaling[1].cmd, EngineCmd::WorkerJoin { worker: 2 }));
    }

    #[test]
    fn offline_origin_tracks_who_owns_each_offline_worker() {
        let mut e = engine();
        assert!(e.offline_origins().iter().all(Option::is_none), "all online at start");
        // autoscaler parks worker 2 → Autoscale-owned offline state
        e.apply_scaling(EngineCmd::WorkerLeave { worker: 2 });
        assert_eq!(e.offline_origins()[2], Some(CmdOrigin::Autoscale));
        // chaos recovers it → ownership cleared
        e.apply(EngineCmd::Recover { worker: 2 });
        assert_eq!(e.offline_origins()[2], None);
        // chaos crashes it → External-owned; a redundant autoscaler park
        // is a Noop and MUST NOT steal ownership of the offline state
        e.apply(EngineCmd::Crash { worker: 2 });
        assert_eq!(e.offline_origins()[2], Some(CmdOrigin::External));
        assert_eq!(e.apply_scaling(EngineCmd::WorkerLeave { worker: 2 }), Effect::Noop);
        assert_eq!(
            e.offline_origins()[2],
            Some(CmdOrigin::External),
            "a noop park must not relabel a chaos crash"
        );
        // graceful SetOnline toggles stamp and clear like the rest
        e.apply(EngineCmd::SetOnline { worker: 3, up: false });
        assert_eq!(e.offline_origins()[3], Some(CmdOrigin::External));
        e.apply(EngineCmd::SetOnline { worker: 3, up: true });
        assert_eq!(e.offline_origins()[3], None);
        // out-of-range commands are noops and leave the record untouched
        assert_eq!(e.apply(EngineCmd::Crash { worker: 99 }), Effect::Noop);
    }

    #[test]
    fn fault_surface_replay_tracks_scaling_commands() {
        let mut e = engine();
        e.apply_scaling(EngineCmd::WorkerLeave { worker: 5 });
        e.apply(EngineCmd::Crash { worker: 1 });
        e.apply_scaling(EngineCmd::WorkerLeave { worker: 4 });
        e.apply_scaling(EngineCmd::WorkerJoin { worker: 5 });
        e.step_interval();
        let replayed = FaultSurface::replay(e.workers(), e.ledger());
        assert_eq!(replayed, e.fault_surface());
        assert!(replayed.online[5] && !replayed.online[4] && !replayed.online[1]);
    }

    #[test]
    fn eviction_keeps_the_incremental_indices_exact() {
        let mut e = engine();
        e.admit(task(1, App::Mnist, 16_000), SplitDecision::Layer);
        e.admit(task(2, App::Cifar100, 32_000), SplitDecision::Semantic);
        // chain on worker 2, semantic fragments spread across 2..6
        e.apply_placement(&[(0, 2), (1, 2), (2, 2), (3, 2), (4, 3), (5, 4), (6, 5)]);
        e.verify_indices().unwrap();
        e.step_interval();
        e.verify_indices().unwrap();
        // migrate one container away, then crash its destination mid-flight
        let moved = e.apply_placement(&[(3, 6)]);
        if !moved.is_empty() {
            e.verify_indices().unwrap();
            e.apply(EngineCmd::Crash { worker: 6 });
        }
        e.apply(EngineCmd::Crash { worker: 2 });
        e.verify_indices().unwrap();
        e.apply(EngineCmd::CorruptPayload { worker: 3 });
        e.verify_indices().unwrap();
        e.step_interval();
        e.verify_indices().unwrap();
    }

    #[test]
    fn starvation_sweep_via_the_bus_names_the_failed_tasks() {
        let mut e = engine();
        e.admit(task(7, App::Mnist, 32_000), SplitDecision::Compressed);
        for _ in 0..3 {
            e.step_interval(); // never placed: starves
        }
        assert_eq!(
            e.apply(EngineCmd::FailTasksOlderThan { age_s: 2.0 * 300.0 }),
            Effect::Affected { tasks: vec![7] }
        );
        assert_eq!(
            e.apply(EngineCmd::FailTasksOlderThan { age_s: 2.0 * 300.0 }),
            Effect::Affected { tasks: vec![] },
            "sweep is idempotent"
        );
    }

    #[test]
    fn handoff_rehomes_the_worker_and_stretches_inflight_transfers() {
        use crate::chaos::events::RACKS;
        let mut e = engine();
        e.admit(task(1, App::Cifar100, 64_000), SplitDecision::Compressed);
        e.apply_placement(&[(0, 0)]);
        let before = match e.containers[0].state {
            ContainerState::Transferring { until_s } => until_s,
            other => panic!("expected staging transfer, got {other:?}"),
        };
        let from = e.rack_of()[0];
        let to = (from + 1) % RACKS;
        assert_eq!(
            e.apply(EngineCmd::Handoff { worker: 0, from_rack: from, to_rack: to }),
            Effect::Affected { tasks: vec![1] }
        );
        assert_eq!(e.rack_of()[0], to);
        let after = match e.containers[0].state {
            ContainerState::Transferring { until_s } => until_s,
            other => panic!("transfer must stay in flight, got {other:?}"),
        };
        assert!(after > before, "handoff must stretch the transfer: {after} vs {before}");
        // the audit log remembers the move and every resident's progress
        let audit = e.handoff_audits().last().expect("executed handoff must be audited");
        assert_eq!((audit.worker, audit.from_rack, audit.to_rack), (0, from, to));
        assert_eq!(audit.residents, vec![(0, 1, 0.0)]);
        // stale handoff (worker no longer in from_rack) is a Noop, no audit
        assert_eq!(
            e.apply(EngineCmd::Handoff { worker: 0, from_rack: from, to_rack: to }),
            Effect::Noop
        );
        assert_eq!(e.handoff_audits().len(), 1);
        // self-handoff and out-of-range targets are Noops too
        assert_eq!(
            e.apply(EngineCmd::Handoff { worker: 0, from_rack: to, to_rack: to }),
            Effect::Noop
        );
        assert_eq!(
            e.apply(EngineCmd::Handoff { worker: 99, from_rack: 0, to_rack: 1 }),
            Effect::Noop
        );
        e.verify_indices().unwrap();
        // progress survives the handoff end-to-end
        let mut done = false;
        for _ in 0..30 {
            if !e.step_interval().completed.is_empty() {
                done = true;
                break;
            }
        }
        assert!(done, "task must complete after the handoff");
    }

    #[test]
    fn handoff_preserves_running_progress_and_keeps_the_worker() {
        use crate::chaos::events::RACKS;
        let mut e = engine();
        e.admit(task(1, App::Mnist, 32_000), SplitDecision::Compressed);
        e.apply_placement(&[(0, 2)]);
        e.step_interval();
        let progress = e.containers[0].mi_done;
        assert!(progress > 0.0);
        let from = e.rack_of()[2];
        let eff = e.apply(EngineCmd::Handoff {
            worker: 2,
            from_rack: from,
            to_rack: (from + 2) % RACKS,
        });
        // a running container is not an in-flight transfer: nothing stretches
        assert_eq!(eff, Effect::Affected { tasks: vec![] });
        let c = &e.containers[0];
        assert_eq!(c.worker, Some(2), "handoff must not evict");
        assert!((c.mi_done - progress).abs() < 1e-12, "handoff must not touch progress");
        let audit = e.handoff_audits().last().unwrap();
        assert_eq!(audit.residents, vec![(0, 1, progress)]);
        // the handoff lands in the command ledger like any other mutation
        let rec = e.ledger().last().unwrap();
        assert!(matches!(rec.cmd, EngineCmd::Handoff { worker: 2, .. }));
    }
}
