//! Container lifecycle state machine.
//!
//! A task is realized as one container per split fragment (paper §3:
//! C^i from decision d^i). Chain fragments are created `Blocked` and
//! unblock when their predecessor completes; parallel fragments are
//! immediately `Queued`. Placement moves `Queued` containers to a worker
//! (input transfer, then `Running`); re-placement of a `Running` container
//! triggers a CRIU-style `Migrating` phase.

use crate::splits::{FragmentProfile, Precedence, SplitDecision};

pub type ContainerId = usize;

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ContainerState {
    /// Waiting on a chain predecessor.
    Blocked,
    /// Ready for placement; in the broker's wait queue.
    Queued,
    /// Input/intermediate payload in flight to the assigned worker.
    Transferring { until_s: f64 },
    /// Executing on `worker`.
    Running,
    /// CRIU checkpoint/restore to another worker in progress.
    Migrating { until_s: f64, to: usize },
    /// Finished at the recorded time.
    Done { at_s: f64 },
    /// Abandoned: the task was failed (timeout / unrecoverable fault) and
    /// this fragment will never run. Terminal, like `Done`.
    Failed,
}

#[derive(Clone, Debug)]
pub struct Container {
    pub id: ContainerId,
    pub task_id: u64,
    pub frag_idx: usize,
    pub decision: SplitDecision,
    pub precedence: Precedence,
    pub profile: FragmentProfile,
    /// Chain predecessor (container id), if any.
    pub prev: Option<ContainerId>,
    /// Total / completed work, million instructions.
    pub mi_total: f64,
    pub mi_done: f64,
    /// Resident memory demand while running (MB).
    pub ram_mb: f64,
    /// Input payload that must reach the worker before start (MB).
    pub input_mb: f64,
    /// Output payload forwarded on completion (MB).
    pub output_mb: f64,
    pub state: ContainerState,
    pub worker: Option<usize>,
    /// Where the input payload currently lives (broker = None, or the
    /// predecessor's worker).
    pub input_src: Option<usize>,
    pub created_s: f64,
    // ---- time decomposition (seconds), for Fig. 14 ----
    pub t_wait: f64,
    pub t_transfer: f64,
    pub t_exec: f64,
    pub t_migrate: f64,
}

impl Container {
    pub fn is_active(&self) -> bool {
        !matches!(
            self.state,
            ContainerState::Done { .. } | ContainerState::Failed
        )
    }

    /// Containers the placement engine should consider this interval.
    /// Blocked chain successors are included: the paper's P_t covers ALL
    /// active containers, so a chain is pre-placed at admission and each
    /// stage starts the moment its predecessor finishes (no interval-
    /// boundary wait).
    pub fn is_placeable(&self) -> bool {
        matches!(
            self.state,
            ContainerState::Blocked
                | ContainerState::Queued
                | ContainerState::Running
                | ContainerState::Transferring { .. }
        )
    }

    pub fn is_done(&self) -> bool {
        matches!(self.state, ContainerState::Done { .. })
    }

    pub fn remaining_fraction(&self) -> f64 {
        if self.mi_total <= 0.0 {
            0.0
        } else {
            ((self.mi_total - self.mi_done) / self.mi_total).clamp(0.0, 1.0)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::splits::{Registry, SplitDecision};

    fn mk() -> Container {
        let plan = Registry::plan(crate::splits::App::Mnist, SplitDecision::Layer);
        Container {
            id: 0,
            task_id: 1,
            frag_idx: 0,
            decision: SplitDecision::Layer,
            precedence: plan.precedence,
            profile: plan.fragments[0].clone(),
            prev: None,
            mi_total: 100.0,
            mi_done: 0.0,
            ram_mb: 500.0,
            input_mb: 10.0,
            output_mb: 5.0,
            state: ContainerState::Queued,
            worker: None,
            input_src: None,
            created_s: 0.0,
            t_wait: 0.0,
            t_transfer: 0.0,
            t_exec: 0.0,
            t_migrate: 0.0,
        }
    }

    #[test]
    fn state_predicates() {
        let mut c = mk();
        assert!(c.is_active() && c.is_placeable() && !c.is_done());
        c.state = ContainerState::Blocked;
        assert!(c.is_active() && c.is_placeable(), "chains are pre-placed");
        c.state = ContainerState::Done { at_s: 5.0 };
        assert!(!c.is_active() && c.is_done());
        c.state = ContainerState::Failed;
        assert!(!c.is_active() && !c.is_placeable() && !c.is_done());
    }

    #[test]
    fn remaining_fraction_bounds() {
        let mut c = mk();
        assert_eq!(c.remaining_fraction(), 1.0);
        c.mi_done = 50.0;
        assert!((c.remaining_fraction() - 0.5).abs() < 1e-12);
        c.mi_done = 200.0;
        assert_eq!(c.remaining_fraction(), 0.0);
    }
}
