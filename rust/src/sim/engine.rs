//! The discrete-interval execution engine.
//!
//! Per scheduling interval (paper I_t, 300 s), the broker admits tasks,
//! takes split + placement decisions, then the engine integrates container
//! progress over `sub_steps` fixed sub-steps:
//!
//!   * fair-share CPU: containers on a worker split its MIPS evenly;
//!   * RAM pressure: if resident demand exceeds node RAM, all containers on
//!     the node slow by ram/demand (swap-on-NAS, the paper's memory
//!     bottleneck), floored at 0.2×;
//!   * transfers: input payloads move at min(net, disk) bandwidth of the
//!     endpoints (cPickle+bzip2+rsync goes through disk), scaled by the
//!     mobility channel;
//!   * migration: CRIU checkpoint of the resident set over the same path,
//!     no progress during migration;
//!   * chains: fragment k+1 unblocks when k completes; its input source is
//!     k's worker.
//!
//! Energy integrates the SPEC power curve over busy time per worker.

// BTreeMap, not HashMap: task iteration order feeds order-sensitive
// consumers (the MAB response-time EMA, Gillis RL updates), and std's
// HashMap order varies per process — which would break the chaos engine's
// bit-identical replay guarantee.
use std::collections::BTreeMap;

use crate::cluster::energy;
use crate::cluster::mobility::{ChannelState, MobilityModel};
use crate::cluster::node::Cluster;
use crate::cluster::topology;
use crate::config::SimConfig;
use crate::splits::{Precedence, Registry, SplitDecision};
use crate::workload::Task;

use super::container::{Container, ContainerId, ContainerState};

/// Allowed RAM overcommit at allocation time (swap headroom): a worker
/// accepts a container while resident demand stays under this × RAM.
pub const RAM_OVERCOMMIT: f64 = 2.0;
/// Thrash floor: heaviest slowdown from memory pressure.
const THRASH_FLOOR: f64 = 0.2;

/// A task that left the system this interval (paper E_t member).
#[derive(Clone, Debug)]
pub struct CompletedTask {
    pub task_id: u64,
    pub app: crate::splits::App,
    pub decision: SplitDecision,
    pub batch: u64,
    pub sla: f64,
    /// Response time in scheduling intervals (paper r_i).
    pub response: f64,
    pub wait: f64,
    pub exec: f64,
    pub transfer: f64,
    pub migrate: f64,
    /// Workers that hosted at least one fragment.
    pub workers: Vec<usize>,
    /// Filled by the coordinator (accuracy oracle), not the engine.
    pub accuracy: f64,
}

/// A task that was abandoned (timeout or unrecoverable fault) rather than
/// completed. Failed tasks leave the system like completions do, so the
/// broker's bookkeeping stays conserved under fault injection.
#[derive(Clone, Debug)]
pub struct FailedTask {
    pub task_id: u64,
    pub app: crate::splits::App,
    pub decision: SplitDecision,
    pub batch: u64,
    pub sla: f64,
    /// Age at failure, in scheduling intervals.
    pub age: f64,
}

/// Per-worker observability snapshot (feeds S_t featurization).
#[derive(Clone, Debug, Default)]
pub struct WorkerSnapshot {
    /// Fraction of the interval the CPU was busy.
    pub cpu: f64,
    /// Resident demand / RAM at interval end (can exceed 1 under pressure).
    pub ram: f64,
    /// Transfer seconds that touched this worker / interval length.
    pub net: f64,
    /// Same, for disk-bound payload movement.
    pub disk: f64,
    /// Number of resident containers at interval end.
    pub containers: usize,
}

/// What happened during one simulated interval.
#[derive(Clone, Debug)]
pub struct IntervalReport {
    pub interval: usize,
    pub completed: Vec<CompletedTask>,
    /// Tasks abandoned this interval (see [`Engine::fail_task`]).
    pub failed: Vec<FailedTask>,
    pub energy_wh: f64,
    /// Normalized AEC ∈ [0,1] (for eq. 10).
    pub aec: f64,
    pub snapshots: Vec<WorkerSnapshot>,
    /// Containers still waiting (unplaceable) at interval end.
    pub queued: usize,
    /// Workers offline this interval (churn).
    pub offline: usize,
}

pub struct Engine {
    pub cluster: Cluster,
    mobility: MobilityModel,
    pub channels: Vec<ChannelState>,
    cfg: SimConfig,
    pub containers: Vec<Container>,
    tasks: BTreeMap<u64, TaskEntry>,
    pub now_s: f64,
    pub interval: usize,
    /// Worker availability under churn (paper §7 future work); all online
    /// by default.
    online: Vec<bool>,
    churn_rate: f64,
    churn_rng: crate::util::rng::Rng,
    /// Per-worker MIPS degradation factor ∈ (0, 1] (straggler injection).
    mips_factor: Vec<f64>,
    /// Per-worker effective-RAM factor ∈ (0, 1] (RAM-squeeze injection).
    ram_factor: Vec<f64>,
    /// Per-worker forced channel state (network blackout injection);
    /// overlays the mobility model while set.
    channel_override: Vec<Option<ChannelState>>,
    /// Per-worker clock-skew seconds (clock-skew injection): coordination
    /// with a skewed worker pays this extra latency on every payload
    /// movement that touches it (the broker must reconcile timestamps
    /// before trusting a transfer window). 0 = clocks agree.
    clock_skew_s: Vec<f64>,
    /// Tasks failed since the last interval report.
    pending_failed: Vec<FailedTask>,
    // scratch: per-worker busy seconds within the current interval
    busy_s: Vec<f64>,
    xfer_s: Vec<f64>,
}

#[derive(Clone, Debug)]
struct TaskEntry {
    task: Task,
    containers: Vec<ContainerId>,
    done: bool,
    failed: bool,
}

impl Engine {
    pub fn new(cluster: Cluster, cfg: SimConfig, seed: u64) -> Self {
        let flags: Vec<bool> = cluster.workers.iter().map(|w| w.mobile).collect();
        let n = cluster.len();
        let mut mobility = MobilityModel::new(&flags, seed);
        let channels = mobility.step();
        Engine {
            cluster,
            mobility,
            channels,
            cfg,
            containers: Vec::new(),
            tasks: BTreeMap::new(),
            now_s: 0.0,
            interval: 0,
            online: vec![true; n],
            churn_rate: 0.0,
            churn_rng: crate::util::rng::Rng::new(seed ^ 0xC0FFEE),
            mips_factor: vec![1.0; n],
            ram_factor: vec![1.0; n],
            channel_override: vec![None; n],
            clock_skew_s: vec![0.0; n],
            pending_failed: Vec::new(),
            busy_s: vec![0.0; n],
            xfer_s: vec![0.0; n],
        }
    }

    pub fn interval_seconds(&self) -> f64 {
        self.cfg.interval_seconds
    }

    pub fn workers(&self) -> usize {
        self.cluster.len()
    }

    pub fn task(&self, id: u64) -> Option<&Task> {
        self.tasks.get(&id).map(|e| &e.task)
    }

    /// Admit a task whose split decision has been taken: create one
    /// container per fragment of the plan.
    pub fn admit(&mut self, mut task: Task, decision: SplitDecision) {
        task.decision = Some(decision);
        let plan = Registry::plan(task.app, decision);
        let k = task.batch_k();
        let mut ids = Vec::new();
        for (fi, frag) in plan.fragments.iter().enumerate() {
            let id = self.containers.len();
            let chain = plan.precedence == Precedence::Chain;
            let prev = if chain && fi > 0 { Some(id - 1) } else { None };
            let input_mb = if chain && fi > 0 {
                plan.fragments[fi - 1].out_mb_per_ksample * k
            } else {
                plan.input_mb_per_ksample * k
            };
            self.containers.push(Container {
                id,
                task_id: task.id,
                frag_idx: fi,
                decision,
                precedence: plan.precedence,
                profile: frag.clone(),
                prev,
                mi_total: frag.mi_per_ksample * k,
                mi_done: 0.0,
                ram_mb: frag.ram_fixed_mb + frag.ram_per_ksample_mb * k,
                input_mb,
                output_mb: frag.out_mb_per_ksample * k,
                state: if prev.is_some() { ContainerState::Blocked } else { ContainerState::Queued },
                worker: None,
                input_src: None, // broker
                created_s: self.now_s,
                t_wait: 0.0,
                t_transfer: 0.0,
                t_exec: 0.0,
                t_migrate: 0.0,
            });
            ids.push(id);
        }
        self.tasks
            .insert(task.id, TaskEntry { task, containers: ids, done: false, failed: false });
    }

    /// Containers the placement engine must consider (placeable states).
    pub fn placeable(&self) -> Vec<ContainerId> {
        self.containers
            .iter()
            .filter(|c| c.is_placeable())
            .map(|c| c.id)
            .collect()
    }

    /// Resident RAM demand per worker: running/transferring/migrating-in
    /// containers plus Blocked chain successors holding a reservation —
    /// a reservation consumes capacity so the later unblock (which starts
    /// its transfer unconditionally) can never breach the overcommit cap.
    pub fn resident_ram(&self) -> Vec<f64> {
        let mut ram = vec![0.0; self.cluster.len()];
        for c in &self.containers {
            match c.state {
                ContainerState::Running
                | ContainerState::Transferring { .. }
                | ContainerState::Blocked => {
                    if let Some(w) = c.worker {
                        ram[w] += c.ram_mb;
                    }
                }
                ContainerState::Migrating { to, .. } => ram[to] += c.ram_mb,
                _ => {}
            }
        }
        ram
    }

    /// Enable worker churn: per-interval probability that a mobile worker
    /// toggles offline/online (paper §7: non-stationary node population).
    pub fn set_churn(&mut self, rate: f64) {
        self.churn_rate = rate.clamp(0.0, 1.0);
    }

    /// Worker availability (false = offline under churn).
    pub fn online(&self) -> &[bool] {
        &self.online
    }

    /// Force a worker offline/online. Checkpoints (CRIU-style: progress
    /// kept) and requeues every container resident on a failing worker.
    pub fn set_online(&mut self, w: usize, up: bool) {
        if self.online[w] == up {
            return;
        }
        self.online[w] = up;
        if !up {
            self.evict_worker(w, false);
        }
    }

    /// Hard-crash a worker: offline immediately, and unlike the graceful
    /// churn path there is no time to checkpoint — resident containers are
    /// requeued with their progress LOST (input must be re-staged and the
    /// fragment recomputed from scratch).
    pub fn crash_worker(&mut self, w: usize) {
        if w >= self.online.len() || !self.online[w] {
            return;
        }
        self.online[w] = false;
        self.evict_worker(w, true);
    }

    /// Bring a crashed/offline worker back.
    pub fn recover_worker(&mut self, w: usize) {
        if w < self.online.len() {
            self.set_online(w, true);
        }
    }

    /// Chaos-testing bug-injection hook: take a worker offline WITHOUT
    /// evicting its containers. This deliberately violates the
    /// `crashed-workers-idle` invariant so the chaos oracles can be
    /// validated end-to-end. Never call this outside fault-injection tests.
    pub fn force_offline_no_evict(&mut self, w: usize) {
        if w < self.online.len() {
            self.online[w] = false;
        }
    }

    fn evict_worker(&mut self, w: usize, drop_progress: bool) {
        for c in self.containers.iter_mut() {
            let resident_here = match c.state {
                ContainerState::Running | ContainerState::Transferring { .. } => {
                    c.worker == Some(w)
                }
                ContainerState::Migrating { to, .. } => to == w || c.worker == Some(w),
                ContainerState::Blocked => {
                    // clear a chain reservation on the failed worker
                    if c.worker == Some(w) {
                        c.worker = None;
                    }
                    false
                }
                _ => false,
            };
            if resident_here {
                // checkpoint (or drop): input must be re-staged either way
                c.worker = None;
                c.state = ContainerState::Queued;
                if drop_progress {
                    c.mi_done = 0.0;
                }
            }
        }
    }

    /// Degrade a worker's compute throughput (straggler injection):
    /// `factor` scales its MIPS; 1.0 restores full speed.
    pub fn set_mips_factor(&mut self, w: usize, factor: f64) {
        if w < self.mips_factor.len() {
            self.mips_factor[w] = factor.clamp(0.05, 1.0);
        }
    }

    /// Shrink a worker's effective RAM (memory-squeeze injection): `factor`
    /// scales the capacity seen by allocation and thrash checks; 1.0
    /// restores it. The physical Table-3 capacity is unchanged.
    pub fn set_ram_factor(&mut self, w: usize, factor: f64) {
        if w < self.ram_factor.len() {
            self.ram_factor[w] = factor.clamp(0.1, 1.0);
        }
    }

    /// Force a worker's channel state (network blackout injection); `None`
    /// returns control to the mobility model at the next interval.
    pub fn set_channel_override(&mut self, w: usize, ch: Option<ChannelState>) {
        if w >= self.channel_override.len() {
            return;
        }
        self.channel_override[w] = ch;
        if let Some(ch) = ch {
            self.channels[w] = ch;
        }
    }

    /// Drift a worker's clock by `skew_s` seconds (clock-skew injection):
    /// every payload movement touching the worker pays the skew as extra
    /// coordination latency; 0.0 ends the episode. Clamped to [0, 600] —
    /// NTP-grade drift, not a wall-clock rewrite.
    pub fn set_clock_skew(&mut self, w: usize, skew_s: f64) {
        if w < self.clock_skew_s.len() {
            self.clock_skew_s[w] = skew_s.clamp(0.0, 600.0);
        }
    }

    /// Currently applied clock skew of worker `w`, in seconds.
    pub fn clock_skew(&self, w: usize) -> f64 {
        self.clock_skew_s.get(w).copied().unwrap_or(0.0)
    }

    /// Effective RAM capacity of worker `w` under any active squeeze.
    pub fn effective_ram_mb(&self, w: usize) -> f64 {
        self.cluster.workers[w].spec.ram_mb * self.ram_factor[w]
    }

    /// Abandon a task: mark it failed, kill its unfinished containers and
    /// release their workers. Returns false if the task is unknown or has
    /// already left the system. The failure surfaces in the next
    /// [`IntervalReport::failed`].
    pub fn fail_task(&mut self, id: u64) -> bool {
        let Some(e) = self.tasks.get_mut(&id) else {
            return false;
        };
        if e.done {
            return false;
        }
        e.done = true;
        e.failed = true;
        let task = e.task.clone();
        let cids = e.containers.clone();
        for &cid in &cids {
            let c = &mut self.containers[cid];
            if !c.is_done() {
                c.state = ContainerState::Failed;
                c.worker = None;
            }
        }
        self.pending_failed.push(FailedTask {
            task_id: id,
            app: task.app,
            decision: task.decision.unwrap_or(SplitDecision::Full),
            batch: task.batch,
            sla: task.sla,
            age: (self.now_s - task.arrival_s) / self.cfg.interval_seconds,
        });
        true
    }

    /// Fail every active task older than `age_s` simulation seconds
    /// (starvation guard under fault injection). Returns how many failed.
    pub fn fail_tasks_older_than(&mut self, age_s: f64) -> usize {
        let now = self.now_s;
        let ids: Vec<u64> = self
            .tasks
            .iter()
            .filter(|(_, e)| !e.done && now - e.task.arrival_s > age_s)
            .map(|(id, _)| *id)
            .collect();
        for id in &ids {
            self.fail_task(*id);
        }
        ids.len()
    }

    /// Tasks ever admitted.
    pub fn admitted_task_count(&self) -> usize {
        self.tasks.len()
    }

    /// Tasks that completed successfully.
    pub fn completed_task_count(&self) -> usize {
        self.tasks.values().filter(|e| e.done && !e.failed).count()
    }

    /// Tasks that were abandoned via [`Engine::fail_task`].
    pub fn failed_task_count(&self) -> usize {
        self.tasks.values().filter(|e| e.failed).count()
    }

    fn apply_churn(&mut self) {
        if self.churn_rate <= 0.0 {
            return;
        }
        for w in 0..self.cluster.len() {
            if !self.cluster.workers[w].mobile {
                continue;
            }
            if self.churn_rng.chance(self.churn_rate) {
                let up = !self.online[w];
                // never take the last online worker down
                if !up && self.online.iter().filter(|&&o| o).count() <= 1 {
                    continue;
                }
                self.set_online(w, up);
            }
        }
    }

    /// Can `cid` be (re)placed on worker `w` right now?
    pub fn fits(&self, cid: ContainerId, w: usize) -> bool {
        if !self.online[w] {
            return false;
        }
        let c = &self.containers[cid];
        if c.worker == Some(w) {
            return true;
        }
        let resident = self.resident_ram();
        resident[w] + c.ram_mb <= self.effective_ram_mb(w) * RAM_OVERCOMMIT
    }

    /// Apply a placement: allocations for queued containers, migrations for
    /// running ones. Infeasible assignments are skipped (stay queued —
    /// paper §4.3's wait-queue relaxation); returns ids actually applied.
    pub fn apply_placement(&mut self, assignment: &[(ContainerId, usize)]) -> Vec<ContainerId> {
        let mut applied = Vec::new();
        for &(cid, w) in assignment {
            if w >= self.cluster.len() || cid >= self.containers.len() {
                continue;
            }
            if !self.fits(cid, w) {
                continue;
            }
            let now = self.now_s;
            // compute transfer costs immutably first
            let (state, worker) = {
                let c = &self.containers[cid];
                match c.state {
                    ContainerState::Queued => {
                        let t = self.payload_transfer_s(c.input_src, w, c.input_mb);
                        (ContainerState::Transferring { until_s: now + t }, Some(w))
                    }
                    // Blocked chain successor: reserve the worker; the
                    // transfer starts the moment the predecessor finishes.
                    ContainerState::Blocked => (ContainerState::Blocked, Some(w)),
                    ContainerState::Running if c.worker != Some(w) => {
                        // CRIU migration: checkpoint resident set, move it.
                        let t = self.payload_transfer_s(c.worker, w, c.ram_mb * 0.5);
                        (ContainerState::Migrating { until_s: now + t, to: w }, c.worker)
                    }
                    _ => continue,
                }
            };
            let c = &mut self.containers[cid];
            c.state = state;
            c.worker = worker.or(Some(w));
            if let ContainerState::Migrating { .. } = c.state {
                // worker updated on arrival
            } else {
                c.worker = Some(w);
            }
            applied.push(cid);
        }
        applied
    }

    /// Transfer seconds for `mb` from `src` (None = broker) to worker `dst`,
    /// bottlenecked by disk bandwidth on both ends (rsync-through-disk).
    fn payload_transfer_s(&self, src: Option<usize>, dst: usize, mb: f64) -> f64 {
        let ch_dst = &self.channels[dst];
        let net_s = match src {
            None => topology::broker_transfer_s(&self.cluster, dst, ch_dst, mb),
            Some(s) if s == dst => {
                return mb / self.cluster.workers[dst].spec.ram_bw_mbps.max(1.0);
            }
            Some(s) => topology::worker_transfer_s(
                &self.cluster,
                s,
                dst,
                &self.channels[s],
                ch_dst,
                mb,
            ),
        };
        let disk_dst = self.cluster.workers[dst].spec.disk_bw_mbps;
        let disk_src = src.map(|s| self.cluster.workers[s].spec.disk_bw_mbps).unwrap_or(f64::MAX);
        let disk_s = mb / disk_dst.min(disk_src);
        // Clock skew on either endpoint: the broker reconciles timestamps
        // before trusting the transfer window (same-node moves above never
        // cross a clock boundary and stay skew-free).
        let skew_s = self.clock_skew_s[dst]
            + src.map(|s| self.clock_skew_s[s]).unwrap_or(0.0);
        net_s.max(disk_s) + skew_s
    }

    /// Simulate one full interval; the placement must already be applied.
    pub fn step_interval(&mut self) -> IntervalReport {
        self.apply_churn();
        let n = self.cluster.len();
        self.busy_s.iter_mut().for_each(|b| *b = 0.0);
        self.xfer_s.iter_mut().for_each(|b| *b = 0.0);
        let dt = self.cfg.interval_seconds / self.cfg.sub_steps as f64;
        let mut completed = Vec::new();

        for _ in 0..self.cfg.sub_steps {
            self.sub_step(dt);
            self.collect_completions(&mut completed);
        }

        // energy over the interval from busy time per worker
        let mut energy_wh = 0.0;
        let mut utils = Vec::with_capacity(n);
        for (w, worker) in self.cluster.workers.iter().enumerate() {
            let util = (self.busy_s[w] / self.cfg.interval_seconds).clamp(0.0, 1.0);
            utils.push(util);
            energy_wh += energy::energy_wh(&worker.spec, util, self.cfg.interval_seconds);
        }
        let specs: Vec<&crate::cluster::node::NodeType> =
            self.cluster.workers.iter().map(|w| &w.spec).collect();
        let aec = energy::normalized_aec(&specs, &utils, self.cfg.interval_seconds);

        // snapshots
        let resident = self.resident_ram();
        let mut counts = vec![0usize; n];
        for c in &self.containers {
            if c.is_active() {
                if let Some(w) = c.worker {
                    counts[w] += 1;
                }
            }
        }
        let snapshots = (0..n)
            .map(|w| WorkerSnapshot {
                cpu: utils[w],
                ram: resident[w] / self.cluster.workers[w].spec.ram_mb,
                net: (self.xfer_s[w] / self.cfg.interval_seconds).min(1.0),
                disk: (self.xfer_s[w] / self.cfg.interval_seconds).min(1.0),
                containers: counts[w],
            })
            .collect();

        let queued = self
            .containers
            .iter()
            .filter(|c| matches!(c.state, ContainerState::Queued))
            .count();

        let report = IntervalReport {
            interval: self.interval,
            completed,
            failed: std::mem::take(&mut self.pending_failed),
            energy_wh,
            aec,
            snapshots,
            queued,
            offline: self.online.iter().filter(|&&o| !o).count(),
        };

        self.interval += 1;
        // advance mobility for the next interval; blackout overrides win
        self.channels = self.mobility.step();
        for (w, ov) in self.channel_override.iter().enumerate() {
            if let Some(ch) = ov {
                self.channels[w] = *ch;
            }
        }
        report
    }

    fn sub_step(&mut self, dt: f64) {
        let t_end = self.now_s + dt;

        // 1. transfers & migrations that finish within this sub-step
        for i in 0..self.containers.len() {
            match self.containers[i].state {
                ContainerState::Transferring { until_s } => {
                    let c = &mut self.containers[i];
                    let spent = (until_s.min(t_end) - self.now_s).max(0.0).min(dt);
                    c.t_transfer += spent;
                    if let Some(w) = c.worker {
                        self.xfer_s[w] += spent;
                    }
                    if until_s <= t_end {
                        c.state = ContainerState::Running;
                    }
                }
                ContainerState::Migrating { until_s, to } => {
                    let c = &mut self.containers[i];
                    let spent = (until_s.min(t_end) - self.now_s).max(0.0).min(dt);
                    c.t_migrate += spent;
                    self.xfer_s[to] += spent;
                    if until_s <= t_end {
                        c.worker = Some(to);
                        c.state = ContainerState::Running;
                    }
                }
                ContainerState::Queued => {
                    self.containers[i].t_wait += dt;
                }
                _ => {}
            }
        }

        // 2. fair-share CPU with RAM-pressure slowdown
        let n = self.cluster.len();
        let mut running: Vec<Vec<ContainerId>> = vec![Vec::new(); n];
        let mut resident = vec![0.0f64; n];
        for c in &self.containers {
            if let (ContainerState::Running, Some(w)) = (&c.state, c.worker) {
                running[w].push(c.id);
                resident[w] += c.ram_mb;
            }
        }
        for w in 0..n {
            if running[w].is_empty() {
                continue;
            }
            let spec = &self.cluster.workers[w].spec;
            // Straggler injection scales the whole node's throughput.
            let mips = spec.mips * self.mips_factor[w];
            // Per-container rate is capped at two cores' worth: every
            // Table-3 node has the same per-core speed ("Intel i3 2.4 GHz
            // cores" for all types), so a bigger node hosts more
            // containers rather than running one container faster. This
            // keeps layer response times tight (paper: 9.92±0.91).
            let per_core = mips / spec.cores as f64;
            let share = (mips / running[w].len() as f64).min(per_core * 2.0);
            let ram_cap = self.effective_ram_mb(w);
            let thrash = if resident[w] > ram_cap {
                (ram_cap / resident[w]).max(THRASH_FLOOR)
            } else {
                1.0
            };
            let used: f64 = share * running[w].len() as f64;
            self.busy_s[w] += dt * (used / mips).min(1.0);
            for &cid in &running[w] {
                let c = &mut self.containers[cid];
                c.mi_done += share * thrash * dt;
                c.t_exec += dt;
                if c.mi_done >= c.mi_total {
                    c.state = ContainerState::Done { at_s: t_end };
                }
            }
        }

        // 3. unblock chain successors of containers that just finished.
        //    Pre-placed successors (worker reserved at placement time)
        //    start their input transfer immediately; unreserved ones fall
        //    back to the wait queue for the next placement round.
        for i in 0..self.containers.len() {
            if let ContainerState::Blocked = self.containers[i].state {
                if let Some(prev) = self.containers[i].prev {
                    if self.containers[prev].is_done() {
                        let src = self.containers[prev].worker;
                        let dst = self.containers[i].worker;
                        match dst {
                            Some(w) => {
                                let mb = self.containers[i].input_mb;
                                let t = self.payload_transfer_s(src, w, mb);
                                let c = &mut self.containers[i];
                                c.input_src = src;
                                c.state =
                                    ContainerState::Transferring { until_s: t_end + t };
                            }
                            None => {
                                let c = &mut self.containers[i];
                                c.input_src = src;
                                c.state = ContainerState::Queued;
                            }
                        }
                    }
                }
            }
        }

        self.now_s = t_end;
    }

    fn collect_completions(&mut self, out: &mut Vec<CompletedTask>) {
        let ids: Vec<u64> = self
            .tasks
            .iter()
            .filter(|(_, e)| !e.done && e.containers.iter().all(|&c| self.containers[c].is_done()))
            .map(|(id, _)| *id)
            .collect();
        for id in ids {
            let e = self.tasks.get_mut(&id).unwrap();
            e.done = true;
            let task = e.task.clone();
            let cids = e.containers.clone();
            let isec = self.cfg.interval_seconds;
            let done_at = cids
                .iter()
                .map(|&c| match self.containers[c].state {
                    ContainerState::Done { at_s } => at_s,
                    _ => unreachable!(),
                })
                .fold(0.0f64, f64::max);
            // final result hop back to the broker
            let last = &self.containers[*cids.last().unwrap()];
            let result_s = self
                .payload_transfer_s(last.worker, last.worker.unwrap_or(0), 0.0)
                .max(0.05);
            let mut workers: Vec<usize> = cids
                .iter()
                .filter_map(|&c| self.containers[c].worker)
                .collect();
            workers.sort_unstable();
            workers.dedup();
            let sum = |f: fn(&Container) -> f64| -> f64 {
                cids.iter().map(|&c| f(&self.containers[c])).sum::<f64>()
            };
            out.push(CompletedTask {
                task_id: id,
                app: task.app,
                decision: task.decision.unwrap(),
                batch: task.batch,
                sla: task.sla,
                response: (done_at + result_s - task.arrival_s) / isec,
                wait: sum(|c| c.t_wait) / isec,
                exec: sum(|c| c.t_exec) / isec,
                transfer: sum(|c| c.t_transfer) / isec,
                migrate: sum(|c| c.t_migrate) / isec,
                workers,
                accuracy: f64::NAN,
            });
        }
    }

    /// Drop completed tasks/containers older than the horizon to bound
    /// memory in long runs. Keeps ids stable by tombstoning.
    pub fn active_task_count(&self) -> usize {
        self.tasks.values().filter(|e| !e.done).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::node::build_fleet;
    use crate::config::{ClusterConfig, SimConfig};
    use crate::splits::App;

    fn engine() -> Engine {
        let cluster = build_fleet(&ClusterConfig::small());
        Engine::new(cluster, SimConfig { intervals: 10, ..Default::default() }, 1)
    }

    fn task(id: u64, app: App, batch: u64) -> Task {
        Task { id, app, batch, sla: 5.0, arrival_s: 0.0, decision: None }
    }

    #[test]
    fn admit_layer_creates_chain() {
        let mut e = engine();
        e.admit(task(1, App::Mnist, 32_000), SplitDecision::Layer);
        assert_eq!(e.containers.len(), 3);
        assert_eq!(e.containers[0].state, ContainerState::Queued);
        assert_eq!(e.containers[1].state, ContainerState::Blocked);
        assert_eq!(e.containers[1].prev, Some(0));
        // the whole chain is placeable up-front (paper: P_t covers C_t)
        assert_eq!(e.placeable(), vec![0, 1, 2]);
    }

    #[test]
    fn admit_semantic_all_queued() {
        let mut e = engine();
        e.admit(task(1, App::Cifar100, 32_000), SplitDecision::Semantic);
        assert_eq!(e.containers.len(), 4);
        assert!(e.containers.iter().all(|c| c.state == ContainerState::Queued));
        assert_eq!(e.placeable().len(), 4);
    }

    #[test]
    fn layer_task_completes_through_chain() {
        let mut e = engine();
        e.admit(task(1, App::Mnist, 16_000), SplitDecision::Layer);
        let mut done = Vec::new();
        for i in 0..40 {
            // place any queued container on worker (i % n) — dumb but legal
            let assigns: Vec<(ContainerId, usize)> = e
                .placeable()
                .into_iter()
                .filter(|&c| matches!(e.containers[c].state, ContainerState::Queued))
                .map(|c| (c, (c + i) % e.workers()))
                .collect();
            e.apply_placement(&assigns);
            let r = e.step_interval();
            done.extend(r.completed);
            if !done.is_empty() {
                break;
            }
        }
        assert_eq!(done.len(), 1, "layer task must eventually complete");
        let t = &done[0];
        assert!(t.response > 0.0);
        assert!(t.exec > 0.0);
        assert!(!t.workers.is_empty());
    }

    #[test]
    fn semantic_completes_faster_than_layer() {
        let run = |decision: SplitDecision| -> f64 {
            let mut e = engine();
            e.admit(task(1, App::FashionMnist, 40_000), decision);
            for _ in 0..60 {
                let assigns: Vec<(ContainerId, usize)> = e
                    .placeable()
                    .into_iter()
                    .filter(|&c| matches!(e.containers[c].state, ContainerState::Queued))
                    .enumerate()
                    .map(|(i, c)| (c, i % e.workers()))
                    .collect();
                e.apply_placement(&assigns);
                let r = e.step_interval();
                if let Some(t) = r.completed.first() {
                    return t.response;
                }
            }
            // A starved task is a recoverable failed outcome, not a panic:
            // abandon it and surface the failure through the report.
            assert!(e.fail_task(1), "starved task must still be active");
            let r = e.step_interval();
            assert_eq!(r.failed.len(), 1, "{decision:?} starved without a failure report");
            f64::INFINITY
        };
        let layer = run(SplitDecision::Layer);
        let semantic = run(SplitDecision::Semantic);
        // both must actually complete — an INFINITY sentinel would make
        // the ordering assertion below pass vacuously
        assert!(layer.is_finite(), "layer starved instead of completing");
        assert!(semantic.is_finite(), "semantic starved instead of completing");
        assert!(
            semantic < layer,
            "semantic ({semantic}) must beat layer ({layer})"
        );
    }

    #[test]
    fn infeasible_placement_skipped() {
        let mut e = engine();
        // a cifar full container demands huge RAM at max batch
        e.admit(task(1, App::Cifar100, 64_000), SplitDecision::Full);
        let c = &e.containers[0];
        assert!(c.ram_mb > 1000.0);
        // worker 0 is a B2ms with ~4.3 GB; overcommit 2x allows < 8.6 GB
        let ram = c.ram_mb;
        let applied = e.apply_placement(&[(0, 0)]);
        if ram <= e.cluster.workers[0].spec.ram_mb * RAM_OVERCOMMIT {
            assert_eq!(applied.len(), 1);
        } else {
            assert!(applied.is_empty());
        }
    }

    #[test]
    fn ram_pressure_slows_execution() {
        let mk = |n_tasks: u64| -> f64 {
            let mut e = engine();
            for i in 0..n_tasks {
                e.admit(task(i, App::Cifar100, 64_000), SplitDecision::Compressed);
            }
            // all on worker 0
            let assigns: Vec<(ContainerId, usize)> =
                e.placeable().into_iter().map(|c| (c, 0)).collect();
            e.apply_placement(&assigns);
            let r = e.step_interval();
            // MI progress of container 0 after one interval
            let _ = r;
            e.containers[0].mi_done
        };
        let solo = mk(1);
        let crowded = mk(4);
        // 4 containers: fair share alone gives 1/4; pressure must push
        // total progress per container below the pure fair share.
        assert!(crowded < solo / 4.0 + 1e-6, "solo={solo} crowded={crowded}");
    }

    #[test]
    fn migration_pauses_progress() {
        let mut e = engine();
        e.admit(task(1, App::Mnist, 64_000), SplitDecision::Compressed);
        e.apply_placement(&[(0, 0)]);
        e.step_interval();
        let before = e.containers[0].mi_done;
        assert!(before > 0.0);
        assert_eq!(e.containers[0].state, ContainerState::Running);
        // migrate to worker 5
        e.apply_placement(&[(0, 5)]);
        assert!(matches!(e.containers[0].state, ContainerState::Migrating { .. }));
        e.step_interval();
        let c = &e.containers[0];
        assert!(c.t_migrate > 0.0, "migration time must be recorded");
        if let ContainerState::Running = c.state {
            assert_eq!(c.worker, Some(5));
        }
    }

    #[test]
    fn wait_time_accumulates_when_unplaced() {
        let mut e = engine();
        e.admit(task(1, App::Mnist, 16_000), SplitDecision::Semantic);
        e.step_interval(); // never placed
        assert!(e.containers[0].t_wait > 0.0);
        let r = e.step_interval();
        assert_eq!(r.queued, 2);
    }

    #[test]
    fn energy_reflects_busy_workers() {
        let mut e = engine();
        let idle = e.step_interval().energy_wh;
        e.admit(task(1, App::Cifar100, 64_000), SplitDecision::Layer);
        let assigns: Vec<(ContainerId, usize)> =
            e.placeable().into_iter().map(|c| (c, 0)).collect();
        e.apply_placement(&assigns);
        let busy = e.step_interval().energy_wh;
        assert!(busy > idle, "busy={busy} idle={idle}");
    }

    #[test]
    fn worker_failure_checkpoints_and_requeues() {
        let mut e = engine();
        e.admit(task(1, App::Mnist, 32_000), SplitDecision::Compressed);
        e.apply_placement(&[(0, 2)]);
        e.step_interval();
        let progress = e.containers[0].mi_done;
        assert!(progress > 0.0);
        assert_eq!(e.containers[0].state, ContainerState::Running);
        // worker 2 fails
        e.set_online(2, false);
        let c = &e.containers[0];
        assert_eq!(c.state, ContainerState::Queued, "container must requeue");
        assert_eq!(c.worker, None);
        assert!((c.mi_done - progress).abs() < 1e-9, "checkpoint keeps progress");
        // failed worker rejects placements
        assert!(!e.fits(0, 2));
        // replace elsewhere and finish
        e.apply_placement(&[(0, 3)]);
        let mut done = false;
        for _ in 0..20 {
            if !e.step_interval().completed.is_empty() {
                done = true;
                break;
            }
        }
        assert!(done, "task must complete after failover");
    }

    #[test]
    fn churn_toggles_mobile_workers_only() {
        let mut e = engine();
        e.set_churn(0.9);
        let mut saw_offline = false;
        for _ in 0..10 {
            let r = e.step_interval();
            saw_offline |= r.offline > 0;
            for (w, up) in e.online().iter().enumerate() {
                if !e.cluster.workers[w].mobile {
                    assert!(up, "static workers never churn");
                }
            }
            assert!(e.online().iter().any(|&o| o), "at least one worker stays up");
        }
        if e.cluster.workers.iter().any(|w| w.mobile) {
            assert!(saw_offline, "high churn must take someone offline");
        }
    }

    #[test]
    fn blocked_reservation_cleared_on_failure() {
        let mut e = engine();
        e.admit(task(1, App::Mnist, 16_000), SplitDecision::Layer);
        // pre-place the whole chain on worker 4
        e.apply_placement(&[(0, 4), (1, 4), (2, 4)]);
        assert_eq!(e.containers[1].worker, Some(4));
        e.set_online(4, false);
        assert_eq!(e.containers[1].worker, None, "reservation must clear");
        assert_eq!(e.containers[0].state, ContainerState::Queued);
    }

    #[test]
    fn fail_task_reports_failed_outcome() {
        let mut e = engine();
        e.admit(task(1, App::Mnist, 32_000), SplitDecision::Layer);
        e.apply_placement(&[(0, 0)]);
        e.step_interval();
        assert!(e.fail_task(1), "active task fails");
        assert!(!e.fail_task(1), "double-fail is a no-op");
        assert!(!e.fail_task(99), "unknown task ignored");
        let r = e.step_interval();
        assert_eq!(r.failed.len(), 1);
        assert_eq!(r.failed[0].task_id, 1);
        assert_eq!(r.failed[0].decision, SplitDecision::Layer);
        assert!(r.failed[0].age > 0.0);
        // containers are terminal and hold no resources
        for c in &e.containers {
            assert_eq!(c.state, ContainerState::Failed);
            assert_eq!(c.worker, None);
        }
        assert_eq!(e.failed_task_count(), 1);
        assert_eq!(e.completed_task_count(), 0);
        assert_eq!(e.active_task_count(), 0);
        // a later report does not re-announce the failure
        assert!(e.step_interval().failed.is_empty());
    }

    #[test]
    fn fail_tasks_older_than_is_a_starvation_guard() {
        let mut e = engine();
        e.admit(task(1, App::Mnist, 32_000), SplitDecision::Compressed);
        for _ in 0..3 {
            e.step_interval(); // never placed: starves
        }
        assert_eq!(e.fail_tasks_older_than(2.0 * 300.0), 1);
        assert_eq!(e.fail_tasks_older_than(2.0 * 300.0), 0, "only once");
        assert_eq!(e.step_interval().failed.len(), 1);
    }

    #[test]
    fn crash_drops_progress_and_requeues() {
        let mut e = engine();
        e.admit(task(1, App::Mnist, 32_000), SplitDecision::Compressed);
        e.apply_placement(&[(0, 2)]);
        e.step_interval();
        assert!(e.containers[0].mi_done > 0.0);
        e.crash_worker(2);
        let c = &e.containers[0];
        assert_eq!(c.state, ContainerState::Queued);
        assert_eq!(c.worker, None);
        assert_eq!(c.mi_done, 0.0, "hard crash loses progress");
        assert!(!e.fits(0, 2));
        e.recover_worker(2);
        assert!(e.fits(0, 2));
        // crashing an already-offline worker is a no-op
        e.crash_worker(2);
        e.set_online(2, false);
        e.crash_worker(2);
    }

    #[test]
    fn straggler_slows_progress() {
        let progress = |factor: f64| -> f64 {
            let mut e = engine();
            e.admit(task(1, App::Mnist, 64_000), SplitDecision::Compressed);
            e.set_mips_factor(0, factor);
            e.apply_placement(&[(0, 0)]);
            e.step_interval();
            e.containers[0].mi_done
        };
        let full = progress(1.0);
        let slow = progress(0.25);
        assert!(slow < 0.5 * full, "full={full} slow={slow}");
    }

    #[test]
    fn ram_squeeze_restricts_allocation_and_thrashes() {
        let mut e = engine();
        e.admit(task(1, App::Cifar100, 64_000), SplitDecision::Compressed);
        let ram = e.containers[0].ram_mb;
        // squeeze worker 0 so the container no longer fits
        let factor = ram / (e.cluster.workers[0].spec.ram_mb * RAM_OVERCOMMIT) * 0.5;
        e.set_ram_factor(0, factor);
        assert!(!e.fits(0, 0), "squeezed worker must reject the container");
        e.set_ram_factor(0, 1.0);
        assert!(e.fits(0, 0));
    }

    #[test]
    fn channel_override_floors_transfers() {
        use crate::cluster::mobility::ChannelState;
        let stage_time = |blackout: bool| -> f64 {
            let mut e = engine();
            e.admit(task(1, App::Cifar100, 64_000), SplitDecision::Compressed);
            if blackout {
                e.set_channel_override(0, Some(ChannelState::BLACKOUT));
            }
            e.apply_placement(&[(0, 0)]);
            match e.containers[0].state {
                ContainerState::Transferring { until_s } => until_s,
                _ => 0.0,
            }
        };
        let normal = stage_time(false);
        let blackout = stage_time(true);
        assert!(blackout > normal, "blackout={blackout} normal={normal}");
        // override persists across intervals until cleared
        let mut e = engine();
        e.set_channel_override(0, Some(ChannelState::BLACKOUT));
        e.step_interval();
        assert_eq!(e.channels[0], ChannelState::BLACKOUT);
        e.set_channel_override(0, None);
        e.step_interval();
        assert_ne!(e.channels[0], ChannelState::BLACKOUT);
    }

    #[test]
    fn clock_skew_delays_transfers_by_the_offset() {
        let stage_until = |skew: f64| -> f64 {
            let mut e = engine();
            e.admit(task(1, App::Cifar100, 64_000), SplitDecision::Compressed);
            e.set_clock_skew(0, skew);
            e.apply_placement(&[(0, 0)]);
            match e.containers[0].state {
                ContainerState::Transferring { until_s } => until_s,
                other => panic!("expected staging transfer, got {other:?}"),
            }
        };
        let normal = stage_until(0.0);
        let skewed = stage_until(45.0);
        assert!(
            (skewed - normal - 45.0).abs() < 1e-6,
            "skew must add exactly its offset: normal={normal} skewed={skewed}"
        );
        let mut e = engine();
        e.set_clock_skew(3, 1e9);
        assert_eq!(e.clock_skew(3), 600.0, "skew clamps to the NTP-grade cap");
        e.set_clock_skew(3, 0.0);
        assert_eq!(e.clock_skew(3), 0.0);
        assert_eq!(e.clock_skew(99), 0.0, "out-of-range worker reads as unskewed");
    }

    #[test]
    fn interval_counter_and_mobility_advance() {
        let mut e = engine();
        let ch0 = e.channels.clone();
        e.step_interval();
        e.step_interval();
        assert_eq!(e.interval, 2);
        assert!((e.now_s - 600.0).abs() < 1e-9);
        // with mobile workers in the small fleet the channel should change
        if e.cluster.workers.iter().any(|w| w.mobile) {
            assert_ne!(ch0, e.channels);
        }
    }
}
