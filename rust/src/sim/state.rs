//! Engine state: the struct itself, construction, and read-only views.
//!
//! The discrete-interval engine is split along its seams (one file per
//! concern, all `impl Engine` blocks on the same struct):
//!
//! * [`state`](self) — fields, constructor, accessors, report types;
//! * [`super::lifecycle`] — admission, placement, interval integration,
//!   completion/failure bookkeeping;
//! * [`super::faults`] — the typed [`super::faults::EngineCmd`] command
//!   bus (the ONLY mutation path for availability/degradation state) and
//!   its per-interval ledger;
//! * [`super::network`] — payload-movement cost model and channel
//!   refresh.

// BTreeMap, not HashMap: task iteration order feeds order-sensitive
// consumers (the MAB response-time EMA, Gillis RL updates), and std's
// HashMap order varies per process — which would break the chaos engine's
// bit-identical replay guarantee.
use std::collections::BTreeMap;

use crate::cluster::mobility::{ChannelState, MobilityModel};
use crate::cluster::node::Cluster;
use crate::config::SimConfig;
use crate::splits::SplitDecision;
use crate::workload::Task;

use super::container::{Container, ContainerId, ContainerState};
use super::faults::CmdRecord;

/// Allowed RAM overcommit at allocation time (swap headroom): a worker
/// accepts a container while resident demand stays under this × RAM.
pub const RAM_OVERCOMMIT: f64 = 2.0;
/// Thrash floor: heaviest slowdown from memory pressure.
pub(super) const THRASH_FLOOR: f64 = 0.2;

/// A task that left the system this interval (paper E_t member).
#[derive(Clone, Debug)]
pub struct CompletedTask {
    pub task_id: u64,
    pub app: crate::splits::App,
    pub decision: SplitDecision,
    pub batch: u64,
    pub sla: f64,
    /// Response time in scheduling intervals (paper r_i).
    pub response: f64,
    pub wait: f64,
    pub exec: f64,
    pub transfer: f64,
    pub migrate: f64,
    /// Workers that hosted at least one fragment.
    pub workers: Vec<usize>,
    /// Filled by the coordinator (accuracy oracle), not the engine.
    pub accuracy: f64,
}

/// A task that was abandoned (timeout or unrecoverable fault) rather than
/// completed. Failed tasks leave the system like completions do, so the
/// broker's bookkeeping stays conserved under fault injection.
#[derive(Clone, Debug)]
pub struct FailedTask {
    pub task_id: u64,
    pub app: crate::splits::App,
    pub decision: SplitDecision,
    pub batch: u64,
    pub sla: f64,
    /// Age at failure, in scheduling intervals.
    pub age: f64,
}

/// Per-worker observability snapshot (feeds S_t featurization).
#[derive(Clone, Debug, Default)]
pub struct WorkerSnapshot {
    /// Fraction of the interval the CPU was busy.
    pub cpu: f64,
    /// Resident demand / RAM at interval end (can exceed 1 under pressure).
    pub ram: f64,
    /// Transfer seconds that touched this worker / interval length.
    pub net: f64,
    /// Same, for disk-bound payload movement.
    pub disk: f64,
    /// Number of resident containers at interval end.
    pub containers: usize,
}

/// What happened during one simulated interval.
#[derive(Clone, Debug)]
pub struct IntervalReport {
    pub interval: usize,
    pub completed: Vec<CompletedTask>,
    /// Tasks abandoned this interval (see [`Engine::fail_task`]).
    pub failed: Vec<FailedTask>,
    pub energy_wh: f64,
    /// Normalized AEC ∈ [0,1] (for eq. 10).
    pub aec: f64,
    pub snapshots: Vec<WorkerSnapshot>,
    /// Containers still waiting (unplaceable) at interval end.
    pub queued: usize,
    /// Workers offline this interval (churn).
    pub offline: usize,
}

pub struct Engine {
    pub cluster: Cluster,
    pub(super) mobility: MobilityModel,
    pub channels: Vec<ChannelState>,
    pub(super) cfg: SimConfig,
    pub containers: Vec<Container>,
    pub(super) tasks: BTreeMap<u64, TaskEntry>,
    pub now_s: f64,
    pub interval: usize,
    /// Worker availability under churn (paper §7 future work); all online
    /// by default.
    pub(super) online: Vec<bool>,
    pub(super) churn_rate: f64,
    pub(super) churn_rng: crate::util::rng::Rng,
    /// Per-worker MIPS degradation factor ∈ (0, 1] (straggler injection).
    pub(super) mips_factor: Vec<f64>,
    /// Per-worker effective-RAM factor ∈ (0, 1] (RAM-squeeze injection).
    pub(super) ram_factor: Vec<f64>,
    /// Per-worker forced channel state (network blackout injection);
    /// overlays the mobility model while set.
    pub(super) channel_override: Vec<Option<ChannelState>>,
    /// Per-worker clock-skew seconds (clock-skew injection): coordination
    /// with a skewed worker pays this extra latency on every payload
    /// movement that touches it (the broker must reconcile timestamps
    /// before trusting a transfer window). 0 = clocks agree.
    pub(super) clock_skew_s: Vec<f64>,
    /// Tasks failed since the last interval report.
    pub(super) pending_failed: Vec<FailedTask>,
    /// Append-only record of every [`super::faults::EngineCmd`] applied,
    /// stamped with the interval it landed in. Chaos oracles audit this
    /// instead of re-deriving state.
    pub(super) cmd_ledger: Vec<CmdRecord>,
    // scratch: per-worker busy seconds within the current interval
    pub(super) busy_s: Vec<f64>,
    pub(super) xfer_s: Vec<f64>,
}

#[derive(Clone, Debug)]
pub(super) struct TaskEntry {
    pub(super) task: Task,
    pub(super) containers: Vec<ContainerId>,
    pub(super) done: bool,
    pub(super) failed: bool,
}

impl Engine {
    pub fn new(cluster: Cluster, cfg: SimConfig, seed: u64) -> Self {
        let flags: Vec<bool> = cluster.workers.iter().map(|w| w.mobile).collect();
        let n = cluster.len();
        let mut mobility = MobilityModel::new(&flags, seed);
        let channels = mobility.step();
        Engine {
            cluster,
            mobility,
            channels,
            cfg,
            containers: Vec::new(),
            tasks: BTreeMap::new(),
            now_s: 0.0,
            interval: 0,
            online: vec![true; n],
            churn_rate: 0.0,
            churn_rng: crate::util::rng::Rng::new(seed ^ 0xC0FFEE),
            mips_factor: vec![1.0; n],
            ram_factor: vec![1.0; n],
            channel_override: vec![None; n],
            clock_skew_s: vec![0.0; n],
            pending_failed: Vec::new(),
            cmd_ledger: Vec::new(),
            busy_s: vec![0.0; n],
            xfer_s: vec![0.0; n],
        }
    }

    pub fn interval_seconds(&self) -> f64 {
        self.cfg.interval_seconds
    }

    pub fn workers(&self) -> usize {
        self.cluster.len()
    }

    pub fn task(&self, id: u64) -> Option<&Task> {
        self.tasks.get(&id).map(|e| &e.task)
    }

    /// Has `id` been abandoned via [`Engine::fail_task`]? Unknown tasks
    /// read as not-failed.
    pub fn task_failed(&self, id: u64) -> bool {
        self.tasks.get(&id).map(|e| e.failed).unwrap_or(false)
    }

    /// Containers the placement engine must consider (placeable states).
    pub fn placeable(&self) -> Vec<ContainerId> {
        self.containers
            .iter()
            .filter(|c| c.is_placeable())
            .map(|c| c.id)
            .collect()
    }

    /// Resident RAM demand per worker: running/transferring/migrating-in
    /// containers plus Blocked chain successors holding a reservation —
    /// a reservation consumes capacity so the later unblock (which starts
    /// its transfer unconditionally) can never breach the overcommit cap.
    pub fn resident_ram(&self) -> Vec<f64> {
        let mut ram = vec![0.0; self.cluster.len()];
        for c in &self.containers {
            match c.state {
                ContainerState::Running
                | ContainerState::Transferring { .. }
                | ContainerState::Blocked => {
                    if let Some(w) = c.worker {
                        ram[w] += c.ram_mb;
                    }
                }
                ContainerState::Migrating { to, .. } => ram[to] += c.ram_mb,
                _ => {}
            }
        }
        ram
    }

    /// Worker availability (false = offline under churn).
    pub fn online(&self) -> &[bool] {
        &self.online
    }

    /// Currently applied clock skew of worker `w`, in seconds.
    pub fn clock_skew(&self, w: usize) -> f64 {
        self.clock_skew_s.get(w).copied().unwrap_or(0.0)
    }

    /// Effective RAM capacity of worker `w` under any active squeeze.
    pub fn effective_ram_mb(&self, w: usize) -> f64 {
        self.cluster.workers[w].spec.ram_mb * self.ram_factor[w]
    }

    /// Tasks ever admitted.
    pub fn admitted_task_count(&self) -> usize {
        self.tasks.len()
    }

    /// Tasks that completed successfully.
    pub fn completed_task_count(&self) -> usize {
        self.tasks.values().filter(|e| e.done && !e.failed).count()
    }

    /// Tasks that were abandoned via [`Engine::fail_task`].
    pub fn failed_task_count(&self) -> usize {
        self.tasks.values().filter(|e| e.failed).count()
    }

    /// Tasks still in flight.
    pub fn active_task_count(&self) -> usize {
        self.tasks.values().filter(|e| !e.done).count()
    }

    /// Can `cid` be (re)placed on worker `w` right now?
    pub fn fits(&self, cid: ContainerId, w: usize) -> bool {
        if !self.online[w] {
            return false;
        }
        let c = &self.containers[cid];
        if c.worker == Some(w) {
            return true;
        }
        let resident = self.resident_ram();
        resident[w] + c.ram_mb <= self.effective_ram_mb(w) * RAM_OVERCOMMIT
    }
}
