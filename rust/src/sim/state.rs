//! Engine state: the struct itself, construction, and read-only views.
//!
//! The discrete-interval engine is split along its seams (one file per
//! concern, all `impl Engine` blocks on the same struct):
//!
//! * [`state`](self) — fields, constructor, accessors, report types;
//! * [`super::lifecycle`] — admission, placement, interval integration,
//!   completion/failure bookkeeping;
//! * [`super::faults`] — the typed [`super::faults::EngineCmd`] command
//!   bus (the ONLY mutation path for availability/degradation state) and
//!   its per-interval ledger;
//! * [`super::network`] — payload-movement cost model and channel
//!   refresh.

// BTreeMap, not HashMap: task iteration order feeds order-sensitive
// consumers (the MAB response-time EMA, Gillis RL updates), and std's
// HashMap order varies per process — which would break the chaos engine's
// bit-identical replay guarantee.
use std::collections::{BTreeMap, BTreeSet};

use crate::cluster::mobility::{ChannelState, MobilityModel};
use crate::cluster::node::Cluster;
use crate::config::SimConfig;
use crate::splits::SplitDecision;
use crate::workload::Task;

use super::container::{Container, ContainerId, ContainerState};
use super::faults::{CmdOrigin, CmdRecord};

/// Allowed RAM overcommit at allocation time (swap headroom): a worker
/// accepts a container while resident demand stays under this × RAM.
pub const RAM_OVERCOMMIT: f64 = 2.0;
/// Thrash floor: heaviest slowdown from memory pressure.
pub(super) const THRASH_FLOOR: f64 = 0.2;

/// A task that left the system this interval (paper E_t member).
#[derive(Clone, Debug)]
pub struct CompletedTask {
    pub task_id: u64,
    pub app: crate::splits::App,
    pub decision: SplitDecision,
    pub batch: u64,
    pub sla: f64,
    /// Response time in scheduling intervals (paper r_i).
    pub response: f64,
    pub wait: f64,
    pub exec: f64,
    pub transfer: f64,
    pub migrate: f64,
    /// Workers that hosted at least one fragment.
    pub workers: Vec<usize>,
    /// Filled by the coordinator (accuracy oracle), not the engine.
    pub accuracy: f64,
}

/// A task that was abandoned (timeout or unrecoverable fault) rather than
/// completed. Failed tasks leave the system like completions do, so the
/// broker's bookkeeping stays conserved under fault injection.
#[derive(Clone, Debug)]
pub struct FailedTask {
    pub task_id: u64,
    pub app: crate::splits::App,
    pub decision: SplitDecision,
    pub batch: u64,
    pub sla: f64,
    /// Age at failure, in scheduling intervals.
    pub age: f64,
}

/// Per-worker observability snapshot (feeds S_t featurization).
#[derive(Clone, Debug, Default)]
pub struct WorkerSnapshot {
    /// Fraction of the interval the CPU was busy.
    pub cpu: f64,
    /// Resident demand / RAM at interval end (can exceed 1 under pressure).
    pub ram: f64,
    /// Transfer seconds that touched this worker / interval length.
    pub net: f64,
    /// Same, for disk-bound payload movement.
    pub disk: f64,
    /// Number of resident containers at interval end.
    pub containers: usize,
}

/// One executed mobility handoff, recorded at execution time so the
/// `handoff-preserves-progress` oracle audits what the handoff actually
/// touched instead of re-deriving it. On a correct engine a handoff never
/// changes `mi_done` of any resident — the record pins that.
#[derive(Clone, Debug, PartialEq)]
pub struct HandoffAudit {
    /// Interval the handoff landed in.
    pub interval: usize,
    pub worker: usize,
    pub from_rack: usize,
    /// Destination rack, normalized into `0..RACKS`.
    pub to_rack: usize,
    /// `(container, owning task, MI completed at handoff time)` for every
    /// container resident on the worker when it re-homed, ascending by
    /// container id.
    pub residents: Vec<(ContainerId, u64, f64)>,
}

/// What happened during one simulated interval.
#[derive(Clone, Debug)]
pub struct IntervalReport {
    pub interval: usize,
    pub completed: Vec<CompletedTask>,
    /// Tasks abandoned this interval (see [`Engine::fail_task`]).
    pub failed: Vec<FailedTask>,
    pub energy_wh: f64,
    /// Normalized AEC ∈ [0,1] (for eq. 10).
    pub aec: f64,
    pub snapshots: Vec<WorkerSnapshot>,
    /// Containers still waiting (unplaceable) at interval end.
    pub queued: usize,
    /// Workers offline this interval (churn).
    pub offline: usize,
}

pub struct Engine {
    pub cluster: Cluster,
    pub(super) mobility: MobilityModel,
    pub channels: Vec<ChannelState>,
    pub(super) cfg: SimConfig,
    /// The container pool. `pub(super)` on purpose: index correctness
    /// depends on every state/worker mutation routing through
    /// [`Engine::set_container`], so outside `sim` the pool is readable
    /// only via [`Engine::containers`].
    pub(super) containers: Vec<Container>,
    pub(super) tasks: BTreeMap<u64, TaskEntry>,
    pub now_s: f64,
    pub interval: usize,
    /// Worker availability under churn (paper §7 future work); all online
    /// by default.
    pub(super) online: Vec<bool>,
    pub(super) churn_rate: f64,
    pub(super) churn_rng: crate::util::rng::Rng,
    /// Per-worker MIPS degradation factor ∈ (0, 1] (straggler injection).
    pub(super) mips_factor: Vec<f64>,
    /// Per-worker effective-RAM factor ∈ (0, 1] (RAM-squeeze injection).
    pub(super) ram_factor: Vec<f64>,
    /// Per-worker forced channel state (network blackout injection);
    /// overlays the mobility model while set.
    pub(super) channel_override: Vec<Option<ChannelState>>,
    /// Per-worker clock-skew seconds (clock-skew injection): coordination
    /// with a skewed worker pays this extra latency on every payload
    /// movement that touches it (the broker must reconcile timestamps
    /// before trusting a transfer window). 0 = clocks agree.
    pub(super) clock_skew_s: Vec<f64>,
    /// Tasks failed since the last interval report.
    pub(super) pending_failed: Vec<FailedTask>,
    /// Append-only record of every [`super::faults::EngineCmd`] applied,
    /// stamped with the interval it landed in. Chaos oracles audit this
    /// instead of re-deriving state.
    pub(super) cmd_ledger: Vec<CmdRecord>,
    /// Who owns each worker's *current* offline state (`None` while
    /// online). Maintained by the command bus alongside `online`, so the
    /// autoscaler can tell "offline because I parked it" from "offline
    /// because chaos crashed it" without replaying the ledger.
    pub(super) offline_origin: Vec<Option<CmdOrigin>>,
    // scratch: per-worker busy seconds within the current interval
    pub(super) busy_s: Vec<f64>,
    pub(super) xfer_s: Vec<f64>,
    // ---- indexed active-set core -----------------------------------------
    // The hot path must cost O(in-flight work), not O(everything ever
    // admitted). Every container state/worker mutation goes through
    // `set_container`, which keeps these indexes exact; `verify_indices`
    // cross-checks them against the old full-scan derivations.
    /// Non-terminal containers, ascending by id — the same visit order the
    /// old full pool scan had, so float accumulation (xfer/busy seconds,
    /// resident sums) is bit-identical to the pre-index engine.
    pub(super) active: Vec<ContainerId>,
    /// Per-worker containers currently holding resident RAM there
    /// (Running/Transferring/Blocked at `worker`, Migrating at `to`),
    /// ascending by id for the same summation-order guarantee.
    pub(super) resident_idx: Vec<Vec<ContainerId>>,
    /// State partition of the active set, phase-1 side: containers in a
    /// payload-movement or queue-wait state (Queued ∪ Transferring ∪
    /// Migrating), ascending by id. `sub_step` phase 1 walks this instead
    /// of filtering the whole active list — O(in-transit), same visit
    /// order, byte-identical accumulation. Maintained exclusively by
    /// [`Engine::set_container`] (admission pushes the initial state).
    pub(super) transit: Vec<ContainerId>,
    /// State partition of the active set, phase-3 side: Blocked chain
    /// successors awaiting their predecessor, ascending by id. `sub_step`
    /// phase 3 walks this — O(blocked) — in the active-list filter's
    /// exact order.
    pub(super) blocked: Vec<ContainerId>,
    /// Walk scratch for sub-step phases 1 and 3: the phases mutate the
    /// very index they sweep (a finished transfer leaves `transit`, an
    /// unblocked successor leaves `blocked`), so each phase copies its
    /// index here and iterates the frozen snapshot — which is exactly the
    /// pre-phase membership the old active-list filter visited.
    pub(super) walk_scratch: Vec<ContainerId>,
    /// Per-interval report scratch (utilizations, per-worker container
    /// counts): reused across intervals instead of reallocated.
    pub(super) utils_scratch: Vec<f64>,
    pub(super) counts_scratch: Vec<usize>,
    /// Tasks whose remaining-fragment counter hit zero this sub-step;
    /// drained (in task-id order) by completion collection.
    pub(super) pending_done: Vec<u64>,
    /// Tasks still in flight (not done, not failed), ascending by id —
    /// starvation sweeps walk this instead of the full task map.
    pub(super) active_tasks: BTreeSet<u64>,
    pub(super) n_completed: usize,
    pub(super) n_failed: usize,
    /// Chain-precedence terminal-transition latch, ascending by id:
    /// containers that reached Done/Failed *while their predecessor was
    /// still unfinished* and had already made progress. The indexed
    /// `chain-precedence` oracle sweeps the merge of this set with the
    /// active list, giving it the full pool scan's post-hoc memory of
    /// terminal offenders without ever walking the terminal pool. Entries
    /// whose predecessor later finishes simply stop producing details
    /// (the sweep re-checks predecessor done-ness), exactly like the full
    /// scan — so stale entries are harmless and never pruned. On a
    /// correct engine this stays empty.
    pub(super) chain_suspects: Vec<ContainerId>,
    /// Per-phase wall-clock profiler (`cfg.profile_phases`); inert and
    /// clock-free when disabled. Timing reads never feed back into
    /// simulation state.
    pub(super) phases: crate::util::phase_timer::PhaseTimer,
    /// Persistent CPU-shard lanes (see [`super::pool`]): spawned lazily by
    /// the first sharded sub-step, reused for the rest of the run. `None`
    /// until then and forever on single-shard runs.
    pub(super) pool: Option<super::pool::ShardPool>,
    /// Current topology rack of each worker. Starts at the
    /// contiguous-quarter assignment of
    /// [`crate::chaos::events::rack_members`]; mobility handoffs
    /// ([`super::faults::EngineCmd::Handoff`]) re-home entries.
    pub(super) rack_of: Vec<usize>,
    /// Append-only audit log of executed handoffs (see [`HandoffAudit`]).
    pub(super) handoff_audits: Vec<HandoffAudit>,
    /// Remaining battery (Wh) per worker; `None` = grid-powered fleet
    /// (the inert default — no state, no draws, no crashes). Drained by
    /// the interval energy integration; exhaustion crashes the worker
    /// under [`super::faults::CmdOrigin::Battery`].
    pub(super) battery_wh: Option<Vec<f64>>,
}

#[derive(Clone, Debug)]
pub(super) struct TaskEntry {
    pub(super) task: Task,
    pub(super) containers: Vec<ContainerId>,
    pub(super) done: bool,
    pub(super) failed: bool,
    /// Fragments not yet `Done` — completion detection is O(1) per
    /// terminal transition instead of a task-map scan.
    pub(super) remaining: usize,
}

/// Insert into an id-sorted index (no-op if already present).
pub(super) fn insert_sorted(v: &mut Vec<ContainerId>, cid: ContainerId) {
    if let Err(pos) = v.binary_search(&cid) {
        v.insert(pos, cid);
    }
}

/// Remove from an id-sorted index (no-op if absent). Positional remove —
/// not swap_remove — so the id-sorted invariant (and with it the float
/// summation order) survives without a re-sort.
pub(super) fn remove_sorted(v: &mut Vec<ContainerId>, cid: ContainerId) {
    if let Ok(pos) = v.binary_search(&cid) {
        v.remove(pos);
    }
}

impl Engine {
    pub fn new(cluster: Cluster, cfg: SimConfig, seed: u64) -> Self {
        let flags: Vec<bool> = cluster.workers.iter().map(|w| w.mobile).collect();
        let n = cluster.len();
        let mut mobility = MobilityModel::new(&flags, seed);
        let channels = mobility.step();
        let profile_phases = cfg.profile_phases;
        let rack_of = crate::chaos::events::initial_racks(n);
        let battery_wh = cluster.battery_wh.map(|cap| vec![cap; n]);
        Engine {
            cluster,
            mobility,
            channels,
            cfg,
            containers: Vec::new(),
            tasks: BTreeMap::new(),
            now_s: 0.0,
            interval: 0,
            online: vec![true; n],
            churn_rate: 0.0,
            churn_rng: crate::util::rng::Rng::new(seed ^ 0xC0FFEE),
            mips_factor: vec![1.0; n],
            ram_factor: vec![1.0; n],
            channel_override: vec![None; n],
            clock_skew_s: vec![0.0; n],
            pending_failed: Vec::new(),
            cmd_ledger: Vec::new(),
            offline_origin: vec![None; n],
            busy_s: vec![0.0; n],
            xfer_s: vec![0.0; n],
            active: Vec::new(),
            resident_idx: vec![Vec::new(); n],
            transit: Vec::new(),
            blocked: Vec::new(),
            walk_scratch: Vec::new(),
            utils_scratch: Vec::new(),
            counts_scratch: Vec::new(),
            pending_done: Vec::new(),
            active_tasks: BTreeSet::new(),
            n_completed: 0,
            n_failed: 0,
            chain_suspects: Vec::new(),
            phases: crate::util::phase_timer::PhaseTimer::new(profile_phases),
            pool: None,
            rack_of,
            handoff_audits: Vec::new(),
            battery_wh,
        }
    }

    /// Make sure the persistent CPU-shard pool exists with `lanes` lanes.
    /// The shard count is fixed for a run (it comes from `cfg.shards`), so
    /// the spawn happens exactly once — the whole point of the pool.
    pub(super) fn ensure_pool(&mut self, lanes: usize) {
        let rebuild = self.pool.as_ref().map(|p| p.lanes() != lanes).unwrap_or(true);
        if rebuild {
            self.pool = Some(super::pool::ShardPool::new(lanes));
        }
    }

    /// Where a `(state, worker)` combination holds resident RAM, if
    /// anywhere. Single source of truth for the residency index AND for
    /// [`Engine::resident_ram`].
    pub(super) fn residency(state: &ContainerState, worker: Option<usize>) -> Option<usize> {
        match state {
            ContainerState::Running
            | ContainerState::Transferring { .. }
            | ContainerState::Blocked => worker,
            ContainerState::Migrating { to, .. } => Some(*to),
            _ => None,
        }
    }

    /// Does `state` belong to the phase-1 transit partition? Membership is
    /// a pure function of the state variant, so [`Engine::set_container`]
    /// can maintain the `transit` index with two variant tests — and a
    /// Queued→Transferring or Transferring→Migrating transition is a
    /// membership no-op.
    pub(super) fn in_transit(state: &ContainerState) -> bool {
        matches!(
            state,
            ContainerState::Queued
                | ContainerState::Transferring { .. }
                | ContainerState::Migrating { .. }
        )
    }

    /// The choke point for container state/worker mutation: updates the
    /// container AND the active list, residency index, per-state sub-step
    /// partitions (`transit`/`blocked`), remaining-fragment counter and
    /// completion queue in one place. Everything that mutates
    /// `state`/`worker` must route through here — a direct field write
    /// desynchronizes the indexes (caught by [`Engine::verify_indices`]).
    pub(super) fn set_container(
        &mut self,
        cid: ContainerId,
        state: ContainerState,
        worker: Option<usize>,
    ) {
        let (old_state, old_worker) = {
            let c = &self.containers[cid];
            (c.state, c.worker)
        };
        let old_home = Self::residency(&old_state, old_worker);
        let new_home = Self::residency(&state, worker);
        {
            let c = &mut self.containers[cid];
            c.state = state;
            c.worker = worker;
        }
        if old_home != new_home {
            if let Some(w) = old_home {
                remove_sorted(&mut self.resident_idx[w], cid);
            }
            if let Some(w) = new_home {
                insert_sorted(&mut self.resident_idx[w], cid);
            }
        }
        // Sub-step state partitions: membership depends only on the state
        // variant, so same-partition transitions cost nothing.
        let was_transit = Self::in_transit(&old_state);
        let is_transit = Self::in_transit(&state);
        if was_transit != is_transit {
            if was_transit {
                remove_sorted(&mut self.transit, cid);
            } else {
                insert_sorted(&mut self.transit, cid);
            }
        }
        let was_blocked = matches!(old_state, ContainerState::Blocked);
        let is_blocked = matches!(state, ContainerState::Blocked);
        if was_blocked != is_blocked {
            if was_blocked {
                remove_sorted(&mut self.blocked, cid);
            } else {
                insert_sorted(&mut self.blocked, cid);
            }
        }
        let was_terminal =
            matches!(old_state, ContainerState::Done { .. } | ContainerState::Failed);
        let is_terminal = matches!(state, ContainerState::Done { .. } | ContainerState::Failed);
        debug_assert!(!was_terminal || is_terminal, "terminal containers never revive");
        if !was_terminal && is_terminal {
            remove_sorted(&mut self.active, cid);
            // chain-precedence latch: this container is leaving the active
            // sweep's view forever — if it got ahead of an unfinished
            // predecessor, remember it NOW so the indexed oracle keeps the
            // full scan's post-hoc memory. Predecessor done-ness is
            // monotone (terminal containers never revive), so anything
            // flaggable later is flaggable at this instant.
            {
                let c = &self.containers[cid];
                if let Some(prev) = c.prev {
                    if c.mi_done > 0.0 && !self.containers[prev].is_done() {
                        insert_sorted(&mut self.chain_suspects, cid);
                    }
                }
            }
            if matches!(state, ContainerState::Done { .. }) {
                let tid = self.containers[cid].task_id;
                if let Some(e) = self.tasks.get_mut(&tid) {
                    e.remaining = e.remaining.saturating_sub(1);
                    if e.remaining == 0 && !e.done {
                        self.pending_done.push(tid);
                    }
                }
            }
        }
    }

    /// Recompute every incremental index from a full scan (the pre-index
    /// engine's derivations) and compare. Used by the index-consistency
    /// property tests; any divergence is a bug in [`Engine::set_container`]
    /// routing.
    pub fn verify_indices(&self) -> Result<(), String> {
        let want_active: Vec<ContainerId> =
            self.containers.iter().filter(|c| c.is_active()).map(|c| c.id).collect();
        if want_active != self.active {
            return Err(format!(
                "active list diverged: index has {} entries, full scan {}",
                self.active.len(),
                want_active.len()
            ));
        }
        let want_transit: Vec<ContainerId> = self
            .containers
            .iter()
            .filter(|c| Self::in_transit(&c.state))
            .map(|c| c.id)
            .collect();
        if want_transit != self.transit {
            return Err(format!(
                "transit partition diverged: index has {} entries, full scan {}",
                self.transit.len(),
                want_transit.len()
            ));
        }
        let want_blocked: Vec<ContainerId> = self
            .containers
            .iter()
            .filter(|c| matches!(c.state, ContainerState::Blocked))
            .map(|c| c.id)
            .collect();
        if want_blocked != self.blocked {
            return Err(format!(
                "blocked partition diverged: index has {} entries, full scan {}",
                self.blocked.len(),
                want_blocked.len()
            ));
        }
        let mut want_res: Vec<Vec<ContainerId>> = vec![Vec::new(); self.cluster.len()];
        for c in &self.containers {
            if let Some(w) = Self::residency(&c.state, c.worker) {
                want_res[w].push(c.id);
            }
        }
        if want_res != self.resident_idx {
            let w = (0..want_res.len())
                .find(|&w| want_res[w] != self.resident_idx[w])
                .unwrap();
            return Err(format!(
                "residency index diverged at worker {w}: index {:?}, full scan {:?}",
                self.resident_idx[w], want_res[w]
            ));
        }
        // resident-RAM totals must be BIT-identical to the full-scan
        // derivation, not merely approximately so. Both sides reduce
        // through the order-free accumulator, so the comparison holds
        // regardless of visit order (full pool scan here vs id-sorted
        // residency index there).
        let mut want_ram = vec![crate::util::accum::Accum::ZERO; self.cluster.len()];
        for c in &self.containers {
            if let Some(w) = Self::residency(&c.state, c.worker) {
                want_ram[w].add(c.ram_mb);
            }
        }
        let want_ram: Vec<f64> = want_ram.iter().map(|a| a.value()).collect();
        let got_ram = self.resident_ram();
        for (w, (want, got)) in want_ram.iter().zip(&got_ram).enumerate() {
            if want.to_bits() != got.to_bits() {
                return Err(format!(
                    "resident RAM diverged at worker {w}: index {got}, full scan {want}"
                ));
            }
        }
        for (id, e) in &self.tasks {
            let want =
                e.containers.iter().filter(|&&c| !self.containers[c].is_done()).count();
            if want != e.remaining {
                return Err(format!(
                    "task {id}: remaining counter {} vs full scan {want}",
                    e.remaining
                ));
            }
        }
        let want_completed = self.tasks.values().filter(|e| e.done && !e.failed).count();
        let want_failed = self.tasks.values().filter(|e| e.failed).count();
        if want_completed != self.n_completed || want_failed != self.n_failed {
            return Err(format!(
                "task counters diverged: completed {}/{want_completed}, failed {}/{want_failed}",
                self.n_completed, self.n_failed
            ));
        }
        let want_active_tasks: Vec<u64> =
            self.tasks.iter().filter(|(_, e)| !e.done).map(|(id, _)| *id).collect();
        if want_active_tasks != self.active_tasks.iter().copied().collect::<Vec<u64>>() {
            return Err(format!(
                "active-task set diverged: index holds {}, full scan {}",
                self.active_tasks.len(),
                want_active_tasks.len()
            ));
        }
        if !self.pending_done.is_empty() {
            return Err(format!(
                "pending completions not drained: {:?}",
                self.pending_done
            ));
        }
        // chain-precedence latch: every terminal container the full scan
        // would flag right now (progressed, predecessor still unfinished)
        // must have been latched at its terminal transition; and nothing
        // enters the latch without having been a progressed chain
        // successor that went terminal. Entries whose predecessor later
        // finished legitimately remain (they just stop producing details),
        // so the reverse check does not require the predecessor to still
        // be unfinished.
        for c in &self.containers {
            let terminal = !c.is_active();
            if let Some(prev) = c.prev {
                if terminal && c.mi_done > 0.0 && !self.containers[prev].is_done()
                    && self.chain_suspects.binary_search(&c.id).is_err()
                {
                    return Err(format!(
                        "container {} is a terminal chain offender but was never latched",
                        c.id
                    ));
                }
            }
        }
        for &cid in &self.chain_suspects {
            let c = &self.containers[cid];
            if c.is_active() || c.prev.is_none() || c.mi_done <= 0.0 {
                return Err(format!(
                    "container {cid} sits in the chain-suspect latch but is not a \
                     terminal progressed chain successor"
                ));
            }
        }
        Ok(())
    }

    pub fn interval_seconds(&self) -> f64 {
        self.cfg.interval_seconds
    }

    pub fn workers(&self) -> usize {
        self.cluster.len()
    }

    pub fn task(&self, id: u64) -> Option<&Task> {
        self.tasks.get(&id).map(|e| &e.task)
    }

    /// Read-only view of the container pool (every container ever
    /// admitted, terminal ones included). Mutation goes through engine
    /// methods only — see the field doc.
    pub fn containers(&self) -> &[Container] {
        &self.containers
    }

    /// Non-terminal container ids in ascending order — the active-set
    /// index the O(active) hot path walks. Exposed read-only so the chaos
    /// oracles can derive their sweeps from the index and cross-check
    /// against the full-pool scan (the ROADMAP's oracle migration).
    pub fn active_ids(&self) -> &[ContainerId] {
        &self.active
    }

    /// Phase-1 state partition (Queued ∪ Transferring ∪ Migrating),
    /// ascending by id — exposed read-only so property tests can pin the
    /// index against a full-pool recomputation.
    pub fn transit_ids(&self) -> &[ContainerId] {
        &self.transit
    }

    /// Phase-3 state partition (Blocked), ascending by id — read-only, see
    /// [`Engine::transit_ids`].
    pub fn blocked_ids(&self) -> &[ContainerId] {
        &self.blocked
    }

    /// Terminal containers latched at the moment they went Done/Failed
    /// ahead of an unfinished predecessor, ascending by id (see the field
    /// doc). The indexed `chain-precedence` oracle merges this with
    /// [`Engine::active_ids`]; empty on a correct engine.
    pub fn chain_suspects(&self) -> &[ContainerId] {
        &self.chain_suspects
    }

    /// Per-phase wall-clock profiler (read side). Enabled via
    /// `SimConfig::profile_phases`; inert otherwise.
    pub fn phases(&self) -> &crate::util::phase_timer::PhaseTimer {
        &self.phases
    }

    /// Per-phase profiler, mutable — the broker charges its decision and
    /// traffic phases here so one timer owns the whole interval breakdown.
    pub fn phases_mut(&mut self) -> &mut crate::util::phase_timer::PhaseTimer {
        &mut self.phases
    }

    /// Test-only sabotage: drive `cid` terminal RIGHT NOW, with fake
    /// progress, through the normal `set_container` choke point — the
    /// out-of-order terminal transition no correct engine ever performs
    /// (successors only progress after their predecessor is Done, and
    /// Done is permanent). This is the only way to manufacture the state
    /// the chain-precedence terminal latch exists to remember, so the
    /// oracle tests use it to prove the latch keeps the indexed sweep
    /// equal to the full scan post-hoc. Not part of the engine API.
    #[cfg(test)]
    pub(crate) fn sabotage_out_of_order_terminal(&mut self, cid: ContainerId) {
        self.containers[cid].mi_done += 1.0;
        let worker = self.containers[cid].worker;
        self.set_container(cid, ContainerState::Failed, worker);
    }

    /// Has `id` been abandoned via [`Engine::fail_task`]? Unknown tasks
    /// read as not-failed.
    pub fn task_failed(&self, id: u64) -> bool {
        self.tasks.get(&id).map(|e| e.failed).unwrap_or(false)
    }

    /// Containers the placement engine must consider (placeable states).
    /// Walks the active index in id order — identical output to the old
    /// full pool scan, in O(active).
    pub fn placeable(&self) -> Vec<ContainerId> {
        self.active
            .iter()
            .copied()
            .filter(|&cid| self.containers[cid].is_placeable())
            .collect()
    }

    /// Resident RAM demand per worker: running/transferring/migrating-in
    /// containers plus Blocked chain successors holding a reservation —
    /// a reservation consumes capacity so the later unblock (which starts
    /// its transfer unconditionally) can never breach the overcommit cap.
    ///
    /// Summed from the per-worker residency index through the order-free
    /// [`crate::util::accum::Accum`], so the result is bit-identical to
    /// the full-scan derivation whatever order the terms are visited in —
    /// in O(workers + resident).
    pub fn resident_ram(&self) -> Vec<f64> {
        (0..self.cluster.len()).map(|w| self.resident_ram_of(w)).collect()
    }

    /// [`Engine::resident_ram`] into a caller-owned buffer — same values,
    /// no per-call allocation (the broker feeds its placement-input
    /// scratch through here every interval).
    pub fn resident_ram_into(&self, out: &mut Vec<f64>) {
        out.clear();
        out.extend((0..self.cluster.len()).map(|w| self.resident_ram_of(w)));
    }

    /// Resident RAM demand of one worker (see [`Engine::resident_ram`]).
    pub fn resident_ram_of(&self, w: usize) -> f64 {
        crate::util::accum::sum(
            self.resident_idx[w].iter().map(|&cid| self.containers[cid].ram_mb),
        )
    }

    /// Worker availability (false = offline under churn).
    pub fn online(&self) -> &[bool] {
        &self.online
    }

    /// Per-worker owner of the current offline state (`None` while the
    /// worker is online). `Some(CmdOrigin::Autoscale)` means the traffic
    /// plane parked it and may rejoin it; any other origin means chaos or
    /// the harness took it down and the autoscaler must keep its hands
    /// off.
    pub fn offline_origins(&self) -> &[Option<CmdOrigin>] {
        &self.offline_origin
    }

    /// Currently applied clock skew of worker `w`, in seconds.
    pub fn clock_skew(&self, w: usize) -> f64 {
        self.clock_skew_s.get(w).copied().unwrap_or(0.0)
    }

    /// Current topology rack of each worker (see the field doc): the
    /// contiguous-quarter assignment until a mobility handoff re-homes a
    /// worker.
    pub fn rack_of(&self) -> &[usize] {
        &self.rack_of
    }

    /// Append-only audit log of executed handoffs, in execution order.
    /// The `handoff-preserves-progress` oracle sweeps this.
    pub fn handoff_audits(&self) -> &[HandoffAudit] {
        &self.handoff_audits
    }

    /// Remaining battery (Wh) per worker; `None` on grid-powered fleets.
    pub fn battery_levels(&self) -> Option<&[f64]> {
        self.battery_wh.as_deref()
    }

    /// Effective RAM capacity of worker `w` under any active squeeze.
    pub fn effective_ram_mb(&self, w: usize) -> f64 {
        self.cluster.workers[w].spec.ram_mb * self.ram_factor[w]
    }

    /// Tasks ever admitted.
    pub fn admitted_task_count(&self) -> usize {
        self.tasks.len()
    }

    /// Tasks that completed successfully.
    pub fn completed_task_count(&self) -> usize {
        self.n_completed
    }

    /// Tasks that were abandoned via [`Engine::fail_task`].
    pub fn failed_task_count(&self) -> usize {
        self.n_failed
    }

    /// Tasks still in flight.
    pub fn active_task_count(&self) -> usize {
        self.active_tasks.len()
    }

    /// Containers still in flight (the active-set size the hot path
    /// scales with; throughput benches report work in these units).
    pub fn active_container_count(&self) -> usize {
        self.active.len()
    }

    /// Can `cid` be (re)placed on worker `w` right now? O(resident on
    /// `w`), not O(every container ever admitted).
    pub fn fits(&self, cid: ContainerId, w: usize) -> bool {
        if !self.online[w] {
            return false;
        }
        let c = &self.containers[cid];
        if c.worker == Some(w) {
            return true;
        }
        self.resident_ram_of(w) + c.ram_mb <= self.effective_ram_mb(w) * RAM_OVERCOMMIT
    }
}
