//! Discrete-interval mobile-edge execution engine.
//!
//! Substitutes the paper's physical Azure + Docker + CRIU testbed
//! (DESIGN.md §3): containers with compute/memory demands run on the
//! Table-3 fleet under fair-share CPU contention, RAM-pressure (swap)
//! slowdown, mobility-modulated transfer times, CRIU-style migration, and
//! SPEC-style energy accounting.

//! The engine is one struct split across four files along its seams:
//! [`state`] (fields + read-only views), [`lifecycle`] (admission,
//! placement, interval integration), [`faults`] (the typed
//! [`faults::EngineCmd`] command bus and its audit ledger — the only
//! mutation path for the fault/availability surface), and [`network`]
//! (payload-movement costs, channel refresh).

pub mod container;
pub mod faults;
pub mod lifecycle;
pub mod network;
pub mod state;

pub use container::{Container, ContainerId, ContainerState};
pub use faults::{CmdOrigin, CmdRecord, Effect, EngineCmd};
pub use state::{
    CompletedTask, Engine, FailedTask, IntervalReport, WorkerSnapshot, RAM_OVERCOMMIT,
};
