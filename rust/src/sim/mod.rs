//! Discrete-interval mobile-edge execution engine.
//!
//! Substitutes the paper's physical Azure + Docker + CRIU testbed
//! (DESIGN.md §3): containers with compute/memory demands run on the
//! Table-3 fleet under fair-share CPU contention, RAM-pressure (swap)
//! slowdown, mobility-modulated transfer times, CRIU-style migration, and
//! SPEC-style energy accounting.

pub mod container;
pub mod engine;

pub use container::{Container, ContainerId, ContainerState};
pub use engine::{CompletedTask, Engine, FailedTask, IntervalReport, WorkerSnapshot};
