//! Discrete-interval mobile-edge execution engine.
//!
//! Substitutes the paper's physical Azure + Docker + CRIU testbed
//! (DESIGN.md §3): containers with compute/memory demands run on the
//! Table-3 fleet under fair-share CPU contention, RAM-pressure (swap)
//! slowdown, mobility-modulated transfer times, CRIU-style migration, and
//! SPEC-style energy accounting.

//! The engine is one struct split across four files along its seams:
//! [`state`] (fields + read-only views), [`lifecycle`] (admission,
//! placement, interval integration), [`faults`] (the typed
//! [`faults::EngineCmd`] command bus and its audit ledger — the only
//! mutation path for the fault/availability surface), and [`network`]
//! (payload-movement costs, channel refresh).
//!
//! The core is an **indexed active set**: an id-sorted list of in-flight
//! containers plus per-worker residency indexes and per-task
//! remaining-fragment counters, all maintained through the single
//! `set_container` choke point. The integrator hot path costs O(active)
//! per sub-step instead of O(everything ever admitted) — what makes
//! 1000-worker, long-horizon fleets sweepable — while visiting containers
//! in the same id order as the old full scans, so trajectories are
//! bit-identical (`Engine::verify_indices` cross-checks the indexes
//! against the full-scan derivations).

pub mod container;
pub mod faults;
pub mod lifecycle;
pub mod network;
mod pool;
pub mod state;

pub use container::{Container, ContainerId, ContainerState};
pub use faults::{CmdOrigin, CmdRecord, Effect, EngineCmd, FaultSurface};
pub use state::{
    CompletedTask, Engine, FailedTask, HandoffAudit, IntervalReport, WorkerSnapshot,
    RAM_OVERCOMMIT,
};
