//! Payload-movement cost model and per-interval channel refresh.
//!
//! Transfers (input staging, chain hand-offs, CRIU migration images) move
//! at min(net, disk) bandwidth of the endpoints — cPickle+bzip2+rsync goes
//! through disk — scaled by the mobility channel, plus any clock-skew
//! reconciliation latency on either endpoint.

use crate::cluster::topology;

use super::state::Engine;

impl Engine {
    /// Transfer seconds for `mb` from `src` (None = broker) to worker `dst`,
    /// bottlenecked by disk bandwidth on both ends (rsync-through-disk).
    pub(super) fn payload_transfer_s(&self, src: Option<usize>, dst: usize, mb: f64) -> f64 {
        let ch_dst = &self.channels[dst];
        let net_s = match src {
            None => topology::broker_transfer_s(&self.cluster, dst, ch_dst, mb),
            Some(s) if s == dst => {
                return mb / self.cluster.workers[dst].spec.ram_bw_mbps.max(1.0);
            }
            Some(s) => topology::worker_transfer_s(
                &self.cluster,
                s,
                dst,
                &self.channels[s],
                ch_dst,
                mb,
            ),
        };
        let disk_dst = self.cluster.workers[dst].spec.disk_bw_mbps;
        let disk_src = src.map(|s| self.cluster.workers[s].spec.disk_bw_mbps).unwrap_or(f64::MAX);
        let disk_s = mb / disk_dst.min(disk_src);
        // Clock skew on either endpoint: the broker reconciles timestamps
        // before trusting the transfer window (same-node moves above never
        // cross a clock boundary and stay skew-free).
        let skew_s = self.clock_skew_s[dst]
            + src.map(|s| self.clock_skew_s[s]).unwrap_or(0.0);
        net_s.max(disk_s) + skew_s
    }

    /// Advance mobility for the next interval; blackout overrides win.
    pub(super) fn refresh_channels(&mut self) {
        self.channels = self.mobility.step();
        for (w, ov) in self.channel_override.iter().enumerate() {
            if let Some(ch) = ov {
                self.channels[w] = *ch;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::faults::EngineCmd;
    use super::super::state::Engine;
    use crate::cluster::node::build_fleet;
    use crate::config::{ClusterConfig, SimConfig};

    fn engine() -> Engine {
        let cluster = build_fleet(&ClusterConfig::small());
        Engine::new(cluster, SimConfig { intervals: 10, ..Default::default() }, 1)
    }

    #[test]
    fn interval_counter_and_mobility_advance() {
        let mut e = engine();
        let ch0 = e.channels.clone();
        e.step_interval();
        e.step_interval();
        assert_eq!(e.interval, 2);
        assert!((e.now_s - 600.0).abs() < 1e-9);
        // with mobile workers in the small fleet the channel should change
        if e.cluster.workers.iter().any(|w| w.mobile) {
            assert_ne!(ch0, e.channels);
        }
    }

    #[test]
    fn same_node_moves_are_ram_bound_and_skew_free() {
        let mut e = engine();
        e.apply(EngineCmd::SetClockSkew { worker: 0, skew_s: 120.0 });
        let t = e.payload_transfer_s(Some(0), 0, 100.0);
        let ram_bw = e.cluster.workers[0].spec.ram_bw_mbps.max(1.0);
        assert!(
            (t - 100.0 / ram_bw).abs() < 1e-9,
            "same-node move must be RAM-bandwidth bound and pay no skew (got {t})"
        );
        // a cross-node move touching the skewed worker pays the offset
        let skewed = e.payload_transfer_s(Some(0), 1, 100.0);
        e.apply(EngineCmd::SetClockSkew { worker: 0, skew_s: 0.0 });
        let clean = e.payload_transfer_s(Some(0), 1, 100.0);
        assert!((skewed - clean - 120.0).abs() < 1e-6, "skewed={skewed} clean={clean}");
    }
}
