//! Task and container lifecycle: admission, placement application, the
//! per-interval progress integrator, and completion/failure bookkeeping.
//!
//! Per scheduling interval (paper I_t, 300 s), the broker admits tasks,
//! takes split + placement decisions, then the engine integrates container
//! progress over `sub_steps` fixed sub-steps:
//!
//!   * fair-share CPU: containers on a worker split its MIPS evenly;
//!   * RAM pressure: if resident demand exceeds node RAM, all containers on
//!     the node slow by ram/demand (swap-on-NAS, the paper's memory
//!     bottleneck), floored at 0.2×;
//!   * transfers: input payloads move at min(net, disk) bandwidth of the
//!     endpoints (cPickle+bzip2+rsync goes through disk), scaled by the
//!     mobility channel;
//!   * migration: CRIU checkpoint of the resident set over the same path,
//!     no progress during migration;
//!   * chains: fragment k+1 unblocks when k completes; its input source is
//!     k's worker.
//!
//! Energy integrates the SPEC power curve over busy time per worker.

use crate::cluster::energy;
use crate::splits::{Precedence, Registry, SplitDecision};
use crate::util::accum::Accum;
use crate::workload::Task;

use super::container::{Container, ContainerId, ContainerState};
use super::faults::{CmdOrigin, EngineCmd};
use super::state::{
    CompletedTask, Engine, FailedTask, IntervalReport, TaskEntry, WorkerSnapshot, THRASH_FLOOR,
};

/// Deltas computed by one rack shard of the CPU integration phase
/// ([`Engine::cpu_shard`]): read-only over a contiguous worker range,
/// applied serially after the join. Workers partition across shards and a
/// running container belongs to exactly one worker's residency index, so
/// shard results are disjoint — the join is concatenation in shard order
/// (= worker-ascending, the serial walk's order), and every float that
/// crosses a shard boundary goes through the order-free
/// [`crate::util::accum::Accum`]. `pub(super)` so the persistent lane
/// pool ([`super::pool`]) can carry one per reply.
pub(super) struct CpuShard {
    /// `(worker, busy-seconds increment)` for each worker that ran work.
    pub(super) busy: Vec<(usize, f64)>,
    /// `(container, mi increment)` for every Running container visited.
    pub(super) exec: Vec<(ContainerId, f64)>,
    /// Containers whose increment finishes them this sub-step.
    pub(super) done: Vec<ContainerId>,
}

impl Engine {
    /// Admit a task whose split decision has been taken: create one
    /// container per fragment of the plan.
    pub fn admit(&mut self, mut task: Task, decision: SplitDecision) {
        task.decision = Some(decision);
        let plan = Registry::plan(task.app, decision);
        let k = task.batch_k();
        let mut ids = Vec::new();
        for (fi, frag) in plan.fragments.iter().enumerate() {
            let id = self.containers.len();
            let chain = plan.precedence == Precedence::Chain;
            let prev = if chain && fi > 0 { Some(id - 1) } else { None };
            let input_mb = if chain && fi > 0 {
                plan.fragments[fi - 1].out_mb_per_ksample * k
            } else {
                plan.input_mb_per_ksample * k
            };
            self.containers.push(Container {
                id,
                task_id: task.id,
                frag_idx: fi,
                decision,
                precedence: plan.precedence,
                profile: frag.clone(),
                prev,
                mi_total: frag.mi_per_ksample * k,
                mi_done: 0.0,
                ram_mb: frag.ram_fixed_mb + frag.ram_per_ksample_mb * k,
                input_mb,
                output_mb: frag.out_mb_per_ksample * k,
                state: if prev.is_some() { ContainerState::Blocked } else { ContainerState::Queued },
                worker: None,
                input_src: None, // broker
                created_s: self.now_s,
                t_wait: 0.0,
                t_transfer: 0.0,
                t_exec: 0.0,
                t_migrate: 0.0,
            });
            ids.push(id);
            // ids are allocated in ascending order, so a plain push keeps
            // the active list — and the state partitions below — id-sorted
            self.active.push(id);
            if prev.is_some() {
                self.blocked.push(id);
            } else {
                self.transit.push(id);
            }
        }
        self.active_tasks.insert(task.id);
        let remaining = ids.len();
        self.tasks.insert(
            task.id,
            TaskEntry { task, containers: ids, done: false, failed: false, remaining },
        );
    }

    /// Apply a placement: allocations for queued containers, migrations for
    /// running ones. Infeasible assignments are skipped (stay queued —
    /// paper §4.3's wait-queue relaxation); returns ids actually applied.
    pub fn apply_placement(&mut self, assignment: &[(ContainerId, usize)]) -> Vec<ContainerId> {
        let mut applied = Vec::new();
        for &(cid, w) in assignment {
            if w >= self.cluster.len() || cid >= self.containers.len() {
                continue;
            }
            if !self.fits(cid, w) {
                continue;
            }
            let now = self.now_s;
            // compute transfer costs immutably first
            let (state, worker) = {
                let c = &self.containers[cid];
                match c.state {
                    ContainerState::Queued => {
                        let t = self.payload_transfer_s(c.input_src, w, c.input_mb);
                        (ContainerState::Transferring { until_s: now + t }, Some(w))
                    }
                    // Blocked chain successor: reserve the worker; the
                    // transfer starts the moment the predecessor finishes.
                    ContainerState::Blocked => (ContainerState::Blocked, Some(w)),
                    ContainerState::Running if c.worker != Some(w) => {
                        // CRIU migration: checkpoint resident set, move it;
                        // `worker` stays the source until arrival, resident
                        // RAM counts at the destination.
                        let t = self.payload_transfer_s(c.worker, w, c.ram_mb * 0.5);
                        (ContainerState::Migrating { until_s: now + t, to: w }, c.worker)
                    }
                    _ => continue,
                }
            };
            self.set_container(cid, state, worker);
            applied.push(cid);
        }
        applied
    }

    /// Abandon a task: mark it failed, kill its unfinished containers and
    /// release their workers. Returns false if the task is unknown or has
    /// already left the system. The failure surfaces in the next
    /// [`IntervalReport::failed`].
    pub fn fail_task(&mut self, id: u64) -> bool {
        let Some(e) = self.tasks.get_mut(&id) else {
            return false;
        };
        if e.done {
            return false;
        }
        e.done = true;
        e.failed = true;
        let task = e.task.clone();
        let cids = e.containers.clone();
        for &cid in &cids {
            if !self.containers[cid].is_done() {
                self.set_container(cid, ContainerState::Failed, None);
            }
        }
        self.n_failed += 1;
        self.active_tasks.remove(&id);
        self.pending_failed.push(FailedTask {
            task_id: id,
            app: task.app,
            decision: task.decision.unwrap_or(SplitDecision::Full),
            batch: task.batch,
            sla: task.sla,
            age: (self.now_s - task.arrival_s) / self.cfg.interval_seconds,
        });
        true
    }

    /// Fail every active task older than `age_s` simulation seconds
    /// (starvation guard under fault injection). Returns how many failed.
    /// Chaos harnesses should route this through
    /// [`super::faults::EngineCmd::FailTasksOlderThan`] so the ledger
    /// records it.
    pub fn fail_tasks_older_than(&mut self, age_s: f64) -> usize {
        self.fail_tasks_older_than_collect(age_s).len()
    }

    /// Like [`Engine::fail_tasks_older_than`], returning the failed ids
    /// (the command bus records them as the command's effect).
    pub(super) fn fail_tasks_older_than_collect(&mut self, age_s: f64) -> Vec<u64> {
        let now = self.now_s;
        // walk only in-flight tasks (ascending id, like the old full
        // task-map filter) — O(active tasks), not O(ever admitted)
        let ids: Vec<u64> = self
            .active_tasks
            .iter()
            .copied()
            .filter(|id| now - self.tasks[id].task.arrival_s > age_s)
            .collect();
        for id in &ids {
            self.fail_task(*id);
        }
        ids
    }

    /// Simulate one full interval; the placement must already be applied.
    pub fn step_interval(&mut self) -> IntervalReport {
        self.apply_churn();
        let n = self.cluster.len();
        self.busy_s.iter_mut().for_each(|b| *b = 0.0);
        self.xfer_s.iter_mut().for_each(|b| *b = 0.0);
        let dt = self.cfg.interval_seconds / self.cfg.sub_steps as f64;
        let mut completed = Vec::new();

        for _ in 0..self.cfg.sub_steps {
            self.sub_step(dt);
            self.collect_completions(&mut completed);
        }

        // energy over the interval from busy time per worker — summed
        // order-free so the total is independent of worker visit order.
        // An offline worker draws 0 W: a crashed, parked, or battery-dead
        // machine is powered off, not idling (it used to be billed at
        // idle watts, inflating fleet energy and AEC under faults). The
        // utilization and container-count buffers are engine-owned
        // scratch (taken, refilled, restored) so steady-state intervals
        // allocate nothing here.
        let mut energy = Accum::ZERO;
        let mut utils = std::mem::take(&mut self.utils_scratch);
        utils.clear();
        utils.reserve(n);
        for (w, worker) in self.cluster.workers.iter().enumerate() {
            let util = (self.busy_s[w] / self.cfg.interval_seconds).clamp(0.0, 1.0);
            utils.push(util);
            if self.online[w] {
                energy.add(energy::energy_wh(&worker.spec, util, self.cfg.interval_seconds));
            }
        }
        let energy_wh = energy.value();
        let aec = energy::normalized_aec_gated_over(
            self.cluster.workers.iter().map(|w| &w.spec),
            &utils,
            &self.online,
            self.cfg.interval_seconds,
        );

        // Battery plane (inert on a grid-powered fleet): each online
        // worker drains its interval draw from its battery; exhausted
        // workers crash through the command bus under
        // [`CmdOrigin::Battery`], in worker-id order, and stay down — the
        // autoscaler rejoins only `Autoscale`-owned offline workers, so a
        // dead battery is never resurrected. A chaos `Recover` of a
        // battery-dead worker lasts one interval: the empty battery kills
        // it again at the next drain.
        if self.battery_wh.is_some() {
            let isec = self.cfg.interval_seconds;
            let mut dead: Vec<usize> = Vec::new();
            {
                let levels = self.battery_wh.as_mut().expect("gated on is_some");
                for (w, worker) in self.cluster.workers.iter().enumerate() {
                    if !self.online[w] {
                        continue;
                    }
                    levels[w] -= energy::energy_wh(&worker.spec, utils[w], isec);
                    if levels[w] <= 0.0 {
                        levels[w] = 0.0;
                        dead.push(w);
                    }
                }
            }
            for w in dead {
                self.apply_with_origin(EngineCmd::Crash { worker: w }, CmdOrigin::Battery);
            }
        }

        // snapshots — derived from the active index, O(workers + active)
        let resident = self.resident_ram();
        let mut counts = std::mem::take(&mut self.counts_scratch);
        counts.clear();
        counts.resize(n, 0);
        for &cid in &self.active {
            if let Some(w) = self.containers[cid].worker {
                counts[w] += 1;
            }
        }
        let snapshots = (0..n)
            .map(|w| WorkerSnapshot {
                cpu: utils[w],
                ram: resident[w] / self.cluster.workers[w].spec.ram_mb,
                net: (self.xfer_s[w] / self.cfg.interval_seconds).min(1.0),
                disk: (self.xfer_s[w] / self.cfg.interval_seconds).min(1.0),
                containers: counts[w],
            })
            .collect();
        self.utils_scratch = utils;
        self.counts_scratch = counts;

        // Queued ⊆ transit, so the count walks the O(in-transit) state
        // partition instead of the whole active list.
        let queued = self
            .transit
            .iter()
            .filter(|&&cid| matches!(self.containers[cid].state, ContainerState::Queued))
            .count();

        let report = IntervalReport {
            interval: self.interval,
            completed,
            failed: std::mem::take(&mut self.pending_failed),
            energy_wh,
            aec,
            snapshots,
            queued,
            offline: self.online.iter().filter(|&&o| !o).count(),
        };

        self.interval += 1;
        self.refresh_channels();
        report
    }

    /// One integrator sub-step, O(in-state + workers): every loop below
    /// walks a per-state partition of the active set or the per-worker
    /// residency index (all id-sorted), never the whole container pool —
    /// and phases 1/3 no longer even walk the whole active list. Phase 1
    /// (transfers) sweeps the `transit` partition and phase 3 (chain
    /// unblock) the `blocked` partition, each via a frozen pre-phase
    /// snapshot; phase 2 (fair-share CPU) is per-worker-independent and
    /// fans out across `cfg.shards` rack shards — with every reduction
    /// order-free ([`crate::util::accum`]), the result is byte-identical
    /// at any shard count.
    fn sub_step(&mut self, dt: f64) {
        let t_end = self.now_s + dt;
        let tok = self.phases.start();
        let mut walk = std::mem::take(&mut self.walk_scratch);

        // 1. transfers & migrations that finish within this sub-step —
        //    sweep the frozen transit partition (Queued ∪ Transferring ∪
        //    Migrating, ascending id): exactly the subsequence of the
        //    active list the old full filter matched, in its order. The
        //    sweep copies the index first because a finishing transfer
        //    removes the visited entry from `transit` mid-sweep; each
        //    visit mutates only its own container and no phase-1
        //    transition ADDS transit membership, so the snapshot sees
        //    precisely the states the live active-list walk saw. No
        //    transition here is terminal or changes residency
        //    (Transferring→Running and Migrating→Running keep their home).
        walk.clear();
        walk.extend_from_slice(&self.transit);
        for i in 0..walk.len() {
            let cid = walk[i];
            match self.containers[cid].state {
                ContainerState::Transferring { until_s } => {
                    let spent = (until_s.min(t_end) - self.now_s).max(0.0).min(dt);
                    let c = &mut self.containers[cid];
                    c.t_transfer += spent;
                    let worker = c.worker;
                    if let Some(w) = worker {
                        self.xfer_s[w] += spent;
                    }
                    if until_s <= t_end {
                        self.set_container(cid, ContainerState::Running, worker);
                    }
                }
                ContainerState::Migrating { until_s, to } => {
                    let spent = (until_s.min(t_end) - self.now_s).max(0.0).min(dt);
                    self.containers[cid].t_migrate += spent;
                    self.xfer_s[to] += spent;
                    if until_s <= t_end {
                        self.set_container(cid, ContainerState::Running, Some(to));
                    }
                }
                ContainerState::Queued => {
                    self.containers[cid].t_wait += dt;
                }
                _ => {}
            }
        }

        // 2. fair-share CPU with RAM-pressure slowdown: per worker, the
        //    Running members of its residency index. The phase is a pure
        //    function of pre-phase state (each running container belongs
        //    to exactly one worker), so it fans out across contiguous
        //    worker shards ([`Engine::cpu_shard`]) and the deltas are
        //    applied serially in shard order — byte-identical to the
        //    single-shard walk at any shard count. The fan-out goes to the
        //    engine-owned persistent lane pool ([`super::pool`]): threads
        //    spawn on the first sharded sub-step of the run and are fed
        //    ranges over channels thereafter, instead of a scoped
        //    spawn/join cycle per sub-step.
        self.phases.stop(crate::util::phase_timer::Phase::Network, tok);
        let tok = self.phases.start();
        let n = self.cluster.len();
        let shards = self.cfg.shards.max(1).min(n.max(1));
        let results: Vec<CpuShard> = if shards <= 1 {
            vec![self.cpu_shard(0..n, dt)]
        } else {
            self.ensure_pool(shards);
            let chunk = (n + shards - 1) / shards;
            let ranges =
                (0..shards).map(|s| (s * chunk).min(n)..((s + 1) * chunk).min(n));
            self.pool.as_ref().expect("pool just ensured").dispatch(self, dt, ranges)
        };
        // apply in shard-index order = worker-ascending, container-id
        // ascending within each worker — the serial walk's exact order
        for shard in &results {
            for &(w, busy) in &shard.busy {
                self.busy_s[w] += busy;
            }
            for &(cid, inc) in &shard.exec {
                let c = &mut self.containers[cid];
                c.mi_done += inc;
                c.t_exec += dt;
            }
            for &cid in &shard.done {
                let worker = self.containers[cid].worker;
                self.set_container(cid, ContainerState::Done { at_s: t_end }, worker);
            }
        }
        self.phases.stop(crate::util::phase_timer::Phase::Cpu, tok);
        let tok = self.phases.start();

        // 3. unblock chain successors of containers that just finished —
        //    sweep the frozen blocked partition (ascending id), the exact
        //    subsequence of the active list the old filter matched. An
        //    unblocking visit removes its entry from `blocked` (hence the
        //    snapshot); it mutates only its own container, never produces
        //    a Done state, and nothing in this phase creates new Blocked
        //    members — so later entries see predecessor done-ness exactly
        //    as the live walk did. Neither transition is terminal.
        walk.clear();
        walk.extend_from_slice(&self.blocked);
        for i in 0..walk.len() {
            let cid = walk[i];
            if !matches!(self.containers[cid].state, ContainerState::Blocked) {
                continue;
            }
            let Some(prev) = self.containers[cid].prev else {
                continue;
            };
            if !self.containers[prev].is_done() {
                continue;
            }
            let src = self.containers[prev].worker;
            match self.containers[cid].worker {
                Some(w) => {
                    let mb = self.containers[cid].input_mb;
                    let t = self.payload_transfer_s(src, w, mb);
                    self.containers[cid].input_src = src;
                    self.set_container(
                        cid,
                        ContainerState::Transferring { until_s: t_end + t },
                        Some(w),
                    );
                }
                None => {
                    self.containers[cid].input_src = src;
                    self.set_container(cid, ContainerState::Queued, None);
                }
            }
        }
        self.phases.stop(crate::util::phase_timer::Phase::Network, tok);
        self.walk_scratch = walk;

        self.now_s = t_end;
    }

    /// One rack shard of the CPU integration phase: fair-share CPU with
    /// RAM-pressure slowdown over the contiguous worker range, computed
    /// READ-ONLY against pre-phase state. The per-worker resident sum
    /// reduces through the order-free accumulator, so the numbers cannot
    /// depend on how the fleet is sliced into shards; completion is
    /// detected as `mi_done + inc >= mi_total`, exactly the value the
    /// serial `+=` would have compared. `pub(super)` so the persistent
    /// lane pool can run it on its worker threads.
    pub(super) fn cpu_shard(&self, workers: std::ops::Range<usize>, dt: f64) -> CpuShard {
        let mut out = CpuShard { busy: Vec::new(), exec: Vec::new(), done: Vec::new() };
        let mut running: Vec<ContainerId> = Vec::new();
        for w in workers {
            if self.resident_idx[w].is_empty() {
                continue;
            }
            running.clear();
            let mut resident = Accum::ZERO;
            for &cid in &self.resident_idx[w] {
                let c = &self.containers[cid];
                if matches!(c.state, ContainerState::Running) {
                    running.push(cid);
                    resident.add(c.ram_mb);
                }
            }
            if running.is_empty() {
                continue;
            }
            let resident = resident.value();
            let spec = &self.cluster.workers[w].spec;
            // Straggler injection scales the whole node's throughput.
            let mips = spec.mips * self.mips_factor[w];
            // Per-container rate is capped at two cores' worth: every
            // Table-3 node has the same per-core speed ("Intel i3 2.4 GHz
            // cores" for all types), so a bigger node hosts more
            // containers rather than running one container faster. This
            // keeps layer response times tight (paper: 9.92±0.91).
            let per_core = mips / spec.cores as f64;
            let share = (mips / running.len() as f64).min(per_core * 2.0);
            let ram_cap = self.effective_ram_mb(w);
            let thrash = if resident > ram_cap {
                (ram_cap / resident).max(THRASH_FLOOR)
            } else {
                1.0
            };
            let used: f64 = share * running.len() as f64;
            out.busy.push((w, dt * (used / mips).min(1.0)));
            for &cid in &running {
                let inc = share * thrash * dt;
                out.exec.push((cid, inc));
                let c = &self.containers[cid];
                if c.mi_done + inc >= c.mi_total {
                    out.done.push(cid);
                }
            }
        }
        out
    }

    /// Drain tasks whose remaining-fragment counter hit zero this
    /// sub-step — O(completed-this-step), not a task-map scan. The drain
    /// is sorted so completions surface in task-id order per sub-step,
    /// exactly as the old ordered map filter emitted them.
    fn collect_completions(&mut self, out: &mut Vec<CompletedTask>) {
        if self.pending_done.is_empty() {
            return;
        }
        let mut ids = std::mem::take(&mut self.pending_done);
        ids.sort_unstable();
        for id in ids {
            let e = self.tasks.get_mut(&id).unwrap();
            if e.done {
                continue;
            }
            e.done = true;
            self.n_completed += 1;
            self.active_tasks.remove(&id);
            let task = e.task.clone();
            let cids = e.containers.clone();
            let isec = self.cfg.interval_seconds;
            let done_at = cids
                .iter()
                .map(|&c| match self.containers[c].state {
                    ContainerState::Done { at_s } => at_s,
                    _ => unreachable!(),
                })
                .fold(0.0f64, f64::max);
            // final result hop back to the broker
            let last = &self.containers[*cids.last().unwrap()];
            let result_s = self
                .payload_transfer_s(last.worker, last.worker.unwrap_or(0), 0.0)
                .max(0.05);
            let mut workers: Vec<usize> = cids
                .iter()
                .filter_map(|&c| self.containers[c].worker)
                .collect();
            workers.sort_unstable();
            workers.dedup();
            let sum = |f: fn(&Container) -> f64| -> f64 {
                crate::util::accum::sum(cids.iter().map(|&c| f(&self.containers[c])))
            };
            out.push(CompletedTask {
                task_id: id,
                app: task.app,
                decision: task.decision.unwrap(),
                batch: task.batch,
                sla: task.sla,
                response: (done_at + result_s - task.arrival_s) / isec,
                wait: sum(|c| c.t_wait) / isec,
                exec: sum(|c| c.t_exec) / isec,
                transfer: sum(|c| c.t_transfer) / isec,
                migrate: sum(|c| c.t_migrate) / isec,
                workers,
                accuracy: f64::NAN,
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::state::{Engine, RAM_OVERCOMMIT};
    use super::super::container::{ContainerId, ContainerState};
    use crate::cluster::node::build_fleet;
    use crate::config::{ClusterConfig, SimConfig};
    use crate::splits::{App, SplitDecision};
    use crate::workload::Task;

    fn engine() -> Engine {
        let cluster = build_fleet(&ClusterConfig::small());
        Engine::new(cluster, SimConfig { intervals: 10, ..Default::default() }, 1)
    }

    fn task(id: u64, app: App, batch: u64) -> Task {
        Task { id, app, batch, sla: 5.0, arrival_s: 0.0, decision: None }
    }

    #[test]
    fn admit_layer_creates_chain() {
        let mut e = engine();
        e.admit(task(1, App::Mnist, 32_000), SplitDecision::Layer);
        assert_eq!(e.containers.len(), 3);
        assert_eq!(e.containers[0].state, ContainerState::Queued);
        assert_eq!(e.containers[1].state, ContainerState::Blocked);
        assert_eq!(e.containers[1].prev, Some(0));
        // the whole chain is placeable up-front (paper: P_t covers C_t)
        assert_eq!(e.placeable(), vec![0, 1, 2]);
    }

    #[test]
    fn admit_semantic_all_queued() {
        let mut e = engine();
        e.admit(task(1, App::Cifar100, 32_000), SplitDecision::Semantic);
        assert_eq!(e.containers.len(), 4);
        assert!(e.containers.iter().all(|c| c.state == ContainerState::Queued));
        assert_eq!(e.placeable().len(), 4);
    }

    #[test]
    fn layer_task_completes_through_chain() {
        let mut e = engine();
        e.admit(task(1, App::Mnist, 16_000), SplitDecision::Layer);
        let mut done = Vec::new();
        for i in 0..40 {
            // place any queued container on worker (i % n) — dumb but legal
            let assigns: Vec<(ContainerId, usize)> = e
                .placeable()
                .into_iter()
                .filter(|&c| matches!(e.containers[c].state, ContainerState::Queued))
                .map(|c| (c, (c + i) % e.workers()))
                .collect();
            e.apply_placement(&assigns);
            let r = e.step_interval();
            done.extend(r.completed);
            if !done.is_empty() {
                break;
            }
        }
        assert_eq!(done.len(), 1, "layer task must eventually complete");
        let t = &done[0];
        assert!(t.response > 0.0);
        assert!(t.exec > 0.0);
        assert!(!t.workers.is_empty());
    }

    #[test]
    fn semantic_completes_faster_than_layer() {
        let run = |decision: SplitDecision| -> f64 {
            let mut e = engine();
            e.admit(task(1, App::FashionMnist, 40_000), decision);
            for _ in 0..60 {
                let assigns: Vec<(ContainerId, usize)> = e
                    .placeable()
                    .into_iter()
                    .filter(|&c| matches!(e.containers[c].state, ContainerState::Queued))
                    .enumerate()
                    .map(|(i, c)| (c, i % e.workers()))
                    .collect();
                e.apply_placement(&assigns);
                let r = e.step_interval();
                if let Some(t) = r.completed.first() {
                    return t.response;
                }
            }
            // A starved task is a recoverable failed outcome, not a panic:
            // abandon it and surface the failure through the report.
            assert!(e.fail_task(1), "starved task must still be active");
            let r = e.step_interval();
            assert_eq!(r.failed.len(), 1, "{decision:?} starved without a failure report");
            f64::INFINITY
        };
        let layer = run(SplitDecision::Layer);
        let semantic = run(SplitDecision::Semantic);
        // both must actually complete — an INFINITY sentinel would make
        // the ordering assertion below pass vacuously
        assert!(layer.is_finite(), "layer starved instead of completing");
        assert!(semantic.is_finite(), "semantic starved instead of completing");
        assert!(
            semantic < layer,
            "semantic ({semantic}) must beat layer ({layer})"
        );
    }

    #[test]
    fn infeasible_placement_skipped() {
        let mut e = engine();
        // a cifar full container demands huge RAM at max batch
        e.admit(task(1, App::Cifar100, 64_000), SplitDecision::Full);
        let c = &e.containers[0];
        assert!(c.ram_mb > 1000.0);
        // worker 0 is a B2ms with ~4.3 GB; overcommit 2x allows < 8.6 GB
        let ram = c.ram_mb;
        let applied = e.apply_placement(&[(0, 0)]);
        if ram <= e.cluster.workers[0].spec.ram_mb * RAM_OVERCOMMIT {
            assert_eq!(applied.len(), 1);
        } else {
            assert!(applied.is_empty());
        }
    }

    #[test]
    fn ram_pressure_slows_execution() {
        let mk = |n_tasks: u64| -> f64 {
            let mut e = engine();
            for i in 0..n_tasks {
                e.admit(task(i, App::Cifar100, 64_000), SplitDecision::Compressed);
            }
            // all on worker 0
            let assigns: Vec<(ContainerId, usize)> =
                e.placeable().into_iter().map(|c| (c, 0)).collect();
            e.apply_placement(&assigns);
            let r = e.step_interval();
            // MI progress of container 0 after one interval
            let _ = r;
            e.containers[0].mi_done
        };
        let solo = mk(1);
        let crowded = mk(4);
        // 4 containers: fair share alone gives 1/4; pressure must push
        // total progress per container below the pure fair share.
        assert!(crowded < solo / 4.0 + 1e-6, "solo={solo} crowded={crowded}");
    }

    #[test]
    fn migration_pauses_progress() {
        let mut e = engine();
        e.admit(task(1, App::Mnist, 64_000), SplitDecision::Compressed);
        e.apply_placement(&[(0, 0)]);
        e.step_interval();
        let before = e.containers[0].mi_done;
        assert!(before > 0.0);
        assert_eq!(e.containers[0].state, ContainerState::Running);
        // migrate to worker 5
        e.apply_placement(&[(0, 5)]);
        assert!(matches!(e.containers[0].state, ContainerState::Migrating { .. }));
        e.step_interval();
        let c = &e.containers[0];
        assert!(c.t_migrate > 0.0, "migration time must be recorded");
        if let ContainerState::Running = c.state {
            assert_eq!(c.worker, Some(5));
        }
    }

    #[test]
    fn wait_time_accumulates_when_unplaced() {
        let mut e = engine();
        e.admit(task(1, App::Mnist, 16_000), SplitDecision::Semantic);
        e.step_interval(); // never placed
        assert!(e.containers[0].t_wait > 0.0);
        let r = e.step_interval();
        assert_eq!(r.queued, 2);
    }

    #[test]
    fn energy_reflects_busy_workers() {
        let mut e = engine();
        let idle = e.step_interval().energy_wh;
        e.admit(task(1, App::Cifar100, 64_000), SplitDecision::Layer);
        let assigns: Vec<(ContainerId, usize)> =
            e.placeable().into_iter().map(|c| (c, 0)).collect();
        e.apply_placement(&assigns);
        let busy = e.step_interval().energy_wh;
        assert!(busy > idle, "busy={busy} idle={idle}");
    }

    #[test]
    fn offline_workers_draw_no_power() {
        use super::super::faults::EngineCmd;
        let mut e = engine();
        let full = e.step_interval();
        // idle fleet: every online worker bills exactly its idle draw
        let idle0 =
            e.cluster.workers[0].spec.idle_watts * e.cfg.interval_seconds / 3600.0;
        e.apply(EngineCmd::SetOnline { worker: 0, up: false });
        let less = e.step_interval();
        assert!(
            (full.energy_wh - less.energy_wh - idle0).abs() < 1e-9,
            "taking worker 0 down must remove exactly its idle draw: \
             full={} less={} idle0={idle0}",
            full.energy_wh,
            less.energy_wh
        );
        assert!(less.aec < full.aec, "AEC numerator must drop with the worker");
        assert_eq!(less.offline, 1);
    }

    #[test]
    fn battery_exhaustion_crashes_workers_for_good() {
        use super::super::faults::{CmdOrigin, EngineCmd};
        // idle draw over a 300 s interval is 5.0–6.5 Wh depending on node
        // type, so a 7 Wh battery survives interval 1 (max draw 6.5) and
        // every worker is dead by the end of interval 2
        let cfg = ClusterConfig { battery_wh: Some(7.0), ..ClusterConfig::small() };
        let cluster = build_fleet(&cfg);
        let mut e = Engine::new(cluster, SimConfig { intervals: 10, ..Default::default() }, 1);
        let n = e.workers();
        let r1 = e.step_interval();
        assert_eq!(r1.offline, 0, "one idle interval must not exhaust a 7 Wh battery");
        let r2 = e.step_interval();
        assert_eq!(r2.offline, n, "every battery is empty after two idle intervals");
        let levels = e.battery_levels().expect("battery fleet exposes levels");
        for w in 0..n {
            assert!(!e.online()[w]);
            assert_eq!(levels[w], 0.0, "exhausted batteries clamp at zero");
            assert_eq!(
                e.offline_origins()[w],
                Some(CmdOrigin::Battery),
                "battery deaths must be Battery-owned, worker {w}"
            );
        }
        // the deaths went through the command bus
        assert_eq!(
            e.ledger().iter().filter(|rec| rec.origin == CmdOrigin::Battery).count(),
            n
        );
        // a dead fleet draws nothing
        let r3 = e.step_interval();
        assert_eq!(r3.energy_wh, 0.0);
        assert_eq!(r3.aec, 0.0);
        // a chaos revival lasts exactly one interval: the empty battery
        // kills the worker again at the next drain, Battery-owned
        e.apply(EngineCmd::Recover { worker: 0 });
        assert!(e.online()[0]);
        let r4 = e.step_interval();
        assert!(r4.energy_wh > 0.0, "revived worker billed for its zombie interval");
        assert!(!e.online()[0], "empty battery must re-kill the revived worker");
        assert_eq!(e.offline_origins()[0], Some(CmdOrigin::Battery));
    }

    #[test]
    fn fail_task_reports_failed_outcome() {
        let mut e = engine();
        e.admit(task(1, App::Mnist, 32_000), SplitDecision::Layer);
        e.apply_placement(&[(0, 0)]);
        e.step_interval();
        assert!(e.fail_task(1), "active task fails");
        assert!(!e.fail_task(1), "double-fail is a no-op");
        assert!(!e.fail_task(99), "unknown task ignored");
        assert!(e.task_failed(1));
        assert!(!e.task_failed(99));
        let r = e.step_interval();
        assert_eq!(r.failed.len(), 1);
        assert_eq!(r.failed[0].task_id, 1);
        assert_eq!(r.failed[0].decision, SplitDecision::Layer);
        assert!(r.failed[0].age > 0.0);
        // containers are terminal and hold no resources
        for c in &e.containers {
            assert_eq!(c.state, ContainerState::Failed);
            assert_eq!(c.worker, None);
        }
        assert_eq!(e.failed_task_count(), 1);
        assert_eq!(e.completed_task_count(), 0);
        assert_eq!(e.active_task_count(), 0);
        // a later report does not re-announce the failure
        assert!(e.step_interval().failed.is_empty());
    }

    #[test]
    fn sharded_cpu_phase_is_byte_identical_to_serial() {
        // the tentpole contract at engine level: any shard count yields
        // the exact trajectory bits the serial walk yields — reports,
        // snapshots, per-container progress, everything
        let run = |shards: usize| -> Vec<u64> {
            let cluster = build_fleet(&ClusterConfig::small());
            let cfg = SimConfig { intervals: 12, shards, ..Default::default() };
            let mut e = Engine::new(cluster, cfg, 1);
            let apps = [App::Mnist, App::FashionMnist, App::Cifar100];
            let decisions = [
                SplitDecision::Layer,
                SplitDecision::Semantic,
                SplitDecision::Compressed,
            ];
            for i in 0..6u64 {
                e.admit(
                    task(i, apps[i as usize % 3], 16_000 + 8_000 * i),
                    decisions[i as usize % 3],
                );
            }
            let mut bits = Vec::new();
            for round in 0..12 {
                let assigns: Vec<(ContainerId, usize)> = e
                    .placeable()
                    .into_iter()
                    .filter(|&c| matches!(e.containers[c].state, ContainerState::Queued))
                    .map(|c| (c, (c + round) % e.workers()))
                    .collect();
                e.apply_placement(&assigns);
                let r = e.step_interval();
                bits.push(r.energy_wh.to_bits());
                bits.push(r.aec.to_bits());
                for s in &r.snapshots {
                    bits.push(s.cpu.to_bits());
                    bits.push(s.ram.to_bits());
                    bits.push(s.net.to_bits());
                }
                for t in &r.completed {
                    bits.push(t.task_id);
                    bits.push(t.response.to_bits());
                    bits.push(t.exec.to_bits());
                }
                e.verify_indices().unwrap();
            }
            for c in e.containers() {
                bits.push(c.mi_done.to_bits());
                bits.push(c.t_exec.to_bits());
            }
            bits
        };
        let serial = run(1);
        // 64 > worker count exercises the clamp; 3 leaves a ragged tail
        for shards in [2, 3, 8, 64] {
            assert_eq!(run(shards), serial, "shards={shards} diverged from serial");
        }
    }

    #[test]
    fn shard_pool_threads_spawn_once_per_run() {
        let cluster = build_fleet(&ClusterConfig::small());
        let cfg = SimConfig { intervals: 10, shards: 4, ..Default::default() };
        let mut e = Engine::new(cluster, cfg, 1);
        assert!(e.pool.is_none(), "no lanes before the first sharded sub-step");
        e.admit(task(1, App::Mnist, 32_000), SplitDecision::Compressed);
        e.apply_placement(&[(0, 0)]);
        e.step_interval();
        let ids = e.pool.as_ref().expect("sharded run builds the pool").thread_ids();
        assert_eq!(ids.len(), 4);
        for _ in 0..5 {
            e.step_interval();
        }
        assert_eq!(
            e.pool.as_ref().unwrap().thread_ids(),
            ids,
            "lanes must be reused across intervals, never respawned"
        );
    }

    #[test]
    fn state_partitions_track_every_transition() {
        let mut e = engine();
        e.admit(task(1, App::Mnist, 16_000), SplitDecision::Layer);
        // chain of 3: fragment 0 Queued, successors Blocked
        assert_eq!(e.transit_ids().to_vec(), vec![0]);
        assert_eq!(e.blocked_ids().to_vec(), vec![1, 2]);
        e.verify_indices().unwrap();
        // reserving a worker for a Blocked successor is a membership no-op
        e.apply_placement(&[(0, 0), (1, 1), (2, 2)]);
        assert_eq!(e.transit_ids().to_vec(), vec![0]);
        assert_eq!(e.blocked_ids().to_vec(), vec![1, 2]);
        let mut done = false;
        for _ in 0..40 {
            let r = e.step_interval();
            e.verify_indices().unwrap();
            if !r.completed.is_empty() {
                done = true;
                break;
            }
        }
        assert!(done, "pre-reserved chain must complete");
        assert!(e.transit_ids().is_empty(), "terminal chain left transit entries");
        assert!(e.blocked_ids().is_empty(), "terminal chain left blocked entries");
    }

    #[test]
    fn fail_tasks_older_than_is_a_starvation_guard() {
        let mut e = engine();
        e.admit(task(1, App::Mnist, 32_000), SplitDecision::Compressed);
        for _ in 0..3 {
            e.step_interval(); // never placed: starves
        }
        assert_eq!(e.fail_tasks_older_than(2.0 * 300.0), 1);
        assert_eq!(e.fail_tasks_older_than(2.0 * 300.0), 0, "only once");
        assert_eq!(e.step_interval().failed.len(), 1);
    }
}
