//! Persistent CPU-shard worker pool.
//!
//! The sharded CPU phase used to fan out through `std::thread::scope`,
//! paying a full spawn/join cycle per **sub-step** — `sub_steps ×
//! intervals` spawns per run (10 × intervals by default), pure overhead
//! that grows with the horizon while the work per spawn shrinks with the
//! fleet's idle fraction. This pool spawns each lane's OS thread once,
//! the first time the engine integrates a sharded sub-step, and feeds it
//! work orders over a channel for the rest of the run: spawn cost drops
//! from per-sub-step to per-run, and the shard results are byte-identical
//! because the work function ([`Engine::cpu_shard`]) and the
//! apply-in-shard-order join are untouched.
//!
//! # Safety
//!
//! Lanes receive a raw `*const Engine` per job instead of a borrowed
//! reference, because a long-lived thread cannot hold a borrow of an
//! engine that lives on the caller's stack. The pointer is sound to
//! dereference under the dispatch protocol:
//!
//! * [`ShardPool::dispatch`] takes `&Engine`, sends every job, and does
//!   not return until it has received one reply per job — so the pointer
//!   is only ever dereferenced while the caller's borrow is live;
//! * the work function is `Engine::cpu_shard(&self, ..)` — read-only, no
//!   interior mutability on anything it touches (containers, residency
//!   indexes, cluster specs, fault factors, config are all plain data);
//! * lanes never touch `Engine::pool` itself, so the one field that is
//!   not `Sync` (channel endpoints) is never shared.
//!
//! Dropping the pool closes the job channels, which ends each lane's
//! receive loop; the drop then joins the threads, so no lane outlives the
//! engine that owns the pool.

use std::ops::Range;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

use super::lifecycle::CpuShard;
use super::state::Engine;

/// One CPU-phase work order: integrate the contiguous worker range
/// against the engine snapshot behind `engine`.
struct Job {
    engine: EnginePtr,
    workers: Range<usize>,
    dt: f64,
}

/// Send-wrapper for the engine pointer; see the module-level safety
/// argument for why moving it across threads is sound.
struct EnginePtr(*const Engine);
unsafe impl Send for EnginePtr {}

/// One long-lived worker thread plus its job/result channels.
struct Lane {
    /// `Option` so `Drop` can hang up the job channel before joining.
    tx: Option<Sender<Job>>,
    rx: Receiver<CpuShard>,
    handle: Option<JoinHandle<()>>,
}

impl Lane {
    fn spawn(idx: usize) -> Lane {
        let (job_tx, job_rx) = channel::<Job>();
        let (res_tx, res_rx) = channel::<CpuShard>();
        let handle = std::thread::Builder::new()
            .name(format!("cpu-shard-{idx}"))
            .spawn(move || {
                while let Ok(job) = job_rx.recv() {
                    // SAFETY: dispatch holds `&Engine` and blocks on our
                    // reply before returning (module doc), so the pointer
                    // is live and the engine unmutated for the read-only
                    // cpu_shard call.
                    let engine = unsafe { &*job.engine.0 };
                    let shard = engine.cpu_shard(job.workers, job.dt);
                    if res_tx.send(shard).is_err() {
                        break; // pool dropped mid-reply: shut down
                    }
                }
            })
            .expect("spawn cpu-shard lane");
        Lane { tx: Some(job_tx), rx: res_rx, handle: Some(handle) }
    }
}

impl Drop for Lane {
    fn drop(&mut self) {
        self.tx = None; // hang up: ends the lane's recv loop
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Engine-owned pool of persistent CPU-shard lanes, sized once from the
/// run's shard count ([`Engine::ensure_pool`] rebuilds only if the count
/// changes, which a fixed `SimConfig` never does — threads spawn at most
/// once per run).
pub(super) struct ShardPool {
    lanes: Vec<Lane>,
}

impl ShardPool {
    pub(super) fn new(lanes: usize) -> ShardPool {
        ShardPool { lanes: (0..lanes).map(Lane::spawn).collect() }
    }

    pub(super) fn lanes(&self) -> usize {
        self.lanes.len()
    }

    /// Test probe: the OS thread identity of every lane, in lane order —
    /// lets tests prove lanes are reused across intervals, not respawned.
    #[cfg(test)]
    pub(super) fn thread_ids(&self) -> Vec<std::thread::ThreadId> {
        self.lanes
            .iter()
            .map(|l| l.handle.as_ref().expect("lane alive").thread().id())
            .collect()
    }

    /// Run one CPU phase: ship `ranges[i]` to lane `i`, then collect the
    /// replies **in lane order** — the same shard order the scoped join
    /// produced, so the serial delta application downstream sees an
    /// identical sequence.
    pub(super) fn dispatch(
        &self,
        engine: &Engine,
        dt: f64,
        ranges: impl ExactSizeIterator<Item = Range<usize>>,
    ) -> Vec<CpuShard> {
        let n = ranges.len();
        assert!(n <= self.lanes.len(), "dispatch wider than the pool");
        for (lane, workers) in self.lanes.iter().zip(ranges) {
            let job = Job { engine: EnginePtr(engine as *const Engine), workers, dt };
            lane.tx.as_ref().expect("lane alive").send(job).expect("lane hung up");
        }
        // one blocking recv per job, in lane order: this is the barrier
        // the safety argument relies on — dispatch cannot return (and the
        // engine borrow cannot end) before every lane has replied
        self.lanes[..n]
            .iter()
            .map(|lane| lane.rx.recv().expect("lane died mid-phase"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::build_fleet;
    use crate::config::{ClusterConfig, SimConfig};
    use crate::sim::Engine;

    #[test]
    fn pool_spawns_joins_and_survives_reuse() {
        let e = Engine::new(build_fleet(&ClusterConfig::small()), SimConfig::default(), 1);
        let pool = ShardPool::new(3);
        assert_eq!(pool.lanes(), 3);
        let n = e.workers();
        let chunk = (n + 2) / 3;
        for _ in 0..5 {
            let ranges =
                (0..3).map(|s| (s * chunk).min(n)..((s + 1) * chunk).min(n));
            let shards = pool.dispatch(&e, 30.0, ranges);
            assert_eq!(shards.len(), 3);
            // idle fleet: every shard is empty, but the protocol ran
            assert!(shards.iter().all(|s| s.busy.is_empty() && s.exec.is_empty()));
        }
        drop(pool); // must hang up + join without deadlock
    }

    #[test]
    fn narrow_dispatch_uses_a_prefix_of_lanes() {
        let e = Engine::new(build_fleet(&ClusterConfig::small()), SimConfig::default(), 1);
        let pool = ShardPool::new(4);
        let shards = pool.dispatch(&e, 30.0, (0..2).map(|s| s * 5..(s + 1) * 5));
        assert_eq!(shards.len(), 2);
    }
}
