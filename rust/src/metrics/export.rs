//! CSV export of experiment telemetry (per-interval series + per-task
//! table) for offline plotting of the paper figures.

use std::io::Write as _;
use std::path::Path;

use anyhow::{Context as _, Result};

use super::Metrics;

/// Write `intervals.csv` (per-interval series) and `tasks.csv` (one row
/// per completed task) into `dir`.
pub fn write_csv(metrics: &Metrics, dir: impl AsRef<Path>) -> Result<()> {
    let dir = dir.as_ref();
    std::fs::create_dir_all(dir)?;

    let mut f = std::fs::File::create(dir.join("intervals.csv"))
        .context("creating intervals.csv")?;
    writeln!(
        f,
        "interval,energy_wh,aec,art,sched_s,queued,failed,o_mab,layer_fraction"
    )?;
    let n = metrics.energy_wh.len();
    for i in 0..n {
        let lf = metrics.layer_fraction.get(i).copied().unwrap_or(f64::NAN);
        writeln!(
            f,
            "{},{},{},{},{},{},{},{},{}",
            i,
            metrics.energy_wh[i],
            metrics.aec[i],
            metrics.art.get(i).copied().unwrap_or(f64::NAN),
            metrics.sched_s[i],
            metrics.queued.get(i).copied().unwrap_or(0),
            metrics.failed.get(i).copied().unwrap_or(0),
            metrics.o_mab.get(i).copied().unwrap_or(f64::NAN),
            lf,
        )?;
    }

    let mut f =
        std::fs::File::create(dir.join("tasks.csv")).context("creating tasks.csv")?;
    writeln!(
        f,
        "task_id,app,decision,batch,sla,response,wait,exec,transfer,migrate,accuracy,violated,n_workers"
    )?;
    for t in &metrics.completed {
        writeln!(
            f,
            "{},{},{},{},{},{},{},{},{},{},{},{},{}",
            t.task_id,
            t.app.name(),
            t.decision.name(),
            t.batch,
            t.sla,
            t.response,
            t.wait,
            t.exec,
            t.transfer,
            t.migrate,
            t.accuracy,
            (t.response > t.sla) as u8,
            t.workers.len(),
        )?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{CompletedTask, IntervalReport, WorkerSnapshot};
    use crate::splits::{App, SplitDecision};

    #[test]
    fn csv_roundtrip_shape() {
        let mut m = Metrics::new(2, 1.0, 300.0);
        m.record_decisions(&[SplitDecision::Layer]);
        m.record_interval(
            &IntervalReport {
                interval: 0,
                failed: vec![],
                completed: vec![CompletedTask {
                    task_id: 1,
                    app: App::Mnist,
                    decision: SplitDecision::Layer,
                    batch: 16_000,
                    sla: 5.0,
                    response: 4.0,
                    wait: 0.5,
                    exec: 3.0,
                    transfer: 0.4,
                    migrate: 0.1,
                    workers: vec![0, 1],
                    accuracy: 0.97,
                }],
                energy_wh: 12.0,
                aec: 0.4,
                snapshots: vec![WorkerSnapshot::default(); 2],
                queued: 3,
                offline: 0,
            },
            0.02,
            0.8,
        );
        let dir = std::env::temp_dir().join("splitplace_csv_test");
        write_csv(&m, &dir).unwrap();
        let intervals = std::fs::read_to_string(dir.join("intervals.csv")).unwrap();
        assert_eq!(intervals.lines().count(), 2);
        assert!(intervals.lines().nth(1).unwrap().starts_with("0,12,0.4,4,"));
        let tasks = std::fs::read_to_string(dir.join("tasks.csv")).unwrap();
        assert_eq!(tasks.lines().count(), 2);
        assert!(tasks.contains("mnist,layer,16000,5,4,0.5,3,0.4,0.1,0.97,0,2"));
    }
}
