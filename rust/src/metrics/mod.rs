//! Experiment metrics: the paper's evaluation quantities (§6.4, eqs.
//! 13–16) plus the per-interval series the figures plot.

pub mod export;

use std::collections::HashMap;

use crate::sim::{CompletedTask, IntervalReport};
use crate::splits::{App, SplitDecision};
use crate::util::stats::{self, Welford};

/// Aggregated results of one experiment run.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    /// All leaving tasks, in completion order.
    pub completed: Vec<CompletedTask>,
    /// Per-interval total energy (watt-hours).
    pub energy_wh: Vec<f64>,
    /// Per-interval normalized AEC.
    pub aec: Vec<f64>,
    /// Per-interval normalized ART (response of that interval's leavers).
    pub art: Vec<f64>,
    /// Per-interval scheduling overhead (seconds of broker decision time).
    pub sched_s: Vec<f64>,
    /// Per-interval queue length at interval end.
    pub queued: Vec<usize>,
    /// Per-interval count of abandoned (failed) tasks — nonzero only under
    /// fault injection / starvation guards.
    pub failed: Vec<usize>,
    /// Running total of failed tasks. A failed task is a blown SLA and a
    /// zero-reward outcome, so the eq. 13–15 metrics count it — otherwise
    /// a policy that strands tasks would beat one that finishes them late.
    pub failed_total: usize,
    /// Per-interval O^MAB (reward signal trace, Fig. 6).
    pub o_mab: Vec<f64>,
    /// Containers executed per worker (fairness input).
    pub per_worker_containers: Vec<f64>,
    /// Per-interval fraction of layer decisions among new tasks (Figs. 11–12).
    pub layer_fraction: Vec<f64>,
    /// Cluster cost rate, $/hour (constant for a static fleet).
    pub cost_per_hour: f64,
    /// Interval length (seconds), for cost/energy integration.
    pub interval_seconds: f64,
}

/// Scalar summary = one row of Table 4.
#[derive(Clone, Debug)]
pub struct Summary {
    pub policy: String,
    pub energy_mwh: f64,
    pub sched_time_s: (f64, f64),
    pub fairness: f64,
    pub wait: (f64, f64),
    pub response: (f64, f64),
    pub sla_violations: f64,
    pub accuracy: f64,
    pub avg_reward: f64,
    pub exec: (f64, f64),
    pub transfer_mean: f64,
    pub migrate_mean: f64,
    pub cost_usd: f64,
    pub cost_per_container: f64,
    pub tasks: usize,
}

impl Metrics {
    pub fn new(workers: usize, cost_per_hour: f64, interval_seconds: f64) -> Self {
        Metrics {
            per_worker_containers: vec![0.0; workers],
            cost_per_hour,
            interval_seconds,
            ..Default::default()
        }
    }

    /// Record one simulated interval (tasks must already carry accuracy).
    pub fn record_interval(&mut self, report: &IntervalReport, sched_s: f64, o_mab: f64) {
        self.energy_wh.push(report.energy_wh);
        self.aec.push(report.aec);
        self.sched_s.push(sched_s);
        self.queued.push(report.queued);
        self.failed.push(report.failed.len());
        self.failed_total += report.failed.len();
        self.o_mab.push(o_mab);
        let art = stats::mean(
            &report
                .completed
                .iter()
                .map(|t| t.response)
                .collect::<Vec<_>>(),
        );
        self.art.push(art);
        for t in &report.completed {
            for &w in &t.workers {
                if w < self.per_worker_containers.len() {
                    self.per_worker_containers[w] += 1.0;
                }
            }
        }
        self.completed.extend(report.completed.iter().cloned());
    }

    pub fn record_decisions(&mut self, decisions: &[SplitDecision]) {
        if decisions.is_empty() {
            self.layer_fraction.push(f64::NAN);
            return;
        }
        let layer = decisions
            .iter()
            .filter(|d| matches!(d, SplitDecision::Layer))
            .count();
        self.layer_fraction.push(layer as f64 / decisions.len() as f64);
    }

    // ---- paper metrics -----------------------------------------------

    /// Eq. 13: mean task accuracy.
    pub fn accuracy(&self) -> f64 {
        stats::mean(
            &self
                .completed
                .iter()
                .filter(|t| t.accuracy.is_finite())
                .map(|t| t.accuracy)
                .collect::<Vec<_>>(),
        )
    }

    /// Eq. 14: fraction of leaving tasks with response > SLA. A failed
    /// (abandoned) task never met its deadline, so it counts as violated.
    pub fn sla_violations(&self) -> f64 {
        let n = self.completed.len() + self.failed_total;
        if n == 0 {
            return 0.0;
        }
        let late = self.completed.iter().filter(|t| t.response > t.sla).count();
        (late + self.failed_total) as f64 / n as f64
    }

    /// Eq. 15: mean of (1(r≤sla) + p)/2 over leaving tasks; a failed task
    /// contributes reward 0.
    pub fn avg_reward(&self) -> f64 {
        let n = self.completed.len() + self.failed_total;
        if n == 0 {
            return 0.0;
        }
        let sum = crate::util::accum::sum(self.completed.iter().map(|t| {
            let ok = if t.response <= t.sla { 1.0 } else { 0.0 };
            let p = if t.accuracy.is_finite() { t.accuracy } else { 0.0 };
            (ok + p) / 2.0
        }));
        sum / n as f64
    }

    /// Eq. 16: fleet cost over the run (static fleet ⇒ rate × wall time).
    pub fn cost_usd(&self) -> f64 {
        let hours = self.energy_wh.len() as f64 * self.interval_seconds / 3600.0;
        self.cost_per_hour * hours
    }

    /// Jain fairness over per-worker executed-container counts.
    pub fn fairness(&self) -> f64 {
        stats::jain_fairness(&self.per_worker_containers)
    }

    /// Response-time EMA over leaving tasks in completion order (φ-weighted
    /// like the MAB's eq. 2 smoothing). The matrix harness's headline
    /// latency figure: robust to tail noise but still order-sensitive, so
    /// a replay that reorders completions drifts immediately. NaN when no
    /// task has left the system.
    pub fn response_ema(&self, phi: f64) -> f64 {
        let mut ema = f64::NAN;
        for t in &self.completed {
            ema = if ema.is_nan() { t.response } else { phi * ema + (1.0 - phi) * t.response };
        }
        ema
    }

    fn dist(&self, f: impl Fn(&CompletedTask) -> f64) -> (f64, f64) {
        let xs: Vec<f64> = self.completed.iter().map(f).collect();
        (stats::mean(&xs), stats::std(&xs))
    }

    pub fn summary(&self, policy: &str) -> Summary {
        let (resp_m, resp_s) = self.dist(|t| t.response);
        let (wait_m, wait_s) = self.dist(|t| t.wait);
        let (exec_m, exec_s) = self.dist(|t| t.exec);
        let n = self.completed.len().max(1);
        Summary {
            policy: policy.to_string(),
            energy_mwh: crate::util::accum::sum(self.energy_wh.iter().copied()) / 1e6,
            sched_time_s: (stats::mean(&self.sched_s), stats::std(&self.sched_s)),
            fairness: self.fairness(),
            wait: (wait_m, wait_s),
            response: (resp_m, resp_s),
            sla_violations: self.sla_violations(),
            accuracy: self.accuracy(),
            avg_reward: self.avg_reward(),
            exec: (exec_m, exec_s),
            transfer_mean: self.dist(|t| t.transfer).0,
            migrate_mean: self.dist(|t| t.migrate).0,
            cost_usd: self.cost_usd(),
            cost_per_container: self.cost_usd() / n as f64,
            tasks: self.completed.len(),
        }
    }

    /// Per-app breakdown: (accuracy, response mean, violations) — Fig. 7's
    /// per-application panels and Fig. 15.
    pub fn per_app(&self) -> HashMap<App, (f64, f64, f64)> {
        let mut out = HashMap::new();
        for app in crate::splits::APPS {
            let ts: Vec<&CompletedTask> =
                self.completed.iter().filter(|t| t.app == app).collect();
            if ts.is_empty() {
                continue;
            }
            let acc = stats::mean(&ts.iter().map(|t| t.accuracy).collect::<Vec<_>>());
            let resp = stats::mean(&ts.iter().map(|t| t.response).collect::<Vec<_>>());
            let viol = ts.iter().filter(|t| t.response > t.sla).count() as f64
                / ts.len() as f64;
            out.insert(app, (acc, resp, viol));
        }
        out
    }

    /// Response-time decomposition means (Fig. 14): wait, exec, transfer,
    /// migrate, scheduling (per-task amortized).
    pub fn decomposition(&self) -> [f64; 5] {
        let n = self.completed.len().max(1) as f64;
        let sched_per_task =
            crate::util::accum::sum(self.sched_s.iter().copied()) / n / self.interval_seconds;
        [
            self.dist(|t| t.wait).0,
            self.dist(|t| t.exec).0,
            self.dist(|t| t.transfer).0,
            self.dist(|t| t.migrate).0,
            sched_per_task,
        ]
    }

    /// Response-time stats per decision (Fig. 2 / Fig. 19).
    pub fn per_decision_response(&self) -> HashMap<SplitDecision, (f64, f64)> {
        let mut out = HashMap::new();
        for d in [
            SplitDecision::Layer,
            SplitDecision::Semantic,
            SplitDecision::Compressed,
            SplitDecision::Full,
        ] {
            let xs: Vec<f64> = self
                .completed
                .iter()
                .filter(|t| t.decision == d)
                .map(|t| t.response)
                .collect();
            if !xs.is_empty() {
                out.insert(d, (stats::mean(&xs), stats::std(&xs)));
            }
        }
        out
    }

    /// Mean RAM-pressure proxy: upper-bound utilization indicator used for
    /// the "32% lower RAM utilization" claim — mean queued containers.
    pub fn mean_queue(&self) -> f64 {
        stats::mean(&self.queued.iter().map(|&q| q as f64).collect::<Vec<_>>())
    }
}

/// Running aggregate over several seeded runs of the same scenario.
#[derive(Clone, Debug, Default)]
pub struct MultiRun {
    pub reward: Welford,
    pub accuracy: Welford,
    pub response: Welford,
    pub violations: Welford,
    pub energy: Welford,
}

impl MultiRun {
    pub fn push(&mut self, s: &Summary) {
        self.reward.push(s.avg_reward);
        self.accuracy.push(s.accuracy);
        self.response.push(s.response.0);
        self.violations.push(s.sla_violations);
        self.energy.push(s.energy_mwh);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::WorkerSnapshot;

    fn done(app: App, d: SplitDecision, response: f64, sla: f64, acc: f64) -> CompletedTask {
        CompletedTask {
            task_id: 0,
            app,
            decision: d,
            batch: 1000,
            sla,
            response,
            wait: 0.5,
            exec: response - 0.5,
            transfer: 0.1,
            migrate: 0.0,
            workers: vec![0, 1],
            accuracy: acc,
        }
    }

    fn report(completed: Vec<CompletedTask>) -> IntervalReport {
        IntervalReport {
            interval: 0,
            completed,
            failed: vec![],
            energy_wh: 1000.0,
            aec: 0.5,
            snapshots: vec![WorkerSnapshot::default(); 4],
            queued: 2,
            offline: 0,
        }
    }

    fn metrics_with(tasks: Vec<CompletedTask>) -> Metrics {
        let mut m = Metrics::new(4, 10.0, 300.0);
        m.record_interval(&report(tasks), 0.1, 0.9);
        m
    }

    #[test]
    fn eq13_accuracy() {
        let m = metrics_with(vec![
            done(App::Mnist, SplitDecision::Layer, 2.0, 5.0, 0.9),
            done(App::Mnist, SplitDecision::Semantic, 1.0, 5.0, 0.8),
        ]);
        assert!((m.accuracy() - 0.85).abs() < 1e-12);
    }

    #[test]
    fn eq14_sla_violations() {
        let m = metrics_with(vec![
            done(App::Mnist, SplitDecision::Layer, 6.0, 5.0, 0.9), // violated
            done(App::Mnist, SplitDecision::Layer, 2.0, 5.0, 0.9),
        ]);
        assert!((m.sla_violations() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn failed_tasks_count_as_violations_and_zero_reward() {
        let mut m = Metrics::new(4, 10.0, 300.0);
        let mut r = report(vec![done(App::Mnist, SplitDecision::Layer, 2.0, 5.0, 1.0)]);
        r.failed = vec![crate::sim::FailedTask {
            task_id: 9,
            app: App::Mnist,
            decision: SplitDecision::Layer,
            batch: 1000,
            sla: 5.0,
            age: 40.0,
        }];
        m.record_interval(&r, 0.1, 0.9);
        // one perfect completion (reward 1, in-SLA) + one failure:
        // violations 1/2, reward (1 + 0)/2
        assert!((m.sla_violations() - 0.5).abs() < 1e-12);
        assert!((m.avg_reward() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn eq15_reward() {
        let m = metrics_with(vec![
            done(App::Mnist, SplitDecision::Layer, 2.0, 5.0, 1.0), // (1+1)/2
            done(App::Mnist, SplitDecision::Layer, 9.0, 5.0, 0.5), // (0+.5)/2
        ]);
        assert!((m.avg_reward() - (1.0 + 0.25) / 2.0).abs() < 1e-12);
    }

    #[test]
    fn eq16_cost_scales_with_time() {
        let mut m = Metrics::new(4, 7.2, 300.0);
        for _ in 0..12 {
            m.record_interval(&report(vec![]), 0.0, 0.0);
        }
        // 12 intervals × 300 s = 1 h at $7.2/h
        assert!((m.cost_usd() - 7.2).abs() < 1e-9);
    }

    #[test]
    fn fairness_counts_workers() {
        let m = metrics_with(vec![done(App::Mnist, SplitDecision::Layer, 1.0, 5.0, 1.0)]);
        // workers 0 and 1 each executed once; 2 and 3 idle
        assert!((m.fairness() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn per_app_and_per_decision() {
        let m = metrics_with(vec![
            done(App::Mnist, SplitDecision::Layer, 4.0, 5.0, 0.99),
            done(App::Cifar100, SplitDecision::Semantic, 2.0, 5.0, 0.55),
        ]);
        let per = m.per_app();
        assert_eq!(per.len(), 2);
        assert!((per[&App::Mnist].0 - 0.99).abs() < 1e-12);
        let pd = m.per_decision_response();
        assert!((pd[&SplitDecision::Semantic].0 - 2.0).abs() < 1e-12);
    }

    #[test]
    fn response_ema_weights_recent_tasks() {
        let mut m = Metrics::new(4, 10.0, 300.0);
        assert!(m.response_ema(0.9).is_nan(), "no completions yet");
        m.record_interval(
            &report(vec![
                done(App::Mnist, SplitDecision::Layer, 10.0, 5.0, 0.9),
                done(App::Mnist, SplitDecision::Layer, 2.0, 5.0, 0.9),
            ]),
            0.1,
            0.9,
        );
        // seeded at 10, then 0.9·10 + 0.1·2 = 9.2
        assert!((m.response_ema(0.9) - 9.2).abs() < 1e-12);
        // φ = 0 tracks the latest completion exactly
        assert!((m.response_ema(0.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn layer_fraction_series() {
        let mut m = Metrics::new(2, 1.0, 300.0);
        m.record_decisions(&[SplitDecision::Layer, SplitDecision::Semantic]);
        m.record_decisions(&[]);
        assert!((m.layer_fraction[0] - 0.5).abs() < 1e-12);
        assert!(m.layer_fraction[1].is_nan());
    }

    #[test]
    fn summary_assembles() {
        let m = metrics_with(vec![done(App::Mnist, SplitDecision::Layer, 2.0, 5.0, 0.9)]);
        let s = m.summary("Test");
        assert_eq!(s.tasks, 1);
        assert!(s.energy_mwh > 0.0);
        assert!((s.response.0 - 2.0).abs() < 1e-12);
        assert!(s.cost_per_container > 0.0);
    }
}
