//! Model-Compression baseline (BottleNet++-flavored, §6.5): every task runs
//! the magnitude-pruned single-container model. Fast-ish and memory-light,
//! but pays a permanent accuracy penalty — the trade-off Table 4 shows.

use crate::splits::SplitDecision;
use crate::workload::Task;

#[derive(Clone, Copy, Debug, Default)]
pub struct McPolicy;

impl McPolicy {
    pub fn new() -> Self {
        McPolicy
    }

    pub fn decide(&mut self, _task: &Task) -> SplitDecision {
        SplitDecision::Compressed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::splits::App;

    #[test]
    fn always_compressed() {
        let mut p = McPolicy::new();
        for i in 0..10 {
            let t = Task {
                id: i,
                app: App::FashionMnist,
                batch: 20_000,
                sla: 3.0,
                arrival_s: 0.0,
                decision: None,
            };
            assert_eq!(p.decide(&t), SplitDecision::Compressed);
        }
    }
}
