//! Gillis baseline (Yu et al., ICDCS'21, as characterized in §2.1/§6.5):
//! a reinforcement-learning model-serving policy that chooses between
//! layer-partitioned execution and model compression per request, adapting
//! online. It cannot use semantic splits (those need retraining per
//! partitioning scheme), which is exactly the capability gap SplitPlace
//! exploits.
//!
//! Implementation: tabular Q-learning over (app, SLA band) states with
//! actions {Layer, Compressed}, ε-greedy with multiplicative decay.

use crate::sim::CompletedTask;
use crate::splits::{App, SplitDecision};
use crate::util::rng::Rng;
use crate::workload::Task;

const ACTIONS: [SplitDecision; 2] = [SplitDecision::Layer, SplitDecision::Compressed];
/// SLA bands relative to the app's nominal layer response time.
const BANDS: usize = 3;

#[derive(Clone, Debug)]
pub struct GillisPolicy {
    /// Q[app][band][action]
    q: [[[f64; 2]; BANDS]; 3],
    n: [[[u64; 2]; BANDS]; 3],
    epsilon: f64,
    alpha: f64,
    rng: Rng,
    /// task id -> (app, band, action) for delayed reward assignment
    pending: std::collections::HashMap<u64, (usize, usize, usize)>,
}

fn band_of(task_sla: f64, app: App) -> usize {
    let rel = task_sla / app.nominal_layer_rt();
    if rel < 0.9 {
        0
    } else if rel < 1.3 {
        1
    } else {
        2
    }
}

impl GillisPolicy {
    pub fn new(seed: u64) -> Self {
        GillisPolicy {
            // optimistic init so both actions get explored
            q: [[[0.6; 2]; BANDS]; 3],
            n: [[[0; 2]; BANDS]; 3],
            epsilon: 0.3,
            alpha: 0.15,
            rng: Rng::new(seed),
            pending: std::collections::HashMap::new(),
        }
    }

    pub fn decide(&mut self, task: &Task) -> SplitDecision {
        let a = task.app.index();
        let b = band_of(task.sla, task.app);
        let act = if self.rng.chance(self.epsilon) {
            self.rng.below(2) as usize
        } else if self.q[a][b][0] >= self.q[a][b][1] {
            0
        } else {
            1
        };
        self.n[a][b][act] += 1;
        self.pending.insert(task.id, (a, b, act));
        ACTIONS[act]
    }

    /// Online Q update from leaving tasks (same reward as eq. 15's term).
    pub fn observe(&mut self, leaving: &[CompletedTask]) {
        for t in leaving {
            if let Some((a, b, act)) = self.pending.remove(&t.task_id) {
                let sla_ok = if t.response <= t.sla { 1.0 } else { 0.0 };
                let p = if t.accuracy.is_finite() { t.accuracy } else { 0.0 };
                let r = (sla_ok + p) / 2.0;
                self.q[a][b][act] += self.alpha * (r - self.q[a][b][act]);
            }
        }
        // slow exploration decay, floor at 5% (Gillis "continuously adapts")
        self.epsilon = (self.epsilon * 0.995).max(0.05);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::splits::App;

    fn task(id: u64, app: App, sla: f64) -> Task {
        Task { id, app, batch: 32_000, sla, arrival_s: 0.0, decision: None }
    }

    fn done(id: u64, d: SplitDecision, response: f64, sla: f64, acc: f64) -> CompletedTask {
        CompletedTask {
            task_id: id,
            app: App::Mnist,
            decision: d,
            batch: 32_000,
            sla,
            response,
            wait: 0.0,
            exec: response,
            transfer: 0.0,
            migrate: 0.0,
            workers: vec![0],
            accuracy: acc,
        }
    }

    #[test]
    fn decisions_are_layer_or_compressed_only() {
        let mut g = GillisPolicy::new(1);
        for i in 0..100 {
            let d = g.decide(&task(i, App::Cifar100, 5.0));
            assert!(matches!(d, SplitDecision::Layer | SplitDecision::Compressed));
        }
    }

    #[test]
    fn learns_compression_for_tight_slas() {
        let mut g = GillisPolicy::new(2);
        // tight SLA: layer always violates, compressed always meets
        for round in 0..300 {
            let t = task(round, App::Mnist, 2.0); // band 0 (< 0.9 * 4.5)
            let d = g.decide(&t);
            let (resp, acc) = match d {
                SplitDecision::Layer => (5.0, 0.99),
                SplitDecision::Compressed => (1.0, 0.9),
                _ => unreachable!(),
            };
            g.observe(&[done(round, d, resp, 2.0, acc)]);
        }
        assert!(
            g.q[0][0][1] > g.q[0][0][0],
            "compressed must win the tight band: {:?}",
            g.q[0][0]
        );
    }

    #[test]
    fn learns_layer_for_loose_slas() {
        let mut g = GillisPolicy::new(3);
        for round in 0..300 {
            let t = task(round, App::Mnist, 9.0); // band 2
            let d = g.decide(&t);
            let (resp, acc) = match d {
                SplitDecision::Layer => (5.0, 0.99),
                SplitDecision::Compressed => (1.0, 0.80),
                _ => unreachable!(),
            };
            g.observe(&[done(round, d, resp, 9.0, acc)]);
        }
        assert!(
            g.q[0][2][0] > g.q[0][2][1],
            "layer must win the loose band: {:?}",
            g.q[0][2]
        );
    }

    #[test]
    fn epsilon_decays_to_floor() {
        let mut g = GillisPolicy::new(4);
        for i in 0..2000 {
            g.observe(&[done(i, SplitDecision::Layer, 1.0, 5.0, 1.0)]);
        }
        assert!((g.epsilon - 0.05).abs() < 1e-9);
    }
}
