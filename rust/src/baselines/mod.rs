//! Baseline policies from the paper's evaluation (§6.5): Gillis (RL over
//! layer-partitioning + compression, no semantic arm) and BottleNet++-style
//! Model Compression.

pub mod gillis;
pub mod mc;

pub use gillis::GillisPolicy;
pub use mc::McPolicy;
