//! PJRT runtime: loads the AOT artifacts (HLO text + binary blobs) emitted
//! by `python/compile/aot.py` and executes them on the CPU PJRT client.
//! This is the only place the `xla` crate is touched; Python never runs at
//! request time.

pub mod artifacts;
pub mod client;
pub mod infer;
pub mod surrogate;

pub use artifacts::Manifest;
pub use client::Runtime;
pub use infer::InferenceEngine;
pub use surrogate::Surrogate;
