//! PJRT client wrapper with an executable cache: each HLO-text artifact is
//! parsed and compiled once, then reused across the whole run.

use std::collections::HashMap;
use std::sync::Mutex;

use anyhow::{Context as _, Result};

use super::artifacts::Manifest;

/// Shared runtime: one PJRT CPU client + compiled-executable cache.
pub struct Runtime {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    pub fn new(manifest: Manifest) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, manifest, cache: Mutex::new(HashMap::new()) })
    }

    pub fn load(artifacts_dir: &str) -> Result<Self> {
        Self::new(Manifest::load(artifacts_dir)?)
    }

    /// Compile (or fetch from cache) the executable for an HLO-text file.
    pub fn executable(&self, hlo_file: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(hlo_file) {
            return Ok(exe.clone());
        }
        let path = self.manifest.path(hlo_file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))?,
        );
        self.cache
            .lock()
            .unwrap()
            .insert(hlo_file.to_string(), exe.clone());
        Ok(exe)
    }

    /// Number of compiled executables currently cached.
    pub fn cached(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    /// Execute an artifact with literal inputs (owned or borrowed);
    /// returns the (tuple) output decomposed into element literals.
    ///
    /// Inputs are staged through caller-owned `PjRtBuffer`s and executed
    /// with `execute_b`: the crate's `execute` leaks its implicitly-created
    /// input device buffers (~input-size bytes per call — §Perf iteration
    /// 4), whereas buffers created here are freed on drop.
    pub fn run<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        hlo_file: &str,
        inputs: &[L],
    ) -> Result<Vec<xla::Literal>> {
        let bufs: Vec<xla::PjRtBuffer> = inputs
            .iter()
            .map(|l| self.client.buffer_from_host_literal(None, l.borrow()))
            .collect::<Result<_, _>>()
            .context("staging input buffers")?;
        self.run_b(hlo_file, &bufs)
    }

    /// Execute with pre-staged device buffers (hot path: callers keep
    /// long-lived inputs — e.g. surrogate parameters — device-resident).
    pub fn run_b<B: std::borrow::Borrow<xla::PjRtBuffer>>(
        &self,
        hlo_file: &str,
        inputs: &[B],
    ) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(hlo_file)?;
        let result = exe
            .execute_b::<B>(inputs)
            .with_context(|| format!("executing {hlo_file}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .context("copying result to host")?;
        Ok(out.to_tuple()?)
    }

    /// Stage an f32 tensor on the device.
    pub fn buffer_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }
}

/// Build an f32 literal of the given shape from a flat slice.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(
        n as usize == data.len(),
        "literal shape {dims:?} wants {n} elements, got {}",
        data.len()
    );
    if dims.len() == 1 {
        return Ok(xla::Literal::vec1(data));
    }
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Scalar f32 literal.
pub fn literal_scalar(x: f32) -> xla::Literal {
    xla::Literal::scalar(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn runtime() -> Option<Runtime> {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !d.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(Runtime::load(d.to_str().unwrap()).unwrap())
    }

    #[test]
    fn compile_and_cache() {
        let Some(rt) = runtime() else { return };
        let app = &rt.manifest.apps[&crate::splits::App::Mnist];
        let hlo = app.layer[0].hlo.clone();
        assert_eq!(rt.cached(), 0);
        rt.executable(&hlo).unwrap();
        assert_eq!(rt.cached(), 1);
        rt.executable(&hlo).unwrap();
        assert_eq!(rt.cached(), 1, "second load must hit the cache");
    }

    #[test]
    fn run_layer_fragment() {
        let Some(rt) = runtime() else { return };
        let m = &rt.manifest;
        let app = &m.apps[&crate::splits::App::Mnist];
        let batch = m.eval_batch;
        let x = vec![0.1f32; batch * app.input_dim];
        let lit = literal_f32(&x, &[batch as i64, app.input_dim as i64]).unwrap();
        let out = rt.run(&app.layer[0].hlo, &[lit]).unwrap();
        assert_eq!(out.len(), 1);
        let v = out[0].to_vec::<f32>().unwrap();
        assert_eq!(v.len(), batch * app.layer[0].out_dim);
        assert!(v.iter().all(|x| x.is_finite()));
        // relu output: non-negative
        assert!(v.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn literal_shape_mismatch_rejected() {
        let data = [1.0f32; 6];
        assert!(literal_f32(&data, &[2, 3]).is_ok());
        assert!(literal_f32(&data, &[2, 4]).is_err());
    }
}
