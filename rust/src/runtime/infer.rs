//! Split-fragment inference execution: runs the real AOT HLO modules for a
//! task's split plan (chain forwarding for layer splits, parallel fan-out +
//! logit concat for semantic — what the paper does with scp/rsync +
//! torch.cat) and measures top-1 accuracy on held-out data.

use std::collections::HashMap;

use anyhow::Result;

use super::client::{literal_f32, Runtime};
use crate::splits::{App, SplitDecision};

/// Cached held-out evaluation data for one app.
struct EvalData {
    x: Vec<f32>,
    y: Vec<i32>,
    rows: usize,
    dim: usize,
}

/// Executes split plans on the PJRT runtime.
pub struct InferenceEngine<'rt> {
    rt: &'rt Runtime,
    data: HashMap<App, EvalData>,
}

/// Result of one real inference execution.
#[derive(Clone, Debug)]
pub struct InferenceResult {
    pub accuracy: f64,
    pub rows: usize,
    /// Wall-clock seconds spent inside PJRT execute calls.
    pub compute_s: f64,
    /// Logits of the evaluated batch (row-major `rows × classes`).
    pub logits: Vec<f32>,
}

impl<'rt> InferenceEngine<'rt> {
    pub fn new(rt: &'rt Runtime) -> Result<Self> {
        let mut data = HashMap::new();
        for (&app, a) in &rt.manifest.apps {
            data.insert(
                app,
                EvalData {
                    x: rt.manifest.read_f32(&a.data_x)?,
                    y: rt.manifest.read_i32(&a.data_y)?,
                    rows: a.data_rows,
                    dim: a.input_dim,
                },
            );
        }
        Ok(InferenceEngine { rt, data })
    }

    /// Warm the executable cache for every fragment of (app, decision) —
    /// the paper's one-time container-image distribution step.
    pub fn warm(&self, app: App, d: SplitDecision) -> Result<()> {
        for f in self.rt.manifest.apps[&app].fragments(d) {
            self.rt.executable(&f.hlo)?;
        }
        Ok(())
    }

    /// Run a split plan on (a batch-sized slice of) the held-out data and
    /// return measured accuracy. `batch` rows must equal the AOT batch.
    pub fn run(&self, app: App, d: SplitDecision) -> Result<InferenceResult> {
        let a = &self.rt.manifest.apps[&app];
        let ev = &self.data[&app];
        let batch = self.rt.manifest.eval_batch.min(ev.rows);
        let x = &ev.x[..batch * ev.dim];
        let t0 = std::time::Instant::now();

        let logits: Vec<f32> = match d {
            SplitDecision::Layer => {
                // sequential chain: output of k feeds k+1
                let mut h = x.to_vec();
                let mut dim = ev.dim;
                for f in &a.layer {
                    let lit = literal_f32(&h, &[batch as i64, dim as i64])?;
                    let out = self.rt.run(&f.hlo, &[lit])?;
                    h = out[0].to_vec::<f32>()?;
                    dim = f.out_dim;
                }
                h
            }
            SplitDecision::Semantic => {
                // parallel fan-out; concat group logits in class order
                let lit = literal_f32(x, &[batch as i64, ev.dim as i64])?;
                let mut parts = Vec::new();
                for f in &a.semantic {
                    let out = self.rt.run(&f.hlo, &[lit.reshape(
                        &[batch as i64, ev.dim as i64],
                    )?])?;
                    parts.push((out[0].to_vec::<f32>()?, f.out_dim));
                }
                let classes: usize = parts.iter().map(|(_, d)| d).sum();
                let mut merged = vec![0.0f32; batch * classes];
                let mut off = 0;
                for (p, pd) in &parts {
                    for r in 0..batch {
                        merged[r * classes + off..r * classes + off + pd]
                            .copy_from_slice(&p[r * pd..(r + 1) * pd]);
                    }
                    off += pd;
                }
                merged
            }
            SplitDecision::Compressed | SplitDecision::Full => {
                let f = if d == SplitDecision::Compressed { &a.compressed } else { &a.full };
                let lit = literal_f32(x, &[batch as i64, ev.dim as i64])?;
                self.rt.run(&f.hlo, &[lit])?[0].to_vec::<f32>()?
            }
        };

        let compute_s = t0.elapsed().as_secs_f64();
        let classes = a.classes;
        anyhow::ensure!(logits.len() == batch * classes, "logit shape mismatch");
        let mut correct = 0usize;
        for r in 0..batch {
            let row = &logits[r * classes..(r + 1) * classes];
            let argmax = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if argmax as i32 == ev.y[r] {
                correct += 1;
            }
        }
        Ok(InferenceResult {
            accuracy: correct as f64 / batch as f64,
            rows: batch,
            compute_s,
            logits,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn runtime() -> Option<Runtime> {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !d.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(Runtime::load(d.to_str().unwrap()).unwrap())
    }

    #[test]
    fn measured_accuracy_matches_manifest() {
        let Some(rt) = runtime() else { return };
        let eng = InferenceEngine::new(&rt).unwrap();
        for app in crate::splits::APPS {
            let a = &rt.manifest.apps[&app];
            for (d, expected) in [
                (SplitDecision::Layer, a.accuracy_layer),
                (SplitDecision::Semantic, a.accuracy_semantic),
                (SplitDecision::Compressed, a.accuracy_compressed),
            ] {
                let r = eng.run(app, d).unwrap();
                // manifest accuracy was measured on the full 512-row split;
                // we evaluate the first 256 rows, so allow sampling slack.
                assert!(
                    (r.accuracy - expected).abs() < 0.08,
                    "{app:?}/{d:?}: measured {} vs manifest {expected}",
                    r.accuracy
                );
            }
        }
    }

    #[test]
    fn layer_equals_full_pipeline() {
        // composing the layer-fragment HLOs must reproduce the full model
        let Some(rt) = runtime() else { return };
        let eng = InferenceEngine::new(&rt).unwrap();
        let chain = eng.run(crate::splits::App::Mnist, SplitDecision::Layer).unwrap();
        let full = eng.run(crate::splits::App::Mnist, SplitDecision::Full).unwrap();
        assert_eq!(chain.rows, full.rows);
        for (a, b) in chain.logits.iter().zip(&full.logits) {
            assert!((a - b).abs() < 1e-3, "chain {a} vs full {b}");
        }
    }

    #[test]
    fn warm_populates_cache() {
        let Some(rt) = runtime() else { return };
        let eng = InferenceEngine::new(&rt).unwrap();
        let before = rt.cached();
        eng.warm(crate::splits::App::Cifar100, SplitDecision::Semantic).unwrap();
        assert_eq!(rt.cached(), before + 4);
    }
}
