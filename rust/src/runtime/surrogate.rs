//! DASO surrogate bindings: forward / gradient / AdamW-train-step HLOs with
//! host-side parameter state (fine-tuned online, Algorithm 1 line 14).

use anyhow::{ensure, Context as _, Result};

use super::artifacts::SurrogateArtifacts;
use super::client::{literal_f32, literal_scalar, Runtime};

/// Runtime surrogate instance: compiled executables + current parameters.
pub struct Surrogate<'rt> {
    rt: &'rt Runtime,
    pub spec: SurrogateArtifacts,
    /// Flat parameter tensors (w1, b1, w2, b2, w3, b3) as host vectors.
    params: Vec<Vec<f32>>,
    /// Device-resident parameter buffers (§Perf iterations 1+4: rebuilding
    /// host literals copied ~8 MB per gradient call, and the crate's
    /// `execute` leaked its implicit input buffers; staging once and
    /// executing with `execute_b` fixes both). Invalidated by train_step.
    params_buf: Option<Vec<xla::PjRtBuffer>>,
    /// AdamW moments.
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    /// AdamW step counter (bias correction).
    step: f32,
}

impl<'rt> Surrogate<'rt> {
    /// Load the variant for a worker count and its initial parameters.
    pub fn for_workers(rt: &'rt Runtime, workers: usize) -> Result<Self> {
        let spec = rt.manifest.surrogate_for(workers)?.clone();
        let init = rt.manifest.read_f32(&spec.init)?;
        let mut params = Vec::new();
        let mut off = 0;
        for shape in &spec.param_shapes {
            let n: usize = shape.iter().product();
            ensure!(off + n <= init.len(), "init blob too small");
            params.push(init[off..off + n].to_vec());
            off += n;
        }
        ensure!(off == init.len(), "init blob has trailing data");
        let m = params.iter().map(|p| vec![0.0; p.len()]).collect();
        let v = params.iter().map(|p| vec![0.0; p.len()]).collect();
        // pre-compile all three programs
        rt.executable(&spec.fwd)?;
        rt.executable(&spec.grad)?;
        rt.executable(&spec.train)?;
        Ok(Surrogate { rt, spec, params, params_buf: None, m, v, step: 0.0 })
    }

    pub fn feature_dim(&self) -> usize {
        self.spec.feature_dim
    }

    pub fn slots(&self) -> usize {
        self.spec.slots
    }

    pub fn workers(&self) -> usize {
        self.spec.workers
    }

    fn build_param_literals(&self) -> Result<Vec<xla::Literal>> {
        self.params
            .iter()
            .zip(&self.spec.param_shapes)
            .map(|(p, s)| {
                let dims: Vec<i64> = s.iter().map(|&d| d as i64).collect();
                literal_f32(p, &dims)
            })
            .collect()
    }

    /// Device-resident parameter buffers; re-staged only after a train step.
    fn param_buffers(&mut self) -> Result<&[xla::PjRtBuffer]> {
        if self.params_buf.is_none() {
            let bufs = self
                .params
                .iter()
                .zip(&self.spec.param_shapes)
                .map(|(p, s)| self.rt.buffer_f32(p, s))
                .collect::<Result<Vec<_>>>()?;
            self.params_buf = Some(bufs);
        }
        Ok(self.params_buf.as_deref().unwrap())
    }

    /// f([S,P,D]; θ) → scalar objective estimate.
    pub fn fwd(&mut self, x: &[f32]) -> Result<f32> {
        ensure!(x.len() == self.spec.feature_dim, "feature dim mismatch");
        let x_buf = self.rt.buffer_f32(x, &[x.len()])?;
        let hlo = self.spec.fwd.clone();
        let rt = self.rt;
        let params = self.param_buffers()?;
        let mut inputs: Vec<&xla::PjRtBuffer> = params.iter().collect();
        inputs.push(&x_buf);
        let out = rt.run_b(&hlo, &inputs)?;
        Ok(out[0].to_vec::<f32>()?[0])
    }

    /// Batched scoring of `fwd_batch_size` candidate feature vectors.
    pub fn fwd_batch(&mut self, xb: &[f32]) -> Result<Vec<f32>> {
        let b = self.spec.fwd_batch_size;
        ensure!(xb.len() == b * self.spec.feature_dim, "batch shape mismatch");
        let x_buf = self.rt.buffer_f32(xb, &[b, self.spec.feature_dim])?;
        let hlo = self.spec.fwd_batch.clone();
        let rt = self.rt;
        let params = self.param_buffers()?;
        let mut inputs: Vec<&xla::PjRtBuffer> = params.iter().collect();
        inputs.push(&x_buf);
        let out = rt.run_b(&hlo, &inputs)?;
        Ok(out[0].to_vec::<f32>()?)
    }

    /// (f, ∂f/∂x) — the placement loop reads the P-segment of the gradient.
    pub fn grad(&mut self, x: &[f32]) -> Result<(f32, Vec<f32>)> {
        ensure!(x.len() == self.spec.feature_dim, "feature dim mismatch");
        let x_buf = self.rt.buffer_f32(x, &[x.len()])?;
        let hlo = self.spec.grad.clone();
        let rt = self.rt;
        let params = self.param_buffers()?;
        let mut inputs: Vec<&xla::PjRtBuffer> = params.iter().collect();
        inputs.push(&x_buf);
        let out = rt.run_b(&hlo, &inputs)?;
        let y = out[0].to_vec::<f32>()?[0];
        let dx = out[1].to_vec::<f32>()?;
        Ok((y, dx))
    }

    /// One AdamW step on MSE over a minibatch (xb row-major [B,F], yb [B]).
    /// Returns the pre-step loss.
    pub fn train_step(&mut self, xb: &[f32], yb: &[f32]) -> Result<f32> {
        let b = self.spec.train_batch;
        ensure!(xb.len() == b * self.spec.feature_dim, "xb shape mismatch");
        ensure!(yb.len() == b, "yb shape mismatch");
        self.step += 1.0;

        let mut inputs = self.build_param_literals()?;
        for (mm, s) in self.m.iter().zip(&self.spec.param_shapes) {
            let dims: Vec<i64> = s.iter().map(|&d| d as i64).collect();
            inputs.push(literal_f32(mm, &dims)?);
        }
        for (vv, s) in self.v.iter().zip(&self.spec.param_shapes) {
            let dims: Vec<i64> = s.iter().map(|&d| d as i64).collect();
            inputs.push(literal_f32(vv, &dims)?);
        }
        inputs.push(literal_scalar(self.step));
        inputs.push(literal_f32(xb, &[b as i64, self.spec.feature_dim as i64])?);
        inputs.push(literal_f32(yb, &[b as i64])?);

        let out = self.rt.run(&self.spec.train, &inputs)?;
        let np = self.params.len();
        ensure!(out.len() == 1 + 3 * np, "train output arity");
        let loss = out[0].to_vec::<f32>()?[0];
        for i in 0..np {
            self.params[i] = out[1 + i].to_vec::<f32>()?;
            self.m[i] = out[1 + np + i].to_vec::<f32>()?;
            self.v[i] = out[1 + 2 * np + i].to_vec::<f32>()?;
        }
        self.params_buf = None; // invalidate the device-buffer cache
        Ok(loss)
    }

    /// Pre-train on a trace buffer until the loss plateaus (used by the
    /// experiment runner to reproduce the paper's offline GOBI training).
    pub fn pretrain(
        &mut self,
        buf: &crate::workload::trace::TraceBuffer,
        steps: usize,
        rng: &mut crate::util::rng::Rng,
    ) -> Result<f32> {
        let mut last = f32::NAN;
        for _ in 0..steps {
            if let Some((xb, yb)) =
                buf.minibatch(self.spec.train_batch, |n| rng.below(n as u64) as usize)
            {
                last = self.train_step(&xb, &yb).context("pretrain step")?;
            }
        }
        Ok(last)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use std::path::PathBuf;

    fn runtime() -> Option<Runtime> {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !d.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(Runtime::load(d.to_str().unwrap()).unwrap())
    }

    #[test]
    fn fwd_and_grad_consistent() {
        let Some(rt) = runtime() else { return };
        let mut s = Surrogate::for_workers(&rt, 10).unwrap();
        let f = s.feature_dim();
        let mut rng = Rng::new(3);
        let x: Vec<f32> = (0..f).map(|_| rng.f64() as f32).collect();
        let y0 = s.fwd(&x).unwrap();
        let (y1, dx) = s.grad(&x).unwrap();
        assert!((y0 - y1).abs() < 1e-3, "fwd {y0} vs grad-value {y1}");
        assert_eq!(dx.len(), f);
        // gradient should predict a small step's effect (directional check)
        let eps = 1e-3f32;
        let gnorm2: f32 = dx.iter().map(|g| g * g).sum();
        if gnorm2 > 1e-12 {
            let x2: Vec<f32> = x.iter().zip(&dx).map(|(xi, gi)| xi + eps * gi).collect();
            let y2 = s.fwd(&x2).unwrap();
            assert!(
                y2 > y0 - 1e-4,
                "ascent along gradient must not decrease f: {y0} -> {y2}"
            );
        }
    }

    #[test]
    fn train_reduces_loss_on_fixed_batch() {
        let Some(rt) = runtime() else { return };
        let mut s = Surrogate::for_workers(&rt, 10).unwrap();
        let b = s.spec.train_batch;
        let f = s.feature_dim();
        let mut rng = Rng::new(4);
        let xb: Vec<f32> = (0..b * f).map(|_| rng.f64() as f32).collect();
        let yb: Vec<f32> = (0..b).map(|_| rng.f64() as f32).collect();
        let first = s.train_step(&xb, &yb).unwrap();
        let mut last = first;
        for _ in 0..25 {
            last = s.train_step(&xb, &yb).unwrap();
        }
        assert!(
            last < first * 0.6,
            "loss should drop on a fixed batch: {first} -> {last}"
        );
    }

    #[test]
    fn batched_fwd_matches_scalar() {
        let Some(rt) = runtime() else { return };
        let mut s = Surrogate::for_workers(&rt, 10).unwrap();
        let f = s.feature_dim();
        let b = s.spec.fwd_batch_size;
        let mut rng = Rng::new(5);
        let xb: Vec<f32> = (0..b * f).map(|_| rng.f64() as f32).collect();
        let ys = s.fwd_batch(&xb).unwrap();
        assert_eq!(ys.len(), b);
        for i in [0usize, b - 1] {
            let yi = s.fwd(&xb[i * f..(i + 1) * f]).unwrap();
            assert!((ys[i] - yi).abs() < 1e-3, "row {i}: {} vs {yi}", ys[i]);
        }
    }
}
